#!/usr/bin/env bash
# Tier-1 gate for the rust/ crate, split into CI lanes. Run from anywhere.
#
#   ci/rust.sh fast         style gates only: rustfmt + clippy (-D warnings) +
#                           rustdoc (-D warnings, --no-deps) — the quick PR
#                           signal, fails in a couple of minutes
#   ci/rust.sh msrv         cargo check on the pinned MSRV toolchain (the
#                           rust-fast matrix's second cell: fmt/clippy output
#                           varies across versions, a type check does not)
#   ci/rust.sh full         release build + tests
#   ci/rust.sh simd         SIMD kernel lane: the full test suite with
#                           dispatch forced scalar (DAQ_SIMD=off), then —
#                           on runners whose CPU advertises AVX2 — the
#                           same suite rebuilt with
#                           RUSTFLAGS="-C target-feature=+avx2" so the
#                           vector arms compile with the ISA statically
#                           enabled as well as runtime-detected
#   ci/rust.sh determinism  tests/streaming.rs across the CI matrix
#                           {DAQ_TEST_WORKERS: 1, 4} x {DAQ_TEST_DEPTH: 1, 3}
#                           x {DAQ_SIMD: detect, off};
#                           every cell must produce byte-identical shards
#                           (each asserts against the env-independent
#                           in-memory pipeline AND the workers=1/depth=1
#                           anchor store)
#   ci/rust.sh chaos        tests/fault.rs across the fault matrix
#                           {DAQ_FAULT_SEED: 0, 7, 1234} x
#                           {DAQ_TEST_WORKERS: 1, 4} plus a DAQ_SIMD=off
#                           cell at seed 0; the seed relocates the
#                           injected faults (each test probes it into
#                           a usable regime), the workers axis shakes the
#                           retry/quarantine plumbing under parallelism,
#                           and the forced-scalar cell proves recovery is
#                           dispatch-independent
#   ci/rust.sh              fast + full (the local pre-push default)
#
# Every cargo invocation passes --locked so drift in the vendored shims
# (rust/vendor/*) or a hand-edited manifest is caught at the gate — cargo
# refuses to silently rewrite Cargo.lock. A belt-and-braces git check
# fails the lane if anything dirtied the lock file anyway.
set -euo pipefail
cd "$(dirname "$0")/../rust"

mode="${1:-all}"

run_fast() {
  cargo fmt --check
  cargo clippy --locked --all-targets -- -D warnings
  # rustdoc is a gate, not a suggestion: broken intra-doc links or
  # malformed doc comments fail the lane like any other warning
  RUSTDOCFLAGS="-D warnings" cargo doc --locked --no-deps
}

run_msrv() {
  cargo check --locked --all-targets
}

run_full() {
  cargo build --locked --release
  cargo test --locked -q
}

run_simd() {
  # the whole suite with the kernel layer pinned to the scalar reference:
  # every bitwise contract must hold no matter what the runner's CPU has
  echo "== simd cell: DAQ_SIMD=off =="
  DAQ_SIMD=off cargo test --locked -q
  # rebuild with AVX2 statically enabled where the runner supports it —
  # catches codegen differences between runtime-detected and
  # statically-enabled vector arms (same dispatch, different baseline ISA)
  if grep -q avx2 /proc/cpuinfo 2>/dev/null; then
    echo "== simd cell: RUSTFLAGS=-C target-feature=+avx2 =="
    RUSTFLAGS="-C target-feature=+avx2" cargo test --locked -q
  else
    echo "== simd cell: +avx2 build skipped (runner CPU has no AVX2) =="
  fi
}

run_determinism() {
  for simd in detect off; do
    for workers in 1 4; do
      for depth in 1 3; do
        echo "== determinism cell: workers=${workers} depth=${depth} simd=${simd} =="
        if [ "$simd" = off ]; then
          DAQ_SIMD=off DAQ_TEST_WORKERS="$workers" DAQ_TEST_DEPTH="$depth" \
            cargo test --locked -q --test streaming
        else
          DAQ_TEST_WORKERS="$workers" DAQ_TEST_DEPTH="$depth" \
            cargo test --locked -q --test streaming
        fi
      done
    done
  done
}

run_chaos() {
  for seed in 0 7 1234; do
    for workers in 1 4; do
      echo "== chaos cell: fault_seed=${seed} workers=${workers} =="
      DAQ_FAULT_SEED="$seed" DAQ_TEST_WORKERS="$workers" \
        cargo test --locked -q --test fault
    done
  done
  # forced-scalar cell: fault recovery must not depend on dispatch mode
  echo "== chaos cell: fault_seed=0 workers=4 simd=off =="
  DAQ_SIMD=off DAQ_FAULT_SEED=0 DAQ_TEST_WORKERS=4 \
    cargo test --locked -q --test fault
}

case "$mode" in
  fast) run_fast ;;
  msrv) run_msrv ;;
  full) run_full ;;
  simd) run_simd ;;
  determinism) run_determinism ;;
  chaos) run_chaos ;;
  all)
    # style gates first: a fmt/clippy violation should surface in the
    # couple of minutes the fast lane promises, not after a full build
    run_fast
    run_full
    ;;
  *)
    echo "usage: ci/rust.sh [fast|msrv|full|simd|determinism|chaos|all]" >&2
    exit 2
    ;;
esac

# fail on a dirty Cargo.lock: --locked should have refused already, but a
# stale checkout or a tool writing through the lock must not pass
# silently. Compare against HEAD (catches staged drift too) and refuse an
# untracked lock — --locked means nothing if the file isn't committed.
if command -v git >/dev/null 2>&1 \
    && git rev-parse --is-inside-work-tree >/dev/null 2>&1; then
  if ! git ls-files --error-unmatch Cargo.lock >/dev/null 2>&1; then
    echo "error: Cargo.lock is untracked — commit it so --locked is enforced" >&2
    exit 1
  fi
  if ! git diff HEAD --exit-code -- Cargo.lock; then
    echo "error: Cargo.lock is dirty after the '$mode' lane" >&2
    exit 1
  fi
fi
