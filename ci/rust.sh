#!/usr/bin/env bash
# Tier-1 gate for the rust/ crate: release build + tests, then the style
# gates (rustfmt, clippy with warnings denied). Run from anywhere.
set -euo pipefail
cd "$(dirname "$0")/../rust"

cargo build --release
cargo test -q
cargo fmt --check
cargo clippy --all-targets -- -D warnings
