//! Integration test: the full quantization pipeline on the real trained
//! checkpoints (native engine — no PJRT dependency), asserting the
//! invariants the paper's tables rely on. Skips politely when artifacts
//! are missing.

use daq::coordinator::Method;
use daq::experiments::{Lab, PAPER_RANGES};
use daq::io::dts::Dts;
use daq::quant::Granularity;
use daq::search::Objective;

fn open_lab() -> Option<Lab> {
    match Lab::open(
        &std::env::var("DAQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
        false,
    ) {
        Ok(l) => Some(l),
        Err(e) => {
            eprintln!("skipped: {e:#} (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn small_delta_regime_holds() {
    let Some(lab) = open_lab() else { return };
    // the trained pair must be in the paper's operative regime:
    // ||dW|| well below ||W||
    let mut d2 = 0.0f64;
    let mut w2 = 0.0f64;
    for name in &lab.quantizable {
        let wp = lab.post.tensor_f32(name).unwrap();
        let wb = lab.base.tensor_f32(name).unwrap();
        d2 += (wp.sub(&wb).norm() as f64).powi(2);
        w2 += (wb.norm() as f64).powi(2);
    }
    let ratio = (d2 / w2).sqrt();
    assert!(ratio < 0.25, "delta ratio {ratio:.3} too large for the DAQ regime");
    assert!(ratio > 1e-4, "delta ratio {ratio:.6} suspiciously small — did SFT run?");
}

#[test]
fn search_objectives_improve_their_own_metric() {
    let Some(lab) = open_lab() else { return };
    let gran = Granularity::Block(128);
    let absmax = lab.quantize_native(gran, Method::AbsMax).unwrap();
    let a0 = absmax.agg.unwrap();

    for (obj, check) in [
        (Objective::SignRate, "sign"),
        (Objective::CosSim, "cos"),
    ] {
        let out = lab
            .quantize_native(gran, Method::Search { objective: obj, range: PAPER_RANGES[1] })
            .unwrap();
        let a = out.agg.unwrap();
        match check {
            "sign" => assert!(
                a.sign_rate() >= a0.sign_rate() - 1e-9,
                "sign search must not reduce model-level sign rate: {} vs {}",
                a.sign_rate(), a0.sign_rate()
            ),
            _ => assert!(
                a.cos_sim() >= a0.cos_sim() - 1e-9,
                "cos search must not reduce model-level cos: {} vs {}",
                a.cos_sim(), a0.cos_sim()
            ),
        }
    }
}

#[test]
fn mse_search_reduces_mse_but_not_delta_fidelity() {
    let Some(lab) = open_lab() else { return };
    let gran = Granularity::PerChannel;
    let absmax = lab.quantize_native(gran, Method::AbsMax).unwrap();
    let mse = lab
        .quantize_native(gran, Method::Search {
            objective: Objective::NegMse,
            range: PAPER_RANGES[0],
        })
        .unwrap();
    let (a0, a1) = (absmax.agg.unwrap(), mse.agg.unwrap());
    // Eq. 3 under -MSE: reconstruction error must not get worse
    assert!(a1.mse() <= a0.mse() + 1e-12);
}

#[test]
fn quantized_checkpoint_roundtrip_and_eval() {
    let Some(lab) = open_lab() else { return };
    let out = lab
        .quantize_native(Granularity::Block(128), Method::Search {
            objective: Objective::SignRate,
            range: PAPER_RANGES[1],
        })
        .unwrap();
    assert_eq!(out.layers.len(), lab.quantizable.len());

    // every quantizable layer quantized exactly once, alpha within range
    // (or the α=1 default)
    for l in &out.layers {
        assert!(
            l.alpha == 1.0 || (0.8..=1.25).contains(&l.alpha),
            "{}: alpha {}", l.name, l.alpha
        );
        assert_eq!(l.evals, 16, "paper budget: 1 default + 5 coarse + 10 fine");
    }

    let tmp = std::env::temp_dir().join(format!("daq_e2e_{}.dts", std::process::id()));
    out.write_checkpoint(tmp.to_str().unwrap(), &lab.post.meta).unwrap();
    let rd = Dts::read(&tmp).unwrap();
    std::fs::remove_file(&tmp).unwrap();

    // the checkpoint contains dequantized weights + sidecars, and scores
    // must be computable from the reloaded params
    let params = daq::eval::load_params_filtered(&rd).unwrap();
    let (style, general) = lab.rubric(&params).unwrap();
    assert!((0.0..=2.0).contains(&style));
    assert!((0.0..=2.0).contains(&general));
}

#[test]
fn baseline_rows_are_reproducible() {
    let Some(lab) = open_lab() else { return };
    let a = lab.quantize_native(Granularity::Block(128), Method::AbsMax).unwrap();
    let b = lab.quantize_native(Granularity::Block(128), Method::AbsMax).unwrap();
    let (sa, sb) = (a.agg.unwrap(), b.agg.unwrap());
    assert_eq!(sa.agree, sb.agree);
    assert_eq!(sa.sq, sb.sq);
}
