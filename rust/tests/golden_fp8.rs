//! Cross-layer golden test: the Rust FP8 codec must reproduce the JAX
//! reference bit-for-bit on the vectors `aot.py` exported. Skips politely
//! when artifacts have not been built yet.

use daq::fp8::{decode_e4m3, encode_e4m3, qdq_e4m3};
use daq::io::dts::Dts;

fn golden() -> Option<Dts> {
    let dir = std::env::var("DAQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    Dts::read(format!("{dir}/fp8_golden.dts")).ok()
}

#[test]
fn qdq_matches_jax_bit_exact() {
    let Some(d) = golden() else {
        eprintln!("skipped: run `make artifacts` first");
        return;
    };
    let inputs = d.tensor_f32("inputs").unwrap().into_data();
    let qdq = d.tensor_f32("qdq").unwrap().into_data();
    for (i, (&x, &want)) in inputs.iter().zip(&qdq).enumerate() {
        let got = qdq_e4m3(x);
        assert_eq!(got.to_bits(), want.to_bits(),
                   "vector {i}: qdq({x}) = {got} want {want}");
    }
}

#[test]
fn encode_matches_jax_bit_exact() {
    let Some(d) = golden() else {
        eprintln!("skipped: run `make artifacts` first");
        return;
    };
    let inputs = d.tensor_f32("inputs").unwrap().into_data();
    let (_, codes) = d.tensor_u8("codes").unwrap();
    for (i, (&x, &want)) in inputs.iter().zip(&codes).enumerate() {
        assert_eq!(encode_e4m3(x), want, "vector {i}: encode({x})");
    }
}

#[test]
fn decode_matches_jax_on_all_256_codes() {
    let Some(d) = golden() else {
        eprintln!("skipped: run `make artifacts` first");
        return;
    };
    let decoded = d.tensor_f32("all_codes_decoded").unwrap().into_data();
    let (_, nan_mask) = d.tensor_u8("all_codes_nan").unwrap();
    for c in 0..256usize {
        let got = decode_e4m3(c as u8);
        if nan_mask[c] == 1 {
            assert!(got.is_nan(), "code {c:#04x} should be NaN");
        } else {
            assert_eq!(got.to_bits(), decoded[c].to_bits(), "code {c:#04x}");
        }
    }
}
