//! Dispatch-level contracts of the SIMD kernel layer
//! (`daq::quant::kernels`): bitwise decode/GEMV/GEMM equality between
//! every vector mode this machine supports and the always-compiled
//! scalar reference, the 1e-9 sweep-objective bar plus worker-count
//! invariance on a fixed ISA, serve-completion stability across
//! dispatch modes, and the `DAQ_SIMD`/`force` semantics themselves.
//!
//! The dispatch mode is process-global state, so every test that forces
//! it serializes behind [`DISPATCH`]; the library's own unit tests never
//! call `force` (they invoke the per-ISA kernel bodies directly), which
//! keeps `cargo test`'s parallel suites race-free.

use std::sync::{Mutex, MutexGuard};

use daq::metrics::SweepPlan;
use daq::quant::kernels::{self, SimdMode};
use daq::quant::{
    absmax_scales_fmt, matmul_quant, matvec_quant_into, quantize_fmt, CodeFormat, Granularity,
};
use daq::tensor::Tensor;
use daq::util::proptest::{run, Config};
use daq::util::rng::XorShift;

static DISPATCH: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    // a panic inside one test (e.g. a shrinking proptest case) must not
    // poison the dispatch lock for the rest of the binary
    DISPATCH.lock().unwrap_or_else(|e| e.into_inner())
}

/// Every non-scalar mode this machine can execute.
fn vector_modes() -> Vec<SimdMode> {
    [SimdMode::Sse41, SimdMode::Avx2, SimdMode::Neon]
        .into_iter()
        .filter(|&m| kernels::supported(m))
        .collect()
}

fn with_mode<T>(mode: SimdMode, f: impl FnOnce() -> T) -> T {
    let prev = kernels::force(mode);
    let out = f();
    kernels::force(prev);
    out
}

const FORMATS: [CodeFormat; 3] =
    [CodeFormat::Fp8E4m3, CodeFormat::Fp8E5m2, CodeFormat::Int4 { group: 64 }];

fn assert_bits(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what} length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "{what}[{i}]: {g} vs {w}");
    }
}

/// The sweep's planned-vs-native agreement bar, reused for SIMD-vs-scalar.
fn assert_close(x: f64, y: f64, what: &str) {
    assert!((x - y).abs() <= 1e-9 * x.abs().max(1e-9), "{what}: {x} vs {y}");
}

#[test]
fn force_returns_previous_and_clamps_unsupported() {
    let _g = lock();
    let prev = kernels::force(SimdMode::Scalar);
    assert_eq!(kernels::active(), SimdMode::Scalar);
    assert_eq!(kernels::label(), "scalar");
    for mode in [SimdMode::Sse41, SimdMode::Avx2, SimdMode::Neon] {
        let before = kernels::active();
        let got = kernels::force(mode);
        assert_eq!(got, before, "force must return the mode it replaced");
        if kernels::supported(mode) {
            assert_eq!(kernels::active(), mode);
            assert_eq!(kernels::label(), kernels::mode_label(mode));
        } else {
            // forcing an ISA the machine lacks must clamp to scalar, not
            // dispatch into instructions that would fault
            assert_eq!(kernels::active(), SimdMode::Scalar);
        }
        kernels::force(SimdMode::Scalar);
    }
    kernels::force(prev);
}

#[test]
fn daq_simd_env_grammar() {
    for off in ["off", "OFF", "scalar", "0"] {
        assert_eq!(kernels::parse_mode(off), SimdMode::Scalar, "{off}");
    }
    for (name, mode) in [
        ("sse4.1", SimdMode::Sse41),
        ("sse41", SimdMode::Sse41),
        ("avx2", SimdMode::Avx2),
        ("neon", SimdMode::Neon),
    ] {
        // a named ISA resolves to itself where supported and degrades to
        // scalar elsewhere — never a silent upgrade to a different ISA
        let want = if kernels::supported(mode) { mode } else { SimdMode::Scalar };
        assert_eq!(kernels::parse_mode(name), want, "{name}");
    }
    // anything else auto-detects: always a supported mode, and stable
    let auto = kernels::parse_mode("auto");
    assert!(kernels::supported(auto));
    assert_eq!(kernels::parse_mode("definitely-not-an-isa"), auto);
}

#[test]
fn proptest_decode_kernels_bitwise_equal_across_modes() {
    let _g = lock();
    run("simd decode bitwise", Config { cases: 48, ..Config::default() }, |g| {
        // widths cover empty, sub-lane, non-multiple-of-lane tails, and
        // multiple full vectors for every ISA's lane count
        let n = g.usize_range(0, 70);
        let codes: Vec<u8> = (0..n).map(|_| g.u64() as u8).collect();
        let mut want = vec![0.0f32; n];
        let mut got = vec![0.0f32; n];
        daq::fp8::decode_slice_into_scalar(&codes, &mut want);
        for mode in vector_modes() {
            got.fill(0.0);
            with_mode(mode, || kernels::decode_e4m3_into(&codes, &mut got));
            assert_bits(&got, &want, "e4m3");
        }
        daq::fp8::decode_slice_into_e5m2_scalar(&codes, &mut want);
        for mode in vector_modes() {
            got.fill(0.0);
            with_mode(mode, || kernels::decode_e5m2_into(&codes, &mut got));
            assert_bits(&got, &want, "e5m2");
        }
        // packed INT4 at odd element counts: the last byte is half-used
        let n4 = g.usize_range(0, 70);
        let nibbles: Vec<u8> = (0..n4).map(|_| (g.u64() % 16) as u8).collect();
        let packed = daq::quant::format::pack_int4(&nibbles);
        let mut want4 = vec![0.0f32; n4];
        let mut got4 = vec![0.0f32; n4];
        daq::quant::format::decode_int4_slice_into_scalar(&packed, &mut want4);
        for mode in vector_modes() {
            got4.fill(0.0);
            with_mode(mode, || kernels::decode_int4_into(&packed, &mut got4));
            assert_bits(&got4, &want4, "int4");
        }
    });
}

#[test]
fn proptest_dequant_and_gemm_bitwise_equal_across_modes() {
    let _g = lock();
    run("simd dequant/gemm bitwise", Config { cases: 16, ..Config::default() }, |g| {
        let k = g.usize_range(1, 24);
        let n = g.usize_range(1, 70);
        let m = g.usize_range(1, 4);
        let w = Tensor::new(vec![k, n], g.normal_vec(k * n, 0.3));
        let fmt = *g.pick(&FORMATS);
        // rank-0 (no residual) and rank-4 low-rank correction both ride
        // through kernels::axpy in dequant_row_into
        let rank = *g.pick(&[0usize, 4]);
        let gran = *g.pick(&[
            Granularity::PerTensor,
            Granularity::PerChannel,
            Granularity::Block(16),
        ]);
        let q = quantize_fmt(&w, gran, fmt, 1.0, rank);
        let x = Tensor::new(vec![m, k], g.normal_vec(m * k, 1.0));

        let run_all = || {
            let mut rows = vec![0.0f32; k * n];
            for (r, chunk) in rows.chunks_mut(n).enumerate() {
                q.dequant_row_into(r, chunk);
            }
            let mut mv = vec![0.0f32; n];
            let mut scratch = vec![0.0f32; n];
            matvec_quant_into(&x.data()[..k], &q, &mut mv, &mut scratch);
            let mm = matmul_quant(&x, &q);
            (rows, mv, mm.data().to_vec())
        };
        let want = with_mode(SimdMode::Scalar, &run_all);
        for mode in vector_modes() {
            let got = with_mode(mode, &run_all);
            let tag = format!("{fmt:?} rank {rank} {gran:?} {mode:?}");
            assert_bits(&got.0, &want.0, &format!("dequant rows ({tag})"));
            assert_bits(&got.1, &want.1, &format!("matvec ({tag})"));
            assert_bits(&got.2, &want.2, &format!("matmul ({tag})"));
        }
    });
}

#[test]
fn sweep_objectives_simd_vs_scalar_within_1e9_and_worker_invariant() {
    let _g = lock();
    let mut rng = XorShift::new(0x51D);
    let alphas: Vec<f32> = (0..16).map(|i| 0.8 + 0.028 * i as f32).collect();
    // 37x133 spans multiple tiles at the default tile size's divisors and
    // makes Block(16) ragged on both axes
    let (r, c) = (37usize, 133usize);
    for fmt in FORMATS {
        let wb = Tensor::new(vec![r, c], rng.normal_vec(r * c, 0.1));
        let wp = Tensor::new(
            vec![r, c],
            wb.data().iter().map(|&b| b + rng.normal() * 0.002).collect(),
        );
        let s0 = absmax_scales_fmt(&wp, Granularity::Block(16), fmt);
        let plan = SweepPlan::new(&wp, &wb, &s0);
        let want = with_mode(SimdMode::Scalar, || plan.eval_with_workers(&alphas, 1));
        for mode in vector_modes() {
            let got = with_mode(mode, || plan.eval_with_workers(&alphas, 1));
            assert_eq!(got.len(), want.len());
            for (cand, (g, w)) in got.iter().zip(&want).enumerate() {
                let tag = format!("{fmt:?} cand {cand} {mode:?}");
                // the per-element projection is bitwise-equal, so the
                // integer agreement count matches exactly; only the f64
                // reduction order differs, bounded by the sweep's bar
                assert_eq!(g.agree, w.agree, "{tag} agree");
                assert_eq!(g.n, w.n, "{tag} n");
                assert_eq!(g.npost.to_bits(), w.npost.to_bits(), "{tag} npost");
                assert_close(g.dot, w.dot, &format!("{tag} dot"));
                assert_close(g.nq, w.nq, &format!("{tag} nq"));
                assert_close(g.sq, w.sq, &format!("{tag} sq"));
                assert_close(g.sign_rate(), w.sign_rate(), &format!("{tag} sign_rate"));
                assert_close(g.cos_sim(), w.cos_sim(), &format!("{tag} cos_sim"));
            }
            // on a fixed ISA the reduction order is worker-invariant:
            // bitwise-identical objectives no matter the thread count
            let w1 = with_mode(mode, || plan.eval_with_workers(&alphas, 1));
            let w3 = with_mode(mode, || plan.eval_with_workers(&alphas, 3));
            for (cand, (a, b)) in w1.iter().zip(&w3).enumerate() {
                let tag = format!("{fmt:?} cand {cand} {mode:?} workers 1 vs 3");
                assert_eq!(a.agree, b.agree, "{tag} agree");
                assert_eq!(a.dot.to_bits(), b.dot.to_bits(), "{tag} dot");
                assert_eq!(a.nq.to_bits(), b.nq.to_bits(), "{tag} nq");
                assert_eq!(a.sq.to_bits(), b.sq.to_bits(), "{tag} sq");
            }
        }
    }
}

#[test]
fn serve_completions_bitwise_identical_across_modes() {
    let _g = lock();
    use daq::eval::decode::Decoder;
    use daq::eval::model_native::{synth_params, synth_quantized, ModelCfg};
    use daq::serve::{gen_requests, serve, ServeConfig};

    let cfg = ModelCfg { vocab: 64, d_model: 48, n_layer: 2, n_head: 4, d_ff: 96, seq_len: 24 };
    let params = synth_params(&cfg, 2024);
    let mut quantizable: Vec<String> = Vec::new();
    for l in 0..cfg.n_layer {
        for w in ["wq", "wk", "wv", "wo", "w1", "w2"] {
            quantizable.push(format!("l{l}.{w}"));
        }
    }
    quantizable.push("head".into());
    let qp = synth_quantized(&params, &quantizable, Granularity::Block(128));
    let dec = Decoder::new(&qp, cfg);
    let reqs = gen_requests(6, 42);
    let scfg = ServeConfig { slots: 4, new_tokens: 4, ..Default::default() };
    let want = with_mode(SimdMode::Scalar, || serve(&dec, &reqs, &scfg).unwrap());
    for mode in vector_modes() {
        let got = with_mode(mode, || serve(&dec, &reqs, &scfg).unwrap());
        assert_eq!(
            got.completions, want.completions,
            "quantized-serve completions must be bitwise-identical under {mode:?} and scalar"
        );
    }
}
