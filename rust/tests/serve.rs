//! Integration tests for the quantized-resident serving engine: streaming
//! pipeline → sharded store → `QuantizedParams` → fused dequant-matmul
//! forward / incremental decode / continuous-batching scheduler, end to
//! end, plus the sharded-store coverage for the dequantizing loader.

use std::collections::BTreeMap;
use std::path::PathBuf;

use daq::coordinator::stream::{run_stream, StreamConfig};
use daq::coordinator::Method;
use daq::eval::decode::Decoder;
use daq::eval::model_native::{
    forward_native, synth_params, synth_quantized_fmt, ModelCfg,
};
use daq::eval::{
    load_params_dequant_source, NativeForward, QuantForward, QuantizedParams,
};
use daq::experiments::quantizable_from_source;
use daq::io::dts::{Dts, DtsTensor};
use daq::io::shard::{ShardWriter, ShardedDts};
use daq::io::TensorSource;
use daq::quant::{quantize, CodeFormat, Granularity};
use daq::serve::{gen_requests, serve, serve_reforward, ServeConfig};
use daq::tensor::Tensor;
use daq::util::telemetry::{self, Telemetry};

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("daq_servetest_{tag}_{}", std::process::id()))
}

fn serve_cfg() -> ModelCfg {
    // vocab 64 covers the serve workload's token alphabet (BOS/SEP,
    // content 4..47, style 48..63). GEMM weights dominate this shape on
    // purpose: the resident-bytes acceptance bound (<= 0.35x f32) only
    // holds when the quantizable fraction is transformer-like, not
    // toy-tiny
    ModelCfg { vocab: 64, d_model: 64, n_layer: 2, n_head: 4, d_ff: 128, seq_len: 32 }
}

fn ckpt_from_params(cfg: &ModelCfg, seed: u64) -> Dts {
    let params = synth_params(cfg, seed);
    let mut d = Dts::new();
    let mut names: Vec<&String> = params.keys().collect();
    names.sort();
    for name in names {
        d.insert_f32(name, &params[name]);
    }
    daq::eval::trace::stamp_model_meta(&mut d, cfg);
    d
}

/// Quantize a synthetic model through the *streaming* pipeline into a
/// sharded store, then prove the whole quantized-resident serving path
/// over that store.
#[test]
fn quantized_store_serves_end_to_end() {
    let cfg = serve_cfg();
    let post = ckpt_from_params(&cfg, 101);
    let base = ckpt_from_params(&cfg, 102);
    let quantizable = quantizable_from_source(&post);
    assert_eq!(quantizable.len(), 6 * cfg.n_layer + 1);

    let out_dir = tmp("store");
    let _ = std::fs::remove_dir_all(&out_dir);
    let mut scfg = StreamConfig::new(Granularity::Block(128), Method::AbsMax, 2);
    scfg.shard_budget = 64 << 10;
    run_stream(&post, &base, &quantizable, None, &out_dir, &scfg).unwrap();
    let store = ShardedDts::open(&out_dir).unwrap();

    // the store's model-config metadata survived the streaming pipeline
    let stored_cfg = ModelCfg::from_meta(TensorSource::meta(&store)).unwrap();
    assert_eq!(stored_cfg, cfg);

    // --- loader coverage over the sharded store (previously only the
    //     in-memory Dts path was exercised) ---
    let qp = QuantizedParams::load(&store).unwrap();
    assert_eq!(qp.n_quantized(), quantizable.len());
    let dense = load_params_dequant_source(&store).unwrap();
    let via_store = qp.dequantize_all();
    assert_eq!(dense.len(), via_store.len());
    for (name, t) in &dense {
        let u = &via_store[name];
        assert_eq!(t.shape(), u.shape(), "{name}");
        for (a, b) in t.data().iter().zip(u.data()) {
            assert_eq!(a.to_bits(), b.to_bits(), "{name}");
        }
    }

    // --- acceptance: resident param bytes <= 0.35x of the f32 path ---
    let resident = qp.resident_param_bytes();
    let f32_bytes = qp.f32_param_bytes();
    assert!(
        (resident as f64) <= 0.35 * f32_bytes as f64,
        "resident {resident} vs f32 {f32_bytes} ({:.3}x)",
        resident as f64 / f32_bytes as f64
    );

    // --- acceptance: QuantBackend forward agrees with NativeBackend over
    //     the dequantized params (<= 1e-6 rel; in fact bitwise) ---
    let tokens: Vec<i32> = (0..2 * cfg.seq_len).map(|i| (i % cfg.vocab) as i32).collect();
    let native = forward_native(&dense, &cfg, 2, &tokens).unwrap();
    let qfwd = QuantForward { params: &qp, cfg, batch: 2 };
    let quant = daq::eval::ForwardFn::forward(&qfwd, 2, &tokens).unwrap();
    for (i, (a, b)) in native.iter().zip(&quant).enumerate() {
        let rel = (a - b).abs() / a.abs().max(1e-6);
        assert!(rel <= 1e-6, "logit {i}: {a} vs {b} (rel {rel})");
        assert_eq!(a.to_bits(), b.to_bits(), "logit {i}");
    }

    // --- the continuous-batching scheduler over the quantized store
    //     produces exactly the tokens the full-reforward loop does ---
    let reqs = gen_requests(6, 7);
    let rep = serve(
        &Decoder::new(&qp, cfg),
        &reqs,
        &ServeConfig { slots: 3, new_tokens: 4, ..Default::default() },
    )
    .unwrap();
    assert_eq!(rep.requests, 6);
    assert_eq!(rep.request_latency.count(), 6);
    assert_eq!(rep.resident_param_bytes, resident);
    assert!(rep.peak_active_slots <= 3);
    for gen in &rep.completions {
        assert_eq!(gen.len(), 4);
    }
    let reforward = serve_reforward(&qfwd, &reqs, 4, resident).unwrap();
    assert_eq!(rep.completions, reforward.completions);

    // and the dense-resident scheduler decodes the same tokens too
    // (quantization changed the weights, not the decode semantics)
    let dec_dense = Decoder::new(&dense, cfg);
    let rep_dense = serve(
        &dec_dense,
        &reqs,
        &ServeConfig { slots: 3, new_tokens: 4, ..Default::default() },
    )
    .unwrap();
    let nfwd = NativeForward { params: &dense, cfg, batch: 3 };
    let reforward_dense = serve_reforward(&nfwd, &reqs, 4, f32_bytes).unwrap();
    assert_eq!(rep_dense.completions, reforward_dense.completions);

    std::fs::remove_dir_all(&out_dir).unwrap();
}

/// The tentpole determinism contract, end to end over a real model:
/// identical `ServeReport` completions AND identical telemetry
/// count-metrics (counter map, histogram counts) for every cell of
/// {workers: 1, 4} x {prefill_chunk: 0, 16}. Workers only mutate their
/// own slot's session and the coordinator merges in fixed slot order,
/// so the thread count must be unobservable in anything counted; the
/// 14-token prompts prefill in a single chunk under both settings, so
/// chunking must be unobservable here too.
#[test]
fn serve_is_deterministic_across_workers_and_prefill_chunking() {
    let cfg = serve_cfg();
    let params = synth_params(&cfg, 77);
    let reqs = gen_requests(6, 11);

    type CountMaps = (Vec<Vec<i32>>, BTreeMap<String, u64>, BTreeMap<String, u64>);
    let mut reference: Option<CountMaps> = None;
    for workers in [1usize, 4] {
        for chunk in [0usize, 16] {
            // the Decoder captures its step counter at construction, so
            // it is rebuilt inside each cell's registry context
            let guard = telemetry::set_current(Telemetry::new(&format!(
                "serve-det-w{workers}-c{chunk}"
            )));
            let dec = Decoder::new(&params, cfg);
            let rep = serve(
                &dec,
                &reqs,
                &ServeConfig {
                    slots: 3,
                    new_tokens: 4,
                    workers,
                    prefill_chunk: chunk,
                    ..Default::default()
                },
            )
            .unwrap();
            drop(guard);

            assert_eq!(rep.workers, workers, "w={workers} chunk={chunk}");
            assert_eq!(rep.requests, 6);
            assert_eq!(rep.timed_out, 0);
            assert_eq!(rep.errored, 0);
            let counters = rep.telemetry.counters.clone();
            let hist_counts: BTreeMap<String, u64> = rep
                .telemetry
                .histograms
                .iter()
                .map(|(k, h)| (k.clone(), h.count))
                .collect();
            match &reference {
                None => reference = Some((rep.completions, counters, hist_counts)),
                Some((comp0, counters0, hist0)) => {
                    assert_eq!(
                        &rep.completions, comp0,
                        "completions differ at w={workers} chunk={chunk}"
                    );
                    assert_eq!(
                        &counters, counters0,
                        "counter map differs at w={workers} chunk={chunk}"
                    );
                    assert_eq!(
                        &hist_counts, hist0,
                        "histogram counts differ at w={workers} chunk={chunk}"
                    );
                }
            }
        }
    }
}

/// Deadline eviction is coordinator-side bookkeeping and must keep
/// firing at tick boundaries when the decode fan-out runs on multiple
/// workers: a zero deadline evicts every slot at its first tick, before
/// any token lands, regardless of thread count.
#[test]
fn deadline_eviction_under_multithreaded_decode() {
    let cfg = serve_cfg();
    let params = synth_params(&cfg, 78);
    let dec = Decoder::new(&params, cfg);
    let reqs = gen_requests(4, 3);
    let rep = serve(
        &dec,
        &reqs,
        &ServeConfig {
            slots: 2,
            new_tokens: 4,
            deadline_ms: Some(0.0),
            workers: 4,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(rep.requests, 4);
    assert_eq!(rep.timed_out, 4);
    assert_eq!(rep.request_latency.count(), 4);
    for gen in &rep.completions {
        assert!(gen.is_empty(), "evicted-at-admission request decoded tokens");
    }
}

/// Acceptance: the fused dequant-matmul backend produces bitwise the same
/// logits as the dense NativeBackend over the dequantized (plus residual)
/// weights, for EVERY code format with and without a low-rank residual.
/// The scratch-row decode inside the quantized GEMM keeps the accumulation
/// order identical to a dense matmul over `dequantize()`'s output, and
/// `dequantize()` itself applies the residual, so the two paths see the
/// same f32 values in the same order.
#[test]
fn every_code_format_serves_bitwise_with_and_without_residual() {
    let cfg = serve_cfg();
    let params = synth_params(&cfg, 91);
    let quantizable: Vec<String> = {
        let mut q: Vec<String> = params
            .keys()
            .filter(|n| {
                n.ends_with(".wq") || n.ends_with(".wk") || n.ends_with(".wv")
                    || n.ends_with(".wo") || n.ends_with(".w1")
                    || n.ends_with(".w2") || n.as_str() == "head"
            })
            .cloned()
            .collect();
        q.sort();
        q
    };
    assert_eq!(quantizable.len(), 6 * cfg.n_layer + 1);
    let tokens: Vec<i32> =
        (0..2 * cfg.seq_len).map(|i| (i % cfg.vocab) as i32).collect();

    for fmt in [
        CodeFormat::Fp8E4m3,
        CodeFormat::Fp8E5m2,
        CodeFormat::Int4 { group: 16 },
    ] {
        for rank in [0usize, 2] {
            let qp = synth_quantized_fmt(
                &params,
                &quantizable,
                Granularity::Block(16),
                fmt,
                rank,
            );
            assert_eq!(qp.n_quantized(), quantizable.len());
            let dense = qp.dequantize_all();
            let native = forward_native(&dense, &cfg, 2, &tokens).unwrap();
            let qfwd = QuantForward { params: &qp, cfg, batch: 2 };
            let quant = daq::eval::ForwardFn::forward(&qfwd, 2, &tokens).unwrap();
            assert_eq!(native.len(), quant.len());
            for (i, (a, b)) in native.iter().zip(&quant).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{} rank {rank} logit {i}: {a} vs {b}",
                    fmt.label()
                );
            }
        }
    }

    // INT4 residency really is sub-byte: against the same model, the
    // packed store resides in fewer bytes than the FP8 one
    let qp8 = synth_quantized_fmt(
        &params, &quantizable, Granularity::Block(16), CodeFormat::Fp8E4m3, 0,
    );
    let qp4 = synth_quantized_fmt(
        &params, &quantizable, Granularity::Block(16),
        CodeFormat::Int4 { group: 16 }, 0,
    );
    assert!(
        qp4.resident_param_bytes() < qp8.resident_param_bytes(),
        "int4 {} vs fp8 {}",
        qp4.resident_param_bytes(),
        qp8.resident_param_bytes()
    );
}

/// The codes-without-`gran.<name>`-meta fallback path over a sharded
/// store: the stored f32 copy must win, and a sidecar pair *with* the
/// metadata must stay quantized — both through `ShardedDts`.
#[test]
fn sharded_dequant_loader_gran_meta_fallback() {
    let dir = tmp("fallback");
    let _ = std::fs::remove_dir_all(&dir);

    let w = Tensor::new(vec![6, 10], (0..60).map(|i| (i as f32 - 30.0) * 0.01).collect());
    let qw = quantize(&w, Granularity::PerChannel, 1.0);
    let v = Tensor::new(vec![4, 8], (0..32).map(|i| (i as f32 - 16.0) * 0.02).collect());
    let qv = quantize(&v, Granularity::PerChannel, 1.0);

    let mut writer = ShardWriter::create(&dir, 1 << 20).unwrap();
    // `w`: f32 copy + sidecars but NO gran meta -> fallback to the copy
    writer
        .append(
            "w",
            &DtsTensor::F32 { shape: vec![6, 10], data: w.data().to_vec() },
        )
        .unwrap();
    writer
        .append(
            "w.codes",
            &DtsTensor::U8 { shape: vec![6, 10], data: qw.codes.clone() },
        )
        .unwrap();
    writer
        .append(
            "w.scales",
            &DtsTensor::F32 { shape: vec![1, 10], data: qw.scales.scales.clone() },
        )
        .unwrap();
    // `v`: codes-only WITH gran meta -> quantized-resident, no f32 copy
    writer
        .append(
            "v.codes",
            &DtsTensor::U8 { shape: vec![4, 8], data: qv.codes.clone() },
        )
        .unwrap();
    writer
        .append(
            "v.scales",
            &DtsTensor::F32 { shape: vec![1, 8], data: qv.scales.scales.clone() },
        )
        .unwrap();
    let mut meta = std::collections::BTreeMap::new();
    meta.insert("gran.v".to_string(), "channel".to_string());
    writer.finish(&meta).unwrap();

    let store = ShardedDts::open(&dir).unwrap();
    let p = load_params_dequant_source(&store).unwrap();
    assert_eq!(p.len(), 2);
    // w: bitwise the stored f32 copy, NOT a dequantization of its codes
    for (a, b) in p["w"].data().iter().zip(w.data()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    // v: bitwise the dequantized codes
    let vd = qv.dequantize();
    for (a, b) in p["v"].data().iter().zip(vd.data()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }

    // the quantized-resident loader applies the same policy
    let qp = QuantizedParams::load(&store).unwrap();
    assert_eq!(qp.n_quantized(), 1);
    assert!(qp.dense("w").is_ok());
    assert!(qp.dense("v").is_err());

    std::fs::remove_dir_all(&dir).unwrap();
}
