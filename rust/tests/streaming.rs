//! Integration tests for the bounded-memory streaming pipeline
//! (`coordinator::stream`) against synthetic checkpoints — no artifacts
//! or PJRT required.
//!
//! The acceptance invariants of the streaming subsystem:
//! 1. output is **bitwise-identical** to the in-memory `run_pipeline`
//!    for the same (method, granularity, seed), over both sharded and
//!    monolithic seek-based sources;
//! 2. peak live tensor bytes stay bounded by `depth x (largest unit)`,
//!    not by model size;
//! 3. an interrupted run resumed from a truncated journal skips the
//!    completed layers and converges to the same per-tensor bytes.

use std::collections::BTreeMap;
use std::path::PathBuf;

use daq::coordinator::stream::{run_stream, StreamConfig, RESUME_JOURNAL};
use daq::coordinator::{run_pipeline, Engine, Method, PipelineConfig, PipelineOutcome};
use daq::eval::load_params_dequant_source;
use daq::experiments::quantizable_from_source;
use daq::io::dts::{Dts, DtsReader, DtsTensor};
use daq::io::shard::{shard_dts_file, ShardedDts};
use daq::quant::Granularity;
use daq::search::Objective;
use daq::tensor::Tensor;
use daq::util::rng::XorShift;

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("daq_streamtest_{tag}_{}", std::process::id()))
}

/// Synthetic (post, base) pair: `n_layers` quantizable GEMMs plus
/// layernorm / embedding passthrough tensors.
fn fake_ckpts(seed: u64, n_layers: usize, dim: usize) -> (Dts, Dts) {
    let mut rng = XorShift::new(seed);
    let mut base = Dts::new();
    let mut post = Dts::new();
    base.meta.insert("vocab".into(), "64".into());
    post.meta.insert("vocab".into(), "64".into());
    for i in 0..n_layers {
        let name = match i % 3 {
            0 => format!("l{i}.wq"),
            1 => format!("l{i}.w1"),
            _ => format!("l{i}.w2"),
        };
        let (r, c) = (dim, dim + 8 * (i % 2));
        let wb = Tensor::new(vec![r, c], rng.normal_vec(r * c, 0.1));
        let wp = Tensor::new(
            vec![r, c],
            wb.data().iter().map(|&b| b + rng.normal() * 0.002).collect(),
        );
        base.insert_f32(&name, &wb);
        post.insert_f32(&name, &wp);
        let g = Tensor::full(vec![r], 1.0);
        base.insert_f32(&format!("l{i}.ln1.g"), &g);
        post.insert_f32(&format!("l{i}.ln1.g"), &g);
    }
    let embed = Tensor::new(vec![16, dim], rng.normal_vec(16 * dim, 0.1));
    base.insert_f32("embed", &embed);
    post.insert_f32("embed", &embed);
    (post, base)
}

fn assert_bits_eq(a: &DtsTensor, b: &DtsTensor, what: &str) {
    match (a, b) {
        (
            DtsTensor::F32 { shape: sa, data: da },
            DtsTensor::F32 { shape: sb, data: db },
        ) => {
            assert_eq!(sa, sb, "{what}: shape");
            assert_eq!(da.len(), db.len(), "{what}: len");
            for (x, y) in da.iter().zip(db) {
                assert_eq!(x.to_bits(), y.to_bits(), "{what}");
            }
        }
        _ => assert_eq!(a, b, "{what}"),
    }
}

fn run_both(
    post: &Dts,
    base: &Dts,
    gran: Granularity,
    method: Method,
    tag: &str,
) -> (PipelineOutcome, daq::coordinator::stream::StreamOutcome, ShardedDts) {
    let quantizable = quantizable_from_source(post);
    assert!(!quantizable.is_empty());

    let cfg = PipelineConfig {
        granularity: gran,
        method: method.clone(),
        engine: Engine::Native { workers: 2 },
    };
    let mem = run_pipeline(post, base, &quantizable, None, &cfg, None).unwrap();

    // post goes through a sharded store, base through the seek-based
    // monolithic reader — both streaming source backends in one run
    let post_file = tmp(&format!("{tag}_post_dts")).with_extension("dts");
    post.write(&post_file).unwrap();
    let post_shards = tmp(&format!("{tag}_post_shards"));
    let _ = std::fs::remove_dir_all(&post_shards);
    let (manifest, _) = shard_dts_file(&post_file, &post_shards, 4096).unwrap();
    let post_src = ShardedDts::open(&manifest).unwrap();

    let base_file = tmp(&format!("{tag}_base_dts")).with_extension("dts");
    base.write(&base_file).unwrap();
    let base_src = DtsReader::open(&base_file).unwrap();

    let out_dir = tmp(&format!("{tag}_out"));
    let _ = std::fs::remove_dir_all(&out_dir);
    let mut scfg = StreamConfig::new(gran, method, 2);
    scfg.shard_budget = 8192;
    let streamed =
        run_stream(&post_src, &base_src, &quantizable, &out_dir, &scfg).unwrap();
    let store = ShardedDts::open(&out_dir).unwrap();

    std::fs::remove_file(&post_file).unwrap();
    std::fs::remove_file(&base_file).unwrap();
    std::fs::remove_dir_all(&post_shards).unwrap();
    (mem, streamed, store)
}

#[test]
fn streaming_matches_in_memory_pipeline_bitwise() {
    for (gi, gran) in [Granularity::Block(16), Granularity::PerChannel]
        .into_iter()
        .enumerate()
    {
        for (mi, method) in [
            Method::Search {
                objective: Objective::SignRate,
                range: (0.8, 1.25),
            },
            Method::AbsMax,
        ]
        .into_iter()
        .enumerate()
        {
            let (post, base) = fake_ckpts(11, 5, 32);
            let tag = format!("eq{gi}{mi}");
            let (mem, streamed, store) =
                run_both(&post, &base, gran, method, &tag);

            // per-layer search results identical
            assert_eq!(mem.layers.len(), streamed.layers.len());
            for (a, b) in mem.layers.iter().zip(&streamed.layers) {
                assert_eq!(a.name, b.name);
                assert_eq!(a.alpha.to_bits(), b.alpha.to_bits(), "{}", a.name);
                assert_eq!(a.evals, b.evals);
                assert_eq!(a.stats, b.stats, "{}", a.name);
            }
            // fixed-order model aggregate identical
            assert_eq!(mem.agg.unwrap(), streamed.agg);

            // stored tensors identical: codes, scales, dequantized weights
            for (name, q) in &mem.quantized {
                let codes = store.read_tensor(&format!("{name}.codes")).unwrap();
                assert_bits_eq(
                    &codes,
                    &DtsTensor::U8 {
                        shape: vec![q.shape.0, q.shape.1],
                        data: q.codes.clone(),
                    },
                    &format!("{name}.codes"),
                );
                let scales = store.read_tensor(&format!("{name}.scales")).unwrap();
                assert_bits_eq(
                    &scales,
                    &DtsTensor::F32 {
                        shape: vec![q.scales.grid_rows, q.scales.grid_cols],
                        data: q.scales.scales.clone(),
                    },
                    &format!("{name}.scales"),
                );
            }
            // every parameter (quantized + passthrough) matches the
            // in-memory outcome via the shared sidecar dequant loader
            let loaded = load_params_dequant_source(&store).unwrap();
            assert_eq!(loaded.len(), mem.params.len());
            for (name, want) in &mem.params {
                let got = loaded.get(name).unwrap_or_else(|| panic!("missing {name}"));
                assert_eq!(got.shape(), want.shape(), "{name}");
                for (x, y) in got.data().iter().zip(want.data()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{name}");
                }
            }
            // metadata mirrors write_checkpoint's
            assert_eq!(
                store.meta.get("quantized").map(|s| s.as_str()),
                Some("fp8_e4m3")
            );
            for l in &mem.layers {
                assert_eq!(
                    store.meta.get(&format!("alpha.{}", l.name)),
                    Some(&format!("{}", l.alpha)),
                    "{}",
                    l.name
                );
                assert_eq!(
                    store.meta.get(&format!("gran.{}", l.name)),
                    Some(&gran.label()),
                );
            }
            drop(store);
            std::fs::remove_dir_all(tmp(&format!("{tag}_out"))).unwrap();
        }
    }
}

#[test]
fn residency_bounded_by_depth_not_model_size() {
    let (post, base) = fake_ckpts(23, 12, 64);
    let quantizable = quantizable_from_source(&post);
    assert_eq!(quantizable.len(), 12);

    let out_dir = tmp("residency_out");
    let _ = std::fs::remove_dir_all(&out_dir);
    let mut cfg = StreamConfig::new(
        Granularity::Block(16),
        Method::Search { objective: Objective::SignRate, range: (0.8, 1.25) },
        2,
    );
    cfg.depth = 2;
    let out = run_stream(&post, &base, &quantizable, &out_dir, &cfg).unwrap();

    // the admission gate holds each layer's permit from read to write, so
    // live bytes never exceed depth x the largest single-unit footprint
    assert!(out.peak_live_bytes > 0);
    assert!(
        out.peak_live_bytes <= cfg.depth * out.max_unit_bytes,
        "peak {} > depth {} x max unit {}",
        out.peak_live_bytes,
        cfg.depth,
        out.max_unit_bytes
    );
    // ... and that bound is far below whole-model residency
    let model_total: usize = out
        .layers
        .iter()
        .map(|l| {
            let n = l.shape.0 * l.shape.1;
            2 * n * 4 + n + n * 4 // pair + codes + dequant (scales omitted)
        })
        .sum();
    assert!(
        cfg.depth * out.max_unit_bytes <= model_total / 3,
        "bound {} not meaningfully below model residency {model_total}",
        cfg.depth * out.max_unit_bytes
    );
    std::fs::remove_dir_all(&out_dir).unwrap();
}

#[test]
fn resume_after_interruption_converges_to_identical_bytes() {
    for (gi, gran) in [Granularity::Block(16), Granularity::PerChannel]
        .into_iter()
        .enumerate()
    {
        let (post, base) = fake_ckpts(31, 6, 32);
        let quantizable = quantizable_from_source(&post);
        let method = Method::Search {
            objective: Objective::SignRate,
            range: (0.8, 1.25),
        };

        // tiny budget: every layer (and passthrough tensor) gets its own
        // shard, so truncating at a layer boundary maps to whole shards
        let mut cfg = StreamConfig::new(gran, method, 2);
        cfg.shard_budget = 1;

        // reference: uninterrupted run
        let ref_dir = tmp(&format!("resume_ref{gi}"));
        let _ = std::fs::remove_dir_all(&ref_dir);
        let reference =
            run_stream(&post, &base, &quantizable, &ref_dir, &cfg).unwrap();

        // victim: full run, then simulate an interruption after 3 layers
        // by truncating the journal and deleting everything the journal
        // no longer records (later shards, manifest)
        let dir = tmp(&format!("resume_cut{gi}"));
        let _ = std::fs::remove_dir_all(&dir);
        run_stream(&post, &base, &quantizable, &dir, &cfg).unwrap();

        let keep_layers = 3usize;
        let journal = std::fs::read_to_string(dir.join(RESUME_JOURNAL)).unwrap();
        let mut kept = String::new();
        let mut kept_shards: Vec<String> = Vec::new();
        let mut layer_lines = 0usize;
        for line in journal.lines() {
            if line.contains("\"layer\"") {
                if layer_lines == keep_layers {
                    break;
                }
                layer_lines += 1;
                let shard = line
                    .split("\"shard\":\"")
                    .nth(1)
                    .and_then(|s| s.split('"').next())
                    .unwrap()
                    .to_string();
                kept_shards.push(shard);
            }
            kept.push_str(line);
            kept.push('\n');
        }
        assert_eq!(layer_lines, keep_layers);
        std::fs::write(dir.join(RESUME_JOURNAL), &kept).unwrap();
        for entry in std::fs::read_dir(&dir).unwrap() {
            let name = entry.unwrap().file_name().to_string_lossy().into_owned();
            let is_shard = name.starts_with("shard_") && name.ends_with(".dts");
            if (is_shard && !kept_shards.contains(&name)) || name == "manifest.json"
            {
                std::fs::remove_file(dir.join(&name)).unwrap();
            }
        }

        // resume: completed layers skip, the rest recompute
        let mut rcfg = cfg.clone();
        rcfg.resume = true;
        let resumed =
            run_stream(&post, &base, &quantizable, &dir, &rcfg).unwrap();
        assert_eq!(resumed.resumed, keep_layers, "journaled layers must skip");

        // outcomes identical to the uninterrupted run
        assert_eq!(reference.layers.len(), resumed.layers.len());
        for (a, b) in reference.layers.iter().zip(&resumed.layers) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.alpha.to_bits(), b.alpha.to_bits(), "{}", a.name);
            assert_eq!(a.stats, b.stats, "{}", a.name);
        }
        assert_eq!(reference.agg, resumed.agg);

        // stores identical tensor-for-tensor (bitwise) and meta-for-meta
        let sa = ShardedDts::open(&ref_dir).unwrap();
        let sb = ShardedDts::open(&dir).unwrap();
        assert_eq!(sa.names(), sb.names());
        for name in sa.names() {
            assert_bits_eq(
                &sa.read_tensor(name).unwrap(),
                &sb.read_tensor(name).unwrap(),
                name,
            );
        }
        assert_eq!(sa.meta, sb.meta);

        // a second resume over the finished store is a no-op that still
        // converges (all layers skip)
        let again = run_stream(&post, &base, &quantizable, &dir, &rcfg).unwrap();
        assert_eq!(again.resumed, quantizable.len());
        assert_eq!(again.agg, resumed.agg);

        std::fs::remove_dir_all(&ref_dir).unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

#[test]
fn resume_with_changed_config_is_rejected() {
    let (post, base) = fake_ckpts(41, 3, 16);
    let quantizable = quantizable_from_source(&post);
    let dir = tmp("resume_cfg");
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = StreamConfig::new(Granularity::Block(16), Method::AbsMax, 1);
    run_stream(&post, &base, &quantizable, &dir, &cfg).unwrap();

    let mut other = StreamConfig::new(Granularity::PerChannel, Method::AbsMax, 1);
    other.resume = true;
    let err = run_stream(&post, &base, &quantizable, &dir, &other).unwrap_err();
    assert!(format!("{err:#}").contains("gran"), "{err:#}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn fresh_run_refuses_existing_store() {
    let (post, base) = fake_ckpts(43, 3, 16);
    let quantizable = quantizable_from_source(&post);
    let dir = tmp("fresh_refuse");
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = StreamConfig::new(Granularity::Block(16), Method::AbsMax, 1);
    run_stream(&post, &base, &quantizable, &dir, &cfg).unwrap();
    let err = run_stream(&post, &base, &quantizable, &dir, &cfg).unwrap_err();
    assert!(format!("{err:#}").contains("resume"), "{err:#}");
    std::fs::remove_dir_all(&dir).unwrap();
}

/// The non-streamed `write_checkpoint` output and the streamed store load
/// identically through the shared source-based dequant loader — the eval
/// path is backend-agnostic (BTreeMap for deterministic comparison).
#[test]
fn eval_loader_agrees_across_backends() {
    let (post, base) = fake_ckpts(53, 4, 24);
    let (mem, _streamed, store) = run_both(
        &post,
        &base,
        Granularity::Block(16),
        Method::Search { objective: Objective::CosSim, range: (0.9, 1.11) },
        "loader",
    );
    let ckpt = tmp("loader_ckpt").with_extension("dts");
    mem.write_checkpoint(ckpt.to_str().unwrap(), &post.meta).unwrap();

    let mono = DtsReader::open(&ckpt).unwrap();
    let a = load_params_dequant_source(&mono).unwrap();
    let b = load_params_dequant_source(&store).unwrap();
    let an: BTreeMap<_, _> = a.iter().collect();
    let bn: BTreeMap<_, _> = b.iter().collect();
    assert_eq!(
        an.keys().collect::<Vec<_>>(),
        bn.keys().collect::<Vec<_>>()
    );
    for (name, ta) in an {
        let tb = bn[name];
        assert_eq!(ta.shape(), tb.shape(), "{name}");
        for (x, y) in ta.data().iter().zip(tb.data()) {
            assert_eq!(x.to_bits(), y.to_bits(), "{name}");
        }
    }
    std::fs::remove_file(&ckpt).unwrap();
    drop(store);
    std::fs::remove_dir_all(tmp("loader_out")).unwrap();
}
