//! Integration tests for the bounded-memory streaming pipeline
//! (`coordinator::stream`) against synthetic checkpoints — no artifacts
//! or PJRT required.
//!
//! The acceptance invariants of the streaming subsystem:
//! 1. output is **bitwise-identical** to the in-memory `run_pipeline`
//!    for the same (method, granularity, seed) — for the delta methods
//!    *and* for the layernorm-coupled transform baselines
//!    (SmoothQuant/AWQ), over both sharded and monolithic seek-based
//!    sources;
//! 2. peak live tensor bytes stay bounded by `depth x (largest unit)` —
//!    a layer pair for delta methods, a whole transform group for the
//!    baselines — not by model size;
//! 3. an interrupted run resumed from a truncated journal skips the
//!    completed units and converges to the same per-tensor bytes,
//!    including when the interruption falls mid-group.

use std::collections::BTreeMap;
use std::path::PathBuf;

use daq::coordinator::group::GroupSource;
use daq::coordinator::stream::{run_stream, StreamConfig, RESUME_JOURNAL};
use daq::coordinator::{
    run_pipeline_grouped, Engine, Method, PipelineConfig, PipelineOutcome,
};
use daq::eval::load_params_dequant_source;
use daq::eval::model_native::ModelCfg;
use daq::eval::trace::{stamp_model_meta, trace_checkpoint};
use daq::experiments::quantizable_from_source;
use daq::io::dts::{Dts, DtsReader, DtsTensor};
use daq::io::shard::{shard_dts_file, ShardedDts};
use daq::quant::{CodeFormat, Descriptor, Granularity};
use daq::search::Objective;
use daq::tensor::Tensor;
use daq::util::json::Json;
use daq::util::rng::XorShift;
use daq::util::telemetry::{self, Telemetry};

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("daq_streamtest_{tag}_{}", std::process::id()))
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Streaming config for the equality tests, parameterized by the CI
/// determinism matrix: `DAQ_TEST_WORKERS` / `DAQ_TEST_DEPTH` vary the
/// worker count and admission depth per matrix cell, and every cell must
/// produce byte-identical stores (each is asserted against the
/// env-independent in-memory pipeline, and the anchor test below pins
/// the streamed bytes of the env cell to the workers=1/depth=1 cell).
fn test_stream_cfg(gran: Granularity, method: Method) -> StreamConfig {
    let mut cfg = StreamConfig::new(gran, method, env_usize("DAQ_TEST_WORKERS", 2));
    cfg.depth = env_usize("DAQ_TEST_DEPTH", cfg.depth);
    cfg
}

/// Synthetic (post, base) pair: `n_layers` quantizable GEMMs plus
/// layernorm / embedding passthrough tensors.
fn fake_ckpts(seed: u64, n_layers: usize, dim: usize) -> (Dts, Dts) {
    let mut rng = XorShift::new(seed);
    let mut base = Dts::new();
    let mut post = Dts::new();
    base.meta.insert("vocab".into(), "64".into());
    post.meta.insert("vocab".into(), "64".into());
    for i in 0..n_layers {
        let name = match i % 3 {
            0 => format!("l{i}.wq"),
            1 => format!("l{i}.w1"),
            _ => format!("l{i}.w2"),
        };
        let (r, c) = (dim, dim + 8 * (i % 2));
        let wb = Tensor::new(vec![r, c], rng.normal_vec(r * c, 0.1));
        let wp = Tensor::new(
            vec![r, c],
            wb.data().iter().map(|&b| b + rng.normal() * 0.002).collect(),
        );
        base.insert_f32(&name, &wb);
        post.insert_f32(&name, &wp);
        let g = Tensor::full(vec![r], 1.0);
        base.insert_f32(&format!("l{i}.ln1.g"), &g);
        post.insert_f32(&format!("l{i}.ln1.g"), &g);
    }
    let embed = Tensor::new(vec![16, dim], rng.normal_vec(16 * dim, 0.1));
    base.insert_f32("embed", &embed);
    post.insert_f32("embed", &embed);
    (post, base)
}

fn pair_into(
    post: &mut Dts,
    base: &mut Dts,
    rng: &mut XorShift,
    name: &str,
    r: usize,
    c: usize,
) {
    let wb = Tensor::new(vec![r, c], rng.normal_vec(r * c, 0.1));
    let wp = Tensor::new(
        vec![r, c],
        wb.data().iter().map(|&b| b + rng.normal() * 0.002).collect(),
    );
    base.insert_f32(name, &wb);
    post.insert_f32(name, &wp);
}

/// Synthetic transformer-shaped (post, base, calib) triple for the
/// transform baselines: each block has a qkv triplet fed by ln1, a w1
/// fed by ln2, and a non-foldable w2; plus head/lnf, an embedding, and
/// an activation-stat sidecar keyed by each group's first member.
fn fake_group_ckpts(seed: u64, n_blocks: usize, dim: usize) -> (Dts, Dts, Dts) {
    let mut rng = XorShift::new(seed);
    let mut base = Dts::new();
    let mut post = Dts::new();
    let mut calib = Dts::new();
    base.meta.insert("vocab".into(), "64".into());
    post.meta.insert("vocab".into(), "64".into());
    for i in 0..n_blocks {
        for w in ["wq", "wk", "wv"] {
            pair_into(&mut post, &mut base, &mut rng, &format!("l{i}.{w}"), dim, dim);
        }
        pair_into(&mut post, &mut base, &mut rng, &format!("l{i}.w1"), dim, dim + 8);
        pair_into(&mut post, &mut base, &mut rng, &format!("l{i}.w2"), dim + 8, dim);
        for ln in ["ln1", "ln2"] {
            let g = Tensor::new(
                vec![dim],
                (0..dim).map(|_| 1.0 + rng.normal() * 0.05).collect(),
            );
            let b = Tensor::new(
                vec![dim],
                (0..dim).map(|_| rng.normal() * 0.1).collect(),
            );
            base.insert_f32(&format!("l{i}.{ln}.g"), &g);
            post.insert_f32(&format!("l{i}.{ln}.g"), &g);
            base.insert_f32(&format!("l{i}.{ln}.b"), &b);
            post.insert_f32(&format!("l{i}.{ln}.b"), &b);
        }
        for first in ["wq", "w1"] {
            let acts = Tensor::new(
                vec![dim],
                (0..dim).map(|_| rng.f32() * 2.0 + 0.05).collect(),
            );
            calib.insert_f32(&format!("l{i}.{first}"), &acts);
        }
    }
    pair_into(&mut post, &mut base, &mut rng, "head", dim, 16);
    let g = Tensor::full(vec![dim], 1.0);
    let b = Tensor::zeros(vec![dim]);
    for d in [&mut base, &mut post] {
        d.insert_f32("lnf.g", &g);
        d.insert_f32("lnf.b", &b);
    }
    calib.insert_f32(
        "head",
        &Tensor::new(vec![dim], (0..dim).map(|_| rng.f32() + 0.1).collect()),
    );
    let embed = Tensor::new(vec![16, dim], rng.normal_vec(16 * dim, 0.1));
    base.insert_f32("embed", &embed);
    post.insert_f32("embed", &embed);
    (post, base, calib)
}

fn assert_bits_eq(a: &DtsTensor, b: &DtsTensor, what: &str) {
    match (a, b) {
        (
            DtsTensor::F32 { shape: sa, data: da },
            DtsTensor::F32 { shape: sb, data: db },
        ) => {
            assert_eq!(sa, sb, "{what}: shape");
            assert_eq!(da.len(), db.len(), "{what}: len");
            for (x, y) in da.iter().zip(db) {
                assert_eq!(x.to_bits(), y.to_bits(), "{what}");
            }
        }
        _ => assert_eq!(a, b, "{what}"),
    }
}

fn run_both(
    post: &Dts,
    base: &Dts,
    calib: Option<&Dts>,
    gran: Granularity,
    method: Method,
    tag: &str,
) -> (PipelineOutcome, daq::coordinator::stream::StreamOutcome, ShardedDts) {
    let quantizable = quantizable_from_source(post);
    run_both_grouped(
        post,
        base,
        calib,
        &quantizable,
        gran,
        method,
        tag,
        GroupSource::Patterns,
    )
}

#[allow(clippy::too_many_arguments)]
fn run_both_grouped(
    post: &Dts,
    base: &Dts,
    calib: Option<&Dts>,
    quantizable: &[String],
    gran: Granularity,
    method: Method,
    tag: &str,
    groups: GroupSource,
) -> (PipelineOutcome, daq::coordinator::stream::StreamOutcome, ShardedDts) {
    assert!(!quantizable.is_empty());

    let cfg = PipelineConfig::new(gran, method.clone(), Engine::Native { workers: 2 });
    let mem =
        run_pipeline_grouped(post, base, quantizable, calib, &cfg, None, &groups)
            .unwrap();

    // post goes through a sharded store, base through the seek-based
    // monolithic reader — both streaming source backends in one run
    let post_file = tmp(&format!("{tag}_post_dts")).with_extension("dts");
    post.write(&post_file).unwrap();
    let post_shards = tmp(&format!("{tag}_post_shards"));
    let _ = std::fs::remove_dir_all(&post_shards);
    let (manifest, _) = shard_dts_file(&post_file, &post_shards, 4096).unwrap();
    let post_src = ShardedDts::open(&manifest).unwrap();

    let base_file = tmp(&format!("{tag}_base_dts")).with_extension("dts");
    base.write(&base_file).unwrap();
    let base_src = DtsReader::open(&base_file).unwrap();

    let out_dir = tmp(&format!("{tag}_out"));
    let _ = std::fs::remove_dir_all(&out_dir);
    let mut scfg = test_stream_cfg(gran, method);
    scfg.shard_budget = 8192;
    scfg.groups = groups;
    let streamed = run_stream(
        &post_src,
        &base_src,
        quantizable,
        calib.map(|c| c as &dyn daq::io::TensorSource),
        &out_dir,
        &scfg,
    )
    .unwrap();
    let store = ShardedDts::open(&out_dir).unwrap();

    std::fs::remove_file(&post_file).unwrap();
    std::fs::remove_file(&base_file).unwrap();
    std::fs::remove_dir_all(&post_shards).unwrap();
    (mem, streamed, store)
}

/// Shared equality assertions: per-layer outcomes, stored tensors, the
/// sidecar dequant loader, and store metadata all match the in-memory
/// pipeline bitwise.
fn assert_store_matches(
    mem: &PipelineOutcome,
    streamed: &daq::coordinator::stream::StreamOutcome,
    store: &ShardedDts,
) {
    assert_eq!(mem.layers.len(), streamed.layers.len());
    for (a, b) in mem.layers.iter().zip(&streamed.layers) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.alpha.to_bits(), b.alpha.to_bits(), "{}", a.name);
        assert_eq!(a.evals, b.evals);
        assert_eq!(a.stats, b.stats, "{}", a.name);
    }
    assert_eq!(mem.agg, streamed.agg);

    // stored tensors identical: codes (at the format's packed shape),
    // scales, residual sidecars, dequantized weights
    for (name, q) in &mem.quantized {
        let fmt = q.format();
        let codes = store.read_tensor(&format!("{name}.codes")).unwrap();
        assert_bits_eq(
            &codes,
            &DtsTensor::U8 {
                shape: vec![q.shape.0, fmt.packed_row_bytes(q.shape.1)],
                data: q.codes.clone(),
            },
            &format!("{name}.codes"),
        );
        let scales = store.read_tensor(&format!("{name}.scales")).unwrap();
        assert_bits_eq(
            &scales,
            &DtsTensor::F32 {
                shape: vec![q.scales.grid_rows, q.scales.grid_cols],
                data: q.scales.scales.clone(),
            },
            &format!("{name}.scales"),
        );
        match &q.residual {
            Some(lr) => {
                let u = store.read_tensor(&format!("{name}.res_u")).unwrap();
                assert_bits_eq(
                    &u,
                    &DtsTensor::F32 {
                        shape: vec![q.shape.0, lr.k],
                        data: lr.u.clone(),
                    },
                    &format!("{name}.res_u"),
                );
                let v = store.read_tensor(&format!("{name}.res_v")).unwrap();
                assert_bits_eq(
                    &v,
                    &DtsTensor::F32 {
                        shape: vec![lr.k, q.shape.1],
                        data: lr.v.clone(),
                    },
                    &format!("{name}.res_v"),
                );
            }
            None => {
                assert!(
                    store.entry(&format!("{name}.res_u")).is_none(),
                    "{name}: spurious residual sidecar"
                );
            }
        }
    }
    // every parameter (quantized + folded layernorms + passthrough)
    // matches the in-memory outcome via the shared sidecar dequant loader
    let loaded = load_params_dequant_source(store).unwrap();
    assert_eq!(loaded.len(), mem.params.len());
    for (name, want) in &mem.params {
        let got = loaded.get(name).unwrap_or_else(|| panic!("missing {name}"));
        assert_eq!(got.shape(), want.shape(), "{name}");
        for (x, y) in got.data().iter().zip(want.data()) {
            assert_eq!(x.to_bits(), y.to_bits(), "{name}");
        }
    }
    // metadata mirrors write_checkpoint's: the structured fmt.<name>
    // descriptor replaced the legacy `quantized` + gran.<name> pair
    assert!(store.meta.get("quantized").is_none());
    for l in &mem.layers {
        assert_eq!(
            store.meta.get(&format!("alpha.{}", l.name)),
            Some(&format!("{}", l.alpha)),
            "{}",
            l.name
        );
        let q = &mem.quantized[&l.name];
        assert_eq!(
            store.meta.get(&format!("fmt.{}", l.name)),
            Some(&Descriptor::for_tensor(q).to_meta()),
            "{}",
            l.name
        );
        assert!(
            store.meta.get(&format!("gran.{}", l.name)).is_none(),
            "{}: legacy gran meta resurfaced",
            l.name
        );
    }
}

#[test]
fn streaming_matches_in_memory_pipeline_bitwise() {
    for (gi, gran) in [Granularity::Block(16), Granularity::PerChannel]
        .into_iter()
        .enumerate()
    {
        for (mi, method) in [
            Method::Search {
                objective: Objective::SignRate,
                range: (0.8, 1.25),
            },
            Method::AbsMax,
        ]
        .into_iter()
        .enumerate()
        {
            let (post, base) = fake_ckpts(11, 5, 32);
            let tag = format!("eq{gi}{mi}");
            let (mem, streamed, store) =
                run_both(&post, &base, None, gran, method, &tag);
            assert_store_matches(&mem, &streamed, &store);
            drop(store);
            std::fs::remove_dir_all(tmp(&format!("{tag}_out"))).unwrap();
        }
    }
}

/// The tentpole invariant: group-at-a-time streaming of the transform
/// baselines is bitwise-identical to the in-memory transformed pipeline —
/// quantized members, folded layernorm affines, metadata, everything —
/// across granularities.
#[test]
fn group_streaming_matches_in_memory_transformed_bitwise() {
    for (gi, gran) in [Granularity::Block(16), Granularity::PerChannel]
        .into_iter()
        .enumerate()
    {
        for (mi, method) in [Method::SmoothQuant { alpha: 0.5 }, Method::Awq]
            .into_iter()
            .enumerate()
        {
            let (post, base, calib) = fake_group_ckpts(61, 2, 32);
            let tag = format!("geq{gi}{mi}");
            let (mem, streamed, store) =
                run_both(&post, &base, Some(&calib), gran, method, &tag);
            // delta metrics are undefined for the transform baselines
            assert!(mem.agg.is_none());
            assert!(streamed.agg.is_none());
            assert!(streamed.layers.iter().all(|l| l.stats.is_none()));
            assert_store_matches(&mem, &streamed, &store);
            // the folded layernorm affines are persisted (not the
            // pre-fold post values)
            let g = store.read_tensor("l0.ln1.g").unwrap();
            let DtsTensor::F32 { data, .. } = &g else { panic!("ln gain dtype") };
            let want = &mem.params["l0.ln1.g"];
            for (x, y) in data.iter().zip(want.data()) {
                assert_eq!(x.to_bits(), y.to_bits(), "l0.ln1.g");
            }
            drop(store);
            std::fs::remove_dir_all(tmp(&format!("{tag}_out"))).unwrap();
        }
    }
}

#[test]
fn residency_bounded_by_depth_not_model_size() {
    let (post, base) = fake_ckpts(23, 12, 64);
    let quantizable = quantizable_from_source(&post);
    assert_eq!(quantizable.len(), 12);

    let out_dir = tmp("residency_out");
    let _ = std::fs::remove_dir_all(&out_dir);
    let mut cfg = StreamConfig::new(
        Granularity::Block(16),
        Method::Search { objective: Objective::SignRate, range: (0.8, 1.25) },
        2,
    );
    cfg.depth = 2;
    let out = run_stream(&post, &base, &quantizable, None, &out_dir, &cfg).unwrap();

    // the admission gate holds each unit's permit from read to write, so
    // live bytes never exceed depth x the largest single-unit footprint
    assert!(out.peak_live_bytes > 0);
    assert!(
        out.peak_live_bytes <= cfg.depth * out.max_unit_bytes,
        "peak {} > depth {} x max unit {}",
        out.peak_live_bytes,
        cfg.depth,
        out.max_unit_bytes
    );
    // ... and that bound is far below whole-model residency
    let model_total: usize = out
        .layers
        .iter()
        .map(|l| {
            let n = l.shape.0 * l.shape.1;
            2 * n * 4 + n + n * 4 // pair + codes + dequant (scales omitted)
        })
        .sum();
    assert!(
        cfg.depth * out.max_unit_bytes <= model_total / 3,
        "bound {} not meaningfully below model residency {model_total}",
        cfg.depth * out.max_unit_bytes
    );
    std::fs::remove_dir_all(&out_dir).unwrap();
}

/// Group streaming keeps the same residency shape with the unit enlarged
/// to one transform group: `peak <= depth x (largest group footprint)`,
/// still far below whole-model residency.
#[test]
fn group_residency_bounded_by_depth_times_largest_group() {
    let (post, base, calib) = fake_group_ckpts(81, 4, 32);
    let quantizable = quantizable_from_source(&post);
    assert_eq!(quantizable.len(), 4 * 5 + 1);

    let out_dir = tmp("gresidency_out");
    let _ = std::fs::remove_dir_all(&out_dir);
    let mut cfg =
        StreamConfig::new(Granularity::Block(16), Method::SmoothQuant { alpha: 0.5 }, 2);
    cfg.depth = 2;
    let out = run_stream(
        &post,
        &base,
        &quantizable,
        Some(&calib),
        &out_dir,
        &cfg,
    )
    .unwrap();

    assert!(out.peak_live_bytes > 0);
    assert!(
        out.peak_live_bytes <= cfg.depth * out.max_unit_bytes,
        "peak {} > depth {} x max group {}",
        out.peak_live_bytes,
        cfg.depth,
        out.max_unit_bytes
    );
    // transform units read only post weights: footprint per member is
    // roughly post + codes + scales + dequant; the model holds 21 GEMMs
    // while the largest group holds 3
    let model_total: usize = out
        .layers
        .iter()
        .map(|l| {
            let n = l.shape.0 * l.shape.1;
            n * 4 + n + n * 4
        })
        .sum();
    assert!(
        cfg.depth * out.max_unit_bytes <= model_total / 2,
        "bound {} not meaningfully below model residency {model_total}",
        cfg.depth * out.max_unit_bytes
    );
    std::fs::remove_dir_all(&out_dir).unwrap();
}

/// Truncate a journal to its config line plus the first `keep` unit
/// records, delete every shard the truncated journal no longer records
/// (plus the manifest), and return how many member layers survive.
fn truncate_store(dir: &PathBuf, keep: usize) -> usize {
    let journal = std::fs::read_to_string(dir.join(RESUME_JOURNAL)).unwrap();
    let mut kept = String::new();
    let mut kept_shards: Vec<String> = Vec::new();
    let mut units = 0usize;
    let mut kept_layers = 0usize;
    for line in journal.lines() {
        let is_unit = line.contains("\"shard\":\"");
        if is_unit {
            if units == keep {
                break;
            }
            units += 1;
            let shard = line
                .split("\"shard\":\"")
                .nth(1)
                .and_then(|s| s.split('"').next())
                .unwrap()
                .to_string();
            kept_shards.push(shard);
            kept_layers += line.matches("\"layer\":").count();
        }
        kept.push_str(line);
        kept.push('\n');
    }
    assert_eq!(units, keep, "journal shorter than {keep} unit records");
    std::fs::write(dir.join(RESUME_JOURNAL), &kept).unwrap();
    for entry in std::fs::read_dir(dir).unwrap() {
        let name = entry.unwrap().file_name().to_string_lossy().into_owned();
        let is_shard = name.starts_with("shard_") && name.ends_with(".dts");
        if (is_shard && !kept_shards.contains(&name)) || name == "manifest.json" {
            std::fs::remove_file(dir.join(&name)).unwrap();
        }
    }
    kept_layers
}

fn assert_stores_identical(a: &PathBuf, b: &PathBuf) {
    let sa = ShardedDts::open(a).unwrap();
    let sb = ShardedDts::open(b).unwrap();
    assert_eq!(sa.names(), sb.names());
    for name in sa.names() {
        assert_bits_eq(
            &sa.read_tensor(name).unwrap(),
            &sb.read_tensor(name).unwrap(),
            name,
        );
    }
    assert_eq!(sa.meta, sb.meta);
}

#[test]
fn resume_after_interruption_converges_to_identical_bytes() {
    for (gi, gran) in [Granularity::Block(16), Granularity::PerChannel]
        .into_iter()
        .enumerate()
    {
        let (post, base) = fake_ckpts(31, 6, 32);
        let quantizable = quantizable_from_source(&post);
        let method = Method::Search {
            objective: Objective::SignRate,
            range: (0.8, 1.25),
        };

        // tiny budget: every unit (and passthrough tensor) gets its own
        // shard, so truncating at a unit boundary maps to whole shards
        let mut cfg = StreamConfig::new(gran, method, 2);
        cfg.shard_budget = 1;

        // reference: uninterrupted run
        let ref_dir = tmp(&format!("resume_ref{gi}"));
        let _ = std::fs::remove_dir_all(&ref_dir);
        let reference =
            run_stream(&post, &base, &quantizable, None, &ref_dir, &cfg).unwrap();

        // victim: full run, then simulate an interruption after 3 layers
        let dir = tmp(&format!("resume_cut{gi}"));
        let _ = std::fs::remove_dir_all(&dir);
        run_stream(&post, &base, &quantizable, None, &dir, &cfg).unwrap();
        let kept_layers = truncate_store(&dir, 3);
        assert_eq!(kept_layers, 3, "delta units are single layers");

        // resume: completed layers skip, the rest recompute
        let mut rcfg = cfg.clone();
        rcfg.resume = true;
        let resumed =
            run_stream(&post, &base, &quantizable, None, &dir, &rcfg).unwrap();
        assert_eq!(resumed.resumed, 3, "journaled layers must skip");

        // outcomes identical to the uninterrupted run
        assert_eq!(reference.layers.len(), resumed.layers.len());
        for (a, b) in reference.layers.iter().zip(&resumed.layers) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.alpha.to_bits(), b.alpha.to_bits(), "{}", a.name);
            assert_eq!(a.stats, b.stats, "{}", a.name);
        }
        assert_eq!(reference.agg, resumed.agg);
        assert_stores_identical(&ref_dir, &dir);

        // a second resume over the finished store is a no-op that still
        // converges (all layers skip)
        let again =
            run_stream(&post, &base, &quantizable, None, &dir, &rcfg).unwrap();
        assert_eq!(again.resumed, quantizable.len());
        assert_eq!(again.agg, resumed.agg);

        std::fs::remove_dir_all(&ref_dir).unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

/// Interrupting a transform run between groups (the journal tail — and
/// with it a whole group's shard — is lost) must reconverge to a
/// byte-identical store: resumed groups skip wholesale, lost groups
/// recompute with the identical shared smoothing vector and fold.
#[test]
fn group_resume_mid_run_converges_to_identical_bytes() {
    let (post, base, calib) = fake_group_ckpts(71, 2, 24);
    let quantizable = quantizable_from_source(&post);
    let method = Method::SmoothQuant { alpha: 0.5 };
    let mut cfg = StreamConfig::new(Granularity::Block(16), method, 2);
    cfg.shard_budget = 1; // one unit per shard

    let ref_dir = tmp("gresume_ref");
    let _ = std::fs::remove_dir_all(&ref_dir);
    let reference = run_stream(
        &post,
        &base,
        &quantizable,
        Some(&calib),
        &ref_dir,
        &cfg,
    )
    .unwrap();

    let dir = tmp("gresume_cut");
    let _ = std::fs::remove_dir_all(&dir);
    run_stream(&post, &base, &quantizable, Some(&calib), &dir, &cfg).unwrap();
    // keep the first two units: the l0.ln1 qkv group (3 members) and the
    // l0.ln2 group (1 member) — the cut falls between coupled groups
    let kept_layers = truncate_store(&dir, 2);
    assert_eq!(kept_layers, 4, "qkv group + w1 group");

    let mut rcfg = cfg.clone();
    rcfg.resume = true;
    let resumed = run_stream(
        &post,
        &base,
        &quantizable,
        Some(&calib),
        &dir,
        &rcfg,
    )
    .unwrap();
    assert_eq!(resumed.resumed, 4, "both journaled groups must skip whole");
    assert!(resumed.agg.is_none());

    assert_eq!(reference.layers.len(), resumed.layers.len());
    for (a, b) in reference.layers.iter().zip(&resumed.layers) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.alpha.to_bits(), b.alpha.to_bits(), "{}", a.name);
        assert!(b.stats.is_none(), "{}", a.name);
    }
    assert_stores_identical(&ref_dir, &dir);

    // a second resume skips every member
    let again = run_stream(
        &post,
        &base,
        &quantizable,
        Some(&calib),
        &dir,
        &rcfg,
    )
    .unwrap();
    assert_eq!(again.resumed, quantizable.len());

    std::fs::remove_dir_all(&ref_dir).unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

/// A calibration sidecar missing a group's stat (or holding one of the
/// wrong width) must fail at plan time, before any shard is written —
/// not hours into the run when the prefetch reaches that group.
#[test]
fn group_streaming_validates_calib_at_plan_time() {
    let (post, base, _full_calib) = fake_group_ckpts(91, 1, 16);
    let quantizable = quantizable_from_source(&post);
    let cfg =
        StreamConfig::new(Granularity::Block(16), Method::SmoothQuant { alpha: 0.5 }, 1);
    let dir = tmp("calib_plan");
    let _ = std::fs::remove_dir_all(&dir);

    // sidecar lacking the qkv group's first-member stat entirely
    let mut missing = Dts::new();
    missing.insert_f32("l0.w1", &Tensor::full(vec![16], 0.5));
    missing.insert_f32("head", &Tensor::full(vec![16], 0.5));
    let err = run_stream(&post, &base, &quantizable, Some(&missing), &dir, &cfg)
        .unwrap_err();
    assert!(format!("{err:#}").contains("no stat"), "{err:#}");
    assert!(!dir.exists(), "plan-time failure must not create the store");

    // sidecar with a wrong-width stat
    let mut short = Dts::new();
    for n in ["l0.wq", "l0.w1", "head"] {
        short.insert_f32(n, &Tensor::full(vec![4], 0.5));
    }
    let err = run_stream(&post, &base, &quantizable, Some(&short), &dir, &cfg)
        .unwrap_err();
    assert!(format!("{err:#}").contains("input channel"), "{err:#}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A shard whose unit record was lost from the journal is a corrupted
/// store, not a resumable one — the driver must refuse rather than
/// silently requantize into duplicate tensors.
#[test]
fn group_resume_with_unjournaled_shard_is_rejected() {
    let (post, base, calib) = fake_group_ckpts(73, 1, 16);
    let quantizable = quantizable_from_source(&post);
    let mut cfg =
        StreamConfig::new(Granularity::Block(16), Method::SmoothQuant { alpha: 0.5 }, 1);
    cfg.shard_budget = 1;
    let dir = tmp("gresume_unjournaled");
    let _ = std::fs::remove_dir_all(&dir);
    run_stream(&post, &base, &quantizable, Some(&calib), &dir, &cfg).unwrap();

    // drop every unit record but keep all shards
    let journal = std::fs::read_to_string(dir.join(RESUME_JOURNAL)).unwrap();
    let config_only: String = journal
        .lines()
        .filter(|l| l.contains("\"config\""))
        .map(|l| format!("{l}\n"))
        .collect();
    std::fs::write(dir.join(RESUME_JOURNAL), config_only).unwrap();

    let mut rcfg = cfg.clone();
    rcfg.resume = true;
    let err = run_stream(
        &post,
        &base,
        &quantizable,
        Some(&calib),
        &dir,
        &rcfg,
    )
    .unwrap_err();
    assert!(format!("{err:#}").contains("missing from the resume journal"), "{err:#}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn resume_with_changed_config_is_rejected() {
    let (post, base) = fake_ckpts(41, 3, 16);
    let quantizable = quantizable_from_source(&post);
    let dir = tmp("resume_cfg");
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = StreamConfig::new(Granularity::Block(16), Method::AbsMax, 1);
    run_stream(&post, &base, &quantizable, None, &dir, &cfg).unwrap();

    let mut other = StreamConfig::new(Granularity::PerChannel, Method::AbsMax, 1);
    other.resume = true;
    let err = run_stream(&post, &base, &quantizable, None, &dir, &other).unwrap_err();
    assert!(format!("{err:#}").contains("gran"), "{err:#}");
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Sub-8-bit tentpole: streamed INT4-with-residual stores are
/// bitwise-identical to the in-memory pipeline for every cell of
/// {workers: 1, 4} — packed codes, scales, residual sidecars, metadata —
/// and the cells are byte-identical to each other.
#[test]
fn int4_residual_streaming_matches_in_memory_across_workers() {
    let (post, base) = fake_ckpts(17, 5, 32);
    let quantizable = quantizable_from_source(&post);
    let method = Method::Search {
        objective: Objective::SignRate,
        range: (0.8, 1.25),
    };
    let fmt = CodeFormat::Int4 { group: 16 };

    let mut dirs = Vec::new();
    for workers in [1usize, 4] {
        let mut pcfg = PipelineConfig::new(
            Granularity::Block(16),
            method.clone(),
            Engine::Native { workers },
        );
        pcfg.format = fmt;
        pcfg.residual_rank = 4;
        let mem = run_pipeline_grouped(
            &post, &base, &quantizable, None, &pcfg, None, &GroupSource::Patterns,
        )
        .unwrap();

        let out_dir = tmp(&format!("int4res_w{workers}"));
        let _ = std::fs::remove_dir_all(&out_dir);
        let mut scfg =
            StreamConfig::new(Granularity::Block(16), method.clone(), workers);
        scfg.format = fmt;
        scfg.residual_rank = 4;
        scfg.shard_budget = 8192;
        let streamed =
            run_stream(&post, &base, &quantizable, None, &out_dir, &scfg).unwrap();
        let store = ShardedDts::open(&out_dir).unwrap();
        assert_store_matches(&mem, &streamed, &store);

        // the residual sidecars really are on disk, at their packed names
        for name in &quantizable {
            assert!(store.entry(&format!("{name}.res_u")).is_some(), "{name}");
            assert!(store.entry(&format!("{name}.res_v")).is_some(), "{name}");
            let q = &mem.quantized[name];
            assert_eq!(q.residual.as_ref().map(|r| r.k), Some(4), "{name}");
        }
        drop(store);
        dirs.push(out_dir);
    }
    // worker count is unobservable in the stored bytes
    assert_stores_identical(&dirs[0], &dirs[1]);
    for d in dirs {
        std::fs::remove_dir_all(&d).unwrap();
    }
}

/// Acceptance: `--format int4:64 --residual-rank 4` on
/// transformer-scale layers (512-wide) resides in <= 0.18x the f32
/// bytes — through both the in-memory pipeline and a streamed store
/// reloaded by `QuantizedParams`.
#[test]
fn int4_residual_store_resides_under_0p18_of_f32() {
    let (post, base) = fake_ckpts(19, 2, 512);
    let quantizable = quantizable_from_source(&post);

    // in-memory: ratio over the quantized tensors themselves
    let mut pcfg = PipelineConfig::new(
        Granularity::Block(64),
        Method::AbsMax,
        Engine::Native { workers: 2 },
    );
    pcfg.format = CodeFormat::Int4 { group: 64 };
    pcfg.residual_rank = 4;
    let mem = run_pipeline_grouped(
        &post, &base, &quantizable, None, &pcfg, None, &GroupSource::Patterns,
    )
    .unwrap();
    let packed: usize = mem.quantized.values().map(|q| q.nbytes()).sum();
    let dense: usize =
        mem.quantized.values().map(|q| 4 * q.shape.0 * q.shape.1).sum();
    assert!(
        (packed as f64) <= 0.18 * dense as f64,
        "in-memory: {packed} vs {dense} ({:.3}x)",
        packed as f64 / dense as f64
    );

    // streamed: the loaded store's resident footprint, passthrough
    // tensors (embed / layernorm gains) included
    let out_dir = tmp("int4_ratio");
    let _ = std::fs::remove_dir_all(&out_dir);
    let mut cfg = StreamConfig::new(Granularity::Block(64), Method::AbsMax, 2);
    cfg.format = CodeFormat::Int4 { group: 64 };
    cfg.residual_rank = 4;
    cfg.shard_budget = 1 << 20;
    run_stream(&post, &base, &quantizable, None, &out_dir, &cfg).unwrap();
    let store = ShardedDts::open(&out_dir).unwrap();
    let qp = daq::eval::QuantizedParams::load(&store).unwrap();
    assert_eq!(qp.n_quantized(), quantizable.len());
    let ratio =
        qp.resident_param_bytes() as f64 / qp.f32_param_bytes() as f64;
    assert!(ratio <= 0.18, "streamed resident ratio {ratio:.4}");
    drop(store);
    std::fs::remove_dir_all(&out_dir).unwrap();
}

/// Resume over an interrupted INT4+residual run: the journal's written
/// names include the residual sidecars, so completed units skip whole
/// and the store reconverges byte-identically.
#[test]
fn int4_residual_resume_converges_to_identical_bytes() {
    let (post, base) = fake_ckpts(37, 4, 32);
    let quantizable = quantizable_from_source(&post);
    let mut cfg = StreamConfig::new(Granularity::Block(16), Method::AbsMax, 2);
    cfg.format = CodeFormat::Int4 { group: 16 };
    cfg.residual_rank = 2;
    cfg.shard_budget = 1; // one unit per shard

    let ref_dir = tmp("int4_resume_ref");
    let _ = std::fs::remove_dir_all(&ref_dir);
    run_stream(&post, &base, &quantizable, None, &ref_dir, &cfg).unwrap();

    let dir = tmp("int4_resume_cut");
    let _ = std::fs::remove_dir_all(&dir);
    run_stream(&post, &base, &quantizable, None, &dir, &cfg).unwrap();
    let kept = truncate_store(&dir, 2);
    assert_eq!(kept, 2);

    let mut rcfg = cfg.clone();
    rcfg.resume = true;
    let resumed =
        run_stream(&post, &base, &quantizable, None, &dir, &rcfg).unwrap();
    assert_eq!(resumed.resumed, 2, "journaled INT4+residual units must skip");
    assert_stores_identical(&ref_dir, &dir);

    std::fs::remove_dir_all(&ref_dir).unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

/// The resume journal records the code format and residual rank; a
/// resume under a different format is a config mismatch, not a silent
/// mixed-format store.
#[test]
fn resume_with_changed_format_is_rejected() {
    let (post, base) = fake_ckpts(47, 3, 16);
    let quantizable = quantizable_from_source(&post);
    let dir = tmp("resume_fmt");
    let _ = std::fs::remove_dir_all(&dir);
    let mut cfg = StreamConfig::new(Granularity::Block(16), Method::AbsMax, 1);
    cfg.format = CodeFormat::Int4 { group: 16 };
    run_stream(&post, &base, &quantizable, None, &dir, &cfg).unwrap();

    let mut other = StreamConfig::new(Granularity::Block(16), Method::AbsMax, 1);
    other.resume = true;
    let err = run_stream(&post, &base, &quantizable, None, &dir, &other).unwrap_err();
    assert!(format!("{err:#}").contains("format"), "{err:#}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn fresh_run_refuses_existing_store() {
    let (post, base) = fake_ckpts(43, 3, 16);
    let quantizable = quantizable_from_source(&post);
    let dir = tmp("fresh_refuse");
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = StreamConfig::new(Granularity::Block(16), Method::AbsMax, 1);
    run_stream(&post, &base, &quantizable, None, &dir, &cfg).unwrap();
    let err = run_stream(&post, &base, &quantizable, None, &dir, &cfg).unwrap_err();
    assert!(format!("{err:#}").contains("resume"), "{err:#}");
    std::fs::remove_dir_all(&dir).unwrap();
}

/// The non-streamed `write_checkpoint` output and the streamed store load
/// identically through the shared source-based dequant loader — the eval
/// path is backend-agnostic (BTreeMap for deterministic comparison).
#[test]
fn eval_loader_agrees_across_backends() {
    let (post, base) = fake_ckpts(53, 4, 24);
    let (mem, _streamed, store) = run_both(
        &post,
        &base,
        None,
        Granularity::Block(16),
        Method::Search { objective: Objective::CosSim, range: (0.9, 1.11) },
        "loader",
    );
    let ckpt = tmp("loader_ckpt").with_extension("dts");
    mem.write_checkpoint(ckpt.to_str().unwrap(), &post.meta).unwrap();

    let mono = DtsReader::open(&ckpt).unwrap();
    let a = load_params_dequant_source(&mono).unwrap();
    let b = load_params_dequant_source(&store).unwrap();
    let an: BTreeMap<_, _> = a.iter().collect();
    let bn: BTreeMap<_, _> = b.iter().collect();
    assert_eq!(
        an.keys().collect::<Vec<_>>(),
        bn.keys().collect::<Vec<_>>()
    );
    for (name, ta) in an {
        let tb = bn[name];
        assert_eq!(ta.shape(), tb.shape(), "{name}");
        for (x, y) in ta.data().iter().zip(tb.data()) {
            assert_eq!(x.to_bits(), y.to_bits(), "{name}");
        }
    }
    std::fs::remove_file(&ckpt).unwrap();
    drop(store);
    std::fs::remove_dir_all(tmp("loader_out")).unwrap();
}

/// Synthetic transformer whose tensor names follow a foreign convention
/// (`blk0.q_proj`, `final_norm.g`, ...) that defeats every name pattern
/// in the repo — `quantizable_from_source` finds nothing and
/// `upstream_ln` cannot couple anything. The checkpoint carries the
/// model config plus `layout.*` metadata, so the dataflow trace can
/// still execute (index-only) and recover the grouping structurally.
fn renamed_ckpts() -> (Dts, Dts, Dts, ModelCfg) {
    let cfg =
        ModelCfg { vocab: 32, d_model: 16, n_layer: 1, n_head: 2, d_ff: 24, seq_len: 4 };
    let mut rng = XorShift::new(211);
    let mut post = Dts::new();
    let mut base = Dts::new();
    let mut calib = Dts::new();
    stamp_model_meta(&mut post, &cfg);
    stamp_model_meta(&mut base, &cfg);
    for (role, actual) in [
        ("embed", "emb_tok"),
        ("pos", "emb_pos"),
        ("l0.wq", "blk0.q_proj"),
        ("l0.wk", "blk0.k_proj"),
        ("l0.wv", "blk0.v_proj"),
        ("l0.wo", "blk0.o_proj"),
        ("l0.w1", "blk0.ffn_up"),
        ("l0.w2", "blk0.ffn_down"),
        ("l0.ln1.g", "blk0.norm_attn.g"),
        ("l0.ln1.b", "blk0.norm_attn.b"),
        ("l0.ln2.g", "blk0.norm_ffn.g"),
        ("l0.ln2.b", "blk0.norm_ffn.b"),
        ("lnf.g", "final_norm.g"),
        ("lnf.b", "final_norm.b"),
        ("head", "lm_out"),
    ] {
        for d in [&mut post, &mut base] {
            d.meta.insert(format!("layout.{role}"), actual.to_string());
        }
    }
    let d = cfg.d_model;
    pair_into(&mut post, &mut base, &mut rng, "emb_tok", cfg.vocab, d);
    pair_into(&mut post, &mut base, &mut rng, "emb_pos", cfg.seq_len, d);
    for w in ["q_proj", "k_proj", "v_proj", "o_proj"] {
        pair_into(&mut post, &mut base, &mut rng, &format!("blk0.{w}"), d, d);
    }
    pair_into(&mut post, &mut base, &mut rng, "blk0.ffn_up", d, cfg.d_ff);
    pair_into(&mut post, &mut base, &mut rng, "blk0.ffn_down", cfg.d_ff, d);
    pair_into(&mut post, &mut base, &mut rng, "lm_out", d, cfg.vocab);
    for ln in ["blk0.norm_attn", "blk0.norm_ffn", "final_norm"] {
        let g = Tensor::new(vec![d], (0..d).map(|_| 1.0 + rng.normal() * 0.05).collect());
        let b = Tensor::new(vec![d], (0..d).map(|_| rng.normal() * 0.1).collect());
        for dd in [&mut post, &mut base] {
            dd.insert_f32(&format!("{ln}.g"), &g);
            dd.insert_f32(&format!("{ln}.b"), &b);
        }
    }
    for first in ["blk0.q_proj", "blk0.ffn_up", "lm_out"] {
        let acts =
            Tensor::new(vec![d], (0..d).map(|_| rng.f32() * 2.0 + 0.05).collect());
        calib.insert_f32(first, &acts);
    }
    (post, base, calib, cfg)
}

/// The tentpole acceptance test: on a checkpoint whose tensor names
/// defeat the `upstream_ln` patterns entirely, trace-derived groups
/// drive both the in-memory transformed pipeline and the streaming
/// driver to bitwise-identical stores — and the layernorm fold really
/// happens (the groups are not silently degraded to singletons).
#[test]
fn trace_groups_stream_renamed_checkpoint_bitwise() {
    let (post, base, calib, _cfg) = renamed_ckpts();

    // the name patterns are defeated: no quantizable tensors, nothing
    // groupable
    assert!(quantizable_from_source(&post).is_empty());

    // the dataflow trace recovers both the GEMM set and the coupling
    let graph = trace_checkpoint(&post).unwrap();
    let quantizable = graph.quantizable();
    assert_eq!(
        quantizable,
        vec![
            "blk0.q_proj",
            "blk0.k_proj",
            "blk0.v_proj",
            "blk0.o_proj",
            "blk0.ffn_up",
            "blk0.ffn_down",
            "lm_out"
        ]
    );

    for (mi, method) in [Method::SmoothQuant { alpha: 0.5 }, Method::Awq]
        .into_iter()
        .enumerate()
    {
        let gran = Granularity::Block(16);
        let tag = format!("renamed{mi}");
        let (mem, streamed, store) = run_both_grouped(
            &post,
            &base,
            Some(&calib),
            &quantizable,
            gran,
            method,
            &tag,
            GroupSource::Trace(graph.clone()),
        );
        assert!(mem.agg.is_none());
        assert_store_matches(&mem, &streamed, &store);
        // the qkv group's affine actually absorbed the inverse smoothing
        // (SmoothQuant's factors are generically != 1; AWQ may
        // legitimately settle on alpha = 0, i.e. identity scaling)
        let folded = &mem.params["blk0.norm_attn.g"];
        if mi == 0 {
            let original = post.tensor_f32("blk0.norm_attn.g").unwrap();
            assert!(
                folded
                    .data()
                    .iter()
                    .zip(original.data())
                    .any(|(a, b)| (a - b).abs() > 1e-6),
                "layernorm affine unchanged — the trace-derived group did not fold"
            );
        }
        // ...and the streamed store persists the folded value bitwise
        let DtsTensor::F32 { data, .. } =
            store.read_tensor("blk0.norm_attn.g").unwrap()
        else {
            panic!("ln gain dtype")
        };
        for (x, y) in data.iter().zip(folded.data()) {
            assert_eq!(x.to_bits(), y.to_bits(), "blk0.norm_attn.g");
        }
        drop(store);
        std::fs::remove_dir_all(tmp(&format!("{tag}_out"))).unwrap();
    }
}

/// Every CI determinism-matrix cell (`DAQ_TEST_WORKERS` x
/// `DAQ_TEST_DEPTH`) must produce byte-identical shards: the env-driven
/// configuration is pinned against the workers=1 / depth=1 anchor, so
/// any two cells are transitively identical.
#[test]
fn stream_determinism_across_workers_and_depth() {
    let (post, base) = fake_ckpts(77, 6, 24);
    let quantizable = quantizable_from_source(&post);
    let method = Method::Search {
        objective: Objective::SignRate,
        range: (0.8, 1.25),
    };

    let anchor_dir = tmp("det_anchor");
    let _ = std::fs::remove_dir_all(&anchor_dir);
    let mut anchor_cfg = StreamConfig::new(Granularity::Block(16), method.clone(), 1);
    anchor_cfg.depth = 1;
    anchor_cfg.shard_budget = 8192;
    run_stream(&post, &base, &quantizable, None, &anchor_dir, &anchor_cfg).unwrap();

    let cell_dir = tmp("det_cell");
    let _ = std::fs::remove_dir_all(&cell_dir);
    let mut cell_cfg = test_stream_cfg(Granularity::Block(16), method);
    cell_cfg.shard_budget = 8192;
    run_stream(&post, &base, &quantizable, None, &cell_dir, &cell_cfg).unwrap();

    assert_stores_identical(&anchor_dir, &cell_dir);
    std::fs::remove_dir_all(&anchor_dir).unwrap();
    std::fs::remove_dir_all(&cell_dir).unwrap();
}

/// Telemetry inherits the sweep's determinism contract: counters are
/// commuting atomic adds and every histogram records once per
/// unit/tile/append, so the snapshot's count-type metrics are
/// bitwise-identical for any worker count. Only wall-time-valued
/// metrics (gauges, histogram sums over seconds) may differ.
#[test]
fn telemetry_snapshot_deterministic_across_worker_counts() {
    let (post, base) = fake_ckpts(91, 6, 24);
    let quantizable = quantizable_from_source(&post);
    let method = Method::Search {
        objective: Objective::SignRate,
        range: (0.8, 1.25),
    };

    let run = |workers: usize, tag: &str| {
        let _tg = telemetry::set_current(Telemetry::new(&format!("det-w{workers}")));
        let out_dir = tmp(tag);
        let _ = std::fs::remove_dir_all(&out_dir);
        let mut cfg = StreamConfig::new(Granularity::Block(16), method.clone(), workers);
        cfg.shard_budget = 8192;
        let out =
            run_stream(&post, &base, &quantizable, None, &out_dir, &cfg).unwrap();
        std::fs::remove_dir_all(&out_dir).unwrap();
        out.telemetry
    };
    let a = run(1, "tel_det_w1");
    let b = run(4, "tel_det_w4");

    // the full counter map — retries, quarantines, shard rolls, bytes
    // written, candidates evaluated — is identical, not merely close
    assert_eq!(a.counters, b.counters);
    assert!(a.counters["shard.rolls"] >= 1);
    assert!(a.counters["shard.bytes_written"] > 0);
    assert!(a.counters["sweep.candidates_evaluated"] > 0);
    assert_eq!(a.counters["stream.quarantined"], 0);

    // same histograms registered, same observation counts everywhere
    assert_eq!(
        a.histograms.keys().collect::<Vec<_>>(),
        b.histograms.keys().collect::<Vec<_>>()
    );
    for (name, ha) in &a.histograms {
        assert_eq!(ha.count, b.histograms[name].count, "{name} count");
    }
    assert!(a.histograms["stream.compute.seconds"].count > 0);

    // count-valued observations (candidates per tile): the entire bucket
    // vector and the exact integer-valued sum are bitwise-identical
    let (ca, cb) = (&a.histograms["sweep.tile.candidates"], &b.histograms["sweep.tile.candidates"]);
    assert!(ca.count > 0);
    assert_eq!(ca.buckets, cb.buckets);
    assert_eq!(ca.sum.to_bits(), cb.sum.to_bits());
}

/// `StreamConfig::metrics_out` materialises the registry as JSON at
/// every shard-roll boundary plus end of run — an interrupted run still
/// leaves its last-roll snapshot behind for inspection.
#[test]
fn telemetry_metrics_out_written_at_shard_rolls() {
    let (post, base) = fake_ckpts(92, 4, 24);
    let quantizable = quantizable_from_source(&post);
    let _tg = telemetry::set_current(Telemetry::new("metrics-out-test"));

    let out_dir = tmp("tel_mout");
    let _ = std::fs::remove_dir_all(&out_dir);
    let metrics = tmp("tel_mout_metrics").with_extension("json");
    let _ = std::fs::remove_file(&metrics);
    let mut cfg = test_stream_cfg(
        Granularity::Block(16),
        Method::Search { objective: Objective::SignRate, range: (0.8, 1.25) },
    );
    cfg.shard_budget = 8192;
    cfg.metrics_out = Some(metrics.clone());
    let out = run_stream(&post, &base, &quantizable, None, &out_dir, &cfg).unwrap();
    assert!(!out.telemetry.is_empty());

    let text = std::fs::read_to_string(&metrics).unwrap();
    let doc = Json::parse(&text).unwrap();
    assert_eq!(doc.get("run_id").and_then(Json::as_str), Some("metrics-out-test"));
    for key in ["bucket_bounds", "counters", "gauges", "histograms"] {
        assert!(doc.get(key).is_some(), "metrics.json missing {key}");
    }
    let Some(Json::Obj(counters)) = doc.get("counters") else {
        panic!("counters is not an object")
    };
    assert!(counters.values().all(|v| v.as_f64().unwrap() >= 0.0));
    assert!(counters["shard.rolls"].as_f64().unwrap() >= 1.0);

    std::fs::remove_dir_all(&out_dir).unwrap();
    std::fs::remove_file(&metrics).unwrap();
}

/// Library callers that never install a context get the passive default
/// registry: the run records nothing and the outcome snapshot is empty.
/// (Context is thread-local, so concurrently running tests that do
/// install one cannot leak into this thread.)
#[test]
fn telemetry_default_is_passive_for_library_callers() {
    let (post, base) = fake_ckpts(93, 3, 24);
    let quantizable = quantizable_from_source(&post);
    let out_dir = tmp("tel_passive");
    let _ = std::fs::remove_dir_all(&out_dir);
    let mut cfg = test_stream_cfg(
        Granularity::Block(16),
        Method::Search { objective: Objective::SignRate, range: (0.8, 1.25) },
    );
    cfg.shard_budget = 8192;
    let out = run_stream(&post, &base, &quantizable, None, &out_dir, &cfg).unwrap();
    assert!(out.telemetry.is_empty(), "default registry must be passive");
    assert_eq!(out.telemetry, Default::default());
    std::fs::remove_dir_all(&out_dir).unwrap();
}
