//! Integration tests over the PJRT runtime + AOT artifacts: the Pallas
//! kernels executed through PJRT must agree with the native Rust
//! implementations, and the forward artifact must agree with the native
//! transformer. Skips politely when artifacts are missing.

use daq::eval::model_native::{forward_native, ModelCfg};
use daq::eval::load_params;
use daq::io::dts::Dts;
use daq::metrics::sweep_native;
use daq::quant::{absmax_scales, qdq, Granularity};
use daq::runtime::Runtime;
use daq::tensor::Tensor;
use daq::util::rng::XorShift;

fn open() -> Option<(Runtime, String)> {
    let dir = std::env::var("DAQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    match Runtime::open(&dir) {
        Ok(rt) => Some((rt, dir)),
        Err(e) => {
            eprintln!("skipped: {e:#} (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn qdq_artifact_matches_native_codec() {
    let Some((rt, _)) = open() else { return };
    let mut rng = XorShift::new(3);
    let w = Tensor::new(vec![128, 128], rng.normal_vec(128 * 128, 0.1));
    let s0 = absmax_scales(&w, Granularity::Block(128));
    let s_full = s0.expand();
    let got = rt.qdq_128(&w, &s_full).unwrap();
    let want = qdq(&w, &s0, 1.0);
    let mut diff = 0usize;
    for (a, b) in got.data().iter().zip(want.data()) {
        if a.to_bits() != b.to_bits() {
            diff += 1;
        }
    }
    assert_eq!(diff, 0, "{diff} of {} elements differ", w.len());
}

#[test]
fn sweep_artifact_matches_native_engine() {
    let Some((rt, dir)) = open() else { return };
    let post = Dts::read(format!("{dir}/ckpt_post.dts")).unwrap();
    let base = Dts::read(format!("{dir}/ckpt_base.dts")).unwrap();
    for name in rt.manifest.quantizable.iter().take(3) {
        let wp = post.tensor_f32(name).unwrap();
        let wb = base.tensor_f32(name).unwrap();
        for gran in [Granularity::Block(128), Granularity::PerChannel] {
            let s0 = absmax_scales(&wp, gran);
            let alphas: Vec<f32> = (0..16).map(|i| 0.7 + 0.04 * i as f32).collect();
            let native = sweep_native(&wp, &wb, &s0, &alphas);
            let pjrt = rt.sweep(&wp, &wb, &s0.expand(), &alphas).unwrap();
            for (k, (a, b)) in native.iter().zip(&pjrt).enumerate() {
                // sign counts: XLA may fuse f32 chains differently from
                // the sequential Rust codec, flipping boundary elements —
                // allow O(1) of 64k disagreements
                assert!((a.agree - b.agree).abs() <= 2.0,
                        "{name}/{}: candidate {k} sign counts {} vs {}",
                        gran.label(), a.agree, b.agree);
                assert_eq!(a.n, b.n);
                let rel = |x: f64, y: f64| (x - y).abs() / x.abs().max(1e-9);
                assert!(rel(a.dot, b.dot) < 1e-3, "{name} dot {} vs {}", a.dot, b.dot);
                assert!(rel(a.nq, b.nq) < 1e-3);
                assert!(rel(a.sq, b.sq) < 1e-2, "{name} sq {} vs {}", a.sq, b.sq);
            }
        }
    }
}

#[test]
fn forward_artifact_matches_native_transformer() {
    let Some((rt, dir)) = open() else { return };
    let post = Dts::read(format!("{dir}/ckpt_post.dts")).unwrap();
    let params = load_params(&post).unwrap();
    let cfg = ModelCfg::from_meta(&post.meta).unwrap();
    let b = rt.manifest.serve_batch;

    // real eval tokens, first batch
    let eset = daq::eval::EvalSet::load(&format!("{dir}/eval_style.dts")).unwrap();
    let tokens: Vec<i32> = eset.tokens[..b * cfg.seq_len].to_vec();

    let pjrt_logits = rt.forward(b, &tokens, &params).unwrap();
    let native_logits = forward_native(&params, &cfg, b, &tokens).unwrap();
    assert_eq!(pjrt_logits.len(), native_logits.len());

    // numeric agreement (different op orders): moderate tolerance, and
    // argmax agreement at every position
    let v = cfg.vocab;
    let mut max_abs = 0.0f32;
    let mut argmax_mismatch = 0usize;
    for i in 0..pjrt_logits.len() / v {
        let pr = &pjrt_logits[i * v..(i + 1) * v];
        let nr = &native_logits[i * v..(i + 1) * v];
        for (a, b) in pr.iter().zip(nr) {
            max_abs = max_abs.max((a - b).abs());
        }
        let am = |r: &[f32]| {
            let mut b = 0;
            for j in 1..r.len() {
                if r[j] > r[b] {
                    b = j;
                }
            }
            b
        };
        if am(pr) != am(nr) {
            argmax_mismatch += 1;
        }
    }
    assert!(max_abs < 2e-2, "max |logit diff| = {max_abs}");
    let total = pjrt_logits.len() / v;
    assert!(
        argmax_mismatch * 100 <= total,
        "{argmax_mismatch}/{total} argmax mismatches (>1%)"
    );
}

#[test]
fn manifest_is_consistent_with_checkpoints() {
    let Some((rt, dir)) = open() else { return };
    let post = Dts::read(format!("{dir}/ckpt_post.dts")).unwrap();
    for name in &rt.manifest.param_order {
        assert!(post.contains(name), "manifest param {name} missing in ckpt");
        let shape = rt.manifest.param_shapes.get(name).unwrap();
        assert_eq!(post.get(name).unwrap().shape(), shape.as_slice(),
                   "shape mismatch for {name}");
    }
    for name in &rt.manifest.quantizable {
        let t = post.get(name).unwrap();
        assert_eq!(t.shape().len(), 2);
        let key = (t.shape()[0], t.shape()[1]);
        assert!(rt.manifest.sweeps.contains_key(&key),
                "no sweep artifact for {name} {key:?}");
    }
}
