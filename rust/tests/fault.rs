//! Chaos suite: end-to-end fault tolerance of the quantize-and-serve
//! path under deterministic fault injection (`io::fault`).
//!
//! The acceptance invariants:
//! 1. **no silent corruption** — a truncated shard or a single flipped
//!    payload byte (codes *or* scales) fails loudly, naming the tensor
//!    and the shard, before any logits are produced;
//! 2. **transient faults are invisible** — with the prefetcher retrying,
//!    an injected-blip run produces a store bitwise-identical to the
//!    fault-free run;
//! 3. **persistent corruption degrades, never aborts** — afflicted units
//!    are quarantined (journaled, skipped), the rest of the store is
//!    still written, and a resume over the repaired source reconverges
//!    to the fault-free bytes tensor-for-tensor;
//! 4. **the scheduler contains request-level faults** — overload is shed
//!    at admission, slow requests die at their deadline, a faulty decode
//!    kills only its own slot, and every surviving request's tokens are
//!    bitwise what a fault-free run produces.
//!
//! The CI chaos lane sweeps `DAQ_FAULT_SEED` x `DAQ_TEST_WORKERS`; every
//! cell must pass (seeds are probed into a usable regime, so an unlucky
//! seed relocates the faults instead of weakening the assertions).

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use anyhow::{bail, Result};
use daq::coordinator::stream::{run_stream, StreamConfig, RESUME_JOURNAL};
use daq::coordinator::Method;
use daq::eval::decode::TokenDecoder;
use daq::eval::QuantizedParams;
use daq::experiments::quantizable_from_source;
use daq::io::dts::{Dts, DtsIndex, DtsTensor};
use daq::io::fault::{
    flip_byte, truncate_file, FaultConfig, FaultSource, PERSISTENT_MARKER,
};
use daq::io::shard::ShardedDts;
use daq::io::TensorSource;
use daq::quant::Granularity;
use daq::serve::{gen_requests, serve, ServeConfig};
use daq::tensor::Tensor;
use daq::util::json::Json;
use daq::util::rng::XorShift;
use daq::util::telemetry::{self, Telemetry};

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("daq_faulttest_{tag}_{}", std::process::id()))
}

fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Base seed for the injected faults; the CI chaos matrix varies it.
fn fault_seed() -> u64 {
    env_u64("DAQ_FAULT_SEED", 0)
}

/// Streaming config matching the chaos matrix cell: `DAQ_TEST_WORKERS`
/// varies unit-parallelism, retries back off without sleeping so the
/// suite stays fast, and the small shard budget forces multi-shard
/// stores (so corruption and quarantine cross shard boundaries).
fn chaos_stream_cfg() -> StreamConfig {
    let mut cfg = StreamConfig::new(
        Granularity::PerChannel,
        Method::AbsMax,
        env_usize("DAQ_TEST_WORKERS", 2),
    );
    cfg.shard_budget = 4 << 10;
    cfg.retry_base_ms = 0;
    cfg
}

/// Synthetic (post, base) pair, same shape family as the streaming
/// suite: quantizable GEMMs plus layernorm/embedding passthroughs.
fn fake_ckpts(seed: u64, n_layers: usize, dim: usize) -> (Dts, Dts) {
    let mut rng = XorShift::new(seed);
    let mut base = Dts::new();
    let mut post = Dts::new();
    base.meta.insert("vocab".into(), "64".into());
    post.meta.insert("vocab".into(), "64".into());
    for i in 0..n_layers {
        let name = match i % 3 {
            0 => format!("l{i}.wq"),
            1 => format!("l{i}.w1"),
            _ => format!("l{i}.w2"),
        };
        let (r, c) = (dim, dim + 8 * (i % 2));
        let wb = Tensor::new(vec![r, c], rng.normal_vec(r * c, 0.1));
        let wp = Tensor::new(
            vec![r, c],
            wb.data().iter().map(|&b| b + rng.normal() * 0.002).collect(),
        );
        base.insert_f32(&name, &wb);
        post.insert_f32(&name, &wp);
        let g = Tensor::full(vec![r], 1.0);
        base.insert_f32(&format!("l{i}.ln1.g"), &g);
        post.insert_f32(&format!("l{i}.ln1.g"), &g);
    }
    let embed = Tensor::new(vec![16, dim], rng.normal_vec(16 * dim, 0.1));
    base.insert_f32("embed", &embed);
    post.insert_f32("embed", &embed);
    (post, base)
}

/// Quantize a fresh synthetic model into `tag`'s directory; returns the
/// store dir and the quantizable layer names.
fn build_store(tag: &str) -> (PathBuf, Vec<String>) {
    let (post, base) = fake_ckpts(13, 5, 16);
    let quantizable = quantizable_from_source(&post);
    let dir = tmp(tag);
    let _ = std::fs::remove_dir_all(&dir);
    run_stream(&post, &base, &quantizable, None, &dir, &chaos_stream_cfg()).unwrap();
    (dir, quantizable)
}

/// Absolute file position and length of one tensor's payload inside its
/// shard (index entries store payload-section-relative offsets).
fn payload_pos(dir: &Path, name: &str) -> (PathBuf, u64, u64) {
    let store = ShardedDts::open(dir).unwrap();
    let (shard, _) = store.entry(name).expect("tensor in store");
    let shard_path = dir.join(shard);
    let idx = DtsIndex::open(&shard_path).unwrap();
    let flen = std::fs::metadata(&shard_path).unwrap().len();
    let base = flen - idx.payload_bytes();
    let e = idx.entry(name).expect("tensor in shard index");
    (shard_path, base + e.offset, e.nbytes)
}

fn assert_tensor_bits_eq(a: &DtsTensor, b: &DtsTensor, what: &str) {
    match (a, b) {
        (
            DtsTensor::F32 { shape: sa, data: da },
            DtsTensor::F32 { shape: sb, data: db },
        ) => {
            assert_eq!(sa, sb, "{what}: shape");
            assert_eq!(da.len(), db.len(), "{what}: length");
            for (i, (x, y)) in da.iter().zip(db).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "{what}[{i}]");
            }
        }
        (
            DtsTensor::U8 { shape: sa, data: da },
            DtsTensor::U8 { shape: sb, data: db },
        ) => {
            assert_eq!(sa, sb, "{what}: shape");
            assert_eq!(da, db, "{what}: bytes");
        }
        _ => panic!("{what}: dtype mismatch"),
    }
}

/// Tensor-for-tensor equality of two stores: same name *set*, bitwise
/// payloads, same metadata. Deliberately order-insensitive — a resumed
/// run packs re-quantized units into later shards than the fault-free
/// run did, so shard layout may differ while content must not.
fn assert_stores_equivalent(a: &Path, b: &Path) {
    let sa = ShardedDts::open(a).unwrap();
    let sb = ShardedDts::open(b).unwrap();
    let na: BTreeSet<String> = TensorSource::names(&sa).into_iter().collect();
    let nb: BTreeSet<String> = TensorSource::names(&sb).into_iter().collect();
    assert_eq!(na, nb, "stores hold different tensor sets");
    for name in &na {
        assert_tensor_bits_eq(
            &sa.read_tensor(name).unwrap(),
            &sb.read_tensor(name).unwrap(),
            name,
        );
    }
    assert_eq!(TensorSource::meta(&sa), TensorSource::meta(&sb), "metadata");
}

// ---------------------------------------------------------------------
// 1. Corruption detection: no silent wrong logits, ever.
// ---------------------------------------------------------------------

/// A torn write (truncated shard) fails the payload read, naming the
/// tensor and the shard — and the quantized-resident loader refuses the
/// store instead of serving from it.
#[test]
fn truncated_shard_is_detected_and_named() {
    let (dir, quantizable) = build_store("trunc");
    let target = format!("{}.codes", quantizable[0]);
    let (shard_path, off, nbytes) = payload_pos(&dir, &target);
    truncate_file(&shard_path, off + nbytes - 1).unwrap();

    let store = ShardedDts::open(&dir).unwrap();
    let msg = format!("{:#}", store.read_tensor(&target).unwrap_err());
    assert!(msg.contains(&target), "error must name the tensor: {msg}");
    assert!(msg.contains("payload of"), "{msg}");
    let shard_name = shard_path.file_name().unwrap().to_str().unwrap();
    assert!(msg.contains(shard_name), "error must name the shard: {msg}");

    // never silent wrong logits: the loader fails, it does not serve
    assert!(QuantizedParams::load(&store).is_err());
    std::fs::remove_dir_all(&dir).unwrap();
}

/// One flipped bit in a *codes* payload trips the stored CRC-32 on read.
#[test]
fn flipped_codes_byte_fails_checksum_naming_tensor_and_shard() {
    let (dir, quantizable) = build_store("flipcodes");
    let store = ShardedDts::open(&dir).unwrap();
    // the streamed store is v2: every payload carries a CRC and reads
    // back verified before we corrupt anything
    for name in TensorSource::names(&store) {
        assert!(store.crc32_of(&name).is_some(), "{name}: no stored CRC");
        store.read_tensor(&name).unwrap();
    }
    let target = format!("{}.codes", quantizable[0]);
    let (shard_path, off, nbytes) = payload_pos(&dir, &target);
    flip_byte(&shard_path, off + nbytes / 2, 0x20).unwrap();

    let store = ShardedDts::open(&dir).unwrap();
    let msg = format!("{:#}", store.read_tensor(&target).unwrap_err());
    assert!(msg.contains("checksum mismatch"), "{msg}");
    assert!(msg.contains(&target), "error must name the tensor: {msg}");
    let shard_name = shard_path.file_name().unwrap().to_str().unwrap();
    assert!(msg.contains(shard_name), "error must name the shard: {msg}");

    let e = QuantizedParams::load(&store).unwrap_err();
    assert!(format!("{e:#}").contains("checksum mismatch"), "{e:#}");
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Same guarantee for a *scales* payload — a flipped scale byte would
/// silently rescale a whole channel if it were not checksummed.
#[test]
fn flipped_scales_byte_fails_checksum_naming_tensor_and_shard() {
    let (dir, quantizable) = build_store("flipscales");
    let target = format!("{}.scales", quantizable[1 % quantizable.len()]);
    let (shard_path, off, nbytes) = payload_pos(&dir, &target);
    flip_byte(&shard_path, off + nbytes / 2, 0x01).unwrap();

    let store = ShardedDts::open(&dir).unwrap();
    let msg = format!("{:#}", store.read_tensor(&target).unwrap_err());
    assert!(msg.contains("checksum mismatch"), "{msg}");
    assert!(msg.contains(&target), "error must name the tensor: {msg}");

    let e = QuantizedParams::load(&store).unwrap_err();
    assert!(format!("{e:#}").contains("checksum mismatch"), "{e:#}");
    std::fs::remove_dir_all(&dir).unwrap();
}

// ---------------------------------------------------------------------
// 2. Transient faults: retried into invisibility.
// ---------------------------------------------------------------------

/// With transient read errors injected at a rate the retry budget
/// covers, the streamed store is bitwise-identical to the fault-free
/// run — same shard layout, same payload bytes, same metadata.
#[test]
fn transient_read_faults_retry_to_the_fault_free_store() {
    let (post, base) = fake_ckpts(29, 6, 16);
    let quantizable = quantizable_from_source(&post);
    let mut cfg = chaos_stream_cfg();
    cfg.max_retries = 12;

    let ref_dir = tmp("transient_ref");
    let _ = std::fs::remove_dir_all(&ref_dir);
    run_stream(&post, &base, &quantizable, None, &ref_dir, &cfg).unwrap();

    // probe the seed forward until the very first PRNG draw injects, so
    // every matrix cell provably exercises the retry path (the shared
    // fault RNG draws once per read, starting at the seed)
    let rate = 0.2;
    let seed = (fault_seed()..)
        .find(|&s| XorShift::new(s).f64() < rate)
        .expect("open-ended seed probe");
    let fcfg = FaultConfig { seed, read_error_rate: rate, ..Default::default() };
    let fs = FaultSource::new(&post, fcfg);

    let out_dir = tmp("transient_out");
    let _ = std::fs::remove_dir_all(&out_dir);
    let outcome = run_stream(&fs, &base, &quantizable, None, &out_dir, &cfg).unwrap();

    let c = fs.counters();
    assert!(c.transient > 0, "probed seed must inject at least one fault");
    assert_eq!(c.persistent, 0);
    assert!(
        outcome.quarantined.is_empty(),
        "transient faults must never quarantine: {:?}",
        outcome.quarantined
    );
    // bitwise-identical, *including* shard packing order
    let sa = ShardedDts::open(&out_dir).unwrap();
    let sb = ShardedDts::open(&ref_dir).unwrap();
    assert_eq!(TensorSource::names(&sa), TensorSource::names(&sb));
    assert_stores_equivalent(&out_dir, &ref_dir);

    std::fs::remove_dir_all(&out_dir).unwrap();
    std::fs::remove_dir_all(&ref_dir).unwrap();
}

// ---------------------------------------------------------------------
// 3. Persistent corruption: quarantine, then reconverge after repair.
// ---------------------------------------------------------------------

/// Persistently corrupt tensors are quarantined (journaled, skipped —
/// the pipeline finishes the rest), and a `resume` over the repaired
/// source re-quantizes exactly the quarantined units, converging to the
/// fault-free store tensor-for-tensor.
#[test]
fn persistent_corruption_quarantines_then_resume_reconverges() {
    let (post, base) = fake_ckpts(31, 6, 16);
    let quantizable = quantizable_from_source(&post);
    let cfg = chaos_stream_cfg();

    let ref_dir = tmp("quarantine_ref");
    let _ = std::fs::remove_dir_all(&ref_dir);
    run_stream(&post, &base, &quantizable, None, &ref_dir, &cfg).unwrap();

    // probe the seed until the per-name fault set afflicts at least one
    // quantizable layer but not all of them: the run must both
    // quarantine *and* make progress. Persistent faults depend only on
    // (seed, name), so probing reads predicts the run exactly.
    let all_names: Vec<String> = TensorSource::names(&post);
    let mut fcfg = FaultConfig {
        flip_rate: 0.25,
        truncate_rate: 0.1,
        ..Default::default()
    };
    let mut afflicted: BTreeSet<String> = BTreeSet::new();
    let mut found = false;
    for k in 0..512u64 {
        fcfg.seed = fault_seed().wrapping_add(k.wrapping_mul(0x9E37_79B9));
        let probe = FaultSource::new(&post, fcfg);
        afflicted = all_names
            .iter()
            .filter(|n| probe.read_tensor(n).is_err())
            .cloned()
            .collect();
        let hit = quantizable.iter().filter(|q| afflicted.contains(*q)).count();
        if hit >= 1 && hit < quantizable.len() {
            found = true;
            break;
        }
    }
    assert!(found, "no usable fault seed in 512 probes");

    let fs = FaultSource::new(&post, fcfg);
    let out_dir = tmp("quarantine_out");
    let _ = std::fs::remove_dir_all(&out_dir);
    let outcome = run_stream(&fs, &base, &quantizable, None, &out_dir, &cfg).unwrap();

    // exactly the afflicted names were quarantined — no more, no less
    let got: BTreeSet<String> = outcome.quarantined.iter().cloned().collect();
    assert_eq!(got, afflicted, "quarantine set != injected fault set");
    // quarantined tensors are *absent*, not silently wrong
    let partial = ShardedDts::open(&out_dir).unwrap();
    for name in &afflicted {
        assert!(
            !TensorSource::contains(&partial, name)
                && !partial.contains(&format!("{name}.codes")),
            "{name}: quarantined tensor leaked into the store"
        );
    }
    // each quarantine is journaled with its error, for the repair loop
    let journal = std::fs::read_to_string(out_dir.join(RESUME_JOURNAL)).unwrap();
    for name in &afflicted {
        let line = journal
            .lines()
            .find(|l| l.contains("quarantined") && l.contains(name.as_str()));
        assert!(line.is_some(), "{name}: no quarantine journal line");
        assert!(
            line.unwrap().contains(PERSISTENT_MARKER),
            "{name}: journal line lost the error: {}",
            line.unwrap()
        );
    }

    // "repair" = read the clean source; resume re-quantizes exactly the
    // quarantined units and reconverges to the fault-free bytes
    let mut rcfg = cfg.clone();
    rcfg.resume = true;
    let resumed = run_stream(&post, &base, &quantizable, None, &out_dir, &rcfg).unwrap();
    assert!(resumed.quarantined.is_empty());
    assert!(resumed.resumed > 0, "clean units must resume, not recompute");
    assert_stores_equivalent(&out_dir, &ref_dir);

    std::fs::remove_dir_all(&out_dir).unwrap();
    std::fs::remove_dir_all(&ref_dir).unwrap();
}

// ---------------------------------------------------------------------
// 4. Serving: shed, deadline, and per-slot fault containment.
// ---------------------------------------------------------------------

/// Deterministic decoder for scheduler chaos: next token is a hash of
/// the consumed history, one step optionally sleeps, and feeding the
/// poison token fails the step (an injected decode fault).
struct ChaosDecoder {
    vocab: usize,
    max_pos: usize,
    poison: Option<i32>,
    step_delay_ms: u64,
}

impl TokenDecoder for ChaosDecoder {
    type Session = Vec<i32>;

    fn start(&self) -> Vec<i32> {
        Vec::new()
    }

    fn step(&self, s: &mut Vec<i32>, token: i32) -> Result<Vec<f32>> {
        if self.step_delay_ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(self.step_delay_ms));
        }
        if self.poison == Some(token) {
            bail!("injected decoder fault on token {token}");
        }
        s.push(token);
        let mut h = 0i64;
        for &t in s.iter() {
            h = h.wrapping_mul(31).wrapping_add(t as i64);
        }
        let mut logits = vec![0.0f32; self.vocab];
        logits[h.rem_euclid(self.vocab as i64) as usize] = 1.0;
        Ok(logits)
    }

    fn max_positions(&self) -> usize {
        self.max_pos
    }

    fn resident_param_bytes(&self) -> usize {
        4096
    }
}

/// Overload + decode faults together: requests past the admission budget
/// are shed, poisoned requests die in their own slot, and every survivor
/// decodes tokens bitwise-equal to the fault-free run.
#[test]
fn scheduler_survivors_are_bitwise_unchanged_under_shed_and_faults() {
    let dec = ChaosDecoder { vocab: 64, max_pos: 32, poison: Some(-7), step_delay_ms: 0 };
    let clean = gen_requests(12, 21);
    // fault-free reference: everything admitted, nothing poisoned
    let reference = serve(
        &dec,
        &clean,
        &ServeConfig { slots: 2, new_tokens: 4, ..Default::default() },
    )
    .unwrap();
    assert_eq!((reference.shed, reference.timed_out, reference.errored), (0, 0, 0));

    // chaos run: slots 2 + queue budget 5 admits the first 7 of 12;
    // requests 3 and 6 carry the poison token in their prompt
    let mut reqs = clean.clone();
    reqs[3].prompt[1] = -7;
    reqs[6].prompt[1] = -7;
    let rep = serve(
        &dec,
        &reqs,
        &ServeConfig {
            slots: 2,
            new_tokens: 4,
            queue_budget: Some(5),
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(rep.requests, 12);
    assert_eq!(rep.shed, 5, "12 arrivals into slots 2 + budget 5");
    assert_eq!(rep.errored, 2, "both poisoned requests, nothing else");
    assert_eq!(rep.timed_out, 0);
    assert_eq!(rep.request_latency.count(), 5, "only clean admitted requests finish");
    for idx in 0..12 {
        if idx == 3 || idx == 6 {
            assert!(rep.completions[idx].is_empty(), "poisoned request {idx} decoded");
        } else if idx >= 7 {
            assert!(rep.completions[idx].is_empty(), "shed request {idx} decoded");
        } else {
            assert_eq!(
                rep.completions[idx], reference.completions[idx],
                "surviving request {idx} diverged from the fault-free run"
            );
            assert_eq!(rep.completions[idx].len(), 4);
        }
    }
}

/// A uniformly slow decoder against a tight deadline: every request is
/// evicted at its first tick boundary with its (empty) partial output,
/// the run terminates, and the evictions are all accounted for.
#[test]
fn slow_decoder_requests_all_die_at_the_deadline() {
    let dec = ChaosDecoder { vocab: 64, max_pos: 32, poison: None, step_delay_ms: 2 };
    let reqs = gen_requests(5, 33);
    let rep = serve(
        &dec,
        &reqs,
        &ServeConfig {
            slots: 2,
            new_tokens: 6,
            deadline_ms: Some(1.0),
            ..Default::default()
        },
    )
    .unwrap();
    // prefill alone takes ~26ms per request (13 steps x 2ms), so the
    // 1ms deadline has always expired by the first tick
    assert_eq!(rep.timed_out, 5);
    assert_eq!((rep.shed, rep.errored), (0, 0));
    assert_eq!(rep.request_latency.count(), 5, "evicted requests still complete");
    for gen in &rep.completions {
        assert!(gen.is_empty(), "no tokens fit inside the deadline");
    }
}

// ---------------------------------------------------------------------
// 5. Telemetry: the trace is a faithful journal of the chaos.
// ---------------------------------------------------------------------

/// Under mixed transient + persistent fault injection the JSONL trace
/// stays well-formed — every line parses, timestamps are monotone, and
/// every retry and every quarantine the pipeline performed has exactly
/// one matching trace event (cross-checked against the registry
/// counters and the outcome's quarantine list).
#[test]
fn trace_journal_is_well_formed_under_chaos() {
    let (post, base) = fake_ckpts(37, 6, 16);
    let all_names: Vec<String> = TensorSource::names(&post);
    let quantizable = quantizable_from_source(&post);
    let mut cfg = chaos_stream_cfg();
    cfg.max_retries = 12;

    // probe the seed until (a) the very first shared-RNG draw injects a
    // transient fault — so the run provably retries at least once — and
    // (b) the per-name persistent fault set afflicts some but not all
    // quantizable layers — so the run both quarantines and progresses.
    // Persistent faults are checked before the transient draw, so the
    // probe's marker-based classification predicts the run exactly.
    let rate = 0.2;
    let mut fcfg = FaultConfig {
        read_error_rate: rate,
        flip_rate: 0.25,
        truncate_rate: 0.1,
        ..Default::default()
    };
    let mut found = false;
    for k in 0..4096u64 {
        fcfg.seed = fault_seed().wrapping_add(k.wrapping_mul(0x9E37_79B9));
        if XorShift::new(fcfg.seed).f64() >= rate {
            continue;
        }
        let probe = FaultSource::new(&post, fcfg);
        let afflicted: BTreeSet<String> = all_names
            .iter()
            .filter(|n| {
                probe
                    .read_tensor(n)
                    .err()
                    .is_some_and(|e| format!("{e:#}").contains(PERSISTENT_MARKER))
            })
            .cloned()
            .collect();
        let hit = quantizable.iter().filter(|q| afflicted.contains(*q)).count();
        if hit >= 1 && hit < quantizable.len() && afflicted.len() < all_names.len() {
            found = true;
            break;
        }
    }
    assert!(found, "no usable fault seed in 4096 probes");

    let tel = Telemetry::new("chaos-trace");
    let trace = tmp("trace_journal").with_extension("jsonl");
    let _ = std::fs::remove_file(&trace);
    tel.set_trace_out(&trace).unwrap();
    let _tg = telemetry::set_current(tel);

    let fs = FaultSource::new(&post, fcfg);
    let out_dir = tmp("trace_out");
    let _ = std::fs::remove_dir_all(&out_dir);
    let outcome = run_stream(&fs, &base, &quantizable, None, &out_dir, &cfg).unwrap();
    assert!(!outcome.quarantined.is_empty(), "probed seed must quarantine");

    let text = std::fs::read_to_string(&trace).unwrap();
    let mut last_ts = f64::NEG_INFINITY;
    let (mut retries, mut quarantines, mut spans) = (0u64, 0u64, 0u64);
    for (i, line) in text.lines().enumerate() {
        let doc = Json::parse(line)
            .unwrap_or_else(|e| panic!("trace line {i} unparseable ({e:?}): {line}"));
        for key in ["ts_us", "run", "kind", "name"] {
            assert!(doc.get(key).is_some(), "trace line {i} missing {key}: {line}");
        }
        assert_eq!(doc.get("run").and_then(Json::as_str), Some("chaos-trace"));
        let ts = doc.get("ts_us").and_then(Json::as_f64).unwrap();
        assert!(ts >= last_ts, "trace line {i}: ts_us went backwards");
        last_ts = ts;
        let kind = doc.get("kind").and_then(Json::as_str).unwrap();
        let name = doc.get("name").and_then(Json::as_str).unwrap();
        match kind {
            "span" => {
                spans += 1;
                assert!(
                    doc.get("dur_us").and_then(Json::as_f64).is_some_and(|d| d >= 0.0),
                    "trace line {i}: span without dur_us"
                );
            }
            "event" => match name {
                "stream.retry" => {
                    retries += 1;
                    assert!(doc.get("attempt").is_some(), "retry event lost its attempt");
                }
                "stream.quarantine" => {
                    quarantines += 1;
                    let unit = doc.get("unit").and_then(Json::as_str).unwrap();
                    assert!(
                        outcome.quarantined.iter().any(|q| unit.contains(q.as_str())),
                        "quarantine event for unknown unit {unit:?}"
                    );
                }
                _ => {}
            },
            other => panic!("trace line {i}: unknown kind {other:?}"),
        }
    }
    assert!(spans > 0, "no spans traced");
    // 1:1 accounting: the trace neither drops nor invents faults
    assert!(retries > 0, "probed seed must retry at least once");
    assert_eq!(retries, outcome.telemetry.counters["stream.retries"]);
    assert_eq!(quarantines, outcome.quarantined.len() as u64);
    assert_eq!(quarantines, outcome.telemetry.counters["stream.quarantined"]);

    std::fs::remove_dir_all(&out_dir).unwrap();
    std::fs::remove_file(&trace).unwrap();
}
