//! Bench: Table 1 — metric comparison (range / delta-awareness /
//! complexity), with measured per-element costs on this machine, plus the
//! metric-evaluation microbenchmarks backing the "Complexity" column.

use daq::metrics::{delta_stats, sweep_native};
use daq::quant::{absmax_scales, qdq, Granularity};
use daq::report::Table;
use daq::tensor::Tensor;
use daq::util::bench::bench;
use daq::util::rng::XorShift;

fn pair(r: usize, c: usize, seed: u64) -> (Tensor, Tensor) {
    let mut rng = XorShift::new(seed);
    let wb = Tensor::new(vec![r, c], rng.normal_vec(r * c, 0.1));
    let wp = Tensor::new(
        vec![r, c],
        wb.data().iter().map(|&b| b + rng.normal() * 0.002).collect(),
    );
    (wp, wb)
}

fn main() {
    let (wp, wb) = pair(512, 512, 1);
    let n = wp.len() as f64;

    println!("{}", daq::experiments::table1(&wp, &wb).unwrap().render());

    // microbench: each metric's evaluation cost given a quantized tensor
    // (the closed-form extraction is O(1); the pass is shared)
    let s0 = absmax_scales(&wp, Granularity::Block(128));
    let wq = qdq(&wp, &s0, 1.0);

    let mut t = Table::new(
        "Metric evaluation cost (512x512 tensor)",
        &["operation", "mean ms", "ns/elem"],
    );
    let r = bench("delta_stats (all 3 metrics, one pass)", 2, 10, || {
        delta_stats(&wp, &wb, &wq)
    });
    t.row(vec![r.name.clone(), format!("{:.3}", r.mean_s * 1e3),
               format!("{:.2}", r.mean_s * 1e9 / n)]);

    for nc in [1usize, 4, 16] {
        let alphas: Vec<f32> = (0..nc).map(|i| 0.8 + 0.05 * i as f32).collect();
        let r = bench(&format!("fused sweep, {nc} candidates"), 1, 5, || {
            sweep_native(&wp, &wb, &s0, &alphas)
        });
        t.row(vec![r.name.clone(), format!("{:.3}", r.mean_s * 1e3),
                   format!("{:.2}", r.mean_s * 1e9 / (n * nc as f64))]);
    }
    println!("{}", t.render());

    // demonstrate delta-awareness empirically: MSE is invariant to the
    // base model, SignRate/CosSim are not (paper Eq. 7)
    let mut rng = XorShift::new(99);
    let wb2 = Tensor::new(vec![512, 512], rng.normal_vec(512 * 512, 0.1));
    let s_a = delta_stats(&wp, &wb, &wq);
    let s_b = delta_stats(&wp, &wb2, &wq);
    let mut t2 = Table::new(
        "Delta-awareness check (same quantization, different base)",
        &["metric", "base A", "base B", "base-dependent?"],
    );
    t2.row(vec!["MSE".into(), format!("{:.3e}", s_a.mse()),
                format!("{:.3e}", s_b.mse()),
                if (s_a.mse() - s_b.mse()).abs() < 1e-12 { "NO".into() }
                else { "yes".into() }]);
    t2.row(vec!["SignRate".into(), format!("{:.4}", s_a.sign_rate()),
                format!("{:.4}", s_b.sign_rate()), "YES".into()]);
    t2.row(vec!["CosSim".into(), format!("{:.4}", s_a.cos_sim()),
                format!("{:.4}", s_b.cos_sim()), "YES".into()]);
    println!("{}", t2.render());
}
