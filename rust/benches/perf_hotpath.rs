//! Bench: §Perf — hot-path profiling across the stack:
//!   L3 native fused sweep throughput (the coordinator's hot loop),
//!   the planned tiled engine vs the naive reference, tile-worker
//!   scaling, PJRT sweep vs native (when artifacts exist), and
//!   end-to-end pipeline latency.
//!
//! Emits a machine-readable `BENCH_sweep.json` (path overridable via
//! `DAQ_BENCH_OUT`) so the sweep-throughput trajectory is tracked across
//! PRs: one record per (shape, granularity, variant, workers) with
//! Melem/s and speedup vs the naive sweep.

use daq::coordinator::stream::{run_stream, StreamConfig};
use daq::coordinator::{run_pipeline, Engine, Method, PipelineConfig};
use daq::experiments::{quantizable_from_source, Lab};
use daq::io::dts::Dts;
use daq::metrics::{sweep_native, sweep_native_regions, SweepPlan};
use daq::quant::{absmax_scales, kernels, CodeFormat, Granularity};
use daq::report::Table;
use daq::search::Objective;
use daq::tensor::Tensor;
use daq::util::bench::bench;
use daq::util::rng::XorShift;
use daq::util::telemetry::{self, Telemetry};

fn pair(r: usize, c: usize, seed: u64) -> (Tensor, Tensor) {
    let mut rng = XorShift::new(seed);
    let wb = Tensor::new(vec![r, c], rng.normal_vec(r * c, 0.1));
    let wp = Tensor::new(
        vec![r, c],
        wb.data().iter().map(|&b| b + rng.normal() * 0.002).collect(),
    );
    (wp, wb)
}

/// One machine-readable bench record.
struct Record {
    shape: String,
    granularity: String,
    variant: String,
    workers: usize,
    simd: String,
    mean_ms: f64,
    melem_per_s: f64,
    speedup_vs_naive: f64,
}

impl Record {
    fn json(&self) -> String {
        format!(
            "{{\"shape\": \"{}\", \"granularity\": \"{}\", \"variant\": \"{}\", \
             \"workers\": {}, \"simd\": \"{}\", \"mean_ms\": {:.4}, \
             \"melem_per_s\": {:.2}, \"speedup_vs_naive\": {:.3}}}",
            self.shape,
            self.granularity,
            self.variant,
            self.workers,
            self.simd,
            self.mean_ms,
            self.melem_per_s,
            self.speedup_vs_naive
        )
    }
}

fn main() {
    let n_candidates = 16usize;
    let alphas: Vec<f32> = (0..n_candidates).map(|i| 0.8 + 0.028 * i as f32).collect();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    // ISA the kernel layer dispatched to for this run (honours DAQ_SIMD);
    // recorded per row so baselines from different runners stay comparable
    let simd_label = kernels::label();
    println!("simd dispatch: {simd_label}");
    let mut records: Vec<Record> = Vec::new();
    // DAQ_BENCH_FAST=1: reduced shape set for the CI bench-smoke lane —
    // every variant still emits its BENCH_sweep.json rows, just on
    // smaller tensors so the job finishes in minutes
    let fast = std::env::var("DAQ_BENCH_FAST").map(|v| v == "1").unwrap_or(false);

    // --- §Perf: sweep variants — naive / region-hoisted (negative
    //     result, kept for the record) / planned tiled / planned + workers
    let sweep_shapes: &[(usize, usize)] =
        if fast { &[(256, 256)] } else { &[(512, 512), (1024, 1024)] };
    for &(r, c) in sweep_shapes {
        let (wp, wb) = pair(r, c, (r + c) as u64);
        let mut t = Table::new(
            &format!("Sweep engines ({r}x{c}, {n_candidates} candidates)"),
            &["variant", "granularity", "workers", "mean ms", "Melem/s (xNC)", "speedup"],
        );
        for gran in [Granularity::Block(128), Granularity::PerChannel] {
            let s0 = absmax_scales(&wp, gran);
            let evals = (r * c * n_candidates) as f64;
            let shape = format!("{r}x{c}");

            let naive = bench("naive", 1, 5, || sweep_native(&wp, &wb, &s0, &alphas));
            let naive_mean_s = naive.mean_s;
            let mut push = |variant: &str, workers: usize, mean_s: f64| {
                let rec = Record {
                    shape: shape.clone(),
                    granularity: gran.label(),
                    variant: variant.into(),
                    workers,
                    simd: simd_label.into(),
                    mean_ms: mean_s * 1e3,
                    melem_per_s: evals / mean_s / 1e6,
                    speedup_vs_naive: naive_mean_s / mean_s,
                };
                t.row(vec![
                    variant.into(),
                    gran.label(),
                    workers.to_string(),
                    format!("{:.2}", rec.mean_ms),
                    format!("{:.1}", rec.melem_per_s),
                    format!("{:.2}x", rec.speedup_vs_naive),
                ]);
                records.push(rec);
            };
            push("naive (per-element recompute)", 1, naive_mean_s);

            let regions =
                bench("regions", 1, 5, || sweep_native_regions(&wp, &wb, &s0, &alphas));
            push("region-hoisted (superseded)", 1, regions.mean_s);

            // plan amortized across batches, as Algorithm 1 uses it
            let plan = SweepPlan::new(&wp, &wb, &s0);
            let planned =
                bench("planned", 1, 5, || plan.eval_with_workers(&alphas, 1));
            push("planned tiled", 1, planned.mean_s);

            for workers in [2usize, 4, 8] {
                if workers > cores {
                    continue;
                }
                let res = bench(&format!("planned x{workers}"), 1, 5, || {
                    plan.eval_with_workers(&alphas, workers)
                });
                push("planned tiled", workers, res.mean_s);
            }

            // the plan build itself, for the amortization story (built
            // once per layer, reused for all 16+ candidate evaluations)
            let build = bench("plan build", 1, 5, || SweepPlan::new(&wp, &wb, &s0));
            t.row(vec![
                "  (plan build, once per layer)".into(),
                gran.label(),
                "1".into(),
                format!("{:.2}", build.mean_s * 1e3),
                "-".into(),
                "-".into(),
            ]);
        }
        println!("{}", t.render());
    }

    // --- L3 native sweep throughput across shapes (reference engine) ---
    let mut t = Table::new(
        "Naive fused sweep throughput (16 candidates)",
        &["shape", "granularity", "mean ms", "Melem/s (xNC)"],
    );
    let naive_shapes: &[(usize, usize)] = if fast {
        &[(128, 128), (128, 512)]
    } else {
        &[(128, 128), (128, 512), (512, 512), (1024, 1024)]
    };
    for &(r, c) in naive_shapes {
        let (wp, wb) = pair(r, c, (r + c) as u64);
        for gran in [Granularity::Block(128), Granularity::PerChannel] {
            let s0 = absmax_scales(&wp, gran);
            let res = bench(&format!("{r}x{c}/{}", gran.label()), 1, 5, || {
                sweep_native(&wp, &wb, &s0, &alphas)
            });
            let melem = (r * c * n_candidates) as f64 / res.mean_s / 1e6;
            t.row(vec![
                format!("{r}x{c}"),
                gran.label(),
                format!("{:.2}", res.mean_s * 1e3),
                format!("{melem:.1}"),
            ]);
        }
    }
    println!("{}", t.render());

    // --- §Perf: streaming pipeline vs in-memory pipeline -------------
    // synthetic 8-layer model; the streaming driver pays shard I/O and
    // bounded admission for O(depth) residency — this row tracks that tax
    {
        let n_layers = if fast { 4 } else { 8 };
        let dim = if fast { 128 } else { 256 };
        let mut post = Dts::new();
        let mut base = Dts::new();
        let mut rng = XorShift::new(97);
        for i in 0..n_layers {
            let name = format!("l{i}.wq");
            let wb = Tensor::new(vec![dim, dim], rng.normal_vec(dim * dim, 0.1));
            let wp = Tensor::new(
                vec![dim, dim],
                wb.data().iter().map(|&b| b + rng.normal() * 0.002).collect(),
            );
            base.insert_f32(&name, &wb);
            post.insert_f32(&name, &wp);
        }
        let quantizable = quantizable_from_source(&post);
        let method = Method::Search {
            objective: Objective::SignRate,
            range: (0.8, 1.25),
        };
        let gran = Granularity::Block(128);
        let workers = cores.min(8);

        let pcfg = PipelineConfig::new(gran, method.clone(), Engine::Native { workers });
        let mem = bench("pipeline (in-memory)", 0, 3, || {
            run_pipeline(&post, &base, &quantizable, None, &pcfg, None).unwrap()
        });

        // forced-scalar companion for the same workload: this pair prices
        // the SIMD kernel layer itself, and check_bench_regress.py
        // --simd-speedup gates the intra-run ratio (skipped with a warning
        // when the ambient dispatch is already scalar)
        let prev = kernels::force(kernels::SimdMode::Scalar);
        let scalar = bench("pipeline (forced scalar)", 0, 3, || {
            run_pipeline(&post, &base, &quantizable, None, &pcfg, None).unwrap()
        });
        kernels::force(prev);

        // sub-8-bit path: INT4 codes (group 64) + rank-4 ΔW residual —
        // same pipeline, but the sweep/quantize stages dispatch through
        // CodeFormat and the power-iteration residual rides on top
        let mut icfg = PipelineConfig::new(
            Granularity::Block(64),
            method.clone(),
            Engine::Native { workers },
        );
        icfg.format = CodeFormat::Int4 { group: 64 };
        icfg.residual_rank = 4;
        let int4 = bench("pipeline (int4 + residual)", 0, 3, || {
            run_pipeline(&post, &base, &quantizable, None, &icfg, None).unwrap()
        });

        // fresh dir per iteration, deleted outside the timed closure so
        // cleanup cost doesn't bias the streaming-vs-in-memory ratio
        let base_dir = std::env::temp_dir()
            .join(format!("daq_bench_stream_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base_dir);
        // checksums off isolates the raw streaming tax; the second run
        // with per-payload CRC-32 on (the default) prices the integrity
        // layer — check_bench_regress.py gates the ratio between them
        let mut scfg = StreamConfig::new(gran, method.clone(), workers);
        scfg.checksums = false;
        let mut iter = 0usize;
        let stream = bench("pipeline (streaming)", 0, 3, || {
            iter += 1;
            run_stream(
                &post,
                &base,
                &quantizable,
                None,
                &base_dir.join(iter.to_string()),
                &scfg,
            )
            .unwrap()
        });
        let _ = std::fs::remove_dir_all(&base_dir);
        let ccfg = StreamConfig::new(gran, method, workers);
        let mut citer = 0usize;
        let stream_crc = bench("pipeline (streaming + checksums)", 0, 3, || {
            citer += 1;
            run_stream(
                &post,
                &base,
                &quantizable,
                None,
                &base_dir.join(format!("crc{citer}")),
                &ccfg,
            )
            .unwrap()
        });
        let _ = std::fs::remove_dir_all(&base_dir);

        // telemetry on (live registry: spans, counters, roll snapshots)
        // against the same checksums-off config as `pipeline-streaming`:
        // this pair prices the instrumentation itself, and
        // check_bench_regress.py --telemetry-overhead gates it intra-run
        let tguard = telemetry::set_current(Telemetry::new("bench-stream"));
        let mut titer = 0usize;
        let stream_tel = bench("pipeline (streaming + telemetry)", 0, 3, || {
            titer += 1;
            run_stream(
                &post,
                &base,
                &quantizable,
                None,
                &base_dir.join(format!("tel{titer}")),
                &scfg,
            )
            .unwrap()
        });
        drop(tguard);
        let _ = std::fs::remove_dir_all(&base_dir);

        let evals = (n_layers * dim * dim * n_candidates) as f64;
        let shape = format!("{n_layers}x{dim}x{dim}");
        let mut t = Table::new(
            "Full pipeline: in-memory vs streaming (synthetic 8 layers)",
            &["variant", "workers", "simd", "mean ms", "Melem/s (xNC)", "vs in-memory"],
        );
        for (variant, mean_s, simd) in [
            ("pipeline-inmemory", mem.mean_s, simd_label),
            ("pipeline-scalar", scalar.mean_s, "scalar"),
            ("pipeline-streaming", stream.mean_s, simd_label),
            ("pipeline-streaming-checksum", stream_crc.mean_s, simd_label),
            ("pipeline-streaming-telemetry", stream_tel.mean_s, simd_label),
        ] {
            records.push(Record {
                shape: shape.clone(),
                granularity: gran.label(),
                variant: variant.into(),
                workers,
                simd: simd.into(),
                mean_ms: mean_s * 1e3,
                melem_per_s: evals / mean_s / 1e6,
                speedup_vs_naive: mem.mean_s / mean_s,
            });
            t.row(vec![
                variant.into(),
                workers.to_string(),
                simd.into(),
                format!("{:.2}", mean_s * 1e3),
                format!("{:.1}", evals / mean_s / 1e6),
                format!("{:.2}x", mem.mean_s / mean_s),
            ]);
        }
        records.push(Record {
            shape: shape.clone(),
            granularity: Granularity::Block(64).label(),
            variant: "pipeline-int4".into(),
            workers,
            simd: simd_label.into(),
            mean_ms: int4.mean_s * 1e3,
            melem_per_s: evals / int4.mean_s / 1e6,
            speedup_vs_naive: mem.mean_s / int4.mean_s,
        });
        t.row(vec![
            "pipeline-int4 (group 64, rank-4 residual)".into(),
            workers.to_string(),
            simd_label.into(),
            format!("{:.2}", int4.mean_s * 1e3),
            format!("{:.1}", evals / int4.mean_s / 1e6),
            format!("{:.2}x", mem.mean_s / int4.mean_s),
        ]);
        println!("{}", t.render());
    }

    // --- §Perf: group-at-a-time streaming (transform baselines) -------
    // SmoothQuant couples every GEMM fed by one layernorm, so the
    // streaming driver admits whole groups through the gate; this row
    // tracks the group-streaming tax vs the in-memory transformed
    // pipeline (expected ≈1×: the fold is cheap, quantization dominates)
    {
        let n_blocks = if fast { 2 } else { 4 };
        let dim = if fast { 128 } else { 256 };
        let mut post = Dts::new();
        let mut base = Dts::new();
        let mut calib = Dts::new();
        let mut rng = XorShift::new(131);
        for i in 0..n_blocks {
            for w in ["wq", "wk", "wv", "w1"] {
                let name = format!("l{i}.{w}");
                let wb = Tensor::new(vec![dim, dim], rng.normal_vec(dim * dim, 0.1));
                let wp = Tensor::new(
                    vec![dim, dim],
                    wb.data().iter().map(|&b| b + rng.normal() * 0.002).collect(),
                );
                base.insert_f32(&name, &wb);
                post.insert_f32(&name, &wp);
            }
            for ln in ["ln1", "ln2"] {
                let g = Tensor::full(vec![dim], 1.0);
                let b = Tensor::zeros(vec![dim]);
                base.insert_f32(&format!("l{i}.{ln}.g"), &g);
                post.insert_f32(&format!("l{i}.{ln}.g"), &g);
                base.insert_f32(&format!("l{i}.{ln}.b"), &b);
                post.insert_f32(&format!("l{i}.{ln}.b"), &b);
            }
            for first in ["wq", "w1"] {
                let acts = Tensor::new(
                    vec![dim],
                    (0..dim).map(|_| rng.f32() * 2.0 + 0.05).collect(),
                );
                calib.insert_f32(&format!("l{i}.{first}"), &acts);
            }
        }
        let quantizable = quantizable_from_source(&post);
        let method = Method::SmoothQuant { alpha: 0.5 };
        let gran = Granularity::Block(128);
        let workers = cores.min(8);

        let pcfg = PipelineConfig::new(gran, method.clone(), Engine::Native { workers });
        let mem = bench("pipeline (in-memory transform)", 0, 3, || {
            run_pipeline(&post, &base, &quantizable, Some(&calib), &pcfg, None)
                .unwrap()
        });

        let base_dir = std::env::temp_dir()
            .join(format!("daq_bench_gstream_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base_dir);
        let scfg = StreamConfig::new(gran, method, workers);
        let mut iter = 0usize;
        let stream = bench("pipeline (streaming group)", 0, 3, || {
            iter += 1;
            run_stream(
                &post,
                &base,
                &quantizable,
                Some(&calib),
                &base_dir.join(iter.to_string()),
                &scfg,
            )
            .unwrap()
        });
        let _ = std::fs::remove_dir_all(&base_dir);

        let elems = (n_blocks * 4 * dim * dim) as f64;
        let shape = format!("{}x{dim}x{dim}", n_blocks * 4);
        let mut t = Table::new(
            "Transform pipeline: in-memory vs group streaming (SmoothQuant)",
            &["variant", "workers", "mean ms", "Melem/s", "vs in-memory"],
        );
        for (variant, mean_s) in [
            ("pipeline-inmemory-transform", mem.mean_s),
            ("pipeline-streaming-group", stream.mean_s),
        ] {
            records.push(Record {
                shape: shape.clone(),
                granularity: gran.label(),
                variant: variant.into(),
                workers,
                simd: simd_label.into(),
                mean_ms: mean_s * 1e3,
                melem_per_s: elems / mean_s / 1e6,
                speedup_vs_naive: mem.mean_s / mean_s,
            });
            t.row(vec![
                variant.into(),
                workers.to_string(),
                format!("{:.2}", mean_s * 1e3),
                format!("{:.1}", elems / mean_s / 1e6),
                format!("{:.2}x", mem.mean_s / mean_s),
            ]);
        }
        println!("{}", t.render());
    }

    // --- §Perf: serving — full-reforward baseline vs incremental decode
    //     vs quantized-resident incremental decode. The reforward loop
    //     re-runs the whole-sequence forward per generated token
    //     (O(seq²)); the scheduler decodes O(t) per token against KV
    //     caches, and the quantized row additionally serves straight from
    //     codes+scales through the fused dequant-matmul.
    let mut serve_rows: Vec<String> = Vec::new();
    {
        use daq::eval::decode::Decoder;
        use daq::eval::model_native::{synth_params, synth_quantized, synth_quantized_fmt, ModelCfg};
        use daq::eval::{params_bytes, NativeForward};
        use daq::serve::{gen_requests, serve, serve_reforward, ServeConfig};

        // vocab 64 covers the serve workload's token alphabet; GEMM
        // weights must dominate the shape for the 0.35x resident bound
        let cfg = if fast {
            ModelCfg { vocab: 64, d_model: 48, n_layer: 2, n_head: 4, d_ff: 96, seq_len: 24 }
        } else {
            ModelCfg { vocab: 64, d_model: 64, n_layer: 2, n_head: 4, d_ff: 128, seq_len: 32 }
        };
        let params = synth_params(&cfg, 2024);
        let mut quantizable: Vec<String> = Vec::new();
        for l in 0..cfg.n_layer {
            for w in ["wq", "wk", "wv", "wo", "w1", "w2"] {
                quantizable.push(format!("l{l}.{w}"));
            }
        }
        quantizable.push("head".into());
        let qp = synth_quantized(&params, &quantizable, Granularity::Block(128));
        let n_req = if fast { 6 } else { 12 };
        let new_tokens = if fast { 4 } else { 8 };
        let slots = 4usize;
        let reqs = gen_requests(n_req, 42);
        let scfg = ServeConfig { slots, new_tokens, ..Default::default() };
        let total_tokens = (n_req * new_tokens) as f64;

        let fwd = NativeForward { params: &params, cfg, batch: slots };
        let reforward = bench("serve reforward", 0, 3, || {
            serve_reforward(&fwd, &reqs, new_tokens, params_bytes(&params)).unwrap()
        });
        let dec = Decoder::new(&params, cfg);
        let inmem = bench("serve inmemory", 0, 3, || {
            serve(&dec, &reqs, &scfg).unwrap()
        });
        let qdec = Decoder::new(&qp, cfg);
        let quant = bench("serve quantized", 0, 3, || {
            serve(&qdec, &reqs, &scfg).unwrap()
        });
        // forced-scalar companion: same decoder and workload with the
        // kernel layer pinned to the scalar reference. The intra-run pair
        // is gated by check_bench_regress.py --simd-speedup, and the
        // completions must stay bitwise-identical across dispatch modes
        // (the serve determinism contract).
        let prev = kernels::force(kernels::SimdMode::Scalar);
        let quant_scalar = bench("serve quantized (forced scalar)", 0, 3, || {
            serve(&qdec, &reqs, &scfg).unwrap()
        });
        let rep_scalar = serve(&qdec, &reqs, &scfg).unwrap();
        kernels::force(prev);
        // slot-parallel decode: same quantized decoder, ticks fanned out
        // across worker threads. Completions must stay bitwise-identical
        // to the serial run (the determinism contract); tokens/s scaling
        // vs the serial row is gated in CI via --mt-scaling.
        let mt_workers = cores.clamp(1, slots);
        let scfg_mt = ServeConfig { workers: mt_workers, ..scfg };
        let quant_mt = bench("serve quantized mt", 0, 3, || {
            serve(&qdec, &reqs, &scfg_mt).unwrap()
        });
        let rep_serial = serve(&qdec, &reqs, &scfg).unwrap();
        let rep_mt = serve(&qdec, &reqs, &scfg_mt).unwrap();
        assert_eq!(
            rep_serial.completions, rep_mt.completions,
            "multi-threaded serve must produce bitwise-identical completions"
        );
        assert_eq!(
            rep_serial.completions, rep_scalar.completions,
            "SIMD and forced-scalar serve must produce bitwise-identical completions"
        );
        // same quantized workload with a live registry; the Decoder
        // captures its step counter at construction, so it is rebuilt
        // inside the instrumented context exactly like a real serve run.
        // check_bench_regress.py gates this pair within 3% intra-run.
        let tguard = telemetry::set_current(Telemetry::new("bench-serve"));
        let qdec_tel = Decoder::new(&qp, cfg);
        let quant_tel = bench("serve quantized + telemetry", 0, 3, || {
            serve(&qdec_tel, &reqs, &scfg).unwrap()
        });
        drop(tguard);
        // sub-8-bit serving: INT4 codes (group 64) + rank-4 residual
        // applied after the fused dequant-matmul. The row reports
        // resident bytes rather than asserting the fp8 bound — on these
        // tiny bench shapes the rank-4 sidecar is not amortized the way
        // it is on real layer widths (see tests/streaming.rs for the
        // dim-512 0.18x assertion).
        let qp4 = synth_quantized_fmt(
            &params,
            &quantizable,
            Granularity::Block(64),
            CodeFormat::Int4 { group: 64 },
            4,
        );
        let qdec4 = Decoder::new(&qp4, cfg);
        let quant4 = bench("serve int4 + residual", 0, 3, || {
            serve(&qdec4, &reqs, &scfg).unwrap()
        });

        let shape = format!(
            "{}x{}x{}x{}",
            cfg.n_layer, cfg.d_model, cfg.d_ff, cfg.seq_len
        );
        let gran = Granularity::Block(128);
        let mut t = Table::new(
            "Serving: full-reforward vs incremental vs quantized-resident",
            &[
                "variant",
                "slots",
                "workers",
                "simd",
                "mean ms",
                "tok/s",
                "resident MiB",
                "vs reforward",
            ],
        );
        let qbytes = qp.resident_param_bytes();
        for (variant, mean_s, resident, w, simd) in [
            ("serve-reforward", reforward.mean_s, params_bytes(&params), 1, simd_label),
            ("serve-inmemory", inmem.mean_s, params_bytes(&params), 1, simd_label),
            ("serve-quantized", quant.mean_s, qbytes, 1, simd_label),
            ("serve-quantized-scalar", quant_scalar.mean_s, qbytes, 1, "scalar"),
            ("serve-quantized-mt", quant_mt.mean_s, qbytes, mt_workers, simd_label),
            ("serve-quantized-telemetry", quant_tel.mean_s, qbytes, 1, simd_label),
        ] {
            let tok_s = total_tokens / mean_s;
            serve_rows.push(format!(
                "{{\"shape\": \"{shape}\", \"granularity\": \"{}\", \
                 \"variant\": \"{variant}\", \"workers\": {w}, \
                 \"simd\": \"{simd}\", \
                 \"mean_ms\": {:.4}, \"tokens_per_s\": {tok_s:.2}, \
                 \"resident_param_bytes\": {resident}, \
                 \"speedup_vs_reforward\": {:.3}}}",
                gran.label(),
                mean_s * 1e3,
                reforward.mean_s / mean_s,
            ));
            t.row(vec![
                variant.into(),
                slots.to_string(),
                w.to_string(),
                simd.into(),
                format!("{:.2}", mean_s * 1e3),
                format!("{tok_s:.1}"),
                format!("{:.3}", resident as f64 / (1 << 20) as f64),
                format!("{:.2}x", reforward.mean_s / mean_s),
            ]);
        }
        {
            let tok_s = total_tokens / quant4.mean_s;
            let resident = qp4.resident_param_bytes();
            serve_rows.push(format!(
                "{{\"shape\": \"{shape}\", \"granularity\": \"{}\", \
                 \"variant\": \"serve-int4-residual\", \"workers\": 1, \
                 \"simd\": \"{simd_label}\", \
                 \"mean_ms\": {:.4}, \"tokens_per_s\": {tok_s:.2}, \
                 \"resident_param_bytes\": {resident}, \
                 \"speedup_vs_reforward\": {:.3}}}",
                Granularity::Block(64).label(),
                quant4.mean_s * 1e3,
                reforward.mean_s / quant4.mean_s,
            ));
            t.row(vec![
                "serve-int4-residual".into(),
                slots.to_string(),
                "1".into(),
                simd_label.into(),
                format!("{:.2}", quant4.mean_s * 1e3),
                format!("{tok_s:.1}"),
                format!("{:.3}", resident as f64 / (1 << 20) as f64),
                format!("{:.2}x", reforward.mean_s / quant4.mean_s),
            ]);
        }
        println!("{}", t.render());
        // the whole point of incremental decode: strictly faster than
        // re-running the full forward per token, even quantized
        assert!(
            quant.mean_s < reforward.mean_s,
            "serve-quantized ({:.2} ms) must beat the full-reforward \
             baseline ({:.2} ms)",
            quant.mean_s * 1e3,
            reforward.mean_s * 1e3
        );
        assert!(
            qp.resident_param_bytes() * 100 <= params_bytes(&params) * 35,
            "quantized-resident params must be <= 0.35x of f32"
        );
    }

    // --- machine-readable perf trajectory ---
    let out_path =
        std::env::var("DAQ_BENCH_OUT").unwrap_or_else(|_| "BENCH_sweep.json".into());
    let mut body: Vec<String> = records.iter().map(|r| format!("  {}", r.json())).collect();
    body.extend(serve_rows.iter().map(|r| format!("  {r}")));
    let json = format!(
        "{{\"bench\": \"sweep\", \"candidates\": {}, \"cores\": {}, \
         \"simd\": \"{}\", \"rows\": [\n{}\n]}}\n",
        n_candidates,
        cores,
        simd_label,
        body.join(",\n")
    );
    match std::fs::write(&out_path, &json) {
        Ok(()) => println!(
            "wrote {out_path} ({} records)",
            records.len() + serve_rows.len()
        ),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }

    // --- full-pipeline latency on the real checkpoints (if present) ---
    let dir = std::env::var("DAQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let lab_native = Lab::open(&dir, false);
    if let Ok(lab) = &lab_native {
        let mut t = Table::new(
            "End-to-end pipeline latency (quantize all layers)",
            &["method", "engine", "secs"],
        );
        for (label, method) in [
            ("absmax", Method::AbsMax),
            (
                "daq-sign [0.8,1.25]",
                Method::Search { objective: Objective::SignRate, range: (0.8, 1.25) },
            ),
        ] {
            let res = bench(label, 0, 3, || {
                lab.quantize_native(Granularity::Block(128), method.clone()).unwrap()
            });
            t.row(vec![label.into(), "native".into(), format!("{:.3}", res.mean_s)]);
        }
        println!("{}", t.render());
    } else {
        eprintln!("pipeline section skipped (no artifacts)");
    }

    // --- PJRT sweep vs native on one layer ---
    if std::env::var("DAQ_ENGINE").as_deref() == Ok("pjrt") {
        if let Ok(lab) = Lab::open(&dir, true) {
            let rt = lab.rt.as_ref().unwrap();
            let name = &lab.quantizable[0];
            let wp = lab.post.tensor_f32(name).unwrap();
            let wb = lab.base.tensor_f32(name).unwrap();
            let s0 = absmax_scales(&wp, Granularity::Block(128));
            let s0_full = s0.expand();
            let mut t = Table::new(
                &format!("Sweep engines on layer {name} ({:?})", wp.shape()),
                &["engine", "mean ms"],
            );
            let rn = bench("native", 1, 5, || sweep_native(&wp, &wb, &s0, &alphas));
            t.row(vec!["native".into(), format!("{:.2}", rn.mean_s * 1e3)]);
            let rp = bench("pjrt", 1, 5, || {
                rt.sweep(&wp, &wb, &s0_full, &alphas).unwrap()
            });
            t.row(vec![
                "pjrt (Pallas artifact)".into(),
                format!("{:.2}", rp.mean_s * 1e3),
            ]);
            println!("{}", t.render());
        }
    } else {
        eprintln!("PJRT section skipped (set DAQ_ENGINE=pjrt to include)");
    }
}
