//! Bench: §Perf — hot-path profiling across the stack:
//!   L3 native fused sweep throughput (the coordinator's hot loop),
//!   thread-pool scaling, PJRT sweep vs native (when artifacts exist),
//!   and end-to-end pipeline latency.

use daq::experiments::Lab;
use daq::coordinator::Method;
use daq::metrics::{sweep_native, sweep_native_regions};
use daq::quant::{absmax_scales, Granularity};
use daq::report::Table;
use daq::search::Objective;
use daq::tensor::Tensor;
use daq::util::bench::bench;
use daq::util::rng::XorShift;

fn pair(r: usize, c: usize, seed: u64) -> (Tensor, Tensor) {
    let mut rng = XorShift::new(seed);
    let wb = Tensor::new(vec![r, c], rng.normal_vec(r * c, 0.1));
    let wp = Tensor::new(
        vec![r, c],
        wb.data().iter().map(|&b| b + rng.normal() * 0.002).collect(),
    );
    (wp, wb)
}

fn main() {
    // --- §Perf iteration 1: naive elementwise sweep vs region-hoisted ---
    {
        let (wp, wb) = pair(512, 512, 3);
        let alphas: Vec<f32> = (0..16).map(|i| 0.8 + 0.028 * i as f32).collect();
        let mut t = Table::new(
            "Sweep optimization (512x512, 16 candidates)",
            &["variant", "granularity", "mean ms", "speedup"],
        );
        for gran in [Granularity::Block(128), Granularity::PerChannel] {
            let s0 = absmax_scales(&wp, gran);
            let naive = bench("naive", 1, 5, || sweep_native(&wp, &wb, &s0, &alphas));
            let fast = bench("optimized", 1, 5, || sweep_native_regions(&wp, &wb, &s0, &alphas));
            t.row(vec!["naive (per-element scale lookup)".into(), gran.label(),
                       format!("{:.2}", naive.mean_s * 1e3), "1.00x".into()]);
            t.row(vec!["optimized (region-hoisted)".into(), gran.label(),
                       format!("{:.2}", fast.mean_s * 1e3),
                       format!("{:.2}x", naive.mean_s / fast.mean_s)]);
        }
        println!("{}", t.render());
    }

    // --- L3 native sweep throughput across shapes/granularities ---
    let mut t = Table::new(
        "Native fused sweep throughput (16 candidates)",
        &["shape", "granularity", "mean ms", "Melem/s (xNC)"],
    );
    let alphas: Vec<f32> = (0..16).map(|i| 0.8 + 0.028 * i as f32).collect();
    for (r, c) in [(128usize, 128usize), (128, 512), (512, 512), (1024, 1024)] {
        let (wp, wb) = pair(r, c, (r + c) as u64);
        for gran in [Granularity::Block(128), Granularity::PerChannel] {
            let s0 = absmax_scales(&wp, gran);
            let res = bench(&format!("{r}x{c}/{}", gran.label()), 1, 5, || {
                sweep_native(&wp, &wb, &s0, &alphas)
            });
            let melem = (r * c * 16) as f64 / res.mean_s / 1e6;
            t.row(vec![format!("{r}x{c}"), gran.label(),
                       format!("{:.2}", res.mean_s * 1e3),
                       format!("{melem:.1}")]);
        }
    }
    println!("{}", t.render());

    // --- full-pipeline latency on the real checkpoints (if present) ---
    let dir = std::env::var("DAQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let lab_native = Lab::open(&dir, false);
    if let Ok(lab) = &lab_native {
        let mut t = Table::new(
            "End-to-end pipeline latency (quantize all layers)",
            &["method", "engine", "secs"],
        );
        for (label, method) in [
            ("absmax", Method::AbsMax),
            ("daq-sign [0.8,1.25]",
             Method::Search { objective: Objective::SignRate, range: (0.8, 1.25) }),
        ] {
            let res = bench(label, 0, 3, || {
                lab.quantize_native(Granularity::Block(128), method.clone()).unwrap()
            });
            t.row(vec![label.into(), "native".into(),
                       format!("{:.3}", res.mean_s)]);
        }
        println!("{}", t.render());
    } else {
        eprintln!("pipeline section skipped (no artifacts)");
    }

    // --- PJRT sweep vs native on one layer ---
    if std::env::var("DAQ_ENGINE").as_deref() == Ok("pjrt") {
        if let Ok(lab) = Lab::open(&dir, true) {
            let rt = lab.rt.as_ref().unwrap();
            let name = &lab.quantizable[0];
            let wp = lab.post.tensor_f32(name).unwrap();
            let wb = lab.base.tensor_f32(name).unwrap();
            let s0 = absmax_scales(&wp, Granularity::Block(128));
            let s0_full = s0.expand();
            let mut t = Table::new(
                &format!("Sweep engines on layer {name} ({:?})", wp.shape()),
                &["engine", "mean ms"],
            );
            let rn = bench("native", 1, 5, || sweep_native(&wp, &wb, &s0, &alphas));
            t.row(vec!["native".into(), format!("{:.2}", rn.mean_s * 1e3)]);
            let rp = bench("pjrt", 1, 5, || {
                rt.sweep(&wp, &wb, &s0_full, &alphas).unwrap()
            });
            t.row(vec!["pjrt (Pallas artifact)".into(),
                       format!("{:.2}", rp.mean_s * 1e3)]);
            println!("{}", t.render());
        }
    } else {
        eprintln!("PJRT section skipped (set DAQ_ENGINE=pjrt to include)");
    }
}
