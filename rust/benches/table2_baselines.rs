//! Bench: Table 2 — baseline comparison (Base, Post, AbsMax FP8 block &
//! channel, SmoothQuant, AWQ) with the paper's columns (ΔW L2, SignRate,
//! CosSim, Style, General).
//!
//! Requires `make artifacts`. Engine: native by default; set
//! DAQ_ENGINE=pjrt to run metric sweeps + eval through the AOT artifacts.

use daq::experiments::{table2, Lab};

fn main() {
    let dir = std::env::var("DAQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let use_pjrt = std::env::var("DAQ_ENGINE").as_deref() == Ok("pjrt");
    let lab = match Lab::open(&dir, use_pjrt) {
        Ok(l) => l,
        Err(e) => {
            eprintln!("table2 bench skipped: {e:#}\n(run `make artifacts` first)");
            return;
        }
    };
    let t0 = std::time::Instant::now();
    match table2(&lab) {
        Ok(t) => {
            println!("{}", t.render());
            println!("[total {:.1}s, engine={}]", t0.elapsed().as_secs_f64(),
                     if use_pjrt { "pjrt" } else { "native" });
        }
        Err(e) => eprintln!("table2 failed: {e:#}"),
    }
}
