//! Bench: Table 3 — coarse-to-fine scale search with the MSE metric
//! (the paper's negative result: delta-unaware search degrades Style).

use daq::experiments::{table_search, Lab};
use daq::search::Objective;

fn main() {
    let dir = std::env::var("DAQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let use_pjrt = std::env::var("DAQ_ENGINE").as_deref() == Ok("pjrt");
    let lab = match Lab::open(&dir, use_pjrt) {
        Ok(l) => l,
        Err(e) => {
            eprintln!("table3 bench skipped: {e:#}\n(run `make artifacts` first)");
            return;
        }
    };
    let t0 = std::time::Instant::now();
    match table_search(&lab, Objective::NegMse) {
        Ok(t) => {
            println!("{}", t.render());
            println!("[total {:.1}s]", t0.elapsed().as_secs_f64());
        }
        Err(e) => eprintln!("table3 failed: {e:#}"),
    }
}
