//! Ablation benches for the design choices DESIGN.md calls out:
//!
//!  A1. Search strategy: Algorithm 1 (coarse-to-fine) vs exhaustive grid
//!      vs golden-section, at matched and unmatched evaluation budgets.
//!      (Why the paper's grid search is the right default: SignRate is
//!      piecewise-constant, so golden-section's unimodality assumption
//!      fails.)
//!  A2. Numeric format: E4M3 vs E5M2 (paper §5 "lower bit-widths" /
//!      format generality) — delta fidelity at matched storage cost.
//!  A3. Granularity sweep: per-tensor vs per-channel vs block {32,64,128}.
//!
//! Runs on synthetic small-delta pairs (no artifacts needed), and on the
//! real checkpoints when present.

use daq::fp8;
use daq::metrics::{delta_stats, DeltaStats};
use daq::quant::{absmax_scales, qdq, Granularity};
use daq::report::{fmt3, fmt_pct, Table};
use daq::search::{
    search_exhaustive, search_golden, search_scale_with, NativeSweep, Objective,
    SearchConfig,
};
use daq::tensor::Tensor;
use daq::util::rng::XorShift;

fn pair(r: usize, c: usize, delta: f32, seed: u64) -> (Tensor, Tensor) {
    let mut rng = XorShift::new(seed);
    let wb = Tensor::new(vec![r, c], rng.normal_vec(r * c, 0.1));
    let wp = Tensor::new(
        vec![r, c],
        wb.data().iter().map(|&b| b + rng.normal() * delta).collect(),
    );
    (wp, wb)
}

fn main() {
    let (wp, wb) = pair(256, 256, 0.0015, 11);
    let s0 = absmax_scales(&wp, Granularity::Block(128));

    // ---- A1: search strategies ----
    let mut t = Table::new(
        "A1: search strategy (objective = SignRate, range [0.8, 1.25])",
        &["strategy", "evals", "alpha*", "SignRate"],
    );
    let cfg = SearchConfig::paper_default(Objective::SignRate, (0.8, 1.25));
    let ctf = search_scale_with(&NativeSweep, &wp, &wb, &s0, &cfg);
    t.row(vec!["coarse-to-fine (Algorithm 1)".into(), ctf.evals.to_string(),
               format!("{:.4}", ctf.alpha), fmt_pct(ctf.stats.sign_rate())]);
    for n in [16usize, 64, 256] {
        let ex = search_exhaustive(&NativeSweep, &wp, &wb, &s0,
                                   Objective::SignRate, (0.8, 1.25), n);
        t.row(vec![format!("exhaustive grid (n={n})"), ex.evals.to_string(),
                   format!("{:.4}", ex.alpha), fmt_pct(ex.stats.sign_rate())]);
    }
    let gold = search_golden(&NativeSweep, &wp, &wb, &s0,
                             Objective::SignRate, (0.8, 1.25), 14);
    t.row(vec!["golden-section (unimodal assumption)".into(),
               gold.evals.to_string(), format!("{:.4}", gold.alpha),
               fmt_pct(gold.stats.sign_rate())]);
    println!("{}", t.render());

    // ---- A2: numeric format ----
    let mut t = Table::new(
        "A2: format ablation at alpha=1 (same scale machinery)",
        &["format", "SignRate", "CosSim", "MSE"],
    );
    let stats_for = |f: &dyn Fn(f32) -> f32| -> DeltaStats {
        let (rows, cols) = (wp.rows(), wp.cols());
        let mut wq = Tensor::zeros(vec![rows, cols]);
        for r in 0..rows {
            for c in 0..cols {
                let s = s0.at(r, c);
                wq.set2(r, c, f(wp.at2(r, c) / s) * s);
            }
        }
        delta_stats(&wp, &wb, &wq)
    };
    // E5M2 shares the absmax scale convention: rescale to its own max
    let ratio = fp8::e5m2_ratio();
    let e4 = stats_for(&fp8::qdq_e4m3);
    let e5 = stats_for(&|x| fp8::qdq_e5m2(x * ratio) / ratio);
    t.row(vec!["E4M3 (paper)".into(), fmt_pct(e4.sign_rate()),
               fmt3(e4.cos_sim()), format!("{:.3e}", e4.mse())]);
    t.row(vec!["E5M2".into(), fmt_pct(e5.sign_rate()),
               fmt3(e5.cos_sim()), format!("{:.3e}", e5.mse())]);
    println!("{}", t.render());

    // ---- A3: granularity ----
    let mut t = Table::new(
        "A3: granularity (AbsMax, alpha = 1)",
        &["granularity", "scales stored", "SignRate", "CosSim"],
    );
    for gran in [
        Granularity::PerTensor,
        Granularity::PerChannel,
        Granularity::Block(128),
        Granularity::Block(64),
        Granularity::Block(32),
    ] {
        let s = absmax_scales(&wp, gran);
        let wq = qdq(&wp, &s, 1.0);
        let st = delta_stats(&wp, &wb, &wq);
        t.row(vec![gran.label(), s.scales.len().to_string(),
                   fmt_pct(st.sign_rate()), fmt3(st.cos_sim())]);
    }
    println!("{}", t.render());

    // ---- real checkpoints (optional) ----
    if let Ok(lab) = daq::experiments::Lab::open(
        &std::env::var("DAQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
        false,
    ) {
        let mut t = Table::new(
            "A1 on real checkpoints: per-layer alpha histogram (sign, [0.8,1.25])",
            &["layer", "alpha*", "SignRate"],
        );
        for name in lab.quantizable.iter().take(8) {
            let wp = lab.post.tensor_f32(name).unwrap();
            let wb = lab.base.tensor_f32(name).unwrap();
            let s0 = absmax_scales(&wp, Granularity::Block(128));
            let res = search_scale_with(
                &NativeSweep, &wp, &wb, &s0,
                &SearchConfig::paper_default(Objective::SignRate, (0.8, 1.25)),
            );
            t.row(vec![name.clone(), format!("{:.4}", res.alpha),
                       fmt_pct(res.stats.sign_rate())]);
        }
        println!("{}", t.render());
    } else {
        eprintln!("real-checkpoint section skipped (no artifacts)");
    }
}
