//! PJRT runtime: loads the AOT-compiled HLO artifacts (`make artifacts`)
//! and executes them from the Rust hot path. Python never runs here.
//!
//! Pattern (see /opt/xla-example/load_hlo): HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`. Compiled executables are cached per
//! artifact so each graph compiles once per process.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

use crate::metrics::DeltaStats;
use crate::quant::ScaleGrid;
use crate::search::SweepEngine;
use crate::tensor::Tensor;
use crate::util::json::Json;

/// Parsed `artifacts/manifest.json` — the machine-readable index of what
/// aot.py lowered, including the model configuration and parameter order
/// the forward graphs expect.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub n_candidates: usize,
    pub eval_batch: usize,
    pub serve_batch: usize,
    pub seq_len: usize,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layer: usize,
    pub n_head: usize,
    pub d_ff: usize,
    pub param_order: Vec<String>,
    pub param_shapes: HashMap<String, Vec<usize>>,
    pub quantizable: Vec<String>,
    /// (rows, cols) -> sweep artifact file
    pub sweeps: HashMap<(usize, usize), String>,
    /// batch -> forward artifact file
    pub forwards: HashMap<usize, String>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {path:?} (run `make artifacts` first)"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;
        let usize_of = |key: &str| -> Result<usize> {
            j.get(key)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("manifest missing {key}"))
        };
        let mut sweeps = HashMap::new();
        for s in j.get("sweeps").and_then(Json::as_arr).unwrap_or(&[]) {
            let shape = s.get("shape").and_then(Json::as_arr).unwrap();
            let file = s.get("file").and_then(Json::as_str).unwrap().to_string();
            sweeps.insert(
                (shape[0].as_usize().unwrap(), shape[1].as_usize().unwrap()),
                file,
            );
        }
        let mut forwards = HashMap::new();
        for f in j.get("forwards").and_then(Json::as_arr).unwrap_or(&[]) {
            forwards.insert(
                f.get("batch").and_then(Json::as_usize).unwrap(),
                f.get("file").and_then(Json::as_str).unwrap().to_string(),
            );
        }
        let param_order: Vec<String> = j
            .get("param_order")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .filter_map(|v| v.as_str().map(String::from))
            .collect();
        let mut param_shapes = HashMap::new();
        if let Some(Json::Obj(m)) = j.get("param_shapes") {
            for (k, v) in m {
                let dims: Vec<usize> = v
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(Json::as_usize)
                    .collect();
                param_shapes.insert(k.clone(), dims);
            }
        }
        let quantizable = j
            .get("quantizable")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .filter_map(|v| v.as_str().map(String::from))
            .collect();
        Ok(Manifest {
            n_candidates: usize_of("n_candidates")?,
            eval_batch: usize_of("eval_batch")?,
            serve_batch: usize_of("serve_batch")?,
            seq_len: usize_of("seq_len")?,
            vocab: usize_of("vocab")?,
            d_model: usize_of("d_model")?,
            n_layer: usize_of("n_layer")?,
            n_head: usize_of("n_head")?,
            d_ff: usize_of("d_ff")?,
            param_order,
            param_shapes,
            quantizable,
            sweeps,
            forwards,
        })
    }

    /// The model configuration the artifacts were lowered for, as a
    /// native `ModelCfg` — the fallback config source for `daq trace` and
    /// `daq serve` over pre-metadata checkpoints
    /// ([`crate::eval::trace::model_cfg_for`]).
    pub fn model_cfg(&self) -> crate::eval::model_native::ModelCfg {
        crate::eval::model_native::ModelCfg {
            vocab: self.vocab,
            d_model: self.d_model,
            n_layer: self.n_layer,
            n_head: self.n_head,
            d_ff: self.d_ff,
            seq_len: self.seq_len,
        }
    }
}

/// The PJRT runtime: CPU client + compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Open an artifacts directory (must contain manifest.json).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Runtime { client, dir, manifest, cache: RefCell::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile (cached) an HLO-text artifact by file name.
    pub fn executable(&self, file: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(file) {
            return Ok(Rc::clone(exe));
        }
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {file}: {e:?}"))?,
        );
        self.cache.borrow_mut().insert(file.to_string(), Rc::clone(&exe));
        Ok(exe)
    }

    fn literal_f32(t: &Tensor) -> Result<xla::Literal> {
        let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
        xla::Literal::vec1(t.data())
            .reshape(&dims)
            .map_err(|e| anyhow!("reshape literal: {e:?}"))
    }

    fn run_tuple1(
        exe: &xla::PjRtLoadedExecutable,
        args: &[xla::Literal],
    ) -> Result<Vec<f32>> {
        let bufs = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let out = lit.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
    }

    /// Execute the fused DAQ sweep kernel for one weight. `alphas` is
    /// padded with 1.0 to the artifact's fixed candidate count.
    pub fn sweep(
        &self,
        w_post: &Tensor,
        w_base: &Tensor,
        s0_full: &Tensor,
        alphas: &[f32],
    ) -> Result<Vec<DeltaStats>> {
        let (r, c) = (w_post.rows(), w_post.cols());
        let nc = self.manifest.n_candidates;
        if alphas.len() > nc {
            bail!("{} candidates > artifact capacity {nc}", alphas.len());
        }
        let file = self
            .manifest
            .sweeps
            .get(&(r, c))
            .ok_or_else(|| anyhow!("no sweep artifact for shape {r}x{c}"))?
            .clone();
        let exe = self.executable(&file)?;
        let mut padded = alphas.to_vec();
        padded.resize(nc, 1.0);
        let args = [
            Self::literal_f32(w_post)?,
            Self::literal_f32(w_base)?,
            Self::literal_f32(s0_full)?,
            xla::Literal::vec1(&padded),
        ];
        let flat = Self::run_tuple1(&exe, &args)?;
        if flat.len() != nc * 6 {
            bail!("sweep output len {} != {nc}*6", flat.len());
        }
        Ok(flat[..alphas.len() * 6]
            .chunks_exact(6)
            .map(DeltaStats::from_row)
            .collect())
    }

    /// Execute the transformer forward: tokens `[batch, seq]` (row-major)
    /// plus parameters in manifest order → logits `[batch, seq, vocab]`.
    pub fn forward(
        &self,
        batch: usize,
        tokens: &[i32],
        params: &HashMap<String, Tensor>,
    ) -> Result<Vec<f32>> {
        let m = &self.manifest;
        if tokens.len() != batch * m.seq_len {
            bail!("tokens len {} != {batch}x{}", tokens.len(), m.seq_len);
        }
        let file = m
            .forwards
            .get(&batch)
            .ok_or_else(|| anyhow!("no forward artifact for batch {batch}"))?
            .clone();
        let exe = self.executable(&file)?;
        let mut args = Vec::with_capacity(1 + m.param_order.len());
        args.push(
            xla::Literal::vec1(tokens)
                .reshape(&[batch as i64, m.seq_len as i64])
                .map_err(|e| anyhow!("tokens literal: {e:?}"))?,
        );
        for name in &m.param_order {
            let t = params
                .get(name)
                .ok_or_else(|| anyhow!("forward missing param {name:?}"))?;
            args.push(Self::literal_f32(t)?);
        }
        let flat = Self::run_tuple1(&exe, &args)?;
        let want = batch * m.seq_len * m.vocab;
        if flat.len() != want {
            bail!("logits len {} != {want}", flat.len());
        }
        Ok(flat)
    }

    /// Execute the standalone Pallas quantize–dequantize artifact
    /// (quickstart / integration-test path).
    pub fn qdq_128(&self, w: &Tensor, s_full: &Tensor) -> Result<Tensor> {
        let exe = self.executable("qdq_128x128.hlo.txt")?;
        let args = [Self::literal_f32(w)?, Self::literal_f32(s_full)?];
        let flat = Self::run_tuple1(&exe, &args)?;
        Ok(Tensor::new(vec![128, 128], flat))
    }
}

/// `search::SweepEngine` implementation backed by the PJRT sweep artifact,
/// making Algorithm 1 run its metric evaluations on the L1 Pallas kernel.
pub struct PjrtSweep<'a> {
    pub rt: &'a Runtime,
}

impl SweepEngine for PjrtSweep<'_> {
    fn sweep(
        &self,
        w_post: &Tensor,
        w_base: &Tensor,
        s0: &ScaleGrid,
        alphas: &[f32],
    ) -> Vec<DeltaStats> {
        let s0_full = s0.expand();
        let nc = self.rt.manifest.n_candidates;
        let mut out = Vec::with_capacity(alphas.len());
        for chunk in alphas.chunks(nc) {
            out.extend(
                self.rt
                    .sweep(w_post, w_base, &s0_full, chunk)
                    .expect("PJRT sweep failed"),
            );
        }
        out
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}
