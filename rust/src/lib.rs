//! # DAQ — Delta-Aware Quantization for Post-Training LLM Weight Compression
//!
//! A full-stack reproduction of the DAQ paper (Yuanbao & Hunyuan AI Infra
//! Team, 2026): a **data-free post-training quantization pipeline** that
//! optimizes the FP8 scale per layer for *directional fidelity of the
//! post-training delta* `ΔW = W_post − W_base` (Sign Preservation Rate /
//! Cosine Similarity) instead of reconstruction error.
//!
//! ## Architecture
//!
//! This crate is Layer 3 of a three-layer stack (see `DESIGN.md`):
//!
//! - **L1** Pallas kernels (`python/compile/kernels/`) implement the FP8
//!   quantize–dequantize and the fused delta-metric sweep; they are lowered
//!   at build time.
//! - **L2** JAX graphs (`python/compile/model.py`) provide the transformer
//!   forward used for evaluation and serving.
//! - **L3** (this crate) owns everything at run time: checkpoint streaming,
//!   the layer-parallel scale-search coordinator, the PJRT runtime that
//!   executes the AOT artifacts, evaluation, serving, and reporting.
//!   Python never runs on the request path.
//!
//! ## Quick tour
//!
//! ```no_run
//! use daq::io::dts::Dts;
//! use daq::quant::{Granularity, quantize};
//! use daq::search::{SearchConfig, Objective, search_scale};
//!
//! let post = Dts::read("artifacts/ckpt_post.dts").unwrap();
//! let base = Dts::read("artifacts/ckpt_base.dts").unwrap();
//! let wp = post.tensor_f32("l0.wq").unwrap();
//! let wb = base.tensor_f32("l0.wq").unwrap();
//! let cfg = SearchConfig::paper_default(Objective::SignRate, (0.8, 1.25));
//! let res = search_scale(&wp, &wb, Granularity::Block(128), &cfg);
//! let q = quantize(&wp, Granularity::Block(128), res.alpha);
//! ```

pub mod baselines;
pub mod cli;
pub mod coordinator;
pub mod experiments;
pub mod eval;
pub mod fp8;
pub mod io;
pub mod metrics;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod search;
pub mod serve;
pub mod tensor;
pub mod util;
