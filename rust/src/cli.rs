//! CLI subcommand implementations (`daq <cmd> ...`).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::group::{GroupManifest, GroupPlan, GroupSource, Unit};
use crate::coordinator::Method;
use crate::eval::decode::Decoder;
use crate::eval::trace::{model_cfg_for, sidecar_path, trace_graph, TraceGraph};
use crate::eval::{load_params, params_bytes, QuantizedParams};
use crate::experiments::{table1, table2, table_search, Lab};
use crate::io::dts::Dts;
use crate::quant::{CodeFormat, Descriptor, Granularity};
use crate::search::Objective;
use crate::tensor::Tensor;
use crate::util::cliargs::Args;
use crate::util::rng::XorShift;
use crate::util::telemetry::{self, MetricsServer, Telemetry};

pub const USAGE: &str = "\
daq — Delta-Aware Quantization pipeline (paper reproduction)

USAGE: daq <command> [options]

COMMANDS:
  quantize   Quantize a post-trained checkpoint against its base
             --artifacts DIR (default artifacts)
             --method absmax|sign|cos|mse|smoothquant|awq (default sign;
               --metric is an alias)
             --gran block|channel|tensor|blockN (default: block for fp8,
               blockG for int4:G — the scale-group width)
             --format fp8-e4m3|fp8-e5m2|int4[:GROUP] (code format for the
               delta methods, default fp8-e4m3; int4 packs two codes per
               byte, GROUP defaults to 64)
             --residual-rank K (delta methods only: store a rank-K
               low-rank residual of dW - Q(dW) as <name>.res_u/.res_v
               and apply it after the quantized GEMM; default 0)
             --range lo,hi (default 0.8,1.25)
             --engine native|pjrt (default native)
             --out FILE (write quantized checkpoint)
             --stream (bounded-memory pipeline; --out names a shard DIR.
               Delta methods stream layer-at-a-time; smoothquant/awq
               stream group-at-a-time — the layernorm fold couples every
               GEMM fed by one layernorm, so whole groups pass through
               the admission gate and peak memory stays at --depth
               units, not the model)
             --shard-mb N (output shard budget, default 256)
             --resume (skip units recorded in DIR/resume.jsonl)
             --workers N --depth K (streaming parallelism / in-flight)
             --post PATH --base PATH (checkpoint overrides; a .dts file,
               a shard directory, or a manifest.json)
             --calib PATH (activation-stat sidecar for smoothquant/awq;
               default ARTIFACTS/calib.dts)
             --groups FILE (explicit transform-group manifest overriding
               the name-pattern grouping; JSON
               {"groups": [{"ln": NAME|null, "members": [...]}]})
             --group-source auto|trace|patterns|manifest (where transform
               groups come from, default auto: --groups manifest if
               given, else the traced graph.dts sidecar if present, else
               the name patterns; if both a manifest and a sidecar exist
               they are cross-checked and any disagreement is an error)
             --graph PATH (traced-graph sidecar; default is the
               checkpoint's sibling <stem>.graph.dts / DIR/graph.dts)
             --metrics-out FILE (streaming only: snapshot the telemetry
               registry to FILE as JSON at every shard-roll boundary)
             --trace-out FILE (streaming only: structured JSONL trace,
               one object per span/event with monotonic timestamps)
  trace      Record the checkpoint's dataflow graph (index-only — no
             payload is read) and persist it as a DTS sidecar so
             streaming runs can derive transform groups for any tensor
             naming without re-tracing. The model config comes from the
             checkpoint metadata, falling back to ARTIFACTS/manifest.json
             for pre-metadata checkpoints
             --ckpt PATH (default ARTIFACTS/ckpt_post.dts)
             --out PATH (default sibling <stem>.graph.dts)
             --artifacts DIR (default artifacts)
  shard      Convert a monolithic .dts checkpoint into a sharded store
             --in FILE --out DIR --shard-mb N (default 256)
  eval       Score a checkpoint on the Style/General rubric
             --ckpt PATH (.dts file or sharded store) --artifacts DIR
             --quantized (evaluate with the store's FP8 codes+scales
               resident, through the fused dequant-matmul; requires
               --ckpt and --engine native)
             --engine native|pjrt
  tables     Regenerate the paper's tables (1-5)
             --artifacts DIR --only N --engine native|pjrt
  serve      Serve a synthetic request load: continuous batching with
             incremental (KV-cached) decode — requests join the batch as
             slots free up and leave when done, O(t) per generated token
             --artifacts DIR --requests N (default 32)
             --new-tokens N (default 8)
             --batch B (concurrent decode slots, default 8)
             --store PATH (serve straight from a checkpoint store: a .dts
               file, a shard directory, or a manifest.json; model config
               from checkpoint metadata, falling back to the artifact
               manifest)
             --quantized (FP8 params end-to-end: codes+scales stay
               resident and rows dequantize inside the fused
               dequant-matmul; requires --engine native)
             --quantize (quantize first, then serve dequantized f32 —
               the legacy comparison path)
             --deadline-ms MS (per-request deadline, admission ->
               completion; a request past it is evicted at the next tick
               with whatever it generated; native scheduler only)
             --queue-budget N (admission control: beyond N queued
               requests past the active slots, arrivals are shed instead
               of queueing; native scheduler only)
             --serve-workers N (threads the tick fans active slots out
               over, default = available cores; completions are
               bitwise-identical for any value; native scheduler only)
             --prefill-chunk N (max prompt tokens one prefill tick
               consumes per slot, so a long prompt cannot head-of-line
               block running decodes; 0 = whole prompt in one batched
               forward, the default; native scheduler only)
             --engine native|pjrt (default native; pjrt serves the AOT
               artifact through the full-reforward loop)
             --metrics-addr HOST:PORT (serve Prometheus-style text on
               GET /metrics from a background thread while running,
               e.g. --metrics-addr 127.0.0.1:9184)
  inspect    Print a container's metadata and tensor index (dtype, shape,
             payload bytes, totals) for a .dts file, a sharded-store
             directory, or a manifest.json. Quantized stores additionally
             decode their fmt.<name> descriptors: code format,
             bits/element, packed codes bytes, and residual sidecars
             <path>
  verify-store  Re-read every payload of a checkpoint store and verify
             it against its stored CRC-32 (a .dts file, a shard
             directory, or a manifest.json). Corrupt payloads are listed
             with tensor, shard, and byte offset; exits non-zero if any
             payload fails. v1 containers (no checksum section) read but
             count as unverifiable. fmt.<name> descriptors are parsed and
             cross-checked against the stored sidecar shapes
             <path>
  golden     Cross-check the Rust FP8 codec against the JAX golden file
             --artifacts DIR
  help       Show this message
";

pub fn dispatch(args: &Args) -> Result<()> {
    // one telemetry registry per invocation, installed as the calling
    // thread's context — every subsystem (pipeline, sweep, serve, shard
    // writer) finds it through `telemetry::current()`; library callers
    // that never install one get the passive default for free
    let run_id = format!(
        "{}-{}",
        args.subcommand.as_deref().unwrap_or("help"),
        std::process::id()
    );
    let _tg = telemetry::set_current(Telemetry::new(&run_id));
    match args.subcommand.as_deref() {
        Some("quantize") => cmd_quantize(args),
        Some("trace") => cmd_trace(args),
        Some("shard") => cmd_shard(args),
        Some("eval") => cmd_eval(args),
        Some("tables") => cmd_tables(args),
        Some("serve") => cmd_serve(args),
        Some("inspect") => cmd_inspect(args),
        Some("verify-store") => cmd_verify_store(args),
        Some("golden") => cmd_golden(args),
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn parse_method(args: &Args) -> Result<Method> {
    // `--method` is the documented spelling; `--metric` stays as the
    // historical alias for the delta objectives
    let metric = args
        .get("method")
        .map(|s| s.to_string())
        .unwrap_or_else(|| args.str_or("metric", "sign"));
    let range = args.range_or("range", (0.8, 1.25)).map_err(|e| anyhow!(e))?;
    Ok(match metric.as_str() {
        "absmax" => Method::AbsMax,
        "smoothquant" => Method::SmoothQuant { alpha: 0.5 },
        "awq" => Method::Awq,
        m => Method::Search { objective: Objective::parse(m).map_err(|e| anyhow!(e))?, range },
    })
}

/// Parse `--format` / `--residual-rank` against the chosen method. The
/// transform baselines re-quantize folded weights with the paper's FP8
/// E4M3 codec and define no ΔW to fit a residual against, so anything
/// non-default there is a hard error rather than a silent ignore.
fn parse_format(args: &Args, method: &Method) -> Result<(CodeFormat, usize)> {
    let format = match args.get("format") {
        Some(s) => CodeFormat::parse(s).map_err(|e| anyhow!(e))?,
        None => CodeFormat::Fp8E4m3,
    };
    let residual_rank = args.usize_or("residual-rank", 0).map_err(|e| anyhow!(e))?;
    if !method.delta_defined()
        && (format != CodeFormat::Fp8E4m3 || residual_rank > 0)
    {
        bail!(
            "--format / --residual-rank only apply to the delta methods \
             (absmax / search): {} always stores fp8-e4m3 without a residual",
            method.label()
        );
    }
    Ok((format, residual_rank))
}

/// Resolve `--gran`: an explicit spelling wins; otherwise the format's
/// default (the paper's block-128 for FP8, `Block(G)` for `int4:G`).
fn parse_gran(args: &Args, format: CodeFormat) -> Result<Granularity> {
    match args.get("gran") {
        Some(s) => Granularity::parse(s).map_err(|e| anyhow!(e)),
        None => Ok(format.default_granularity()),
    }
}

fn open_lab(args: &Args) -> Result<Lab> {
    let dir = args.str_or("artifacts", "artifacts");
    let use_pjrt = args.str_or("engine", "native") == "pjrt";
    Lab::open(&dir, use_pjrt)
}

fn layer_table(layers: &[crate::coordinator::LayerOutcome]) -> crate::report::Table {
    let mut t = crate::report::Table::new(
        "per-layer results",
        &["layer", "shape", "alpha", "evals", "SignRate", "CosSim", "ms"],
    );
    for l in layers {
        t.row(vec![
            l.name.clone(),
            format!("{}x{}", l.shape.0, l.shape.1),
            format!("{:.4}", l.alpha),
            l.evals.to_string(),
            l.stats.map(|s| crate::report::fmt_pct(s.sign_rate()))
                .unwrap_or_else(crate::report::na),
            l.stats.map(|s| crate::report::fmt3(s.cos_sim()))
                .unwrap_or_else(crate::report::na),
            format!("{:.1}", l.secs * 1e3),
        ]);
    }
    t
}

fn cmd_quantize(args: &Args) -> Result<()> {
    if args.flag("stream") {
        return cmd_quantize_stream(args);
    }
    // refuse rather than silently ignore: the in-memory path always uses
    // ARTIFACTS/calib.dts and the name-pattern grouping
    for flag in ["groups", "calib", "group-source", "graph", "metrics-out", "trace-out"] {
        if args.get(flag).is_some() {
            bail!("--{flag} requires --stream");
        }
    }
    // flag validation before any artifact I/O so mistakes fail fast
    let method = parse_method(args)?;
    let (format, residual_rank) = parse_format(args, &method)?;
    let gran = parse_gran(args, format)?;
    if args.str_or("engine", "native") == "pjrt"
        && (format != CodeFormat::Fp8E4m3 || residual_rank > 0)
    {
        bail!(
            "--format / --residual-rank require --engine native (the PJRT \
             sweep kernels are compiled for the FP8 E4M3 grid)"
        );
    }
    let lab = open_lab(args)?;
    println!(
        "quantizing {} layers  method={}  gran={}  format={}  engine={}",
        lab.quantizable.len(),
        method.label(),
        gran.label(),
        format.label(),
        if lab.rt.is_some() { "pjrt" } else { "native" }
    );
    let out = lab.quantize_fmt(gran, method.clone(), format, residual_rank)?;

    println!("{}", layer_table(&out.layers).render());
    if let Some(a) = &out.agg {
        println!(
            "aggregate: dW_L2={:.2} SignRate={:.2}% CosSim={:.4} MSE={:.3e} ({:.2}s total)",
            a.delta_l2(),
            100.0 * a.sign_rate(),
            a.cos_sim(),
            a.mse(),
            out.total_secs
        );
    }
    let (s, g) = lab.rubric(&out.params)?;
    println!("rubric: Style={s:.3} General={g:.3}");

    if let Some(path) = args.get("out") {
        out.write_checkpoint(path, &lab.post.meta)?;
        println!("wrote {path}");
    }
    let phases = telemetry::current().snapshot().render();
    if !phases.is_empty() {
        println!("{phases}");
    }
    Ok(())
}

/// `daq quantize --stream`: the bounded-memory pipeline over seek-based
/// sources. Never loads a whole checkpoint; the rubric evaluation is
/// intentionally skipped (it would require full-model residency — run
/// `daq eval --ckpt <out dir>` afterwards).
fn cmd_quantize_stream(args: &Args) -> Result<()> {
    if args.str_or("engine", "native") == "pjrt" {
        bail!("--stream requires --engine native (the PJRT client is serial)");
    }
    let out_dir = args
        .get("out")
        .ok_or_else(|| anyhow!("--stream needs --out DIR for the sharded store"))?;
    let dir = args.str_or("artifacts", "artifacts");

    let method = parse_method(args)?;
    let (format, residual_rank) = parse_format(args, &method)?;
    let gran = parse_gran(args, format)?;
    let workers = args
        .usize_or(
            "workers",
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        )
        .map_err(|e| anyhow!(e))?;
    let mut cfg = crate::coordinator::stream::StreamConfig::new(gran, method, workers);
    cfg.format = format;
    cfg.residual_rank = residual_rank;
    cfg.depth = args.usize_or("depth", cfg.depth).map_err(|e| anyhow!(e))?;
    cfg.shard_budget = (args
        .usize_or("shard-mb", crate::io::shard::DEFAULT_SHARD_MB as usize)
        .map_err(|e| anyhow!(e))? as u64)
        << 20;
    cfg.resume = args.flag("resume");
    cfg.metrics_out = args.get("metrics-out").map(PathBuf::from);
    if let Some(p) = args.get("trace-out") {
        telemetry::current().set_trace_out(Path::new(p))?;
    }
    // refuse rather than silently ignore flags the method cannot use
    // (validated before any checkpoint I/O so mistakes fail fast)
    if cfg.method.delta_defined() {
        for flag in ["calib", "groups", "group-source", "graph"] {
            if args.get(flag).is_some() {
                bail!(
                    "--{flag} only applies to the transform baselines \
                     (smoothquant / awq); {} ignores it",
                    cfg.method.label()
                );
            }
        }
    }

    let post_path = args.str_or("post", &format!("{dir}/ckpt_post.dts"));
    let base_path = args.str_or("base", &format!("{dir}/ckpt_base.dts"));
    if !cfg.method.delta_defined() {
        // resolved before any checkpoint I/O so flag mistakes fail fast
        cfg.groups = resolve_group_source(args, &post_path)?;
    }

    // the transform baselines fold per-group state and need the
    // activation-stat sidecar
    let calib = if !cfg.method.delta_defined() {
        let calib_path = args.str_or("calib", &format!("{dir}/calib.dts"));
        Some(crate::io::open_source(&calib_path)?)
    } else {
        None
    };

    let post = crate::io::open_source(&post_path)?;
    // the transform baselines never read the base checkpoint (they
    // quantize the transformed post weights); don't require one
    let base: Box<dyn crate::io::TensorSource> = if cfg.method.delta_defined() {
        crate::io::open_source(&base_path)?
    } else {
        Box::new(Dts::new())
    };
    let mut quantizable = crate::experiments::quantizable_from_source(post.as_ref());
    if quantizable.is_empty() {
        // a renamed checkpoint defeats the name patterns entirely — the
        // traced graph still knows which tensors are GEMM weights
        if let GroupSource::Trace(g) | GroupSource::ManifestAndTrace(_, g) =
            &cfg.groups
        {
            quantizable = g.quantizable();
        }
    }
    if quantizable.is_empty() {
        bail!("{post_path}: no quantizable 2-D weights found");
    }

    println!(
        "streaming {} layers  method={}  gran={}  format={}  workers={}  \
         depth={}  shard-budget={}MiB{}",
        quantizable.len(),
        cfg.method.label(),
        cfg.granularity.label(),
        cfg.format.label(),
        cfg.workers,
        cfg.depth,
        cfg.shard_budget >> 20,
        if cfg.resume { "  (resume)" } else { "" }
    );
    if !cfg.method.delta_defined() {
        println!("transform groups from: {}", cfg.groups.label());
    }
    let out = crate::coordinator::stream::run_stream(
        post.as_ref(),
        base.as_ref(),
        &quantizable,
        calib.as_deref(),
        std::path::Path::new(out_dir),
        &cfg,
    )?;

    println!("{}", layer_table(&out.layers).render());
    if let Some(a) = &out.agg {
        println!(
            "aggregate: dW_L2={:.2} SignRate={:.2}% CosSim={:.4} MSE={:.3e} ({:.2}s total)",
            a.delta_l2(),
            100.0 * a.sign_rate(),
            a.cos_sim(),
            a.mse(),
            out.total_secs
        );
    } else {
        println!(
            "aggregate: delta metrics undefined for {} ({:.2}s total)",
            cfg.method.label(),
            out.total_secs
        );
    }
    if out.resumed > 0 {
        println!("resumed: {} layers skipped via the journal", out.resumed);
    }
    println!(
        "peak residency: {:.2} MiB live tensors (largest unit {:.2} MiB x depth {})",
        out.peak_live_bytes as f64 / (1 << 20) as f64,
        out.max_unit_bytes as f64 / (1 << 20) as f64,
        cfg.depth
    );
    println!("wrote {}", out.manifest.display());
    // phase attribution (gate-wait vs read vs compute vs write) + fault
    // counters, printed at the end of every run without any flags
    let phases = out.telemetry.render();
    if !phases.is_empty() {
        println!("{phases}");
    }
    Ok(())
}

fn load_graph(path: &Path) -> Result<TraceGraph> {
    TraceGraph::read_sidecar(path).with_context(|| {
        format!("no usable traced graph at {path:?} — run `daq trace` first")
    })
}

/// Resolve where transform groups come from (`--group-source`, default
/// `auto`). Precedence in auto mode: an explicit `--groups` manifest
/// and/or a traced `graph.dts` sidecar next to the checkpoint — when
/// both exist they are cross-checked against each other and any
/// disagreement is an error; with neither, the name patterns apply.
fn resolve_group_source(args: &Args, post_path: &str) -> Result<GroupSource> {
    let manifest = match args.get("groups") {
        Some(path) => Some(GroupManifest::load(path)?),
        None => None,
    };
    let graph_path = args
        .get("graph")
        .map(PathBuf::from)
        .unwrap_or_else(|| sidecar_path(post_path));
    Ok(match args.str_or("group-source", "auto").as_str() {
        "patterns" => {
            if manifest.is_some() {
                bail!("--groups conflicts with --group-source patterns");
            }
            GroupSource::Patterns
        }
        "manifest" => GroupSource::Manifest(
            manifest
                .ok_or_else(|| anyhow!("--group-source manifest requires --groups FILE"))?,
        ),
        "trace" => {
            if manifest.is_some() {
                bail!(
                    "--groups conflicts with --group-source trace \
                     (use --group-source auto to cross-check both)"
                );
            }
            GroupSource::Trace(load_graph(&graph_path)?)
        }
        "auto" => {
            // only read the sidecar when the user named one or the
            // default location exists
            let graph = if args.get("graph").is_some() || graph_path.exists() {
                Some(load_graph(&graph_path)?)
            } else {
                None
            };
            match (manifest, graph) {
                (Some(m), Some(g)) => GroupSource::ManifestAndTrace(m, g),
                (Some(m), None) => GroupSource::Manifest(m),
                (None, Some(g)) => GroupSource::Trace(g),
                (None, None) => GroupSource::Patterns,
            }
        }
        other => bail!("unknown --group-source {other:?} (auto|trace|patterns|manifest)"),
    })
}

/// `daq trace`: record the checkpoint's dataflow graph (index-only) and
/// persist it as a DTS sidecar for `--group-source trace` streaming runs.
fn cmd_trace(args: &Args) -> Result<()> {
    let dir = args.str_or("artifacts", "artifacts");
    let ckpt = args.str_or("ckpt", &format!("{dir}/ckpt_post.dts"));
    let source = crate::io::open_source(&ckpt)?;
    // config from checkpoint metadata, else the artifact manifest —
    // pre-metadata checkpoints trace through the lowered config
    let cfg = model_cfg_for(source.as_ref(), &dir)?;
    let graph = trace_graph(source.as_ref(), &cfg)?;
    let quantizable = graph.quantizable();
    let plan = GroupPlan::from_graph(source.as_ref(), &quantizable, &graph)?;
    let n_groups =
        plan.units.iter().filter(|u| matches!(u, Unit::Group { .. })).count();
    let out = args
        .get("out")
        .map(PathBuf::from)
        .unwrap_or_else(|| sidecar_path(&ckpt));
    graph.write_sidecar(&out)?;
    println!(
        "traced {ckpt}: {} ops over {} checkpoint tensors (fingerprint {:016x})",
        graph.ops.len(),
        graph.leaves.len(),
        graph.fingerprint
    );
    println!(
        "transform grouping: {n_groups} ln-coupled groups + {} singletons \
         over {} quantizable GEMMs",
        plan.units.len() - n_groups,
        quantizable.len()
    );
    println!("wrote {}", out.display());
    Ok(())
}

/// `daq shard`: stream a monolithic checkpoint into a sharded store.
fn cmd_shard(args: &Args) -> Result<()> {
    let src = args
        .get("in")
        .ok_or_else(|| anyhow!("usage: daq shard --in FILE --out DIR [--shard-mb N]"))?;
    let out = args
        .get("out")
        .ok_or_else(|| anyhow!("usage: daq shard --in FILE --out DIR [--shard-mb N]"))?;
    let budget = (args
        .usize_or("shard-mb", crate::io::shard::DEFAULT_SHARD_MB as usize)
        .map_err(|e| anyhow!(e))? as u64)
        << 20;
    let (manifest, n) = crate::io::shard::shard_dts_file(src, out, budget)?;
    println!("wrote {n} shards under {out} ({})", manifest.display());
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let lab = open_lab(args)?;
    // --quantized: keep the store's codes+scales resident and evaluate
    // through the fused dequant-matmul backend — same rubric, ~0.3x the
    // parameter footprint (bitwise-equal logits, pinned in tests)
    if args.flag("quantized") {
        if lab.rt.is_some() {
            bail!("--quantized requires --engine native");
        }
        let path = args
            .get("ckpt")
            .ok_or_else(|| anyhow!("--quantized requires --ckpt STORE"))?;
        let src = crate::io::open_source(path)?;
        let qp = QuantizedParams::load(src.as_ref())?;
        if qp.n_quantized() == 0 {
            bail!(
                "{path}: no codes+scales sidecars found — nothing to \
                 evaluate quantized-resident"
            );
        }
        let fwd = crate::eval::QuantForward { params: &qp, cfg: lab.cfg, batch: 64 };
        let s = crate::eval::eval_rubric(&fwd, &lab.style)?;
        let g = crate::eval::eval_rubric(&fwd, &lab.general)?;
        println!(
            "Style={s:.3} General={g:.3} (quantized-resident: {:.2} MiB vs \
             {:.2} MiB f32)",
            qp.resident_param_bytes() as f64 / (1 << 20) as f64,
            qp.f32_param_bytes() as f64 / (1 << 20) as f64,
        );
        return Ok(());
    }
    let params = match args.get("ckpt") {
        // quantized checkpoints dequantize from the compact sidecars
        // through the shared decode table; plain checkpoints load as-is.
        // The path may be a monolithic .dts file or a sharded store
        // (directory / manifest.json) from `daq quantize --stream`.
        Some(path) => {
            crate::eval::load_params_dequant_source(crate::io::open_source(path)?.as_ref())?
        }
        None => load_params(&lab.post)?,
    };
    let (s, g) = lab.rubric(&params)?;
    println!("Style={s:.3} General={g:.3}");
    Ok(())
}

fn cmd_tables(args: &Args) -> Result<()> {
    let lab = open_lab(args)?;
    let only = args.get("only").map(|s| s.parse::<usize>().unwrap_or(0));
    let want = |n: usize| only.is_none() || only == Some(n);

    if want(1) {
        let wp = lab.post.tensor_f32(&lab.quantizable[0])?;
        let wb = lab.base.tensor_f32(&lab.quantizable[0])?;
        println!("{}", table1(&wp, &wb)?.render());
    }
    if want(2) {
        println!("{}", table2(&lab)?.render());
    }
    if want(3) {
        println!("{}", table_search(&lab, Objective::NegMse)?.render());
    }
    if want(4) {
        println!("{}", table_search(&lab, Objective::SignRate)?.render());
    }
    if want(5) {
        println!("{}", table_search(&lab, Objective::CosSim)?.render());
    }
    Ok(())
}

fn print_serve_report(rep: &crate::serve::ServeReport, engine: &str, f32_bytes: usize) {
    println!(
        "served {} requests over {} slots x {} workers ({engine}) \
         | {:.1} tok/s | style adherence {:.1}%",
        rep.requests,
        rep.slots,
        rep.workers,
        rep.tokens_per_sec,
        100.0 * rep.style_adherence
    );
    println!("request latency: {}", rep.request_latency.summary());
    println!("step latency:    {}", rep.step_latency.summary());
    if rep.shed + rep.timed_out + rep.errored > 0 {
        println!(
            "degraded: {} shed at admission, {} past deadline, {} errored",
            rep.shed, rep.timed_out, rep.errored
        );
    }
    if f32_bytes > 0 {
        println!(
            "resident params: {:.2} MiB ({:.2}x of the {:.2} MiB f32 path)",
            rep.resident_param_bytes as f64 / (1 << 20) as f64,
            rep.resident_param_bytes as f64 / f32_bytes as f64,
            f32_bytes as f64 / (1 << 20) as f64,
        );
    }
    // phase attribution (prefill vs decode vs queue wait) + shed/evict
    // counters, printed at the end of every run without any flags
    let phases = rep.telemetry.render();
    if !phases.is_empty() {
        println!("{phases}");
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    let n = args.usize_or("requests", 32).map_err(|e| anyhow!(e))?;
    let new_tokens = args.usize_or("new-tokens", 8).map_err(|e| anyhow!(e))?;
    let quantized = args.flag("quantized");
    let store = args.get("store");
    let dir = args.str_or("artifacts", "artifacts");
    let reqs = crate::serve::gen_requests(n, 42);

    // live observability: a background thread serves the registry as
    // Prometheus text on GET /metrics for the whole run (both engines);
    // the binding stays alive until this command returns
    let _metrics_server = args
        .get("metrics-addr")
        .map(|addr| MetricsServer::bind(addr, telemetry::current()))
        .transpose()?;

    // PJRT serves the AOT full-sequence graph via the reforward loop;
    // the incremental scheduler is native-only.
    if args.str_or("engine", "native") == "pjrt" {
        if quantized {
            bail!(
                "--quantized requires --engine native (the AOT graph takes \
                 dense f32 params)"
            );
        }
        let lab = open_lab(args)?;
        let rt = lab.rt.as_ref().ok_or_else(|| anyhow!("PJRT runtime unavailable"))?;
        let params = match store {
            Some(path) => crate::eval::load_params_dequant_source(
                crate::io::open_source(path)?.as_ref(),
            )?,
            None => load_params(&lab.post)?,
        };
        let fwd = crate::eval::PjrtForward { rt, params: &params, batch: rt.manifest.serve_batch };
        let rep =
            crate::serve::serve_reforward(&fwd, &reqs, new_tokens, params_bytes(&params))?;
        print_serve_report(&rep, "pjrt-reforward", params_bytes(&params));
        return Ok(());
    }

    let slots = args.usize_or("batch", 8).map_err(|e| anyhow!(e))?;
    let deadline_ms = args
        .get("deadline-ms")
        .map(|s| s.parse::<f64>().map_err(|e| anyhow!("--deadline-ms {s:?}: {e}")))
        .transpose()?;
    let queue_budget = args
        .get("queue-budget")
        .map(|s| s.parse::<usize>().map_err(|e| anyhow!("--queue-budget {s:?}: {e}")))
        .transpose()?;
    // decode ticks scale with cores by default; the slot-order merge
    // keeps completions bitwise-identical regardless
    let default_workers = std::thread::available_parallelism().map_or(1, |n| n.get());
    let workers = args
        .usize_or("serve-workers", default_workers)
        .map_err(|e| anyhow!(e))?;
    let prefill_chunk = args.usize_or("prefill-chunk", 0).map_err(|e| anyhow!(e))?;
    let scfg = crate::serve::ServeConfig {
        slots,
        new_tokens,
        deadline_ms,
        queue_budget,
        workers,
        prefill_chunk,
    };

    // --quantize (run the quantization pipeline first) only makes sense
    // without a store; refuse rather than silently serve the store dense
    // when the user likely meant --quantized (one letter apart)
    if store.is_some() && args.flag("quantize") {
        bail!(
            "--quantize runs the quantization pipeline on the artifacts \
             checkpoint and cannot combine with --store; to serve a store \
             FP8-resident use --quantized"
        );
    }

    // resolve the parameter storage: quantized-resident or dense f32,
    // from a store or from the artifacts directory
    let (rep, engine, f32_bytes) = match (store, quantized) {
        (Some(path), true) => {
            let src = crate::io::open_source(path)?;
            let cfg = model_cfg_for(src.as_ref(), &dir)?;
            let at_rest: u64 = src
                .names()
                .iter()
                .filter_map(|nm| src.nbytes_of(nm))
                .sum();
            let qp = QuantizedParams::load(src.as_ref())?;
            // a store with no sidecars would "serve quantized" at 1.0x —
            // the exact silent-dense trap --quantize+--store errors on
            if qp.n_quantized() == 0 {
                bail!(
                    "{path}: no codes+scales sidecars found — nothing to \
                     serve quantized-resident (quantize it first: \
                     daq quantize --stream --out DIR)"
                );
            }
            println!(
                "store {path}: {:.2} MiB at rest, {} quantized tensors",
                at_rest as f64 / (1 << 20) as f64,
                qp.n_quantized()
            );
            let f32_bytes = qp.f32_param_bytes();
            let dec = Decoder::new(&qp, cfg);
            (crate::serve::serve(&dec, &reqs, &scfg)?, "native-quantized", f32_bytes)
        }
        (Some(path), false) => {
            let src = crate::io::open_source(path)?;
            let cfg = model_cfg_for(src.as_ref(), &dir)?;
            let params = crate::eval::load_params_dequant_source(src.as_ref())?;
            let f32_bytes = params_bytes(&params);
            let dec = Decoder::new(&params, cfg);
            (crate::serve::serve(&dec, &reqs, &scfg)?, "native-inmemory", f32_bytes)
        }
        (None, true) => {
            // quantize the post checkpoint and keep the storage form
            let lab = open_lab(args)?;
            let out = lab.quantize(
                Granularity::Block(128),
                Method::Search { objective: Objective::SignRate, range: (0.8, 1.25) },
            )?;
            let qp = QuantizedParams::from_pipeline(&out.params, &out.quantized);
            let f32_bytes = qp.f32_param_bytes();
            let dec = Decoder::new(&qp, lab.cfg);
            (crate::serve::serve(&dec, &reqs, &scfg)?, "native-quantized", f32_bytes)
        }
        (None, false) => {
            let lab = open_lab(args)?;
            let params = if args.flag("quantize") {
                lab.quantize(Granularity::Block(128), Method::Search {
                    objective: Objective::SignRate,
                    range: (0.8, 1.25),
                })?
                .params
            } else {
                load_params(&lab.post)?
            };
            let f32_bytes = params_bytes(&params);
            let dec = Decoder::new(&params, lab.cfg);
            (crate::serve::serve(&dec, &reqs, &scfg)?, "native-inmemory", f32_bytes)
        }
    };
    print_serve_report(&rep, engine, f32_bytes);
    Ok(())
}

/// Decode the `fmt.<name>` descriptors of a quantized store: code format,
/// bits/element, packed codes bytes (matching the index's `nbytes_of`,
/// which for sub-byte formats is *less* than elements × 1 byte), and the
/// residual sidecar pair when present. An unparsable descriptor is a hard
/// error — a store that cannot be described cannot be loaded either.
fn print_format_summary(
    meta: &std::collections::BTreeMap<String, String>,
    nbytes_of: &dyn Fn(&str) -> Option<u64>,
) -> Result<()> {
    for (k, v) in meta {
        let Some(name) = k.strip_prefix("fmt.") else { continue };
        let d = Descriptor::parse(v).map_err(|e| anyhow!("{k} = {v:?}: {e}"))?;
        let codes = nbytes_of(&format!("{name}.codes")).unwrap_or(0);
        let residual = if d.residual_rank > 0 {
            let res = nbytes_of(&format!("{name}.res_u")).unwrap_or(0)
                + nbytes_of(&format!("{name}.res_v")).unwrap_or(0);
            format!("  + rank-{} residual ({res} B)", d.residual_rank)
        } else {
            String::new()
        };
        println!(
            "  format {name:<24} {:<10} {} b/elem  gran {:<9} {codes} B packed{residual}",
            d.format.label(),
            d.format.bits_per_element(),
            d.granularity.label(),
        );
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let path = args
        .positional
        .first()
        .or_else(|| args.options.get("ckpt"))
        .ok_or_else(|| anyhow!("usage: daq inspect <file.dts | shard dir | manifest.json>"))?;
    if std::path::Path::new(path).is_dir() || path.ends_with(".json") {
        // sharded store: manifest + per-shard indexes, payloads untouched
        let s = crate::io::shard::ShardedDts::open(path)?;
        println!("{path}: sharded store");
        for (k, v) in &s.meta {
            println!("  meta {k} = {v}");
        }
        for name in s.names() {
            let (shard, e) = s.entry(name).expect("listed name");
            println!(
                "  tensor {name:<24} {:<4} shape {:?} {} B  [{shard}]",
                e.dtype_label(),
                e.shape,
                e.nbytes
            );
        }
        print_format_summary(&s.meta, &|n| s.entry(n).map(|(_, e)| e.nbytes))?;
        println!(
            "  total: {} tensors, {} payload bytes, {} shards",
            s.names().len(),
            s.payload_bytes(),
            s.n_shards()
        );
    } else {
        // index-only read: multi-GB checkpoints inspect in O(index)
        let idx = crate::io::dts::DtsIndex::open(path)?;
        println!("{path}:");
        for (k, v) in &idx.meta {
            println!("  meta {k} = {v}");
        }
        for e in &idx.entries {
            println!(
                "  tensor {:<24} {:<4} shape {:?} {} B",
                e.name,
                e.dtype_label(),
                e.shape,
                e.nbytes
            );
        }
        print_format_summary(&idx.meta, &|n| idx.entry(n).map(|e| e.nbytes))?;
        println!(
            "  total: {} tensors, {} payload bytes",
            idx.entries.len(),
            idx.payload_bytes()
        );
        // a traced-graph sidecar additionally decodes into an op summary
        if idx.meta.get("daq.graph").map(|v| v.as_str()) == Some("1") {
            let g = TraceGraph::read_sidecar(path)?;
            println!(
                "  traced dataflow graph: {} ops, {} leaf tensors, \
                 fingerprint {:016x}",
                g.ops.len(),
                g.leaves.len(),
                g.fingerprint
            );
            for (kind, n) in g.op_histogram() {
                println!("    op {kind:<10} x{n}");
            }
            println!("    quantizable GEMM weights: {:?}", g.quantizable());
        }
    }
    Ok(())
}

/// `daq verify-store`: re-read every payload of a store through the
/// checksum-verifying read path and report the damage. Reads are
/// independent, so one corrupt shard never masks corruption elsewhere.
fn cmd_verify_store(args: &Args) -> Result<()> {
    let path = args
        .positional
        .first()
        .or_else(|| args.options.get("ckpt"))
        .ok_or_else(|| {
            anyhow!("usage: daq verify-store <file.dts | shard dir | manifest.json>")
        })?;
    let src = crate::io::open_source(path)?;
    let mut ok = 0usize;
    let mut unverified = 0usize;
    let mut corrupt: Vec<String> = Vec::new();
    for name in src.names() {
        match src.read_tensor(&name) {
            Ok(_) if src.crc32_of(&name).is_some() => ok += 1,
            Ok(_) => unverified += 1,
            Err(e) => {
                println!("CORRUPT {name}: {e:#}");
                corrupt.push(name.clone());
            }
        }
    }
    if unverified > 0 {
        println!(
            "note: {unverified} payloads sit in v1 containers (no checksum \
             section) — they read back but cannot be verified"
        );
    }
    if !corrupt.is_empty() {
        bail!(
            "{path}: {} of {} payloads corrupt ({ok} verified ok)",
            corrupt.len(),
            ok + unverified + corrupt.len()
        );
    }
    // structural pass: every fmt.<name> descriptor must parse and agree
    // with the sidecars it describes — packed codes shape, scales
    // presence, and the residual pair when a rank is declared
    let mut described = 0usize;
    for (k, v) in src.meta() {
        let Some(name) = k.strip_prefix("fmt.") else { continue };
        let d = Descriptor::parse(v)
            .map_err(|e| anyhow!("{path}: {k} = {v:?}: {e}"))?;
        let codes_name = format!("{name}.codes");
        let Some(shape) = src.shape_of(&codes_name) else {
            bail!("{path}: {k} describes a quantized tensor but {codes_name} is missing");
        };
        match d.cols {
            Some(c) => {
                let want = d.format.packed_row_bytes(c);
                if shape.len() != 2 || shape[1] != want {
                    bail!(
                        "{path}: {codes_name} shape {shape:?} does not match \
                         its descriptor ({} expects {want} packed bytes per \
                         row for cols={c})",
                        d.format.label()
                    );
                }
            }
            None if d.format.is_sub_byte() => bail!(
                "{path}: {k} = {v:?} is sub-byte but lacks the cols= field \
                 needed to recover the logical width"
            ),
            None => {}
        }
        if !src.contains(&format!("{name}.scales")) {
            bail!("{path}: {k} describes a quantized tensor but {name}.scales is missing");
        }
        if d.residual_rank > 0 {
            for side in ["res_u", "res_v"] {
                if !src.contains(&format!("{name}.{side}")) {
                    bail!(
                        "{path}: {k} declares res={} but {name}.{side} is missing",
                        d.residual_rank
                    );
                }
            }
        }
        described += 1;
    }
    if described > 0 {
        println!("{path}: {described} format descriptors consistent");
    }
    println!("{path}: {ok} payloads verified ok ({unverified} unverifiable v1)");
    Ok(())
}

fn cmd_golden(args: &Args) -> Result<()> {
    let dir = args.str_or("artifacts", "artifacts");
    let d = Dts::read(format!("{dir}/fp8_golden.dts"))?;
    let (_, inputs) = {
        let t = d.tensor_f32("inputs")?;
        (t.shape().to_vec(), t.into_data())
    };
    let qdq = d.tensor_f32("qdq")?.into_data();
    let (_, codes) = d.tensor_u8("codes")?;
    let mut bad = 0usize;
    for i in 0..inputs.len() {
        if crate::fp8::qdq_e4m3(inputs[i]).to_bits() != qdq[i].to_bits() {
            bad += 1;
        }
        if crate::fp8::encode_e4m3(inputs[i]) != codes[i] {
            bad += 1;
        }
    }
    let decoded = d.tensor_f32("all_codes_decoded")?.into_data();
    let (_, nan_mask) = d.tensor_u8("all_codes_nan")?;
    for c in 0..256usize {
        let v = crate::fp8::decode_e4m3(c as u8);
        if nan_mask[c] == 1 {
            if !v.is_nan() {
                bad += 1;
            }
        } else if v.to_bits() != decoded[c].to_bits() {
            bad += 1;
        }
    }
    if bad > 0 {
        bail!("FP8 golden cross-check FAILED: {bad} mismatches");
    }
    println!(
        "FP8 golden cross-check OK ({} vectors + 256 codes, bit-exact)",
        inputs.len()
    );
    Ok(())
}

/// Quick self-contained demo tensor for docs/smoke flows.
pub fn demo_tensor(rows: usize, cols: usize, seed: u64) -> Tensor {
    let mut rng = XorShift::new(seed);
    Tensor::new(vec![rows, cols], rng.normal_vec(rows * cols, 0.1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn usage_mentions_all_commands() {
        for cmd in [
            "quantize",
            "trace",
            "shard",
            "eval",
            "tables",
            "serve",
            "inspect",
            "verify-store",
            "golden",
        ] {
            assert!(USAGE.contains(cmd), "{cmd} missing from usage");
        }
        // the streaming mode's flags are documented
        for flag in [
            "--stream",
            "--shard-mb",
            "--resume",
            "--groups",
            "--calib",
            "--method",
            "--group-source",
            "--graph",
            "--metrics-out",
            "--trace-out",
            "--format",
            "--residual-rank",
        ] {
            assert!(USAGE.contains(flag), "{flag} missing from usage");
        }
        // the serving mode's flags are documented
        for flag in [
            "--store",
            "--quantized",
            "--new-tokens",
            "--batch",
            "--deadline-ms",
            "--queue-budget",
            "--serve-workers",
            "--prefill-chunk",
            "--metrics-addr",
        ] {
            assert!(USAGE.contains(flag), "{flag} missing from usage");
        }
    }

    #[test]
    fn verify_store_requires_path() {
        let args = Args::parse(["verify-store".to_string()]).unwrap();
        let err = dispatch(&args).unwrap_err();
        assert!(format!("{err:#}").contains("usage"), "{err:#}");
    }

    #[test]
    fn serve_quantized_rejects_pjrt_engine() {
        let args = Args::parse([
            "serve".to_string(),
            "--quantized".into(),
            "--engine".into(),
            "pjrt".into(),
        ])
        .unwrap();
        let err = dispatch(&args).unwrap_err();
        assert!(format!("{err:#}").contains("native"), "{err:#}");
    }

    #[test]
    fn serve_rejects_quantize_with_store() {
        let args = Args::parse([
            "serve".to_string(),
            "--store".into(),
            "/tmp/daq_no_such_store.dts".into(),
            "--quantize".into(),
        ])
        .unwrap();
        let err = dispatch(&args).unwrap_err();
        assert!(format!("{err:#}").contains("--quantized"), "{err:#}");
    }

    #[test]
    fn eval_quantized_requires_ckpt() {
        // fails on the missing --ckpt (after the artifacts open, which
        // this environment does not have -> either error is fine, but it
        // must not fall through to the dense loader)
        let args = Args::parse(["eval".to_string(), "--quantized".into()]).unwrap();
        assert!(dispatch(&args).is_err());
    }

    #[test]
    fn serve_store_must_exist() {
        let args = Args::parse([
            "serve".to_string(),
            "--store".into(),
            "/tmp/daq_no_such_store.dts".into(),
        ])
        .unwrap();
        assert!(dispatch(&args).is_err());
    }

    #[test]
    fn stream_requires_out_dir() {
        let args = Args::parse(
            ["quantize".to_string(), "--stream".into()],
        ).unwrap();
        let err = dispatch(&args).unwrap_err();
        assert!(format!("{err:#}").contains("--out"), "{err:#}");
    }

    #[test]
    fn stream_rejects_pjrt_engine() {
        let args = Args::parse([
            "quantize".to_string(),
            "--stream".into(),
            "--engine".into(),
            "pjrt".into(),
            "--out".into(),
            "/tmp/daq_stream_cli_test".into(),
        ]).unwrap();
        let err = dispatch(&args).unwrap_err();
        assert!(format!("{err:#}").contains("native"), "{err:#}");
    }

    #[test]
    fn shard_requires_in_and_out() {
        let args = Args::parse(["shard".to_string()]).unwrap();
        assert!(dispatch(&args).is_err());
    }

    #[test]
    fn unknown_command_errors() {
        let args = Args::parse(["frobnicate".to_string()]).unwrap();
        assert!(dispatch(&args).is_err());
    }

    #[test]
    fn help_works() {
        let args = Args::parse(["help".to_string()]).unwrap();
        dispatch(&args).unwrap();
    }

    #[test]
    fn parse_method_variants() {
        let m = |s: &str| {
            parse_method(&Args::parse([
                "quantize".to_string(),
                "--metric".into(),
                s.into(),
            ]).unwrap())
        };
        assert!(matches!(m("absmax").unwrap(), Method::AbsMax));
        assert!(matches!(m("sign").unwrap(),
            Method::Search { objective: Objective::SignRate, .. }));
        assert!(matches!(m("smoothquant").unwrap(), Method::SmoothQuant { .. }));
        assert!(matches!(m("awq").unwrap(), Method::Awq));
        assert!(m("nonsense").is_err());
    }

    #[test]
    fn stream_calib_with_delta_method_rejected() {
        let args = Args::parse([
            "quantize".to_string(),
            "--stream".into(),
            "--out".into(),
            "/tmp/daq_calib_delta_test".into(),
            "--calib".into(),
            "x.dts".into(),
        ])
        .unwrap();
        let err = dispatch(&args).unwrap_err();
        assert!(format!("{err:#}").contains("transform baselines"), "{err:#}");
    }

    #[test]
    fn groups_and_calib_require_stream() {
        for flag in ["--groups", "--calib", "--group-source", "--graph"] {
            let args = Args::parse([
                "quantize".to_string(),
                flag.to_string(),
                "x".into(),
            ])
            .unwrap();
            let err = dispatch(&args).unwrap_err();
            assert!(format!("{err:#}").contains("--stream"), "{flag}: {err:#}");
        }
    }

    #[test]
    fn group_source_flag_validation() {
        // unknown mode
        let args = Args::parse([
            "quantize".to_string(),
            "--stream".into(),
            "--out".into(),
            "/tmp/daq_gs_test".into(),
            "--method".into(),
            "smoothquant".into(),
            "--group-source".into(),
            "vibes".into(),
        ])
        .unwrap();
        let err = dispatch(&args).unwrap_err();
        assert!(format!("{err:#}").contains("--group-source"), "{err:#}");

        // manifest mode without --groups
        let args = Args::parse([
            "quantize".to_string(),
            "--stream".into(),
            "--out".into(),
            "/tmp/daq_gs_test".into(),
            "--method".into(),
            "smoothquant".into(),
            "--group-source".into(),
            "manifest".into(),
        ])
        .unwrap();
        let err = dispatch(&args).unwrap_err();
        assert!(format!("{err:#}").contains("--groups"), "{err:#}");

        // trace mode with no sidecar anywhere
        let args = Args::parse([
            "quantize".to_string(),
            "--stream".into(),
            "--out".into(),
            "/tmp/daq_gs_test".into(),
            "--method".into(),
            "smoothquant".into(),
            "--group-source".into(),
            "trace".into(),
            "--graph".into(),
            "/tmp/daq_gs_no_such_graph.dts".into(),
        ])
        .unwrap();
        let err = dispatch(&args).unwrap_err();
        assert!(format!("{err:#}").contains("daq trace"), "{err:#}");
    }

    #[test]
    fn format_flag_validation() {
        // unknown formats are hard errors naming the valid set — before
        // any artifact I/O, so this fails on the flag, not the missing lab
        let args = Args::parse([
            "quantize".to_string(),
            "--format".into(),
            "int9".into(),
        ])
        .unwrap();
        let err = dispatch(&args).unwrap_err();
        assert!(
            format!("{err:#}").contains("fp8-e4m3 | fp8-e5m2 | int4"),
            "{err:#}"
        );

        // --residual-rank on a transform baseline is a hard error
        let args = Args::parse([
            "quantize".to_string(),
            "--method".into(),
            "smoothquant".into(),
            "--residual-rank".into(),
            "2".into(),
        ])
        .unwrap();
        let err = dispatch(&args).unwrap_err();
        assert!(format!("{err:#}").contains("delta methods"), "{err:#}");

        // and through the streaming path
        let args = Args::parse([
            "quantize".to_string(),
            "--stream".into(),
            "--out".into(),
            "/tmp/daq_fmt_cli_test".into(),
            "--method".into(),
            "awq".into(),
            "--format".into(),
            "int4:32".into(),
        ])
        .unwrap();
        let err = dispatch(&args).unwrap_err();
        assert!(format!("{err:#}").contains("delta methods"), "{err:#}");
    }

    #[test]
    fn int4_group_defaults_granularity() {
        let args = Args::parse([
            "quantize".to_string(),
            "--format".into(),
            "int4:32".into(),
        ])
        .unwrap();
        let (fmt, rank) = parse_format(&args, &Method::AbsMax).unwrap();
        assert_eq!(fmt, CodeFormat::Int4 { group: 32 });
        assert_eq!(rank, 0);
        assert_eq!(parse_gran(&args, fmt).unwrap(), Granularity::Block(32));
        // an explicit --gran wins over the format default
        let args = Args::parse([
            "quantize".to_string(),
            "--format".into(),
            "int4".into(),
            "--gran".into(),
            "channel".into(),
        ])
        .unwrap();
        assert_eq!(
            parse_gran(&args, CodeFormat::Int4 { group: 64 }).unwrap(),
            Granularity::PerChannel
        );
        // no flags: the paper's FP8 block-128 default
        let args = Args::parse(["quantize".to_string()]).unwrap();
        let (fmt, rank) = parse_format(&args, &Method::AbsMax).unwrap();
        assert_eq!(fmt, CodeFormat::Fp8E4m3);
        assert_eq!(rank, 0);
        assert_eq!(parse_gran(&args, fmt).unwrap(), Granularity::Block(128));
    }

    #[test]
    fn inspect_and_verify_store_decode_format_descriptors() {
        use crate::io::dts::DtsTensor;
        let dir =
            std::env::temp_dir().join(format!("daq_cli_fmt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();

        let w = demo_tensor(6, 9, 3);
        let q = crate::quant::quantize_fmt(
            &w,
            Granularity::Block(4),
            CodeFormat::Int4 { group: 4 },
            1.0,
            2,
        );
        let fmt = q.format();
        let mut d = Dts::new();
        d.meta.insert("fmt.w".into(), Descriptor::for_tensor(&q).to_meta());
        d.insert("w.codes", DtsTensor::U8 {
            shape: vec![q.shape.0, fmt.packed_row_bytes(q.shape.1)],
            data: q.codes.clone(),
        });
        d.insert_f32("w.scales", &Tensor::new(
            vec![q.scales.grid_rows, q.scales.grid_cols],
            q.scales.scales.clone(),
        ));
        let lr = q.residual.as_ref().unwrap();
        d.insert_f32("w.res_u", &Tensor::new(vec![q.shape.0, lr.k], lr.u.clone()));
        d.insert_f32("w.res_v", &Tensor::new(vec![lr.k, q.shape.1], lr.v.clone()));
        let store = dir.join("store.dts");
        d.write(&store).unwrap();
        let p = store.to_str().unwrap().to_string();

        // both commands decode the descriptor and exit clean
        dispatch(&Args::parse(["inspect".to_string(), p.clone()]).unwrap()).unwrap();
        dispatch(&Args::parse(["verify-store".to_string(), p]).unwrap()).unwrap();

        // a sub-byte descriptor without cols= is rejected by both
        d.meta.insert("fmt.w".into(), "int4:4;block4;res=2".into());
        let bad = dir.join("bad.dts");
        d.write(&bad).unwrap();
        let p = bad.to_str().unwrap().to_string();
        let err = dispatch(&Args::parse(["verify-store".to_string(), p]).unwrap())
            .unwrap_err();
        assert!(format!("{err:#}").contains("cols"), "{err:#}");

        // a descriptor declaring a residual that is not there is rejected
        let mut d = Dts::new();
        d.meta.insert("fmt.w".into(), Descriptor::for_tensor(&q).to_meta());
        d.insert("w.codes", DtsTensor::U8 {
            shape: vec![q.shape.0, fmt.packed_row_bytes(q.shape.1)],
            data: q.codes.clone(),
        });
        d.insert_f32("w.scales", &Tensor::new(
            vec![q.scales.grid_rows, q.scales.grid_cols],
            q.scales.scales.clone(),
        ));
        d.insert_f32("w.res_u", &Tensor::new(vec![q.shape.0, lr.k], lr.u.clone()));
        let gone = dir.join("gone.dts");
        d.write(&gone).unwrap();
        let p = gone.to_str().unwrap().to_string();
        let err = dispatch(&Args::parse(["verify-store".to_string(), p]).unwrap())
            .unwrap_err();
        assert!(format!("{err:#}").contains("res_v"), "{err:#}");

        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn method_flag_aliases_metric() {
        let args = Args::parse([
            "quantize".to_string(),
            "--method".into(),
            "awq".into(),
        ])
        .unwrap();
        assert!(matches!(parse_method(&args).unwrap(), Method::Awq));
        // --method wins when both are given
        let both = Args::parse([
            "quantize".to_string(),
            "--method".into(),
            "absmax".into(),
            "--metric".into(),
            "awq".into(),
        ])
        .unwrap();
        assert!(matches!(parse_method(&both).unwrap(), Method::AbsMax));
    }
}
