//! Evaluation harness: the paper's rubric — Style and General scores on
//! the [0, 2] scale — computed over the held-out eval sets produced by
//! `make artifacts`.
//!
//! Two interchangeable forward paths:
//! - **PJRT** (default): the AOT-lowered L2 graph via `runtime::Runtime`.
//! - **native**: a from-scratch Rust reimplementation of the transformer
//!   (`forward_native`), used to cross-check the artifact and in tests.

pub mod decode;
pub mod model_native;
pub mod quantstore;
pub mod trace;

pub use quantstore::{QParam, QuantizedParams};

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::io::dts::Dts;
use crate::io::TensorSource;
use crate::tensor::Tensor;

/// A loaded model checkpoint: name → f32 tensor.
pub type Params = HashMap<String, Tensor>;

/// Load all f32 tensors of a DTS checkpoint as model parameters.
pub fn load_params(d: &Dts) -> Result<Params> {
    let mut p = Params::new();
    for name in d.names() {
        p.insert(name.clone(), d.tensor_f32(name)?);
    }
    Ok(p)
}

/// Like [`load_params`] but skips non-f32 tensors and quantization
/// sidecars (`*.codes`, `*.scales`, `*.res_u`, `*.res_v`) — the loader
/// for quantized checkpoints written by
/// `PipelineOutcome::write_checkpoint`.
pub fn load_params_filtered(d: &Dts) -> Result<Params> {
    let mut p = Params::new();
    for name in d.names() {
        if name.ends_with(".codes")
            || name.ends_with(".scales")
            || name.ends_with(".res_u")
            || name.ends_with(".res_v")
        {
            continue;
        }
        if let Ok(t) = d.tensor_f32(name) {
            p.insert(name.clone(), t);
        }
    }
    Ok(p)
}

/// Load a checkpoint preferring the compact quantized sidecars: every
/// `<name>.codes` / `<name>.scales` pair is bulk-dequantized through its
/// format's decode path (`CodeFormat::decode_row_into` — FP8 LUTs or
/// INT4 nibble unpacking, per the `fmt.<name>` descriptor), with the
/// low-rank residual applied when present, instead of trusting (or even
/// requiring) a stored f32 copy — the serving-path loader. Tensors
/// without sidecars load as plain f32; non-f32 extras are skipped.
pub fn load_params_dequant(d: &Dts) -> Result<Params> {
    load_params_dequant_source(d)
}

/// [`load_params_dequant`] generalized over any [`TensorSource`] backend —
/// in particular the sharded stores the streaming pipeline writes, where
/// tensors dequantize shard-by-shard as they are pulled.
///
/// Built on the quantized-resident loader ([`QuantizedParams::load`]) so
/// both paths share one name-derivation and fallback policy; this one
/// then expands every weight to dense f32 — use it only where a full f32
/// copy is actually wanted (PJRT, cross-checks). The serving path keeps
/// the store quantized instead.
pub fn load_params_dequant_source(d: &dyn TensorSource) -> Result<Params> {
    Ok(QuantizedParams::load(d)?.dequantize_all())
}

/// Total f32 footprint of a dense parameter map, for the resident-memory
/// comparison the serve report prints.
pub fn params_bytes(p: &Params) -> usize {
    p.values().map(|t| t.len() * 4).sum()
}

/// One eval set: tokens `[n, seq]` and a 0/1 mask of scored positions
/// (mask at t scores the prediction of token t+1 — the corpus convention).
pub struct EvalSet {
    pub n: usize,
    pub seq: usize,
    pub tokens: Vec<i32>,
    pub mask: Vec<i32>,
}

impl EvalSet {
    pub fn load(path: &str) -> Result<EvalSet> {
        let d = Dts::read(path)?;
        let (tshape, tokens) = d.tensor_i32("tokens")?;
        let (mshape, mask) = d.tensor_i32("mask")?;
        if tshape != mshape || tshape.len() != 2 {
            bail!("eval set {path}: tokens {tshape:?} vs mask {mshape:?}");
        }
        Ok(EvalSet { n: tshape[0], seq: tshape[1], tokens, mask })
    }
}

/// Raw correct/total counts of argmax next-token predictions at masked
/// positions, given logits `[n, seq, vocab]` flattened row-major. The
/// single source of truth for scoring: [`masked_accuracy`] is the ratio,
/// and [`eval_rubric`] sums these counts across batches directly — no
/// lossy reconstruction of counts from a rounded ratio. Note a mask bit
/// at the final position never scores (there is no next token), so the
/// scored total here can be smaller than the raw mask popcount.
pub fn masked_counts(set: &EvalSet, logits: &[f32], vocab: usize) -> (usize, usize) {
    let (n, seq) = (set.n, set.seq);
    assert_eq!(logits.len(), n * seq * vocab);
    let mut correct = 0usize;
    let mut total = 0usize;
    for i in 0..n {
        for t in 0..seq - 1 {
            if set.mask[i * seq + t] == 0 {
                continue;
            }
            let target = set.tokens[i * seq + t + 1];
            let row = &logits[(i * seq + t) * vocab..(i * seq + t + 1) * vocab];
            let mut best = 0usize;
            for v in 1..vocab {
                if row[v] > row[best] {
                    best = v;
                }
            }
            total += 1;
            if best as i32 == target {
                correct += 1;
            }
        }
    }
    (correct, total)
}

/// Accuracy of argmax next-token predictions at masked positions.
pub fn masked_accuracy(set: &EvalSet, logits: &[f32], vocab: usize) -> f64 {
    let (correct, total) = masked_counts(set, logits, vocab);
    if total == 0 {
        return 0.0;
    }
    correct as f64 / total as f64
}

/// Map accuracy to the paper's [0, 2] rubric scale.
pub fn accuracy_to_rubric(acc: f64) -> f64 {
    2.0 * acc
}

/// A full-sequence forward function: `(batch, tokens) -> logits`.
/// Parameters are bound at construction — every implementation closes
/// over its own parameter storage (dense f32, PJRT-resident, or the
/// quantized store), so callers never thread a params map through.
pub trait ForwardFn {
    fn forward(&self, batch: usize, tokens: &[i32]) -> Result<Vec<f32>>;
    fn vocab(&self) -> usize;
    fn seq_len(&self) -> usize;
    fn batch(&self) -> usize;
}

/// Evaluate one eval set in fixed-size batches (padding the last batch by
/// repeating row 0; padded rows carry zero masks so they never score).
/// Correct/total counts sum directly across batches via
/// [`masked_counts`] — the per-batch ratio is never rounded back into a
/// count, so a mask bit at an unscoreable position cannot drift the
/// aggregate.
pub fn eval_rubric(fwd: &dyn ForwardFn, set: &EvalSet) -> Result<f64> {
    let b = fwd.batch();
    let seq = fwd.seq_len();
    if seq != set.seq {
        bail!("eval set seq {} != model seq {seq}", set.seq);
    }
    let vocab = fwd.vocab();
    let (mut correct, mut total) = (0usize, 0usize);
    let mut i = 0;
    while i < set.n {
        let take = (set.n - i).min(b);
        let mut tokens = vec![0i32; b * seq];
        let mut mask = vec![0i32; b * seq];
        for j in 0..take {
            let src = (i + j) * seq;
            tokens[j * seq..(j + 1) * seq]
                .copy_from_slice(&set.tokens[src..src + seq]);
            mask[j * seq..(j + 1) * seq].copy_from_slice(&set.mask[src..src + seq]);
        }
        for j in take..b {
            let src = i * seq; // repeat a real row; mask stays zero
            tokens[j * seq..(j + 1) * seq]
                .copy_from_slice(&set.tokens[src..src + seq]);
        }
        let logits = fwd.forward(b, &tokens)?;
        let batch_set = EvalSet { n: b, seq, tokens, mask };
        let (c, t) = masked_counts(&batch_set, &logits, vocab);
        correct += c;
        total += t;
        i += take;
    }
    Ok(accuracy_to_rubric(if total == 0 {
        0.0
    } else {
        correct as f64 / total as f64
    }))
}

/// PJRT-backed forward (params bound at construction).
pub struct PjrtForward<'a> {
    pub rt: &'a crate::runtime::Runtime,
    pub params: &'a Params,
    pub batch: usize,
}

impl ForwardFn for PjrtForward<'_> {
    fn forward(&self, batch: usize, tokens: &[i32]) -> Result<Vec<f32>> {
        let mut hp: HashMap<String, Tensor> = HashMap::new();
        for (k, v) in self.params.iter() {
            hp.insert(k.clone(), v.clone());
        }
        self.rt.forward(batch, tokens, &hp)
    }

    fn vocab(&self) -> usize {
        self.rt.manifest.vocab
    }

    fn seq_len(&self) -> usize {
        self.rt.manifest.seq_len
    }

    fn batch(&self) -> usize {
        self.batch
    }
}

/// Native-Rust forward (params + config bound at construction).
pub struct NativeForward<'a> {
    pub params: &'a Params,
    pub cfg: model_native::ModelCfg,
    pub batch: usize,
}

impl ForwardFn for NativeForward<'_> {
    fn forward(&self, batch: usize, tokens: &[i32]) -> Result<Vec<f32>> {
        model_native::forward_native(self.params, &self.cfg, batch, tokens)
    }

    fn vocab(&self) -> usize {
        self.cfg.vocab
    }

    fn seq_len(&self) -> usize {
        self.cfg.seq_len
    }

    fn batch(&self) -> usize {
        self.batch
    }
}

/// Quantized-resident forward: the same native graph flowing through the
/// fused dequant-matmul backend — weights never leave their codes+scales
/// storage form.
pub struct QuantForward<'a> {
    pub params: &'a QuantizedParams,
    pub cfg: model_native::ModelCfg,
    pub batch: usize,
}

impl ForwardFn for QuantForward<'_> {
    fn forward(&self, batch: usize, tokens: &[i32]) -> Result<Vec<f32>> {
        model_native::forward_quant(self.params, &self.cfg, batch, tokens)
    }

    fn vocab(&self) -> usize {
        self.cfg.vocab
    }

    fn seq_len(&self) -> usize {
        self.cfg.seq_len
    }

    fn batch(&self) -> usize {
        self.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masked_accuracy_counts_only_masked() {
        // n=1, seq=3, vocab=2; predictions: pos0 -> token1, pos1 -> token0
        let set = EvalSet {
            n: 1,
            seq: 3,
            tokens: vec![0, 1, 0],
            mask: vec![1, 1, 0],
        };
        // logits at t=0 favour 1 (correct: target tokens[1]=1),
        // at t=1 favour 1 (wrong: target tokens[2]=0)
        let logits = vec![
            0.0, 1.0, // t=0
            0.0, 1.0, // t=1
            0.0, 0.0, // t=2 (unscored)
        ];
        let acc = masked_accuracy(&set, &logits, 2);
        assert!((acc - 0.5).abs() < 1e-12);
    }

    #[test]
    fn rubric_scale() {
        assert_eq!(accuracy_to_rubric(0.0), 0.0);
        assert_eq!(accuracy_to_rubric(1.0), 2.0);
        assert_eq!(accuracy_to_rubric(0.75), 1.5);
    }

    #[test]
    fn empty_mask_gives_zero() {
        let set = EvalSet { n: 1, seq: 2, tokens: vec![0, 0], mask: vec![0, 0] };
        assert_eq!(masked_accuracy(&set, &[0.0; 4], 2), 0.0);
    }

    /// Always predicts token 1, whatever the input.
    struct PredictOneForward {
        seq: usize,
        vocab: usize,
        batch: usize,
    }

    impl ForwardFn for PredictOneForward {
        fn forward(&self, batch: usize, _tokens: &[i32]) -> Result<Vec<f32>> {
            let mut logits = vec![0.0f32; batch * self.seq * self.vocab];
            for row in logits.chunks_mut(self.vocab) {
                row[1] = 1.0;
            }
            Ok(logits)
        }

        fn vocab(&self) -> usize {
            self.vocab
        }

        fn seq_len(&self) -> usize {
            self.seq
        }

        fn batch(&self) -> usize {
            self.batch
        }
    }

    #[test]
    fn rubric_sums_counts_exactly_no_roundtrip_drift() {
        // A mask bit at the final position is in the raw mask popcount but
        // can never score (no next token). The old accumulation
        // reconstructed counts as round(batch_accuracy * popcount), which
        // inflates the total and fabricates correct-counts here; summing
        // masked_counts directly must give exactly 2 * (1/2) = 1.0.
        let fwd = PredictOneForward { seq: 3, vocab: 4, batch: 2 };
        let set = EvalSet {
            n: 1,
            seq: 3,
            // t=0 scores target tokens[1]=1 (predicted 1: correct),
            // t=1 scores target tokens[2]=0 (predicted 1: wrong),
            // t=2 carries a mask bit but has no next token
            tokens: vec![0, 1, 0],
            mask: vec![1, 1, 1],
        };
        let (c, t) = masked_counts(&set, &fwd.forward(1, &set.tokens).unwrap(), 4);
        assert_eq!((c, t), (1, 2));
        let r = eval_rubric(&fwd, &set).unwrap();
        assert!((r - 1.0).abs() < 1e-12, "rubric drifted: {r}");
    }

    #[test]
    fn dequant_loader_handles_codes_only_checkpoint() {
        // a compact checkpoint: sidecars + metadata, NO stored f32 copy
        use crate::io::dts::DtsTensor;
        use crate::quant::{quantize, Granularity};
        use crate::util::rng::XorShift;

        let mut rng = XorShift::new(31);
        let w = Tensor::new(vec![8, 12], rng.normal_vec(96, 0.1));
        let q = quantize(&w, Granularity::PerChannel, 1.0);
        let mut d = Dts::new();
        d.meta.insert("gran.w".into(), "channel".into());
        d.insert(
            "w.codes",
            DtsTensor::U8 { shape: vec![8, 12], data: q.codes.clone() },
        );
        d.insert(
            "w.scales",
            DtsTensor::F32 {
                shape: vec![q.scales.grid_rows, q.scales.grid_cols],
                data: q.scales.scales.clone(),
            },
        );
        let p = load_params_dequant(&d).unwrap();
        let got = &p["w"];
        let want = q.dequantize();
        assert_eq!(got.shape(), want.shape());
        for (a, b) in got.data().iter().zip(want.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
