//! Evaluation harness: the paper's rubric — Style and General scores on
//! the [0, 2] scale — computed over the held-out eval sets produced by
//! `make artifacts`.
//!
//! Two interchangeable forward paths:
//! - **PJRT** (default): the AOT-lowered L2 graph via `runtime::Runtime`.
//! - **native**: a from-scratch Rust reimplementation of the transformer
//!   (`forward_native`), used to cross-check the artifact and in tests.

pub mod model_native;
pub mod trace;

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use crate::io::dts::{Dts, DtsTensor};
use crate::io::TensorSource;
use crate::quant::{Granularity, QuantizedTensor, ScaleGrid};
use crate::tensor::Tensor;

/// A loaded model checkpoint: name → f32 tensor.
pub type Params = HashMap<String, Tensor>;

/// Load all f32 tensors of a DTS checkpoint as model parameters.
pub fn load_params(d: &Dts) -> Result<Params> {
    let mut p = Params::new();
    for name in d.names() {
        p.insert(name.clone(), d.tensor_f32(name)?);
    }
    Ok(p)
}

/// Like [`load_params`] but skips non-f32 tensors and quantization
/// sidecars (`*.codes`, `*.scales`) — the loader for quantized
/// checkpoints written by `PipelineOutcome::write_checkpoint`.
pub fn load_params_filtered(d: &Dts) -> Result<Params> {
    let mut p = Params::new();
    for name in d.names() {
        if name.ends_with(".codes") || name.ends_with(".scales") {
            continue;
        }
        if let Ok(t) = d.tensor_f32(name) {
            p.insert(name.clone(), t);
        }
    }
    Ok(p)
}

/// Load a checkpoint preferring the compact quantized sidecars: every
/// `<name>.codes` / `<name>.scales` pair is bulk-dequantized through the
/// shared E4M3 decode table (`fp8::decode_lut`) instead of trusting (or
/// even requiring) a stored f32 copy — the serving-path loader. Tensors
/// without sidecars load as plain f32; non-f32 extras are skipped.
pub fn load_params_dequant(d: &Dts) -> Result<Params> {
    load_params_dequant_source(d)
}

/// [`load_params_dequant`] generalized over any [`TensorSource`] backend —
/// in particular the sharded stores the streaming pipeline writes, where
/// tensors dequantize shard-by-shard as they are pulled.
pub fn load_params_dequant_source(d: &dyn TensorSource) -> Result<Params> {
    let mut p = Params::new();
    // base names come from both plain tensors AND the stems of `.codes`
    // sidecars: a compact checkpoint may store only codes+scales with no
    // f32 copy at all. A `.codes`/`.scales` suffix only counts as a
    // sidecar when its counterpart exists — a plain parameter that merely
    // happens to end in `.scales` must still load as itself.
    let mut names: Vec<String> = Vec::new();
    let mut seen = std::collections::BTreeSet::new();
    for name in d.names() {
        let base = if let Some(stem) = name.strip_suffix(".codes") {
            if d.contains(&format!("{stem}.scales")) {
                stem.to_string()
            } else {
                name.clone()
            }
        } else if let Some(stem) = name.strip_suffix(".scales") {
            if d.contains(&format!("{stem}.codes")) {
                continue;
            }
            name.clone()
        } else {
            name.clone()
        };
        if seen.insert(base.clone()) {
            names.push(base);
        }
    }
    for name in &names {
        let codes_name = format!("{name}.codes");
        let scales_name = format!("{name}.scales");
        let has_codes = d.contains(&codes_name);
        let gran_label = d.meta().get(&format!("gran.{name}"));
        if has_codes && d.contains(&scales_name) && gran_label.is_some() {
            let (cshape, codes) = d.tensor_u8(&codes_name)?;
            if cshape.len() != 2 {
                bail!("{codes_name}: expected 2-D codes, got {cshape:?}");
            }
            let (rows, cols) = (cshape[0], cshape[1]);
            let gran =
                Granularity::parse(gran_label.expect("checked")).map_err(|e| anyhow!(e))?;
            let scales = d.tensor_f32(&scales_name)?.into_data();
            let grid = ScaleGrid::from_sidecar(gran, rows, cols, scales)
                .map_err(|e| anyhow!("{name}: {e}"))?;
            let q = QuantizedTensor { shape: (rows, cols), codes, scales: grid };
            p.insert(name.clone(), q.dequantize());
        } else {
            match d.read_tensor(name) {
                // pre-metadata checkpoints (codes but no `gran.<name>`
                // meta) and plain tensors: use the stored f32 copy
                Ok(DtsTensor::F32 { shape, data }) => {
                    p.insert(name.clone(), Tensor::new(shape, data));
                }
                // non-f32 extras (token tables etc.) are skipped — unless
                // codes exist, in which case a silently missing weight
                // would fail far from here
                Ok(_) if !has_codes => {}
                Err(e) if !has_codes => {
                    // file-backed sources can fail mid-read (truncated
                    // shard, unreadable file): propagate, never drop a
                    // parameter silently
                    return Err(e);
                }
                Ok(_) | Err(_) => bail!(
                    "{name}: {codes_name} present but cannot dequantize \
                     (missing {scales_name} or gran.{name} metadata) and no \
                     f32 copy is stored"
                ),
            }
        }
    }
    Ok(p)
}

/// One eval set: tokens `[n, seq]` and a 0/1 mask of scored positions
/// (mask at t scores the prediction of token t+1 — the corpus convention).
pub struct EvalSet {
    pub n: usize,
    pub seq: usize,
    pub tokens: Vec<i32>,
    pub mask: Vec<i32>,
}

impl EvalSet {
    pub fn load(path: &str) -> Result<EvalSet> {
        let d = Dts::read(path)?;
        let (tshape, tokens) = d.tensor_i32("tokens")?;
        let (mshape, mask) = d.tensor_i32("mask")?;
        if tshape != mshape || tshape.len() != 2 {
            bail!("eval set {path}: tokens {tshape:?} vs mask {mshape:?}");
        }
        Ok(EvalSet { n: tshape[0], seq: tshape[1], tokens, mask })
    }
}

/// Accuracy of argmax next-token predictions at masked positions, given
/// logits `[n, seq, vocab]` flattened row-major.
pub fn masked_accuracy(set: &EvalSet, logits: &[f32], vocab: usize) -> f64 {
    let (n, seq) = (set.n, set.seq);
    assert_eq!(logits.len(), n * seq * vocab);
    let mut correct = 0usize;
    let mut total = 0usize;
    for i in 0..n {
        for t in 0..seq - 1 {
            if set.mask[i * seq + t] == 0 {
                continue;
            }
            let target = set.tokens[i * seq + t + 1];
            let row = &logits[(i * seq + t) * vocab..(i * seq + t + 1) * vocab];
            let mut best = 0usize;
            for v in 1..vocab {
                if row[v] > row[best] {
                    best = v;
                }
            }
            total += 1;
            if best as i32 == target {
                correct += 1;
            }
        }
    }
    if total == 0 {
        return 0.0;
    }
    correct as f64 / total as f64
}

/// Map accuracy to the paper's [0, 2] rubric scale.
pub fn accuracy_to_rubric(acc: f64) -> f64 {
    2.0 * acc
}

/// A forward function: (batch, tokens, params) -> logits.
pub trait ForwardFn {
    fn forward(&self, batch: usize, tokens: &[i32], params: &Params) -> Result<Vec<f32>>;
    fn vocab(&self) -> usize;
    fn seq_len(&self) -> usize;
    fn batch(&self) -> usize;
}

/// Evaluate one eval set in fixed-size batches (padding the last batch by
/// repeating row 0; padded rows carry zero masks so they never score).
pub fn eval_rubric(fwd: &dyn ForwardFn, set: &EvalSet) -> Result<f64> {
    let b = fwd.batch();
    let seq = fwd.seq_len();
    if seq != set.seq {
        bail!("eval set seq {} != model seq {seq}", set.seq);
    }
    let vocab = fwd.vocab();
    let mut correct_total = (0usize, 0usize);
    let mut i = 0;
    while i < set.n {
        let take = (set.n - i).min(b);
        let mut tokens = vec![0i32; b * seq];
        let mut mask = vec![0i32; b * seq];
        for j in 0..take {
            let src = (i + j) * seq;
            tokens[j * seq..(j + 1) * seq]
                .copy_from_slice(&set.tokens[src..src + seq]);
            mask[j * seq..(j + 1) * seq].copy_from_slice(&set.mask[src..src + seq]);
        }
        for j in take..b {
            let src = i * seq; // repeat a real row; mask stays zero
            tokens[j * seq..(j + 1) * seq]
                .copy_from_slice(&set.tokens[src..src + seq]);
        }
        let logits = fwd.forward(b, &tokens, &dummy_params_guard())?;
        // note: ForwardFn implementations close over params; the guard is
        // only for the trait signature symmetry (see PjrtForward below).
        let batch_set = EvalSet { n: b, seq, tokens, mask };
        let (mut c, mut t) = correct_total;
        let acc = masked_accuracy(&batch_set, &logits, vocab);
        let scored: usize = batch_set.mask.iter().map(|&m| m as usize).sum();
        c += (acc * scored as f64).round() as usize;
        t += scored;
        correct_total = (c, t);
        i += take;
    }
    let (c, t) = correct_total;
    Ok(accuracy_to_rubric(if t == 0 { 0.0 } else { c as f64 / t as f64 }))
}

fn dummy_params_guard() -> Params {
    Params::new()
}

/// PJRT-backed forward (params bound at construction).
pub struct PjrtForward<'a> {
    pub rt: &'a crate::runtime::Runtime,
    pub params: &'a Params,
    pub batch: usize,
}

impl ForwardFn for PjrtForward<'_> {
    fn forward(&self, batch: usize, tokens: &[i32], _unused: &Params) -> Result<Vec<f32>> {
        let mut hp: HashMap<String, Tensor> = HashMap::new();
        for (k, v) in self.params.iter() {
            hp.insert(k.clone(), v.clone());
        }
        self.rt.forward(batch, tokens, &hp)
    }

    fn vocab(&self) -> usize {
        self.rt.manifest.vocab
    }

    fn seq_len(&self) -> usize {
        self.rt.manifest.seq_len
    }

    fn batch(&self) -> usize {
        self.batch
    }
}

/// Native-Rust forward (params + config bound at construction).
pub struct NativeForward<'a> {
    pub params: &'a Params,
    pub cfg: model_native::ModelCfg,
    pub batch: usize,
}

impl ForwardFn for NativeForward<'_> {
    fn forward(&self, batch: usize, tokens: &[i32], _unused: &Params) -> Result<Vec<f32>> {
        model_native::forward_native(self.params, &self.cfg, batch, tokens)
    }

    fn vocab(&self) -> usize {
        self.cfg.vocab
    }

    fn seq_len(&self) -> usize {
        self.cfg.seq_len
    }

    fn batch(&self) -> usize {
        self.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masked_accuracy_counts_only_masked() {
        // n=1, seq=3, vocab=2; predictions: pos0 -> token1, pos1 -> token0
        let set = EvalSet {
            n: 1,
            seq: 3,
            tokens: vec![0, 1, 0],
            mask: vec![1, 1, 0],
        };
        // logits at t=0 favour 1 (correct: target tokens[1]=1),
        // at t=1 favour 1 (wrong: target tokens[2]=0)
        let logits = vec![
            0.0, 1.0, // t=0
            0.0, 1.0, // t=1
            0.0, 0.0, // t=2 (unscored)
        ];
        let acc = masked_accuracy(&set, &logits, 2);
        assert!((acc - 0.5).abs() < 1e-12);
    }

    #[test]
    fn rubric_scale() {
        assert_eq!(accuracy_to_rubric(0.0), 0.0);
        assert_eq!(accuracy_to_rubric(1.0), 2.0);
        assert_eq!(accuracy_to_rubric(0.75), 1.5);
    }

    #[test]
    fn empty_mask_gives_zero() {
        let set = EvalSet { n: 1, seq: 2, tokens: vec![0, 0], mask: vec![0, 0] };
        assert_eq!(masked_accuracy(&set, &[0.0; 4], 2), 0.0);
    }

    #[test]
    fn dequant_loader_handles_codes_only_checkpoint() {
        // a compact checkpoint: sidecars + metadata, NO stored f32 copy
        use crate::io::dts::DtsTensor;
        use crate::quant::{quantize, Granularity};
        use crate::util::rng::XorShift;

        let mut rng = XorShift::new(31);
        let w = Tensor::new(vec![8, 12], rng.normal_vec(96, 0.1));
        let q = quantize(&w, Granularity::PerChannel, 1.0);
        let mut d = Dts::new();
        d.meta.insert("gran.w".into(), "channel".into());
        d.insert(
            "w.codes",
            DtsTensor::U8 { shape: vec![8, 12], data: q.codes.clone() },
        );
        d.insert(
            "w.scales",
            DtsTensor::F32 {
                shape: vec![q.scales.grid_rows, q.scales.grid_cols],
                data: q.scales.scales.clone(),
            },
        );
        let p = load_params_dequant(&d).unwrap();
        let got = &p["w"];
        let want = q.dequantize();
        assert_eq!(got.shape(), want.shape());
        for (a, b) in got.data().iter().zip(want.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
