//! Incremental (KV-cached) decoding — the serving path's answer to the
//! full-reforward loop.
//!
//! The batched serve loop used to re-run the whole-sequence forward for
//! every generated token: token t cost O(seq²·d) attention plus seq
//! GEMM rows that had already been computed t times before. This module
//! decodes one token per step against per-layer K/V caches keyed on a
//! position cursor, so token t costs O(t·d) attention and exactly one
//! GEMM row per weight — O(t) per token instead of O(seq²).
//!
//! The decoder reproduces the native forward's arithmetic *exactly*: the
//! same accumulation orders, the same layernorm/softmax/GELU bodies, and
//! the full forward's softmax over a causally masked row is bitwise
//! equal to the incremental softmax over the prefix (the masked `-1e9`
//! entries underflow to exactly `0.0` in f32). `decode` tests pin logits
//! at every position to the full forward's bits.
//!
//! Parameter storage is abstracted behind [`ParamSource`] so the same
//! decoder serves dense f32 maps and the quantized-resident store — for
//! the latter every GEMM row flows through the fused dequant path
//! ([`crate::quant::matvec_quant_into`]) and the weight's f32 image never
//! materializes.

use anyhow::{anyhow, bail, Result};

use crate::quant::{matmul_quant_rows_into, matvec_quant_into};
use crate::tensor::ops::gelu;
use crate::tensor::Tensor;
use crate::util::telemetry;

use super::model_native::{embed_rows_at, ModelCfg};
use super::quantstore::{QParam, QuantizedParams};
use super::{params_bytes, Params};

/// Read access to model parameters for the decoder: dense views for the
/// small parameters, row-streamed GEMM products for the weights.
///
/// `Sync` is a supertrait: one parameter store is shared by every decode
/// slot, and the serve scheduler fans slots out across worker threads
/// (reads only — nothing here takes `&mut self`).
pub trait ParamSource: Sync {
    /// Dense view of a non-GEMM parameter (embeddings, layernorm affine).
    fn dense(&self, name: &str) -> Result<&Tensor>;

    /// `(rows, cols)` of a GEMM weight.
    fn gemm_dims(&self, name: &str) -> Result<(usize, usize)>;

    /// `out[N] = x[K] @ W[K,N]`. `row_scratch` must be `N` long; the
    /// quantized store decodes weight rows into it, dense sources ignore
    /// it. Accumulation order matches `ops::matmul` row-for-row.
    fn matvec_into(
        &self,
        name: &str,
        x: &[f32],
        out: &mut [f32],
        row_scratch: &mut [f32],
    ) -> Result<()>;

    /// `out[M,N] = x[M,K] @ W[K,N]` over flat row-major slices — the
    /// batched-prefill GEMM. The default implementation runs
    /// [`Self::matvec_into`] once per row, so every source is
    /// bitwise-identical to the single-row path by construction; the
    /// quantized store overrides it with the k-outer
    /// [`crate::quant::matmul_quant_rows_into`] so each weight row
    /// dequantizes once per chunk instead of once per token.
    fn matmul_rows_into(
        &self,
        name: &str,
        x: &[f32],
        rows: usize,
        out: &mut [f32],
        row_scratch: &mut [f32],
    ) -> Result<()> {
        let (k, n) = self.gemm_dims(name)?;
        assert_eq!(x.len(), rows * k);
        assert_eq!(out.len(), rows * n);
        for (xr, or) in x.chunks_exact(k).zip(out.chunks_exact_mut(n)) {
            self.matvec_into(name, xr, or, row_scratch)?;
        }
        Ok(())
    }

    /// Bytes the parameter set occupies resident in memory.
    fn resident_param_bytes(&self) -> usize;
}

/// Dense matvec mirroring `ops::matmul`'s per-row loop (same `aik == 0`
/// skip, same ascending-k accumulation).
fn matvec_dense(x: &[f32], w: &Tensor, out: &mut [f32]) {
    let (k, n) = (w.rows(), w.cols());
    assert_eq!(x.len(), k);
    assert_eq!(out.len(), n);
    out.fill(0.0);
    let wd = w.data();
    for (kk, &aik) in x.iter().enumerate() {
        if aik == 0.0 {
            continue;
        }
        let wrow = &wd[kk * n..(kk + 1) * n];
        for (oj, wj) in out.iter_mut().zip(wrow) {
            *oj += aik * wj;
        }
    }
}

impl ParamSource for Params {
    fn dense(&self, name: &str) -> Result<&Tensor> {
        self.get(name).ok_or_else(|| anyhow!("missing param {name:?}"))
    }

    fn gemm_dims(&self, name: &str) -> Result<(usize, usize)> {
        let t = ParamSource::dense(self, name)?;
        Ok((t.rows(), t.cols()))
    }

    fn matvec_into(
        &self,
        name: &str,
        x: &[f32],
        out: &mut [f32],
        _row_scratch: &mut [f32],
    ) -> Result<()> {
        matvec_dense(x, ParamSource::dense(self, name)?, out);
        Ok(())
    }

    fn resident_param_bytes(&self) -> usize {
        params_bytes(self)
    }
}

impl ParamSource for QuantizedParams {
    fn dense(&self, name: &str) -> Result<&Tensor> {
        QuantizedParams::dense(self, name)
    }

    fn gemm_dims(&self, name: &str) -> Result<(usize, usize)> {
        match self.get(name) {
            Some(QParam::Quant(q)) => Ok(q.shape),
            Some(QParam::Plain(t)) => Ok((t.rows(), t.cols())),
            None => bail!("missing param {name:?}"),
        }
    }

    fn matvec_into(
        &self,
        name: &str,
        x: &[f32],
        out: &mut [f32],
        row_scratch: &mut [f32],
    ) -> Result<()> {
        match self.get(name) {
            Some(QParam::Quant(q)) => {
                matvec_quant_into(x, q, out, row_scratch);
                Ok(())
            }
            Some(QParam::Plain(t)) => {
                matvec_dense(x, t, out);
                Ok(())
            }
            None => bail!("missing param {name:?}"),
        }
    }

    fn matmul_rows_into(
        &self,
        name: &str,
        x: &[f32],
        rows: usize,
        out: &mut [f32],
        row_scratch: &mut [f32],
    ) -> Result<()> {
        match self.get(name) {
            Some(QParam::Quant(q)) => {
                matmul_quant_rows_into(x, rows, q, out, row_scratch);
                Ok(())
            }
            Some(QParam::Plain(t)) => {
                for (xr, or) in x
                    .chunks_exact(t.rows())
                    .zip(out.chunks_exact_mut(t.cols()))
                {
                    matvec_dense(xr, t, or);
                }
                Ok(())
            }
            None => bail!("missing param {name:?}"),
        }
    }

    fn resident_param_bytes(&self) -> usize {
        QuantizedParams::resident_param_bytes(self)
    }
}

/// Single-row layernorm mirroring `ops::layernorm_rows` (same summation
/// order, same `(x-mu)*inv*g + b` expression, eps 1e-5).
fn layernorm_vec(x: &[f32], g: &[f32], b: &[f32], out: &mut [f32]) {
    let n = x.len();
    assert_eq!(g.len(), n);
    assert_eq!(b.len(), n);
    let mu = x.iter().sum::<f32>() / n as f32;
    let var = x.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / n as f32;
    let inv = 1.0 / (var + 1e-5f32).sqrt();
    for j in 0..n {
        out[j] = (x[j] - mu) * inv * g[j] + b[j];
    }
}

/// Single-row softmax mirroring `ops::softmax_rows`.
fn softmax_vec(row: &mut [f32]) {
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for v in row.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    for v in row.iter_mut() {
        *v /= sum;
    }
}

/// Per-request decode state: the position cursor, one K and one V cache
/// per layer (each `pos · d_model` floats), and the fixed-size step
/// scratch — allocated once at session start so a decode step allocates
/// nothing beyond the logits row it returns.
pub struct DecodeSession {
    pos: usize,
    kcache: Vec<Vec<f32>>,
    vcache: Vec<Vec<f32>>,
    // step scratch (sizes fixed by the model config)
    x: Vec<f32>,
    h: Vec<f32>,
    qv: Vec<f32>,
    kv: Vec<f32>,
    vv: Vec<f32>,
    att: Vec<f32>,
    proj: Vec<f32>,
    m: Vec<f32>,
    m2: Vec<f32>,
    scores: Vec<f32>,
    scratch_d: Vec<f32>,
    scratch_ff: Vec<f32>,
    scratch_v: Vec<f32>,
}

impl DecodeSession {
    /// Tokens consumed so far (the next step decodes this position).
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Live cache footprint in bytes (both caches, all layers).
    pub fn cache_bytes(&self) -> usize {
        self.kcache
            .iter()
            .chain(&self.vcache)
            .map(|c| c.len() * 4)
            .sum()
    }
}

/// Canonical parameter names of one transformer block, resolved once at
/// decoder construction — the per-token hot loop must not rebuild name
/// strings (one `format!` per parameter per layer per token adds up to
/// thousands of allocations per request).
struct LayerNames {
    ln1_g: String,
    ln1_b: String,
    wq: String,
    wk: String,
    wv: String,
    wo: String,
    ln2_g: String,
    ln2_b: String,
    w1: String,
    w2: String,
}

/// The incremental decoder: config + parameter source, stateless across
/// sessions so one decoder drives every slot of the serving scheduler.
pub struct Decoder<'p> {
    src: &'p dyn ParamSource,
    pub cfg: ModelCfg,
    layers: Vec<LayerNames>,
    /// Captured once at construction from the builder thread's telemetry
    /// context: `step` is the serving hot loop and must not touch the
    /// registry (or thread-locals) per token.
    steps: telemetry::Counter,
}

impl<'p> Decoder<'p> {
    /// Build a decoder over `src` (dense or quantized-resident params).
    /// Build a decoder over `src` (dense or quantized-resident params).
    pub fn new(src: &'p dyn ParamSource, cfg: ModelCfg) -> Decoder<'p> {
        let layers = (0..cfg.n_layer)
            .map(|l| LayerNames {
                ln1_g: format!("l{l}.ln1.g"),
                ln1_b: format!("l{l}.ln1.b"),
                wq: format!("l{l}.wq"),
                wk: format!("l{l}.wk"),
                wv: format!("l{l}.wv"),
                wo: format!("l{l}.wo"),
                ln2_g: format!("l{l}.ln2.g"),
                ln2_b: format!("l{l}.ln2.b"),
                w1: format!("l{l}.w1"),
                w2: format!("l{l}.w2"),
            })
            .collect();
        let steps = telemetry::current().counter("decode.steps");
        Decoder { src, cfg, layers, steps }
    }

    /// Fresh per-request state: empty KV caches, position 0, scratch
    /// buffers sized for the model.
    /// Fresh per-request state: empty KV caches, position 0, scratch
    /// buffers sized for the model.
    pub fn session(&self) -> DecodeSession {
        let d = self.cfg.d_model;
        DecodeSession {
            pos: 0,
            kcache: vec![Vec::new(); self.cfg.n_layer],
            vcache: vec![Vec::new(); self.cfg.n_layer],
            x: vec![0.0; d],
            h: vec![0.0; d],
            qv: vec![0.0; d],
            kv: vec![0.0; d],
            vv: vec![0.0; d],
            att: vec![0.0; d],
            proj: vec![0.0; d],
            m: vec![0.0; self.cfg.d_ff],
            m2: vec![0.0; d],
            scores: Vec::with_capacity(self.cfg.seq_len),
            scratch_d: vec![0.0; d],
            scratch_ff: vec![0.0; self.cfg.d_ff],
            scratch_v: vec![0.0; self.cfg.vocab],
        }
    }

    /// Consume one token at the session's position cursor and return the
    /// logits row (`vocab` floats) predicting the next token. All
    /// intermediates live in the session's preallocated scratch — the
    /// only allocation per step is the returned logits row.
    pub fn step(&self, s: &mut DecodeSession, token: i32) -> Result<Vec<f32>> {
        let cfg = &self.cfg;
        let (d, dh) = (cfg.d_model, cfg.d_model / cfg.n_head);
        let t = s.pos;
        if t >= cfg.seq_len {
            bail!("decode position {t} beyond seq_len {}", cfg.seq_len);
        }
        let tok = token as usize;
        let embed = self.src.dense("embed")?;
        let pos = self.src.dense("pos")?;
        if token < 0 || tok >= cfg.vocab {
            bail!("token {token} outside vocab {}", cfg.vocab);
        }

        // disjoint borrows of the session's caches + scratch fields
        let DecodeSession {
            pos: s_pos,
            kcache,
            vcache,
            x,
            h,
            qv,
            kv,
            vv,
            att,
            proj,
            m,
            m2,
            scores,
            scratch_d,
            scratch_ff,
            scratch_v,
        } = s;

        // token + positional embedding for this single row
        for (j, xj) in x.iter_mut().enumerate() {
            *xj = embed.at2(tok, j) + pos.at2(t, j);
        }

        let scale = 1.0 / (dh as f32).sqrt();
        for l in 0..cfg.n_layer {
            let names = &self.layers[l];
            // --- attention block ---
            let g1 = self.src.dense(&names.ln1_g)?;
            let b1 = self.src.dense(&names.ln1_b)?;
            layernorm_vec(x, g1.data(), b1.data(), h);
            self.src.matvec_into(&names.wq, h, qv, scratch_d)?;
            self.src.matvec_into(&names.wk, h, kv, scratch_d)?;
            self.src.matvec_into(&names.wv, h, vv, scratch_d)?;
            kcache[l].extend_from_slice(kv);
            vcache[l].extend_from_slice(vv);

            // causal attention of this one query row over the cache; the
            // full forward's masked positions contribute exp(-1e9-max)=0
            // to its softmax sum, so the prefix-only softmax here is
            // bitwise identical
            let kc = &kcache[l];
            let vc = &vcache[l];
            for hd in 0..cfg.n_head {
                scores.clear();
                scores.resize(t + 1, 0.0);
                for (tk, sc) in scores.iter_mut().enumerate() {
                    let mut acc = 0.0f32;
                    let krow = &kc[tk * d..(tk + 1) * d];
                    for j in 0..dh {
                        acc += qv[hd * dh + j] * krow[hd * dh + j];
                    }
                    *sc = acc * scale;
                }
                softmax_vec(scores);
                for j in 0..dh {
                    let mut acc = 0.0f32;
                    for (tk, sc) in scores.iter().enumerate() {
                        acc += sc * vc[tk * d + hd * dh + j];
                    }
                    att[hd * dh + j] = acc;
                }
            }
            self.src.matvec_into(&names.wo, att, proj, scratch_d)?;
            for (xj, pj) in x.iter_mut().zip(proj.iter()) {
                *xj += pj;
            }

            // --- MLP block ---
            let g2 = self.src.dense(&names.ln2_g)?;
            let b2 = self.src.dense(&names.ln2_b)?;
            layernorm_vec(x, g2.data(), b2.data(), h);
            self.src.matvec_into(&names.w1, h, m, scratch_ff)?;
            for v in m.iter_mut() {
                *v = gelu(*v);
            }
            self.src.matvec_into(&names.w2, m, m2, scratch_d)?;
            for (xj, mj) in x.iter_mut().zip(m2.iter()) {
                *xj += mj;
            }
        }

        let gf = self.src.dense("lnf.g")?;
        let bf = self.src.dense("lnf.b")?;
        layernorm_vec(x, gf.data(), bf.data(), h);
        let mut logits = vec![0.0f32; cfg.vocab];
        self.src.matvec_into("head", h, &mut logits, scratch_v)?;
        *s_pos += 1;
        self.steps.incr();
        Ok(logits)
    }

    /// Consume a contiguous run of prompt tokens in one batched forward,
    /// writing all per-layer K/V cache rows in bulk and discarding the
    /// logits — the admission path of the serving scheduler.
    ///
    /// Two wins over replaying [`Self::step`] per token: each weight row
    /// dequantizes/streams **once per chunk** instead of once per token
    /// (the GEMMs run through [`ParamSource::matmul_rows_into`]), and the
    /// final layernorm + vocab-wide head projection are skipped entirely
    /// (they only produce logits, which prefill discards; the K/V state
    /// they read is unaffected).
    ///
    /// The cache rows written are bitwise-identical to `tokens.len()`
    /// successive `step` calls: same embedding expression at the same
    /// absolute positions ([`embed_rows_at`]), same per-row layernorm /
    /// attention / GELU bodies, and per output row the batched GEMM
    /// accumulates in the same ascending-k order as the matvec. Row `i`
    /// of the chunk attends over cache prefix `0..=t0+i` only, exactly as
    /// the sequential replay would.
    pub fn prefill(&self, s: &mut DecodeSession, tokens: &[i32]) -> Result<()> {
        if tokens.is_empty() {
            return Ok(());
        }
        let cfg = &self.cfg;
        let (d, dh) = (cfg.d_model, cfg.d_model / cfg.n_head);
        let c = tokens.len();
        let t0 = s.pos;
        if t0 + c > cfg.seq_len {
            bail!(
                "prefill of {c} tokens at position {t0} beyond seq_len {}",
                cfg.seq_len
            );
        }
        // validate every token before touching the caches: a rejected
        // prefill must leave the session exactly as it was
        for &token in tokens {
            if token < 0 || token as usize >= cfg.vocab {
                bail!("token {token} outside vocab {}", cfg.vocab);
            }
        }
        let embed = self.src.dense("embed")?;
        let pos = self.src.dense("pos")?;

        // chunk-sized working set ([c, d] / [c, d_ff] row-major) — one
        // allocation burst per admitted chunk, not per token
        let mut x = vec![0.0f32; c * d];
        let mut h = vec![0.0f32; c * d];
        let mut qm = vec![0.0f32; c * d];
        let mut km = vec![0.0f32; c * d];
        let mut vm = vec![0.0f32; c * d];
        let mut att = vec![0.0f32; c * d];
        let mut proj = vec![0.0f32; c * d];
        let mut mm = vec![0.0f32; c * cfg.d_ff];
        let mut m2 = vec![0.0f32; c * d];

        embed_rows_at(embed, pos, t0, tokens, &mut x);

        let DecodeSession {
            pos: s_pos,
            kcache,
            vcache,
            scores,
            scratch_d,
            scratch_ff,
            ..
        } = s;

        let scale = 1.0 / (dh as f32).sqrt();
        for l in 0..cfg.n_layer {
            let names = &self.layers[l];
            // --- attention block ---
            let g1 = self.src.dense(&names.ln1_g)?;
            let b1 = self.src.dense(&names.ln1_b)?;
            for (xr, hr) in x.chunks_exact(d).zip(h.chunks_exact_mut(d)) {
                layernorm_vec(xr, g1.data(), b1.data(), hr);
            }
            self.src.matmul_rows_into(&names.wq, &h, c, &mut qm, scratch_d)?;
            self.src.matmul_rows_into(&names.wk, &h, c, &mut km, scratch_d)?;
            self.src.matmul_rows_into(&names.wv, &h, c, &mut vm, scratch_d)?;
            kcache[l].extend_from_slice(&km);
            vcache[l].extend_from_slice(&vm);

            // per-row causal attention over the cache prefix: row i sees
            // positions 0..=t0+i — later rows of this same chunk are in
            // the cache already but stay outside the score range, exactly
            // as if they had not been written yet
            let kc = &kcache[l];
            let vc = &vcache[l];
            for i in 0..c {
                let t = t0 + i;
                let qrow = &qm[i * d..(i + 1) * d];
                for hd in 0..cfg.n_head {
                    scores.clear();
                    scores.resize(t + 1, 0.0);
                    for (tk, sc) in scores.iter_mut().enumerate() {
                        let mut acc = 0.0f32;
                        let krow = &kc[tk * d..(tk + 1) * d];
                        for j in 0..dh {
                            acc += qrow[hd * dh + j] * krow[hd * dh + j];
                        }
                        *sc = acc * scale;
                    }
                    softmax_vec(scores);
                    for j in 0..dh {
                        let mut acc = 0.0f32;
                        for (tk, sc) in scores.iter().enumerate() {
                            acc += sc * vc[tk * d + hd * dh + j];
                        }
                        att[i * d + hd * dh + j] = acc;
                    }
                }
            }
            self.src.matmul_rows_into(&names.wo, &att, c, &mut proj, scratch_d)?;
            for (xj, pj) in x.iter_mut().zip(proj.iter()) {
                *xj += pj;
            }

            // --- MLP block ---
            let g2 = self.src.dense(&names.ln2_g)?;
            let b2 = self.src.dense(&names.ln2_b)?;
            for (xr, hr) in x.chunks_exact(d).zip(h.chunks_exact_mut(d)) {
                layernorm_vec(xr, g2.data(), b2.data(), hr);
            }
            self.src.matmul_rows_into(&names.w1, &h, c, &mut mm, scratch_ff)?;
            for v in mm.iter_mut() {
                *v = gelu(*v);
            }
            self.src.matmul_rows_into(&names.w2, &mm, c, &mut m2, scratch_d)?;
            for (xj, mj) in x.iter_mut().zip(m2.iter()) {
                *xj += mj;
            }
        }
        // no lnf/head: prefill produces cache state, not logits
        *s_pos += c;
        self.steps.add(c as u64);
        Ok(())
    }

    /// Bytes the parameter source keeps resident while serving.
    /// Bytes the parameter source keeps resident while serving.
    pub fn resident_param_bytes(&self) -> usize {
        self.src.resident_param_bytes()
    }
}

/// What the continuous-batching scheduler needs from a decoding engine —
/// exactly the operations [`crate::serve::serve`] calls, no more.
/// Implemented by [`Decoder`] for real models and by mocks in the serve
/// tests.
///
/// Implementors must be `Sync` and their sessions `Send`: the scheduler
/// shares one decoder across its worker threads and hands each slot's
/// session to whichever worker ticks it (one slot is only ever touched by
/// one worker at a time).
pub trait TokenDecoder {
    type Session;

    fn start(&self) -> Self::Session;

    /// Consume one token, return the next-token logits row.
    fn step(&self, s: &mut Self::Session, token: i32) -> Result<Vec<f32>>;

    /// Consume a run of prompt tokens, discarding the logits — the
    /// admission path. The default implementation replays [`Self::step`]
    /// token by token; [`Decoder`] overrides it with a batched forward
    /// that writes the K/V caches in bulk (bitwise-identical cache state,
    /// one weight-row dequantization per chunk instead of per token).
    fn prefill(&self, s: &mut Self::Session, tokens: &[i32]) -> Result<()> {
        for &t in tokens {
            self.step(s, t)?;
        }
        Ok(())
    }

    /// Hard cap on the position cursor (the positional-embedding table).
    fn max_positions(&self) -> usize;

    fn resident_param_bytes(&self) -> usize;
}

impl TokenDecoder for Decoder<'_> {
    type Session = DecodeSession;

    fn start(&self) -> DecodeSession {
        self.session()
    }

    fn step(&self, s: &mut DecodeSession, token: i32) -> Result<Vec<f32>> {
        Decoder::step(self, s, token)
    }

    fn prefill(&self, s: &mut DecodeSession, tokens: &[i32]) -> Result<()> {
        Decoder::prefill(self, s, tokens)
    }

    fn max_positions(&self) -> usize {
        self.cfg.seq_len
    }

    fn resident_param_bytes(&self) -> usize {
        Decoder::resident_param_bytes(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::model_native::{
        forward_native, forward_quant, synth_params, synth_quantized,
    };
    use crate::quant::Granularity;

    fn tiny_cfg() -> ModelCfg {
        ModelCfg { vocab: 16, d_model: 8, n_layer: 2, n_head: 2, d_ff: 16, seq_len: 6 }
    }

    fn gemm_names(cfg: &ModelCfg) -> Vec<String> {
        let mut v = Vec::new();
        for l in 0..cfg.n_layer {
            for w in ["wq", "wk", "wv", "wo", "w1", "w2"] {
                v.push(format!("l{l}.{w}"));
            }
        }
        v.push("head".into());
        v
    }

    #[test]
    fn incremental_decode_is_bitwise_the_full_forward() {
        let cfg = tiny_cfg();
        let params = synth_params(&cfg, 11);
        let tokens = vec![1i32, 5, 3, 9, 2, 7];
        let full = forward_native(&params, &cfg, 1, &tokens).unwrap();
        let dec = Decoder::new(&params, cfg);
        let mut s = dec.session();
        for (t, &tok) in tokens.iter().enumerate() {
            let row = dec.step(&mut s, tok).unwrap();
            assert_eq!(s.pos(), t + 1);
            let want = &full[t * cfg.vocab..(t + 1) * cfg.vocab];
            for (j, (a, b)) in row.iter().zip(want).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "pos {t} logit {j}: {a} vs {b}"
                );
            }
        }
        assert!(s.cache_bytes() > 0);
    }

    #[test]
    fn quantized_decode_is_bitwise_the_quant_forward() {
        let cfg = tiny_cfg();
        let params = synth_params(&cfg, 13);
        let qp = synth_quantized(&params, &gemm_names(&cfg), Granularity::PerChannel);
        let tokens = vec![4i32, 1, 8, 15, 0, 3];
        let full = forward_quant(&qp, &cfg, 1, &tokens).unwrap();
        let dec = Decoder::new(&qp, cfg);
        let mut s = dec.session();
        for (t, &tok) in tokens.iter().enumerate() {
            let row = dec.step(&mut s, tok).unwrap();
            let want = &full[t * cfg.vocab..(t + 1) * cfg.vocab];
            for (a, b) in row.iter().zip(want) {
                assert_eq!(a.to_bits(), b.to_bits(), "pos {t}");
            }
        }
        // and the quantized store is what the decoder reports resident
        assert_eq!(
            TokenDecoder::resident_param_bytes(&dec),
            QuantizedParams::resident_param_bytes(&qp)
        );
    }

    #[test]
    fn batched_prefill_is_bitwise_token_by_token() {
        let cfg = tiny_cfg();
        let params = synth_params(&cfg, 23);
        let qp = synth_quantized(&params, &gemm_names(&cfg), Granularity::Block(4));
        let tokens = vec![2i32, 9, 4, 1, 11];
        let last = 6i32;
        let sources: [&dyn ParamSource; 2] = [&params, &qp];
        for (si, src) in sources.iter().enumerate() {
            let dec = Decoder::new(*src, cfg);
            // reference: token-by-token replay
            let mut s_ref = dec.session();
            for &tok in &tokens {
                dec.step(&mut s_ref, tok).unwrap();
            }
            let want = dec.step(&mut s_ref, last).unwrap();
            // batched, split across two chunks so the second starts at a
            // nonzero position cursor
            let mut s_bat = dec.session();
            dec.prefill(&mut s_bat, &tokens[..3]).unwrap();
            assert_eq!(s_bat.pos(), 3);
            dec.prefill(&mut s_bat, &tokens[3..]).unwrap();
            assert_eq!(s_bat.pos(), tokens.len());
            assert_eq!(s_bat.cache_bytes(), {
                let mut s2 = dec.session();
                for &tok in &tokens {
                    dec.step(&mut s2, tok).unwrap();
                }
                s2.cache_bytes()
            });
            let got = dec.step(&mut s_bat, last).unwrap();
            for (j, (a, b)) in got.iter().zip(&want).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "src {si} logit {j}");
            }
        }
    }

    #[test]
    fn prefill_validates_before_touching_the_session() {
        let cfg = tiny_cfg();
        let params = synth_params(&cfg, 29);
        let dec = Decoder::new(&params, cfg);
        let mut s = dec.session();
        // bad token anywhere in the chunk: rejected, session untouched
        assert!(dec.prefill(&mut s, &[1, 2, -1]).is_err());
        assert!(dec.prefill(&mut s, &[1, 2, cfg.vocab as i32]).is_err());
        assert_eq!(s.pos(), 0);
        assert_eq!(s.cache_bytes(), 0);
        // overlong chunk: rejected up front
        let long = vec![1i32; cfg.seq_len + 1];
        let err = dec.prefill(&mut s, &long).unwrap_err();
        assert!(format!("{err:#}").contains("seq_len"), "{err:#}");
        assert_eq!(s.pos(), 0);
        // empty chunk is a no-op
        dec.prefill(&mut s, &[]).unwrap();
        assert_eq!(s.pos(), 0);
    }

    #[test]
    fn cursor_is_bounded_by_the_position_table() {
        let cfg = tiny_cfg();
        let params = synth_params(&cfg, 17);
        let dec = Decoder::new(&params, cfg);
        let mut s = dec.session();
        for t in 0..cfg.seq_len {
            dec.step(&mut s, (t % cfg.vocab) as i32).unwrap();
        }
        let err = dec.step(&mut s, 0).unwrap_err();
        assert!(format!("{err:#}").contains("seq_len"), "{err:#}");
    }

    #[test]
    fn bad_token_is_an_error() {
        let cfg = tiny_cfg();
        let params = synth_params(&cfg, 19);
        let dec = Decoder::new(&params, cfg);
        let mut s = dec.session();
        assert!(dec.step(&mut s, -1).is_err());
        assert!(dec.step(&mut s, cfg.vocab as i32).is_err());
        // failed steps must not advance the cursor
        assert_eq!(s.pos(), 0);
    }
}
