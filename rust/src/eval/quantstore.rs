//! Quantized-resident parameter store — the serving-path counterpart of
//! [`load_params_dequant_source`](super::load_params_dequant_source) that
//! *keeps* the compression: every `<name>.codes` / `<name>.scales` pair
//! loads as a [`QuantizedTensor`] (1 byte/element + compact scales) and
//! stays that way for the life of the process, dequantizing row-by-row
//! inside the fused dequant-matmul ([`crate::quant::matmul_quant`]) as the
//! forward consumes it. Parameters without sidecars (embeddings, layernorm
//! affines, biases) load as plain f32 — they are small and the forward
//! needs them dense.
//!
//! Loads from any [`TensorSource`] backend — the in-memory [`Dts`]
//! container, a seek-based monolithic file, or the sharded stores the
//! streaming pipeline writes — and never materializes an f32 copy of a
//! quantized weight at load time.

use std::collections::{BTreeMap, HashMap};

use anyhow::{anyhow, bail, Result};

use crate::io::dts::DtsTensor;
use crate::io::TensorSource;
use crate::quant::{
    CodeFormat, Descriptor, Granularity, LowRank, QuantizedTensor, ScaleGrid,
};
use crate::tensor::Tensor;

use super::Params;

/// One resident parameter: compact storage form for quantized weights,
/// dense f32 for everything else.
pub enum QParam {
    Quant(QuantizedTensor),
    Plain(Tensor),
}

impl QParam {
    /// Logical element count.
    pub fn numel(&self) -> usize {
        match self {
            QParam::Quant(q) => q.shape.0 * q.shape.1,
            QParam::Plain(t) => t.len(),
        }
    }

    /// Bytes this parameter actually occupies in memory.
    pub fn resident_bytes(&self) -> usize {
        match self {
            QParam::Quant(q) => q.nbytes(),
            QParam::Plain(t) => t.len() * 4,
        }
    }

    /// Bytes a dense f32 copy would occupy (the `load_params_dequant`
    /// footprint this store avoids).
    pub fn f32_bytes(&self) -> usize {
        self.numel() * 4
    }
}

/// A loaded model checkpoint with quantized weights kept quantized.
#[derive(Default)]
pub struct QuantizedParams {
    map: HashMap<String, QParam>,
}

impl QuantizedParams {
    /// An empty store (populate with [`QuantizedParams::insert`]).
    pub fn new() -> Self {
        Self::default()
    }

    /// Load from any checkpoint backend. Mirrors the dequantizing
    /// loader's name derivation exactly: a `.codes`/`.scales`
    /// (`.res_u`/`.res_v`) suffix only counts as a sidecar when the
    /// `.codes` counterpart exists, codes-only checkpoints (no stored f32
    /// copy) load fine, and codes with neither a `fmt.<name>` descriptor
    /// nor the legacy `gran.<name>` metadata fall back to the stored f32
    /// copy (pre-metadata checkpoints).
    ///
    /// The per-tensor [`Descriptor`] (`fmt.<name>`) is the source of
    /// truth for format, granularity, residual rank, and — for sub-byte
    /// formats, whose packed codes shape is ambiguous — the logical
    /// column count. Legacy stores carrying only `gran.<name>` load
    /// through a compat shim as FP8 E4M3 without a residual.
    pub fn load(d: &dyn TensorSource) -> Result<QuantizedParams> {
        let mut map = HashMap::new();
        let mut names: Vec<String> = Vec::new();
        let mut seen = std::collections::BTreeSet::new();
        for name in d.names() {
            let base = if let Some(stem) = name.strip_suffix(".codes") {
                if d.contains(&format!("{stem}.scales")) {
                    stem.to_string()
                } else {
                    name.clone()
                }
            } else if let Some(stem) = name.strip_suffix(".scales") {
                if d.contains(&format!("{stem}.codes")) {
                    continue;
                }
                name.clone()
            } else if let Some(stem) = name
                .strip_suffix(".res_u")
                .or_else(|| name.strip_suffix(".res_v"))
            {
                // residual factor sidecars load with their quantized owner
                if d.contains(&format!("{stem}.codes")) {
                    continue;
                }
                name.clone()
            } else {
                name.clone()
            };
            if seen.insert(base.clone()) {
                names.push(base);
            }
        }
        for name in &names {
            let codes_name = format!("{name}.codes");
            let scales_name = format!("{name}.scales");
            let has_codes = d.contains(&codes_name);
            let desc = Self::descriptor_for(d, name)?;
            if has_codes && d.contains(&scales_name) && desc.is_some() {
                let desc = desc.expect("checked");
                let fmt = desc.format;
                let (cshape, codes) = d.tensor_u8(&codes_name)?;
                if cshape.len() != 2 {
                    bail!("{codes_name}: expected 2-D codes, got {cshape:?}");
                }
                let rows = cshape[0];
                // logical columns: the descriptor's for sub-byte formats
                // (the packed shape can't distinguish 2n from 2n−1), the
                // codes shape for byte-wide ones
                let cols = match desc.cols {
                    Some(c) => {
                        if fmt.packed_row_bytes(c) != cshape[1] {
                            bail!(
                                "{codes_name}: packed shape {cshape:?} does not \
                                 match cols={c} of format {}",
                                fmt.label()
                            );
                        }
                        c
                    }
                    None if fmt.is_sub_byte() => bail!(
                        "{name}: sub-byte format {} requires a cols field in \
                         its fmt.{name} descriptor",
                        fmt.label()
                    ),
                    None => cshape[1],
                };
                let scales = d.tensor_f32(&scales_name)?.into_data();
                let grid = ScaleGrid::from_sidecar(desc.granularity, rows, cols, scales)
                    .map_err(|e| anyhow!("{name}: {e}"))?
                    .with_format(fmt);
                let residual =
                    Self::load_residual(d, name, desc.residual_rank, rows, cols)?;
                let q = QuantizedTensor {
                    shape: (rows, cols),
                    codes,
                    scales: grid,
                    residual,
                };
                map.insert(name.clone(), QParam::Quant(q));
            } else {
                match d.read_tensor(name) {
                    // pre-metadata checkpoints (codes but no `fmt.<name>` /
                    // `gran.<name>` meta) and plain tensors: use the stored
                    // f32 copy
                    Ok(DtsTensor::F32 { shape, data }) => {
                        map.insert(name.clone(), QParam::Plain(Tensor::new(shape, data)));
                    }
                    // non-f32 extras (token tables etc.) are skipped — unless
                    // codes exist, in which case a silently missing weight
                    // would fail far from here
                    Ok(_) if !has_codes => {}
                    Err(e) if !has_codes => {
                        // file-backed sources can fail mid-read (truncated
                        // shard, unreadable file): propagate, never drop a
                        // parameter silently
                        return Err(e);
                    }
                    Ok(_) | Err(_) => bail!(
                        "{name}: {codes_name} present but cannot dequantize \
                         (missing {scales_name} or fmt.{name} metadata) and no \
                         f32 copy is stored"
                    ),
                }
            }
        }
        Ok(QuantizedParams { map })
    }

    /// Resolve the per-tensor store descriptor: the structured
    /// `fmt.<name>` value when present, else the legacy `gran.<name>`
    /// label shimmed to FP8 E4M3 / rank 0, else `None` (not quantized, or
    /// a pre-metadata store).
    fn descriptor_for(d: &dyn TensorSource, name: &str) -> Result<Option<Descriptor>> {
        if let Some(s) = d.meta().get(&format!("fmt.{name}")) {
            return Descriptor::parse(s)
                .map(Some)
                .map_err(|e| anyhow!("{name}: {e}"));
        }
        match d.meta().get(&format!("gran.{name}")) {
            Some(g) => Ok(Some(Descriptor {
                format: CodeFormat::Fp8E4m3,
                granularity: Granularity::parse(g).map_err(|e| anyhow!("{name}: {e}"))?,
                residual_rank: 0,
                cols: None,
            })),
            None => Ok(None),
        }
    }

    /// Load the `.res_u` / `.res_v` factor pair a descriptor of rank > 0
    /// promises, validating factor shapes against the logical dims.
    fn load_residual(
        d: &dyn TensorSource,
        name: &str,
        k: usize,
        rows: usize,
        cols: usize,
    ) -> Result<Option<LowRank>> {
        if k == 0 {
            return Ok(None);
        }
        let u = d.tensor_f32(&format!("{name}.res_u"))?;
        let v = d.tensor_f32(&format!("{name}.res_v"))?;
        if u.shape() != [rows, k] {
            bail!("{name}.res_u: shape {:?}, wanted [{rows}, {k}]", u.shape());
        }
        if v.shape() != [k, cols] {
            bail!("{name}.res_v: shape {:?}, wanted [{k}, {cols}]", v.shape());
        }
        Ok(Some(LowRank { k, u: u.into_data(), v: v.into_data() }))
    }

    /// Build from a pipeline outcome's in-memory results: storage-form
    /// tensors where the pipeline quantized, plain f32 for the rest —
    /// `daq serve --quantized` without a `--store` goes through this.
    pub fn from_pipeline(
        params: &Params,
        quantized: &BTreeMap<String, QuantizedTensor>,
    ) -> QuantizedParams {
        let mut map = HashMap::new();
        for (name, t) in params {
            match quantized.get(name) {
                Some(q) => map.insert(name.clone(), QParam::Quant(q.clone())),
                None => map.insert(name.clone(), QParam::Plain(t.clone())),
            };
        }
        QuantizedParams { map }
    }

    /// Insert (or replace) one named parameter.
    pub fn insert(&mut self, name: impl Into<String>, p: QParam) {
        self.map.insert(name.into(), p);
    }

    /// Look up one parameter by name.
    pub fn get(&self, name: &str) -> Option<&QParam> {
        self.map.get(name)
    }

    /// Whether a parameter with this name exists.
    pub fn contains(&self, name: &str) -> bool {
        self.map.contains_key(name)
    }

    /// Number of stored parameters (quantized + plain).
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Whether the store holds no parameters.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Dense view of a parameter the forward needs as plain f32
    /// (embeddings, layernorm affines). Refusing to silently dequantize a
    /// weight here is what keeps the resident-memory guarantee honest:
    /// GEMM weights must flow through the fused dequant-matmul instead.
    pub fn dense(&self, name: &str) -> Result<&Tensor> {
        match self.map.get(name) {
            Some(QParam::Plain(t)) => Ok(t),
            Some(QParam::Quant(_)) => bail!(
                "param {name:?} is quantized but the op needs a dense tensor \
                 (only GEMM weights may be quantized-resident)"
            ),
            None => bail!("missing param {name:?}"),
        }
    }

    /// Number of quantized (storage-form) parameters.
    pub fn n_quantized(&self) -> usize {
        self.map
            .values()
            .filter(|p| matches!(p, QParam::Quant(_)))
            .count()
    }

    /// Bytes the parameter set actually occupies resident in memory.
    pub fn resident_param_bytes(&self) -> usize {
        self.map.values().map(|p| p.resident_bytes()).sum()
    }

    /// Bytes the dequantized-f32 load path would occupy for the same set.
    pub fn f32_param_bytes(&self) -> usize {
        self.map.values().map(|p| p.f32_bytes()).sum()
    }

    /// Expand to a dense parameter map — the equality-test bridge to the
    /// f32 loaders, *not* a serving path (it materializes everything this
    /// store exists to avoid).
    pub fn dequantize_all(&self) -> Params {
        let mut p = Params::new();
        for (name, v) in &self.map {
            let t = match v {
                QParam::Quant(q) => q.dequantize(),
                QParam::Plain(t) => t.clone(),
            };
            p.insert(name.clone(), t);
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::dts::Dts;
    use crate::quant::quantize;
    use crate::util::rng::XorShift;

    fn quantized_ckpt() -> (Dts, Tensor) {
        let mut rng = XorShift::new(41);
        let w = Tensor::new(vec![8, 12], rng.normal_vec(96, 0.1));
        let q = quantize(&w, Granularity::PerChannel, 1.0);
        let mut d = Dts::new();
        d.meta.insert("gran.w".into(), "channel".into());
        d.insert(
            "w.codes",
            DtsTensor::U8 { shape: vec![8, 12], data: q.codes.clone() },
        );
        d.insert(
            "w.scales",
            DtsTensor::F32 {
                shape: vec![q.scales.grid_rows, q.scales.grid_cols],
                data: q.scales.scales.clone(),
            },
        );
        d.insert_f32("ln.g", &Tensor::full(vec![1, 12], 1.0));
        (d, q.dequantize())
    }

    #[test]
    fn load_keeps_codes_resident_and_agrees_with_dequant_loader() {
        let (d, want_w) = quantized_ckpt();
        let qp = QuantizedParams::load(&d).unwrap();
        assert_eq!(qp.n_quantized(), 1);
        assert!(matches!(qp.get("w"), Some(QParam::Quant(_))));
        assert!(matches!(qp.get("ln.g"), Some(QParam::Plain(_))));
        // resident bytes: 96 codes + 12 channel scales * 4 + 12 plain * 4
        assert_eq!(qp.resident_param_bytes(), 96 + 12 * 4 + 12 * 4);
        assert_eq!(qp.f32_param_bytes(), 96 * 4 + 12 * 4);
        // the dense bridge agrees bitwise with the dequantizing loader
        let deq = qp.dequantize_all();
        let via_loader = crate::eval::load_params_dequant(&d).unwrap();
        assert_eq!(deq.len(), via_loader.len());
        for (a, b) in deq["w"].data().iter().zip(via_loader["w"].data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in deq["w"].data().iter().zip(want_w.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn dense_refuses_quantized_weights() {
        let (d, _) = quantized_ckpt();
        let qp = QuantizedParams::load(&d).unwrap();
        assert!(qp.dense("ln.g").is_ok());
        let err = qp.dense("w").unwrap_err();
        assert!(format!("{err:#}").contains("quantized"), "{err:#}");
        assert!(qp.dense("nope").is_err());
    }

    #[test]
    fn codes_without_gran_meta_fall_back_to_stored_f32() {
        let mut rng = XorShift::new(43);
        let w = Tensor::new(vec![4, 4], rng.normal_vec(16, 0.1));
        let q = quantize(&w, Granularity::PerTensor, 1.0);
        let mut d = Dts::new();
        // codes + scales but NO gran meta, WITH an f32 copy: pre-metadata
        // checkpoint — the f32 copy must win, resident as plain f32
        d.insert_f32("w", &w);
        d.insert(
            "w.codes",
            DtsTensor::U8 { shape: vec![4, 4], data: q.codes.clone() },
        );
        d.insert(
            "w.scales",
            DtsTensor::F32 { shape: vec![1, 1], data: q.scales.scales.clone() },
        );
        let qp = QuantizedParams::load(&d).unwrap();
        assert_eq!(qp.n_quantized(), 0);
        match qp.get("w") {
            Some(QParam::Plain(t)) => {
                for (a, b) in t.data().iter().zip(w.data()) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!(
                "expected plain fallback, got {:?}",
                other.map(|p| p.numel())
            ),
        }
    }

    #[test]
    fn fmt_descriptor_store_loads_every_format_with_residual() {
        use crate::quant::quantize_fmt;
        let mut rng = XorShift::new(53);
        // odd column count: exercises the packed-shape/cols disambiguation
        let w = Tensor::new(vec![9, 13], rng.normal_vec(9 * 13, 0.1));
        for fmt in [
            CodeFormat::Fp8E4m3,
            CodeFormat::Fp8E5m2,
            CodeFormat::Int4 { group: 4 },
        ] {
            let q = quantize_fmt(&w, Granularity::Block(4), fmt, 1.0, 2);
            let lr = q.residual.as_ref().unwrap();
            let mut d = Dts::new();
            d.meta.insert("fmt.w".into(), Descriptor::for_tensor(&q).to_meta());
            d.insert(
                "w.codes",
                DtsTensor::U8 {
                    shape: vec![9, fmt.packed_row_bytes(13)],
                    data: q.codes.clone(),
                },
            );
            d.insert(
                "w.scales",
                DtsTensor::F32 {
                    shape: vec![q.scales.grid_rows, q.scales.grid_cols],
                    data: q.scales.scales.clone(),
                },
            );
            d.insert(
                "w.res_u",
                DtsTensor::F32 { shape: vec![9, lr.k], data: lr.u.clone() },
            );
            d.insert(
                "w.res_v",
                DtsTensor::F32 { shape: vec![lr.k, 13], data: lr.v.clone() },
            );
            let qp = QuantizedParams::load(&d).unwrap();
            assert_eq!(qp.n_quantized(), 1, "{}", fmt.label());
            // factor sidecars never surface as standalone params
            assert!(!qp.contains("w.res_u") && !qp.contains("w.res_v"));
            assert_eq!(qp.resident_param_bytes(), q.nbytes(), "{}", fmt.label());
            let got = match qp.get("w") {
                Some(QParam::Quant(g)) => g,
                other => panic!("{}: {:?}", fmt.label(), other.map(|p| p.numel())),
            };
            assert_eq!(got.format(), fmt);
            assert_eq!(got.residual.as_ref().unwrap().k, 2);
            for (a, b) in got.dequantize().data().iter().zip(q.dequantize().data()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{}", fmt.label());
            }
        }
    }

    #[test]
    fn sub_byte_store_without_cols_is_rejected() {
        use crate::quant::quantize_fmt;
        let mut rng = XorShift::new(59);
        let w = Tensor::new(vec![4, 6], rng.normal_vec(24, 0.1));
        let fmt = CodeFormat::Int4 { group: 2 };
        let q = quantize_fmt(&w, Granularity::Block(2), fmt, 1.0, 0);
        let mut d = Dts::new();
        // descriptor is missing the mandatory cols field for a sub-byte fmt
        d.meta.insert("fmt.w".into(), "int4:2;block2".into());
        d.insert(
            "w.codes",
            DtsTensor::U8 { shape: vec![4, 3], data: q.codes.clone() },
        );
        d.insert(
            "w.scales",
            DtsTensor::F32 {
                shape: vec![q.scales.grid_rows, q.scales.grid_cols],
                data: q.scales.scales.clone(),
            },
        );
        let err = QuantizedParams::load(&d).unwrap_err();
        assert!(format!("{err:#}").contains("cols"), "{err:#}");
    }

    #[test]
    fn from_pipeline_prefers_storage_form() {
        let mut rng = XorShift::new(47);
        let w = Tensor::new(vec![6, 6], rng.normal_vec(36, 0.1));
        let q = quantize(&w, Granularity::PerChannel, 1.0);
        let mut params = Params::new();
        params.insert("w".into(), q.dequantize());
        params.insert("b".into(), Tensor::zeros(vec![1, 6]));
        let mut quantized = BTreeMap::new();
        quantized.insert("w".to_string(), q);
        let qp = QuantizedParams::from_pipeline(&params, &quantized);
        assert!(matches!(qp.get("w"), Some(QParam::Quant(_))));
        assert!(matches!(qp.get("b"), Some(QParam::Plain(_))));
        assert!(qp.resident_param_bytes() < qp.f32_param_bytes());
    }
}
