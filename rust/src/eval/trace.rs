//! Dataflow tracing over checkpoint tensors — the structure source for
//! transform grouping.
//!
//! The transform baselines (SmoothQuant / AWQ) fold the inverse smoothing
//! vector into the *upstream layernorm*, so grouping GEMMs correctly
//! requires knowing which layernorm actually feeds each GEMM. The name
//! patterns in [`crate::coordinator::group::upstream_ln`] guess this from
//! the model naming convention; this module derives it from the model's
//! real dataflow instead: it re-runs the shared forward body
//! ([`forward_with`](super::model_native::forward_with)) under a
//! **shape-only backend** whose handles are value ids, recording one
//! [`OpNode`] per operation. No payload is ever read — tracing is
//! index-only, exactly like the group planner's other validations.
//!
//! Checkpoints whose tensors are named differently (the renamed-tensor
//! case the patterns cannot group) declare their naming through
//! `layout.<role> = <actual name>` metadata entries ([`Layout`]); the
//! layout only *locates* tensors — which layernorm couples to which GEMM,
//! and whether a layernorm is foldable at all, comes from the graph.
//!
//! The traced graph persists as a DTS sidecar (`graph.dts`, written by
//! `daq trace`) carrying a fingerprint of the checkpoint index, so
//! streaming runs can load groups index-only without re-tracing and a
//! stale sidecar is rejected instead of silently mis-grouping.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::io::dts::Dts;
use crate::io::TensorSource;

use super::model_native::{forward_with, Backend, ModelCfg};

/// A value in the traced graph: checkpoint tensors are leaves, every op
/// output is a fresh id.
pub type ValueId = u32;

/// Operation kinds the forward is built from (one per [`Backend`] op).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    Embed,
    Layernorm,
    Matmul,
    Attention,
    Add,
    Gelu,
}

impl OpKind {
    pub fn label(self) -> &'static str {
        match self {
            OpKind::Embed => "embed",
            OpKind::Layernorm => "layernorm",
            OpKind::Matmul => "matmul",
            OpKind::Attention => "attention",
            OpKind::Add => "add",
            OpKind::Gelu => "gelu",
        }
    }

    fn code(self) -> i32 {
        match self {
            OpKind::Embed => 0,
            OpKind::Layernorm => 1,
            OpKind::Matmul => 2,
            OpKind::Attention => 3,
            OpKind::Add => 4,
            OpKind::Gelu => 5,
        }
    }

    fn from_code(c: i32) -> Result<OpKind> {
        Ok(match c {
            0 => OpKind::Embed,
            1 => OpKind::Layernorm,
            2 => OpKind::Matmul,
            3 => OpKind::Attention,
            4 => OpKind::Add,
            5 => OpKind::Gelu,
            other => bail!("graph sidecar: unknown op kind code {other}"),
        })
    }
}

/// One traced operation: `inputs` → `output` (value ids).
///
/// Input conventions (fixed by the [`Backend`] trait):
/// - `Matmul`: `[activation, weight]` — the weight is always input 1;
/// - `Layernorm`: `[x, gain, bias]`;
/// - `Embed`: `[embedding, positional]`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct OpNode {
    pub kind: OpKind,
    pub inputs: Vec<ValueId>,
    pub output: ValueId,
}

/// The traced producer→consumer graph over one checkpoint.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TraceGraph {
    /// Checkpoint tensor name (as stored, post-layout) → leaf value id.
    pub leaves: BTreeMap<String, ValueId>,
    /// Operations in execution order.
    pub ops: Vec<OpNode>,
    /// [`fingerprint`] of the checkpoint index the trace was taken from.
    pub fingerprint: u64,
}

impl TraceGraph {
    /// Name of the leaf holding `vid`, if `vid` is a checkpoint tensor.
    pub fn leaf_name(&self, vid: ValueId) -> Option<&str> {
        self.leaves
            .iter()
            .find_map(|(n, &v)| (v == vid).then(|| n.as_str()))
    }

    /// The op that produced `vid` (None for leaves).
    pub fn producer(&self, vid: ValueId) -> Option<&OpNode> {
        self.ops.iter().find(|o| o.output == vid)
    }

    /// Every op consuming `vid` as an input.
    pub fn consumers(&self, vid: ValueId) -> Vec<&OpNode> {
        self.ops.iter().filter(|o| o.inputs.contains(&vid)).collect()
    }

    /// Checkpoint tensors consumed as GEMM weights (matmul input 1), in
    /// first-use order — the graph's answer to "what is quantizable",
    /// with no name patterns involved.
    pub fn quantizable(&self) -> Vec<String> {
        let by_vid: BTreeMap<ValueId, &str> =
            self.leaves.iter().map(|(n, &v)| (v, n.as_str())).collect();
        let mut out: Vec<String> = Vec::new();
        for op in &self.ops {
            if op.kind != OpKind::Matmul {
                continue;
            }
            if let Some(name) = op.inputs.get(1).and_then(|v| by_vid.get(v)) {
                if !out.iter().any(|n| n == name) {
                    out.push(name.to_string());
                }
            }
        }
        out
    }

    /// Op counts by kind, for `daq inspect`.
    pub fn op_histogram(&self) -> BTreeMap<&'static str, usize> {
        let mut h = BTreeMap::new();
        for op in &self.ops {
            *h.entry(op.kind.label()).or_insert(0) += 1;
        }
        h
    }

    // -- DTS sidecar ---------------------------------------------------

    /// Serialize into an in-memory DTS container: op arrays as i32
    /// tensors, leaf bindings and the fingerprint as metadata.
    pub fn to_dts(&self) -> Dts {
        let mut d = Dts::new();
        d.meta.insert("daq.graph".into(), "1".into());
        d.meta.insert(
            "daq.graph.fingerprint".into(),
            format!("{:016x}", self.fingerprint),
        );
        for (name, vid) in &self.leaves {
            d.meta.insert(format!("leaf.{name}"), vid.to_string());
        }
        let kinds: Vec<i32> = self.ops.iter().map(|o| o.kind.code()).collect();
        let outs: Vec<i32> = self.ops.iter().map(|o| o.output as i32).collect();
        let in_len: Vec<i32> = self.ops.iter().map(|o| o.inputs.len() as i32).collect();
        let ins: Vec<i32> = self
            .ops
            .iter()
            .flat_map(|o| o.inputs.iter().map(|&v| v as i32))
            .collect();
        d.insert_i32("ops.kind", vec![kinds.len()], kinds);
        d.insert_i32("ops.out", vec![outs.len()], outs);
        d.insert_i32("ops.in_len", vec![in_len.len()], in_len);
        d.insert_i32("ops.in", vec![ins.len()], ins);
        d
    }

    /// Decode a sidecar container written by [`TraceGraph::to_dts`].
    pub fn from_dts(d: &Dts) -> Result<TraceGraph> {
        if d.meta.get("daq.graph").map(|v| v.as_str()) != Some("1") {
            bail!("not a daq graph sidecar (missing `daq.graph = 1` metadata)");
        }
        let fingerprint = d
            .meta
            .get("daq.graph.fingerprint")
            .and_then(|v| u64::from_str_radix(v, 16).ok())
            .ok_or_else(|| anyhow!("graph sidecar: bad or missing fingerprint"))?;
        let mut leaves = BTreeMap::new();
        for (k, v) in &d.meta {
            if let Some(name) = k.strip_prefix("leaf.") {
                let vid: ValueId = v
                    .parse()
                    .map_err(|_| anyhow!("graph sidecar: bad leaf id for {name:?}"))?;
                leaves.insert(name.to_string(), vid);
            }
        }
        let (_, kinds) = d.tensor_i32("ops.kind")?;
        let (_, outs) = d.tensor_i32("ops.out")?;
        let (_, in_len) = d.tensor_i32("ops.in_len")?;
        let (_, ins) = d.tensor_i32("ops.in")?;
        if kinds.len() != outs.len() || kinds.len() != in_len.len() {
            bail!("graph sidecar: op array lengths disagree");
        }
        // corrupt files must error, not panic on an `as usize` underflow
        if outs.iter().chain(&in_len).chain(&ins).any(|&v| v < 0) {
            bail!("graph sidecar: negative op array entry");
        }
        let total: usize = in_len.iter().map(|&n| n as usize).sum();
        if total != ins.len() {
            bail!("graph sidecar: ops.in has {} ids, index wants {total}", ins.len());
        }
        let mut ops = Vec::with_capacity(kinds.len());
        let mut cursor = 0usize;
        for i in 0..kinds.len() {
            let n = in_len[i] as usize;
            ops.push(OpNode {
                kind: OpKind::from_code(kinds[i])?,
                inputs: ins[cursor..cursor + n].iter().map(|&v| v as u32).collect(),
                output: outs[i] as u32,
            });
            cursor += n;
        }
        Ok(TraceGraph { leaves, ops, fingerprint })
    }

    /// Write the sidecar file.
    pub fn write_sidecar(&self, path: impl AsRef<Path>) -> Result<()> {
        self.to_dts().write(path)
    }

    /// Read a sidecar file written by [`TraceGraph::write_sidecar`].
    pub fn read_sidecar(path: impl AsRef<Path>) -> Result<TraceGraph> {
        let path = path.as_ref();
        let d = Dts::read(path).with_context(|| format!("graph sidecar {path:?}"))?;
        TraceGraph::from_dts(&d).with_context(|| format!("{path:?}"))
    }
}

/// Default sidecar location for a checkpoint path: `<stem>.graph.dts`
/// next to a monolithic file, `graph.dts` inside a sharded store.
pub fn sidecar_path(ckpt: &str) -> PathBuf {
    let p = Path::new(ckpt);
    if p.is_dir() {
        p.join("graph.dts")
    } else if ckpt.ends_with(".json") {
        p.parent().unwrap_or_else(|| Path::new(".")).join("graph.dts")
    } else {
        p.with_extension("graph.dts")
    }
}

/// Order-independent fingerprint of everything a trace is derived from:
/// FNV-1a over the sorted (name, shape) pairs of the checkpoint index
/// plus the trace-relevant metadata (the model config keys and every
/// `layout.*` entry). Payload-free, stable across the monolithic /
/// sharded backends, and it changes whenever a tensor is added,
/// removed, renamed, or reshaped — or the layout / model config is
/// edited — the staleness signal for persisted graph sidecars.
pub fn fingerprint(source: &dyn TensorSource) -> u64 {
    let mut names = source.names();
    names.sort();
    let mut h: u64 = 0xcbf29ce484222325;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    };
    for name in &names {
        eat(name.as_bytes());
        eat(&[0xff]);
        for d in source.shape_of(name).unwrap_or_default() {
            eat(&(d as u64).to_le_bytes());
        }
        eat(&[0xfe]);
    }
    // metadata the traced graph depends on: editing the layout role map
    // or the model config invalidates a recorded graph even when no
    // tensor changed (BTreeMap iteration is already sorted)
    for (k, v) in source.meta() {
        let relevant = k.starts_with("layout.")
            || matches!(
                k.as_str(),
                "vocab" | "d_model" | "n_layer" | "n_head" | "d_ff" | "seq_len"
            );
        if relevant {
            eat(k.as_bytes());
            eat(&[0xfd]);
            eat(v.as_bytes());
            eat(&[0xfc]);
        }
    }
    h
}

/// Role → stored-name mapping for checkpoints that do not follow the
/// canonical naming, declared as `layout.<role> = <actual>` metadata
/// entries (analogous to a weight map in an HF index). Roles without an
/// entry resolve to themselves.
#[derive(Clone, Debug, Default)]
pub struct Layout {
    map: BTreeMap<String, String>,
}

impl Layout {
    pub fn from_meta(meta: &BTreeMap<String, String>) -> Layout {
        let map = meta
            .iter()
            .filter_map(|(k, v)| {
                k.strip_prefix("layout.").map(|role| (role.to_string(), v.clone()))
            })
            .collect();
        Layout { map }
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// The stored tensor name for a canonical role.
    pub fn resolve(&self, role: &str) -> String {
        self.map.get(role).cloned().unwrap_or_else(|| role.to_string())
    }
}

/// Shape-only handle flowing through the [`TraceBackend`].
#[derive(Clone, Debug)]
pub struct TracedVal {
    pub vid: ValueId,
    pub shape: Vec<usize>,
}

fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

fn cols2(shape: &[usize], what: &str) -> Result<(usize, usize)> {
    match shape {
        [r, c] => Ok((*r, *c)),
        other => bail!("trace: {what} has shape {other:?}, wanted 2-D"),
    }
}

/// Records the dataflow graph while checking shapes from the checkpoint
/// index — an invalid checkpoint (missing tensor, dimension mismatch)
/// fails the trace with the offending op, before any payload is read.
pub struct TraceBackend<'s> {
    source: &'s dyn TensorSource,
    layout: Layout,
    leaves: BTreeMap<String, ValueId>,
    ops: Vec<OpNode>,
    next: ValueId,
}

impl<'s> TraceBackend<'s> {
    pub fn new(source: &'s dyn TensorSource, layout: Layout) -> TraceBackend<'s> {
        TraceBackend { source, layout, leaves: BTreeMap::new(), ops: Vec::new(), next: 0 }
    }

    fn fresh(&mut self) -> ValueId {
        let v = self.next;
        self.next += 1;
        v
    }

    fn op(&mut self, kind: OpKind, inputs: Vec<ValueId>, shape: Vec<usize>) -> TracedVal {
        let output = self.fresh();
        self.ops.push(OpNode { kind, inputs, output });
        TracedVal { vid: output, shape }
    }

    /// Finish the trace, stamping the checkpoint fingerprint.
    pub fn finish(self) -> TraceGraph {
        TraceGraph {
            leaves: self.leaves,
            ops: self.ops,
            fingerprint: fingerprint(self.source),
        }
    }
}

impl Backend for TraceBackend<'_> {
    type H = TracedVal;

    fn param(&mut self, name: &str) -> Result<TracedVal> {
        let actual = self.layout.resolve(name);
        let shape = self.source.shape_of(&actual).ok_or_else(|| {
            if actual == name {
                anyhow!("trace: checkpoint has no tensor {name:?}")
            } else {
                anyhow!(
                    "trace: checkpoint has no tensor {actual:?} \
                     (layout target of role {name:?})"
                )
            }
        })?;
        if let Some(&vid) = self.leaves.get(&actual) {
            return Ok(TracedVal { vid, shape });
        }
        let vid = self.fresh();
        self.leaves.insert(actual, vid);
        Ok(TracedVal { vid, shape })
    }

    fn embed(
        &mut self,
        embed: &TracedVal,
        pos: &TracedVal,
        batch: usize,
        tokens: &[i32],
    ) -> Result<TracedVal> {
        let (_, d) = cols2(&embed.shape, "embedding")?;
        let (p_rows, p_cols) = cols2(&pos.shape, "positional embedding")?;
        let t_len = tokens.len() / batch;
        if p_rows < t_len || p_cols != d {
            bail!(
                "trace: positional embedding {:?} incompatible with \
                 seq_len {t_len} x d_model {d}",
                pos.shape
            );
        }
        Ok(self.op(
            OpKind::Embed,
            vec![embed.vid, pos.vid],
            vec![batch * t_len, d],
        ))
    }

    fn layernorm(
        &mut self,
        x: &TracedVal,
        gain: &TracedVal,
        bias: &TracedVal,
    ) -> Result<TracedVal> {
        let (_, d) = cols2(&x.shape, "layernorm input")?;
        for (t, part) in [(gain, "gain"), (bias, "bias")] {
            if numel(&t.shape) != d {
                bail!(
                    "trace: layernorm {part} has {} elements, input width is {d}",
                    numel(&t.shape)
                );
            }
        }
        let shape = x.shape.clone();
        Ok(self.op(OpKind::Layernorm, vec![x.vid, gain.vid, bias.vid], shape))
    }

    fn matmul(&mut self, x: &TracedVal, w: &TracedVal) -> Result<TracedVal> {
        let (n, k) = cols2(&x.shape, "matmul lhs")?;
        let (wk, m) = cols2(&w.shape, "matmul weight")?;
        if k != wk {
            bail!("trace: matmul inner dims disagree ({k} vs {wk})");
        }
        Ok(self.op(OpKind::Matmul, vec![x.vid, w.vid], vec![n, m]))
    }

    fn attention(
        &mut self,
        q: &TracedVal,
        k: &TracedVal,
        v: &TracedVal,
        _batch: usize,
        n_head: usize,
    ) -> Result<TracedVal> {
        let (_, d) = cols2(&q.shape, "attention query")?;
        if k.shape != q.shape || v.shape != q.shape {
            bail!(
                "trace: attention q/k/v shapes disagree ({:?} / {:?} / {:?})",
                q.shape,
                k.shape,
                v.shape
            );
        }
        if d % n_head != 0 {
            bail!("trace: d_model {d} not divisible by n_head {n_head}");
        }
        let shape = q.shape.clone();
        Ok(self.op(OpKind::Attention, vec![q.vid, k.vid, v.vid], shape))
    }

    fn add(&mut self, a: &TracedVal, b: &TracedVal) -> Result<TracedVal> {
        if a.shape != b.shape {
            bail!("trace: add shapes disagree ({:?} vs {:?})", a.shape, b.shape);
        }
        let shape = a.shape.clone();
        Ok(self.op(OpKind::Add, vec![a.vid, b.vid], shape))
    }

    fn gelu(&mut self, x: TracedVal) -> Result<TracedVal> {
        let TracedVal { vid, shape } = x;
        Ok(self.op(OpKind::Gelu, vec![vid], shape))
    }
}

/// Trace the forward over a checkpoint's index: run the shared
/// `forward_with` body under the shape-only backend (layout read from
/// `layout.*` metadata) and return the recorded graph, fingerprinted
/// against the checkpoint.
pub fn trace_graph(source: &dyn TensorSource, cfg: &ModelCfg) -> Result<TraceGraph> {
    let layout = Layout::from_meta(source.meta());
    let tokens = vec![0i32; cfg.seq_len];
    let mut be = TraceBackend::new(source, layout);
    forward_with(&mut be, cfg, 1, &tokens)?;
    Ok(be.finish())
}

/// Convenience: trace with the config read from the checkpoint metadata.
pub fn trace_checkpoint(source: &dyn TensorSource) -> Result<TraceGraph> {
    let cfg = ModelCfg::from_meta(source.meta())
        .context("tracing needs the model config in checkpoint metadata")?;
    trace_graph(source, &cfg)
}

/// Resolve the model config for a checkpoint: the checkpoint metadata
/// when present, else the artifact manifest (`manifest.json` under
/// `artifacts_dir`) — pre-metadata checkpoints trace and serve through
/// the same config the AOT artifacts were lowered for. With neither
/// source available the metadata error propagates, annotated with the
/// missing fallback.
pub fn model_cfg_for(source: &dyn TensorSource, artifacts_dir: &str) -> Result<ModelCfg> {
    match ModelCfg::from_meta(source.meta()) {
        Ok(cfg) => Ok(cfg),
        Err(meta_err) => {
            let dir = Path::new(artifacts_dir);
            if dir.join("manifest.json").exists() {
                let m = crate::runtime::Manifest::load(dir).with_context(|| {
                    format!(
                        "checkpoint has no model-config metadata; falling back \
                         to {artifacts_dir}/manifest.json"
                    )
                })?;
                Ok(m.model_cfg())
            } else {
                Err(meta_err.context(format!(
                    "checkpoint has no model-config metadata and no artifact \
                     manifest exists at {artifacts_dir}/manifest.json to derive \
                     it from"
                )))
            }
        }
    }
}

/// Extend an in-memory checkpoint with the canonical model-config and
/// (optionally) layout metadata — test/builder helper.
pub fn stamp_model_meta(d: &mut Dts, cfg: &ModelCfg) {
    for (k, v) in [
        ("vocab", cfg.vocab),
        ("d_model", cfg.d_model),
        ("n_layer", cfg.n_layer),
        ("n_head", cfg.n_head),
        ("d_ff", cfg.d_ff),
        ("seq_len", cfg.seq_len),
    ] {
        d.meta.insert(k.to_string(), v.to_string());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn tiny_cfg() -> ModelCfg {
        ModelCfg { vocab: 12, d_model: 8, n_layer: 1, n_head: 2, d_ff: 12, seq_len: 4 }
    }

    /// Canonical-named checkpoint matching `tiny_cfg` (shapes only — the
    /// values are irrelevant to tracing).
    fn canonical_ckpt(cfg: &ModelCfg) -> Dts {
        let mut d = Dts::new();
        stamp_model_meta(&mut d, cfg);
        d.insert_f32("embed", &Tensor::zeros(vec![cfg.vocab, cfg.d_model]));
        d.insert_f32("pos", &Tensor::zeros(vec![cfg.seq_len, cfg.d_model]));
        for l in 0..cfg.n_layer {
            for w in ["wq", "wk", "wv", "wo"] {
                d.insert_f32(
                    &format!("l{l}.{w}"),
                    &Tensor::zeros(vec![cfg.d_model, cfg.d_model]),
                );
            }
            d.insert_f32(&format!("l{l}.w1"), &Tensor::zeros(vec![cfg.d_model, cfg.d_ff]));
            d.insert_f32(&format!("l{l}.w2"), &Tensor::zeros(vec![cfg.d_ff, cfg.d_model]));
            for ln in ["ln1", "ln2"] {
                d.insert_f32(&format!("l{l}.{ln}.g"), &Tensor::full(vec![cfg.d_model], 1.0));
                d.insert_f32(&format!("l{l}.{ln}.b"), &Tensor::zeros(vec![cfg.d_model]));
            }
        }
        d.insert_f32("lnf.g", &Tensor::full(vec![cfg.d_model], 1.0));
        d.insert_f32("lnf.b", &Tensor::zeros(vec![cfg.d_model]));
        d.insert_f32("head", &Tensor::zeros(vec![cfg.d_model, cfg.vocab]));
        d
    }

    #[test]
    fn trace_records_gemms_and_layernorm_edges() {
        let cfg = tiny_cfg();
        let d = canonical_ckpt(&cfg);
        let g = trace_graph(&d, &cfg).unwrap();
        // every checkpoint tensor the forward touches is a leaf
        assert!(g.leaves.contains_key("l0.wq"));
        assert!(g.leaves.contains_key("l0.ln1.g"));
        assert!(g.leaves.contains_key("head"));
        // quantizable = GEMM weights, in first-use order
        assert_eq!(
            g.quantizable(),
            vec!["l0.wq", "l0.wk", "l0.wv", "l0.wo", "l0.w1", "l0.w2", "head"]
        );
        // the wq matmul's activation is produced by the ln1 layernorm
        let wq = g.leaves["l0.wq"];
        let mm = g
            .ops
            .iter()
            .find(|o| o.kind == OpKind::Matmul && o.inputs.get(1) == Some(&wq))
            .unwrap();
        let ln = g.producer(mm.inputs[0]).unwrap();
        assert_eq!(ln.kind, OpKind::Layernorm);
        assert_eq!(g.leaf_name(ln.inputs[1]), Some("l0.ln1.g"));
        // the w2 matmul's activation comes from a GELU, not a layernorm
        let w2 = g.leaves["l0.w2"];
        let mm2 = g
            .ops
            .iter()
            .find(|o| o.kind == OpKind::Matmul && o.inputs.get(1) == Some(&w2))
            .unwrap();
        assert_eq!(g.producer(mm2.inputs[0]).unwrap().kind, OpKind::Gelu);
        assert_eq!(g.fingerprint, fingerprint(&d));
    }

    #[test]
    fn trace_fails_on_missing_or_misshapen_tensors() {
        let cfg = tiny_cfg();
        let mut d = canonical_ckpt(&cfg);
        let keep = d.tensor_f32("l0.wq").unwrap();
        d.insert_f32("l0.wq", &Tensor::zeros(vec![cfg.d_model + 1, cfg.d_model]));
        let err = trace_graph(&d, &cfg).unwrap_err();
        assert!(format!("{err:#}").contains("inner dims"), "{err:#}");
        d.insert_f32("l0.wq", &keep);
        assert!(trace_graph(&d, &cfg).is_ok());

        let mut missing = canonical_ckpt(&cfg);
        missing.meta.insert("layout.head".into(), "nope".into());
        let err = trace_graph(&missing, &cfg).unwrap_err();
        assert!(format!("{err:#}").contains("nope"), "{err:#}");
    }

    #[test]
    fn layout_resolves_renamed_tensors() {
        let meta: BTreeMap<String, String> = [
            ("layout.l0.wq".to_string(), "blk0.q_proj".to_string()),
            ("other".to_string(), "x".to_string()),
        ]
        .into();
        let l = Layout::from_meta(&meta);
        assert_eq!(l.resolve("l0.wq"), "blk0.q_proj");
        assert_eq!(l.resolve("l0.wk"), "l0.wk");
    }

    #[test]
    fn sidecar_roundtrips_exactly() {
        let cfg = tiny_cfg();
        let d = canonical_ckpt(&cfg);
        let g = trace_graph(&d, &cfg).unwrap();
        let back = TraceGraph::from_dts(&g.to_dts()).unwrap();
        assert_eq!(g, back);

        let p = std::env::temp_dir()
            .join(format!("daq_trace_sidecar_{}.graph.dts", std::process::id()));
        g.write_sidecar(&p).unwrap();
        let back = TraceGraph::read_sidecar(&p).unwrap();
        std::fs::remove_file(&p).unwrap();
        assert_eq!(g, back);
    }

    #[test]
    fn sidecar_rejects_non_graph_containers() {
        let d = Dts::new();
        assert!(TraceGraph::from_dts(&d).is_err());
    }

    #[test]
    fn fingerprint_tracks_index_and_trace_relevant_meta() {
        let cfg = tiny_cfg();
        let a = canonical_ckpt(&cfg);
        let mut b = canonical_ckpt(&cfg);
        assert_eq!(fingerprint(&a), fingerprint(&b));
        // values don't matter...
        b.insert_f32("head", &Tensor::full(vec![cfg.d_model, cfg.vocab], 3.0));
        assert_eq!(fingerprint(&a), fingerprint(&b));
        // ...shapes do
        b.insert_f32("head", &Tensor::zeros(vec![cfg.d_model, cfg.vocab + 1]));
        assert_ne!(fingerprint(&a), fingerprint(&b));
        // ...and extra tensors do
        let mut c = canonical_ckpt(&cfg);
        c.insert_f32("extra", &Tensor::zeros(vec![1]));
        assert_ne!(fingerprint(&a), fingerprint(&c));
        // editing the layout role map or the model config invalidates a
        // trace even when no tensor changed
        let mut d = canonical_ckpt(&cfg);
        d.meta.insert("layout.l0.wq".into(), "l0.wk".into());
        assert_ne!(fingerprint(&a), fingerprint(&d));
        let mut e = canonical_ckpt(&cfg);
        e.meta.insert("n_head".into(), "4".into());
        assert_ne!(fingerprint(&a), fingerprint(&e));
        // unrelated metadata does not
        let mut f = canonical_ckpt(&cfg);
        f.meta.insert("note".into(), "hello".into());
        assert_eq!(fingerprint(&a), fingerprint(&f));
    }

    #[test]
    fn model_cfg_falls_back_to_artifact_manifest() {
        let cfg = tiny_cfg();
        let dir = std::env::temp_dir()
            .join(format!("daq_trace_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            format!(
                "{{\"n_candidates\": 16, \"eval_batch\": 8, \"serve_batch\": 4, \
                 \"seq_len\": {}, \"vocab\": {}, \"d_model\": {}, \
                 \"n_layer\": {}, \"n_head\": {}, \"d_ff\": {}}}",
                cfg.seq_len, cfg.vocab, cfg.d_model, cfg.n_layer, cfg.n_head, cfg.d_ff
            ),
        )
        .unwrap();
        let dir_s = dir.to_str().unwrap();

        // metadata-bearing checkpoint: both sources must agree
        let with_meta = canonical_ckpt(&cfg);
        let from_meta = ModelCfg::from_meta(with_meta.meta()).unwrap();
        assert_eq!(model_cfg_for(&with_meta, dir_s).unwrap(), from_meta);

        // pre-metadata checkpoint: the manifest supplies the config, and
        // the trace over it equals the metadata-driven trace
        let mut bare = canonical_ckpt(&cfg);
        for k in ["vocab", "d_model", "n_layer", "n_head", "d_ff", "seq_len"] {
            bare.meta.remove(k);
        }
        assert!(ModelCfg::from_meta(bare.meta()).is_err());
        let derived = model_cfg_for(&bare, dir_s).unwrap();
        assert_eq!(derived, from_meta);
        let g_meta = trace_graph(&with_meta, &from_meta).unwrap();
        let g_manifest = trace_graph(&bare, &derived).unwrap();
        assert_eq!(g_meta.ops, g_manifest.ops);
        assert_eq!(g_meta.leaves, g_manifest.leaves);

        // with neither source the error names both
        let err = model_cfg_for(&bare, "/nonexistent_daq_artifacts").unwrap_err();
        assert!(format!("{err:#}").contains("manifest"), "{err:#}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn sidecar_path_variants() {
        assert_eq!(
            sidecar_path("artifacts/ckpt_post.dts"),
            PathBuf::from("artifacts/ckpt_post.graph.dts")
        );
        assert_eq!(
            sidecar_path("store/manifest.json"),
            PathBuf::from("store/graph.dts")
        );
    }
}
