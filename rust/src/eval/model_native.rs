//! Native-Rust transformer forward — an independent reimplementation of
//! `python/compile/model.py` used to cross-check the AOT artifact (the
//! integration test asserts argmax agreement) and as a PJRT-free fallback.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::tensor::ops::{gelu, layernorm_rows, matmul, softmax_rows};
use crate::tensor::Tensor;

/// Model configuration (mirrors `model.ModelConfig`; read from the
/// checkpoint metadata or the artifact manifest).
#[derive(Clone, Copy, Debug)]
pub struct ModelCfg {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layer: usize,
    pub n_head: usize,
    pub d_ff: usize,
    pub seq_len: usize,
}

impl ModelCfg {
    pub fn from_meta(meta: &std::collections::BTreeMap<String, String>) -> Result<ModelCfg> {
        let get = |k: &str| -> Result<usize> {
            meta.get(k)
                .ok_or_else(|| anyhow!("checkpoint meta missing {k}"))?
                .parse()
                .map_err(|_| anyhow!("checkpoint meta {k} not an integer"))
        };
        Ok(ModelCfg {
            vocab: get("vocab")?,
            d_model: get("d_model")?,
            n_layer: get("n_layer")?,
            n_head: get("n_head")?,
            d_ff: get("d_ff")?,
            seq_len: get("seq_len")?,
        })
    }
}

fn p<'a>(params: &'a HashMap<String, Tensor>, name: &str) -> Result<&'a Tensor> {
    params.get(name).ok_or_else(|| anyhow!("missing param {name:?}"))
}

/// Forward pass: tokens `[batch * seq]` → logits `[batch * seq * vocab]`.
///
/// Matches the JAX graph: learned positional embeddings, pre-LN blocks,
/// causal softmax attention, tanh-approximated GELU, final LN, untied head.
pub fn forward_native(
    params: &HashMap<String, Tensor>,
    cfg: &ModelCfg,
    batch: usize,
    tokens: &[i32],
) -> Result<Vec<f32>> {
    let (t_len, d, v) = (cfg.seq_len, cfg.d_model, cfg.vocab);
    assert_eq!(tokens.len(), batch * t_len);
    let embed = p(params, "embed")?;
    let pos = p(params, "pos")?;

    // x: [batch*seq, d]
    let mut x = Tensor::zeros(vec![batch * t_len, d]);
    for i in 0..batch {
        for t in 0..t_len {
            let tok = tokens[i * t_len + t] as usize;
            for j in 0..d {
                x.set2(i * t_len + t, j, embed.at2(tok, j) + pos.at2(t, j));
            }
        }
    }

    let n_head = cfg.n_head;
    let dh = d / n_head;
    let scale = 1.0 / (dh as f32).sqrt();

    for l in 0..cfg.n_layer {
        // --- attention block ---
        let g1 = p(params, &format!("l{l}.ln1.g"))?;
        let b1 = p(params, &format!("l{l}.ln1.b"))?;
        let h = layernorm_rows(&x, g1.data(), b1.data(), 1e-5);
        let q = matmul(&h, p(params, &format!("l{l}.wq"))?);
        let k = matmul(&h, p(params, &format!("l{l}.wk"))?);
        let vv = matmul(&h, p(params, &format!("l{l}.wv"))?);

        let mut att_out = Tensor::zeros(vec![batch * t_len, d]);
        for i in 0..batch {
            for hd in 0..n_head {
                // scores [t_len, t_len] for this (sample, head)
                let mut scores = Tensor::zeros(vec![t_len, t_len]);
                for tq in 0..t_len {
                    for tk in 0..=tq {
                        let mut s = 0.0f32;
                        let qrow = q.row(i * t_len + tq);
                        let krow = k.row(i * t_len + tk);
                        for j in 0..dh {
                            s += qrow[hd * dh + j] * krow[hd * dh + j];
                        }
                        scores.set2(tq, tk, s * scale);
                    }
                    for tk in tq + 1..t_len {
                        scores.set2(tq, tk, -1e9);
                    }
                }
                softmax_rows(&mut scores);
                for tq in 0..t_len {
                    for j in 0..dh {
                        let mut acc = 0.0f32;
                        for tk in 0..=tq {
                            acc += scores.at2(tq, tk)
                                * vv.at2(i * t_len + tk, hd * dh + j);
                        }
                        att_out.set2(i * t_len + tq, hd * dh + j, acc);
                    }
                }
            }
        }
        let proj = matmul(&att_out, p(params, &format!("l{l}.wo"))?);
        x = x.add(&proj);

        // --- MLP block ---
        let g2 = p(params, &format!("l{l}.ln2.g"))?;
        let b2 = p(params, &format!("l{l}.ln2.b"))?;
        let h2 = layernorm_rows(&x, g2.data(), b2.data(), 1e-5);
        let mut m = matmul(&h2, p(params, &format!("l{l}.w1"))?);
        for vmut in m.data_mut() {
            *vmut = gelu(*vmut);
        }
        let m2 = matmul(&m, p(params, &format!("l{l}.w2"))?);
        x = x.add(&m2);
    }

    let gf = p(params, "lnf.g")?;
    let bf = p(params, "lnf.b")?;
    let xf = layernorm_rows(&x, gf.data(), bf.data(), 1e-5);
    let logits = matmul(&xf, p(params, "head")?);
    debug_assert_eq!(logits.shape(), &[batch * t_len, v]);
    Ok(logits.into_data())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift;

    fn tiny_cfg() -> ModelCfg {
        ModelCfg { vocab: 16, d_model: 8, n_layer: 1, n_head: 2, d_ff: 16, seq_len: 4 }
    }

    fn tiny_params(cfg: &ModelCfg, seed: u64) -> HashMap<String, Tensor> {
        let mut rng = XorShift::new(seed);
        let mut p = HashMap::new();
        let mut add = |p: &mut HashMap<String, Tensor>, name: &str, r: usize, c: usize,
                       rng: &mut XorShift| {
            p.insert(name.into(), Tensor::new(vec![r, c], rng.normal_vec(r * c, 0.1)));
        };
        add(&mut p, "embed", cfg.vocab, cfg.d_model, &mut rng);
        add(&mut p, "pos", cfg.seq_len, cfg.d_model, &mut rng);
        for l in 0..cfg.n_layer {
            for w in ["wq", "wk", "wv", "wo"] {
                add(&mut p, &format!("l{l}.{w}"), cfg.d_model, cfg.d_model, &mut rng);
            }
            add(&mut p, &format!("l{l}.w1"), cfg.d_model, cfg.d_ff, &mut rng);
            add(&mut p, &format!("l{l}.w2"), cfg.d_ff, cfg.d_model, &mut rng);
            p.insert(format!("l{l}.ln1.g"), Tensor::full(vec![1, cfg.d_model], 1.0));
            p.insert(format!("l{l}.ln1.b"), Tensor::zeros(vec![1, cfg.d_model]));
            p.insert(format!("l{l}.ln2.g"), Tensor::full(vec![1, cfg.d_model], 1.0));
            p.insert(format!("l{l}.ln2.b"), Tensor::zeros(vec![1, cfg.d_model]));
        }
        p.insert("lnf.g".into(), Tensor::full(vec![1, cfg.d_model], 1.0));
        p.insert("lnf.b".into(), Tensor::zeros(vec![1, cfg.d_model]));
        add(&mut p, "head", cfg.d_model, cfg.vocab, &mut rng);
        p
    }

    #[test]
    fn forward_shape_and_finiteness() {
        let cfg = tiny_cfg();
        let params = tiny_params(&cfg, 1);
        let tokens = vec![1i32, 2, 3, 4, 5, 6, 7, 8];
        let logits = forward_native(&params, &cfg, 2, &tokens).unwrap();
        assert_eq!(logits.len(), 2 * cfg.seq_len * cfg.vocab);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn causality() {
        // changing the last token must not change logits at earlier positions
        let cfg = tiny_cfg();
        let params = tiny_params(&cfg, 2);
        let a = forward_native(&params, &cfg, 1, &[1, 2, 3, 4]).unwrap();
        let b = forward_native(&params, &cfg, 1, &[1, 2, 3, 9]).unwrap();
        let v = cfg.vocab;
        for t in 0..cfg.seq_len - 1 {
            for j in 0..v {
                assert!(
                    (a[t * v + j] - b[t * v + j]).abs() < 1e-5,
                    "t={t} j={j}"
                );
            }
        }
    }

    #[test]
    fn batch_consistency() {
        // running two samples in one batch == running them separately
        let cfg = tiny_cfg();
        let params = tiny_params(&cfg, 3);
        let s1 = [1i32, 2, 3, 4];
        let s2 = [5i32, 6, 7, 8];
        let joint = forward_native(&params, &cfg, 2,
                                   &[s1.as_slice(), s2.as_slice()].concat()).unwrap();
        let a = forward_native(&params, &cfg, 1, &s1).unwrap();
        let b = forward_native(&params, &cfg, 1, &s2).unwrap();
        let half = joint.len() / 2;
        for (x, y) in joint[..half].iter().zip(&a) {
            assert!((x - y).abs() < 1e-5);
        }
        for (x, y) in joint[half..].iter().zip(&b) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn missing_param_is_error() {
        let cfg = tiny_cfg();
        let mut params = tiny_params(&cfg, 4);
        params.remove("head");
        assert!(forward_native(&params, &cfg, 1, &[0, 1, 2, 3]).is_err());
    }
}
