//! Native-Rust transformer forward — an independent reimplementation of
//! `python/compile/model.py` used to cross-check the AOT artifact (the
//! integration test asserts argmax agreement) and as a PJRT-free fallback.
//!
//! The forward is written once, generically, against a [`Backend`] whose
//! handles flow through the graph: [`NativeBackend`] computes real
//! tensors (bitwise-identical to the original hand-rolled loop), while
//! `eval::trace`'s shape-only backend re-runs the same `forward_with`
//! body to record the producer→consumer dataflow graph without touching
//! a single payload. Structure lives in exactly one place, so the traced
//! graph cannot drift from what the forward actually computes.

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{anyhow, bail, Result};

use crate::quant::{matmul_quant, QuantizedTensor};
use crate::tensor::ops::{gelu, layernorm_rows, matmul, softmax_rows};
use crate::tensor::Tensor;

use super::quantstore::{QParam, QuantizedParams};

/// Model configuration (mirrors `model.ModelConfig`; read from the
/// checkpoint metadata or the artifact manifest).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelCfg {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layer: usize,
    pub n_head: usize,
    pub d_ff: usize,
    pub seq_len: usize,
}

impl ModelCfg {
    pub fn from_meta(meta: &std::collections::BTreeMap<String, String>) -> Result<ModelCfg> {
        let get = |k: &str| -> Result<usize> {
            meta.get(k)
                .ok_or_else(|| anyhow!("checkpoint meta missing {k}"))?
                .parse()
                .map_err(|_| anyhow!("checkpoint meta {k} not an integer"))
        };
        Ok(ModelCfg {
            vocab: get("vocab")?,
            d_model: get("d_model")?,
            n_layer: get("n_layer")?,
            n_head: get("n_head")?,
            d_ff: get("d_ff")?,
            seq_len: get("seq_len")?,
        })
    }
}

/// The operations the transformer forward is built from. `forward_with`
/// drives a backend through the graph; the handle type `H` is whatever
/// the backend flows between ops — real tensors for [`NativeBackend`],
/// shape-only value ids for the tracing backend.
///
/// Parameters are fetched by their *canonical role name* (`l3.wq`,
/// `lnf.g`, ...); a backend may resolve roles to differently named
/// checkpoint tensors (see `eval::trace::Layout`).
pub trait Backend {
    type H: Clone;

    /// Fetch a model parameter by canonical role name.
    fn param(&mut self, name: &str) -> Result<Self::H>;

    /// Token + learned positional embedding: `[batch * seq, d]`.
    fn embed(
        &mut self,
        embed: &Self::H,
        pos: &Self::H,
        batch: usize,
        tokens: &[i32],
    ) -> Result<Self::H>;

    /// Row-wise layernorm with affine `gain` / `bias` (eps 1e-5).
    fn layernorm(&mut self, x: &Self::H, gain: &Self::H, bias: &Self::H) -> Result<Self::H>;

    /// `x @ w` — every GEMM against a checkpoint weight goes through
    /// here, which is what makes the traced graph's layernorm→GEMM
    /// edges complete.
    fn matmul(&mut self, x: &Self::H, w: &Self::H) -> Result<Self::H>;

    /// Causal softmax attention over `n_head` heads.
    fn attention(
        &mut self,
        q: &Self::H,
        k: &Self::H,
        v: &Self::H,
        batch: usize,
        n_head: usize,
    ) -> Result<Self::H>;

    /// Residual add.
    fn add(&mut self, a: &Self::H, b: &Self::H) -> Result<Self::H>;

    /// Tanh-approximated GELU, elementwise. Consumes its input so a
    /// uniquely owned activation can be updated in place.
    fn gelu(&mut self, x: Self::H) -> Result<Self::H>;
}

/// The transformer forward, generic over the backend: learned positional
/// embeddings, pre-LN blocks, causal softmax attention, tanh-approximated
/// GELU, final LN, untied head. Matches the JAX graph; returns the
/// logits handle (`[batch * seq, vocab]` under the native backend).
pub fn forward_with<B: Backend>(
    be: &mut B,
    cfg: &ModelCfg,
    batch: usize,
    tokens: &[i32],
) -> Result<B::H> {
    assert_eq!(tokens.len(), batch * cfg.seq_len);
    let embed = be.param("embed")?;
    let pos = be.param("pos")?;
    let mut x = be.embed(&embed, &pos, batch, tokens)?;

    for l in 0..cfg.n_layer {
        // --- attention block ---
        let g1 = be.param(&format!("l{l}.ln1.g"))?;
        let b1 = be.param(&format!("l{l}.ln1.b"))?;
        let h = be.layernorm(&x, &g1, &b1)?;
        let wq = be.param(&format!("l{l}.wq"))?;
        let wk = be.param(&format!("l{l}.wk"))?;
        let wv = be.param(&format!("l{l}.wv"))?;
        let q = be.matmul(&h, &wq)?;
        let k = be.matmul(&h, &wk)?;
        let v = be.matmul(&h, &wv)?;
        let att = be.attention(&q, &k, &v, batch, cfg.n_head)?;
        let wo = be.param(&format!("l{l}.wo"))?;
        let proj = be.matmul(&att, &wo)?;
        x = be.add(&x, &proj)?;

        // --- MLP block ---
        let g2 = be.param(&format!("l{l}.ln2.g"))?;
        let b2 = be.param(&format!("l{l}.ln2.b"))?;
        let h2 = be.layernorm(&x, &g2, &b2)?;
        let w1 = be.param(&format!("l{l}.w1"))?;
        let m = be.matmul(&h2, &w1)?;
        let m = be.gelu(m)?;
        let w2 = be.param(&format!("l{l}.w2"))?;
        let m2 = be.matmul(&m, &w2)?;
        x = be.add(&x, &m2)?;
    }

    let gf = be.param("lnf.g")?;
    let bf = be.param("lnf.b")?;
    let xf = be.layernorm(&x, &gf, &bf)?;
    let head = be.param("head")?;
    be.matmul(&xf, &head)
}

/// Token + learned positional embedding, `[batch * t_len, d]` — shared by
/// the dense and quantized backends (one arithmetic, one evaluation
/// order, bitwise-identical results).
pub fn embed_rows(embed: &Tensor, pos: &Tensor, batch: usize, tokens: &[i32]) -> Tensor {
    let d = embed.cols();
    let t_len = tokens.len() / batch;
    let mut x = Tensor::zeros(vec![batch * t_len, d]);
    for i in 0..batch {
        for t in 0..t_len {
            let tok = tokens[i * t_len + t] as usize;
            for j in 0..d {
                x.set2(i * t_len + t, j, embed.at2(tok, j) + pos.at2(t, j));
            }
        }
    }
    x
}

/// Token + learned positional embedding for a contiguous token run
/// *starting at absolute position `t0`*, written into a flat `[c, d]`
/// buffer — the batched-prefill counterpart of [`embed_rows`], which
/// always embeds from position 0. Same per-element expression
/// (`embed[tok, j] + pos[t, j]`), so a chunked prefill embeds bitwise
/// what the full forward embeds at the same positions.
pub fn embed_rows_at(
    embed: &Tensor,
    pos: &Tensor,
    t0: usize,
    tokens: &[i32],
    out: &mut [f32],
) {
    let d = embed.cols();
    assert_eq!(out.len(), tokens.len() * d);
    for (i, (&token, orow)) in tokens.iter().zip(out.chunks_exact_mut(d)).enumerate() {
        let tok = token as usize;
        for (j, oj) in orow.iter_mut().enumerate() {
            *oj = embed.at2(tok, j) + pos.at2(t0 + i, j);
        }
    }
}

/// Causal softmax attention over `n_head` heads — shared by the dense and
/// quantized backends. (The incremental decoder reproduces this loop one
/// query row at a time against its KV cache; `eval::decode` pins the
/// bitwise agreement.)
pub fn attention_causal(
    q: &Tensor,
    k: &Tensor,
    vv: &Tensor,
    batch: usize,
    n_head: usize,
) -> Tensor {
    let d = q.cols();
    let dh = d / n_head;
    let t_len = q.rows() / batch;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut att_out = Tensor::zeros(vec![batch * t_len, d]);
    for i in 0..batch {
        for hd in 0..n_head {
            // scores [t_len, t_len] for this (sample, head)
            let mut scores = Tensor::zeros(vec![t_len, t_len]);
            for tq in 0..t_len {
                for tk in 0..=tq {
                    let mut s = 0.0f32;
                    let qrow = q.row(i * t_len + tq);
                    let krow = k.row(i * t_len + tk);
                    for j in 0..dh {
                        s += qrow[hd * dh + j] * krow[hd * dh + j];
                    }
                    scores.set2(tq, tk, s * scale);
                }
                for tk in tq + 1..t_len {
                    scores.set2(tq, tk, -1e9);
                }
            }
            softmax_rows(&mut scores);
            for tq in 0..t_len {
                for j in 0..dh {
                    let mut acc = 0.0f32;
                    for tk in 0..=tq {
                        acc += scores.at2(tq, tk)
                            * vv.at2(i * t_len + tk, hd * dh + j);
                    }
                    att_out.set2(i * t_len + tq, hd * dh + j, acc);
                }
            }
        }
    }
    att_out
}

/// A value flowing through the [`NativeBackend`]: parameters borrow from
/// the checkpoint map (no copies on the hot serving path), intermediates
/// are owned and cheaply clonable through an `Rc`.
#[derive(Clone)]
pub enum NativeVal<'p> {
    Param(&'p Tensor),
    Owned(Rc<Tensor>),
}

impl NativeVal<'_> {
    fn own(t: Tensor) -> Self {
        NativeVal::Owned(Rc::new(t))
    }

    fn t(&self) -> &Tensor {
        match self {
            NativeVal::Param(t) => t,
            NativeVal::Owned(t) => t,
        }
    }
}

/// Computes the forward with real tensors — the arithmetic (and its
/// evaluation order) is exactly the pre-refactor hand-rolled loop, so
/// logits are bitwise-unchanged.
pub struct NativeBackend<'p> {
    pub params: &'p HashMap<String, Tensor>,
}

impl<'p> Backend for NativeBackend<'p> {
    type H = NativeVal<'p>;

    fn param(&mut self, name: &str) -> Result<NativeVal<'p>> {
        self.params
            .get(name)
            .map(NativeVal::Param)
            .ok_or_else(|| anyhow!("missing param {name:?}"))
    }

    fn embed(
        &mut self,
        embed: &NativeVal<'p>,
        pos: &NativeVal<'p>,
        batch: usize,
        tokens: &[i32],
    ) -> Result<NativeVal<'p>> {
        Ok(NativeVal::own(embed_rows(embed.t(), pos.t(), batch, tokens)))
    }

    fn layernorm(
        &mut self,
        x: &NativeVal<'p>,
        gain: &NativeVal<'p>,
        bias: &NativeVal<'p>,
    ) -> Result<NativeVal<'p>> {
        Ok(NativeVal::own(layernorm_rows(
            x.t(),
            gain.t().data(),
            bias.t().data(),
            1e-5,
        )))
    }

    fn matmul(&mut self, x: &NativeVal<'p>, w: &NativeVal<'p>) -> Result<NativeVal<'p>> {
        Ok(NativeVal::own(matmul(x.t(), w.t())))
    }

    fn attention(
        &mut self,
        q: &NativeVal<'p>,
        k: &NativeVal<'p>,
        v: &NativeVal<'p>,
        batch: usize,
        n_head: usize,
    ) -> Result<NativeVal<'p>> {
        Ok(NativeVal::own(attention_causal(q.t(), k.t(), v.t(), batch, n_head)))
    }

    fn add(&mut self, a: &NativeVal<'p>, b: &NativeVal<'p>) -> Result<NativeVal<'p>> {
        Ok(NativeVal::own(a.t().add(b.t())))
    }

    fn gelu(&mut self, x: NativeVal<'p>) -> Result<NativeVal<'p>> {
        // a uniquely owned activation (the usual case: the matmul result
        // just produced) mutates in place, as the pre-refactor loop did
        let mut t = match x {
            NativeVal::Owned(rc) => Rc::try_unwrap(rc).unwrap_or_else(|rc| (*rc).clone()),
            NativeVal::Param(t) => t.clone(),
        };
        for v in t.data_mut() {
            *v = gelu(*v);
        }
        Ok(NativeVal::own(t))
    }
}

/// A value flowing through the [`QuantBackend`]: GEMM weights stay in
/// their codes+scales storage form, everything else is dense.
#[derive(Clone)]
pub enum QuantVal<'p> {
    Plain(&'p Tensor),
    Quant(&'p QuantizedTensor),
    Owned(Rc<Tensor>),
}

impl QuantVal<'_> {
    fn own(t: Tensor) -> Self {
        QuantVal::Owned(Rc::new(t))
    }

    fn dense(&self, what: &str) -> Result<&Tensor> {
        match self {
            QuantVal::Plain(t) => Ok(t),
            QuantVal::Owned(t) => Ok(t),
            QuantVal::Quant(_) => bail!(
                "{what}: operand is quantized but this op needs a dense \
                 tensor (only GEMM weights may stay quantized-resident)"
            ),
        }
    }
}

/// The third backend: computes the same forward as [`NativeBackend`] but
/// over a [`QuantizedParams`] store — every GEMM whose weight is
/// quantized flows through the fused dequant-matmul
/// ([`crate::quant::matmul_quant`]), so a weight's f32 image never exists
/// beyond one row of scratch. Activations and the non-GEMM parameters
/// (embeddings, layernorm affines) are dense, as the model needs them.
pub struct QuantBackend<'p> {
    pub params: &'p QuantizedParams,
}

impl<'p> Backend for QuantBackend<'p> {
    type H = QuantVal<'p>;

    fn param(&mut self, name: &str) -> Result<QuantVal<'p>> {
        match self.params.get(name) {
            Some(QParam::Plain(t)) => Ok(QuantVal::Plain(t)),
            Some(QParam::Quant(q)) => Ok(QuantVal::Quant(q)),
            None => Err(anyhow!("missing param {name:?}")),
        }
    }

    fn embed(
        &mut self,
        embed: &QuantVal<'p>,
        pos: &QuantVal<'p>,
        batch: usize,
        tokens: &[i32],
    ) -> Result<QuantVal<'p>> {
        Ok(QuantVal::own(embed_rows(
            embed.dense("embed")?,
            pos.dense("pos")?,
            batch,
            tokens,
        )))
    }

    fn layernorm(
        &mut self,
        x: &QuantVal<'p>,
        gain: &QuantVal<'p>,
        bias: &QuantVal<'p>,
    ) -> Result<QuantVal<'p>> {
        Ok(QuantVal::own(layernorm_rows(
            x.dense("layernorm input")?,
            gain.dense("layernorm gain")?.data(),
            bias.dense("layernorm bias")?.data(),
            1e-5,
        )))
    }

    fn matmul(&mut self, x: &QuantVal<'p>, w: &QuantVal<'p>) -> Result<QuantVal<'p>> {
        let x = x.dense("matmul lhs")?;
        Ok(QuantVal::own(match w {
            QuantVal::Quant(q) => matmul_quant(x, q),
            other => matmul(x, other.dense("matmul weight")?),
        }))
    }

    fn attention(
        &mut self,
        q: &QuantVal<'p>,
        k: &QuantVal<'p>,
        v: &QuantVal<'p>,
        batch: usize,
        n_head: usize,
    ) -> Result<QuantVal<'p>> {
        Ok(QuantVal::own(attention_causal(
            q.dense("attention q")?,
            k.dense("attention k")?,
            v.dense("attention v")?,
            batch,
            n_head,
        )))
    }

    fn add(&mut self, a: &QuantVal<'p>, b: &QuantVal<'p>) -> Result<QuantVal<'p>> {
        Ok(QuantVal::own(a.dense("add lhs")?.add(b.dense("add rhs")?)))
    }

    fn gelu(&mut self, x: QuantVal<'p>) -> Result<QuantVal<'p>> {
        let mut t = match x {
            QuantVal::Owned(rc) => Rc::try_unwrap(rc).unwrap_or_else(|rc| (*rc).clone()),
            QuantVal::Plain(t) => t.clone(),
            QuantVal::Quant(_) => bail!("gelu: operand is quantized"),
        };
        for v in t.data_mut() {
            *v = gelu(*v);
        }
        Ok(QuantVal::own(t))
    }
}

/// Forward pass over a quantized-resident store: tokens `[batch * seq]` →
/// logits `[batch * seq * vocab]`. Agrees with [`forward_native`] over
/// the dequantized parameter map bitwise (the fused dequant-matmul
/// reproduces the dense kernel's accumulation order exactly).
pub fn forward_quant(
    params: &QuantizedParams,
    cfg: &ModelCfg,
    batch: usize,
    tokens: &[i32],
) -> Result<Vec<f32>> {
    let mut be = QuantBackend { params };
    let logits = forward_with(&mut be, cfg, batch, tokens)?;
    let t = match logits {
        QuantVal::Plain(t) => t.clone(),
        QuantVal::Owned(rc) => Rc::try_unwrap(rc).unwrap_or_else(|rc| (*rc).clone()),
        QuantVal::Quant(_) => bail!("forward produced a quantized logits handle"),
    };
    debug_assert_eq!(t.shape(), &[batch * cfg.seq_len, cfg.vocab]);
    Ok(t.into_data())
}

/// Forward pass: tokens `[batch * seq]` → logits `[batch * seq * vocab]`.
pub fn forward_native(
    params: &HashMap<String, Tensor>,
    cfg: &ModelCfg,
    batch: usize,
    tokens: &[i32],
) -> Result<Vec<f32>> {
    let mut be = NativeBackend { params };
    let logits = forward_with(&mut be, cfg, batch, tokens)?;
    let t = match logits {
        NativeVal::Param(t) => t.clone(),
        NativeVal::Owned(rc) => Rc::try_unwrap(rc).unwrap_or_else(|rc| (*rc).clone()),
    };
    debug_assert_eq!(t.shape(), &[batch * cfg.seq_len, cfg.vocab]);
    Ok(t.into_data())
}

/// Deterministic synthetic parameter set for a config, canonical naming —
/// the model builder behind the serve bench, the decode/serve tests, and
/// this module's own tests (layernorm affines are identity so tiny models
/// stay numerically tame).
pub fn synth_params(cfg: &ModelCfg, seed: u64) -> HashMap<String, Tensor> {
    use crate::util::rng::XorShift;
    let mut rng = XorShift::new(seed);
    let mut p = HashMap::new();
    let mut add = |p: &mut HashMap<String, Tensor>, name: &str, r: usize, c: usize,
                   rng: &mut XorShift| {
        p.insert(name.into(), Tensor::new(vec![r, c], rng.normal_vec(r * c, 0.1)));
    };
    add(&mut p, "embed", cfg.vocab, cfg.d_model, &mut rng);
    add(&mut p, "pos", cfg.seq_len, cfg.d_model, &mut rng);
    for l in 0..cfg.n_layer {
        for w in ["wq", "wk", "wv", "wo"] {
            add(&mut p, &format!("l{l}.{w}"), cfg.d_model, cfg.d_model, &mut rng);
        }
        add(&mut p, &format!("l{l}.w1"), cfg.d_model, cfg.d_ff, &mut rng);
        add(&mut p, &format!("l{l}.w2"), cfg.d_ff, cfg.d_model, &mut rng);
        p.insert(format!("l{l}.ln1.g"), Tensor::full(vec![1, cfg.d_model], 1.0));
        p.insert(format!("l{l}.ln1.b"), Tensor::zeros(vec![1, cfg.d_model]));
        p.insert(format!("l{l}.ln2.g"), Tensor::full(vec![1, cfg.d_model], 1.0));
        p.insert(format!("l{l}.ln2.b"), Tensor::zeros(vec![1, cfg.d_model]));
    }
    p.insert("lnf.g".into(), Tensor::full(vec![1, cfg.d_model], 1.0));
    p.insert("lnf.b".into(), Tensor::zeros(vec![1, cfg.d_model]));
    add(&mut p, "head", cfg.d_model, cfg.vocab, &mut rng);
    p
}

/// Quantize every GEMM weight of a [`synth_params`] map in place into a
/// [`QuantizedParams`] store (AbsMax FP8 E4M3, the given granularity) —
/// the quantized-side twin of [`synth_params`] for benches and tests.
pub fn synth_quantized(
    params: &HashMap<String, Tensor>,
    quantizable: &[String],
    gran: crate::quant::Granularity,
) -> QuantizedParams {
    synth_quantized_fmt(params, quantizable, gran, crate::quant::CodeFormat::Fp8E4m3, 0)
}

/// [`synth_quantized`] for any code format, optionally fitting a rank-k
/// residual per quantized weight — the builder behind the per-format
/// serve tests and the INT4 bench rows.
pub fn synth_quantized_fmt(
    params: &HashMap<String, Tensor>,
    quantizable: &[String],
    gran: crate::quant::Granularity,
    fmt: crate::quant::CodeFormat,
    residual_rank: usize,
) -> QuantizedParams {
    let mut qp = QuantizedParams::new();
    for (name, t) in params {
        if quantizable.iter().any(|q| q == name) {
            qp.insert(
                name.clone(),
                QParam::Quant(crate::quant::quantize_fmt(t, gran, fmt, 1.0, residual_rank)),
            );
        } else {
            qp.insert(name.clone(), QParam::Plain(t.clone()));
        }
    }
    qp
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ModelCfg {
        ModelCfg { vocab: 16, d_model: 8, n_layer: 1, n_head: 2, d_ff: 16, seq_len: 4 }
    }

    fn tiny_params(cfg: &ModelCfg, seed: u64) -> HashMap<String, Tensor> {
        synth_params(cfg, seed)
    }

    #[test]
    fn forward_shape_and_finiteness() {
        let cfg = tiny_cfg();
        let params = tiny_params(&cfg, 1);
        let tokens = vec![1i32, 2, 3, 4, 5, 6, 7, 8];
        let logits = forward_native(&params, &cfg, 2, &tokens).unwrap();
        assert_eq!(logits.len(), 2 * cfg.seq_len * cfg.vocab);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn causality() {
        // changing the last token must not change logits at earlier positions
        let cfg = tiny_cfg();
        let params = tiny_params(&cfg, 2);
        let a = forward_native(&params, &cfg, 1, &[1, 2, 3, 4]).unwrap();
        let b = forward_native(&params, &cfg, 1, &[1, 2, 3, 9]).unwrap();
        let v = cfg.vocab;
        for t in 0..cfg.seq_len - 1 {
            for j in 0..v {
                assert!(
                    (a[t * v + j] - b[t * v + j]).abs() < 1e-5,
                    "t={t} j={j}"
                );
            }
        }
    }

    #[test]
    fn batch_consistency() {
        // running two samples in one batch == running them separately
        let cfg = tiny_cfg();
        let params = tiny_params(&cfg, 3);
        let s1 = [1i32, 2, 3, 4];
        let s2 = [5i32, 6, 7, 8];
        let joint = forward_native(&params, &cfg, 2,
                                   &[s1.as_slice(), s2.as_slice()].concat()).unwrap();
        let a = forward_native(&params, &cfg, 1, &s1).unwrap();
        let b = forward_native(&params, &cfg, 1, &s2).unwrap();
        let half = joint.len() / 2;
        for (x, y) in joint[..half].iter().zip(&a) {
            assert!((x - y).abs() < 1e-5);
        }
        for (x, y) in joint[half..].iter().zip(&b) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn missing_param_is_error() {
        let cfg = tiny_cfg();
        let mut params = tiny_params(&cfg, 4);
        params.remove("head");
        assert!(forward_native(&params, &cfg, 1, &[0, 1, 2, 3]).is_err());
    }

    #[test]
    fn quant_backend_matches_native_over_dequantized_params() {
        // the acceptance bar is 1e-6 relative; the fused dequant-matmul
        // reproduces the dense kernel's accumulation order, so the
        // agreement is in fact bitwise — assert the stronger property
        let cfg = tiny_cfg();
        let params = tiny_params(&cfg, 5);
        let quantizable: Vec<String> = params
            .keys()
            .filter(|n| {
                n.ends_with(".wq") || n.ends_with(".wk") || n.ends_with(".wv")
                    || n.ends_with(".wo") || n.ends_with(".w1")
                    || n.ends_with(".w2") || n.as_str() == "head"
            })
            .cloned()
            .collect();
        let qp = synth_quantized(&params, &quantizable, crate::quant::Granularity::PerChannel);
        assert_eq!(qp.n_quantized(), quantizable.len());
        let deq = qp.dequantize_all();
        let tokens = vec![1i32, 2, 3, 4, 5, 6, 7, 8];
        let native = forward_native(&deq, &cfg, 2, &tokens).unwrap();
        let quant = forward_quant(&qp, &cfg, 2, &tokens).unwrap();
        assert_eq!(native.len(), quant.len());
        for (i, (a, b)) in native.iter().zip(&quant).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "logit {i}: {a} vs {b}");
        }
    }

    #[test]
    fn quant_backend_refuses_quantized_non_gemm_params() {
        let cfg = tiny_cfg();
        let params = tiny_params(&cfg, 6);
        // quantizing the embedding would silently re-densify inside the
        // forward; the backend must refuse instead
        let qp = synth_quantized(
            &params,
            &["embed".to_string()],
            crate::quant::Granularity::PerChannel,
        );
        let err = forward_quant(&qp, &cfg, 1, &[0, 1, 2, 3]).unwrap_err();
        assert!(format!("{err:#}").contains("dense"), "{err:#}");
    }
}
