//! ASCII/markdown table rendering — every bench prints its paper-shaped
//! table through this module so EXPERIMENTS.md rows can be pasted
//! directly from bench output.

/// A simple aligned text table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as an aligned plain-text table.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let line = |cells: &[String], w: &[usize]| -> String {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    s.push_str("  ");
                }
                s.push_str(&format!("{:>width$}", c, width = w[i]));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.headers, &w));
        let total: usize = w.iter().sum::<usize>() + 2 * (w.len() - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &w));
        }
        out
    }

    /// Render as GitHub markdown (for EXPERIMENTS.md).
    pub fn markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}|\n",
            self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }
}

/// Formatting helpers matching the paper's table style.
pub fn fmt_pct(x: f64) -> String {
    format!("{:.2}%", 100.0 * x)
}

pub fn fmt3(x: f64) -> String {
    format!("{x:.3}")
}

pub fn fmt_l2(x: f64) -> String {
    format!("{x:.2}")
}

/// "n/a" cell for undefined entries (e.g. delta metrics under
/// SmoothQuant/AWQ — paper Table 2 footnote ‡).
pub fn na() -> String {
    "-".to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["name", "value"]);
        t.row(vec!["a".into(), "1.0".into()]);
        t.row(vec!["longer-name".into(), "2.25".into()]);
        let s = t.render();
        assert!(s.contains("== Demo =="));
        assert!(s.contains("longer-name"));
        let lines: Vec<&str> = s.lines().collect();
        // header, separator, two rows, plus title
        assert_eq!(lines.len(), 5);
    }

    #[test]
    fn markdown_shape() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.markdown();
        assert!(md.starts_with("| a | b |"));
        assert!(md.contains("|---|---|"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(fmt_pct(0.5454), "54.54%");
        assert_eq!(fmt3(0.2391), "0.239");
        assert_eq!(fmt_l2(48641.4), "48641.40");
        assert_eq!(na(), "-");
    }
}
