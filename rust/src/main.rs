//! `daq` — the L3 coordinator binary.
//!
//! See `daq help` (or cli::USAGE) for the subcommands. Typical flow:
//!
//! ```text
//! make artifacts                       # python: train + AOT-lower (once)
//! daq quantize --metric sign --range 0.8,1.25 --engine pjrt --out q.dts
//! daq eval --ckpt q.dts --engine pjrt
//! daq tables                           # regenerate paper tables 1-5
//! daq serve --engine pjrt --quantize   # serve the DAQ-quantized model
//! ```

use daq::cli;
use daq::util::cliargs::Args;
use daq::util::telemetry;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            telemetry::warn(&format!("error: {e}\n{}", cli::USAGE));
            std::process::exit(2);
        }
    };
    if let Err(e) = cli::dispatch(&args) {
        telemetry::warn(&format!("error: {e:#}"));
        std::process::exit(1);
    }
}
