//! Dense ops for the native evaluation path and baseline calibration math.
//!
//! `matmul` carries the native transformer forward (used to cross-check
//! PJRT and as fallback when artifacts are absent); it is blocked for
//! cache reuse but deliberately scalar — the performance-critical model
//! execution path is the AOT HLO, not this.

use super::Tensor;

/// C[M,N] = A[M,K] @ B[K,N], i-k-j loop order with 64-wide j blocking.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2);
    assert_eq!(b.ndim(), 2);
    let (m, k) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul inner dims: {k} vs {k2}");
    let mut c = vec![0.0f32; m * n];
    let ad = a.data();
    let bd = b.data();
    for i in 0..m {
        let crow = &mut c[i * n..(i + 1) * n];
        for kk in 0..k {
            let aik = ad[i * k + kk];
            if aik == 0.0 {
                continue;
            }
            let brow = &bd[kk * n..(kk + 1) * n];
            for (cj, bj) in crow.iter_mut().zip(brow) {
                *cj += aik * bj;
            }
        }
    }
    Tensor::new(vec![m, n], c)
}

/// Transpose a 2-D tensor.
pub fn transpose(a: &Tensor) -> Tensor {
    let (m, n) = (a.rows(), a.cols());
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            out[j * m + i] = a.at2(i, j);
        }
    }
    Tensor::new(vec![n, m], out)
}

/// Row-wise softmax in place.
pub fn softmax_rows(a: &mut Tensor) {
    let (m, n) = (a.rows(), a.cols());
    let d = a.data_mut();
    for i in 0..m {
        let row = &mut d[i * n..(i + 1) * n];
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

/// Row-wise layernorm: (x - mu) / sqrt(var + eps) * g + b.
pub fn layernorm_rows(a: &Tensor, g: &[f32], b: &[f32], eps: f32) -> Tensor {
    let (m, n) = (a.rows(), a.cols());
    assert_eq!(g.len(), n);
    assert_eq!(b.len(), n);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let row = a.row(i);
        let mu = row.iter().sum::<f32>() / n as f32;
        let var = row.iter().map(|x| (x - mu) * (x - mu)).sum::<f32>() / n as f32;
        let inv = 1.0 / (var + eps).sqrt();
        for j in 0..n {
            out[i * n + j] = (row[j] - mu) * inv * g[j] + b[j];
        }
    }
    Tensor::new(vec![m, n], out)
}

/// Exact GELU (erf form), matching `jax.nn.gelu(approximate=True)`?
/// No — JAX defaults to the tanh approximation; we match that so the
/// native forward agrees with the AOT graph.
#[inline]
pub fn gelu(x: f32) -> f32 {
    const SQRT_2_OVER_PI: f32 = 0.797_884_6;
    0.5 * x * (1.0 + (SQRT_2_OVER_PI * (x + 0.044715 * x * x * x)).tanh())
}

/// Row-wise argmax.
pub fn argmax_rows(a: &Tensor) -> Vec<usize> {
    let (m, n) = (a.rows(), a.cols());
    (0..m)
        .map(|i| {
            let row = a.row(i);
            let mut best = 0;
            for j in 1..n {
                if row[j] > row[best] {
                    best = j;
                }
            }
            best
        })
        .collect()
}

/// Mean of |x| along rows (per-column statistic), used for calibration.
pub fn col_abs_mean(a: &Tensor) -> Vec<f32> {
    let (m, n) = (a.rows(), a.cols());
    let mut out = vec![0.0f32; n];
    for i in 0..m {
        for (j, o) in out.iter_mut().enumerate() {
            *o += a.at2(i, j).abs();
        }
    }
    for o in &mut out {
        *o /= m as f32;
    }
    out
}

/// Per-column absolute maximum.
pub fn col_abs_max(a: &Tensor) -> Vec<f32> {
    let (m, n) = (a.rows(), a.cols());
    let mut out = vec![0.0f32; n];
    for i in 0..m {
        for (j, o) in out.iter_mut().enumerate() {
            *o = o.max(a.at2(i, j).abs());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{run, Config};

    #[test]
    fn matmul_known() {
        let a = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::new(vec![2, 2], vec![1., 1., 1., 1.]);
        let c = matmul(&a, &b);
        assert_eq!(c.data(), &[3., 3., 7., 7.]);
    }

    #[test]
    fn matmul_identity() {
        let n = 16;
        let eye = Tensor::from_fn(vec![n, n], |i| if i / n == i % n { 1.0 } else { 0.0 });
        let a = Tensor::from_fn(vec![n, n], |i| i as f32 * 0.1);
        assert_eq!(matmul(&a, &eye), a);
        assert_eq!(matmul(&eye, &a), a);
    }

    #[test]
    fn transpose_involution() {
        let a = Tensor::from_fn(vec![3, 5], |i| i as f32);
        assert_eq!(transpose(&transpose(&a)), a);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut a = Tensor::new(vec![2, 3], vec![1., 2., 3., -1., 0., 1.]);
        softmax_rows(&mut a);
        for i in 0..2 {
            let s: f32 = a.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn softmax_handles_large_values() {
        let mut a = Tensor::new(vec![1, 3], vec![1000., 1000., 1000.]);
        softmax_rows(&mut a);
        for &v in a.row(0) {
            assert!((v - 1.0 / 3.0).abs() < 1e-6);
        }
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let a = Tensor::new(vec![1, 4], vec![1., 2., 3., 4.]);
        let g = vec![1.0; 4];
        let b = vec![0.0; 4];
        let out = layernorm_rows(&a, &g, &b, 1e-5);
        let mu: f32 = out.row(0).iter().sum::<f32>() / 4.0;
        let var: f32 = out.row(0).iter().map(|x| (x - mu) * (x - mu)).sum::<f32>() / 4.0;
        assert!(mu.abs() < 1e-6);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn gelu_known_values() {
        assert!(gelu(0.0).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-4); // tanh approximation
        assert!(gelu(-10.0).abs() < 1e-4);
        assert!((gelu(10.0) - 10.0).abs() < 1e-4);
    }

    #[test]
    fn argmax_rows_works() {
        let a = Tensor::new(vec![2, 3], vec![1., 5., 2., 9., 0., 3.]);
        assert_eq!(argmax_rows(&a), vec![1, 0]);
    }

    #[test]
    fn calibration_stats() {
        let a = Tensor::new(vec![2, 2], vec![1., -2., 3., -4.]);
        assert_eq!(col_abs_mean(&a), vec![2.0, 3.0]);
        assert_eq!(col_abs_max(&a), vec![3.0, 4.0]);
    }

    #[test]
    fn prop_matmul_distributes_over_add() {
        run("A(B+C) == AB + AC", Config { cases: 24, ..Config::default() }, |g| {
            let m = g.usize_range(1, 8);
            let k = g.usize_range(1, 8);
            let n = g.usize_range(1, 8);
            let a = Tensor::new(vec![m, k], g.normal_vec(m * k, 1.0));
            let b = Tensor::new(vec![k, n], g.normal_vec(k * n, 1.0));
            let c = Tensor::new(vec![k, n], g.normal_vec(k * n, 1.0));
            let lhs = matmul(&a, &b.add(&c));
            let rhs = matmul(&a, &b).add(&matmul(&a, &c));
            for (x, y) in lhs.data().iter().zip(rhs.data()) {
                assert!((x - y).abs() < 1e-3, "{x} vs {y}");
            }
        });
    }
}
