//! Minimal dense f32 tensor substrate.
//!
//! The coordinator, baselines and the native evaluation path need a small
//! set of dense ops (matmul, layernorm, softmax, reductions). This is a
//! deliberately simple row-major implementation — the *fast* path for
//! model execution is the AOT-compiled HLO via PJRT (`runtime`); this type
//! exists for the quantizer itself, the baselines' calibration math, and
//! as an independent cross-check of the PJRT forward.

pub mod ops;

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} incompatible with {} elements",
            data.len()
        );
        Self { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![0.0; n] }
    }

    pub fn full(shape: Vec<usize>, v: f32) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![v; n] }
    }

    pub fn from_fn(shape: Vec<usize>, mut f: impl FnMut(usize) -> f32) -> Self {
        let n = shape.iter().product();
        Self { shape, data: (0..n).map(&mut f).collect() }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// 2-D accessors ------------------------------------------------------

    pub fn rows(&self) -> usize {
        assert_eq!(self.ndim(), 2);
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.ndim(), 2);
        self.shape[1]
    }

    #[inline]
    pub fn at2(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.shape[1] + c]
    }

    #[inline]
    pub fn set2(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.shape[1] + c] = v;
    }

    pub fn row(&self, r: usize) -> &[f32] {
        let c = self.cols();
        &self.data[r * c..(r + 1) * c]
    }

    /// Reshape (must preserve element count).
    pub fn reshape(mut self, shape: Vec<usize>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape;
        self
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Largest absolute value.
    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }

    /// Elementwise map into a new tensor.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Elementwise binary op (shapes must match).
    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
        assert_eq!(self.shape, other.shape);
        Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| f(a, b))
                .collect(),
        }
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a - b)
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a + b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_accessors() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.rows(), 2);
        assert_eq!(t.cols(), 3);
        assert_eq!(t.at2(1, 2), 6.0);
        assert_eq!(t.row(0), &[1., 2., 3.]);
    }

    #[test]
    #[should_panic(expected = "incompatible")]
    fn shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![1.0; 3]);
    }

    #[test]
    fn norms() {
        let t = Tensor::new(vec![2, 2], vec![3., 4., 0., 0.]);
        assert_eq!(t.norm(), 5.0);
        assert_eq!(t.abs_max(), 4.0);
    }

    #[test]
    fn map_zip_sub() {
        let a = Tensor::new(vec![3], vec![1., 2., 3.]);
        let b = Tensor::new(vec![3], vec![3., 2., 1.]);
        assert_eq!(a.sub(&b).data(), &[-2., 0., 2.]);
        assert_eq!(a.map(|x| x * 2.0).data(), &[2., 4., 6.]);
        assert_eq!(a.zip(&b, |x, y| x * y).data(), &[3., 4., 3.]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::new(vec![2, 3], (0..6).map(|i| i as f32).collect());
        let r = t.reshape(vec![3, 2]);
        assert_eq!(r.shape(), &[3, 2]);
        assert_eq!(r.at2(2, 1), 5.0);
    }
}
