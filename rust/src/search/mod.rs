//! Scale optimization (paper §2.4, Algorithm 1): coarse-to-fine search for
//! the scale multiplier α* that maximizes a chosen objective, plus
//! exhaustive and golden-section variants for the ablation benches.
//!
//! The search is generic over a [`SweepEngine`] so the same Algorithm 1
//! control flow can run on the native engines or the AOT-compiled Pallas
//! kernel through PJRT (`runtime::PjrtSweep`). Engines that can amortize
//! per-(layer, granularity) state across candidate batches expose it
//! through [`SweepEngine::prepare`]: Algorithm 1 plans once and streams
//! the coarse and fine batches (and golden-section's one-candidate
//! probes) through the same [`PreparedSweep`], so Δp/sign/scale lookups
//! are computed once per layer instead of once per batch.

use crate::metrics::{sweep_native, DeltaStats, SweepPlan};
use crate::quant::ScaleGrid;
use crate::tensor::Tensor;

/// Which metric drives the arg-max (paper Eq. 3/5; MSE is negated so every
/// objective is maximized).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    SignRate,
    CosSim,
    NegMse,
    /// Equal-weight blend of SignRate and (rescaled) CosSim — the hybrid
    /// metric the paper's §3.5(3) suggests exploring: the sign term
    /// provides the higher peaks, the cosine term the smoothness that
    /// tames the binary metric's non-monotonicity across ranges.
    Hybrid,
}

impl Objective {
    pub fn parse(s: &str) -> Result<Objective, String> {
        match s {
            "sign" | "signrate" => Ok(Objective::SignRate),
            "cos" | "cosine" | "cossim" => Ok(Objective::CosSim),
            "mse" | "negmse" => Ok(Objective::NegMse),
            "hybrid" => Ok(Objective::Hybrid),
            other => Err(format!("bad metric {other:?} (sign|cos|mse|hybrid)")),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Objective::SignRate => "sign",
            Objective::CosSim => "cos",
            Objective::NegMse => "mse",
            Objective::Hybrid => "hybrid",
        }
    }

    /// Evaluate the objective on a stats row (higher is better).
    pub fn value(&self, s: &DeltaStats) -> f64 {
        match self {
            Objective::SignRate => s.sign_rate(),
            Objective::CosSim => s.cos_sim(),
            Objective::NegMse => -s.mse(),
            // both terms mapped to [0, 1] before blending
            Objective::Hybrid => 0.5 * s.sign_rate() + 0.5 * (s.cos_sim() + 1.0) / 2.0,
        }
    }
}

/// A sweep prepared for one (layer, granularity): candidate-invariant
/// state is computed at construction; each call evaluates one batch.
pub trait PreparedSweep {
    fn eval(&self, alphas: &[f32]) -> Vec<DeltaStats>;
}

/// Engine evaluating a batch of candidate multipliers (the fused sweep).
pub trait SweepEngine {
    fn sweep(
        &self,
        w_post: &Tensor,
        w_base: &Tensor,
        s0: &ScaleGrid,
        alphas: &[f32],
    ) -> Vec<DeltaStats>;

    /// Plan once, evaluate many batches — the entry point Algorithm 1
    /// uses. The default simply re-sweeps per batch (right for PJRT,
    /// which keeps its own executable cache); native engines override it
    /// with a real [`metrics::SweepPlan`](crate::metrics::SweepPlan).
    fn prepare<'a>(
        &'a self,
        w_post: &'a Tensor,
        w_base: &'a Tensor,
        s0: &'a ScaleGrid,
    ) -> Box<dyn PreparedSweep + 'a> {
        Box::new(ResweepEach { engine: self, w_post, w_base, s0 })
    }

    fn name(&self) -> &'static str;
}

/// Fallback [`PreparedSweep`]: no reusable state, re-sweep every batch.
struct ResweepEach<'a, E: SweepEngine + ?Sized> {
    engine: &'a E,
    w_post: &'a Tensor,
    w_base: &'a Tensor,
    s0: &'a ScaleGrid,
}

impl<E: SweepEngine + ?Sized> PreparedSweep for ResweepEach<'_, E> {
    fn eval(&self, alphas: &[f32]) -> Vec<DeltaStats> {
        self.engine.sweep(self.w_post, self.w_base, self.s0, alphas)
    }
}

/// Prepared form of the native engines: an owned plan plus the worker
/// budget its tiles fan out over.
struct PlannedNative {
    plan: SweepPlan,
    workers: usize,
}

impl PreparedSweep for PlannedNative {
    fn eval(&self, alphas: &[f32]) -> Vec<DeltaStats> {
        self.plan.eval_with_workers(alphas, self.workers)
    }
}

/// The in-process scalar reference engine: `sweep` is the straightforward
/// fused loop; `prepare` builds a single-threaded plan.
pub struct NativeSweep;

impl SweepEngine for NativeSweep {
    fn sweep(
        &self,
        w_post: &Tensor,
        w_base: &Tensor,
        s0: &ScaleGrid,
        alphas: &[f32],
    ) -> Vec<DeltaStats> {
        sweep_native(w_post, w_base, s0, alphas)
    }

    fn prepare<'a>(
        &'a self,
        w_post: &'a Tensor,
        w_base: &'a Tensor,
        s0: &'a ScaleGrid,
    ) -> Box<dyn PreparedSweep + 'a> {
        Box::new(PlannedNative { plan: SweepPlan::new(w_post, w_base, s0), workers: 1 })
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// The production native engine: planned, tiled, and multi-threaded —
/// one large layer spreads its tiles over the whole worker budget.
/// Bitwise-deterministic for any `workers` value (fixed-order tile
/// merge), so the coordinator can split cores between layer- and
/// tile-level parallelism freely.
pub struct TiledSweep {
    pub workers: usize,
}

impl TiledSweep {
    pub fn new(workers: usize) -> TiledSweep {
        TiledSweep { workers: workers.max(1) }
    }
}

impl SweepEngine for TiledSweep {
    fn sweep(
        &self,
        w_post: &Tensor,
        w_base: &Tensor,
        s0: &ScaleGrid,
        alphas: &[f32],
    ) -> Vec<DeltaStats> {
        SweepPlan::new(w_post, w_base, s0).eval_with_workers(alphas, self.workers)
    }

    fn prepare<'a>(
        &'a self,
        w_post: &'a Tensor,
        w_base: &'a Tensor,
        s0: &'a ScaleGrid,
    ) -> Box<dyn PreparedSweep + 'a> {
        Box::new(PlannedNative {
            plan: SweepPlan::new(w_post, w_base, s0),
            workers: self.workers,
        })
    }

    fn name(&self) -> &'static str {
        "tiled"
    }
}

/// Search hyperparameters (paper §3.1: ranges {[0.5,2],[0.8,1.25],
/// [0.9,1.11]}, 5 coarse + 10 fine candidates).
#[derive(Clone, Debug)]
pub struct SearchConfig {
    pub objective: Objective,
    pub range: (f32, f32),
    pub n_coarse: usize,
    pub n_fine: usize,
    /// Half-width of the fine stage around the best coarse α, as a
    /// fraction of the coarse spacing (1.0 = one coarse step either side).
    pub fine_halfwidth_steps: f32,
}

impl SearchConfig {
    pub fn paper_default(objective: Objective, range: (f32, f32)) -> Self {
        Self {
            objective,
            range,
            n_coarse: 5,
            n_fine: 10,
            fine_halfwidth_steps: 1.0,
        }
    }
}

/// Search outcome for one tensor.
#[derive(Clone, Debug)]
pub struct SearchResult {
    /// Best multiplier α* (1.0 = the AbsMax default).
    pub alpha: f32,
    /// Objective value at α*.
    pub objective_value: f64,
    /// Full statistics at α*.
    pub stats: DeltaStats,
    /// Total candidate evaluations.
    pub evals: usize,
    /// (α, objective) for every candidate evaluated, in evaluation order.
    pub history: Vec<(f32, f64)>,
}

fn linspace(lo: f32, hi: f32, n: usize) -> Vec<f32> {
    if n == 1 {
        return vec![(lo + hi) / 2.0];
    }
    (0..n)
        .map(|i| lo + (hi - lo) * i as f32 / (n - 1) as f32)
        .collect()
}

/// Algorithm 1: coarse-to-fine scale search over `[lo, hi]·s0`.
///
/// The default α = 1 (plain AbsMax) is always a candidate (lines 5–6), so
/// the search never does worse than no search under its own objective.
///
/// Plans once via [`SweepEngine::prepare`]; the coarse and fine batches
/// stream through the same prepared state.
pub fn search_scale_with(
    engine: &dyn SweepEngine,
    w_post: &Tensor,
    w_base: &Tensor,
    s0: &ScaleGrid,
    cfg: &SearchConfig,
) -> SearchResult {
    let (lo, hi) = cfg.range;
    let prepared = engine.prepare(w_post, w_base, s0);
    let mut history = Vec::new();
    let mut best_alpha = 1.0f32;
    let mut best_val = f64::NEG_INFINITY;
    let mut best_stats = DeltaStats::default();

    let mut eval_batch = |alphas: &[f32],
                          history: &mut Vec<(f32, f64)>,
                          best_alpha: &mut f32,
                          best_val: &mut f64,
                          best_stats: &mut DeltaStats| {
        let stats = prepared.eval(alphas);
        for (&a, st) in alphas.iter().zip(&stats) {
            let v = cfg.objective.value(st);
            history.push((a, v));
            if v > *best_val {
                *best_val = v;
                *best_alpha = a;
                *best_stats = *st;
            }
        }
    };

    // default + coarse stage in one batch
    let mut coarse = vec![1.0f32];
    coarse.extend(linspace(lo, hi, cfg.n_coarse));
    eval_batch(&coarse, &mut history, &mut best_alpha, &mut best_val, &mut best_stats);

    // fine stage around the best coarse candidate
    let step = if cfg.n_coarse > 1 {
        (hi - lo) / (cfg.n_coarse - 1) as f32
    } else {
        hi - lo
    };
    let delta = step * cfg.fine_halfwidth_steps;
    let flo = (best_alpha - delta).max(lo);
    let fhi = (best_alpha + delta).min(hi);
    let fine = linspace(flo, fhi, cfg.n_fine);
    eval_batch(&fine, &mut history, &mut best_alpha, &mut best_val, &mut best_stats);

    SearchResult {
        alpha: best_alpha,
        objective_value: best_val,
        stats: best_stats,
        evals: history.len(),
        history,
    }
}

/// Convenience wrapper using the native engine and AbsMax s0.
pub fn search_scale(
    w_post: &Tensor,
    w_base: &Tensor,
    granularity: crate::quant::Granularity,
    cfg: &SearchConfig,
) -> SearchResult {
    let s0 = crate::quant::absmax_scales(w_post, granularity);
    search_scale_with(&NativeSweep, w_post, w_base, &s0, cfg)
}

/// Ablation: exhaustive uniform grid (upper bound on what coarse-to-fine
/// can find at matched evaluation budget ×N).
pub fn search_exhaustive(
    engine: &dyn SweepEngine,
    w_post: &Tensor,
    w_base: &Tensor,
    s0: &ScaleGrid,
    objective: Objective,
    range: (f32, f32),
    n: usize,
) -> SearchResult {
    let alphas = linspace(range.0, range.1, n);
    let stats = engine.prepare(w_post, w_base, s0).eval(&alphas);
    let mut history = Vec::with_capacity(n);
    let mut best = (1.0f32, f64::NEG_INFINITY, DeltaStats::default());
    for (&a, st) in alphas.iter().zip(&stats) {
        let v = objective.value(st);
        history.push((a, v));
        if v > best.1 {
            best = (a, v, *st);
        }
    }
    SearchResult {
        alpha: best.0,
        objective_value: best.1,
        stats: best.2,
        evals: history.len(),
        history,
    }
}

/// Ablation: golden-section search. Assumes (incorrectly, for SignRate —
/// which is piecewise constant) a unimodal objective; included to show why
/// the paper's grid search is the right default.
pub fn search_golden(
    engine: &dyn SweepEngine,
    w_post: &Tensor,
    w_base: &Tensor,
    s0: &ScaleGrid,
    objective: Objective,
    range: (f32, f32),
    iters: usize,
) -> SearchResult {
    const PHI: f32 = 0.618_034;
    let (mut lo, mut hi) = range;
    // golden-section probes one candidate at a time — the planned entry
    // point matters most here (2 + iters single-candidate batches)
    let prepared = engine.prepare(w_post, w_base, s0);
    let mut history = Vec::new();
    let mut eval1 = |a: f32, history: &mut Vec<(f32, f64)>| {
        let st = prepared.eval(&[a]);
        let v = objective.value(&st[0]);
        history.push((a, v));
        (v, st[0])
    };
    let mut x1 = hi - PHI * (hi - lo);
    let mut x2 = lo + PHI * (hi - lo);
    let (mut f1, mut s1) = eval1(x1, &mut history);
    let (mut f2, mut s2) = eval1(x2, &mut history);
    for _ in 0..iters {
        if f1 < f2 {
            lo = x1;
            x1 = x2;
            f1 = f2;
            s1 = s2;
            x2 = lo + PHI * (hi - lo);
            let r = eval1(x2, &mut history);
            f2 = r.0;
            s2 = r.1;
        } else {
            hi = x2;
            x2 = x1;
            f2 = f1;
            s2 = s1;
            x1 = hi - PHI * (hi - lo);
            let r = eval1(x1, &mut history);
            f1 = r.0;
            s1 = r.1;
        }
    }
    let (alpha, val, stats) = if f1 >= f2 { (x1, f1, s1) } else { (x2, f2, s2) };
    SearchResult {
        alpha,
        objective_value: val,
        stats,
        evals: history.len(),
        history,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{absmax_scales, Granularity};
    use crate::util::rng::XorShift;

    fn pair(r: usize, c: usize, delta: f32, seed: u64) -> (Tensor, Tensor) {
        let mut rng = XorShift::new(seed);
        let wb = Tensor::new(vec![r, c], rng.normal_vec(r * c, 0.1));
        let wp = Tensor::new(
            vec![r, c],
            wb.data().iter().map(|&b| b + rng.normal() * delta).collect(),
        );
        (wp, wb)
    }

    #[test]
    fn objective_parse() {
        assert_eq!(Objective::parse("sign").unwrap(), Objective::SignRate);
        assert_eq!(Objective::parse("cos").unwrap(), Objective::CosSim);
        assert_eq!(Objective::parse("mse").unwrap(), Objective::NegMse);
        assert_eq!(Objective::parse("hybrid").unwrap(), Objective::Hybrid);
        assert!(Objective::parse("foo").is_err());
    }

    #[test]
    fn hybrid_objective_bounds_and_blend() {
        // perfect preservation: sign_rate 1, cos 1 -> hybrid 1
        let perfect = DeltaStats { agree: 10.0, dot: 1.0, nq: 1.0,
                                   npost: 1.0, sq: 0.0, n: 10.0 };
        assert!((Objective::Hybrid.value(&perfect) - 1.0).abs() < 1e-12);
        // fully reversed: sign_rate 0, cos -1 -> hybrid 0
        let reversed = DeltaStats { agree: 0.0, dot: -1.0, nq: 1.0,
                                    npost: 1.0, sq: 4.0, n: 10.0 };
        assert!(Objective::Hybrid.value(&reversed).abs() < 1e-12);
        // hybrid search is never worse than its own objective's default
        // (1e-9: the planned engine merges f64 sums in tile order, so its
        // α=1 value differs from sweep_native's by reordering rounding)
        let (wp, wb) = pair(32, 32, 0.002, 9);
        let s0 = absmax_scales(&wp, Granularity::Block(16));
        let cfg = SearchConfig::paper_default(Objective::Hybrid, (0.8, 1.25));
        let res = search_scale_with(&NativeSweep, &wp, &wb, &s0, &cfg);
        let default = Objective::Hybrid.value(&sweep_native(&wp, &wb, &s0, &[1.0])[0]);
        assert!(res.objective_value >= default - 1e-9);
    }

    #[test]
    fn search_never_worse_than_default() {
        // Algorithm 1 lines 5-6: α=1 is a candidate, so the found objective
        // is >= the default's objective under every metric and range
        // (1e-9 tolerance: the planned engine's tile-order f64 merge vs
        // sweep_native's element-order accumulation).
        let (wp, wb) = pair(64, 64, 0.001, 1);
        let s0 = absmax_scales(&wp, Granularity::Block(32));
        for obj in [Objective::SignRate, Objective::CosSim, Objective::NegMse] {
            for range in [(0.5, 2.0), (0.8, 1.25), (0.9, 1.11f32)] {
                let cfg = SearchConfig::paper_default(obj, range);
                let res = search_scale_with(&NativeSweep, &wp, &wb, &s0, &cfg);
                let default =
                    obj.value(&sweep_native(&wp, &wb, &s0, &[1.0])[0]);
                assert!(
                    res.objective_value >= default - 1e-9,
                    "{obj:?} {range:?}: {} < {default}",
                    res.objective_value
                );
            }
        }
    }

    /// An engine with no `prepare` override: exercises the re-sweep
    /// fallback path the PJRT engine takes.
    struct RawNative;

    impl SweepEngine for RawNative {
        fn sweep(
            &self,
            w_post: &Tensor,
            w_base: &Tensor,
            s0: &ScaleGrid,
            alphas: &[f32],
        ) -> Vec<DeltaStats> {
            sweep_native(w_post, w_base, s0, alphas)
        }

        fn name(&self) -> &'static str {
            "raw"
        }
    }

    #[test]
    fn planned_search_matches_unplanned_control_flow() {
        // SignRate is computed from exact integer counts, which the plan
        // reproduces bit-for-bit — so the planned and re-sweep searches
        // must pick the identical alpha and agree count.
        let (wp, wb) = pair(96, 64, 0.003, 11);
        for gran in [Granularity::PerChannel, Granularity::Block(32)] {
            let s0 = absmax_scales(&wp, gran);
            let cfg = SearchConfig::paper_default(Objective::SignRate, (0.8, 1.25));
            let planned = search_scale_with(&NativeSweep, &wp, &wb, &s0, &cfg);
            let raw = search_scale_with(&RawNative, &wp, &wb, &s0, &cfg);
            assert_eq!(planned.alpha, raw.alpha, "{gran:?}");
            assert_eq!(planned.stats.agree, raw.stats.agree);
            assert_eq!(planned.stats.n, raw.stats.n);
            assert_eq!(planned.evals, raw.evals);
        }
    }

    #[test]
    fn prepared_engine_reuses_plan_across_batches() {
        let (wp, wb) = pair(48, 48, 0.002, 12);
        let s0 = absmax_scales(&wp, Granularity::Block(16));
        let engine = TiledSweep::new(2);
        let prepared = engine.prepare(&wp, &wb, &s0);
        let a = prepared.eval(&[0.9, 1.0, 1.1]);
        let b = prepared.eval(&[1.0]);
        // batch composition must not change a candidate's statistics
        assert_eq!(a[1], b[0]);
        // and the prepared path equals the one-shot path exactly
        assert_eq!(engine.sweep(&wp, &wb, &s0, &[1.0])[0], b[0]);
    }

    #[test]
    fn tiled_engine_deterministic_across_workers() {
        let (wp, wb) = pair(64, 96, 0.004, 13);
        let s0 = absmax_scales(&wp, Granularity::PerChannel);
        let alphas: Vec<f32> = (0..16).map(|i| 0.8 + 0.028 * i as f32).collect();
        let base = TiledSweep::new(1).sweep(&wp, &wb, &s0, &alphas);
        for workers in [2usize, 8] {
            assert_eq!(
                TiledSweep::new(workers).sweep(&wp, &wb, &s0, &alphas),
                base,
                "workers {workers}"
            );
        }
    }

    #[test]
    fn eval_budget_matches_paper() {
        let (wp, wb) = pair(32, 32, 0.002, 2);
        let s0 = absmax_scales(&wp, Granularity::PerTensor);
        let cfg = SearchConfig::paper_default(Objective::SignRate, (0.8, 1.25));
        let res = search_scale_with(&NativeSweep, &wp, &wb, &s0, &cfg);
        // 1 default + 5 coarse + 10 fine
        assert_eq!(res.evals, 16);
    }

    #[test]
    fn alpha_stays_in_range() {
        use crate::util::proptest::{run, Config};
        run("alpha in range", Config { cases: 16, ..Config::default() }, |g| {
            let (wp, wb) = pair(16, 16, 0.01, g.u64());
            let s0 = absmax_scales(&wp, Granularity::PerTensor);
            let lo = g.f32_range(0.5, 0.9);
            let hi = lo + g.f32_range(0.2, 1.0);
            let cfg = SearchConfig::paper_default(Objective::CosSim, (lo, hi));
            let res = search_scale_with(&NativeSweep, &wp, &wb, &s0, &cfg);
            // α=1 default may sit outside [lo,hi]; otherwise in range
            assert!(
                res.alpha == 1.0 || (lo..=hi).contains(&res.alpha),
                "alpha {} not in [{lo},{hi}]",
                res.alpha
            );
        });
    }

    #[test]
    fn exhaustive_at_least_as_good_on_same_grid() {
        let (wp, wb) = pair(48, 48, 0.003, 3);
        let s0 = absmax_scales(&wp, Granularity::PerChannel);
        let obj = Objective::CosSim;
        let res = search_exhaustive(&NativeSweep, &wp, &wb, &s0, obj, (0.8, 1.25), 64);
        let cfg = SearchConfig::paper_default(obj, (0.8, 1.25));
        let ctf = search_scale_with(&NativeSweep, &wp, &wb, &s0, &cfg);
        // dense exhaustive search with 4x the budget should not lose badly
        assert!(res.objective_value >= ctf.objective_value - 0.01);
    }

    #[test]
    fn golden_runs_and_reports() {
        let (wp, wb) = pair(32, 32, 0.002, 4);
        let s0 = absmax_scales(&wp, Granularity::PerTensor);
        let res = search_golden(&NativeSweep, &wp, &wb, &s0,
                                Objective::CosSim, (0.8, 1.25), 10);
        assert!(res.evals == 12);
        assert!((0.8..=1.25).contains(&res.alpha));
    }

    #[test]
    fn history_covers_all_evals() {
        let (wp, wb) = pair(16, 16, 0.005, 5);
        let s0 = absmax_scales(&wp, Granularity::PerTensor);
        let cfg = SearchConfig::paper_default(Objective::NegMse, (0.5, 2.0));
        let res = search_scale_with(&NativeSweep, &wp, &wb, &s0, &cfg);
        assert_eq!(res.history.len(), res.evals);
        // best value appears in the history
        assert!(res
            .history
            .iter()
            .any(|&(a, v)| a == res.alpha && v == res.objective_value));
    }

    #[test]
    fn mse_objective_prefers_small_reconstruction_error() {
        // For pure reconstruction, α=1 (AbsMax) should be near-optimal and
        // extreme α clearly worse.
        let (wp, wb) = pair(64, 64, 0.001, 6);
        let s0 = absmax_scales(&wp, Granularity::PerTensor);
        let stats = sweep_native(&wp, &wb, &s0, &[0.5, 1.0, 2.0]);
        assert!(stats[1].mse() <= stats[0].mse());
        assert!(stats[1].mse() <= stats[2].mse());
    }
}
