//! Bounded-memory streaming quantization driver.
//!
//! A three-stage pipeline over a sharded (or seek-based monolithic)
//! checkpoint pair:
//!
//! 1. a **prefetch** thread pulls `(base, post)` layer pairs through a
//!    depth-`K` admission gate,
//! 2. the existing tiled sweep engine quantizes them on a small worker
//!    pool (each layer runs exactly [`super::quantize_delta_layer`], the
//!    same unit of work the in-memory pipeline uses, so results are
//!    **bitwise-identical** to [`super::run_pipeline`]),
//! 3. a **writer** thread streams `codes` / `scales` / dequantized
//!    weights into output shards in fixed input order, dropping each
//!    layer's tensors as soon as they are written.
//!
//! A layer's admission permit is held from the moment its tensors are
//! read until the writer has persisted and dropped them, so peak live
//! tensor bytes are bounded by `K · (largest layer footprint)` — not by
//! model size. The measured peak and the largest per-unit footprint are
//! reported in [`StreamOutcome`] and asserted by the residency test.
//!
//! **Resume.** The writer journals per-layer completion (name, α, shape,
//! eval count, exact f64 sufficient statistics, owning shard) as JSON
//! lines in `resume.jsonl`. Journal lines are flushed *before* the shard
//! holding them is finalized (tmp + rename), so after an interruption
//! every finalized shard's layers are recorded and at most a discardable
//! `.part` payload is lost. `run_stream` with `resume = true` skips the
//! recorded layers, reuses their journaled statistics (Rust's shortest
//! `Display` repr round-trips f64 exactly), and converges to the same
//! per-tensor bytes as an uninterrupted run.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Condvar, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use crate::io::shard::{shard_file_name, ShardWriter};
use crate::io::TensorSource;
use crate::metrics::DeltaStats;
use crate::quant::{Granularity, QuantizedTensor};
use crate::search::TiledSweep;
use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::timer::time;

use super::{quantize_delta_layer, LayerOutcome, Method};

/// Journal file name inside the output directory.
pub const RESUME_JOURNAL: &str = "resume.jsonl";

#[derive(Clone, Debug)]
pub struct StreamConfig {
    pub granularity: Granularity,
    /// Must be a delta method (`AbsMax` / `Search`); the transformed
    /// baselines fold per-group state across layers and are rejected.
    pub method: Method,
    /// Total worker budget, split between layer- and tile-parallelism.
    pub workers: usize,
    /// K: maximum layer pairs admitted (read but not yet written).
    pub depth: usize,
    /// Output shard payload budget in bytes.
    pub shard_budget: u64,
    /// Skip layers recorded in the output directory's resume journal.
    pub resume: bool,
}

impl StreamConfig {
    pub fn new(granularity: Granularity, method: Method, workers: usize) -> Self {
        StreamConfig {
            granularity,
            method,
            workers: workers.max(1),
            depth: workers.max(2),
            shard_budget: crate::io::shard::DEFAULT_SHARD_MB << 20,
            resume: false,
        }
    }
}

/// Outcome of a streaming run.
pub struct StreamOutcome {
    /// Per-layer outcomes in input order (journaled values for resumed
    /// layers, freshly computed for the rest).
    pub layers: Vec<LayerOutcome>,
    /// Model-level aggregate, merged in fixed layer order.
    pub agg: DeltaStats,
    /// Path of the written sharded-store manifest.
    pub manifest: PathBuf,
    /// Layers skipped via the resume journal.
    pub resumed: usize,
    /// Measured peak of concurrently live tensor bytes.
    pub peak_live_bytes: usize,
    /// Largest single-unit footprint (layer pair + its outputs, or one
    /// passthrough tensor). `peak_live_bytes <= depth * this` holds.
    pub max_unit_bytes: usize,
    pub total_secs: f64,
}

// ---------------------------------------------------------------------
// admission gate: a closable counting semaphore

struct Gate {
    state: Mutex<(usize, bool)>,
    cv: Condvar,
}

impl Gate {
    fn new(permits: usize) -> Gate {
        Gate { state: Mutex::new((permits, false)), cv: Condvar::new() }
    }

    /// Blocks for a permit; returns `false` if the gate was closed.
    fn acquire(&self) -> bool {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.1 {
                return false;
            }
            if st.0 > 0 {
                st.0 -= 1;
                return true;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    fn release(&self) {
        let mut st = self.state.lock().unwrap();
        st.0 += 1;
        self.cv.notify_all();
    }

    /// Wake all waiters and make every future `acquire` fail (abort path).
    fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.1 = true;
        self.cv.notify_all();
    }
}

fn add_live(live: &AtomicUsize, peak: &AtomicUsize, bytes: usize) {
    let now = live.fetch_add(bytes, Ordering::SeqCst) + bytes;
    peak.fetch_max(now, Ordering::SeqCst);
}

fn sub_live(live: &AtomicUsize, bytes: usize) {
    live.fetch_sub(bytes, Ordering::SeqCst);
}

// ---------------------------------------------------------------------
// resume journal lines

fn config_line(cfg: &StreamConfig) -> String {
    let mut c = BTreeMap::new();
    c.insert("gran".to_string(), Json::Str(cfg.granularity.label()));
    c.insert("method".to_string(), Json::Str(cfg.method.label()));
    let mut o = BTreeMap::new();
    o.insert("config".to_string(), Json::Obj(c));
    format!("{}\n", Json::Obj(o))
}

fn layer_line(l: &LayerOutcome, shard: &str) -> String {
    let stats = l.stats.as_ref().expect("delta stats defined in stream mode");
    let mut st = BTreeMap::new();
    st.insert("agree".to_string(), Json::Num(stats.agree));
    st.insert("dot".to_string(), Json::Num(stats.dot));
    st.insert("nq".to_string(), Json::Num(stats.nq));
    st.insert("npost".to_string(), Json::Num(stats.npost));
    st.insert("sq".to_string(), Json::Num(stats.sq));
    st.insert("n".to_string(), Json::Num(stats.n));
    let mut o = BTreeMap::new();
    o.insert("layer".to_string(), Json::Str(l.name.clone()));
    o.insert("rows".to_string(), Json::Num(l.shape.0 as f64));
    o.insert("cols".to_string(), Json::Num(l.shape.1 as f64));
    o.insert("alpha".to_string(), Json::Num(l.alpha as f64));
    o.insert("evals".to_string(), Json::Num(l.evals as f64));
    o.insert("secs".to_string(), Json::Num(l.secs));
    o.insert("stats".to_string(), Json::Obj(st));
    o.insert("shard".to_string(), Json::Str(shard.to_string()));
    format!("{}\n", Json::Obj(o))
}

fn parse_layer_line(j: &Json) -> Option<LayerOutcome> {
    let name = j.get("layer")?.as_str()?.to_string();
    let st = j.get("stats")?;
    let stats = DeltaStats {
        agree: st.get("agree")?.as_f64()?,
        dot: st.get("dot")?.as_f64()?,
        nq: st.get("nq")?.as_f64()?,
        npost: st.get("npost")?.as_f64()?,
        sq: st.get("sq")?.as_f64()?,
        n: st.get("n")?.as_f64()?,
    };
    Some(LayerOutcome {
        name,
        shape: (j.get("rows")?.as_usize()?, j.get("cols")?.as_usize()?),
        alpha: j.get("alpha")?.as_f64()? as f32,
        evals: j.get("evals")?.as_usize()?,
        stats: Some(stats),
        secs: j.get("secs")?.as_f64()?,
    })
}

/// Parse a journal: (config json if present, last layer line per name).
/// Malformed lines (e.g. a truncated tail) are skipped.
fn parse_journal(text: &str) -> (Option<Json>, BTreeMap<String, LayerOutcome>) {
    let mut config = None;
    let mut layers = BTreeMap::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let Ok(j) = Json::parse(line) else { continue };
        if let Some(c) = j.get("config") {
            config.get_or_insert_with(|| c.clone());
        } else if let Some(l) = parse_layer_line(&j) {
            layers.insert(l.name.clone(), l);
        }
    }
    (config, layers)
}

// ---------------------------------------------------------------------
// pipeline stages

/// A prefetched layer pair in flight.
struct LayerJob {
    idx: usize,
    name: String,
    wp: Tensor,
    wb: Tensor,
    pair_bytes: usize,
}

/// A quantized layer awaiting the writer.
struct Done {
    idx: usize,
    outcome: LayerOutcome,
    q: QuantizedTensor,
    deq: Tensor,
    out_bytes: usize,
    /// pair + output bytes: this layer's peak contribution.
    footprint: usize,
}

struct WriterOut {
    writer: ShardWriter,
    computed: Vec<(usize, LayerOutcome)>,
    max_unit_bytes: usize,
}

/// Run the streaming pipeline: quantize `quantizable` layers of `post`
/// against `base` into a sharded store at `out_dir` (shards + resume
/// journal + manifest), never holding more than `cfg.depth` layer pairs
/// in memory.
pub fn run_stream(
    post: &dyn TensorSource,
    base: &dyn TensorSource,
    quantizable: &[String],
    out_dir: &Path,
    cfg: &StreamConfig,
) -> Result<StreamOutcome> {
    if !matches!(cfg.method, Method::AbsMax | Method::Search { .. }) {
        bail!(
            "streaming supports delta methods only (absmax / scale search); \
             {} folds state across layers and needs the in-memory pipeline",
            cfg.method.label()
        );
    }

    let (out, total_secs) = time(|| run_stream_inner(post, base, quantizable, out_dir, cfg));
    let mut out = out?;
    out.total_secs = total_secs;
    Ok(out)
}

fn run_stream_inner(
    post: &dyn TensorSource,
    base: &dyn TensorSource,
    quantizable: &[String],
    out_dir: &Path,
    cfg: &StreamConfig,
) -> Result<StreamOutcome> {
    let journal_path = out_dir.join(RESUME_JOURNAL);

    // -- writer + resume state -----------------------------------------
    let (mut shard_writer, resumed_layers) = if cfg.resume {
        let w = ShardWriter::resume(out_dir, cfg.shard_budget)?;
        let text = std::fs::read_to_string(&journal_path).unwrap_or_default();
        let (config, mut recorded) = parse_journal(&text);
        if let Some(c) = &config {
            let gran = c.get("gran").and_then(|g| g.as_str()).unwrap_or("");
            let method = c.get("method").and_then(|m| m.as_str()).unwrap_or("");
            if gran != cfg.granularity.label() || method != cfg.method.label() {
                bail!(
                    "{out_dir:?}: resume journal was written by gran={gran} \
                     method={method}, current run is gran={} method={}",
                    cfg.granularity.label(),
                    cfg.method.label()
                );
            }
        }
        // a journaled layer is resumable iff all three tensors survive in
        // finalized shards; partial presence means a corrupted store
        let mut resumed = BTreeMap::new();
        for name in quantizable {
            let parts =
                [format!("{name}.codes"), format!("{name}.scales"), name.clone()];
            let present = parts.iter().filter(|p| w.contains(p)).count();
            match (present, recorded.remove(name)) {
                (3, Some(outcome)) => {
                    resumed.insert(name.clone(), outcome);
                }
                (0, _) => {}
                (3, None) => bail!(
                    "{out_dir:?}: layer {name:?} is present in shards but \
                     missing from the resume journal; remove the directory \
                     and rerun"
                ),
                _ => bail!(
                    "{out_dir:?}: layer {name:?} is only partially present \
                     in shards; remove the directory and rerun"
                ),
            }
        }
        (w, resumed)
    } else {
        (ShardWriter::create(out_dir, cfg.shard_budget)?, BTreeMap::new())
    };

    let mut journal = if cfg.resume {
        std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&journal_path)
            .with_context(|| format!("open {journal_path:?}"))?
    } else {
        std::fs::File::create(&journal_path)
            .with_context(|| format!("create {journal_path:?}"))?
    };
    if !cfg.resume || resumed_layers.is_empty() {
        journal.write_all(config_line(cfg).as_bytes())?;
        journal.flush()?;
    }

    // -- plan the work -------------------------------------------------
    let resumed_count = resumed_layers.len();
    let mut slots: Vec<Option<LayerOutcome>> = Vec::with_capacity(quantizable.len());
    let mut todo: Vec<(usize, String)> = Vec::new();
    for (idx, name) in quantizable.iter().enumerate() {
        match resumed_layers.get(name) {
            Some(outcome) => slots.push(Some(outcome.clone())),
            None => {
                slots.push(None);
                todo.push((idx, name.clone()));
            }
        }
    }
    let expected: VecDeque<usize> = todo.iter().map(|&(i, _)| i).collect();

    let depth = cfg.depth.max(1);
    let outer = cfg.workers.clamp(1, depth.min(todo.len().max(1)));
    let intra = (cfg.workers / outer).max(1);

    let gate = Gate::new(depth);
    let live = AtomicUsize::new(0);
    let peak = AtomicUsize::new(0);
    let quant_set: BTreeSet<&String> = quantizable.iter().collect();

    let (job_tx, job_rx) = mpsc::channel::<Result<LayerJob>>();
    let job_rx = Mutex::new(job_rx);
    let (done_tx, done_rx) = mpsc::channel::<Result<Done>>();

    let (gate, live, peak, job_rx) = (&gate, &live, &peak, &job_rx);
    let shard_budget = cfg.shard_budget;

    let writer_out: Result<WriterOut> = std::thread::scope(|s| {
        // stage 1: prefetch (base, post) pairs through the gate
        s.spawn(move || {
            for (idx, name) in todo {
                if !gate.acquire() {
                    return; // aborted by the writer
                }
                let msg = (|| -> Result<LayerJob> {
                    let wp = post.tensor_f32(&name)?;
                    let wb = base.tensor_f32(&name)?;
                    if wp.shape() != wb.shape() {
                        bail!(
                            "{name}: post {:?} vs base {:?}",
                            wp.shape(),
                            wb.shape()
                        );
                    }
                    let pair_bytes = (wp.len() + wb.len()) * 4;
                    add_live(live, peak, pair_bytes);
                    Ok(LayerJob { idx, name: name.clone(), wp, wb, pair_bytes })
                })();
                let stop = msg.is_err();
                if job_tx.send(msg).is_err() || stop {
                    return;
                }
            }
        });

        // stage 2: quantize on `outer` workers × `intra` tile threads
        for _ in 0..outer {
            let done_tx = done_tx.clone();
            s.spawn(move || {
                let engine = TiledSweep::new(intra);
                loop {
                    let msg = job_rx.lock().unwrap().recv();
                    let job = match msg {
                        Err(_) => break, // prefetch done
                        Ok(Err(e)) => {
                            let _ = done_tx.send(Err(e));
                            break;
                        }
                        Ok(Ok(j)) => j,
                    };
                    let LayerJob { idx, name, wp, wb, pair_bytes } = job;
                    let (outcome, q) = quantize_delta_layer(
                        &name,
                        &wp,
                        &wb,
                        &cfg.method,
                        cfg.granularity,
                        &engine,
                    );
                    let deq = q.dequantize();
                    let out_bytes =
                        q.codes.len() + q.scales.scales.len() * 4 + deq.len() * 4;
                    add_live(live, peak, out_bytes);
                    drop(wp);
                    drop(wb);
                    sub_live(live, pair_bytes);
                    let d = Done {
                        idx,
                        outcome,
                        q,
                        deq,
                        out_bytes,
                        footprint: pair_bytes + out_bytes,
                    };
                    if done_tx.send(Ok(d)).is_err() {
                        break;
                    }
                }
            });
        }
        drop(done_tx);

        // stage 3: write completed layers in fixed input order
        let h = s.spawn(move || -> Result<WriterOut> {
            let r = write_stage(
                done_rx,
                expected,
                &mut shard_writer,
                &mut journal,
                shard_budget,
                post,
                &quant_set,
                gate,
                live,
                peak,
            );
            if r.is_err() {
                gate.close();
            }
            r.map(|(computed, max_unit_bytes)| WriterOut {
                writer: shard_writer,
                computed,
                max_unit_bytes,
            })
        });
        match h.join() {
            Ok(r) => r,
            Err(p) => std::panic::resume_unwind(p),
        }
    });
    let WriterOut { writer, computed, max_unit_bytes } = writer_out?;

    for (idx, outcome) in computed {
        slots[idx] = Some(outcome);
    }
    let layers: Vec<LayerOutcome> = slots
        .into_iter()
        .enumerate()
        .map(|(i, s)| {
            s.ok_or_else(|| anyhow!("layer {:?} was never quantized", quantizable[i]))
        })
        .collect::<Result<_>>()?;

    let mut agg = DeltaStats::default();
    for l in &layers {
        agg = agg.merge(l.stats.as_ref().expect("delta stats defined"));
    }

    // store-level metadata, mirroring `PipelineOutcome::write_checkpoint`
    let mut meta = post.meta().clone();
    meta.insert("quantized".into(), "fp8_e4m3".into());
    for l in &layers {
        meta.insert(format!("alpha.{}", l.name), l.alpha.to_string());
        meta.insert(format!("gran.{}", l.name), cfg.granularity.label());
    }
    let manifest = writer.finish(&meta)?;

    Ok(StreamOutcome {
        layers,
        agg,
        manifest,
        resumed: resumed_count,
        peak_live_bytes: peak.load(Ordering::SeqCst),
        max_unit_bytes,
        total_secs: 0.0, // stamped by run_stream
    })
}

/// The writer stage body: drain completed layers, persist them in input
/// order (journal lines flush before each shard roll), then stream the
/// non-quantizable passthrough tensors. Returns the computed outcomes and
/// the largest single-unit footprint.
#[allow(clippy::too_many_arguments)]
fn write_stage(
    done_rx: mpsc::Receiver<Result<Done>>,
    mut expected: VecDeque<usize>,
    writer: &mut ShardWriter,
    journal: &mut std::fs::File,
    shard_budget: u64,
    post: &dyn TensorSource,
    quant_set: &BTreeSet<&String>,
    gate: &Gate,
    live: &AtomicUsize,
    peak: &AtomicUsize,
) -> Result<(Vec<(usize, LayerOutcome)>, usize)> {
    let mut pending: BTreeMap<usize, Done> = BTreeMap::new();
    let mut computed: Vec<(usize, LayerOutcome)> = Vec::new();
    let mut pending_lines = String::new();
    let mut max_unit = 0usize;

    let flush_lines =
        |journal: &mut std::fs::File, lines: &mut String| -> Result<()> {
            if !lines.is_empty() {
                journal.write_all(lines.as_bytes())?;
                journal.sync_data()?;
                lines.clear();
            }
            Ok(())
        };

    for msg in done_rx {
        let d = msg?;
        pending.insert(d.idx, d);
        while let Some(&idx) = expected.front() {
            let Some(d) = pending.remove(&idx) else { break };
            expected.pop_front();
            let Done { outcome, q, deq, out_bytes, footprint, .. } = d;
            max_unit = max_unit.max(footprint);
            let name = outcome.name.clone();
            writer.append(
                &format!("{name}.codes"),
                &crate::io::dts::DtsTensor::U8 {
                    shape: vec![q.shape.0, q.shape.1],
                    data: q.codes,
                },
            )?;
            writer.append(
                &format!("{name}.scales"),
                &crate::io::dts::DtsTensor::F32 {
                    shape: vec![q.scales.grid_rows, q.scales.grid_cols],
                    data: q.scales.scales,
                },
            )?;
            writer.append(
                &name,
                &crate::io::dts::DtsTensor::F32 {
                    shape: deq.shape().to_vec(),
                    data: deq.into_data(),
                },
            )?;
            pending_lines.push_str(&layer_line(
                &outcome,
                &shard_file_name(writer.current_shard_index()),
            ));
            computed.push((idx, outcome));
            sub_live(live, out_bytes);
            gate.release();
            if writer.current_bytes() >= shard_budget {
                // journal before finalizing: a finalized shard's layers
                // are always recorded (resume safety invariant)
                flush_lines(journal, &mut pending_lines)?;
                writer.roll()?;
            }
        }
    }
    if !expected.is_empty() {
        bail!(
            "{} layers were never quantized (worker terminated early)",
            expected.len()
        );
    }

    // passthrough: every non-quantizable tensor of the post checkpoint,
    // streamed one at a time
    for name in post.names() {
        if quant_set.contains(&name) || writer.contains(&name) {
            continue;
        }
        let t = post.read_tensor(&name)?;
        let bytes = t.nbytes();
        max_unit = max_unit.max(bytes);
        add_live(live, peak, bytes);
        writer.append(&name, &t)?;
        drop(t);
        sub_live(live, bytes);
        if writer.current_bytes() >= shard_budget {
            flush_lines(journal, &mut pending_lines)?;
            writer.roll()?;
        }
    }

    flush_lines(journal, &mut pending_lines)?;
    writer.roll()?;
    Ok((computed, max_unit))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::Objective;

    #[test]
    fn gate_bounds_and_closes() {
        let g = Gate::new(2);
        assert!(g.acquire());
        assert!(g.acquire());
        // third acquire would block; release then acquire succeeds
        g.release();
        assert!(g.acquire());
        g.close();
        assert!(!g.acquire(), "closed gate must refuse permits");
        // a blocked acquire wakes on close
        let g = std::sync::Arc::new(Gate::new(0));
        let g2 = std::sync::Arc::clone(&g);
        let h = std::thread::spawn(move || g2.acquire());
        std::thread::sleep(std::time::Duration::from_millis(20));
        g.close();
        assert!(!h.join().unwrap());
    }

    #[test]
    fn transformed_methods_rejected() {
        let d = crate::io::dts::Dts::new();
        let cfg = StreamConfig::new(
            Granularity::PerChannel,
            Method::SmoothQuant { alpha: 0.5 },
            1,
        );
        let dir = std::env::temp_dir()
            .join(format!("daq_stream_reject_{}", std::process::id()));
        let err = run_stream(&d, &d, &[], &dir, &cfg).unwrap_err();
        assert!(format!("{err:#}").contains("delta methods"), "{err:#}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn journal_layer_line_roundtrips_exactly() {
        let outcome = LayerOutcome {
            name: "l0.wq".into(),
            shape: (96, 64),
            alpha: 1.0700000524520874f32, // not exactly representable noise
            evals: 16,
            stats: Some(DeltaStats {
                agree: 6143.0,
                dot: 0.1234567890123456789,
                nq: 1.0 / 3.0,
                npost: 2.5e-7,
                sq: 9.87e-12,
                n: 6144.0,
            }),
            secs: 0.125,
        };
        let line = layer_line(&outcome, "shard_00003.dts");
        let j = Json::parse(line.trim()).unwrap();
        let back = parse_layer_line(&j).unwrap();
        assert_eq!(back.name, outcome.name);
        assert_eq!(back.shape, outcome.shape);
        assert_eq!(back.alpha.to_bits(), outcome.alpha.to_bits());
        assert_eq!(back.evals, outcome.evals);
        let (a, b) = (back.stats.unwrap(), outcome.stats.unwrap());
        for (x, y) in [
            (a.agree, b.agree),
            (a.dot, b.dot),
            (a.nq, b.nq),
            (a.npost, b.npost),
            (a.sq, b.sq),
            (a.n, b.n),
        ] {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(j.get("shard").unwrap().as_str(), Some("shard_00003.dts"));
    }

    #[test]
    fn journal_parser_skips_truncated_tail() {
        let cfg = StreamConfig::new(
            Granularity::Block(16),
            Method::Search { objective: Objective::SignRate, range: (0.8, 1.25) },
            1,
        );
        let full = layer_line(
            &LayerOutcome {
                name: "a".into(),
                shape: (4, 4),
                alpha: 1.0,
                evals: 16,
                stats: Some(DeltaStats::default()),
                secs: 0.0,
            },
            "shard_00000.dts",
        );
        let text = format!(
            "{}{}{}",
            config_line(&cfg),
            full,
            &full[..full.len() / 2] // torn write at the tail
        );
        let (config, layers) = parse_journal(&text);
        assert!(config.is_some());
        assert_eq!(layers.len(), 1);
        assert!(layers.contains_key("a"));
    }
}
