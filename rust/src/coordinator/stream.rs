//! Bounded-memory streaming quantization driver.
//!
//! A three-stage pipeline over a sharded (or seek-based monolithic)
//! checkpoint pair, scheduled unit-by-unit off a
//! [`GroupPlan`](super::group::GroupPlan):
//!
//! 1. a **prefetch** thread pulls whole units through a depth-`K`
//!    admission gate — a unit is a single `(base, post)` layer pair for
//!    the delta methods, or a layernorm-coupled transform group (the
//!    members' post weights, the calibration statistic, and the ln
//!    affine) for SmoothQuant/AWQ,
//! 2. a small worker pool quantizes each unit with exactly the shared
//!    unit of work the in-memory pipeline uses
//!    ([`super::quantize_delta_layer`] / [`super::quantize_transform_unit`]),
//!    so results are **bitwise-identical** to [`super::run_pipeline`],
//! 3. a **writer** thread streams each unit's tensors (per-member
//!    `codes` / `scales` / dequantized weights, plus the folded
//!    layernorm affine for groups) into output shards in fixed unit
//!    order, dropping them as soon as they are written.
//!
//! A unit's admission permit is held from the moment its tensors are
//! read until the writer has persisted and dropped them, so peak live
//! tensor bytes are bounded by `K · (largest unit footprint)` — for the
//! transform baselines that is O(largest group), not O(model), which is
//! what lets SmoothQuant/AWQ stream at all (the layernorm fold couples
//! every GEMM in a group, so the previous per-layer driver rejected
//! them). The measured peak and the largest per-unit footprint are
//! reported in [`StreamOutcome`] and asserted by the residency test.
//!
//! **Resume.** The writer journals per-unit completion (member outcomes
//! with exact f64 sufficient statistics where defined, owning shard) as
//! JSON lines in `resume.jsonl`. Shards roll only at unit boundaries and
//! journal lines are flushed *before* the shard holding them is
//! finalized (tmp + rename), so a unit's tensors land in finalized
//! shards all-or-nothing and after an interruption every finalized
//! shard's units are recorded; at most a discardable `.part` payload is
//! lost. `run_stream` with `resume = true` skips the recorded units,
//! reuses their journaled outcomes (Rust's shortest `Display` repr
//! round-trips f64 exactly), and converges to the same per-tensor bytes
//! as an uninterrupted run — including after an interruption mid-group.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Condvar, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use crate::io::dts::DtsTensor;
use crate::io::shard::{shard_file_name, ShardWriter};
use crate::io::TensorSource;
use crate::metrics::DeltaStats;
use crate::quant::{CodeFormat, Descriptor, Granularity, QuantizedTensor};
use crate::search::TiledSweep;
use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::telemetry::{self, Snapshot, Telemetry};
use crate::util::timer::time;

use super::group::{GroupPlan, GroupSource, Unit};
use super::{quantize_delta_layer, quantize_transform_unit, LayerOutcome, Method};

/// Journal file name inside the output directory.
pub const RESUME_JOURNAL: &str = "resume.jsonl";

#[derive(Clone, Debug)]
pub struct StreamConfig {
    pub granularity: Granularity,
    /// Any pipeline method. Delta methods (`AbsMax` / `Search`) stream
    /// layer-at-a-time; the transform baselines (`SmoothQuant` / `Awq`)
    /// stream group-at-a-time and require calibration stats.
    pub method: Method,
    /// Total worker budget, split between unit- and tile-parallelism.
    pub workers: usize,
    /// Code format the delta methods quantize into; the transform
    /// baselines always store FP8 E4M3 (other formats are rejected up
    /// front, mirroring the in-memory pipeline).
    pub format: CodeFormat,
    /// Rank of the optional low-rank residual correction (0 = none);
    /// delta methods only.
    pub residual_rank: usize,
    /// K: maximum units admitted (read but not yet written).
    pub depth: usize,
    /// Output shard payload budget in bytes.
    pub shard_budget: u64,
    /// Skip units recorded in the output directory's resume journal.
    pub resume: bool,
    /// Where transform groups come from: the model naming convention
    /// (default), an explicit `--groups` manifest, a traced dataflow
    /// graph (`daq trace` sidecar), or both cross-checked against each
    /// other.
    pub groups: GroupSource,
    /// Retries per read for *transient* faults (network blips, injected
    /// chaos) with exponential backoff; persistent corruption is never
    /// retried — it quarantines the unit instead.
    pub max_retries: usize,
    /// Backoff before retry `k` is `retry_base_ms << (k-1)` milliseconds.
    pub retry_base_ms: u64,
    /// Per-payload CRC-32 checksums in the output shards (v2 containers).
    /// On by default; the bench turns it off to isolate the overhead.
    pub checksums: bool,
    /// Snapshot the telemetry registry to this file at every shard-roll
    /// boundary (`--metrics-out metrics.json`). The snapshot is a whole
    /// document rewrite, so a crashed run leaves the last consistent one.
    pub metrics_out: Option<PathBuf>,
}

impl StreamConfig {
    pub fn new(granularity: Granularity, method: Method, workers: usize) -> Self {
        StreamConfig {
            granularity,
            method,
            workers: workers.max(1),
            format: CodeFormat::Fp8E4m3,
            residual_rank: 0,
            depth: workers.max(2),
            shard_budget: crate::io::shard::DEFAULT_SHARD_MB << 20,
            resume: false,
            groups: GroupSource::Patterns,
            max_retries: 3,
            retry_base_ms: 10,
            checksums: true,
            metrics_out: None,
        }
    }
}

/// Outcome of a streaming run.
pub struct StreamOutcome {
    /// Per-layer outcomes in plan order (journaled values for resumed
    /// units, freshly computed for the rest).
    pub layers: Vec<LayerOutcome>,
    /// Model-level aggregate, merged in fixed layer order. None for the
    /// transform baselines, whose delta metrics are undefined (paper
    /// Table 2 footnote ‡).
    pub agg: Option<DeltaStats>,
    /// Path of the written sharded-store manifest.
    pub manifest: PathBuf,
    /// Layers skipped via the resume journal.
    pub resumed: usize,
    /// Measured peak of concurrently live tensor bytes.
    pub peak_live_bytes: usize,
    /// Largest single-unit footprint (a layer pair, a whole transform
    /// group, or one passthrough tensor, plus its outputs).
    /// `peak_live_bytes <= depth * this` holds.
    pub max_unit_bytes: usize,
    /// Labels of units (and names of passthrough tensors) skipped because
    /// their inputs are persistently corrupted. Each is recorded in the
    /// journal; a resume after repairing the source re-quantizes exactly
    /// these.
    pub quarantined: Vec<String>,
    pub total_secs: f64,
    /// End-of-run view of the run's telemetry registry (phase spans,
    /// fault counters). Empty when no telemetry context was installed.
    pub telemetry: Snapshot,
}

// ---------------------------------------------------------------------
// admission gate: a closable counting semaphore

struct Gate {
    state: Mutex<(usize, bool)>,
    cv: Condvar,
}

impl Gate {
    fn new(permits: usize) -> Gate {
        Gate { state: Mutex::new((permits, false)), cv: Condvar::new() }
    }

    /// Blocks for a permit; returns `false` if the gate was closed.
    fn acquire(&self) -> bool {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.1 {
                return false;
            }
            if st.0 > 0 {
                st.0 -= 1;
                return true;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    fn release(&self) {
        let mut st = self.state.lock().unwrap();
        st.0 += 1;
        self.cv.notify_all();
    }

    /// Wake all waiters and make every future `acquire` fail (abort path).
    fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.1 = true;
        self.cv.notify_all();
    }
}

fn add_live(live: &AtomicUsize, peak: &AtomicUsize, bytes: usize) {
    let now = live.fetch_add(bytes, Ordering::SeqCst) + bytes;
    peak.fetch_max(now, Ordering::SeqCst);
}

fn sub_live(live: &AtomicUsize, bytes: usize) {
    live.fetch_sub(bytes, Ordering::SeqCst);
}

// ---------------------------------------------------------------------
// resume journal lines

fn config_line(cfg: &StreamConfig) -> String {
    let mut c = BTreeMap::new();
    c.insert("gran".to_string(), Json::Str(cfg.granularity.label()));
    c.insert("method".to_string(), Json::Str(cfg.method.label()));
    c.insert("format".to_string(), Json::Str(cfg.format.label()));
    c.insert("res".to_string(), Json::Num(cfg.residual_rank as f64));
    let mut o = BTreeMap::new();
    o.insert("config".to_string(), Json::Obj(c));
    format!("{}\n", Json::Obj(o))
}

/// Journal fields of one member outcome. `stats` is present only for
/// delta methods (it is undefined for the transform baselines).
fn outcome_fields(l: &LayerOutcome) -> BTreeMap<String, Json> {
    let mut o = BTreeMap::new();
    o.insert("layer".to_string(), Json::Str(l.name.clone()));
    o.insert("rows".to_string(), Json::Num(l.shape.0 as f64));
    o.insert("cols".to_string(), Json::Num(l.shape.1 as f64));
    o.insert("alpha".to_string(), Json::Num(l.alpha as f64));
    o.insert("evals".to_string(), Json::Num(l.evals as f64));
    o.insert("secs".to_string(), Json::Num(l.secs));
    if let Some(stats) = &l.stats {
        let mut st = BTreeMap::new();
        st.insert("agree".to_string(), Json::Num(stats.agree));
        st.insert("dot".to_string(), Json::Num(stats.dot));
        st.insert("nq".to_string(), Json::Num(stats.nq));
        st.insert("npost".to_string(), Json::Num(stats.npost));
        st.insert("sq".to_string(), Json::Num(stats.sq));
        st.insert("n".to_string(), Json::Num(stats.n));
        o.insert("stats".to_string(), Json::Obj(st));
    }
    o
}

/// Singleton-unit journal line (delta layers and non-foldable transform
/// layers): the member fields flattened at top level, as in PR 2.
fn layer_line(l: &LayerOutcome, shard: &str) -> String {
    let mut o = outcome_fields(l);
    o.insert("shard".to_string(), Json::Str(shard.to_string()));
    format!("{}\n", Json::Obj(o))
}

/// Group-unit journal line: the unit label plus one member object per
/// quantized GEMM, all owned by one shard (units never span shards).
fn unit_line(label: &str, outcomes: &[LayerOutcome], shard: &str) -> String {
    let mut o = BTreeMap::new();
    o.insert("unit".to_string(), Json::Str(label.to_string()));
    o.insert(
        "members".to_string(),
        Json::Arr(outcomes.iter().map(|l| Json::Obj(outcome_fields(l))).collect()),
    );
    o.insert("shard".to_string(), Json::Str(shard.to_string()));
    format!("{}\n", Json::Obj(o))
}

fn parse_outcome(j: &Json) -> Option<LayerOutcome> {
    let name = j.get("layer")?.as_str()?.to_string();
    let stats = match j.get("stats") {
        Some(st) => Some(DeltaStats {
            agree: st.get("agree")?.as_f64()?,
            dot: st.get("dot")?.as_f64()?,
            nq: st.get("nq")?.as_f64()?,
            npost: st.get("npost")?.as_f64()?,
            sq: st.get("sq")?.as_f64()?,
            n: st.get("n")?.as_f64()?,
        }),
        None => None,
    };
    Some(LayerOutcome {
        name,
        shape: (j.get("rows")?.as_usize()?, j.get("cols")?.as_usize()?),
        alpha: j.get("alpha")?.as_f64()? as f32,
        evals: j.get("evals")?.as_usize()?,
        stats,
        secs: j.get("secs")?.as_f64()?,
    })
}

/// Quarantine journal line: a structured record that a unit was skipped
/// because its inputs are persistently corrupted. `parse_journal` ignores
/// these (no `unit`/`layer` key), so a resumed run re-plans the unit —
/// which is exactly right once the source is repaired.
fn quarantine_line(label: &str, error: &str) -> String {
    let mut o = BTreeMap::new();
    o.insert("quarantined".to_string(), Json::Str(label.to_string()));
    o.insert("error".to_string(), Json::Str(error.to_string()));
    format!("{}\n", Json::Obj(o))
}

/// Parse a journal: (config json if present, last record per unit label —
/// a singleton layer's label is its name). Malformed lines (e.g. a
/// truncated tail) are skipped.
fn parse_journal(text: &str) -> (Option<Json>, BTreeMap<String, Vec<LayerOutcome>>) {
    let mut config = None;
    let mut units = BTreeMap::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let Ok(j) = Json::parse(line) else { continue };
        if let Some(c) = j.get("config") {
            config.get_or_insert_with(|| c.clone());
        } else if let Some(label) = j.get("unit").and_then(|u| u.as_str()) {
            let Some(members) = j.get("members").and_then(|m| m.as_arr()) else {
                continue;
            };
            let outcomes: Option<Vec<LayerOutcome>> =
                members.iter().map(parse_outcome).collect();
            if let Some(outcomes) = outcomes {
                units.insert(label.to_string(), outcomes);
            }
        } else if let Some(l) = parse_outcome(&j) {
            units.insert(l.name.clone(), vec![l]);
        }
    }
    (config, units)
}

// ---------------------------------------------------------------------
// fault handling

/// Run `f`, retrying *transient* faults up to `cfg.max_retries` times
/// with exponential backoff (`retry_base_ms << attempt`). Anything else
/// — persistent corruption, missing tensors — propagates immediately.
fn read_with_retry<T>(
    cfg: &StreamConfig,
    mut f: impl FnMut() -> Result<T>,
) -> Result<T> {
    let mut attempt = 0usize;
    loop {
        match f() {
            Ok(v) => return Ok(v),
            Err(e) if attempt < cfg.max_retries && crate::io::fault::is_transient(&e) => {
                attempt += 1;
                // retries are rare by construction; registry lookups here
                // are off the hot path
                let tel = telemetry::current();
                tel.counter("stream.retries").incr();
                tel.event(
                    "stream.retry",
                    &[
                        ("attempt", telemetry::field(attempt)),
                        ("error", telemetry::field(format!("{e:#}"))),
                    ],
                );
                let shift = (attempt - 1).min(10) as u32;
                let delay = cfg.retry_base_ms.saturating_mul(1 << shift);
                if delay > 0 {
                    std::thread::sleep(std::time::Duration::from_millis(delay));
                }
            }
            Err(e) => return Err(e),
        }
    }
}

/// Should this prefetch error quarantine the unit rather than abort the
/// whole run? Corruption is per-unit damage — the store can keep making
/// progress and a resume after repair re-quantizes the unit. Anything
/// else (missing tensors, shape mismatches, bad grouping) is a
/// configuration error that poisons the run and must abort loudly.
/// String-matched because the vendored `anyhow` has no typed chain.
fn is_quarantinable(e: &anyhow::Error) -> bool {
    let s = format!("{e:#}");
    s.contains("checksum mismatch")
        || s.contains("payload of") // truncated/torn payload read
        || s.contains(crate::io::fault::PERSISTENT_MARKER)
        || s.contains(crate::io::fault::TRANSIENT_MARKER) // retries exhausted
}

// ---------------------------------------------------------------------
// pipeline stages

/// A prefetched unit in flight.
struct UnitJob {
    idx: usize,
    unit: Unit,
    /// Member post weights (and base weights for delta methods).
    members: Vec<(String, Tensor, Option<Tensor>)>,
    /// Calibration statistic for group units.
    act: Option<Vec<f32>>,
    /// Upstream layernorm (gain, bias) for group units.
    ln_params: Option<(Tensor, Tensor)>,
    in_bytes: usize,
}

/// A quantized unit awaiting the writer.
struct Done {
    idx: usize,
    unit: Unit,
    outcomes: Vec<LayerOutcome>,
    /// Tensors to persist, in write order.
    tensors: Vec<(String, DtsTensor)>,
    out_bytes: usize,
    /// input + output bytes: this unit's peak contribution.
    footprint: usize,
}

/// What the writer receives for each scheduled unit: its quantized
/// tensors, or notice that the prefetcher quarantined it.
enum UnitResult {
    Done(Done),
    Quarantined { idx: usize, label: String, error: String },
}

struct WriterOut {
    writer: ShardWriter,
    computed: Vec<(usize, Vec<LayerOutcome>)>,
    max_unit_bytes: usize,
    quarantined: Vec<String>,
}

/// Transform baselines are exactly the methods whose delta metrics are
/// undefined (`Method::delta_defined` is the single source of truth for
/// the classification).
fn is_transform(method: &Method) -> bool {
    !method.delta_defined()
}

/// Quantize one unit into its output tensors — stage-2 worker body.
/// Returns the per-member outcomes and the serialized tensors in write
/// order.
fn quantize_unit(
    unit: &Unit,
    members: Vec<(String, Tensor, Option<Tensor>)>,
    act: Option<Vec<f32>>,
    ln_params: Option<(Tensor, Tensor)>,
    cfg: &StreamConfig,
    engine: &TiledSweep,
) -> Result<(Vec<LayerOutcome>, Vec<(String, DtsTensor)>)> {
    if is_transform(&cfg.method) {
        let post_members: Vec<(String, Tensor)> =
            members.into_iter().map(|(name, wp, _)| (name, wp)).collect();
        let out = quantize_transform_unit(
            unit,
            &post_members,
            act.as_deref(),
            ln_params,
            &cfg.method,
            cfg.granularity,
        )?;
        // the folded affine persists under the unit's stored names
        let fold = match (unit, out.ln_fold) {
            (Unit::Group { gain, bias, .. }, Some((g, b))) => {
                Some((gain.clone(), bias.clone(), g, b))
            }
            _ => None,
        };
        Ok((out.outcomes, unit_tensors(out.quantized, fold)))
    } else {
        let (name, wp, wb) = members
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("delta unit with no members"))?;
        let wb = wb.ok_or_else(|| anyhow!("{name}: missing base weight"))?;
        let (outcome, q) = quantize_delta_layer(
            &name,
            &wp,
            &wb,
            &cfg.method,
            cfg.granularity,
            cfg.format,
            cfg.residual_rank,
            engine,
        );
        Ok((vec![outcome], unit_tensors(vec![(name, q)], None)))
    }
}

/// Serialize a quantized unit into the tensors the store persists.
/// `ln_fold` is `(gain name, bias name, folded gain, folded bias)`.
fn unit_tensors(
    quantized: Vec<(String, QuantizedTensor)>,
    ln_fold: Option<(String, String, Tensor, Tensor)>,
) -> Vec<(String, DtsTensor)> {
    let mut tensors = Vec::with_capacity(quantized.len() * 5 + 2);
    for (name, q) in quantized {
        let deq = q.dequantize();
        let fmt = q.format();
        tensors.push((
            format!("{name}.codes"),
            DtsTensor::U8 {
                shape: vec![q.shape.0, fmt.packed_row_bytes(q.shape.1)],
                data: q.codes,
            },
        ));
        tensors.push((
            format!("{name}.scales"),
            DtsTensor::F32 {
                shape: vec![q.scales.grid_rows, q.scales.grid_cols],
                data: q.scales.scales,
            },
        ));
        if let Some(lr) = q.residual {
            tensors.push((
                format!("{name}.res_u"),
                DtsTensor::F32 { shape: vec![q.shape.0, lr.k], data: lr.u },
            ));
            tensors.push((
                format!("{name}.res_v"),
                DtsTensor::F32 { shape: vec![lr.k, q.shape.1], data: lr.v },
            ));
        }
        tensors.push((
            name,
            DtsTensor::F32 { shape: deq.shape().to_vec(), data: deq.into_data() },
        ));
    }
    if let Some((gain_name, bias_name, gain, bias)) = ln_fold {
        tensors.push((
            gain_name,
            DtsTensor::F32 { shape: gain.shape().to_vec(), data: gain.into_data() },
        ));
        tensors.push((
            bias_name,
            DtsTensor::F32 { shape: bias.shape().to_vec(), data: bias.into_data() },
        ));
    }
    tensors
}

/// Run the streaming pipeline: quantize `quantizable` layers of `post`
/// (against `base` for delta methods; using `calib` activation stats for
/// the transform baselines) into a sharded store at `out_dir` (shards +
/// resume journal + manifest), never holding more than `cfg.depth` units
/// in memory.
pub fn run_stream(
    post: &dyn TensorSource,
    base: &dyn TensorSource,
    quantizable: &[String],
    calib: Option<&dyn TensorSource>,
    out_dir: &Path,
    cfg: &StreamConfig,
) -> Result<StreamOutcome> {
    if is_transform(&cfg.method) {
        if calib.is_none() {
            bail!(
                "{} requires calibration stats (pass an activation-stat \
                 sidecar via --calib)",
                cfg.method.label()
            );
        }
        if cfg.format != CodeFormat::Fp8E4m3 || cfg.residual_rank > 0 {
            bail!(
                "--format / --residual-rank only apply to the delta methods \
                 (absmax / search): {} always stores fp8-e4m3 without a \
                 residual",
                cfg.method.label()
            );
        }
    } else if !cfg.groups.is_patterns() {
        bail!(
            "--groups / --group-source only apply to the transform baselines \
             (smoothquant / awq)"
        );
    }

    let tel = telemetry::current();
    let (out, total_secs) =
        time(|| run_stream_inner(post, base, quantizable, calib, out_dir, cfg));
    let mut out = out?;
    out.total_secs = total_secs;
    out.telemetry = tel.snapshot();
    if let Some(p) = &cfg.metrics_out {
        tel.write_metrics_file(p)?;
    }
    Ok(out)
}

fn run_stream_inner(
    post: &dyn TensorSource,
    base: &dyn TensorSource,
    quantizable: &[String],
    calib: Option<&dyn TensorSource>,
    out_dir: &Path,
    cfg: &StreamConfig,
) -> Result<StreamOutcome> {
    let plan = if is_transform(&cfg.method) {
        GroupPlan::resolve(post, quantizable, &cfg.groups)?
    } else {
        GroupPlan::delta(quantizable)
    };

    // index-only calibration validation: a sidecar missing a group's
    // stat must fail here, at plan time, not hours into the run when the
    // prefetch thread finally reaches that group
    if let Some(calib) = calib {
        for unit in &plan.units {
            let Unit::Group { ln, members, .. } = unit else { continue };
            let first = &members[0];
            let rows = post.shape_of(first).map(|s| s[0]).unwrap_or(0);
            match calib.shape_of(first) {
                Some(s) if s.len() == 1 && s[0] == rows => {}
                Some(s) => bail!(
                    "group {ln:?}: calib stat for {first:?} has shape {s:?}, \
                     wanted [{rows}] (one value per input channel)"
                ),
                None => bail!(
                    "group {ln:?}: calibration sidecar has no stat for first \
                     member {first:?}"
                ),
            }
        }
    }

    let journal_path = out_dir.join(RESUME_JOURNAL);

    // -- writer + resume state -----------------------------------------
    let (mut shard_writer, resumed_units) = if cfg.resume {
        let w = ShardWriter::resume(out_dir, cfg.shard_budget)?;
        let text = std::fs::read_to_string(&journal_path).unwrap_or_default();
        let (config, mut recorded) = parse_journal(&text);
        if let Some(c) = &config {
            let gran = c.get("gran").and_then(|g| g.as_str()).unwrap_or("");
            let method = c.get("method").and_then(|m| m.as_str()).unwrap_or("");
            // journals from before the CodeFormat API carry no format
            // fields; they were all FP8 E4M3 with no residual
            let fmt = c
                .get("format")
                .and_then(|f| f.as_str())
                .unwrap_or("fp8-e4m3")
                .to_string();
            let res = c.get("res").and_then(|r| r.as_usize()).unwrap_or(0);
            if gran != cfg.granularity.label()
                || method != cfg.method.label()
                || fmt != cfg.format.label()
                || res != cfg.residual_rank
            {
                bail!(
                    "{out_dir:?}: resume journal was written by gran={gran} \
                     method={method} format={fmt} res={res}, current run is \
                     gran={} method={} format={} res={}",
                    cfg.granularity.label(),
                    cfg.method.label(),
                    cfg.format.label(),
                    cfg.residual_rank
                );
            }
        }
        // a journaled unit is resumable iff every tensor it writes
        // survives in finalized shards; partial presence means a
        // corrupted store (units never span shards, so an interrupted
        // writer cannot produce one honestly)
        let mut resumed = BTreeMap::new();
        for unit in &plan.units {
            let label = unit.label();
            let mut written = unit.written_names();
            if cfg.residual_rank > 0 {
                // residual sidecars ride along with every member of a
                // delta unit (the transform path rejects residuals above)
                for m in unit.members() {
                    written.push(format!("{m}.res_u"));
                    written.push(format!("{m}.res_v"));
                }
            }
            let present = written.iter().filter(|p| w.contains(p)).count();
            if present == written.len() {
                match recorded.remove(&label) {
                    Some(outcomes) => {
                        let got: Vec<&String> =
                            outcomes.iter().map(|o| &o.name).collect();
                        let want: Vec<&String> = unit.members().iter().collect();
                        if got != want {
                            bail!(
                                "{out_dir:?}: unit {label:?} was journaled with \
                                 members {got:?} but the current plan expects \
                                 {want:?} — the grouping changed; remove the \
                                 directory and rerun"
                            );
                        }
                        resumed.insert(label, outcomes);
                    }
                    None => bail!(
                        "{out_dir:?}: unit {label:?} is present in shards but \
                         missing from the resume journal; remove the directory \
                         and rerun"
                    ),
                }
            } else if present != 0 {
                bail!(
                    "{out_dir:?}: unit {label:?} is only partially present in \
                     shards; remove the directory and rerun"
                );
            }
        }
        (w, resumed)
    } else {
        (ShardWriter::create(out_dir, cfg.shard_budget)?, BTreeMap::new())
    };
    shard_writer.set_checksums(cfg.checksums);

    let mut journal = if cfg.resume {
        std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&journal_path)
            .with_context(|| format!("open {journal_path:?}"))?
    } else {
        std::fs::File::create(&journal_path)
            .with_context(|| format!("create {journal_path:?}"))?
    };
    if !cfg.resume || resumed_units.is_empty() {
        journal.write_all(config_line(cfg).as_bytes())?;
        journal.flush()?;
    }

    // -- plan the work -------------------------------------------------
    let resumed_count: usize = resumed_units.values().map(|v| v.len()).sum();
    let mut slots: Vec<Option<Vec<LayerOutcome>>> =
        Vec::with_capacity(plan.units.len());
    let mut todo: Vec<(usize, Unit)> = Vec::new();
    for (idx, unit) in plan.units.iter().enumerate() {
        match resumed_units.get(&unit.label()) {
            Some(outcomes) => slots.push(Some(outcomes.clone())),
            None => {
                slots.push(None);
                todo.push((idx, unit.clone()));
            }
        }
    }
    let expected: VecDeque<usize> = todo.iter().map(|&(i, _)| i).collect();

    let depth = cfg.depth.max(1);
    let outer = cfg.workers.clamp(1, depth.min(todo.len().max(1)));
    let intra = (cfg.workers / outer).max(1);
    let delta_method = !is_transform(&cfg.method);

    let gate = Gate::new(depth);
    let live = AtomicUsize::new(0);
    let peak = AtomicUsize::new(0);
    let quant_set: BTreeSet<&String> = quantizable.iter().collect();

    let (job_tx, job_rx) = mpsc::channel::<Result<UnitJob>>();
    let job_rx = Mutex::new(job_rx);
    let (done_tx, done_rx) = mpsc::channel::<Result<UnitResult>>();

    let (gate, live, peak, job_rx) = (&gate, &live, &peak, &job_rx);

    // scoped threads don't inherit the spawner's thread-local telemetry;
    // re-install the run's instance on every stage thread
    let tel = telemetry::current();

    let writer_out: Result<WriterOut> = std::thread::scope(|s| {
        // stage 1: prefetch whole units through the gate, retrying
        // transient faults and quarantining persistently corrupt units
        let prefetch_done_tx = done_tx.clone();
        let tel_prefetch = tel.clone();
        s.spawn(move || {
            let _tg = telemetry::set_current(tel_prefetch.clone());
            for (idx, unit) in todo {
                let admitted = {
                    let _s = tel_prefetch.span("stream.gate_wait");
                    gate.acquire()
                };
                if !admitted {
                    return; // aborted by the writer
                }
                let read_span = crate::span!(
                    tel_prefetch,
                    "stream.read",
                    "unit" = unit.label(),
                );
                let msg = read_with_retry(cfg, || -> Result<UnitJob> {
                    let mut in_bytes = 0usize;
                    let mut members = Vec::with_capacity(unit.members().len());
                    for name in unit.members() {
                        let wp = post.tensor_f32(name)?;
                        let wb = if delta_method {
                            let wb = base.tensor_f32(name)?;
                            if wp.shape() != wb.shape() {
                                bail!(
                                    "{name}: post {:?} vs base {:?}",
                                    wp.shape(),
                                    wb.shape()
                                );
                            }
                            in_bytes += wb.len() * 4;
                            Some(wb)
                        } else {
                            None
                        };
                        in_bytes += wp.len() * 4;
                        members.push((name.clone(), wp, wb));
                    }
                    let (act, ln_params) = match &unit {
                        Unit::Group { gain, bias, members: names, .. } => {
                            let calib = calib
                                .ok_or_else(|| anyhow!("calib source required"))?;
                            let act = calib
                                .tensor_f32(&names[0])
                                .map_err(|e| {
                                    anyhow!("calib stats for {}: {e}", names[0])
                                })?
                                .into_data();
                            let gain = post.tensor_f32(gain)?;
                            let bias = post.tensor_f32(bias)?;
                            in_bytes += (act.len() + gain.len() + bias.len()) * 4;
                            (Some(act), Some((gain, bias)))
                        }
                        Unit::Layer { .. } => (None, None),
                    };
                    add_live(live, peak, in_bytes);
                    Ok(UnitJob { idx, unit: unit.clone(), members, act, ln_params, in_bytes })
                });
                drop(read_span);
                match msg {
                    Ok(job) => {
                        if job_tx.send(Ok(job)).is_err() {
                            return;
                        }
                    }
                    Err(e) if is_quarantinable(&e) => {
                        // the writer journals it, releases the permit,
                        // and the pipeline moves on
                        let q = UnitResult::Quarantined {
                            idx,
                            label: unit.label(),
                            error: format!("{e:#}"),
                        };
                        if prefetch_done_tx.send(Ok(q)).is_err() {
                            return;
                        }
                    }
                    Err(e) => {
                        let _ = job_tx.send(Err(e));
                        return;
                    }
                }
            }
        });

        // stage 2: quantize on `outer` workers × `intra` tile threads
        for _ in 0..outer {
            let done_tx = done_tx.clone();
            let tel_worker = tel.clone();
            s.spawn(move || {
                let _tg = telemetry::set_current(tel_worker.clone());
                let engine = TiledSweep::new(intra);
                loop {
                    let msg = job_rx.lock().unwrap().recv();
                    let job = match msg {
                        Err(_) => break, // prefetch done
                        Ok(Err(e)) => {
                            let _ = done_tx.send(Err(e));
                            break;
                        }
                        Ok(Ok(j)) => j,
                    };
                    let UnitJob { idx, unit, members, act, ln_params, in_bytes } = job;
                    let quantized = {
                        let _s = crate::span!(
                            tel_worker,
                            "stream.compute",
                            "unit" = unit.label(),
                        );
                        quantize_unit(&unit, members, act, ln_params, cfg, &engine)
                    };
                    let (outcomes, tensors) = match quantized {
                        Ok(v) => v,
                        Err(e) => {
                            let _ = done_tx.send(Err(e));
                            break;
                        }
                    };
                    let out_bytes: usize =
                        tensors.iter().map(|(_, t)| t.nbytes()).sum();
                    add_live(live, peak, out_bytes);
                    sub_live(live, in_bytes);
                    let d = Done {
                        idx,
                        unit,
                        outcomes,
                        tensors,
                        out_bytes,
                        footprint: in_bytes + out_bytes,
                    };
                    if done_tx.send(Ok(UnitResult::Done(d))).is_err() {
                        break;
                    }
                }
            });
        }
        drop(done_tx);

        // stage 3: write completed units in fixed plan order
        let tel_writer = tel.clone();
        let h = s.spawn(move || -> Result<WriterOut> {
            let _tg = telemetry::set_current(tel_writer);
            let r = write_stage(
                done_rx,
                expected,
                &mut shard_writer,
                &mut journal,
                cfg,
                post,
                &quant_set,
                gate,
                live,
                peak,
            );
            if r.is_err() {
                gate.close();
            }
            r.map(|(computed, max_unit_bytes, quarantined)| WriterOut {
                writer: shard_writer,
                computed,
                max_unit_bytes,
                quarantined,
            })
        });
        match h.join() {
            Ok(r) => r,
            Err(p) => std::panic::resume_unwind(p),
        }
    });
    let WriterOut { writer, computed, max_unit_bytes, quarantined } = writer_out?;

    for (idx, outcomes) in computed {
        slots[idx] = Some(outcomes);
    }
    let mut layers: Vec<LayerOutcome> = Vec::with_capacity(quantizable.len());
    for (i, slot) in slots.into_iter().enumerate() {
        match slot {
            Some(outcomes) => layers.extend(outcomes),
            None => {
                // quarantined units are the only legitimate gaps: they
                // were journaled and excluded from the store on purpose
                let label = plan.units[i].label();
                if !quarantined.iter().any(|q| q == &label) {
                    bail!("unit {label:?} was never quantized");
                }
            }
        }
    }

    let agg = if cfg.method.delta_defined() {
        let mut a = DeltaStats::default();
        for l in &layers {
            a = a.merge(l.stats.as_ref().expect("delta stats defined"));
        }
        Some(a)
    } else {
        None
    };

    // store-level metadata, mirroring `PipelineOutcome::write_checkpoint`:
    // one structured `fmt.<name>` descriptor per quantized tensor. The
    // whole run shares one (format, granularity, rank) triple, so the
    // descriptor only varies in its per-tensor `cols` field.
    let mut meta = post.meta().clone();
    for l in &layers {
        meta.insert(format!("alpha.{}", l.name), l.alpha.to_string());
        let d = Descriptor {
            format: cfg.format,
            granularity: cfg.granularity,
            // same clamp `attach_residual` applies, so the descriptor
            // matches the one `write_checkpoint` derives from the tensor
            residual_rank: cfg.residual_rank.min(l.shape.0.min(l.shape.1)),
            cols: cfg.format.is_sub_byte().then_some(l.shape.1),
        };
        meta.insert(format!("fmt.{}", l.name), d.to_meta());
    }
    let manifest = writer.finish(&meta)?;

    Ok(StreamOutcome {
        layers,
        agg,
        manifest,
        resumed: resumed_count,
        peak_live_bytes: peak.load(Ordering::SeqCst),
        max_unit_bytes,
        quarantined,
        total_secs: 0.0,               // stamped by run_stream
        telemetry: Snapshot::default(), // stamped by run_stream
    })
}

/// The writer stage body: drain completed units, persist them in plan
/// order (journal lines flush before each shard roll; shards roll only at
/// unit boundaries, so a unit never spans shards), then stream the
/// non-quantizable passthrough tensors. Quarantined units are journaled
/// and skipped in order. Returns the computed outcomes, the largest
/// single-unit footprint, and the quarantined labels.
#[allow(clippy::too_many_arguments)]
fn write_stage(
    done_rx: mpsc::Receiver<Result<UnitResult>>,
    mut expected: VecDeque<usize>,
    writer: &mut ShardWriter,
    journal: &mut std::fs::File,
    cfg: &StreamConfig,
    post: &dyn TensorSource,
    quant_set: &BTreeSet<&String>,
    gate: &Gate,
    live: &AtomicUsize,
    peak: &AtomicUsize,
) -> Result<(Vec<(usize, Vec<LayerOutcome>)>, usize, Vec<String>)> {
    let shard_budget = cfg.shard_budget;
    let mut pending: BTreeMap<usize, UnitResult> = BTreeMap::new();
    let mut computed: Vec<(usize, Vec<LayerOutcome>)> = Vec::new();
    let mut quarantined: Vec<String> = Vec::new();
    let mut pending_lines = String::new();
    let mut max_unit = 0usize;

    // handles hoisted out of the drain loop: updates are lock-free
    let tel = telemetry::current();
    let quarantine_counter = tel.counter("stream.quarantined");
    let write_hist = tel.histogram("stream.write.seconds");
    let quarantine = |counter: &crate::util::telemetry::Counter,
                      label: &str,
                      error: &str| {
        counter.incr();
        tel.event(
            "stream.quarantine",
            &[
                ("unit", telemetry::field(label)),
                ("error", telemetry::field(error)),
            ],
        );
    };
    let roll_snapshot = |tel: &Telemetry| -> Result<()> {
        match &cfg.metrics_out {
            Some(p) => tel.write_metrics_file(p),
            None => Ok(()),
        }
    };

    let flush_lines =
        |journal: &mut std::fs::File, lines: &mut String| -> Result<()> {
            if !lines.is_empty() {
                journal.write_all(lines.as_bytes())?;
                journal.sync_data()?;
                lines.clear();
            }
            Ok(())
        };

    for msg in done_rx {
        let r = msg?;
        let idx = match &r {
            UnitResult::Done(d) => d.idx,
            UnitResult::Quarantined { idx, .. } => *idx,
        };
        pending.insert(idx, r);
        while let Some(&idx) = expected.front() {
            let Some(r) = pending.remove(&idx) else { break };
            expected.pop_front();
            let d = match r {
                UnitResult::Done(d) => d,
                UnitResult::Quarantined { label, error, .. } => {
                    // structured record; nothing of the unit lands in
                    // shards, so a repaired resume re-plans exactly it
                    pending_lines.push_str(&quarantine_line(&label, &error));
                    quarantine(&quarantine_counter, &label, &error);
                    quarantined.push(label);
                    gate.release();
                    continue;
                }
            };
            let Done { unit, outcomes, tensors, out_bytes, footprint, .. } = d;
            max_unit = max_unit.max(footprint);
            {
                let _s = crate::span!(tel, "stream.write", "unit" = unit.label());
                for (name, t) in &tensors {
                    writer.append(name, t)?;
                }
            }
            let shard = shard_file_name(writer.current_shard_index());
            pending_lines.push_str(&match &unit {
                Unit::Layer { .. } => layer_line(&outcomes[0], &shard),
                Unit::Group { .. } => unit_line(&unit.label(), &outcomes, &shard),
            });
            computed.push((idx, outcomes));
            drop(tensors);
            sub_live(live, out_bytes);
            gate.release();
            if writer.current_bytes() >= shard_budget {
                // journal before finalizing: a finalized shard's units
                // are always recorded (resume safety invariant)
                flush_lines(journal, &mut pending_lines)?;
                writer.roll()?;
                roll_snapshot(&tel)?;
            }
        }
    }
    if !expected.is_empty() {
        bail!(
            "{} units were never quantized (worker terminated early)",
            expected.len()
        );
    }

    // passthrough: every non-quantizable tensor of the post checkpoint
    // not already written by a unit (folded layernorm affines are),
    // streamed one at a time — with the same retry/quarantine policy as
    // the prefetcher, so one rotten embedding table doesn't kill a run
    // that already quantized the whole model
    for name in post.names() {
        if quant_set.contains(&name) || writer.contains(&name) {
            continue;
        }
        let t = match read_with_retry(cfg, || post.read_tensor(&name)) {
            Ok(t) => t,
            Err(e) if is_quarantinable(&e) => {
                let err = format!("{e:#}");
                pending_lines.push_str(&quarantine_line(&name, &err));
                quarantine(&quarantine_counter, &name, &err);
                quarantined.push(name.clone());
                continue;
            }
            Err(e) => return Err(e),
        };
        let bytes = t.nbytes();
        max_unit = max_unit.max(bytes);
        add_live(live, peak, bytes);
        {
            let _t = write_hist.start_timer();
            writer.append(&name, &t)?;
        }
        drop(t);
        sub_live(live, bytes);
        if writer.current_bytes() >= shard_budget {
            flush_lines(journal, &mut pending_lines)?;
            writer.roll()?;
            roll_snapshot(&tel)?;
        }
    }

    flush_lines(journal, &mut pending_lines)?;
    writer.roll()?;
    roll_snapshot(&tel)?;
    Ok((computed, max_unit, quarantined))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::Objective;

    #[test]
    fn gate_bounds_and_closes() {
        let g = Gate::new(2);
        assert!(g.acquire());
        assert!(g.acquire());
        // third acquire would block; release then acquire succeeds
        g.release();
        assert!(g.acquire());
        g.close();
        assert!(!g.acquire(), "closed gate must refuse permits");
        // a blocked acquire wakes on close
        let g = std::sync::Arc::new(Gate::new(0));
        let g2 = std::sync::Arc::clone(&g);
        let h = std::thread::spawn(move || g2.acquire());
        std::thread::sleep(std::time::Duration::from_millis(20));
        g.close();
        assert!(!h.join().unwrap());
    }

    #[test]
    fn transform_stream_requires_calib() {
        let d = crate::io::dts::Dts::new();
        let cfg = StreamConfig::new(
            Granularity::PerChannel,
            Method::SmoothQuant { alpha: 0.5 },
            1,
        );
        let dir = std::env::temp_dir()
            .join(format!("daq_stream_nocalib_{}", std::process::id()));
        let err = run_stream(&d, &d, &[], None, &dir, &cfg).unwrap_err();
        assert!(format!("{err:#}").contains("calibration"), "{err:#}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn groups_manifest_rejected_for_delta_methods() {
        let d = crate::io::dts::Dts::new();
        let mut cfg = StreamConfig::new(Granularity::PerChannel, Method::AbsMax, 1);
        cfg.groups =
            GroupSource::Manifest(crate::coordinator::group::GroupManifest::default());
        let dir = std::env::temp_dir()
            .join(format!("daq_stream_groups_delta_{}", std::process::id()));
        let err = run_stream(&d, &d, &[], None, &dir, &cfg).unwrap_err();
        assert!(format!("{err:#}").contains("--groups"), "{err:#}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn journal_layer_line_roundtrips_exactly() {
        let outcome = LayerOutcome {
            name: "l0.wq".into(),
            shape: (96, 64),
            alpha: 1.0700000524520874f32, // not exactly representable noise
            evals: 16,
            stats: Some(DeltaStats {
                agree: 6143.0,
                dot: 0.1234567890123456789,
                nq: 1.0 / 3.0,
                npost: 2.5e-7,
                sq: 9.87e-12,
                n: 6144.0,
            }),
            secs: 0.125,
        };
        let line = layer_line(&outcome, "shard_00003.dts");
        let j = Json::parse(line.trim()).unwrap();
        let back = parse_outcome(&j).unwrap();
        assert_eq!(back.name, outcome.name);
        assert_eq!(back.shape, outcome.shape);
        assert_eq!(back.alpha.to_bits(), outcome.alpha.to_bits());
        assert_eq!(back.evals, outcome.evals);
        let (a, b) = (back.stats.unwrap(), outcome.stats.unwrap());
        for (x, y) in [
            (a.agree, b.agree),
            (a.dot, b.dot),
            (a.nq, b.nq),
            (a.npost, b.npost),
            (a.sq, b.sq),
            (a.n, b.n),
        ] {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(j.get("shard").unwrap().as_str(), Some("shard_00003.dts"));
    }

    #[test]
    fn journal_unit_line_roundtrips_members_without_stats() {
        let outcomes = vec![
            LayerOutcome {
                name: "l0.wq".into(),
                shape: (32, 32),
                alpha: 1.0,
                evals: 1,
                stats: None,
                secs: 0.5,
            },
            LayerOutcome {
                name: "l0.wk".into(),
                shape: (32, 16),
                alpha: 1.0,
                evals: 1,
                stats: None,
                secs: 0.5,
            },
        ];
        let line = unit_line("ln:l0.ln1", &outcomes, "shard_00001.dts");
        let (_, units) = parse_journal(&line);
        let back = units.get("ln:l0.ln1").unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].name, "l0.wq");
        assert_eq!(back[1].name, "l0.wk");
        assert_eq!(back[1].shape, (32, 16));
        assert!(back.iter().all(|o| o.stats.is_none()));
    }

    #[test]
    fn journal_parser_skips_truncated_tail() {
        let cfg = StreamConfig::new(
            Granularity::Block(16),
            Method::Search { objective: Objective::SignRate, range: (0.8, 1.25) },
            1,
        );
        let full = layer_line(
            &LayerOutcome {
                name: "a".into(),
                shape: (4, 4),
                alpha: 1.0,
                evals: 16,
                stats: Some(DeltaStats::default()),
                secs: 0.0,
            },
            "shard_00000.dts",
        );
        let unit = unit_line(
            "ln:l0.ln1",
            &[LayerOutcome {
                name: "l0.wq".into(),
                shape: (4, 4),
                alpha: 1.0,
                evals: 1,
                stats: None,
                secs: 0.0,
            }],
            "shard_00001.dts",
        );
        let text = format!(
            "{}{}{}{}",
            config_line(&cfg),
            full,
            unit,
            &unit[..unit.len() / 2] // torn write at the tail
        );
        let (config, units) = parse_journal(&text);
        assert!(config.is_some());
        assert_eq!(units.len(), 2);
        assert!(units.contains_key("a"));
        assert!(units.contains_key("ln:l0.ln1"));
    }
}
