//! Transform-group planning: which tensors quantize together.
//!
//! Delta methods (AbsMax / scale search) treat every GEMM as an
//! independent job. The transform-based baselines (SmoothQuant, AWQ) do
//! not: the equivalent per-channel transformation rescales a GEMM's input
//! channels and folds the inverse into the *upstream layernorm's* affine,
//! so every GEMM fed by the same layernorm shares one smoothing vector
//! and the layernorm itself must be rewritten exactly once. A
//! [`GroupPlan`] makes that coupling explicit: it walks a checkpoint
//! index (names + shapes only, no payloads) and partitions the
//! quantizable tensors into [`Unit`]s — singleton layers for delta
//! methods, layernorm-coupled groups (plus un-foldable singletons) for
//! transform methods.
//!
//! Both the in-memory pipeline (`coordinator::run_pipeline`) and the
//! streaming driver (`coordinator::stream`) schedule off the same plan,
//! which is what lets the streaming path bound residency at
//! O(largest group) while staying bitwise-identical to the in-memory
//! result.
//!
//! Grouping is derived from the model naming convention
//! ([`upstream_ln`]); a [`GroupManifest`] (`--groups file.json`) can
//! override the assignment per member for checkpoints that do not follow
//! it.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::io::TensorSource;
use crate::util::json::Json;

/// Upstream layernorm whose affine can absorb an equivalent per-channel
/// transformation for a given GEMM (None = not foldable; such layers
/// fall back to plain AbsMax under SmoothQuant/AWQ).
pub fn upstream_ln(name: &str) -> Option<String> {
    if name == "head" {
        return Some("lnf".to_string());
    }
    let (layer, w) = name.split_once('.')?;
    match w {
        "wq" | "wk" | "wv" => Some(format!("{layer}.ln1")),
        "w1" => Some(format!("{layer}.ln2")),
        _ => None, // wo, w2: preceded by attention / GELU, not foldable
    }
}

/// One schedulable unit of pipeline work.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Unit {
    /// An independent layer: any delta-method layer, or a
    /// transform-method layer with no foldable upstream affine.
    Layer { name: String },
    /// A layernorm-coupled transform group: all members share one
    /// smoothing vector whose inverse folds into `ln`'s gain and bias.
    Group { ln: String, members: Vec<String> },
}

impl Unit {
    /// Stable identifier used by the resume journal.
    pub fn label(&self) -> String {
        match self {
            Unit::Layer { name } => name.clone(),
            Unit::Group { ln, .. } => format!("ln:{ln}"),
        }
    }

    /// Quantizable member names, in quantization order.
    pub fn members(&self) -> &[String] {
        match self {
            Unit::Layer { name } => std::slice::from_ref(name),
            Unit::Group { members, .. } => members,
        }
    }

    /// Tensor names this unit persists into an output store, in write
    /// order: `codes`/`scales`/dequantized weight per member, then the
    /// folded layernorm affine for groups. The streaming writer rolls
    /// shards only between units, so these names land in finalized
    /// shards all-or-nothing — the invariant the resume protocol checks.
    pub fn written_names(&self) -> Vec<String> {
        let mut out = Vec::new();
        for m in self.members() {
            out.push(format!("{m}.codes"));
            out.push(format!("{m}.scales"));
            out.push(m.clone());
        }
        if let Unit::Group { ln, .. } = self {
            out.push(format!("{ln}.g"));
            out.push(format!("{ln}.b"));
        }
        out
    }
}

/// Explicit grouping override loaded from a `--groups` manifest:
///
/// ```json
/// {"groups": [{"ln": "l0.ln1", "members": ["l0.wq", "l0.wk"]},
///             {"ln": null,     "members": ["l0.w1"]}]}
/// ```
///
/// Listed members are assigned to the given layernorm (or forced plain
/// with `"ln": null`); members not listed anywhere still derive their
/// group from the name patterns.
#[derive(Clone, Debug, Default)]
pub struct GroupManifest {
    /// member name -> Some(layernorm) to fold into, None to force plain.
    pub assign: BTreeMap<String, Option<String>>,
}

impl GroupManifest {
    pub fn load(path: impl AsRef<Path>) -> Result<GroupManifest> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read groups manifest {path:?}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;
        GroupManifest::parse(&j).with_context(|| format!("{path:?}"))
    }

    pub fn parse(j: &Json) -> Result<GroupManifest> {
        let groups = j
            .get("groups")
            .and_then(|g| g.as_arr())
            .ok_or_else(|| anyhow!("groups manifest needs a \"groups\" array"))?;
        let mut assign = BTreeMap::new();
        for g in groups {
            let ln = match g.get("ln") {
                Some(Json::Null) | None => None,
                Some(v) => Some(
                    v.as_str()
                        .ok_or_else(|| anyhow!("group \"ln\" must be a string or null"))?
                        .to_string(),
                ),
            };
            let members = g
                .get("members")
                .and_then(|m| m.as_arr())
                .ok_or_else(|| anyhow!("group entry needs a \"members\" array"))?;
            for m in members {
                let name = m
                    .as_str()
                    .ok_or_else(|| anyhow!("group members must be strings"))?;
                if assign.insert(name.to_string(), ln.clone()).is_some() {
                    bail!("member {name:?} listed in more than one group");
                }
            }
        }
        Ok(GroupManifest { assign })
    }
}

/// The partition of `quantizable` into schedulable [`Unit`]s, in
/// execution (and output-store) order.
#[derive(Clone, Debug)]
pub struct GroupPlan {
    pub units: Vec<Unit>,
}

impl GroupPlan {
    /// Delta methods: every layer is its own independent unit, in
    /// `quantizable` order.
    pub fn delta(quantizable: &[String]) -> GroupPlan {
        GroupPlan {
            units: quantizable
                .iter()
                .map(|name| Unit::Layer { name: name.clone() })
                .collect(),
        }
    }

    /// Transform methods: partition into layernorm-coupled groups
    /// (ordered by layernorm name, members in `quantizable` order),
    /// then un-foldable layers in `quantizable` order. Validates against
    /// the checkpoint index only — member shapes, shared input dims, and
    /// the presence/width of each group's layernorm affine — so a bad
    /// plan fails before any payload is read.
    pub fn transform(
        source: &dyn TensorSource,
        quantizable: &[String],
        manifest: Option<&GroupManifest>,
    ) -> Result<GroupPlan> {
        if let Some(m) = manifest {
            for name in m.assign.keys() {
                if !quantizable.contains(name) {
                    bail!("groups manifest lists unknown quantizable tensor {name:?}");
                }
            }
        }

        let mut groups: BTreeMap<String, Vec<String>> = BTreeMap::new();
        let mut plain: Vec<String> = Vec::new();
        for name in quantizable {
            let ln = match manifest.and_then(|m| m.assign.get(name)) {
                Some(over) => over.clone(),
                None => upstream_ln(name),
            };
            match ln {
                Some(ln) => groups.entry(ln).or_default().push(name.clone()),
                None => plain.push(name.clone()),
            }
        }

        for (ln, members) in &groups {
            // the ln affine must exist (peeked by prefix, index-only)
            let ln_params = source.names_with_prefix(&format!("{ln}."));
            for part in ["g", "b"] {
                let want = format!("{ln}.{part}");
                if !ln_params.contains(&want) {
                    bail!(
                        "group {ln:?}: layernorm parameter {want:?} not found \
                         in the checkpoint (members {members:?}; tensors under \
                         the {ln:?} prefix: {ln_params:?})"
                    );
                }
            }
            let ln_dim = match source.shape_of(&format!("{ln}.g")) {
                Some(s) if s.len() == 1 => s[0],
                other => bail!("group {ln:?}: {ln}.g has shape {other:?}, wanted 1-D"),
            };
            for m in members {
                let shape = source
                    .shape_of(m)
                    .ok_or_else(|| anyhow!("group {ln:?}: member {m:?} not found"))?;
                if shape.len() != 2 {
                    bail!("group {ln:?}: member {m:?} has shape {shape:?}, wanted 2-D");
                }
                if shape[0] != ln_dim {
                    bail!(
                        "group {ln:?}: member {m:?} has {} input channels but \
                         {ln}.g has width {ln_dim}",
                        shape[0]
                    );
                }
            }
        }

        let mut units: Vec<Unit> = groups
            .into_iter()
            .map(|(ln, members)| Unit::Group { ln, members })
            .collect();
        units.extend(plain.into_iter().map(|name| Unit::Layer { name }));
        Ok(GroupPlan { units })
    }

    /// Largest member count across units (1 for a pure-delta plan).
    pub fn max_members(&self) -> usize {
        self.units.iter().map(|u| u.members().len()).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::dts::Dts;
    use crate::tensor::Tensor;

    fn source(dim: usize) -> (Dts, Vec<String>) {
        let mut d = Dts::new();
        let names = vec![
            "l0.wq".to_string(),
            "l0.wk".into(),
            "l0.w1".into(),
            "l0.w2".into(),
            "head".into(),
        ];
        for n in &names {
            d.insert_f32(n, &Tensor::zeros(vec![dim, dim]));
        }
        for ln in ["l0.ln1", "l0.ln2", "lnf"] {
            d.insert_f32(&format!("{ln}.g"), &Tensor::full(vec![dim], 1.0));
            d.insert_f32(&format!("{ln}.b"), &Tensor::zeros(vec![dim]));
        }
        d.insert_f32("embed", &Tensor::zeros(vec![4, dim]));
        (d, names)
    }

    #[test]
    fn upstream_ln_patterns() {
        assert_eq!(upstream_ln("l3.wq"), Some("l3.ln1".into()));
        assert_eq!(upstream_ln("l3.wk"), Some("l3.ln1".into()));
        assert_eq!(upstream_ln("l3.wv"), Some("l3.ln1".into()));
        assert_eq!(upstream_ln("l3.w1"), Some("l3.ln2".into()));
        assert_eq!(upstream_ln("head"), Some("lnf".into()));
        assert_eq!(upstream_ln("l3.wo"), None);
        assert_eq!(upstream_ln("l3.w2"), None);
        assert_eq!(upstream_ln("embed"), None);
    }

    #[test]
    fn delta_plan_is_one_unit_per_layer() {
        let names = vec!["a".to_string(), "b".into()];
        let p = GroupPlan::delta(&names);
        assert_eq!(p.units.len(), 2);
        assert_eq!(p.max_members(), 1);
        assert_eq!(p.units[0], Unit::Layer { name: "a".into() });
        assert_eq!(p.units[0].written_names(), vec!["a.codes", "a.scales", "a"]);
    }

    #[test]
    fn transform_plan_groups_by_upstream_ln() {
        let (d, names) = source(8);
        let p = GroupPlan::transform(&d, &names, None).unwrap();
        // groups sorted by ln name, then plain layers in input order
        assert_eq!(
            p.units,
            vec![
                Unit::Group {
                    ln: "l0.ln1".into(),
                    members: vec!["l0.wq".into(), "l0.wk".into()],
                },
                Unit::Group { ln: "l0.ln2".into(), members: vec!["l0.w1".into()] },
                Unit::Group { ln: "lnf".into(), members: vec!["head".into()] },
                Unit::Layer { name: "l0.w2".into() },
            ]
        );
        assert_eq!(p.max_members(), 2);
        let wn = p.units[0].written_names();
        assert_eq!(
            wn,
            vec![
                "l0.wq.codes",
                "l0.wq.scales",
                "l0.wq",
                "l0.wk.codes",
                "l0.wk.scales",
                "l0.wk",
                "l0.ln1.g",
                "l0.ln1.b"
            ]
        );
    }

    #[test]
    fn transform_plan_rejects_missing_ln() {
        let mut d = Dts::new();
        d.insert_f32("l0.wq", &Tensor::zeros(vec![4, 4]));
        let err =
            GroupPlan::transform(&d, &["l0.wq".to_string()], None).unwrap_err();
        assert!(format!("{err:#}").contains("l0.ln1"), "{err:#}");
    }

    #[test]
    fn transform_plan_rejects_width_mismatch() {
        let (mut d, _) = source(8);
        d.insert_f32("l1.wq", &Tensor::zeros(vec![6, 6]));
        d.insert_f32("l1.ln1.g", &Tensor::full(vec![8], 1.0));
        d.insert_f32("l1.ln1.b", &Tensor::zeros(vec![8]));
        let err =
            GroupPlan::transform(&d, &["l1.wq".to_string()], None).unwrap_err();
        assert!(format!("{err:#}").contains("input channels"), "{err:#}");
    }

    #[test]
    fn manifest_overrides_and_forces_plain() {
        let (d, names) = source(8);
        let m = GroupManifest::parse(
            &Json::parse(
                r#"{"groups": [{"ln": "l0.ln1", "members": ["l0.w2"]},
                               {"ln": null, "members": ["head"]}]}"#,
            )
            .unwrap(),
        )
        .unwrap();
        let p = GroupPlan::transform(&d, &names, Some(&m)).unwrap();
        assert_eq!(
            p.units,
            vec![
                Unit::Group {
                    ln: "l0.ln1".into(),
                    members: vec!["l0.wq".into(), "l0.wk".into(), "l0.w2".into()],
                },
                Unit::Group { ln: "l0.ln2".into(), members: vec!["l0.w1".into()] },
                Unit::Layer { name: "head".into() },
            ]
        );
    }

    #[test]
    fn manifest_rejects_duplicates_and_unknown_members() {
        let dup = Json::parse(
            r#"{"groups": [{"ln": "a", "members": ["x"]},
                           {"ln": "b", "members": ["x"]}]}"#,
        )
        .unwrap();
        assert!(GroupManifest::parse(&dup).is_err());

        let (d, names) = source(8);
        let m = GroupManifest::parse(
            &Json::parse(r#"{"groups": [{"ln": "l0.ln1", "members": ["ghost"]}]}"#)
                .unwrap(),
        )
        .unwrap();
        let err = GroupPlan::transform(&d, &names, Some(&m)).unwrap_err();
        assert!(format!("{err:#}").contains("ghost"), "{err:#}");
    }
}
