//! Transform-group planning: which tensors quantize together.
//!
//! Delta methods (AbsMax / scale search) treat every GEMM as an
//! independent job. The transform-based baselines (SmoothQuant, AWQ) do
//! not: the equivalent per-channel transformation rescales a GEMM's input
//! channels and folds the inverse into the *upstream layernorm's* affine,
//! so every GEMM fed by the same layernorm shares one smoothing vector
//! and the layernorm itself must be rewritten exactly once. A
//! [`GroupPlan`] makes that coupling explicit: it walks a checkpoint
//! index (names + shapes only, no payloads) and partitions the
//! quantizable tensors into [`Unit`]s — singleton layers for delta
//! methods, layernorm-coupled groups (plus un-foldable singletons) for
//! transform methods.
//!
//! Both the in-memory pipeline (`coordinator::run_pipeline`) and the
//! streaming driver (`coordinator::stream`) schedule off the same plan,
//! which is what lets the streaming path bound residency at
//! O(largest group) while staying bitwise-identical to the in-memory
//! result.
//!
//! Three [`GroupSource`]s can produce the plan, in decreasing order of
//! trust:
//! - **trace** ([`GroupPlan::from_graph`]): the checkpoint's actual
//!   dataflow, recorded by `eval::trace` — works for any checkpoint the
//!   forward can execute, regardless of tensor naming, and proves
//!   foldability (every consumer of the layernorm output must be a
//!   quantizable GEMM) instead of assuming it;
//! - **manifest** ([`GroupManifest`], `--groups file.json`): an explicit
//!   per-member override;
//! - **patterns** ([`upstream_ln`]): the historical model-naming
//!   convention, the fallback when neither of the above is available.
//!
//! When a manifest *and* a trace are both supplied, the resolver derives
//! the plan from each and errors on any disagreement rather than
//! silently preferring one.

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::eval::trace::{self, OpKind, TraceGraph, ValueId};
use crate::io::TensorSource;
use crate::util::json::Json;

/// Upstream layernorm whose affine can absorb an equivalent per-channel
/// transformation for a given GEMM (None = not foldable; such layers
/// fall back to plain AbsMax under SmoothQuant/AWQ).
pub fn upstream_ln(name: &str) -> Option<String> {
    if name == "head" {
        return Some("lnf".to_string());
    }
    let (layer, w) = name.split_once('.')?;
    match w {
        "wq" | "wk" | "wv" => Some(format!("{layer}.ln1")),
        "w1" => Some(format!("{layer}.ln2")),
        _ => None, // wo, w2: preceded by attention / GELU, not foldable
    }
}

/// One schedulable unit of pipeline work.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Unit {
    /// An independent layer: any delta-method layer, or a
    /// transform-method layer with no foldable upstream affine.
    Layer { name: String },
    /// A layernorm-coupled transform group: all members share one
    /// smoothing vector whose inverse folds into the affine tensors
    /// `gain` / `bias` (stored names — for pattern/manifest plans these
    /// are `<ln>.g` / `<ln>.b`, trace-derived plans carry whatever the
    /// checkpoint actually calls them).
    Group { ln: String, gain: String, bias: String, members: Vec<String> },
}

impl Unit {
    /// A group under the conventional `<ln>.g` / `<ln>.b` affine naming
    /// (the pattern / manifest path).
    pub fn group(ln: String, members: Vec<String>) -> Unit {
        let gain = format!("{ln}.g");
        let bias = format!("{ln}.b");
        Unit::Group { ln, gain, bias, members }
    }

    /// Stable identifier used by the resume journal.
    pub fn label(&self) -> String {
        match self {
            Unit::Layer { name } => name.clone(),
            Unit::Group { ln, .. } => format!("ln:{ln}"),
        }
    }

    /// Quantizable member names, in quantization order.
    pub fn members(&self) -> &[String] {
        match self {
            Unit::Layer { name } => std::slice::from_ref(name),
            Unit::Group { members, .. } => members,
        }
    }

    /// Tensor names this unit persists into an output store, in write
    /// order: `codes`/`scales`/dequantized weight per member, then the
    /// folded layernorm affine for groups. The streaming writer rolls
    /// shards only between units, so these names land in finalized
    /// shards all-or-nothing — the invariant the resume protocol checks.
    pub fn written_names(&self) -> Vec<String> {
        let mut out = Vec::new();
        for m in self.members() {
            out.push(format!("{m}.codes"));
            out.push(format!("{m}.scales"));
            out.push(m.clone());
        }
        if let Unit::Group { gain, bias, .. } = self {
            out.push(gain.clone());
            out.push(bias.clone());
        }
        out
    }
}

/// Explicit grouping override loaded from a `--groups` manifest:
///
/// ```json
/// {"groups": [{"ln": "l0.ln1", "members": ["l0.wq", "l0.wk"]},
///             {"ln": null,     "members": ["l0.w1"]}]}
/// ```
///
/// Listed members are assigned to the given layernorm (or forced plain
/// with `"ln": null`); members not listed anywhere still derive their
/// group from the name patterns.
#[derive(Clone, Debug, Default)]
pub struct GroupManifest {
    /// member name -> Some(layernorm) to fold into, None to force plain.
    pub assign: BTreeMap<String, Option<String>>,
}

impl GroupManifest {
    pub fn load(path: impl AsRef<Path>) -> Result<GroupManifest> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read groups manifest {path:?}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;
        GroupManifest::parse(&j).with_context(|| format!("{path:?}"))
    }

    pub fn parse(j: &Json) -> Result<GroupManifest> {
        let groups = j
            .get("groups")
            .and_then(|g| g.as_arr())
            .ok_or_else(|| anyhow!("groups manifest needs a \"groups\" array"))?;
        if groups.is_empty() {
            bail!(
                "groups manifest has an empty \"groups\" array — an override \
                 that overrides nothing is almost certainly a mistake; remove \
                 --groups to use the derived grouping"
            );
        }
        let mut assign = BTreeMap::new();
        for g in groups {
            let ln = match g.get("ln") {
                Some(Json::Null) | None => None,
                Some(v) => Some(
                    v.as_str()
                        .ok_or_else(|| anyhow!("group \"ln\" must be a string or null"))?
                        .to_string(),
                ),
            };
            let members = g
                .get("members")
                .and_then(|m| m.as_arr())
                .ok_or_else(|| anyhow!("group entry needs a \"members\" array"))?;
            for m in members {
                let name = m
                    .as_str()
                    .ok_or_else(|| anyhow!("group members must be strings"))?;
                if assign.insert(name.to_string(), ln.clone()).is_some() {
                    bail!("member {name:?} listed in more than one group");
                }
            }
        }
        Ok(GroupManifest { assign })
    }
}

/// Where transform groups come from (see the module docs for the trust
/// ordering). `ManifestAndTrace` cross-checks: the plan is derived from
/// both and any disagreement is an error, never a silent preference.
#[derive(Clone, Debug, Default)]
pub enum GroupSource {
    #[default]
    Patterns,
    Manifest(GroupManifest),
    Trace(TraceGraph),
    ManifestAndTrace(GroupManifest, TraceGraph),
}

impl GroupSource {
    pub fn label(&self) -> &'static str {
        match self {
            GroupSource::Patterns => "patterns",
            GroupSource::Manifest(_) => "manifest",
            GroupSource::Trace(_) => "trace",
            GroupSource::ManifestAndTrace(..) => "manifest+trace",
        }
    }

    /// True for the default (no explicit grouping input was supplied).
    pub fn is_patterns(&self) -> bool {
        matches!(self, GroupSource::Patterns)
    }
}

/// The partition of `quantizable` into schedulable [`Unit`]s, in
/// execution (and output-store) order.
#[derive(Clone, Debug)]
pub struct GroupPlan {
    pub units: Vec<Unit>,
}

impl GroupPlan {
    /// Delta methods: every layer is its own independent unit, in
    /// `quantizable` order.
    pub fn delta(quantizable: &[String]) -> GroupPlan {
        GroupPlan {
            units: quantizable
                .iter()
                .map(|name| Unit::Layer { name: name.clone() })
                .collect(),
        }
    }

    /// Derive the transform plan from a [`GroupSource`].
    pub fn resolve(
        source: &dyn TensorSource,
        quantizable: &[String],
        groups: &GroupSource,
    ) -> Result<GroupPlan> {
        match groups {
            GroupSource::Patterns => Self::transform(source, quantizable, None),
            GroupSource::Manifest(m) => Self::transform(source, quantizable, Some(m)),
            GroupSource::Trace(g) => Self::from_graph(source, quantizable, g),
            GroupSource::ManifestAndTrace(m, g) => {
                let from_manifest = Self::transform(source, quantizable, Some(m))?;
                let from_trace = Self::from_graph(source, quantizable, g)?;
                if let Some(diff) = from_manifest.diff(&from_trace) {
                    bail!(
                        "the groups manifest and the traced dataflow graph \
                         disagree — refusing to silently prefer one: {diff} \
                         (fix the manifest, re-run `daq trace`, or pick a \
                         side explicitly with --group-source)"
                    );
                }
                Ok(from_trace)
            }
        }
    }

    /// Transform methods: partition into layernorm-coupled groups
    /// (ordered by layernorm name, members in `quantizable` order),
    /// then un-foldable layers in `quantizable` order. Validates against
    /// the checkpoint index only — member shapes, shared input dims, and
    /// the presence/width of each group's layernorm affine — so a bad
    /// plan fails before any payload is read.
    pub fn transform(
        source: &dyn TensorSource,
        quantizable: &[String],
        manifest: Option<&GroupManifest>,
    ) -> Result<GroupPlan> {
        if let Some(m) = manifest {
            for name in m.assign.keys() {
                if !quantizable.contains(name) {
                    if source.contains(name) {
                        bail!(
                            "groups manifest lists {name:?}, which exists in the \
                             checkpoint but is not a quantizable GEMM weight"
                        );
                    }
                    bail!("groups manifest lists unknown quantizable tensor {name:?}");
                }
            }
        }

        let mut groups: BTreeMap<String, Vec<String>> = BTreeMap::new();
        let mut plain: Vec<String> = Vec::new();
        for name in quantizable {
            let ln = match manifest.and_then(|m| m.assign.get(name)) {
                Some(over) => over.clone(),
                None => upstream_ln(name),
            };
            match ln {
                Some(ln) => groups.entry(ln).or_default().push(name.clone()),
                None => plain.push(name.clone()),
            }
        }

        let mut units: Vec<Unit> = groups
            .into_iter()
            .map(|(ln, members)| Unit::group(ln, members))
            .collect();
        units.extend(plain.into_iter().map(|name| Unit::Layer { name }));
        let plan = GroupPlan { units };
        plan.validate(source)?;
        Ok(plan)
    }

    /// Derive the transform plan from a traced dataflow graph: a GEMM
    /// weight folds into a layernorm iff its matmul consumes that
    /// layernorm's output **and** every other consumer of the layernorm
    /// output is itself a GEMM against a quantizable weight (folding
    /// rescales the layernorm output, so a single non-quantizable
    /// consumer makes the fold incorrect — a case the name patterns
    /// cannot even express). No tensor-name conventions are consulted.
    pub fn from_graph(
        source: &dyn TensorSource,
        quantizable: &[String],
        graph: &TraceGraph,
    ) -> Result<GroupPlan> {
        let fp = trace::fingerprint(source);
        if graph.fingerprint != fp {
            bail!(
                "traced graph fingerprint {:016x} does not match this \
                 checkpoint's index ({fp:016x}) — the sidecar is stale; \
                 re-run `daq trace`",
                graph.fingerprint
            );
        }
        // A weight's fold *candidate*: the stored affine of the single
        // layernorm feeding every one of its GEMM uses (None if any use
        // is fed by something else, by two different layernorms, or by
        // a computed affine).
        struct Cand {
            gain: String,
            bias: String,
            /// Output value(s) of the feeding layernorm op(s).
            ln_outs: BTreeSet<ValueId>,
        }
        let mut cands: BTreeMap<&str, Option<Cand>> = BTreeMap::new();
        for name in quantizable {
            let Some(&vid) = graph.leaves.get(name) else {
                bail!(
                    "quantizable tensor {name:?} never appears in the traced \
                     dataflow graph — the trace and the quantizable set \
                     disagree; re-run `daq trace`"
                );
            };
            let gemm_uses: Vec<_> = graph
                .ops
                .iter()
                .filter(|o| o.kind == OpKind::Matmul && o.inputs.get(1) == Some(&vid))
                .collect();
            if gemm_uses.is_empty() {
                bail!(
                    "quantizable tensor {name:?} is never consumed as a GEMM \
                     weight in the traced dataflow graph"
                );
            }
            let mut cand: Option<Cand> = None;
            let mut ok = true;
            for mm in &gemm_uses {
                let x = mm.inputs[0];
                let produced_by_ln =
                    graph.producer(x).filter(|p| p.kind == OpKind::Layernorm);
                let Some(ln_op) = produced_by_ln else {
                    ok = false;
                    break;
                };
                let (Some(g), Some(b)) = (
                    graph.leaf_name(ln_op.inputs[1]),
                    graph.leaf_name(ln_op.inputs[2]),
                ) else {
                    ok = false; // affine is itself computed, not stored
                    break;
                };
                match &mut cand {
                    None => {
                        cand = Some(Cand {
                            gain: g.to_string(),
                            bias: b.to_string(),
                            ln_outs: BTreeSet::from([x]),
                        });
                    }
                    Some(c) if c.gain == g && c.bias == b => {
                        c.ln_outs.insert(x);
                    }
                    Some(_) => {
                        ok = false; // fed by two different layernorms
                        break;
                    }
                }
            }
            cands.insert(name.as_str(), if ok { cand } else { None });
        }

        // A candidate becomes a group member only if folding is safe:
        // the layernorm output must feed nothing but GEMMs whose weights
        // all fold into this same layernorm (folding rescales the
        // output for EVERY consumer, so one exempt consumer poisons the
        // whole fold).
        let fold_safe = |c: &Cand| -> bool {
            c.ln_outs.iter().all(|&x| {
                graph.consumers(x).iter().all(|cons| {
                    cons.kind == OpKind::Matmul
                        && cons.inputs.first() == Some(&x)
                        && cons
                            .inputs
                            .get(1)
                            .and_then(|&w| graph.leaf_name(w))
                            .and_then(|w| cands.get(w))
                            .and_then(|o| o.as_ref())
                            .map(|wc| wc.gain == c.gain && wc.bias == c.bias)
                            .unwrap_or(false)
                })
            })
        };

        // (gain, bias) -> members, in `quantizable` order — keyed by the
        // full affine pair so tied gains with distinct biases can never
        // fuse into one group
        let mut groups: BTreeMap<(String, String), Vec<String>> = BTreeMap::new();
        let mut plain: Vec<String> = Vec::new();
        for name in quantizable {
            match cands.get(name.as_str()).and_then(|o| o.as_ref()) {
                Some(c) if fold_safe(c) => {
                    groups
                        .entry((c.gain.clone(), c.bias.clone()))
                        .or_default()
                        .push(name.clone());
                }
                _ => plain.push(name.clone()),
            }
        }

        let mut units: Vec<Unit> = groups
            .into_iter()
            .map(|((gain, bias), members)| {
                let ln = gain.strip_suffix(".g").unwrap_or(&gain).to_string();
                Unit::Group { ln, gain, bias, members }
            })
            .collect();
        units.extend(plain.into_iter().map(|name| Unit::Layer { name }));
        let plan = GroupPlan { units };
        plan.validate(source)?;
        Ok(plan)
    }

    /// Index-only validation shared by every group source: the affine
    /// tensors exist and their width matches every member's input dim.
    fn validate(&self, source: &dyn TensorSource) -> Result<()> {
        for unit in &self.units {
            let Unit::Group { ln, gain, bias, members } = unit else { continue };
            for part in [gain, bias] {
                if !source.contains(part) {
                    bail!(
                        "group {ln:?}: layernorm parameter {part:?} not found \
                         in the checkpoint (members {members:?}; tensors under \
                         the {ln:?} prefix: {:?})",
                        source.names_with_prefix(&format!("{ln}."))
                    );
                }
            }
            let Some(gain_shape) = source.shape_of(gain) else {
                bail!("group {ln:?}: cannot read the shape of {gain:?}");
            };
            // accept [d] and the [1, d]-style storage some checkpoints
            // use, but reject anything with two real axes
            if gain_shape.iter().filter(|&&d| d > 1).count() > 1 {
                bail!(
                    "group {ln:?}: {gain} has shape {gain_shape:?}, wanted a \
                     1-D layernorm affine"
                );
            }
            let ln_dim: usize = gain_shape.iter().product();
            for m in members {
                let shape = source
                    .shape_of(m)
                    .ok_or_else(|| anyhow!("group {ln:?}: member {m:?} not found"))?;
                if shape.len() != 2 {
                    bail!("group {ln:?}: member {m:?} has shape {shape:?}, wanted 2-D");
                }
                if shape[0] != ln_dim {
                    bail!(
                        "group {ln:?}: member {m:?} has {} input channels but \
                         {gain} has width {ln_dim}",
                        shape[0]
                    );
                }
            }
        }
        Ok(())
    }

    /// First structural disagreement with `other`, if any — used to
    /// cross-check independently derived plans.
    pub fn diff(&self, other: &GroupPlan) -> Option<String> {
        if self.units.len() != other.units.len() {
            return Some(format!(
                "{} units vs {} units",
                self.units.len(),
                other.units.len()
            ));
        }
        for (a, b) in self.units.iter().zip(&other.units) {
            if a != b {
                return Some(format!("unit {a:?} vs {b:?}"));
            }
        }
        None
    }

    /// Largest member count across units (1 for a pure-delta plan).
    pub fn max_members(&self) -> usize {
        self.units.iter().map(|u| u.members().len()).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::model_native::ModelCfg;
    use crate::eval::trace::{stamp_model_meta, trace_graph};
    use crate::io::dts::Dts;
    use crate::tensor::Tensor;

    fn source(dim: usize) -> (Dts, Vec<String>) {
        let mut d = Dts::new();
        let names = vec![
            "l0.wq".to_string(),
            "l0.wk".into(),
            "l0.w1".into(),
            "l0.w2".into(),
            "head".into(),
        ];
        for n in &names {
            d.insert_f32(n, &Tensor::zeros(vec![dim, dim]));
        }
        for ln in ["l0.ln1", "l0.ln2", "lnf"] {
            d.insert_f32(&format!("{ln}.g"), &Tensor::full(vec![dim], 1.0));
            d.insert_f32(&format!("{ln}.b"), &Tensor::zeros(vec![dim]));
        }
        d.insert_f32("embed", &Tensor::zeros(vec![4, dim]));
        (d, names)
    }

    /// A full canonical checkpoint `trace_graph` can walk (ln affines
    /// stored 1-D, square weights where the config allows).
    fn traceable_ckpt() -> (Dts, ModelCfg, Vec<String>) {
        let cfg =
            ModelCfg { vocab: 12, d_model: 8, n_layer: 1, n_head: 2, d_ff: 8, seq_len: 4 };
        let mut d = Dts::new();
        stamp_model_meta(&mut d, &cfg);
        d.insert_f32("embed", &Tensor::zeros(vec![cfg.vocab, cfg.d_model]));
        d.insert_f32("pos", &Tensor::zeros(vec![cfg.seq_len, cfg.d_model]));
        for w in ["wq", "wk", "wv", "wo", "w1", "w2"] {
            d.insert_f32(&format!("l0.{w}"), &Tensor::zeros(vec![8, 8]));
        }
        for ln in ["l0.ln1", "l0.ln2", "lnf"] {
            d.insert_f32(&format!("{ln}.g"), &Tensor::full(vec![8], 1.0));
            d.insert_f32(&format!("{ln}.b"), &Tensor::zeros(vec![8]));
        }
        d.insert_f32("head", &Tensor::zeros(vec![8, cfg.vocab]));
        let quantizable = vec![
            "l0.wq".to_string(),
            "l0.wk".into(),
            "l0.wv".into(),
            "l0.wo".into(),
            "l0.w1".into(),
            "l0.w2".into(),
            "head".into(),
        ];
        (d, cfg, quantizable)
    }

    #[test]
    fn upstream_ln_patterns() {
        assert_eq!(upstream_ln("l3.wq"), Some("l3.ln1".into()));
        assert_eq!(upstream_ln("l3.wk"), Some("l3.ln1".into()));
        assert_eq!(upstream_ln("l3.wv"), Some("l3.ln1".into()));
        assert_eq!(upstream_ln("l3.w1"), Some("l3.ln2".into()));
        assert_eq!(upstream_ln("head"), Some("lnf".into()));
        assert_eq!(upstream_ln("l3.wo"), None);
        assert_eq!(upstream_ln("l3.w2"), None);
        assert_eq!(upstream_ln("embed"), None);
    }

    #[test]
    fn delta_plan_is_one_unit_per_layer() {
        let names = vec!["a".to_string(), "b".into()];
        let p = GroupPlan::delta(&names);
        assert_eq!(p.units.len(), 2);
        assert_eq!(p.max_members(), 1);
        assert_eq!(p.units[0], Unit::Layer { name: "a".into() });
        assert_eq!(p.units[0].written_names(), vec!["a.codes", "a.scales", "a"]);
    }

    #[test]
    fn transform_plan_groups_by_upstream_ln() {
        let (d, names) = source(8);
        let p = GroupPlan::transform(&d, &names, None).unwrap();
        // groups sorted by ln name, then plain layers in input order
        assert_eq!(
            p.units,
            vec![
                Unit::group("l0.ln1".into(), vec!["l0.wq".into(), "l0.wk".into()]),
                Unit::group("l0.ln2".into(), vec!["l0.w1".into()]),
                Unit::group("lnf".into(), vec!["head".into()]),
                Unit::Layer { name: "l0.w2".into() },
            ]
        );
        assert_eq!(p.max_members(), 2);
        let wn = p.units[0].written_names();
        assert_eq!(
            wn,
            vec![
                "l0.wq.codes",
                "l0.wq.scales",
                "l0.wq",
                "l0.wk.codes",
                "l0.wk.scales",
                "l0.wk",
                "l0.ln1.g",
                "l0.ln1.b"
            ]
        );
    }

    #[test]
    fn transform_plan_rejects_missing_ln() {
        let mut d = Dts::new();
        d.insert_f32("l0.wq", &Tensor::zeros(vec![4, 4]));
        let err =
            GroupPlan::transform(&d, &["l0.wq".to_string()], None).unwrap_err();
        assert!(format!("{err:#}").contains("l0.ln1"), "{err:#}");
    }

    #[test]
    fn transform_plan_rejects_width_mismatch() {
        let (mut d, _) = source(8);
        d.insert_f32("l1.wq", &Tensor::zeros(vec![6, 6]));
        d.insert_f32("l1.ln1.g", &Tensor::full(vec![8], 1.0));
        d.insert_f32("l1.ln1.b", &Tensor::zeros(vec![8]));
        let err =
            GroupPlan::transform(&d, &["l1.wq".to_string()], None).unwrap_err();
        assert!(format!("{err:#}").contains("input channels"), "{err:#}");
    }

    #[test]
    fn manifest_overrides_and_forces_plain() {
        let (d, names) = source(8);
        let m = GroupManifest::parse(
            &Json::parse(
                r#"{"groups": [{"ln": "l0.ln1", "members": ["l0.w2"]},
                               {"ln": null, "members": ["head"]}]}"#,
            )
            .unwrap(),
        )
        .unwrap();
        let p = GroupPlan::transform(&d, &names, Some(&m)).unwrap();
        assert_eq!(
            p.units,
            vec![
                Unit::group(
                    "l0.ln1".into(),
                    vec!["l0.wq".into(), "l0.wk".into(), "l0.w2".into()],
                ),
                Unit::group("l0.ln2".into(), vec!["l0.w1".into()]),
                Unit::Layer { name: "head".into() },
            ]
        );
    }

    #[test]
    fn manifest_rejects_duplicates_and_unknown_members() {
        let dup = Json::parse(
            r#"{"groups": [{"ln": "a", "members": ["x"]},
                           {"ln": "b", "members": ["x"]}]}"#,
        )
        .unwrap();
        assert!(GroupManifest::parse(&dup).is_err());

        let (d, names) = source(8);
        let m = GroupManifest::parse(
            &Json::parse(r#"{"groups": [{"ln": "l0.ln1", "members": ["ghost"]}]}"#)
                .unwrap(),
        )
        .unwrap();
        let err = GroupPlan::transform(&d, &names, Some(&m)).unwrap_err();
        assert!(format!("{err:#}").contains("ghost"), "{err:#}");
    }

    #[test]
    fn manifest_rejects_empty_groups_array() {
        let empty = Json::parse(r#"{"groups": []}"#).unwrap();
        let err = GroupManifest::parse(&empty).unwrap_err();
        assert!(format!("{err:#}").contains("empty"), "{err:#}");
    }

    #[test]
    fn manifest_rejects_non_quantizable_tensor() {
        // "embed" exists in the checkpoint but is not a quantizable GEMM:
        // the error must say so (not just "unknown")
        let (d, names) = source(8);
        let m = GroupManifest::parse(
            &Json::parse(r#"{"groups": [{"ln": "l0.ln1", "members": ["embed"]}]}"#)
                .unwrap(),
        )
        .unwrap();
        let err = GroupPlan::transform(&d, &names, Some(&m)).unwrap_err();
        assert!(format!("{err:#}").contains("not a quantizable"), "{err:#}");
    }

    #[test]
    fn graph_plan_matches_pattern_plan_on_canonical_names() {
        // on a canonical checkpoint the traced dataflow must agree with
        // the name patterns — the patterns are a correct (if fragile)
        // encoding of this very structure
        let (d, cfg, quantizable) = traceable_ckpt();
        let graph = trace_graph(&d, &cfg).unwrap();
        let from_trace = GroupPlan::from_graph(&d, &quantizable, &graph).unwrap();
        let from_patterns = GroupPlan::transform(&d, &quantizable, None).unwrap();
        assert_eq!(from_trace.diff(&from_patterns), None);
        assert!(from_trace
            .units
            .iter()
            .any(|u| matches!(u, Unit::Group { ln, .. } if ln == "l0.ln1")));
    }

    #[test]
    fn graph_plan_unfolds_ln_when_a_sibling_gemm_is_not_quantizable() {
        // drop wv from the quantizable set: ln1's output now feeds a GEMM
        // that will NOT be rescaled, so folding ln1 would corrupt it —
        // the trace demotes wq/wk to singletons; the patterns would have
        // grouped them anyway (the bug class this subsystem removes)
        let (d, cfg, mut quantizable) = traceable_ckpt();
        let graph = trace_graph(&d, &cfg).unwrap();
        quantizable.retain(|n| n != "l0.wv");
        let plan = GroupPlan::from_graph(&d, &quantizable, &graph).unwrap();
        assert!(plan.units.contains(&Unit::Layer { name: "l0.wq".into() }));
        assert!(plan.units.contains(&Unit::Layer { name: "l0.wk".into() }));
        assert!(!plan
            .units
            .iter()
            .any(|u| matches!(u, Unit::Group { ln, .. } if ln == "l0.ln1")));
        // the untouched MLP group is still derived
        assert!(plan
            .units
            .iter()
            .any(|u| matches!(u, Unit::Group { ln, .. } if ln == "l0.ln2")));

        let naive = GroupPlan::transform(&d, &quantizable, None).unwrap();
        assert!(naive
            .units
            .iter()
            .any(|u| matches!(u, Unit::Group { ln, .. } if ln == "l0.ln1")));
    }

    #[test]
    fn graph_plan_rejects_stale_fingerprint() {
        let (mut d, cfg, quantizable) = traceable_ckpt();
        let graph = trace_graph(&d, &cfg).unwrap();
        d.insert_f32("extra", &Tensor::zeros(vec![2]));
        let err = GroupPlan::from_graph(&d, &quantizable, &graph).unwrap_err();
        assert!(format!("{err:#}").contains("stale"), "{err:#}");
    }

    #[test]
    fn manifest_and_trace_disagreement_errors() {
        let (d, cfg, quantizable) = traceable_ckpt();
        let graph = trace_graph(&d, &cfg).unwrap();
        // manifest that forces head plain — disagrees with the trace,
        // which folds head into lnf
        let m = GroupManifest::parse(
            &Json::parse(r#"{"groups": [{"ln": null, "members": ["head"]}]}"#).unwrap(),
        )
        .unwrap();
        let gs = GroupSource::ManifestAndTrace(m, graph.clone());
        let err = GroupPlan::resolve(&d, &quantizable, &gs).unwrap_err();
        assert!(format!("{err:#}").contains("disagree"), "{err:#}");

        // an agreeing manifest resolves fine
        let m = GroupManifest::parse(
            &Json::parse(r#"{"groups": [{"ln": "lnf", "members": ["head"]}]}"#).unwrap(),
        )
        .unwrap();
        let gs = GroupSource::ManifestAndTrace(m, graph);
        let plan = GroupPlan::resolve(&d, &quantizable, &gs).unwrap();
        assert!(plan
            .units
            .iter()
            .any(|u| matches!(u, Unit::Group { ln, .. } if ln == "lnf")));
    }
}
