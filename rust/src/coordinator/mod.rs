//! The quantization pipeline coordinator — L3's center: streams the
//! (base, post) checkpoint pair, schedules per-layer scale search over a
//! worker pool (or serially through the PJRT engine), folds baseline
//! transformations, aggregates model-level delta statistics, and emits the
//! quantized checkpoint.
//!
//! This is the AngelSlim-shaped driver the paper's method ships in: the
//! DAQ objective (§2) is one `Method` among the baselines it must be
//! compared against (Tables 2–5).

pub mod group;
pub mod stream;

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::baselines;
use crate::eval::Params;
use crate::io::dts::{Dts, DtsTensor};
use crate::metrics::DeltaStats;
use crate::quant::{
    absmax_scales, absmax_scales_fmt, quantize_with_scales, CodeFormat, Descriptor,
    Granularity, QuantizedTensor,
};
use crate::runtime::{PjrtSweep, Runtime};
use crate::search::{search_scale_with, Objective, SearchConfig, TiledSweep};
use crate::tensor::Tensor;
use crate::util::threadpool::par_map;
use crate::util::timer::time;

/// Which engine evaluates candidate scales.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// In-process planned tiled sweep over a thread pool. The worker
    /// budget splits between layer-level parallelism and tile-level
    /// parallelism inside each layer's sweep, so both many-small-layer
    /// and few-large-layer workloads use every core. Results are
    /// bitwise-independent of the split (fixed-order tile merge).
    Native { workers: usize },
    /// The AOT-compiled Pallas kernel through PJRT (serial — the PJRT
    /// client is not Sync; on this testbed parallelism is moot anyway).
    Pjrt,
}

/// Quantization method for the pipeline run.
#[derive(Clone, Debug)]
pub enum Method {
    /// Plain AbsMax FP8 (α = 1, no search) — Table 2 baseline.
    AbsMax,
    /// Coarse-to-fine scale search under a metric (Tables 3–5).
    Search { objective: Objective, range: (f32, f32) },
    /// SmoothQuant α-migration + AbsMax (Table 2 baseline).
    SmoothQuant { alpha: f32 },
    /// AWQ-style activation-salience rescaling (Table 2 baseline).
    Awq,
}

impl Method {
    pub fn label(&self) -> String {
        match self {
            Method::AbsMax => "absmax".into(),
            Method::Search { objective, range } => {
                format!("{}[{},{}]", objective.label(), range.0, range.1)
            }
            Method::SmoothQuant { alpha } => format!("smoothquant(a={alpha})"),
            Method::Awq => "awq".into(),
        }
    }

    /// Delta metrics are undefined for methods that leave the base model's
    /// numerical space (paper Table 2 footnote ‡).
    pub fn delta_defined(&self) -> bool {
        !matches!(self, Method::SmoothQuant { .. } | Method::Awq)
    }
}

#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub granularity: Granularity,
    pub method: Method,
    pub engine: Engine,
    /// Code format the delta methods quantize into (Table-2's bits axis).
    /// Transform baselines always store FP8 E4M3; other formats are
    /// rejected up front.
    pub format: CodeFormat,
    /// Rank of the optional low-rank correction fitted against the
    /// quantization residual ΔW − Q(ΔW); 0 disables it. Delta methods
    /// only.
    pub residual_rank: usize,
}

impl PipelineConfig {
    /// FP8 E4M3, no residual — the storage form every pre-`CodeFormat`
    /// call site used.
    pub fn new(granularity: Granularity, method: Method, engine: Engine) -> Self {
        PipelineConfig {
            granularity,
            method,
            engine,
            format: CodeFormat::Fp8E4m3,
            residual_rank: 0,
        }
    }
}

/// Per-layer outcome.
#[derive(Clone, Debug)]
pub struct LayerOutcome {
    pub name: String,
    pub shape: (usize, usize),
    /// Chosen scale multiplier (1.0 for no-search methods).
    pub alpha: f32,
    /// Candidate evaluations performed.
    pub evals: usize,
    /// Delta statistics at the chosen scale (None when undefined).
    pub stats: Option<DeltaStats>,
    pub secs: f64,
}

/// Whole-pipeline outcome.
pub struct PipelineOutcome {
    pub layers: Vec<LayerOutcome>,
    /// Model-level aggregate of per-layer stats (None when undefined).
    pub agg: Option<DeltaStats>,
    /// Full parameter set with quantized weights dequantized in place —
    /// ready for evaluation / serving.
    pub params: Params,
    /// Storage-format quantized tensors.
    pub quantized: BTreeMap<String, QuantizedTensor>,
    pub total_secs: f64,
}

impl PipelineOutcome {
    /// Persist as a DTS checkpoint: dequantized f32 weights (for the eval
    /// path) plus `<name>.codes` / `<name>.scales` sidecars (the compact
    /// storage form, packed two-codes-per-byte for sub-byte formats), the
    /// optional `<name>.res_u` / `<name>.res_v` low-rank residual pair,
    /// and per-layer α + `fmt.<name>` descriptors in metadata.
    pub fn write_checkpoint(&self, path: &str, src_meta: &BTreeMap<String, String>)
        -> Result<()> {
        let mut d = Dts::new();
        d.meta = src_meta.clone();
        for (name, q) in &self.quantized {
            d.meta.insert(
                format!("alpha.{name}"),
                format!("{}", self.layers.iter()
                    .find(|l| &l.name == name).map(|l| l.alpha).unwrap_or(1.0)),
            );
            // structured per-tensor descriptor (format, granularity,
            // residual rank, logical cols for sub-byte packing) — all a
            // loader needs to rebuild the tensor from the sidecars alone
            d.meta.insert(
                format!("fmt.{name}"),
                Descriptor::for_tensor(q).to_meta(),
            );
            let fmt = q.format();
            d.insert(&format!("{name}.codes"), DtsTensor::U8 {
                shape: vec![q.shape.0, fmt.packed_row_bytes(q.shape.1)],
                data: q.codes.clone(),
            });
            d.insert(&format!("{name}.scales"), DtsTensor::F32 {
                shape: vec![q.scales.grid_rows, q.scales.grid_cols],
                data: q.scales.scales.clone(),
            });
            if let Some(lr) = &q.residual {
                d.insert(&format!("{name}.res_u"), DtsTensor::F32 {
                    shape: vec![q.shape.0, lr.k],
                    data: lr.u.clone(),
                });
                d.insert(&format!("{name}.res_v"), DtsTensor::F32 {
                    shape: vec![lr.k, q.shape.1],
                    data: lr.v.clone(),
                });
            }
        }
        // dequantized weights + untouched params, in a stable order
        let mut names: Vec<&String> = self.params.keys().collect();
        names.sort();
        for name in names {
            d.insert_f32(name, &self.params[name]);
        }
        d.write(path)
    }
}

/// Run the pipeline over all quantizable tensors.
///
/// `calib` supplies per-layer activation statistics (required by
/// SmoothQuant/AWQ); `rt` supplies the PJRT engine when selected.
/// Transform groups derive from the name patterns; use
/// [`run_pipeline_grouped`] to supply an explicit [`group::GroupSource`]
/// (a `--groups` manifest or a traced dataflow graph).
pub fn run_pipeline(
    post: &Dts,
    base: &Dts,
    quantizable: &[String],
    calib: Option<&Dts>,
    cfg: &PipelineConfig,
    rt: Option<&Runtime>,
) -> Result<PipelineOutcome> {
    run_pipeline_grouped(post, base, quantizable, calib, cfg, rt, &group::GroupSource::Patterns)
}

/// [`run_pipeline`] with an explicit transform-group source.
#[allow(clippy::too_many_arguments)]
pub fn run_pipeline_grouped(
    post: &Dts,
    base: &Dts,
    quantizable: &[String],
    calib: Option<&Dts>,
    cfg: &PipelineConfig,
    rt: Option<&Runtime>,
    groups: &group::GroupSource,
) -> Result<PipelineOutcome> {
    if cfg.method.delta_defined() && !groups.is_patterns() {
        bail!(
            "--groups / --group-source only apply to the transform baselines \
             (smoothquant / awq)"
        );
    }
    if !cfg.method.delta_defined()
        && (cfg.format != CodeFormat::Fp8E4m3 || cfg.residual_rank > 0)
    {
        bail!(
            "--format / --residual-rank only apply to the delta methods \
             (absmax / search): {} always stores fp8-e4m3 without a residual",
            cfg.method.label()
        );
    }
    // start from the post-trained parameters; quantized layers get
    // replaced below
    let mut params = Params::new();
    for name in post.names() {
        params.insert(name.clone(), post.tensor_f32(name)?);
    }

    let (out, total_secs) = time(|| -> Result<_> {
        match &cfg.method {
            Method::SmoothQuant { .. } | Method::Awq => {
                run_transformed(&mut params, post, quantizable, calib, cfg, groups)
            }
            _ => run_delta_methods(&mut params, post, base, quantizable, cfg, rt),
        }
    });
    let (layers, quantized) = out?;

    let agg = if cfg.method.delta_defined() {
        let mut a = DeltaStats::default();
        for l in &layers {
            a = a.merge(l.stats.as_ref().expect("stats defined"));
        }
        Some(a)
    } else {
        None
    };

    Ok(PipelineOutcome { layers, agg, params, quantized, total_secs })
}

type LayerBundle = (Vec<LayerOutcome>, BTreeMap<String, QuantizedTensor>);

/// Quantize one layer under a delta method (AbsMax / scale search) — the
/// unit of work shared by the in-memory pipeline and the streaming driver
/// (`coordinator::stream`). Both paths call exactly this function, which
/// is what makes their outputs bitwise-identical.
#[allow(clippy::too_many_arguments)]
pub(crate) fn quantize_delta_layer(
    name: &str,
    wp: &Tensor,
    wb: &Tensor,
    method: &Method,
    gran: Granularity,
    format: CodeFormat,
    residual_rank: usize,
    engine: &dyn crate::search::SweepEngine,
) -> (LayerOutcome, QuantizedTensor) {
    let ((alpha, evals, stats, q), secs) = time(|| {
        let s0 = absmax_scales_fmt(wp, gran, format);
        let (alpha, evals, stats, mut q) = match method {
            Method::AbsMax => {
                let st = engine.sweep(wp, wb, &s0, &[1.0])[0];
                let q = quantize_with_scales(wp, &s0, 1.0);
                (1.0f32, 1usize, st, q)
            }
            Method::Search { objective, range } => {
                let scfg = SearchConfig::paper_default(*objective, *range);
                let res = search_scale_with(engine, wp, wb, &s0, &scfg);
                let q = quantize_with_scales(wp, &s0, res.alpha);
                (res.alpha, res.evals, res.stats, q)
            }
            _ => unreachable!("transformed methods handled elsewhere"),
        };
        if residual_rank > 0 {
            q.attach_residual(wp, residual_rank);
        }
        (alpha, evals, stats, q)
    });
    (
        LayerOutcome {
            name: name.to_string(),
            shape: q.shape,
            alpha,
            evals,
            stats: Some(stats),
            secs,
        },
        q,
    )
}

/// AbsMax + scale-search methods: per-layer independent jobs.
fn run_delta_methods(
    params: &mut Params,
    post: &Dts,
    base: &Dts,
    quantizable: &[String],
    cfg: &PipelineConfig,
    rt: Option<&Runtime>,
) -> Result<LayerBundle> {
    struct Job {
        name: String,
        wp: Tensor,
        wb: Tensor,
    }
    let jobs: Vec<Job> = quantizable
        .iter()
        .map(|name| {
            Ok(Job {
                name: name.clone(),
                wp: post.tensor_f32(name)?,
                wb: base.tensor_f32(name)?,
            })
        })
        .collect::<Result<_>>()?;
    for j in &jobs {
        if j.wp.shape() != j.wb.shape() {
            bail!("{}: post {:?} vs base {:?}", j.name, j.wp.shape(), j.wb.shape());
        }
    }

    let gran = cfg.granularity;
    let method = cfg.method.clone();
    let format = cfg.format;
    let residual_rank = cfg.residual_rank;

    let work = move |j: Job, engine: &dyn crate::search::SweepEngine| -> (LayerOutcome, QuantizedTensor) {
        quantize_delta_layer(&j.name, &j.wp, &j.wb, &method, gran, format, residual_rank, engine)
    };

    let results: Vec<(LayerOutcome, QuantizedTensor)> = match cfg.engine {
        Engine::Native { workers } => {
            // split the pool: up to one worker per layer at the outer
            // level, the rest fanned out over each layer's sweep tiles —
            // a single large layer still occupies the whole budget. The
            // division remainder goes to the first `extra` layers (one
            // more tile worker each) so no core idles; at most `outer`
            // layers run at once, of which at most `extra` are boosted,
            // so live tile workers never exceed `workers`. Results are
            // bitwise-independent of the per-layer worker count.
            let outer = workers.clamp(1, jobs.len().max(1));
            let intra = (workers / outer).max(1);
            let extra = workers.saturating_sub(intra * outer);
            let work = std::sync::Arc::new(work);
            let indexed: Vec<(usize, Job)> = jobs.into_iter().enumerate().collect();
            par_map(outer, indexed, move |(i, j)| {
                let w = intra + usize::from(i < extra);
                work(j, &TiledSweep::new(w))
            })
        }
        Engine::Pjrt => {
            let rt = rt.ok_or_else(|| anyhow!("PJRT engine requires a Runtime"))?;
            let engine = PjrtSweep { rt };
            jobs.into_iter().map(|j| work(j, &engine)).collect()
        }
    };

    let mut layers = Vec::new();
    let mut quantized = BTreeMap::new();
    for (outcome, q) in results {
        params.insert(outcome.name.clone(), q.dequantize());
        quantized.insert(outcome.name.clone(), q);
        layers.push(outcome);
    }
    Ok((layers, quantized))
}

/// Outcome of one transform unit: per-member results plus the folded
/// layernorm affine to install (for groups).
pub(crate) struct TransformUnitOut {
    pub outcomes: Vec<LayerOutcome>,
    pub quantized: Vec<(String, QuantizedTensor)>,
    /// `(folded gain, folded bias)` — present for group units; the
    /// stored names come from the unit's `gain` / `bias` fields.
    pub ln_fold: Option<(Tensor, Tensor)>,
}

/// Quantize one transform unit (a layernorm-coupled group, or a
/// non-foldable singleton) — the unit of work shared by the in-memory
/// transformed pipeline and the group-aware streaming driver
/// (`coordinator::stream`). Both paths call exactly this function over
/// the same [`group::GroupPlan`], which is what makes their outputs
/// bitwise-identical.
///
/// `members` are the post weights in unit order; `act` / `ln_params`
/// (layernorm gain, bias) are required for group units.
pub(crate) fn quantize_transform_unit(
    unit: &group::Unit,
    members: &[(String, Tensor)],
    act: Option<&[f32]>,
    ln_params: Option<(Tensor, Tensor)>,
    method: &Method,
    gran: Granularity,
) -> Result<TransformUnitOut> {
    match unit {
        group::Unit::Layer { name } => {
            // no foldable upstream affine: plain AbsMax
            let w = &members[0].1;
            let (q, secs) = time(|| {
                let s0 = absmax_scales(w, gran);
                quantize_with_scales(w, &s0, 1.0)
            });
            Ok(TransformUnitOut {
                outcomes: vec![LayerOutcome {
                    name: name.clone(),
                    shape: q.shape,
                    alpha: 1.0,
                    evals: 1,
                    stats: None,
                    secs,
                }],
                quantized: vec![(name.clone(), q)],
                ln_fold: None,
            })
        }
        group::Unit::Group { ln, .. } => {
            let act = act.ok_or_else(|| {
                anyhow!("group {ln:?}: calibration stats required")
            })?;
            let (gain, bias) = ln_params
                .ok_or_else(|| anyhow!("group {ln:?}: layernorm params required"))?;
            let kind = match method {
                Method::SmoothQuant { alpha } => {
                    baselines::TransformKind::Smooth { alpha: *alpha }
                }
                Method::Awq => baselines::TransformKind::Awq,
                other => bail!("{} is not a transform method", other.label()),
            };
            let (out, secs) = time(|| {
                baselines::quantize_transform_group(
                    &kind, members, act, gain, bias, gran,
                )
            });
            let out = out?;
            // group-level timing, attributed evenly across the members
            let per_member_secs = secs / members.len().max(1) as f64;
            let outcomes = out
                .quantized
                .iter()
                .map(|(name, q)| LayerOutcome {
                    name: name.clone(),
                    shape: q.shape,
                    alpha: 1.0,
                    evals: 1,
                    stats: None,
                    secs: per_member_secs,
                })
                .collect();
            Ok(TransformUnitOut {
                outcomes,
                quantized: out.quantized,
                ln_fold: Some((out.gain, out.bias)),
            })
        }
    }
}

/// SmoothQuant / AWQ: equivalent per-channel transformation folded into
/// the upstream layernorm, then AbsMax quantization of the transformed
/// weight. Scheduled over the shared [`group::GroupPlan`] resolved from
/// `groups` (name patterns, an explicit manifest, or a traced dataflow
/// graph); layers with no foldable upstream affine quantize plainly.
fn run_transformed(
    params: &mut Params,
    post: &Dts,
    quantizable: &[String],
    calib: Option<&Dts>,
    cfg: &PipelineConfig,
    groups: &group::GroupSource,
) -> Result<LayerBundle> {
    let calib = calib.ok_or_else(|| anyhow!("{} requires calibration stats",
                                            cfg.method.label()))?;
    let plan = group::GroupPlan::resolve(post, quantizable, groups)?;
    let mut layers = Vec::new();
    let mut quantized = BTreeMap::new();

    for unit in &plan.units {
        let members: Vec<(String, Tensor)> = unit
            .members()
            .iter()
            .map(|m| Ok((m.clone(), post.tensor_f32(m)?)))
            .collect::<Result<_>>()?;
        let (act, ln_params) = match unit {
            group::Unit::Group { gain, bias, members: names, .. } => {
                let act = match calib.tensor_f32(&names[0]) {
                    Ok(t) => t.into_data(),
                    Err(e) => bail!("calib stats for {}: {e}", names[0]),
                };
                let g = params
                    .get(gain)
                    .ok_or_else(|| anyhow!("missing {gain}"))?
                    .clone();
                let b = params
                    .get(bias)
                    .ok_or_else(|| anyhow!("missing {bias}"))?
                    .clone();
                (Some(act), Some((g, b)))
            }
            group::Unit::Layer { .. } => (None, None),
        };

        let out = quantize_transform_unit(
            unit,
            &members,
            act.as_deref(),
            ln_params,
            &cfg.method,
            cfg.granularity,
        )?;
        for (name, q) in out.quantized {
            params.insert(name.clone(), q.dequantize());
            quantized.insert(name, q);
        }
        layers.extend(out.outcomes);
        if let (group::Unit::Group { gain, bias, .. }, Some((g, b))) =
            (unit, out.ln_fold)
        {
            params.insert(gain.clone(), g);
            params.insert(bias.clone(), b);
        }
    }
    Ok((layers, quantized))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift;

    fn fake_ckpts(seed: u64) -> (Dts, Dts, Vec<String>) {
        let mut rng = XorShift::new(seed);
        let mut base = Dts::new();
        let mut post = Dts::new();
        let names = vec!["l0.wq".to_string(), "l0.w1".to_string(), "head".to_string()];
        let shapes = [(32usize, 32usize), (32, 64), (32, 16)];
        for (n, &(r, c)) in names.iter().zip(&shapes) {
            let wb = Tensor::new(vec![r, c], rng.normal_vec(r * c, 0.1));
            let wp = Tensor::new(
                vec![r, c],
                wb.data().iter().map(|&b| b + rng.normal() * 0.002).collect(),
            );
            base.insert_f32(n, &wb);
            post.insert_f32(n, &wp);
        }
        // layernorm params referenced by transformed methods
        for ln in ["l0.ln1", "l0.ln2", "lnf"] {
            let g = Tensor::full(vec![32], 1.0);
            let b = Tensor::zeros(vec![32]);
            base.insert_f32(&format!("{ln}.g"), &g);
            base.insert_f32(&format!("{ln}.b"), &b);
            post.insert_f32(&format!("{ln}.g"), &g);
            post.insert_f32(&format!("{ln}.b"), &b);
        }
        (post, base, names)
    }

    fn fake_calib(names: &[String], post: &Dts) -> Dts {
        let mut c = Dts::new();
        for n in names {
            let rows = post.tensor_f32(n).unwrap().rows();
            c.insert_f32(n, &Tensor::full(vec![rows], 0.5));
        }
        c
    }

    #[test]
    fn absmax_pipeline_quantizes_every_layer_once() {
        let (post, base, names) = fake_ckpts(1);
        let cfg = PipelineConfig::new(
            Granularity::Block(16),
            Method::AbsMax,
            Engine::Native { workers: 2 },
        );
        let out = run_pipeline(&post, &base, &names, None, &cfg, None).unwrap();
        assert_eq!(out.layers.len(), names.len());
        assert_eq!(out.quantized.len(), names.len());
        let agg = out.agg.unwrap();
        assert_eq!(agg.n as usize, 32 * 32 + 32 * 64 + 32 * 16);
        // dequantized weights replaced in params
        for n in &names {
            let deq = out.quantized[n].dequantize();
            assert_eq!(out.params[n], deq);
        }
    }

    #[test]
    fn search_pipeline_beats_or_matches_absmax_objective() {
        let (post, base, names) = fake_ckpts(2);
        let mk = |method| {
            PipelineConfig::new(
                Granularity::PerChannel,
                method,
                Engine::Native { workers: 1 },
            )
        };
        let absmax =
            run_pipeline(&post, &base, &names, None, &mk(Method::AbsMax), None).unwrap();
        let daq = run_pipeline(
            &post, &base, &names, None,
            &mk(Method::Search {
                objective: Objective::SignRate,
                range: (0.8, 1.25),
            }),
            None,
        )
        .unwrap();
        assert!(
            daq.agg.unwrap().sign_rate() >= absmax.agg.unwrap().sign_rate() - 1e-12
        );
    }

    #[test]
    fn worker_count_does_not_change_results() {
        let (post, base, names) = fake_ckpts(3);
        let mk = |workers| {
            PipelineConfig::new(
                Granularity::Block(16),
                Method::Search {
                    objective: Objective::CosSim,
                    range: (0.9, 1.11),
                },
                Engine::Native { workers },
            )
        };
        let a = run_pipeline(&post, &base, &names, None, &mk(1), None).unwrap();
        let b = run_pipeline(&post, &base, &names, None, &mk(4), None).unwrap();
        for (x, y) in a.layers.iter().zip(&b.layers) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.alpha, y.alpha);
        }
    }

    #[test]
    fn smoothquant_requires_calib() {
        let (post, base, names) = fake_ckpts(4);
        let cfg = PipelineConfig::new(
            Granularity::PerChannel,
            Method::SmoothQuant { alpha: 0.5 },
            Engine::Native { workers: 1 },
        );
        assert!(run_pipeline(&post, &base, &names, None, &cfg, None).is_err());
    }

    #[test]
    fn smoothquant_folds_layernorm_and_has_no_delta_stats() {
        let (post, base, names) = fake_ckpts(5);
        let calib = fake_calib(&names, &post);
        let cfg = PipelineConfig::new(
            Granularity::PerChannel,
            Method::SmoothQuant { alpha: 0.5 },
            Engine::Native { workers: 1 },
        );
        let out = run_pipeline(&post, &base, &names, Some(&calib), &cfg, None).unwrap();
        assert!(out.agg.is_none());
        assert!(out.layers.iter().all(|l| l.stats.is_none()));
        // ln gains actually changed
        let g = &out.params["l0.ln1.g"];
        assert!(g.data().iter().any(|&v| (v - 1.0).abs() > 1e-6));
    }

    #[test]
    fn awq_pipeline_runs() {
        let (post, base, names) = fake_ckpts(6);
        let calib = fake_calib(&names, &post);
        let cfg = PipelineConfig::new(
            Granularity::PerChannel,
            Method::Awq,
            Engine::Native { workers: 1 },
        );
        let out = run_pipeline(&post, &base, &names, Some(&calib), &cfg, None).unwrap();
        assert_eq!(out.layers.len(), names.len());
        assert!(out.agg.is_none());
    }

    #[test]
    fn checkpoint_roundtrip() {
        let (post, base, names) = fake_ckpts(7);
        let cfg = PipelineConfig::new(
            Granularity::Block(16),
            Method::AbsMax,
            Engine::Native { workers: 1 },
        );
        let out = run_pipeline(&post, &base, &names, None, &cfg, None).unwrap();
        let path = std::env::temp_dir().join(format!("daq_ckpt_{}.dts", std::process::id()));
        out.write_checkpoint(path.to_str().unwrap(), &post.meta).unwrap();
        let rd = Dts::read(&path).unwrap();
        std::fs::remove_file(&path).unwrap();
        // legacy stringly meta is gone; the structured descriptor replaces
        // both the top-level marker and the per-name granularity label
        assert!(rd.meta.get("quantized").is_none());
        for n in &names {
            assert!(rd.contains(n));
            assert!(rd.contains(&format!("{n}.codes")));
            assert!(rd.contains(&format!("{n}.scales")));
            assert!(rd.meta.get(&format!("gran.{n}")).is_none());
            assert_eq!(
                rd.meta.get(&format!("fmt.{n}")).map(|s| s.as_str()),
                Some("fp8-e4m3;block16")
            );
        }
    }

    #[test]
    fn transform_methods_reject_format_and_residual() {
        let (post, base, names) = fake_ckpts(9);
        let calib = fake_calib(&names, &post);
        let mut cfg = PipelineConfig::new(
            Granularity::PerChannel,
            Method::SmoothQuant { alpha: 0.5 },
            Engine::Native { workers: 1 },
        );
        cfg.format = CodeFormat::Int4 { group: 16 };
        let err = run_pipeline(&post, &base, &names, Some(&calib), &cfg, None)
            .unwrap_err();
        assert!(format!("{err:#}").contains("delta methods"), "{err:#}");

        let mut cfg = PipelineConfig::new(
            Granularity::PerChannel,
            Method::Awq,
            Engine::Native { workers: 1 },
        );
        cfg.residual_rank = 1;
        let err = run_pipeline(&post, &base, &names, Some(&calib), &cfg, None)
            .unwrap_err();
        assert!(format!("{err:#}").contains("delta methods"), "{err:#}");
    }

    #[test]
    fn int4_residual_pipeline_and_checkpoint_layout() {
        let (post, base, names) = fake_ckpts(10);
        let mut cfg = PipelineConfig::new(
            Granularity::Block(16),
            Method::AbsMax,
            Engine::Native { workers: 2 },
        );
        cfg.format = CodeFormat::Int4 { group: 16 };
        cfg.residual_rank = 2;
        let out = run_pipeline(&post, &base, &names, None, &cfg, None).unwrap();
        for n in &names {
            let q = &out.quantized[n];
            assert_eq!(q.format(), CodeFormat::Int4 { group: 16 });
            assert_eq!(q.residual.as_ref().unwrap().k, 2);
            // the eval-ready params include the residual correction
            assert_eq!(out.params[n], q.dequantize(), "{n}");
        }
        let path = std::env::temp_dir()
            .join(format!("daq_ckpt_int4_{}.dts", std::process::id()));
        out.write_checkpoint(path.to_str().unwrap(), &post.meta).unwrap();
        let rd = Dts::read(&path).unwrap();
        std::fs::remove_file(&path).unwrap();
        assert!(rd.meta.get("quantized").is_none());
        for n in &names {
            let q = &out.quantized[n];
            let (rows, cols) = q.shape;
            assert_eq!(
                rd.meta.get(&format!("fmt.{n}")).map(|s| s.as_str()),
                Some(format!("int4:16;block16;res=2;cols={cols}").as_str()),
                "{n}"
            );
            // codes land packed: two INT4 codes per byte, U8 shape
            // [rows, ceil(cols/2)]
            let (shape, data) = rd.tensor_u8(&format!("{n}.codes")).unwrap();
            assert_eq!(shape, vec![rows, cols.div_ceil(2)], "{n}");
            assert_eq!(data, q.codes, "{n}");
            let u = rd.get(&format!("{n}.res_u")).unwrap();
            assert_eq!(u.shape(), &[rows, 2], "{n}");
            let v = rd.get(&format!("{n}.res_v")).unwrap();
            assert_eq!(v.shape(), &[2, cols], "{n}");
        }
    }

    #[test]
    fn sidecar_dequant_loader_matches_pipeline_params() {
        // the serving-path loader (bulk LUT dequantization of the codes)
        // must reproduce the coordinator's dequantized weights bit-for-bit
        let (post, base, names) = fake_ckpts(8);
        for gran in [Granularity::Block(16), Granularity::PerChannel] {
            let cfg = PipelineConfig::new(
                gran,
                Method::Search {
                    objective: Objective::SignRate,
                    range: (0.8, 1.25),
                },
                Engine::Native { workers: 2 },
            );
            let out = run_pipeline(&post, &base, &names, None, &cfg, None).unwrap();
            let path = std::env::temp_dir().join(format!(
                "daq_ckpt_dequant_{}_{}.dts",
                std::process::id(),
                gran.label()
            ));
            out.write_checkpoint(path.to_str().unwrap(), &post.meta).unwrap();
            let rd = Dts::read(&path).unwrap();
            std::fs::remove_file(&path).unwrap();
            let params = crate::eval::load_params_dequant(&rd).unwrap();
            for n in &names {
                let got = &params[n];
                let want = &out.params[n];
                assert_eq!(got.shape(), want.shape(), "{n}");
                for (a, b) in got.data().iter().zip(want.data()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{n}");
                }
            }
            // non-quantized params (layernorms) still load
            assert!(params.contains_key("l0.ln1.g"));
        }
    }
}
