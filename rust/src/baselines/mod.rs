//! PTQ baselines from the paper's Table 2: AbsMax (no search), MSE-guided
//! search (Table 3), SmoothQuant, and AWQ.
//!
//! SmoothQuant and AWQ operate through an *equivalent per-channel
//! transformation*: the weight is rescaled per input channel and the
//! inverse scaling is folded into the preceding LayerNorm's affine
//! parameters, so the network function is unchanged (up to quantization).
//! As the paper notes (Table 2 footnote ‡), the transformed weights no
//! longer share the base model's numerical space, so the delta metrics
//! are undefined for these baselines — our pipeline reports them as such.

use anyhow::{bail, Result};

use crate::quant::{absmax_scales, quantize_with_scales, Granularity, QuantizedTensor};
use crate::tensor::Tensor;

/// Per-input-channel smoothing factors for one GEMM:
/// `s_j = max(|X_j|)^alpha / max(|W_j|)^(1-alpha)` (SmoothQuant Eq. 4).
///
/// `act_stat[j]` is the calibration statistic of input channel j (we use
/// the mean |activation| collected by the trainer; SmoothQuant's max works
/// the same way at these shapes). `w` is `[in, out]`; `max|W_j|` reduces
/// over the output dim for each input channel (row).
pub fn smoothquant_factors(w: &Tensor, act_stat: &[f32], alpha: f32) -> Vec<f32> {
    let (rows, cols) = (w.rows(), w.cols());
    assert_eq!(act_stat.len(), rows, "act stat per input channel");
    let mut wmax = vec![0.0f32; rows];
    for r in 0..rows {
        for c in 0..cols {
            wmax[r] = wmax[r].max(w.at2(r, c).abs());
        }
    }
    smoothing_factors(act_stat, &wmax, alpha)
}

/// The SmoothQuant factor formula itself — the single source shared by
/// the per-GEMM path and the group path, so a one-member group is
/// bitwise-identical to [`smoothquant_factors`] by construction.
fn smoothing_factors(act: &[f32], wmax: &[f32], alpha: f32) -> Vec<f32> {
    act.iter()
        .zip(wmax)
        .map(|(&a, &w)| {
            (a.max(1e-8).powf(alpha) / w.max(1e-8).powf(1.0 - alpha)).max(1e-6)
        })
        .collect()
}

/// Apply row scaling: `W'[r, c] = W[r, c] * s[r]` (the weight absorbs the
/// activation difficulty; activations would be divided by `s` — which the
/// caller folds into the preceding normalization layer).
pub fn scale_rows(w: &Tensor, s: &[f32]) -> Tensor {
    let (rows, cols) = (w.rows(), w.cols());
    assert_eq!(s.len(), rows);
    let mut out = w.clone();
    for r in 0..rows {
        for c in 0..cols {
            let v = out.at2(r, c) * s[r];
            out.set2(r, c, v);
        }
    }
    out
}

/// SmoothQuant baseline for one GEMM: smooth, then AbsMax-quantize.
/// Returns the quantized transformed weight and the factors the caller
/// must fold into the upstream affine (divide gain/bias by `s`).
pub fn smoothquant_gemm(
    w: &Tensor,
    act_stat: &[f32],
    alpha: f32,
    granularity: Granularity,
) -> (QuantizedTensor, Vec<f32>) {
    let s = smoothquant_factors(w, act_stat, alpha);
    let w2 = scale_rows(w, &s);
    let s0 = absmax_scales(&w2, granularity);
    (quantize_with_scales(&w2, &s0, 1.0), s)
}

/// AWQ-style baseline for one GEMM: grid-search the salience exponent
/// `alpha ∈ {0, 0.25, .., 1}` minimizing an activation-weighted
/// reconstruction proxy `sum_j act_j * ||W_j - Q(W'_j)/s_j||²`
/// (per-channel scaling protects activation-salient channels).
pub fn awq_gemm(
    w: &Tensor,
    act_stat: &[f32],
    granularity: Granularity,
) -> (QuantizedTensor, Vec<f32>, f32) {
    let (rows, cols) = (w.rows(), w.cols());
    let mut best: Option<(f64, f32, QuantizedTensor, Vec<f32>)> = None;
    for step in 0..5 {
        let alpha = step as f32 * 0.25;
        let s: Vec<f32> = act_stat
            .iter()
            .map(|a| a.max(1e-8).powf(alpha).max(1e-6))
            .collect();
        let w2 = scale_rows(w, &s);
        let s0 = absmax_scales(&w2, granularity);
        let q = quantize_with_scales(&w2, &s0, 1.0);
        let deq = q.dequantize();
        // reconstruction in the ORIGINAL space: W ≈ deq / s (rows)
        let mut err = 0.0f64;
        for r in 0..rows {
            let a = act_stat[r] as f64;
            for c in 0..cols {
                let rec = deq.at2(r, c) / s[r];
                let d = (rec - w.at2(r, c)) as f64;
                err += a * d * d;
            }
        }
        if best.as_ref().map(|(e, ..)| err < *e).unwrap_or(true) {
            best = Some((err, alpha, q, s));
        }
    }
    let (_, alpha, q, s) = best.unwrap();
    (q, s, alpha)
}

/// Which equivalent per-channel transformation a group applies.
#[derive(Clone, Copy, Debug)]
pub enum TransformKind {
    /// SmoothQuant α-migration (Eq. 4): shared smoothing vector from the
    /// combined `max|W_j|` of every group member.
    Smooth { alpha: f32 },
    /// AWQ-style salience search: one shared α grid-searched on the
    /// group's first member.
    Awq,
}

/// One layernorm-coupled group, transformed and quantized: the unit of
/// work shared by the in-memory transformed pipeline and the group-aware
/// streaming driver. Residency is O(this group), never O(model).
pub struct TransformedGroup {
    /// Shared per-input-channel smoothing factors.
    pub s: Vec<f32>,
    /// Quantized transformed members, in input order.
    pub quantized: Vec<(String, QuantizedTensor)>,
    /// The upstream layernorm affine with the inverse scaling folded in.
    pub gain: Tensor,
    pub bias: Tensor,
}

/// Transform and quantize one group: derive the shared smoothing vector
/// from `members` (post weights, `[in, out]`, in group order) and the
/// calibration statistic `act` (per input channel), rescale and
/// AbsMax-quantize each member, and fold the inverse into the group's
/// layernorm `gain`/`bias`. Deterministic: the f32 reduction order is
/// fixed by the member order, so callers that agree on a
/// [`GroupPlan`](crate::coordinator::group::GroupPlan) get bitwise-equal
/// output.
pub fn quantize_transform_group(
    kind: &TransformKind,
    members: &[(String, Tensor)],
    act: &[f32],
    mut gain: Tensor,
    mut bias: Tensor,
    granularity: Granularity,
) -> Result<TransformedGroup> {
    let Some((first_name, first)) = members.first() else {
        bail!("transform group has no members");
    };
    let rows = first.rows();
    if act.len() != rows {
        bail!("calib stat len {} != in-dim {rows} for {first_name}", act.len());
    }
    for (name, w) in members {
        if w.rows() != rows {
            bail!("group member {name} has {} rows, first member has {rows}", w.rows());
        }
    }

    let s: Vec<f32> = match kind {
        TransformKind::Smooth { alpha } => {
            // combined per-input-channel |W| max over all group members
            let mut wmax = vec![0.0f32; rows];
            for (_, w) in members {
                for r in 0..rows {
                    for c in 0..w.cols() {
                        wmax[r] = wmax[r].max(w.at2(r, c).abs());
                    }
                }
            }
            smoothing_factors(act, &wmax, *alpha)
        }
        TransformKind::Awq => {
            // one shared AWQ alpha per group, searched on the first member
            let (_, s, _) = awq_gemm(first, act, granularity);
            s
        }
    };

    let quantized = members
        .iter()
        .map(|(name, w)| {
            let w2 = scale_rows(w, &s);
            let s0 = absmax_scales(&w2, granularity);
            (name.clone(), quantize_with_scales(&w2, &s0, 1.0))
        })
        .collect();

    fold_into_layernorm(gain.data_mut(), bias.data_mut(), &s);
    Ok(TransformedGroup { s, quantized, gain, bias })
}

/// Fold the inverse smoothing into a layernorm's gain and bias so the
/// network function is preserved: ln'(x) = ln(x) / s.
pub fn fold_into_layernorm(gain: &mut [f32], bias: &mut [f32], s: &[f32]) {
    assert_eq!(gain.len(), s.len());
    assert_eq!(bias.len(), s.len());
    for j in 0..s.len() {
        gain[j] /= s[j];
        bias[j] /= s[j];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::matmul;
    use crate::util::rng::XorShift;

    fn rand_w(r: usize, c: usize, seed: u64) -> Tensor {
        let mut rng = XorShift::new(seed);
        Tensor::new(vec![r, c], rng.normal_vec(r * c, 0.1))
    }

    fn rand_acts(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = XorShift::new(seed);
        (0..n).map(|_| rng.f32() * 2.0 + 0.05).collect()
    }

    #[test]
    fn smoothquant_factors_balance_scales() {
        let w = rand_w(32, 16, 1);
        let mut acts = rand_acts(32, 2);
        acts[3] = 100.0; // an activation outlier channel
        let s = smoothquant_factors(&w, &acts, 0.5);
        // the outlier channel gets the largest smoothing factor
        let max_idx = s
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(max_idx, 3);
        assert!(s.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn equivalent_transformation_preserves_function() {
        // (x / s) @ (W * s) == x @ W exactly in math; verify to f32 tol
        let w = rand_w(16, 8, 3);
        let acts = rand_acts(16, 4);
        let s = smoothquant_factors(&w, &acts, 0.5);
        let w2 = scale_rows(&w, &s);
        let mut rng = XorShift::new(5);
        let x = Tensor::new(vec![4, 16], rng.normal_vec(64, 1.0));
        let xs = Tensor::new(
            vec![4, 16],
            x.data()
                .iter()
                .enumerate()
                .map(|(i, &v)| v / s[i % 16])
                .collect(),
        );
        let y1 = matmul(&x, &w);
        let y2 = matmul(&xs, &w2);
        for (a, b) in y1.data().iter().zip(y2.data()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn smoothquant_alpha0_is_weight_only() {
        // alpha = 0: s_j = 1 / max|W_j| — balances weight rows only
        let w = rand_w(8, 8, 6);
        let acts = rand_acts(8, 7);
        let s = smoothquant_factors(&w, &acts, 0.0);
        for r in 0..8 {
            let wmax = (0..8).map(|c| w.at2(r, c).abs()).fold(0.0f32, f32::max);
            assert!((s[r] - 1.0 / wmax).abs() / s[r] < 1e-4);
        }
    }

    #[test]
    fn awq_picks_nonnegative_alpha_and_improves_weighted_error() {
        let w = rand_w(64, 32, 8);
        let mut acts = rand_acts(64, 9);
        acts[0] = 50.0; // salient channel
        let (q, s, alpha) = awq_gemm(&w, &acts, Granularity::PerChannel);
        assert!((0.0..=1.0).contains(&alpha));
        assert_eq!(q.shape, (64, 32));
        assert_eq!(s.len(), 64);
        // reconstruct and compare weighted error vs plain absmax
        let deq = q.dequantize();
        let mut err_awq = 0.0f64;
        for r in 0..64 {
            for c in 0..32 {
                let rec = deq.at2(r, c) / s[r];
                let d = (rec - w.at2(r, c)) as f64;
                err_awq += acts[r] as f64 * d * d;
            }
        }
        let plain = crate::quant::quantize(&w, Granularity::PerChannel, 1.0).dequantize();
        let mut err_plain = 0.0f64;
        for r in 0..64 {
            for c in 0..32 {
                let d = (plain.at2(r, c) - w.at2(r, c)) as f64;
                err_plain += acts[r] as f64 * d * d;
            }
        }
        assert!(err_awq <= err_plain * 1.0001,
                "awq {err_awq} vs plain {err_plain}");
    }

    #[test]
    fn fold_into_layernorm_inverts_scaling() {
        let s = vec![2.0f32, 0.5, 1.0];
        let mut g = vec![1.0f32, 1.0, 1.0];
        let mut b = vec![0.2f32, -0.4, 0.0];
        fold_into_layernorm(&mut g, &mut b, &s);
        assert_eq!(g, vec![0.5, 2.0, 1.0]);
        assert_eq!(b, vec![0.1, -0.8, 0.0]);
    }

    #[test]
    fn transform_group_matches_single_gemm_path() {
        // a one-member group must reduce exactly to the per-GEMM
        // smoothquant path (shared-vector derivation degenerates)
        let w = rand_w(16, 8, 11);
        let acts = rand_acts(16, 12);
        let (q_ref, s_ref) = smoothquant_gemm(&w, &acts, 0.5, Granularity::PerChannel);
        let out = quantize_transform_group(
            &TransformKind::Smooth { alpha: 0.5 },
            &[("w".to_string(), w.clone())],
            &acts,
            Tensor::full(vec![16], 1.0),
            Tensor::zeros(vec![16]),
            Granularity::PerChannel,
        )
        .unwrap();
        assert_eq!(out.s, s_ref);
        assert_eq!(out.quantized.len(), 1);
        assert_eq!(out.quantized[0].0, "w");
        assert_eq!(out.quantized[0].1.codes, q_ref.codes);
        assert_eq!(out.quantized[0].1.scales.scales, q_ref.scales.scales);
        for (gv, sv) in out.gain.data().iter().zip(&out.s) {
            assert_eq!(*gv, 1.0 / sv);
        }
    }

    #[test]
    fn transform_group_shares_one_vector_across_members() {
        let wa = rand_w(12, 6, 21);
        let wb = rand_w(12, 10, 22);
        let acts = rand_acts(12, 23);
        let out = quantize_transform_group(
            &TransformKind::Smooth { alpha: 0.5 },
            &[("a".to_string(), wa.clone()), ("b".to_string(), wb.clone())],
            &acts,
            Tensor::full(vec![12], 1.0),
            Tensor::zeros(vec![12]),
            Granularity::PerChannel,
        )
        .unwrap();
        // the shared vector uses the combined per-row max of both members
        let mut wmax = vec![0.0f32; 12];
        for w in [&wa, &wb] {
            for r in 0..12 {
                for c in 0..w.cols() {
                    wmax[r] = wmax[r].max(w.at2(r, c).abs());
                }
            }
        }
        for r in 0..12 {
            let want = (acts[r].max(1e-8).powf(0.5)
                / wmax[r].max(1e-8).powf(0.5))
            .max(1e-6);
            assert_eq!(out.s[r], want);
        }
        assert_eq!(out.quantized.len(), 2);
    }

    #[test]
    fn transform_group_rejects_bad_inputs() {
        let w = rand_w(8, 4, 31);
        let acts = rand_acts(4, 32); // wrong length
        let err = quantize_transform_group(
            &TransformKind::Awq,
            &[("w".to_string(), w)],
            &acts,
            Tensor::full(vec![8], 1.0),
            Tensor::zeros(vec![8]),
            Granularity::PerChannel,
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("calib"), "{err:#}");
    }

    #[test]
    fn scale_rows_shape_guard() {
        let w = rand_w(4, 4, 10);
        let s = vec![1.0f32; 4];
        let w2 = scale_rows(&w, &s);
        assert_eq!(w2, w);
    }
}
