//! E5M2 codec — the second OCP FP8 format, provided for the bit-width
//! ablation the paper lists as future work (§5: "exploring lower
//! bit-widths"). Same saturating-RNE semantics as E4M3.
//!
//! Layout: 1 sign / 5 exponent (bias 15) / 2 mantissa. Max finite ±57344;
//! subnormal step 2⁻¹⁶. We treat the IEEE-style inf/NaN codes (exp = 31)
//! as NaN and saturate on encode, mirroring the E4M3FN convention so both
//! formats behave identically in the quantizer.

/// Largest finite E5M2 value.
pub const E5M2_MAX: f32 = 57344.0;
const MIN_NORMAL_EXP: i32 = -14;
const MANT_BITS: i32 = 2;

#[inline(always)]
fn exp2i(e: i32) -> f32 {
    f32::from_bits(((e + 127) as u32) << 23)
}

/// Encode an `f32` to its nearest E5M2 code (saturating RNE).
#[inline]
pub fn encode_e5m2(x: f32) -> u8 {
    if x.is_nan() {
        return 0x7F;
    }
    let sign = if x < 0.0 { 0x80u8 } else { 0 };
    let mag = x.abs().min(E5M2_MAX);
    if mag == 0.0 {
        return 0;
    }
    let e = ((mag.to_bits() >> 23) as i32 - 127).max(MIN_NORMAL_EXP);
    let step = exp2i(e - MANT_BITS);
    let n = (mag / step).round_ties_even() as u32; // [0, 8]
    if n == 0 {
        return 0;
    }
    let (n, e) = if n == 8 { (4, e + 1) } else { (n, e) };
    debug_assert!(e <= 15);
    if n >= 4 {
        sign | (((e + 15) as u8) << 2) | ((n - 4) as u8)
    } else {
        sign | n as u8
    }
}

/// Decode an E5M2 code to `f32`; exp=31 codes decode to NaN (inf treated
/// as NaN under the saturating convention).
#[inline]
pub fn decode_e5m2(code: u8) -> f32 {
    let sign = if code & 0x80 != 0 { -1.0f32 } else { 1.0 };
    let e = ((code >> 2) & 0x1F) as i32;
    let m = (code & 0x3) as i32;
    if e == 31 {
        return f32::NAN;
    }
    let v = if e == 0 {
        m as f32 * exp2i(-16)
    } else {
        (4 + m) as f32 * exp2i(e - 17)
    };
    sign * v
}

/// Quantize–dequantize onto the E5M2 grid.
#[inline]
pub fn qdq_e5m2(x: f32) -> f32 {
    let a = x.clamp(-E5M2_MAX, E5M2_MAX);
    let mag = a.abs();
    if mag == 0.0 {
        return 0.0;
    }
    let e = ((mag.to_bits() >> 23) as i32 - 127).max(MIN_NORMAL_EXP);
    let step = exp2i(e - MANT_BITS);
    (a / step).round_ties_even() * step
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_codes() {
        for c in 0u16..256 {
            let c = c as u8;
            let v = decode_e5m2(c);
            if v.is_nan() {
                continue;
            }
            let expect = if v == 0.0 { 0 } else { c };
            assert_eq!(encode_e5m2(v), expect, "code {c:#04x}");
        }
    }

    #[test]
    fn qdq_fixed_points() {
        for c in 0u16..256 {
            let v = decode_e5m2(c as u8);
            if v.is_nan() {
                continue;
            }
            assert_eq!(qdq_e5m2(v), v);
        }
    }

    #[test]
    fn saturation_and_range() {
        assert_eq!(qdq_e5m2(1e9), E5M2_MAX);
        assert_eq!(qdq_e5m2(-1e9), -E5M2_MAX);
        // wider dynamic range than E4M3 but coarser mantissa
        assert_eq!(qdq_e5m2(448.0), 448.0); // power-of-two multiple fits
        assert_eq!(qdq_e5m2(17.0), 16.0); // tie to even (grid 16, 20)
    }

    #[test]
    fn coarser_than_e4m3_near_one() {
        // E5M2 step at 1.0 is 0.25; E4M3 step is 0.125
        assert_eq!(qdq_e5m2(1.124), 1.0);
        assert_eq!(crate::fp8::qdq_e4m3(1.124), 1.125);
    }
}
