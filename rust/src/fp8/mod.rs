//! Software FP8 codecs: E4M3 (OCP "E4M3FN") and E5M2.
//!
//! The numeric substrate of the whole pipeline. Bit-exact with the JAX
//! reference (`python/compile/kernels/ref.py`): the cross-layer golden test
//! (`tests/golden_fp8.rs`) decodes `artifacts/fp8_golden.dts` and compares
//! every vector bit-for-bit.
//!
//! E4M3FN layout: 1 sign / 4 exponent (bias 7) / 3 mantissa. No infinities;
//! `S.1111.111` is NaN; max finite ±448; subnormal step 2⁻⁹. Conversion is
//! *saturating* round-to-nearest-even (the semantics quantization pipelines
//! use — out-of-range values clamp to ±448 rather than becoming NaN).

mod e5m2;
pub use e5m2::{decode_e5m2, encode_e5m2, qdq_e5m2, E5M2_MAX};

/// Largest finite E4M3 value.
pub const E4M3_MAX: f32 = 448.0;
/// The canonical E4M3 NaN code.
pub const E4M3_NAN: u8 = 0x7F;
/// Smallest normal exponent (unbiased).
const MIN_NORMAL_EXP: i32 = -6;

/// Encode an `f32` to its nearest E4M3 code (saturating RNE).
///
/// Zero encodes to `0x00` regardless of input sign, matching the JAX
/// reference (sign of zero carries no information for weights).
#[inline]
pub fn encode_e4m3(x: f32) -> u8 {
    if x.is_nan() {
        return E4M3_NAN;
    }
    let sign = if x < 0.0 { 0x80u8 } else { 0 };
    let mag = x.abs().min(E4M3_MAX);
    if mag == 0.0 {
        return 0;
    }
    // floor(log2(mag)) via exponent bits; f32 subnormal inputs have biased
    // exponent 0 -> e = -127, clamped to the E4M3 subnormal regime below.
    let e = ((mag.to_bits() >> 23) as i32 - 127).max(MIN_NORMAL_EXP);
    let step = exp2i(e - 3);
    let n = (mag / step).round_ties_even() as u32; // grid index in [0, 16]
    if n == 0 {
        return 0; // rounded down to zero: drop sign, matching the reference
    }
    let (n, e) = if n == 16 { (8, e + 1) } else { (n, e) }; // crossed binade
    debug_assert!(e <= 8, "saturation must have clamped e (mag={mag})");
    if n >= 8 {
        sign | (((e + 7) as u8) << 3) | ((n - 8) as u8)
    } else {
        sign | n as u8 // subnormal: e == -6, exponent field 0
    }
}

/// Decode an E4M3 code to `f32`. The NaN codes (`0x7F`/`0xFF`) decode to NaN.
#[inline]
pub fn decode_e4m3(code: u8) -> f32 {
    let sign = if code & 0x80 != 0 { -1.0f32 } else { 1.0 };
    let e = ((code >> 3) & 0xF) as i32;
    let m = (code & 0x7) as i32;
    if e == 15 && m == 7 {
        return f32::NAN;
    }
    let v = if e == 0 {
        m as f32 * exp2i(-9)
    } else {
        (8 + m) as f32 * exp2i(e - 10)
    };
    sign * v
}

/// Quantize–dequantize: project onto the E4M3 value grid (saturating RNE).
///
/// Direct computation (no table) — this is the hot path of the scale
/// search; see `metrics::sweep` for the fused loop built on it.
#[inline]
pub fn qdq_e4m3(x: f32) -> f32 {
    let a = x.clamp(-E4M3_MAX, E4M3_MAX);
    let mag = a.abs();
    if mag == 0.0 {
        return 0.0;
    }
    let e = ((mag.to_bits() >> 23) as i32 - 127).max(MIN_NORMAL_EXP);
    let step = exp2i(e - 3);
    (a / step).round_ties_even() * step
}

/// Reciprocal-scale quantize–dequantize: `qdq_e4m3(x · s⁻¹) · s`.
///
/// The canonical *scaled* projection of the whole pipeline: division-free,
/// so the sweep hot loop hoists `s⁻¹ = 1/s` once per candidate × scale
/// region instead of dividing per element. Every scaled qdq/encode path
/// (`quant::qdq`, `quantize_with_scales`, `metrics::sweep_native`, the
/// tiled `metrics::SweepPlan`) goes through this same form, which is what
/// keeps the fused sweep, the pointwise metrics, and the storage quantizer
/// bit-identical to each other.
///
/// `inv_s` must be finite (use [`recip_scale`]) or `x == 0` turns into
/// `0 · ∞ = NaN`.
#[inline(always)]
pub fn qdq_e4m3_scaled(x: f32, inv_s: f32, s: f32) -> f32 {
    qdq_e4m3(x * inv_s) * s
}

/// Saturating scale reciprocal: `min(1/s, f32::MAX)`. The one blessed way
/// to build the `inv_s` for [`qdq_e4m3_scaled`] — if `s·α` goes subnormal
/// (tiny group absmax × small α), a raw `1/s` overflows to `+∞` and zero
/// weights would quantize to NaN; saturating at `f32::MAX` keeps zeros at
/// zero and everything else cleanly clamping to ±448, matching the old
/// division semantics. Every caller must use this same form so the
/// engines stay bit-identical to each other.
#[inline(always)]
pub fn recip_scale(s: f32) -> f32 {
    (1.0 / s).min(f32::MAX)
}

/// Exact power of two for small integer exponents (|e| < 127).
#[inline(always)]
fn exp2i(e: i32) -> f32 {
    f32::from_bits(((e + 127) as u32) << 23)
}

/// Ratio between the two formats' maxima — rescales an E4M3-convention
/// absmax scale (`|W|max/448`) into the E5M2 range for format ablations.
pub fn e5m2_ratio() -> f32 {
    e5m2::E5M2_MAX / E4M3_MAX
}

/// Reciprocal-scale quantize–dequantize on the E5M2 grid:
/// `qdq_e5m2(x · s⁻¹) · s`. The E5M2 instantiation of the canonical
/// scaled projection — same contract as [`qdq_e4m3_scaled`] (`inv_s`
/// built by [`recip_scale`]).
#[inline(always)]
pub fn qdq_e5m2_scaled(x: f32, inv_s: f32, s: f32) -> f32 {
    qdq_e5m2(x * inv_s) * s
}

/// Decode table for fast bulk dequantization (NaN codes decode to NaN).
pub fn decode_table() -> [f32; 256] {
    let mut t = [0.0f32; 256];
    for (c, slot) in t.iter_mut().enumerate() {
        *slot = decode_e4m3(c as u8);
    }
    t
}

/// Bulk-decode a slice of E4M3 codes. The workhorse of every
/// quantized-resident read path: [`crate::quant::QuantizedTensor`] row
/// dequantization and the fused dequant-matmul decode rows through this
/// instead of per-element [`decode_e4m3`] calls. Dispatches to the
/// SIMD kernel layer ([`crate::quant::kernels`]); every mode is
/// bitwise-equal to [`decode_slice_into_scalar`].
#[inline]
pub fn decode_slice_into(codes: &[u8], out: &mut [f32]) {
    crate::quant::kernels::decode_e4m3_into(codes, out);
}

/// The scalar LUT walk behind [`decode_slice_into`] — the always-compiled
/// bitwise reference the SIMD decode kernels are verified against, and
/// the `DAQ_SIMD=off` / unsupported-ISA fallback.
pub fn decode_slice_into_scalar(codes: &[u8], out: &mut [f32]) {
    assert_eq!(codes.len(), out.len());
    let table = decode_lut();
    for (o, &c) in out.iter_mut().zip(codes) {
        *o = table[c as usize];
    }
}

static DECODE_LUT: std::sync::OnceLock<[f32; 256]> = std::sync::OnceLock::new();

/// Process-wide decode table, built once on first use — the bulk
/// dequantization path (`quant::QuantizedTensor::dequantize`, the
/// sidecar checkpoint loader) indexes this instead of calling
/// [`decode_e4m3`] per element or rebuilding the table per tensor.
pub fn decode_lut() -> &'static [f32; 256] {
    DECODE_LUT.get_or_init(decode_table)
}

/// E5M2 decode table (NaN codes decode to NaN).
pub fn decode_table_e5m2() -> [f32; 256] {
    let mut t = [0.0f32; 256];
    for (c, slot) in t.iter_mut().enumerate() {
        *slot = decode_e5m2(c as u8);
    }
    t
}

static DECODE_LUT_E5M2: std::sync::OnceLock<[f32; 256]> = std::sync::OnceLock::new();

/// Process-wide E5M2 decode table — the E5M2 twin of [`decode_lut`].
pub fn decode_lut_e5m2() -> &'static [f32; 256] {
    DECODE_LUT_E5M2.get_or_init(decode_table_e5m2)
}

/// Bulk-decode a slice of E5M2 codes — the E5M2 twin of
/// [`decode_slice_into`] (same SIMD dispatch, same bitwise contract),
/// used by the quantized-resident read paths when a tensor's
/// `CodeFormat` is `fp8-e5m2`.
#[inline]
pub fn decode_slice_into_e5m2(codes: &[u8], out: &mut [f32]) {
    crate::quant::kernels::decode_e5m2_into(codes, out);
}

/// The scalar LUT walk behind [`decode_slice_into_e5m2`] (see
/// [`decode_slice_into_scalar`]).
pub fn decode_slice_into_e5m2_scalar(codes: &[u8], out: &mut [f32]) {
    assert_eq!(codes.len(), out.len());
    let table = decode_lut_e5m2();
    for (o, &c) in out.iter_mut().zip(codes) {
        *o = table[c as usize];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_codes_roundtrip() {
        for c in 0u16..256 {
            let c = c as u8;
            let v = decode_e4m3(c);
            if v.is_nan() {
                assert!(c == 0x7F || c == 0xFF);
                continue;
            }
            let back = encode_e4m3(v);
            // -0 re-encodes to +0 by design
            let expect = if v == 0.0 { 0 } else { c };
            assert_eq!(back, expect, "code {c:#04x} -> {v} -> {back:#04x}");
        }
    }

    #[test]
    fn grid_values_are_qdq_fixed_points() {
        for c in 0u16..256 {
            let v = decode_e4m3(c as u8);
            if v.is_nan() {
                continue;
            }
            assert_eq!(qdq_e4m3(v), v, "code {c:#04x}");
        }
    }

    #[test]
    fn saturation() {
        assert_eq!(qdq_e4m3(1e9), 448.0);
        assert_eq!(qdq_e4m3(-1e9), -448.0);
        assert_eq!(qdq_e4m3(449.0), 448.0);
        assert_eq!(encode_e4m3(1e9), 0x7E);
        assert_eq!(encode_e4m3(-1e9), 0xFE);
    }

    #[test]
    fn max_finite() {
        assert_eq!(decode_e4m3(0x7E), 448.0);
        assert_eq!(decode_e4m3(0xFE), -448.0);
    }

    #[test]
    fn subnormal_grid() {
        for k in 0..8 {
            let v = k as f32 * exp2i(-9);
            assert_eq!(qdq_e4m3(v), v);
        }
        // below half the smallest subnormal rounds to zero
        assert_eq!(qdq_e4m3(exp2i(-11)), 0.0);
        // exactly half ties to even (zero)
        assert_eq!(qdq_e4m3(exp2i(-10)), 0.0);
        // just above half rounds up
        assert_eq!(qdq_e4m3(exp2i(-10) * 1.001), exp2i(-9));
    }

    #[test]
    fn rne_tie_breaking() {
        // 17 ties between 16 and 18 -> 16 (even grid index)
        assert_eq!(qdq_e4m3(17.0), 16.0);
        // 19 ties between 18 and 20 -> 20 (even grid index)
        assert_eq!(qdq_e4m3(19.0), 20.0);
    }

    #[test]
    fn nan_handling() {
        assert_eq!(encode_e4m3(f32::NAN), E4M3_NAN);
        assert!(decode_e4m3(E4M3_NAN).is_nan());
        assert!(decode_e4m3(0xFF).is_nan());
    }

    #[test]
    fn zero_sign_dropped() {
        assert_eq!(encode_e4m3(0.0), 0);
        assert_eq!(encode_e4m3(-0.0), 0);
        assert_eq!(qdq_e4m3(-0.0), 0.0);
        assert_eq!(encode_e4m3(-1e-12), 0); // rounds to zero, sign dropped
    }

    #[test]
    fn monotone_on_grid() {
        // decode must be strictly increasing over positive non-NaN codes
        let mut prev = -1.0f32;
        for c in 0u8..0x7F {
            let v = decode_e4m3(c);
            assert!(v > prev, "code {c:#04x}: {v} <= {prev}");
            prev = v;
        }
    }

    #[test]
    fn qdq_equals_decode_encode() {
        // the fast qdq path must agree with the table path on random values
        let mut rng = crate::util::rng::XorShift::new(7);
        for _ in 0..100_000 {
            let x = (rng.f32() - 0.5) * 1000.0;
            let fast = qdq_e4m3(x);
            let slow = decode_e4m3(encode_e4m3(x));
            assert_eq!(fast.to_bits(), slow.to_bits(), "x={x}");
        }
    }

    #[test]
    fn decode_lut_matches_decode() {
        let lut = decode_lut();
        for c in 0u16..256 {
            let want = decode_e4m3(c as u8);
            let got = lut[c as usize];
            if want.is_nan() {
                assert!(got.is_nan());
            } else {
                assert_eq!(got.to_bits(), want.to_bits());
            }
        }
        // the static is shared, not rebuilt
        assert!(std::ptr::eq(lut, decode_lut()));
    }

    #[test]
    fn decode_slice_matches_scalar_decode() {
        let codes: Vec<u8> = (0..=255).collect();
        let mut out = vec![0.0f32; 256];
        decode_slice_into(&codes, &mut out);
        for (c, v) in codes.iter().zip(&out) {
            let want = decode_e4m3(*c);
            if want.is_nan() {
                assert!(v.is_nan());
            } else {
                assert_eq!(v.to_bits(), want.to_bits());
            }
        }
    }

    #[test]
    fn scaled_qdq_is_plain_qdq_at_unit_scale() {
        let mut rng = crate::util::rng::XorShift::new(11);
        for _ in 0..10_000 {
            let x = (rng.f32() - 0.5) * 1000.0;
            assert_eq!(
                qdq_e4m3_scaled(x, 1.0, 1.0).to_bits(),
                qdq_e4m3(x).to_bits()
            );
        }
    }

    #[test]
    fn scaled_qdq_projects_onto_scaled_grid() {
        // every output must be (grid value) * s exactly
        let s = 0.037f32;
        let inv = 1.0 / s;
        let mut rng = crate::util::rng::XorShift::new(13);
        for _ in 0..10_000 {
            let x = (rng.f32() - 0.5) * 30.0;
            let q = qdq_e4m3_scaled(x, inv, s);
            let grid = qdq_e4m3(x * inv);
            assert_eq!(q.to_bits(), (grid * s).to_bits());
        }
    }

    #[test]
    fn e5m2_lut_and_scaled_qdq_match_scalar_paths() {
        let lut = decode_lut_e5m2();
        for c in 0u16..256 {
            let want = decode_e5m2(c as u8);
            if want.is_nan() {
                assert!(lut[c as usize].is_nan());
            } else {
                assert_eq!(lut[c as usize].to_bits(), want.to_bits());
            }
        }
        assert!(std::ptr::eq(lut, decode_lut_e5m2()));
        let codes: Vec<u8> = (0..=255).collect();
        let mut out = vec![0.0f32; 256];
        decode_slice_into_e5m2(&codes, &mut out);
        for (c, v) in codes.iter().zip(&out) {
            let want = decode_e5m2(*c);
            assert!(want.is_nan() && v.is_nan() || v.to_bits() == want.to_bits());
        }
        let s = 0.21f32;
        let inv = 1.0 / s;
        let mut rng = crate::util::rng::XorShift::new(17);
        for _ in 0..10_000 {
            let x = (rng.f32() - 0.5) * 50.0;
            assert_eq!(
                qdq_e5m2_scaled(x, inv, s).to_bits(),
                (qdq_e5m2(x * inv) * s).to_bits()
            );
        }
    }

    #[test]
    fn relative_error_bound() {
        let mut rng = crate::util::rng::XorShift::new(9);
        for _ in 0..50_000 {
            let x = (rng.f32() - 0.5) * 800.0;
            let q = qdq_e4m3(x);
            let in_range = x.abs() <= 448.0;
            if in_range && x.abs() >= exp2i(-6) {
                assert!((q - x).abs() <= x.abs() * exp2i(-4) + 1e-12,
                        "x={x} q={q}");
            }
        }
    }
}
