//! Quantizer core: scale granularities, AbsMax scale initialization, and
//! the scale-parameterized quantize–dequantize operator `Q_s(W)` (paper
//! Eq. 4), instantiated for every [`CodeFormat`] (FP8 E4M3/E5M2, packed
//! INT4) plus an optional low-rank residual correction.
//!
//! Granularities match the paper's setup (§3.1): block-wise with block
//! size 128 (the DeepSeek-V3 FP8 convention) and per-channel
//! (per output column). Per-tensor is included for ablations. The code
//! format rides on the [`ScaleGrid`] (the sweep needs `Qmax` and the
//! projection; storage needs the packed layout), so every existing
//! `s0`-threading API picks formats up without signature changes.

use crate::fp8;
use crate::tensor::Tensor;

pub mod format;
pub mod kernels;

pub use format::{CodeFormat, Descriptor};

/// Scale granularity for `Q_s`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Granularity {
    /// One scale for the whole tensor.
    PerTensor,
    /// One scale per output channel (column of `W[in, out]`).
    PerChannel,
    /// One scale per `b`×`b` block (paper uses 128).
    Block(usize),
}

impl Granularity {
    pub fn parse(s: &str) -> Result<Granularity, String> {
        match s {
            "tensor" => Ok(Granularity::PerTensor),
            "channel" => Ok(Granularity::PerChannel),
            "block" => Ok(Granularity::Block(128)),
            other => {
                if let Some(b) = other.strip_prefix("block") {
                    b.parse()
                        .map(Granularity::Block)
                        .map_err(|_| format!("bad granularity {other:?}"))
                } else {
                    Err(format!(
                        "bad granularity {other:?} (tensor|channel|block|blockN)"
                    ))
                }
            }
        }
    }

    pub fn label(&self) -> String {
        match self {
            Granularity::PerTensor => "tensor".into(),
            Granularity::PerChannel => "channel".into(),
            Granularity::Block(b) => format!("block{b}"),
        }
    }
}

/// A scale field attached to a 2-D weight: the `s0` of Algorithm 1, stored
/// at its natural granularity with O(1) per-element lookup.
#[derive(Clone, Debug)]
pub struct ScaleGrid {
    pub granularity: Granularity,
    /// Code format the scales were initialized for (sets `Qmax` and the
    /// qdq projection everywhere this grid flows).
    pub format: CodeFormat,
    /// Weight dims this grid was built for.
    pub rows: usize,
    pub cols: usize,
    /// Grid dims (1×1, 1×cols, or ⌈rows/b⌉×⌈cols/b⌉).
    pub grid_rows: usize,
    pub grid_cols: usize,
    pub scales: Vec<f32>,
}

impl ScaleGrid {
    /// Index into `scales` for element (r, c) — the single source of
    /// truth for the granularity dispatch ([`Self::at`] and the tiled
    /// sweep plan's per-element index array both use it).
    #[inline(always)]
    pub fn region_index(&self, r: usize, c: usize) -> usize {
        match self.granularity {
            Granularity::PerTensor => 0,
            Granularity::PerChannel => c,
            Granularity::Block(b) => (r / b) * self.grid_cols + (c / b),
        }
    }

    /// Per-element scale lookup.
    #[inline(always)]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.scales[self.region_index(r, c)]
    }

    /// Expand to a dense rows×cols field (the layout the PJRT sweep
    /// artifact takes, mirroring `ref.expand_block_scale`).
    pub fn expand(&self) -> Tensor {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[r * self.cols + c] = self.at(r, c);
            }
        }
        Tensor::new(vec![self.rows, self.cols], out)
    }

    /// Multiply every scale by `alpha` (Algorithm 1 line 8: s = α·s0).
    pub fn scaled(&self, alpha: f32) -> ScaleGrid {
        let mut g = self.clone();
        for s in &mut g.scales {
            *s *= alpha;
        }
        g
    }

    /// Rebuild a grid from checkpoint sidecar data: the granularity (from
    /// the `fmt.<name>` descriptor `write_checkpoint` stores, or the
    /// legacy `gran.<name>` metadata) plus the compact scales. Validates
    /// that the grid dims implied by the granularity match the sidecar
    /// length.
    pub fn from_sidecar(
        granularity: Granularity,
        rows: usize,
        cols: usize,
        scales: Vec<f32>,
    ) -> Result<ScaleGrid, String> {
        let (grid_rows, grid_cols) = match granularity {
            Granularity::PerTensor => (1, 1),
            Granularity::PerChannel => (1, cols),
            Granularity::Block(b) => (rows.div_ceil(b), cols.div_ceil(b)),
        };
        if scales.len() != grid_rows * grid_cols {
            return Err(format!(
                "scale sidecar has {} entries; {granularity:?} over \
                 {rows}x{cols} needs {}",
                scales.len(),
                grid_rows * grid_cols
            ));
        }
        Ok(ScaleGrid {
            granularity,
            format: CodeFormat::Fp8E4m3,
            rows,
            cols,
            grid_rows,
            grid_cols,
            scales,
        })
    }

    /// Rebind the grid to a code format (builder for loaders that learn
    /// the format from a `fmt.<name>` descriptor after
    /// [`Self::from_sidecar`]).
    pub fn with_format(mut self, format: CodeFormat) -> ScaleGrid {
        self.format = format;
        self
    }

    /// Multiply a decoded row by its scales in place — the scale-multiply
    /// stage of [`QuantizedTensor::dequant_row_into`]. The scalar
    /// dispatch mode keeps the legacy per-element [`Self::at`] loop (the
    /// bitwise and bench reference); SIMD modes walk the row's
    /// constant-scale runs instead, which is bitwise-equal because the
    /// multiply itself is elementwise either way.
    pub fn apply_row(&self, r: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.cols);
        if kernels::active() == kernels::SimdMode::Scalar {
            for (c, o) in out.iter_mut().enumerate() {
                *o *= self.at(r, c);
            }
            return;
        }
        match self.granularity {
            Granularity::PerTensor => kernels::scale_mul(out, self.scales[0]),
            Granularity::PerChannel => kernels::mul_slice(out, &self.scales[..out.len()]),
            Granularity::Block(b) => {
                let base = (r / b) * self.grid_cols;
                for (gc, chunk) in out.chunks_mut(b).enumerate() {
                    kernels::scale_mul(chunk, self.scales[base + gc]);
                }
            }
        }
    }
}

/// AbsMax scale initialization (Algorithm 1 line 3: s0 = max|W| / Qmax)
/// in the paper's FP8 E4M3 format. See [`absmax_scales_fmt`].
pub fn absmax_scales(w: &Tensor, granularity: Granularity) -> ScaleGrid {
    absmax_scales_fmt(w, granularity, CodeFormat::Fp8E4m3)
}

/// AbsMax scale initialization for any code format (Algorithm 1 line 3:
/// s0 = max|W| / Qmax, with `Qmax` = [`CodeFormat::qmax`]).
/// All-zero groups get scale 1 to avoid division by zero, and scales are
/// floored at `f32::MIN_POSITIVE` (smallest normal): the pipeline's
/// canonical projection multiplies by the reciprocal
/// ([`fp8::qdq_e4m3_scaled`] and its per-format twins), and a subnormal
/// scale would make `1/s` overflow to infinity (NaN stats, saturated
/// codes). Groups that small (max|W| ≲ 5e-36) carry no usable signal
/// either way.
pub fn absmax_scales_fmt(
    w: &Tensor,
    granularity: Granularity,
    format: CodeFormat,
) -> ScaleGrid {
    let (rows, cols) = (w.rows(), w.cols());
    let (grid_rows, grid_cols, mut scales) = match granularity {
        Granularity::PerTensor => (1, 1, vec![0.0f32; 1]),
        Granularity::PerChannel => (1, cols, vec![0.0f32; cols]),
        Granularity::Block(b) => {
            let gr = rows.div_ceil(b);
            let gc = cols.div_ceil(b);
            (gr, gc, vec![0.0f32; gr * gc])
        }
    };
    for r in 0..rows {
        for c in 0..cols {
            let v = w.at2(r, c).abs();
            let idx = match granularity {
                Granularity::PerTensor => 0,
                Granularity::PerChannel => c,
                Granularity::Block(b) => (r / b) * grid_cols + (c / b),
            };
            if v > scales[idx] {
                scales[idx] = v;
            }
        }
    }
    let qmax = format.qmax();
    for s in &mut scales {
        *s = if *s > 0.0 {
            (*s / qmax).max(f32::MIN_POSITIVE)
        } else {
            1.0
        };
    }
    ScaleGrid { granularity, format, rows, cols, grid_rows, grid_cols, scales }
}

/// A rank-k correction `U·Vᵀ` to a quantized tensor: the power-iteration
/// SVD of the quantization residual `W − Q(W)` with the singular values
/// folded into `u`. Stored as the `<name>.res_u`/`<name>.res_v` sidecar
/// pair and applied *after* the quantized decode (see
/// [`QuantizedTensor::dequant_row_into`]), so every consumer — full
/// dequantize, fused dequant-matmul, serving — inherits the correction
/// in the same accumulation order.
#[derive(Clone, Debug)]
pub struct LowRank {
    /// Rank (number of components).
    pub k: usize,
    /// Left factors, row-major `[rows, k]`, σ folded in.
    pub u: Vec<f32>,
    /// Right factors, row-major `[k, cols]`, unit-norm rows.
    pub v: Vec<f32>,
}

impl LowRank {
    /// Storage footprint in bytes (both factor sidecars).
    pub fn nbytes(&self) -> usize {
        (self.u.len() + self.v.len()) * 4
    }
}

/// Rank-k approximation of `m` by deterministic power iteration with
/// deflation: for each component, a fixed-seed start vector is iterated a
/// fixed number of times, σ is folded into `u`, and `σ·u·vᵀ` is deflated
/// from a working copy before the next component. Fully sequential f32
/// arithmetic — bitwise-deterministic for any worker count by
/// construction. Returns `None` for rank 0 or an empty matrix; `k` is
/// clamped to `min(rows, cols)`.
pub fn low_rank_approx(m: &Tensor, k: usize) -> Option<LowRank> {
    const ITERS: usize = 8;
    let (rows, cols) = (m.rows(), m.cols());
    let k = k.min(rows).min(cols);
    if k == 0 || rows == 0 || cols == 0 {
        return None;
    }
    let mut work: Vec<f32> = m.data().to_vec();
    let mut u_all = vec![0.0f32; rows * k];
    let mut v_all = vec![0.0f32; k * cols];
    let mut u = vec![0.0f32; rows];
    for t in 0..k {
        // fixed-seed start per component: deterministic, and distinct
        // seeds keep components from starting parallel
        let mut rng = crate::util::rng::XorShift::new(0xDA0_5EED ^ (t as u64 + 1));
        let mut v = rng.normal_vec(cols, 1.0);
        normalize(&mut v);
        let mut sigma = 0.0f32;
        for _ in 0..ITERS {
            // u = work · v
            for (i, ui) in u.iter_mut().enumerate() {
                let row = &work[i * cols..(i + 1) * cols];
                let mut acc = 0.0f32;
                for (wj, vj) in row.iter().zip(&v) {
                    acc += wj * vj;
                }
                *ui = acc;
            }
            if normalize(&mut u) == 0.0 {
                sigma = 0.0;
                break;
            }
            // v = workᵀ · u ; σ = ‖v‖
            v.fill(0.0);
            for (i, ui) in u.iter().enumerate() {
                let row = &work[i * cols..(i + 1) * cols];
                for (vj, wj) in v.iter_mut().zip(row) {
                    *vj += ui * wj;
                }
            }
            sigma = normalize(&mut v);
            if sigma == 0.0 {
                break;
            }
        }
        if sigma == 0.0 {
            // residual is (numerically) exhausted: leave the remaining
            // components zero — they contribute nothing
            break;
        }
        // fold σ into u, store, deflate
        for (i, ui) in u.iter().enumerate() {
            let su = sigma * ui;
            u_all[i * k + t] = su;
            let row = &mut work[i * cols..(i + 1) * cols];
            for (wj, vj) in row.iter_mut().zip(&v) {
                *wj -= su * vj;
            }
        }
        v_all[t * cols..(t + 1) * cols].copy_from_slice(&v);
    }
    Some(LowRank { k, u: u_all, v: v_all })
}

/// Normalize in place, returning the original 2-norm (0 leaves the
/// vector untouched).
fn normalize(v: &mut [f32]) -> f32 {
    let mut ss = 0.0f32;
    for x in v.iter() {
        ss += x * x;
    }
    let n = ss.sqrt();
    if n > 0.0 && n.is_finite() {
        let inv = 1.0 / n;
        for x in v.iter_mut() {
            *x *= inv;
        }
        n
    } else {
        0.0
    }
}

/// A quantized tensor: packed codes + final scales (storage format, the
/// `Ŵ, (s*)⁻¹` pair Algorithm 1 returns), in the code format the scales
/// carry, plus an optional low-rank residual correction.
#[derive(Clone, Debug)]
pub struct QuantizedTensor {
    /// Logical (unpacked) dims.
    pub shape: (usize, usize),
    /// Packed codes, `shape.0 ×` [`CodeFormat::packed_row_bytes`] bytes.
    pub codes: Vec<u8>,
    pub scales: ScaleGrid,
    /// Optional rank-k correction added after the scale multiply.
    pub residual: Option<LowRank>,
}

impl QuantizedTensor {
    /// Code format of the packed `codes` (lives on the scale grid so the
    /// sweep and the storage form can never disagree).
    #[inline(always)]
    pub fn format(&self) -> CodeFormat {
        self.scales.format
    }

    pub fn dequantize(&self) -> Tensor {
        let (rows, cols) = self.shape;
        let mut out = vec![0.0f32; rows * cols];
        for r in 0..rows {
            self.dequant_row_into(r, &mut out[r * cols..(r + 1) * cols]);
        }
        Tensor::new(vec![rows, cols], out)
    }

    /// Dequantize one row into a caller-provided buffer — the unit of the
    /// fused dequant-matmul: only `cols` f32 ever exist at once, not the
    /// whole matrix. Bitwise-identical to the corresponding
    /// [`Self::dequantize`] row (same LUT value, same scale multiply,
    /// same residual accumulation order), which is what keeps every
    /// kernel built on it bitwise-equal to dense over
    /// [`Self::dequantize`] at every format, with or without residual.
    #[inline]
    pub fn dequant_row_into(&self, r: usize, out: &mut [f32]) {
        let (_, cols) = self.shape;
        assert_eq!(out.len(), cols);
        let fmt = self.scales.format;
        let rb = fmt.packed_row_bytes(cols);
        fmt.decode_row_into(&self.codes[r * rb..(r + 1) * rb], out);
        self.scales.apply_row(r, out);
        if let Some(lr) = &self.residual {
            let urow = &lr.u[r * lr.k..(r + 1) * lr.k];
            for (t, &ut) in urow.iter().enumerate() {
                let vrow = &lr.v[t * cols..(t + 1) * cols];
                kernels::axpy(out, ut, vrow);
            }
        }
    }

    /// Attach a rank-k residual correction fitted against `target`:
    /// the power-iteration SVD of `target − dequantize()`. Replaces any
    /// existing residual (the fit is against the codes alone). No-op at
    /// rank 0.
    pub fn attach_residual(&mut self, target: &Tensor, k: usize) {
        self.residual = None;
        if k == 0 {
            return;
        }
        let deq = self.dequantize();
        let resid = Tensor::new(
            vec![self.shape.0, self.shape.1],
            target
                .data()
                .iter()
                .zip(deq.data())
                .map(|(t, d)| t - d)
                .collect(),
        );
        self.residual = low_rank_approx(&resid, k);
    }

    /// Storage footprint in bytes (packed codes + scales + residual
    /// factors).
    pub fn nbytes(&self) -> usize {
        self.codes.len()
            + self.scales.scales.len() * 4
            + self.residual.as_ref().map_or(0, |r| r.nbytes())
    }

    /// Compression ratio vs f32 storage.
    pub fn compression_ratio(&self) -> f64 {
        (self.shape.0 * self.shape.1 * 4) as f64 / self.nbytes() as f64
    }
}

/// Quantize `w` with scales `s0·alpha`, returning the storage form in the
/// format the grid carries.
///
/// Uses the canonical reciprocal-multiply projection (`encode(w·s⁻¹)`,
/// see [`fp8::qdq_e4m3_scaled`] and its per-format twins) so the stored
/// codes are bit-identical to what the fused sweep scored during the
/// scale search. INT4 codes pack two per byte with row-aligned strides
/// (see [`format`]).
pub fn quantize_with_scales(w: &Tensor, s0: &ScaleGrid, alpha: f32) -> QuantizedTensor {
    let (rows, cols) = (w.rows(), w.cols());
    let fmt = s0.format;
    let rb = fmt.packed_row_bytes(cols);
    let mut codes = vec![0u8; rows * rb];
    for r in 0..rows {
        let row = &mut codes[r * rb..(r + 1) * rb];
        for c in 0..cols {
            let s = s0.at(r, c) * alpha;
            let inv_s = fp8::recip_scale(s);
            let x = w.at2(r, c) * inv_s;
            match fmt {
                CodeFormat::Fp8E4m3 => row[c] = fp8::encode_e4m3(x),
                CodeFormat::Fp8E5m2 => row[c] = fp8::encode_e5m2(x),
                CodeFormat::Int4 { .. } => {
                    let nib = format::encode_int4(x);
                    if c % 2 == 0 {
                        row[c / 2] |= nib & 0x0F;
                    } else {
                        row[c / 2] |= nib << 4;
                    }
                }
            }
        }
    }
    QuantizedTensor {
        shape: (rows, cols),
        codes,
        scales: s0.scaled(alpha),
        residual: None,
    }
}

/// Convenience: AbsMax-initialize and quantize in one step (E4M3).
pub fn quantize(w: &Tensor, granularity: Granularity, alpha: f32) -> QuantizedTensor {
    let s0 = absmax_scales(w, granularity);
    quantize_with_scales(w, &s0, alpha)
}

/// Convenience: AbsMax-initialize and quantize in one step for any
/// format, optionally fitting a rank-`residual_rank` correction against
/// `w` afterwards.
pub fn quantize_fmt(
    w: &Tensor,
    granularity: Granularity,
    fmt: CodeFormat,
    alpha: f32,
    residual_rank: usize,
) -> QuantizedTensor {
    let s0 = absmax_scales_fmt(w, granularity, fmt);
    let mut q = quantize_with_scales(w, &s0, alpha);
    if residual_rank > 0 {
        q.attach_residual(w, residual_rank);
    }
    q
}

/// Quantize–dequantize without storing codes (the `Q_s(W)` used by metric
/// evaluation): out[i] = qdq(w[i] · s[i]⁻¹) · s[i] on the grid's format —
/// the same reciprocal-multiply form as the fused sweep, so pointwise
/// stats and sweep stats agree bit-for-bit.
pub fn qdq(w: &Tensor, s0: &ScaleGrid, alpha: f32) -> Tensor {
    let (rows, cols) = (w.rows(), w.cols());
    let fmt = s0.format;
    let mut out = vec![0.0f32; rows * cols];
    for r in 0..rows {
        for c in 0..cols {
            let s = s0.at(r, c) * alpha;
            let inv_s = fp8::recip_scale(s);
            out[r * cols + c] = fmt.qdq_scaled(w.at2(r, c), inv_s, s);
        }
    }
    Tensor::new(vec![rows, cols], out)
}

/// Fused dequant-matmul: `x[M,K] @ Q[K,N]` with `Q` staying in its E4M3
/// codes+scales storage form — rows of `Q` dequantize through the shared
/// LUT into one `N`-wide scratch buffer as the GEMM consumes them, so the
/// resident footprint is the codes plus a single row, never a full f32
/// copy of the weight.
///
/// Bitwise-identical to `ops::matmul(x, &q.dequantize())`: per output
/// element the contributions accumulate in the same ascending-k order,
/// the decoded row values are the exact `dequantize` values, and the
/// `aik == 0` skip matches the dense kernel's.
///
/// A thin allocating wrapper over [`matmul_quant_rows_into`] — the SIMD
/// kernel layer has exactly one fused accumulation body
/// ([`kernels::axpy`]) behind all three GEMM/GEMV entry points.
pub fn matmul_quant(x: &Tensor, q: &QuantizedTensor) -> Tensor {
    assert_eq!(x.ndim(), 2);
    let (m, k) = (x.rows(), x.cols());
    let (k2, n) = q.shape;
    assert_eq!(k, k2, "matmul_quant inner dims: {k} vs {k2}");
    let mut c = vec![0.0f32; m * n];
    let mut scratch = vec![0.0f32; n];
    matmul_quant_rows_into(x.data(), m, q, &mut c, &mut scratch);
    Tensor::new(vec![m, n], c)
}

/// Single-row fused dequant-matmul for the incremental decode path:
/// `out[N] = x[K] @ Q[K,N]`, with `row_scratch` (len `N`) reused across
/// calls so a decode step allocates nothing. Same accumulation order as
/// [`matmul_quant`] with one x-row.
pub fn matvec_quant_into(
    x: &[f32],
    q: &QuantizedTensor,
    out: &mut [f32],
    row_scratch: &mut [f32],
) {
    let (k, n) = q.shape;
    assert_eq!(x.len(), k, "matvec_quant x len {} vs rows {k}", x.len());
    assert_eq!(out.len(), n);
    assert_eq!(row_scratch.len(), n);
    out.fill(0.0);
    for (kk, &aik) in x.iter().enumerate() {
        if aik == 0.0 {
            continue;
        }
        q.dequant_row_into(kk, row_scratch);
        kernels::axpy(out, aik, row_scratch);
    }
}

/// Multi-row fused dequant-matmul over flat slices — the batched-prefill
/// counterpart of [`matvec_quant_into`]: `out[M,N] = x[M,K] @ Q[K,N]`
/// with `x` row-major in a caller-owned buffer. Runs k-outer like
/// [`matmul_quant`], so each weight row dequantizes through the LUT
/// *once* per call and is consumed by all `m` activation rows — this
/// amortization is why prefilling a whole prompt chunk in one forward
/// beats replaying it token-by-token.
///
/// Per output row the contributions accumulate in the same ascending-k
/// order (with the same `aik == 0` skip) as [`matvec_quant_into`], so the
/// result is bitwise-identical to `m` independent matvec calls.
pub fn matmul_quant_rows_into(
    x: &[f32],
    m: usize,
    q: &QuantizedTensor,
    out: &mut [f32],
    row_scratch: &mut [f32],
) {
    let (k, n) = q.shape;
    assert_eq!(x.len(), m * k, "matmul_quant_rows x len {} vs {m}x{k}", x.len());
    assert_eq!(out.len(), m * n);
    assert_eq!(row_scratch.len(), n);
    out.fill(0.0);
    for kk in 0..k {
        q.dequant_row_into(kk, row_scratch);
        for i in 0..m {
            let aik = x[i * k + kk];
            if aik == 0.0 {
                continue;
            }
            let orow = &mut out[i * n..(i + 1) * n];
            kernels::axpy(orow, aik, row_scratch);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift;

    fn rand_w(r: usize, c: usize, seed: u64) -> Tensor {
        let mut rng = XorShift::new(seed);
        Tensor::new(vec![r, c], rng.normal_vec(r * c, 0.1))
    }

    #[test]
    fn granularity_parse() {
        assert_eq!(Granularity::parse("block").unwrap(), Granularity::Block(128));
        assert_eq!(Granularity::parse("block64").unwrap(), Granularity::Block(64));
        assert_eq!(Granularity::parse("channel").unwrap(), Granularity::PerChannel);
        assert_eq!(Granularity::parse("tensor").unwrap(), Granularity::PerTensor);
        assert!(Granularity::parse("bogus").is_err());
    }

    #[test]
    fn absmax_per_tensor() {
        let w = Tensor::new(vec![2, 2], vec![1.0, -2.0, 0.5, 1.5]);
        let s = absmax_scales(&w, Granularity::PerTensor);
        assert_eq!(s.scales.len(), 1);
        assert!((s.at(0, 0) - 2.0 / 448.0).abs() < 1e-9);
    }

    #[test]
    fn absmax_per_channel() {
        let w = Tensor::new(vec![2, 3], vec![1., 2., 3., -4., 0.5, 1.]);
        let s = absmax_scales(&w, Granularity::PerChannel);
        assert_eq!(s.scales.len(), 3);
        assert!((s.at(0, 0) - 4.0 / 448.0).abs() < 1e-9);
        assert!((s.at(1, 1) - 2.0 / 448.0).abs() < 1e-9);
    }

    #[test]
    fn absmax_block_and_edges() {
        // 130x130 with block 128 -> 2x2 grid with ragged edges
        let mut w = Tensor::zeros(vec![130, 130]);
        w.set2(0, 0, 10.0);
        w.set2(129, 129, 20.0); // lives in block (1,1)
        let s = absmax_scales(&w, Granularity::Block(128));
        assert_eq!((s.grid_rows, s.grid_cols), (2, 2));
        assert!((s.at(0, 0) - 10.0 / 448.0).abs() < 1e-9);
        assert!((s.at(129, 129) - 20.0 / 448.0).abs() < 1e-9);
        // all-zero blocks get scale 1
        assert_eq!(s.at(0, 129), 1.0);
    }

    #[test]
    fn expand_matches_at() {
        let w = rand_w(64, 96, 1);
        let s = absmax_scales(&w, Granularity::Block(32));
        let full = s.expand();
        for r in (0..64).step_by(7) {
            for c in (0..96).step_by(11) {
                assert_eq!(full.at2(r, c), s.at(r, c));
            }
        }
    }

    #[test]
    fn quantize_dequantize_consistency() {
        // dequantize(quantize(w)) == qdq(w) elementwise
        let w = rand_w(64, 64, 2);
        let s0 = absmax_scales(&w, Granularity::Block(32));
        let q = quantize_with_scales(&w, &s0, 1.0);
        let deq = q.dequantize();
        let direct = qdq(&w, &s0, 1.0);
        for (a, b) in deq.data().iter().zip(direct.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn alpha_one_absmax_never_saturates_destructively() {
        // with s0 = absmax/448 every |w/s| <= 448, so qdq error is bounded
        // by the E4M3 half-ulp
        let w = rand_w(32, 32, 3);
        let s0 = absmax_scales(&w, Granularity::PerTensor);
        let q = qdq(&w, &s0, 1.0);
        for (x, y) in w.data().iter().zip(q.data()) {
            assert!((x - y).abs() <= x.abs() * 0.0625 + s0.at(0, 0) * 0.002,
                    "{x} vs {y}");
        }
    }

    #[test]
    fn compression_ratio() {
        let w = rand_w(128, 128, 4);
        let q = quantize(&w, Granularity::Block(128), 1.0);
        // 1 byte/elem + one f32 scale for the single block: ~4x
        assert!(q.compression_ratio() > 3.9 && q.compression_ratio() <= 4.0);
        let qc = quantize(&w, Granularity::PerChannel, 1.0);
        assert!(qc.compression_ratio() > 3.8);
    }

    #[test]
    fn tiny_weights_never_produce_nan() {
        // a tiny-but-nonzero group must not subnormalize the scale: the
        // reciprocal projection would turn 1/s infinite (0·inf = NaN)
        let w = Tensor::new(vec![2, 2], vec![1e-38, -1e-38, 5e-39, 0.0]);
        let s0 = absmax_scales(&w, Granularity::PerTensor);
        assert!(s0.at(0, 0) >= f32::MIN_POSITIVE);
        assert!((1.0 / s0.at(0, 0)).is_finite());
        let q = qdq(&w, &s0, 1.0);
        assert!(q.data().iter().all(|v| v.is_finite()), "{:?}", q.data());
        let st = crate::metrics::delta_stats(&w, &Tensor::zeros(vec![2, 2]), &q);
        assert!(st.sq.is_finite() && st.nq.is_finite());
        // even a small alpha that re-subnormalizes s·α must stay NaN-free
        // (the saturating recip_scale): zeros stay zero, stats finite
        let sw = crate::metrics::sweep_native(&w, &Tensor::zeros(vec![2, 2]), &s0, &[0.1, 1.0]);
        assert!(sw.iter().all(|s| s.sq.is_finite() && s.nq.is_finite()));
    }

    #[test]
    fn sidecar_roundtrip_rebuilds_grid() {
        let w = rand_w(70, 50, 6);
        for gran in [
            Granularity::PerTensor,
            Granularity::PerChannel,
            Granularity::Block(32), // ragged: 3x2 grid
        ] {
            let s = absmax_scales(&w, gran);
            let back =
                ScaleGrid::from_sidecar(gran, 70, 50, s.scales.clone()).unwrap();
            assert_eq!((back.grid_rows, back.grid_cols), (s.grid_rows, s.grid_cols));
            for r in (0..70).step_by(9) {
                for c in (0..50).step_by(7) {
                    assert_eq!(back.at(r, c), s.at(r, c), "{gran:?} ({r},{c})");
                }
            }
        }
        // wrong length rejected
        assert!(ScaleGrid::from_sidecar(Granularity::PerChannel, 4, 4, vec![1.0]).is_err());
    }

    #[test]
    fn fused_dequant_matmul_is_bitwise_dense() {
        use crate::tensor::ops::matmul;
        let mut rng = XorShift::new(21);
        for gran in [
            Granularity::PerTensor,
            Granularity::PerChannel,
            Granularity::Block(16),
        ] {
            let w = rand_w(24, 20, 8);
            let q = quantize(&w, gran, 1.0);
            // x includes exact zeros so the skip paths are exercised
            let mut xd = rng.normal_vec(6 * 24, 0.5);
            xd[3] = 0.0;
            xd[40] = 0.0;
            let x = Tensor::new(vec![6, 24], xd);
            let dense = matmul(&x, &q.dequantize());
            let fused = matmul_quant(&x, &q);
            assert_eq!(fused.shape(), dense.shape());
            for (a, b) in fused.data().iter().zip(dense.data()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{gran:?}");
            }
            // single-row form agrees with the fused GEMM's first row
            let mut out = vec![0.0f32; 20];
            let mut scratch = vec![0.0f32; 20];
            matvec_quant_into(x.row(0), &q, &mut out, &mut scratch);
            for (a, b) in out.iter().zip(fused.row(0)) {
                assert_eq!(a.to_bits(), b.to_bits(), "{gran:?}");
            }
        }
    }

    #[test]
    fn batched_rows_matmul_is_bitwise_matvec() {
        let mut rng = XorShift::new(31);
        for gran in [
            Granularity::PerTensor,
            Granularity::PerChannel,
            Granularity::Block(16),
        ] {
            let w = rand_w(24, 20, 11);
            let q = quantize(&w, gran, 1.0);
            let m = 5;
            let mut xd = rng.normal_vec(m * 24, 0.5);
            xd[7] = 0.0;
            xd[60] = 0.0;
            let mut batched = vec![0.0f32; m * 20];
            let mut scratch = vec![0.0f32; 20];
            matmul_quant_rows_into(&xd, m, &q, &mut batched, &mut scratch);
            // each output row bitwise-matches the single-row decode kernel
            let mut row = vec![0.0f32; 20];
            for i in 0..m {
                matvec_quant_into(&xd[i * 24..(i + 1) * 24], &q, &mut row, &mut scratch);
                for (a, b) in batched[i * 20..(i + 1) * 20].iter().zip(&row) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{gran:?} row {i}");
                }
            }
            // and the tensor-level fused GEMM
            let x = Tensor::new(vec![m, 24], xd);
            let fused = matmul_quant(&x, &q);
            for (a, b) in batched.iter().zip(fused.data()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{gran:?}");
            }
        }
    }

    #[test]
    fn dequant_row_matches_full_dequantize() {
        let w = rand_w(30, 14, 9);
        let q = quantize(&w, Granularity::Block(8), 1.0);
        let full = q.dequantize();
        let mut row = vec![0.0f32; 14];
        for r in 0..30 {
            q.dequant_row_into(r, &mut row);
            for (a, b) in row.iter().zip(full.row(r)) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn alpha_scales_the_grid() {
        let w = rand_w(16, 16, 5);
        let s0 = absmax_scales(&w, Granularity::PerTensor);
        let s2 = s0.scaled(2.0);
        assert!((s2.at(0, 0) - 2.0 * s0.at(0, 0)).abs() < 1e-12);
    }

    #[test]
    fn every_format_quantize_dequantize_matches_qdq() {
        // dequantize(quantize(w)) == qdq(w) bitwise at every format,
        // including odd column counts (packed INT4 tail nibbles)
        let w = rand_w(33, 29, 12);
        for fmt in [
            CodeFormat::Fp8E4m3,
            CodeFormat::Fp8E5m2,
            CodeFormat::Int4 { group: 16 },
        ] {
            let s0 = absmax_scales_fmt(&w, Granularity::Block(16), fmt);
            let q = quantize_with_scales(&w, &s0, 1.0);
            assert_eq!(q.format(), fmt);
            assert_eq!(q.codes.len(), fmt.packed_len(33, 29));
            let deq = q.dequantize();
            let direct = qdq(&w, &s0, 1.0);
            for (a, b) in deq.data().iter().zip(direct.data()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{fmt:?}");
            }
        }
    }

    #[test]
    fn int4_fused_matmul_is_bitwise_dense_odd_cols() {
        use crate::tensor::ops::matmul;
        let mut rng = XorShift::new(51);
        let w = rand_w(24, 19, 13); // odd cols: packed rows pad a nibble
        for rank in [0usize, 3] {
            let q = quantize_fmt(&w, Granularity::Block(8), CodeFormat::Int4 { group: 8 }, 1.0, rank);
            assert_eq!(q.residual.is_some(), rank > 0);
            let mut xd = rng.normal_vec(5 * 24, 0.5);
            xd[3] = 0.0;
            let x = Tensor::new(vec![5, 24], xd);
            let dense = matmul(&x, &q.dequantize());
            let fused = matmul_quant(&x, &q);
            for (a, b) in fused.data().iter().zip(dense.data()) {
                assert_eq!(a.to_bits(), b.to_bits(), "rank {rank}");
            }
            let mut out = vec![0.0f32; 19];
            let mut scratch = vec![0.0f32; 19];
            matvec_quant_into(x.row(0), &q, &mut out, &mut scratch);
            for (a, b) in out.iter().zip(fused.row(0)) {
                assert_eq!(a.to_bits(), b.to_bits(), "rank {rank}");
            }
            let mut batched = vec![0.0f32; 5 * 19];
            matmul_quant_rows_into(x.data(), 5, &q, &mut batched, &mut scratch);
            for (a, b) in batched.iter().zip(fused.data()) {
                assert_eq!(a.to_bits(), b.to_bits(), "rank {rank}");
            }
        }
    }

    #[test]
    fn int4_compression_ratio_and_nbytes() {
        let w = rand_w(128, 128, 14);
        let q = quantize_fmt(&w, Granularity::Block(64), CodeFormat::Int4 { group: 64 }, 1.0, 0);
        // 0.5 byte/elem + 4 block scales: ~8x
        assert_eq!(q.codes.len(), 128 * 64);
        assert!(q.compression_ratio() > 7.9 && q.compression_ratio() <= 8.0);
        // residual factors are counted in the footprint
        let qr = quantize_fmt(&w, Granularity::Block(64), CodeFormat::Int4 { group: 64 }, 1.0, 2);
        assert_eq!(qr.nbytes(), q.nbytes() + 2 * (128 + 128) * 4);
    }

    #[test]
    fn residual_reduces_error_and_is_deterministic() {
        let w = rand_w(40, 32, 15);
        let mut q = quantize_fmt(&w, Granularity::PerTensor, CodeFormat::Int4 { group: 64 }, 1.0, 0);
        let base_err: f32 = w
            .data()
            .iter()
            .zip(q.dequantize().data())
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        q.attach_residual(&w, 4);
        let lr = q.residual.clone().unwrap();
        assert_eq!(lr.k, 4);
        assert_eq!(lr.u.len(), 40 * 4);
        assert_eq!(lr.v.len(), 4 * 32);
        let corr_err: f32 = w
            .data()
            .iter()
            .zip(q.dequantize().data())
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        assert!(
            corr_err < base_err * 0.9,
            "rank-4 residual should cut error: {corr_err} vs {base_err}"
        );
        // re-fit is bitwise-deterministic
        let mut q2 = quantize_fmt(&w, Granularity::PerTensor, CodeFormat::Int4 { group: 64 }, 1.0, 0);
        q2.attach_residual(&w, 4);
        let lr2 = q2.residual.unwrap();
        for (a, b) in lr.u.iter().zip(&lr2.u) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in lr.v.iter().zip(&lr2.v) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn low_rank_edge_cases() {
        // rank clamped to min(rows, cols)
        let w = rand_w(3, 8, 16);
        let lr = low_rank_approx(&w, 10).unwrap();
        assert_eq!(lr.k, 3);
        // rank 0 and empty matrices yield no residual
        assert!(low_rank_approx(&w, 0).is_none());
        assert!(low_rank_approx(&Tensor::zeros(vec![0, 4]), 2).is_none());
        // an exactly rank-1 matrix is recovered (near machine precision)
        let u = [1.0f32, -2.0, 0.5];
        let v = [3.0f32, 0.25, -1.0, 2.0];
        let mut m = vec![0.0f32; 12];
        for (i, ui) in u.iter().enumerate() {
            for (j, vj) in v.iter().enumerate() {
                m[i * 4 + j] = ui * vj;
            }
        }
        let m = Tensor::new(vec![3, 4], m);
        let lr = low_rank_approx(&m, 1).unwrap();
        for i in 0..3 {
            for j in 0..4 {
                let approx = lr.u[i] * lr.v[j];
                assert!(
                    (approx - m.at2(i, j)).abs() < 1e-5,
                    "({i},{j}): {approx} vs {}",
                    m.at2(i, j)
                );
            }
        }
        // all-zero residual: factors stay zero, correction is a no-op
        let z = low_rank_approx(&Tensor::zeros(vec![4, 4]), 2).unwrap();
        assert!(z.u.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn proptest_qdq_idempotent() {
        use crate::util::proptest::{run, Config};
        run("qdq idempotent", Config { cases: 24, ..Config::default() }, |g| {
            let r = g.usize_range(1, 40);
            let c = g.usize_range(1, 40);
            let w = Tensor::new(vec![r, c], g.normal_vec(r * c, 0.5));
            let gran = *g.pick(&[
                Granularity::PerTensor,
                Granularity::PerChannel,
                Granularity::Block(16),
            ]);
            let s0 = absmax_scales(&w, gran);
            let q1 = qdq(&w, &s0, 1.0);
            let q2 = qdq(&q1, &s0, 1.0);
            for (a, b) in q1.data().iter().zip(q2.data()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        });
    }
}
