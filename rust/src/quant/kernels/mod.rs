//! Runtime-dispatched SIMD kernels for the pipeline's hot inner loops.
//!
//! Four loop families burn nearly all the cycles of quantize-and-serve,
//! and each has exactly one accumulation/decode body that lives here:
//!
//! 1. **Code decode** — FP8 byte decode and packed-INT4 nibble unpack
//!    into `QuantizedTensor::dequant_row_into`, plus the per-granularity
//!    scale multiply ([`scale_mul`], [`mul_slice`]) and the rank-K
//!    `res_u·res_v` residual add ([`axpy`]).
//! 2. **Fused GEMM/GEMV accumulation** — the single [`axpy`] body under
//!    `matmul_quant`, `matvec_quant_into` and `matmul_quant_rows_into`.
//! 3. **The sweep tile kernel** — [`eval_tile_simd`], the vectorized twin
//!    of `metrics::tile::eval_tile` (sign agreement in integer lanes,
//!    dot/norm accumulation in fixed-order f64 lane partials).
//! 4. **Bulk FP8 dequant** — [`decode_e4m3_into`] / [`decode_e5m2_into`]
//!    behind `fp8::decode_slice_into`, the dequantizing-loader path.
//!
//! The dispatch mode is decided once per process ([`active`]) from the
//! `DAQ_SIMD` environment variable plus runtime feature detection:
//! AVX2 or SSE4.1 on x86_64 (the SSE4.1 tier covers the decode and axpy
//! families and falls back to scalar for the sweep tile), NEON on
//! aarch64, scalar everywhere else. `DAQ_SIMD=off` (or `scalar`) forces
//! the always-compiled scalar reference; naming a specific ISA
//! (`avx2`/`sse4.1`/`neon`) selects it when the machine supports it and
//! falls back to scalar — never to a different ISA — when it does not.
//! The bench overrides the cached mode with [`force`] so it can price
//! SIMD against scalar inside one run.
//!
//! ## Determinism contract
//!
//! Families 1, 2 and 4 are **bitwise-equal** to the scalar reference:
//! every lane performs the same single-rounding f32 ops on the same
//! element (decode bit-twiddles are exact, the axpy uses separate
//! multiply and add — never FMA, which would round once where the scalar
//! reference rounds twice), and lanes map to independent elements, so
//! vector width never reorders a dependent reduction. Fused-GEMM logits
//! are therefore bit-identical in every dispatch mode.
//!
//! Family 3 keeps each per-element projection `q` bitwise-equal but sums
//! tile statistics in per-ISA fixed-order f64 lane partials (lane
//! partials merge low-to-high, then the scalar tail appends in element
//! order), so sweep objectives agree with scalar at ≤1e-9 relative
//! tolerance and remain bitwise-identical across worker counts and
//! across runs on a fixed ISA — the reduction order depends only on the
//! dispatched ISA, never on thread scheduling.
//!
//! See `docs/KERNELS.md` for the operational guide (forcing modes,
//! reading the bench's `simd` column, CI lanes).

#[cfg(target_arch = "aarch64")]
mod neon;
#[cfg(target_arch = "x86_64")]
mod x86;

use std::sync::atomic::{AtomicU8, Ordering};

use super::format::CodeFormat;
use crate::fp8;

/// The dispatch tiers, from portable to widest.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdMode {
    /// The always-compiled scalar reference paths.
    Scalar,
    /// x86_64 SSE4.1: decode + axpy families only (sweep tile stays
    /// scalar — 128-bit f64 lanes do not pay for the extra code).
    Sse41,
    /// x86_64 AVX2: all four families.
    Avx2,
    /// aarch64 NEON: all four families.
    Neon,
}

impl SimdMode {
    fn to_u8(self) -> u8 {
        match self {
            SimdMode::Scalar => 1,
            SimdMode::Sse41 => 2,
            SimdMode::Avx2 => 3,
            SimdMode::Neon => 4,
        }
    }

    fn from_u8(v: u8) -> SimdMode {
        match v {
            2 => SimdMode::Sse41,
            3 => SimdMode::Avx2,
            4 => SimdMode::Neon,
            _ => SimdMode::Scalar,
        }
    }
}

/// Cached dispatch decision; 0 = not yet initialized.
static MODE: AtomicU8 = AtomicU8::new(0);

#[cfg(target_arch = "x86_64")]
fn cpu_has_avx2() -> bool {
    std::arch::is_x86_feature_detected!("avx2")
}

#[cfg(not(target_arch = "x86_64"))]
fn cpu_has_avx2() -> bool {
    false
}

#[cfg(target_arch = "x86_64")]
fn cpu_has_sse41() -> bool {
    std::arch::is_x86_feature_detected!("sse4.1")
}

#[cfg(not(target_arch = "x86_64"))]
fn cpu_has_sse41() -> bool {
    false
}

/// Whether `mode`'s instructions can execute on this machine.
pub fn supported(mode: SimdMode) -> bool {
    match mode {
        SimdMode::Scalar => true,
        SimdMode::Sse41 => cpu_has_sse41(),
        SimdMode::Avx2 => cpu_has_avx2(),
        SimdMode::Neon => cfg!(target_arch = "aarch64"),
    }
}

/// Best mode the machine supports (the `DAQ_SIMD`-unset default).
fn detect() -> SimdMode {
    if supported(SimdMode::Avx2) {
        SimdMode::Avx2
    } else if supported(SimdMode::Neon) {
        SimdMode::Neon
    } else if supported(SimdMode::Sse41) {
        SimdMode::Sse41
    } else {
        SimdMode::Scalar
    }
}

/// Resolve a `DAQ_SIMD` value: `off`/`scalar`/`0` force scalar; a named
/// ISA selects it if supported (scalar otherwise — never a silent
/// upgrade to a different ISA); anything else (including `auto`)
/// auto-detects. Pure given the machine, so the grammar is testable
/// without touching the process environment.
pub fn parse_mode(requested: &str) -> SimdMode {
    match requested.to_ascii_lowercase().as_str() {
        "off" | "scalar" | "0" => SimdMode::Scalar,
        "sse4.1" | "sse41" => {
            if supported(SimdMode::Sse41) {
                SimdMode::Sse41
            } else {
                SimdMode::Scalar
            }
        }
        "avx2" => {
            if supported(SimdMode::Avx2) {
                SimdMode::Avx2
            } else {
                SimdMode::Scalar
            }
        }
        "neon" => {
            if supported(SimdMode::Neon) {
                SimdMode::Neon
            } else {
                SimdMode::Scalar
            }
        }
        _ => detect(),
    }
}

/// Resolve the `DAQ_SIMD` environment variable via [`parse_mode`],
/// auto-detecting when unset.
fn init_mode() -> SimdMode {
    match std::env::var("DAQ_SIMD") {
        Ok(v) => parse_mode(&v),
        Err(_) => detect(),
    }
}

/// The mode every kernel in this module dispatches on. Resolved once
/// from `DAQ_SIMD` + feature detection, then cached for the process
/// (unless overridden by [`force`]).
#[inline]
pub fn active() -> SimdMode {
    let m = MODE.load(Ordering::Relaxed);
    if m != 0 {
        return SimdMode::from_u8(m);
    }
    let mode = init_mode();
    MODE.store(mode.to_u8(), Ordering::Relaxed);
    mode
}

/// Override the cached dispatch mode, returning the previous one — the
/// bench's hook for emitting forced-scalar companion rows in the same
/// run. Unsupported modes clamp to scalar, so a forced mode can never
/// make a kernel execute instructions the machine lacks.
pub fn force(mode: SimdMode) -> SimdMode {
    let prev = active();
    let next = if supported(mode) { mode } else { SimdMode::Scalar };
    MODE.store(next.to_u8(), Ordering::Relaxed);
    prev
}

/// Stable label for a mode (`BENCH_sweep.json`'s `simd` column values).
pub fn mode_label(mode: SimdMode) -> &'static str {
    match mode {
        SimdMode::Scalar => "scalar",
        SimdMode::Sse41 => "sse4.1",
        SimdMode::Avx2 => "avx2",
        SimdMode::Neon => "neon",
    }
}

/// Label of the currently [`active`] mode.
pub fn label() -> &'static str {
    mode_label(active())
}

/// Format tag the per-ISA tile kernels switch their vector qdq on.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
#[derive(Clone, Copy)]
pub(crate) enum KernelFormat {
    E4m3,
    E5m2,
    Int4,
}

#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
impl KernelFormat {
    fn of(fmt: CodeFormat) -> KernelFormat {
        match fmt {
            CodeFormat::Fp8E4m3 => KernelFormat::E4m3,
            CodeFormat::Fp8E5m2 => KernelFormat::E5m2,
            CodeFormat::Int4 { .. } => KernelFormat::Int4,
        }
    }
}

/// Bulk-decode E4M3 codes (family 4). Bitwise-equal to the scalar LUT
/// walk in every mode: the vector path rebuilds each value exactly from
/// the code bits (exponent rebias by 2¹²⁰ is a lossless power-of-two
/// multiply, NaN codes blend in the same `f32::NAN` the LUT holds).
#[inline]
pub fn decode_e4m3_into(codes: &[u8], out: &mut [f32]) {
    assert_eq!(codes.len(), out.len());
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: active() returns Avx2/Sse41 only when detection passed.
        SimdMode::Avx2 => unsafe { x86::decode_e4m3_avx2(codes, out) },
        #[cfg(target_arch = "x86_64")]
        SimdMode::Sse41 => unsafe { x86::decode_e4m3_sse41(codes, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64 targets.
        SimdMode::Neon => unsafe { neon::decode_e4m3_neon(codes, out) },
        _ => fp8::decode_slice_into_scalar(codes, out),
    }
}

/// Bulk-decode E5M2 codes — the E5M2 twin of [`decode_e4m3_into`]
/// (rebias 2¹¹², and every exponent-31 code decodes to NaN, matching
/// `fp8::decode_e5m2`'s no-infinity convention).
#[inline]
pub fn decode_e5m2_into(codes: &[u8], out: &mut [f32]) {
    assert_eq!(codes.len(), out.len());
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: active() returns Avx2/Sse41 only when detection passed.
        SimdMode::Avx2 => unsafe { x86::decode_e5m2_avx2(codes, out) },
        #[cfg(target_arch = "x86_64")]
        SimdMode::Sse41 => unsafe { x86::decode_e5m2_sse41(codes, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64 targets.
        SimdMode::Neon => unsafe { neon::decode_e5m2_neon(codes, out) },
        _ => fp8::decode_slice_into_e5m2_scalar(codes, out),
    }
}

/// Unpack + decode a packed-INT4 row (two codes per byte, low nibble
/// first; `out.len()` is the logical width, odd widths leave a pad
/// nibble unread). Bitwise-equal to the 16-entry LUT walk: nibble → f32
/// conversion and the bias subtraction are exact on small integers.
#[inline]
pub fn decode_int4_into(packed: &[u8], out: &mut [f32]) {
    assert_eq!(packed.len(), out.len().div_ceil(2), "packed row len");
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: active() returns Avx2/Sse41 only when detection passed.
        SimdMode::Avx2 => unsafe { x86::decode_int4_avx2(packed, out) },
        #[cfg(target_arch = "x86_64")]
        SimdMode::Sse41 => unsafe { x86::decode_int4_sse41(packed, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64 targets.
        SimdMode::Neon => unsafe { neon::decode_int4_neon(packed, out) },
        _ => super::format::decode_int4_slice_into_scalar(packed, out),
    }
}

/// `out[j] += a · x[j]` — the one fused-GEMM accumulation body (family
/// 2) and the residual add of family 1. Lanes map to independent output
/// columns and use separate multiply + add (no FMA), so every dispatch
/// mode is bitwise-equal to the scalar loop and the caller's ascending-k
/// accumulation order per output element is preserved by construction.
#[inline]
pub fn axpy(out: &mut [f32], a: f32, x: &[f32]) {
    assert_eq!(out.len(), x.len());
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: active() returns Avx2/Sse41 only when detection passed.
        SimdMode::Avx2 => unsafe { x86::axpy_avx2(out, a, x) },
        #[cfg(target_arch = "x86_64")]
        SimdMode::Sse41 => unsafe { x86::axpy_sse41(out, a, x) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64 targets.
        SimdMode::Neon => unsafe { neon::axpy_neon(out, a, x) },
        _ => {
            for (o, xv) in out.iter_mut().zip(x) {
                *o += a * xv;
            }
        }
    }
}

/// `out[j] *= s` — the per-block/per-tensor scale multiply of the
/// dequant row path. Elementwise, so bitwise-equal in every mode.
#[inline]
pub fn scale_mul(out: &mut [f32], s: f32) {
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: active() returns Avx2/Sse41 only when detection passed.
        SimdMode::Avx2 => unsafe { x86::scale_mul_avx2(out, s) },
        #[cfg(target_arch = "x86_64")]
        SimdMode::Sse41 => unsafe { x86::scale_mul_sse41(out, s) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64 targets.
        SimdMode::Neon => unsafe { neon::scale_mul_neon(out, s) },
        _ => {
            for o in out.iter_mut() {
                *o *= s;
            }
        }
    }
}

/// `out[j] *= s[j]` — the per-channel scale multiply of the dequant row
/// path. Elementwise, so bitwise-equal in every mode.
#[inline]
pub fn mul_slice(out: &mut [f32], s: &[f32]) {
    assert_eq!(out.len(), s.len());
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: active() returns Avx2/Sse41 only when detection passed.
        SimdMode::Avx2 => unsafe { x86::mul_slice_avx2(out, s) },
        #[cfg(target_arch = "x86_64")]
        SimdMode::Sse41 => unsafe { x86::mul_slice_sse41(out, s) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64 targets.
        SimdMode::Neon => unsafe { neon::mul_slice_neon(out, s) },
        _ => {
            for (o, sv) in out.iter_mut().zip(s) {
                *o *= sv;
            }
        }
    }
}

/// Per-candidate partial statistics of one tile, as produced by the
/// SIMD tile kernels (the shape `metrics::tile::TileStats` is built
/// from).
pub struct TilePartials {
    /// Per-candidate sign-agreement counts.
    pub agree: Vec<u64>,
    /// Per-candidate Σ dq·Δp.
    pub dot: Vec<f64>,
    /// Per-candidate Σ dq².
    pub nq: Vec<f64>,
    /// Per-candidate Σ err².
    pub sq: Vec<f64>,
}

/// Vectorized sweep tile evaluation (family 3), or `None` when the
/// active mode has no tile kernel (scalar and SSE4.1 — callers fall
/// back to `metrics::tile::eval_tile`).
///
/// Every per-element projection `q = qdq(p·s⁻¹)·s` is bitwise-equal to
/// the scalar kernel's (the vector qdq clamps, extracts the exponent
/// and rounds with the exact same single-rounding semantics); only the
/// f64 accumulation order differs — fixed low-to-high lane partials
/// plus an element-order scalar tail, a function of the ISA alone. See
/// the module docs for the resulting determinism contract.
///
/// `s_tab`/`inv_tab` are `[candidate][region]` tables with `n_regions`
/// columns; every `scale_idx` entry must be `< n_regions`.
#[allow(clippy::too_many_arguments)]
pub fn eval_tile_simd(
    format: CodeFormat,
    p: &[f32],
    b: &[f32],
    dp: &[f32],
    sp: &[i8],
    scale_idx: &[u32],
    s_tab: &[f32],
    inv_tab: &[f32],
    n_regions: usize,
    n_candidates: usize,
) -> Option<TilePartials> {
    let len = p.len();
    assert_eq!(b.len(), len);
    assert_eq!(dp.len(), len);
    assert_eq!(sp.len(), len);
    assert_eq!(scale_idx.len(), len);
    assert_eq!(s_tab.len(), n_regions * n_candidates);
    assert_eq!(inv_tab.len(), n_regions * n_candidates);
    debug_assert!(scale_idx.iter().all(|&i| (i as usize) < n_regions));
    match active() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: active() returns Avx2 only when detection passed; the
        // slice lengths were just checked and the gather indexes are the
        // caller-validated scale_idx entries.
        SimdMode::Avx2 => Some(unsafe {
            x86::eval_tile_avx2(
                KernelFormat::of(format),
                p,
                b,
                dp,
                sp,
                scale_idx,
                s_tab,
                inv_tab,
                n_regions,
                n_candidates,
            )
        }),
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64; lengths checked above.
        SimdMode::Neon => Some(unsafe {
            neon::eval_tile_neon(
                KernelFormat::of(format),
                p,
                b,
                dp,
                sp,
                scale_idx,
                s_tab,
                inv_tab,
                n_regions,
                n_candidates,
            )
        }),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_roundtrip_and_labels() {
        for m in [SimdMode::Scalar, SimdMode::Sse41, SimdMode::Avx2, SimdMode::Neon] {
            assert_eq!(SimdMode::from_u8(m.to_u8()), m);
            assert!(!mode_label(m).is_empty());
        }
        assert_eq!(SimdMode::from_u8(0), SimdMode::Scalar);
        assert!(supported(SimdMode::Scalar));
        // whatever is active must be supported and labeled
        assert!(supported(active()));
        assert_eq!(label(), mode_label(active()));
    }

    // The dispatch-level SIMD-vs-scalar equality suite lives in
    // tests/simd.rs (it forces modes process-globally, which unit tests
    // running in parallel threads must not). The tests below call the
    // per-ISA bodies directly, so they are safe at any dispatch mode.

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn x86_decode_kernels_match_luts_on_all_codes() {
        let codes: Vec<u8> = (0..=255).collect();
        for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 64, 255, 256] {
            let mut want = vec![0.0f32; n];
            let mut got = vec![0.0f32; n];
            fp8::decode_slice_into_scalar(&codes[..n], &mut want);
            if supported(SimdMode::Avx2) {
                got.fill(-1.0);
                unsafe { x86::decode_e4m3_avx2(&codes[..n], &mut got) };
                assert_bits(&got, &want, "avx2 e4m3");
            }
            if supported(SimdMode::Sse41) {
                got.fill(-1.0);
                unsafe { x86::decode_e4m3_sse41(&codes[..n], &mut got) };
                assert_bits(&got, &want, "sse4.1 e4m3");
            }
            fp8::decode_slice_into_e5m2_scalar(&codes[..n], &mut want);
            if supported(SimdMode::Avx2) {
                got.fill(-1.0);
                unsafe { x86::decode_e5m2_avx2(&codes[..n], &mut got) };
                assert_bits(&got, &want, "avx2 e5m2");
            }
            if supported(SimdMode::Sse41) {
                got.fill(-1.0);
                unsafe { x86::decode_e5m2_sse41(&codes[..n], &mut got) };
                assert_bits(&got, &want, "sse4.1 e5m2");
            }
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn x86_int4_kernels_match_lut_at_odd_widths() {
        let mut rng = crate::util::rng::XorShift::new(41);
        for n in [1usize, 2, 7, 15, 16, 17, 31, 32, 33, 129] {
            let nibbles: Vec<u8> = (0..n).map(|_| rng.below(16) as u8).collect();
            let packed = super::super::format::pack_int4(&nibbles);
            let mut want = vec![0.0f32; n];
            super::super::format::decode_int4_slice_into_scalar(&packed, &mut want);
            let mut got = vec![0.0f32; n];
            if supported(SimdMode::Avx2) {
                got.fill(-1.0);
                unsafe { x86::decode_int4_avx2(&packed, &mut got) };
                assert_bits(&got, &want, "avx2 int4");
            }
            if supported(SimdMode::Sse41) {
                got.fill(-1.0);
                unsafe { x86::decode_int4_sse41(&packed, &mut got) };
                assert_bits(&got, &want, "sse4.1 int4");
            }
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn x86_axpy_and_scale_kernels_are_bitwise_scalar() {
        let mut rng = crate::util::rng::XorShift::new(43);
        for n in [0usize, 1, 3, 4, 7, 8, 9, 16, 23, 64, 101] {
            let x = rng.normal_vec(n, 1.0);
            let s = rng.normal_vec(n, 1.0);
            let base = rng.normal_vec(n, 1.0);
            let a = rng.normal() * 0.7;
            let mut want = base.clone();
            for (o, xv) in want.iter_mut().zip(&x) {
                *o += a * xv;
            }
            if supported(SimdMode::Avx2) {
                let mut got = base.clone();
                unsafe { x86::axpy_avx2(&mut got, a, &x) };
                assert_bits(&got, &want, "avx2 axpy");
            }
            if supported(SimdMode::Sse41) {
                let mut got = base.clone();
                unsafe { x86::axpy_sse41(&mut got, a, &x) };
                assert_bits(&got, &want, "sse4.1 axpy");
            }
            let mut want_s = base.clone();
            for o in want_s.iter_mut() {
                *o *= a;
            }
            let mut want_m = base.clone();
            for (o, sv) in want_m.iter_mut().zip(&s) {
                *o *= sv;
            }
            if supported(SimdMode::Avx2) {
                let mut got = base.clone();
                unsafe { x86::scale_mul_avx2(&mut got, a) };
                assert_bits(&got, &want_s, "avx2 scale_mul");
                let mut got = base.clone();
                unsafe { x86::mul_slice_avx2(&mut got, &s) };
                assert_bits(&got, &want_m, "avx2 mul_slice");
            }
            if supported(SimdMode::Sse41) {
                let mut got = base.clone();
                unsafe { x86::scale_mul_sse41(&mut got, a) };
                assert_bits(&got, &want_s, "sse4.1 scale_mul");
                let mut got = base.clone();
                unsafe { x86::mul_slice_sse41(&mut got, &s) };
                assert_bits(&got, &want_m, "sse4.1 mul_slice");
            }
        }
    }

    #[cfg(target_arch = "aarch64")]
    #[test]
    fn neon_kernels_match_scalar_references() {
        let codes: Vec<u8> = (0..=255).collect();
        for n in [0usize, 1, 3, 7, 8, 9, 64, 256] {
            let mut want = vec![0.0f32; n];
            let mut got = vec![0.0f32; n];
            fp8::decode_slice_into_scalar(&codes[..n], &mut want);
            got.fill(-1.0);
            unsafe { neon::decode_e4m3_neon(&codes[..n], &mut got) };
            assert_bits(&got, &want, "neon e4m3");
            fp8::decode_slice_into_e5m2_scalar(&codes[..n], &mut want);
            got.fill(-1.0);
            unsafe { neon::decode_e5m2_neon(&codes[..n], &mut got) };
            assert_bits(&got, &want, "neon e5m2");
        }
        let mut rng = crate::util::rng::XorShift::new(47);
        for n in [1usize, 7, 16, 17, 33] {
            let nibbles: Vec<u8> = (0..n).map(|_| rng.below(16) as u8).collect();
            let packed = super::super::format::pack_int4(&nibbles);
            let mut want = vec![0.0f32; n];
            super::super::format::decode_int4_slice_into_scalar(&packed, &mut want);
            let mut got = vec![-1.0f32; n];
            unsafe { neon::decode_int4_neon(&packed, &mut got) };
            assert_bits(&got, &want, "neon int4");
            let x = rng.normal_vec(n, 1.0);
            let base = rng.normal_vec(n, 1.0);
            let a = rng.normal();
            let mut want = base.clone();
            for (o, xv) in want.iter_mut().zip(&x) {
                *o += a * xv;
            }
            let mut gota = base.clone();
            unsafe { neon::axpy_neon(&mut gota, a, &x) };
            assert_bits(&gota, &want, "neon axpy");
        }
    }

    #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
    fn assert_bits(got: &[f32], want: &[f32], what: &str) {
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "{what} [{i}]: {g} vs {w}");
        }
    }
}
