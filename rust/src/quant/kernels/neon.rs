//! aarch64 NEON kernel bodies — the 128-bit mirror of `x86.rs`, covering
//! all four loop families.
//!
//! The same bitwise rules apply: decode rebuilds FP8 values exactly from
//! their bits, axpy uses separate `vmulq`/`vaddq` (never `vmlaq`, which
//! compiles to fused `fmla` on aarch64 and would round once where the
//! scalar reference rounds twice), and the tile kernel's vector qdq
//! matches the scalar `fp8::qdq_*` per element (`vrndnq` is
//! round-to-nearest-even; `vminq`/`vmaxq` propagate NaN in any operand
//! order). Tile reductions use two f64 lane partials per statistic,
//! merged low-to-high — NEON's fixed reduction order.

use std::arch::aarch64::*;

use super::{KernelFormat, TilePartials};

#[inline]
fn exp2f(e: i32) -> f32 {
    f32::from_bits(((e + 127) as u32) << 23)
}

#[target_feature(enable = "neon")]
pub(super) unsafe fn axpy_neon(out: &mut [f32], a: f32, x: &[f32]) {
    let n = out.len();
    let av = vdupq_n_f32(a);
    let mut i = 0;
    while i + 4 <= n {
        let xv = vld1q_f32(x.as_ptr().add(i));
        let ov = vld1q_f32(out.as_ptr().add(i));
        vst1q_f32(out.as_mut_ptr().add(i), vaddq_f32(ov, vmulq_f32(av, xv)));
        i += 4;
    }
    while i < n {
        out[i] += a * x[i];
        i += 1;
    }
}

#[target_feature(enable = "neon")]
pub(super) unsafe fn scale_mul_neon(out: &mut [f32], s: f32) {
    let n = out.len();
    let sv = vdupq_n_f32(s);
    let mut i = 0;
    while i + 4 <= n {
        let ov = vld1q_f32(out.as_ptr().add(i));
        vst1q_f32(out.as_mut_ptr().add(i), vmulq_f32(ov, sv));
        i += 4;
    }
    while i < n {
        out[i] *= s;
        i += 1;
    }
}

#[target_feature(enable = "neon")]
pub(super) unsafe fn mul_slice_neon(out: &mut [f32], s: &[f32]) {
    let n = out.len();
    let mut i = 0;
    while i + 4 <= n {
        let ov = vld1q_f32(out.as_ptr().add(i));
        let sv = vld1q_f32(s.as_ptr().add(i));
        vst1q_f32(out.as_mut_ptr().add(i), vmulq_f32(ov, sv));
        i += 4;
    }
    while i < n {
        out[i] *= s[i];
        i += 1;
    }
}

/// Shared FP8 byte-decode body; see `x86::decode_fp8_avx2` for the
/// `SHIFT`/`rebias`/`nan_mask` contract. Returns the vector-covered
/// prefix length.
#[target_feature(enable = "neon")]
unsafe fn decode_fp8_neon<const SHIFT: i32>(
    codes: &[u8],
    out: &mut [f32],
    rebias: f32,
    nan_mask: u32,
) -> usize {
    let n = codes.len();
    let rb = vdupq_n_f32(rebias);
    let nanv = vdupq_n_f32(f32::NAN);
    let payload_mask = vdupq_n_u32(0x7F);
    let sign_mask = vdupq_n_u32(0x80);
    let nm = vdupq_n_u32(nan_mask);
    let mut i = 0;
    while i + 4 <= n {
        let b32 = (codes.as_ptr().add(i) as *const u32).read_unaligned();
        let bytes = vreinterpret_u8_u32(vdup_n_u32(b32));
        let v = vmovl_u16(vget_low_u16(vmovl_u8(bytes)));
        let payload = vandq_u32(v, payload_mask);
        let sign = vshlq_n_u32::<24>(vandq_u32(v, sign_mask));
        let bits = vorrq_u32(vshlq_n_u32::<SHIFT>(payload), sign);
        let val = vmulq_f32(vreinterpretq_f32_u32(bits), rb);
        let isnan = vceqq_u32(vandq_u32(payload, nm), nm);
        vst1q_f32(out.as_mut_ptr().add(i), vbslq_f32(isnan, nanv, val));
        i += 4;
    }
    i
}

#[target_feature(enable = "neon")]
pub(super) unsafe fn decode_e4m3_neon(codes: &[u8], out: &mut [f32]) {
    let main = decode_fp8_neon::<20>(codes, out, exp2f(120), 0x7F);
    crate::fp8::decode_slice_into_scalar(&codes[main..], &mut out[main..]);
}

#[target_feature(enable = "neon")]
pub(super) unsafe fn decode_e5m2_neon(codes: &[u8], out: &mut [f32]) {
    let main = decode_fp8_neon::<21>(codes, out, exp2f(112), 0x7C);
    crate::fp8::decode_slice_into_e5m2_scalar(&codes[main..], &mut out[main..]);
}

#[target_feature(enable = "neon")]
pub(super) unsafe fn decode_int4_neon(packed: &[u8], out: &mut [f32]) {
    let n = out.len();
    let main = n - n % 16;
    let nibble = vdup_n_u8(0x0F);
    let eight = vdupq_n_f32(8.0);
    let mut i = 0;
    // 16 outputs per step from 8 packed bytes; `i` stays even, so the
    // byte cursor `i / 2` never straddles a code pair.
    while i < main {
        let v8 = vld1_u8(packed.as_ptr().add(i / 2));
        let lo = vand_u8(v8, nibble);
        let hi = vshr_n_u8::<4>(v8);
        let z = vzip_u8(lo, hi);
        let w0 = vmovl_u8(z.0);
        let w1 = vmovl_u8(z.1);
        let c0 = vmovl_u16(vget_low_u16(w0));
        let c1 = vmovl_u16(vget_high_u16(w0));
        let c2 = vmovl_u16(vget_low_u16(w1));
        let c3 = vmovl_u16(vget_high_u16(w1));
        vst1q_f32(out.as_mut_ptr().add(i), vsubq_f32(vcvtq_f32_u32(c0), eight));
        vst1q_f32(out.as_mut_ptr().add(i + 4), vsubq_f32(vcvtq_f32_u32(c1), eight));
        vst1q_f32(out.as_mut_ptr().add(i + 8), vsubq_f32(vcvtq_f32_u32(c2), eight));
        vst1q_f32(out.as_mut_ptr().add(i + 12), vsubq_f32(vcvtq_f32_u32(c3), eight));
        i += 16;
    }
    crate::quant::format::decode_int4_slice_into_scalar(&packed[main / 2..], &mut out[main..]);
}

/// Vector FP8 quantize–dequantize; same per-lane contract as
/// `x86::qdq8_avx2` (bitwise-equal to the scalar `fp8::qdq_*`).
#[target_feature(enable = "neon")]
unsafe fn qdq4_fp8_neon(
    x: float32x4_t,
    max: f32,
    e_min: i32,
    step_bias: i32,
    inv_bias: i32,
) -> float32x4_t {
    let a = vminq_f32(vdupq_n_f32(max), vmaxq_f32(vdupq_n_f32(-max), x));
    let magbits = vandq_u32(vreinterpretq_u32_f32(a), vdupq_n_u32(0x7FFF_FFFF));
    let is_zero = vceqq_u32(magbits, vdupq_n_u32(0));
    let exp_field = vreinterpretq_s32_u32(vshrq_n_u32::<23>(magbits));
    let e = vmaxq_s32(vsubq_s32(exp_field, vdupq_n_s32(127)), vdupq_n_s32(e_min));
    let step = vreinterpretq_f32_s32(vshlq_n_s32::<23>(vaddq_s32(e, vdupq_n_s32(step_bias))));
    let inv = vreinterpretq_f32_s32(vshlq_n_s32::<23>(vsubq_s32(vdupq_n_s32(inv_bias), e)));
    let g = vrndnq_f32(vmulq_f32(a, inv));
    vbslq_f32(is_zero, vdupq_n_f32(0.0), vmulq_f32(g, step))
}

/// Vector INT4 quantize–dequantize (clamp ±7 then round-to-nearest-even).
#[target_feature(enable = "neon")]
unsafe fn qdq4_int4_neon(x: float32x4_t) -> float32x4_t {
    let a = vminq_f32(vdupq_n_f32(7.0), vmaxq_f32(vdupq_n_f32(-7.0), x));
    vrndnq_f32(a)
}

/// Fixed-order horizontal sum of four f64 lane partials: `a` lanes 0→1,
/// then `b` lanes 0→1. Part of the per-ISA reduction-order contract.
#[target_feature(enable = "neon")]
unsafe fn hsum4_f64(a: float64x2_t, b: float64x2_t) -> f64 {
    let mut acc = vgetq_lane_f64::<0>(a);
    acc += vgetq_lane_f64::<1>(a);
    acc += vgetq_lane_f64::<0>(b);
    acc += vgetq_lane_f64::<1>(b);
    acc
}

/// NEON sweep tile kernel (family 3) — the 4-wide mirror of
/// `x86::eval_tile_avx2`: per-element `q` bitwise-equal to scalar,
/// branchless {-1, 0, +1} sign lanes, agreement counts widened into u64
/// lanes, f64 stats in two lane-partial registers each merged in fixed
/// order before the element-order scalar tail.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "neon")]
pub(super) unsafe fn eval_tile_neon(
    fmt: KernelFormat,
    p: &[f32],
    b: &[f32],
    dp: &[f32],
    sp: &[i8],
    scale_idx: &[u32],
    s_tab: &[f32],
    inv_tab: &[f32],
    n_regions: usize,
    n_candidates: usize,
) -> TilePartials {
    let len = p.len();
    let main = len - len % 4;
    let zero = vdupq_n_f32(0.0);
    let mut agree = Vec::with_capacity(n_candidates);
    let mut dot = Vec::with_capacity(n_candidates);
    let mut nq = Vec::with_capacity(n_candidates);
    let mut sq = Vec::with_capacity(n_candidates);
    for k in 0..n_candidates {
        let s_row = &s_tab[k * n_regions..(k + 1) * n_regions];
        let inv_row = &inv_tab[k * n_regions..(k + 1) * n_regions];
        let mut agree_acc = vdupq_n_u64(0);
        let mut dot_a = vdupq_n_f64(0.0);
        let mut dot_b = vdupq_n_f64(0.0);
        let mut nq_a = vdupq_n_f64(0.0);
        let mut nq_b = vdupq_n_f64(0.0);
        let mut sq_a = vdupq_n_f64(0.0);
        let mut sq_b = vdupq_n_f64(0.0);
        let mut i = 0;
        while i + 4 <= len {
            let i0 = scale_idx[i] as usize;
            let i1 = scale_idx[i + 1] as usize;
            let i2 = scale_idx[i + 2] as usize;
            let i3 = scale_idx[i + 3] as usize;
            let s_arr = [s_row[i0], s_row[i1], s_row[i2], s_row[i3]];
            let inv_arr = [inv_row[i0], inv_row[i1], inv_row[i2], inv_row[i3]];
            let sv = vld1q_f32(s_arr.as_ptr());
            let iv = vld1q_f32(inv_arr.as_ptr());
            let pv = vld1q_f32(p.as_ptr().add(i));
            let bv = vld1q_f32(b.as_ptr().add(i));
            let dpv = vld1q_f32(dp.as_ptr().add(i));
            let x = vmulq_f32(pv, iv);
            let q0 = match fmt {
                KernelFormat::E4m3 => qdq4_fp8_neon(x, 448.0, -6, 124, 130),
                KernelFormat::E5m2 => qdq4_fp8_neon(x, 57344.0, -14, 125, 129),
                KernelFormat::Int4 => qdq4_int4_neon(x),
            };
            let q = vmulq_f32(q0, sv);
            let dq = vsubq_f32(q, bv);
            let err = vsubq_f32(q, pv);
            let neg = vreinterpretq_s32_u32(vcltq_f32(dq, zero));
            let pos = vreinterpretq_s32_u32(vcgtq_f32(dq, zero));
            let sgn = vsubq_s32(neg, pos);
            let sp_arr = [sp[i] as i32, sp[i + 1] as i32, sp[i + 2] as i32, sp[i + 3] as i32];
            let spv = vld1q_s32(sp_arr.as_ptr());
            let eq_ones = vshrq_n_u32::<31>(vceqq_s32(sgn, spv));
            agree_acc = vaddw_u32(agree_acc, vget_low_u32(eq_ones));
            agree_acc = vaddw_u32(agree_acc, vget_high_u32(eq_ones));
            let dq_lo = vcvt_f64_f32(vget_low_f32(dq));
            let dq_hi = vcvt_f64_f32(vget_high_f32(dq));
            let dp_lo = vcvt_f64_f32(vget_low_f32(dpv));
            let dp_hi = vcvt_f64_f32(vget_high_f32(dpv));
            dot_a = vaddq_f64(dot_a, vmulq_f64(dq_lo, dp_lo));
            dot_b = vaddq_f64(dot_b, vmulq_f64(dq_hi, dp_hi));
            let nq_f = vmulq_f32(dq, dq);
            nq_a = vaddq_f64(nq_a, vcvt_f64_f32(vget_low_f32(nq_f)));
            nq_b = vaddq_f64(nq_b, vcvt_f64_f32(vget_high_f32(nq_f)));
            let sq_f = vmulq_f32(err, err);
            sq_a = vaddq_f64(sq_a, vcvt_f64_f32(vget_low_f32(sq_f)));
            sq_b = vaddq_f64(sq_b, vcvt_f64_f32(vget_high_f32(sq_f)));
            i += 4;
        }
        let mut agree_k = vgetq_lane_u64::<0>(agree_acc) + vgetq_lane_u64::<1>(agree_acc);
        let mut dot_k = hsum4_f64(dot_a, dot_b);
        let mut nq_k = hsum4_f64(nq_a, nq_b);
        let mut sq_k = hsum4_f64(sq_a, sq_b);
        for j in main..len {
            let si = scale_idx[j] as usize;
            let x = p[j] * inv_row[si];
            let q0 = match fmt {
                KernelFormat::E4m3 => crate::fp8::qdq_e4m3(x),
                KernelFormat::E5m2 => crate::fp8::qdq_e5m2(x),
                KernelFormat::Int4 => crate::quant::format::qdq_int4(x),
            };
            let q = q0 * s_row[si];
            let dq = q - b[j];
            let err = q - p[j];
            agree_k += (crate::metrics::tile::sign_i8(dq) == sp[j]) as u64;
            dot_k += dq as f64 * dp[j] as f64;
            nq_k += (dq * dq) as f64;
            sq_k += (err * err) as f64;
        }
        agree.push(agree_k);
        dot.push(dot_k);
        nq.push(nq_k);
        sq.push(sq_k);
    }
    TilePartials { agree, dot, nq, sq }
}
