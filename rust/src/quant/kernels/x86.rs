//! x86_64 kernel bodies: the AVX2 tier (all four loop families) and the
//! SSE4.1 tier (decode + axpy families; the sweep tile stays scalar).
//!
//! Every function is `unsafe` because of its `#[target_feature]`
//! attribute; the dispatcher in the parent module only calls a body
//! after runtime detection proved the feature present. Bitwise
//! contracts are documented on the safe entry points — the short
//! version: decode rebuilds each FP8 value exactly from its bits
//! (power-of-two exponent rebias), axpy uses separate multiply and add
//! (never FMA), and the tile kernel's vector qdq performs the same
//! single-rounding ops as the scalar `fp8::qdq_*` per element.

use std::arch::x86_64::*;

use super::{KernelFormat, TilePartials};

#[inline]
fn exp2f(e: i32) -> f32 {
    f32::from_bits(((e + 127) as u32) << 23)
}

#[target_feature(enable = "avx2")]
pub(super) unsafe fn axpy_avx2(out: &mut [f32], a: f32, x: &[f32]) {
    let n = out.len();
    let av = _mm256_set1_ps(a);
    let mut i = 0;
    while i + 8 <= n {
        let xv = _mm256_loadu_ps(x.as_ptr().add(i));
        let ov = _mm256_loadu_ps(out.as_ptr().add(i));
        _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_add_ps(ov, _mm256_mul_ps(av, xv)));
        i += 8;
    }
    while i < n {
        out[i] += a * x[i];
        i += 1;
    }
}

#[target_feature(enable = "sse4.1")]
pub(super) unsafe fn axpy_sse41(out: &mut [f32], a: f32, x: &[f32]) {
    let n = out.len();
    let av = _mm_set1_ps(a);
    let mut i = 0;
    while i + 4 <= n {
        let xv = _mm_loadu_ps(x.as_ptr().add(i));
        let ov = _mm_loadu_ps(out.as_ptr().add(i));
        _mm_storeu_ps(out.as_mut_ptr().add(i), _mm_add_ps(ov, _mm_mul_ps(av, xv)));
        i += 4;
    }
    while i < n {
        out[i] += a * x[i];
        i += 1;
    }
}

#[target_feature(enable = "avx2")]
pub(super) unsafe fn scale_mul_avx2(out: &mut [f32], s: f32) {
    let n = out.len();
    let sv = _mm256_set1_ps(s);
    let mut i = 0;
    while i + 8 <= n {
        let ov = _mm256_loadu_ps(out.as_ptr().add(i));
        _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_mul_ps(ov, sv));
        i += 8;
    }
    while i < n {
        out[i] *= s;
        i += 1;
    }
}

#[target_feature(enable = "sse4.1")]
pub(super) unsafe fn scale_mul_sse41(out: &mut [f32], s: f32) {
    let n = out.len();
    let sv = _mm_set1_ps(s);
    let mut i = 0;
    while i + 4 <= n {
        let ov = _mm_loadu_ps(out.as_ptr().add(i));
        _mm_storeu_ps(out.as_mut_ptr().add(i), _mm_mul_ps(ov, sv));
        i += 4;
    }
    while i < n {
        out[i] *= s;
        i += 1;
    }
}

#[target_feature(enable = "avx2")]
pub(super) unsafe fn mul_slice_avx2(out: &mut [f32], s: &[f32]) {
    let n = out.len();
    let mut i = 0;
    while i + 8 <= n {
        let ov = _mm256_loadu_ps(out.as_ptr().add(i));
        let sv = _mm256_loadu_ps(s.as_ptr().add(i));
        _mm256_storeu_ps(out.as_mut_ptr().add(i), _mm256_mul_ps(ov, sv));
        i += 8;
    }
    while i < n {
        out[i] *= s[i];
        i += 1;
    }
}

#[target_feature(enable = "sse4.1")]
pub(super) unsafe fn mul_slice_sse41(out: &mut [f32], s: &[f32]) {
    let n = out.len();
    let mut i = 0;
    while i + 4 <= n {
        let ov = _mm_loadu_ps(out.as_ptr().add(i));
        let sv = _mm_loadu_ps(s.as_ptr().add(i));
        _mm_storeu_ps(out.as_mut_ptr().add(i), _mm_mul_ps(ov, sv));
        i += 4;
    }
    while i < n {
        out[i] *= s[i];
        i += 1;
    }
}

/// Shared FP8 byte-decode body. `SHIFT` places the 7-bit exp+mantissa
/// payload at the f32 exponent/mantissa boundary (20 for E4M3's 4-bit
/// exponent, 21 for E5M2's 5-bit one), `rebias` is the exact
/// power-of-two ratio between the f32 reinterpretation and the true
/// value (2¹²⁰ / 2¹¹²), and codes whose payload satisfies
/// `payload & nan_mask == nan_mask` blend to `f32::NAN` — the same NaN
/// the scalar LUT stores. Returns the vector-covered prefix length.
#[target_feature(enable = "avx2")]
unsafe fn decode_fp8_avx2<const SHIFT: i32>(
    codes: &[u8],
    out: &mut [f32],
    rebias: f32,
    nan_mask: i32,
) -> usize {
    let n = codes.len();
    let rb = _mm256_set1_ps(rebias);
    let nanv = _mm256_set1_ps(f32::NAN);
    let payload_mask = _mm256_set1_epi32(0x7F);
    let sign_mask = _mm256_set1_epi32(0x80);
    let nm = _mm256_set1_epi32(nan_mask);
    let mut i = 0;
    while i + 8 <= n {
        let b64 = (codes.as_ptr().add(i) as *const i64).read_unaligned();
        let v = _mm256_cvtepu8_epi32(_mm_set_epi64x(0, b64));
        let payload = _mm256_and_si256(v, payload_mask);
        let sign = _mm256_slli_epi32::<24>(_mm256_and_si256(v, sign_mask));
        let bits = _mm256_or_si256(_mm256_slli_epi32::<SHIFT>(payload), sign);
        let val = _mm256_mul_ps(_mm256_castsi256_ps(bits), rb);
        let isnan = _mm256_cmpeq_epi32(_mm256_and_si256(payload, nm), nm);
        let dec = _mm256_blendv_ps(val, nanv, _mm256_castsi256_ps(isnan));
        _mm256_storeu_ps(out.as_mut_ptr().add(i), dec);
        i += 8;
    }
    i
}

/// The SSE4.1 variant of [`decode_fp8_avx2`] (4 codes per step).
#[target_feature(enable = "sse4.1")]
unsafe fn decode_fp8_sse41<const SHIFT: i32>(
    codes: &[u8],
    out: &mut [f32],
    rebias: f32,
    nan_mask: i32,
) -> usize {
    let n = codes.len();
    let rb = _mm_set1_ps(rebias);
    let nanv = _mm_set1_ps(f32::NAN);
    let payload_mask = _mm_set1_epi32(0x7F);
    let sign_mask = _mm_set1_epi32(0x80);
    let nm = _mm_set1_epi32(nan_mask);
    let mut i = 0;
    while i + 4 <= n {
        let b32 = (codes.as_ptr().add(i) as *const i32).read_unaligned();
        let v = _mm_cvtepu8_epi32(_mm_cvtsi32_si128(b32));
        let payload = _mm_and_si128(v, payload_mask);
        let sign = _mm_slli_epi32::<24>(_mm_and_si128(v, sign_mask));
        let bits = _mm_or_si128(_mm_slli_epi32::<SHIFT>(payload), sign);
        let val = _mm_mul_ps(_mm_castsi128_ps(bits), rb);
        let isnan = _mm_cmpeq_epi32(_mm_and_si128(payload, nm), nm);
        let dec = _mm_blendv_ps(val, nanv, _mm_castsi128_ps(isnan));
        _mm_storeu_ps(out.as_mut_ptr().add(i), dec);
        i += 4;
    }
    i
}

#[target_feature(enable = "avx2")]
pub(super) unsafe fn decode_e4m3_avx2(codes: &[u8], out: &mut [f32]) {
    let main = decode_fp8_avx2::<20>(codes, out, exp2f(120), 0x7F);
    crate::fp8::decode_slice_into_scalar(&codes[main..], &mut out[main..]);
}

#[target_feature(enable = "avx2")]
pub(super) unsafe fn decode_e5m2_avx2(codes: &[u8], out: &mut [f32]) {
    let main = decode_fp8_avx2::<21>(codes, out, exp2f(112), 0x7C);
    crate::fp8::decode_slice_into_e5m2_scalar(&codes[main..], &mut out[main..]);
}

#[target_feature(enable = "sse4.1")]
pub(super) unsafe fn decode_e4m3_sse41(codes: &[u8], out: &mut [f32]) {
    let main = decode_fp8_sse41::<20>(codes, out, exp2f(120), 0x7F);
    crate::fp8::decode_slice_into_scalar(&codes[main..], &mut out[main..]);
}

#[target_feature(enable = "sse4.1")]
pub(super) unsafe fn decode_e5m2_sse41(codes: &[u8], out: &mut [f32]) {
    let main = decode_fp8_sse41::<21>(codes, out, exp2f(112), 0x7C);
    crate::fp8::decode_slice_into_e5m2_scalar(&codes[main..], &mut out[main..]);
}

#[target_feature(enable = "avx2")]
pub(super) unsafe fn decode_int4_avx2(packed: &[u8], out: &mut [f32]) {
    let n = out.len();
    let main = n - n % 16;
    let nibble = _mm_set1_epi8(0x0F);
    let eight = _mm256_set1_ps(8.0);
    let mut i = 0;
    // 16 outputs per step from 8 packed bytes; `i` stays even, so the
    // byte cursor `i / 2` never straddles a code pair.
    while i < main {
        let b64 = (packed.as_ptr().add(i / 2) as *const i64).read_unaligned();
        let v = _mm_set_epi64x(0, b64);
        let lo = _mm_and_si128(v, nibble);
        let hi = _mm_and_si128(_mm_srli_epi16::<4>(v), nibble);
        let inter = _mm_unpacklo_epi8(lo, hi);
        let c0 = _mm256_cvtepu8_epi32(inter);
        let c1 = _mm256_cvtepu8_epi32(_mm_srli_si128::<8>(inter));
        let f0 = _mm256_sub_ps(_mm256_cvtepi32_ps(c0), eight);
        let f1 = _mm256_sub_ps(_mm256_cvtepi32_ps(c1), eight);
        _mm256_storeu_ps(out.as_mut_ptr().add(i), f0);
        _mm256_storeu_ps(out.as_mut_ptr().add(i + 8), f1);
        i += 16;
    }
    crate::quant::format::decode_int4_slice_into_scalar(&packed[main / 2..], &mut out[main..]);
}

#[target_feature(enable = "sse4.1")]
pub(super) unsafe fn decode_int4_sse41(packed: &[u8], out: &mut [f32]) {
    let n = out.len();
    let main = n - n % 8;
    let nibble = _mm_set1_epi8(0x0F);
    let eight = _mm_set1_ps(8.0);
    let mut i = 0;
    while i < main {
        let b32 = (packed.as_ptr().add(i / 2) as *const i32).read_unaligned();
        let v = _mm_cvtsi32_si128(b32);
        let lo = _mm_and_si128(v, nibble);
        let hi = _mm_and_si128(_mm_srli_epi16::<4>(v), nibble);
        let inter = _mm_unpacklo_epi8(lo, hi);
        let c0 = _mm_cvtepu8_epi32(inter);
        let c1 = _mm_cvtepu8_epi32(_mm_srli_si128::<4>(inter));
        let f0 = _mm_sub_ps(_mm_cvtepi32_ps(c0), eight);
        let f1 = _mm_sub_ps(_mm_cvtepi32_ps(c1), eight);
        _mm_storeu_ps(out.as_mut_ptr().add(i), f0);
        _mm_storeu_ps(out.as_mut_ptr().add(i + 4), f1);
        i += 8;
    }
    crate::quant::format::decode_int4_slice_into_scalar(&packed[main / 2..], &mut out[main..]);
}

const RNE: i32 = _MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC;

/// Vector FP8 quantize–dequantize, bitwise-equal to `fp8::qdq_e4m3` /
/// `qdq_e5m2` per lane: NaN-propagating clamp (operand order makes
/// MINPS/MAXPS return the input when it is NaN, like `f32::clamp`),
/// exponent extraction from the magnitude bits, exact power-of-two
/// `step`/`step⁻¹` built in the exponent field (`a · step⁻¹ ≡ a / step`
/// bitwise for powers of two in range), round-to-nearest-even, and a
/// `+0.0` blend where the clamped magnitude is zero (the scalar early
/// return).
#[target_feature(enable = "avx2")]
unsafe fn qdq8_avx2(x: __m256, max: f32, e_min: i32, step_bias: i32, inv_bias: i32) -> __m256 {
    let a = _mm256_min_ps(_mm256_set1_ps(max), _mm256_max_ps(_mm256_set1_ps(-max), x));
    let magbits = _mm256_and_si256(_mm256_castps_si256(a), _mm256_set1_epi32(0x7FFF_FFFF));
    let zero = _mm256_setzero_ps();
    let is_zero = _mm256_cmp_ps::<_CMP_EQ_OQ>(_mm256_castsi256_ps(magbits), zero);
    let e_raw = _mm256_sub_epi32(_mm256_srli_epi32::<23>(magbits), _mm256_set1_epi32(127));
    let e = _mm256_max_epi32(e_raw, _mm256_set1_epi32(e_min));
    let step_e = _mm256_add_epi32(e, _mm256_set1_epi32(step_bias));
    let step = _mm256_castsi256_ps(_mm256_slli_epi32::<23>(step_e));
    let inv_e = _mm256_sub_epi32(_mm256_set1_epi32(inv_bias), e);
    let inv = _mm256_castsi256_ps(_mm256_slli_epi32::<23>(inv_e));
    let g = _mm256_round_ps::<RNE>(_mm256_mul_ps(a, inv));
    _mm256_blendv_ps(_mm256_mul_ps(g, step), zero, is_zero)
}

/// Vector INT4 quantize–dequantize: clamp to ±7 (NaN-propagating, same
/// operand order as [`qdq8_avx2`]) then round-to-nearest-even —
/// bitwise-equal to `format::qdq_int4` per lane.
#[target_feature(enable = "avx2")]
unsafe fn qdq4_avx2(x: __m256) -> __m256 {
    let a = _mm256_min_ps(_mm256_set1_ps(7.0), _mm256_max_ps(_mm256_set1_ps(-7.0), x));
    _mm256_round_ps::<RNE>(a)
}

/// Fixed-order horizontal sum of eight f64 lane partials: low register
/// lanes 0→3, then high register lanes 0→3. Part of the per-ISA
/// reduction-order contract.
#[target_feature(enable = "avx2")]
unsafe fn hsum8_pd(lo: __m256d, hi: __m256d) -> f64 {
    let mut lanes = [0.0f64; 8];
    _mm256_storeu_pd(lanes.as_mut_ptr(), lo);
    _mm256_storeu_pd(lanes.as_mut_ptr().add(4), hi);
    let mut acc = 0.0;
    for l in lanes {
        acc += l;
    }
    acc
}

/// Horizontal sum of four non-negative i64 lane counts.
#[target_feature(enable = "avx2")]
unsafe fn hsum4_epi64(v: __m256i) -> u64 {
    let mut lanes = [0i64; 4];
    _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, v);
    lanes.iter().map(|&x| x as u64).sum()
}

/// AVX2 sweep tile kernel (family 3). Per element, `q` is bitwise-equal
/// to the scalar kernel's; the sign comparison runs branchless in
/// integer lanes ({-1, 0, +1} built from two ordered compares, NaN → 0
/// like `sign_i8`); agreement counts accumulate in i64 lanes; dot/norm
/// stats accumulate in two f64 lane-partial registers each and merge in
/// a fixed order ([`hsum8_pd`]) before the scalar tail appends in
/// element order.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2")]
pub(super) unsafe fn eval_tile_avx2(
    fmt: KernelFormat,
    p: &[f32],
    b: &[f32],
    dp: &[f32],
    sp: &[i8],
    scale_idx: &[u32],
    s_tab: &[f32],
    inv_tab: &[f32],
    n_regions: usize,
    n_candidates: usize,
) -> TilePartials {
    let len = p.len();
    let main = len - len % 8;
    let zero = _mm256_setzero_ps();
    let mut agree = Vec::with_capacity(n_candidates);
    let mut dot = Vec::with_capacity(n_candidates);
    let mut nq = Vec::with_capacity(n_candidates);
    let mut sq = Vec::with_capacity(n_candidates);
    for k in 0..n_candidates {
        let s_row = &s_tab[k * n_regions..(k + 1) * n_regions];
        let inv_row = &inv_tab[k * n_regions..(k + 1) * n_regions];
        let mut agree_lo = _mm256_setzero_si256();
        let mut agree_hi = _mm256_setzero_si256();
        let mut dot_lo = _mm256_setzero_pd();
        let mut dot_hi = _mm256_setzero_pd();
        let mut nq_lo = _mm256_setzero_pd();
        let mut nq_hi = _mm256_setzero_pd();
        let mut sq_lo = _mm256_setzero_pd();
        let mut sq_hi = _mm256_setzero_pd();
        let mut i = 0;
        while i + 8 <= len {
            let idx = _mm256_loadu_si256(scale_idx.as_ptr().add(i) as *const __m256i);
            let sv = _mm256_i32gather_ps::<4>(s_row.as_ptr(), idx);
            let iv = _mm256_i32gather_ps::<4>(inv_row.as_ptr(), idx);
            let pv = _mm256_loadu_ps(p.as_ptr().add(i));
            let bv = _mm256_loadu_ps(b.as_ptr().add(i));
            let dpv = _mm256_loadu_ps(dp.as_ptr().add(i));
            let x = _mm256_mul_ps(pv, iv);
            let q0 = match fmt {
                KernelFormat::E4m3 => qdq8_avx2(x, 448.0, -6, 124, 130),
                KernelFormat::E5m2 => qdq8_avx2(x, 57344.0, -14, 125, 129),
                KernelFormat::Int4 => qdq4_avx2(x),
            };
            let q = _mm256_mul_ps(q0, sv);
            let dq = _mm256_sub_ps(q, bv);
            let err = _mm256_sub_ps(q, pv);
            let neg = _mm256_castps_si256(_mm256_cmp_ps::<_CMP_LT_OQ>(dq, zero));
            let pos = _mm256_castps_si256(_mm256_cmp_ps::<_CMP_GT_OQ>(dq, zero));
            let sgn = _mm256_sub_epi32(neg, pos);
            let s64 = (sp.as_ptr().add(i) as *const i64).read_unaligned();
            let spv = _mm256_cvtepi8_epi32(_mm_set_epi64x(0, s64));
            let eq = _mm256_cmpeq_epi32(sgn, spv);
            let eq_lo = _mm256_cvtepi32_epi64(_mm256_castsi256_si128(eq));
            let eq_hi = _mm256_cvtepi32_epi64(_mm256_extracti128_si256::<1>(eq));
            agree_lo = _mm256_sub_epi64(agree_lo, eq_lo);
            agree_hi = _mm256_sub_epi64(agree_hi, eq_hi);
            let dq_lo = _mm256_cvtps_pd(_mm256_castps256_ps128(dq));
            let dq_hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(dq));
            let dp_lo = _mm256_cvtps_pd(_mm256_castps256_ps128(dpv));
            let dp_hi = _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(dpv));
            dot_lo = _mm256_add_pd(dot_lo, _mm256_mul_pd(dq_lo, dp_lo));
            dot_hi = _mm256_add_pd(dot_hi, _mm256_mul_pd(dq_hi, dp_hi));
            let nq_f = _mm256_mul_ps(dq, dq);
            nq_lo = _mm256_add_pd(nq_lo, _mm256_cvtps_pd(_mm256_castps256_ps128(nq_f)));
            nq_hi = _mm256_add_pd(nq_hi, _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(nq_f)));
            let sq_f = _mm256_mul_ps(err, err);
            sq_lo = _mm256_add_pd(sq_lo, _mm256_cvtps_pd(_mm256_castps256_ps128(sq_f)));
            sq_hi = _mm256_add_pd(sq_hi, _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(sq_f)));
            i += 8;
        }
        let mut agree_k = hsum4_epi64(agree_lo) + hsum4_epi64(agree_hi);
        let mut dot_k = hsum8_pd(dot_lo, dot_hi);
        let mut nq_k = hsum8_pd(nq_lo, nq_hi);
        let mut sq_k = hsum8_pd(sq_lo, sq_hi);
        for j in main..len {
            let si = scale_idx[j] as usize;
            let x = p[j] * inv_row[si];
            let q0 = match fmt {
                KernelFormat::E4m3 => crate::fp8::qdq_e4m3(x),
                KernelFormat::E5m2 => crate::fp8::qdq_e5m2(x),
                KernelFormat::Int4 => crate::quant::format::qdq_int4(x),
            };
            let q = q0 * s_row[si];
            let dq = q - b[j];
            let err = q - p[j];
            agree_k += (crate::metrics::tile::sign_i8(dq) == sp[j]) as u64;
            dot_k += dq as f64 * dp[j] as f64;
            nq_k += (dq * dq) as f64;
            sq_k += (err * err) as f64;
        }
        agree.push(agree_k);
        dot.push(dot_k);
        nq.push(nq_k);
        sq.push(sq_k);
    }
    TilePartials { agree, dot, nq, sq }
}
