//! First-class code formats for quantized storage.
//!
//! A [`CodeFormat`] owns everything that differs between the storable code
//! families — the value grid (`qmax`), the scaled quantize–dequantize
//! projection, bits per element, the packed sidecar layout, and bulk
//! decode — so the rest of the pipeline (sweep engine, coordinator
//! writers, `QuantizedParams` loader, fused dequant-matmul, CLI) can
//! dispatch on one enum instead of hardcoding FP8 E4M3.
//!
//! Formats:
//!
//! | label        | grid                    | bits | codes layout              |
//! | ------------ | ----------------------- | ---- | ------------------------- |
//! | `fp8-e4m3`   | E4M3FN, max ±448        | 8    | 1 byte / element          |
//! | `fp8-e5m2`   | E5M2, max ±57344        | 8    | 1 byte / element          |
//! | `int4[:G]`   | symmetric INT4, ±7      | 4    | 2 codes / byte, row-packed |
//!
//! INT4 codes are stored biased (`code = q + 8`, `q ∈ [−7, 7]`, so codes
//! occupy `[1, 15]` and nibble `0` is never produced by the encoder) and
//! packed two per byte **per row**: row `r` starts at byte
//! `r · ⌈cols/2⌉`, the low nibble holds the even column and the high
//! nibble the odd column, and a row with an odd column count zero-pads the
//! final high nibble. Row-aligned packing is what lets the fused
//! dequant-matmul decode one row at a time without cross-row nibble
//! straddling. The `G` in `int4:G` is the scale-group width: the CLI maps
//! it to [`Granularity::Block`]`(G)` when no explicit `--gran` is given.
//!
//! The per-tensor store metadata is a [`Descriptor`]
//! (`fmt.<name> = "<format>;<granularity>[;res=<k>][;cols=<n>]"`), the
//! structured replacement for the legacy `quantized: "fp8_e4m3"` +
//! `gran.<name>` metadata pair (old stores still load through a compat
//! shim in `eval::quantstore`). `cols` records the logical column count
//! for sub-byte formats, where the packed codes shape alone cannot
//! distinguish an even column count from the preceding odd one.
//!
//! See `docs/FORMATS.md` for the full format table, sidecar layout, and
//! the low-rank residual math.

use crate::fp8;

use super::Granularity;

/// Largest representable INT4 magnitude (symmetric grid, −8 unused).
pub const INT4_MAX: f32 = 7.0;

/// Decode LUT for biased INT4 nibbles: `code & 0xF` → `code − 8` as f32.
/// Nibble 0 (−8) is never produced by [`encode_int4`] but decodes to a
/// well-defined value so corrupt stores fail loudly in value space, not UB.
pub const INT4_DECODE: [f32; 16] = [
    -8.0, -7.0, -6.0, -5.0, -4.0, -3.0, -2.0, -1.0, 0.0, 1.0, 2.0, 3.0, 4.0,
    5.0, 6.0, 7.0,
];

/// The valid `--format` spellings, quoted by every parse error.
pub const VALID_FORMATS: &str = "fp8-e4m3 | fp8-e5m2 | int4[:GROUP]";

/// A storable code family: the value grid plus its packed byte layout.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CodeFormat {
    /// FP8 E4M3FN — the paper's format; 1 byte/element.
    Fp8E4m3,
    /// FP8 E5M2 — wider range, coarser mantissa; 1 byte/element.
    Fp8E5m2,
    /// Symmetric INT4 with scale groups of width `group`; 2 codes/byte.
    Int4 {
        /// Scale-group width (the `G` of `int4:G`); defaults the scale
        /// granularity to `Block(G)` when the CLI gets no explicit `--gran`.
        group: usize,
    },
}

impl Default for CodeFormat {
    fn default() -> Self {
        CodeFormat::Fp8E4m3
    }
}

impl CodeFormat {
    /// Parse a format label: `fp8-e4m3`, `fp8-e5m2`, `int4` (group 64) or
    /// `int4:G`. Unknown spellings are hard errors naming the valid set.
    pub fn parse(s: &str) -> Result<CodeFormat, String> {
        match s {
            "fp8-e4m3" => Ok(CodeFormat::Fp8E4m3),
            "fp8-e5m2" => Ok(CodeFormat::Fp8E5m2),
            "int4" => Ok(CodeFormat::Int4 { group: 64 }),
            other => {
                if let Some(g) = other.strip_prefix("int4:") {
                    match g.parse::<usize>() {
                        Ok(group) if group > 0 => {
                            return Ok(CodeFormat::Int4 { group });
                        }
                        _ => {}
                    }
                }
                Err(format!("bad format {other:?} (valid: {VALID_FORMATS})"))
            }
        }
    }

    /// Canonical label, `parse`-roundtrippable.
    pub fn label(&self) -> String {
        match self {
            CodeFormat::Fp8E4m3 => "fp8-e4m3".into(),
            CodeFormat::Fp8E5m2 => "fp8-e5m2".into(),
            CodeFormat::Int4 { group } => format!("int4:{group}"),
        }
    }

    /// Largest representable magnitude — the `Qmax` of the AbsMax scale
    /// init `s0 = max|W| / Qmax` (Algorithm 1 line 3).
    pub fn qmax(&self) -> f32 {
        match self {
            CodeFormat::Fp8E4m3 => fp8::E4M3_MAX,
            CodeFormat::Fp8E5m2 => fp8::E5M2_MAX,
            CodeFormat::Int4 { .. } => INT4_MAX,
        }
    }

    /// Code width in bits.
    pub fn bits_per_element(&self) -> usize {
        match self {
            CodeFormat::Fp8E4m3 | CodeFormat::Fp8E5m2 => 8,
            CodeFormat::Int4 { .. } => 4,
        }
    }

    /// Whether codes pack below one byte per element.
    pub fn is_sub_byte(&self) -> bool {
        self.bits_per_element() < 8
    }

    /// Packed bytes one `cols`-wide row of codes occupies (the row stride
    /// of the codes buffer).
    pub fn packed_row_bytes(&self, cols: usize) -> usize {
        match self {
            CodeFormat::Fp8E4m3 | CodeFormat::Fp8E5m2 => cols,
            CodeFormat::Int4 { .. } => cols.div_ceil(2),
        }
    }

    /// Packed bytes a full `rows`×`cols` codes buffer occupies.
    pub fn packed_len(&self, rows: usize, cols: usize) -> usize {
        rows * self.packed_row_bytes(cols)
    }

    /// The scale granularity this format implies when the caller gives
    /// none: the paper's Block(128) for FP8, `Block(G)` for `int4:G`.
    pub fn default_granularity(&self) -> Granularity {
        match self {
            CodeFormat::Fp8E4m3 | CodeFormat::Fp8E5m2 => Granularity::Block(128),
            CodeFormat::Int4 { group } => Granularity::Block(*group),
        }
    }

    /// The format's scaled quantize–dequantize projection
    /// `qdq(x · s⁻¹) · s` — the same reciprocal-multiply form as
    /// [`fp8::qdq_e4m3_scaled`], dispatched. Every engine (pointwise
    /// sweeps, the tiled `SweepPlan`, the storage quantizer) must go
    /// through the same per-format function so they stay bit-identical.
    #[inline(always)]
    pub fn qdq_scaled(&self, x: f32, inv_s: f32, s: f32) -> f32 {
        match self {
            CodeFormat::Fp8E4m3 => fp8::qdq_e4m3_scaled(x, inv_s, s),
            CodeFormat::Fp8E5m2 => fp8::qdq_e5m2_scaled(x, inv_s, s),
            CodeFormat::Int4 { .. } => qdq_int4_scaled(x, inv_s, s),
        }
    }

    /// Bulk-decode one packed row of codes into `out` (len = logical
    /// cols). FP8 rows decode through the shared 256-entry LUTs; INT4
    /// rows unpack nibbles through [`INT4_DECODE`].
    #[inline]
    pub fn decode_row_into(&self, row: &[u8], out: &mut [f32]) {
        match self {
            CodeFormat::Fp8E4m3 => fp8::decode_slice_into(row, out),
            CodeFormat::Fp8E5m2 => fp8::decode_slice_into_e5m2(row, out),
            CodeFormat::Int4 { .. } => decode_int4_slice_into(row, out),
        }
    }
}

/// Project onto the symmetric INT4 grid `{−7, …, 7}` (saturating RNE).
#[inline(always)]
pub fn qdq_int4(x: f32) -> f32 {
    x.clamp(-INT4_MAX, INT4_MAX).round_ties_even()
}

/// Reciprocal-scale INT4 quantize–dequantize: `qdq_int4(x · s⁻¹) · s` —
/// the INT4 instantiation of the pipeline's canonical scaled projection
/// (see [`fp8::qdq_e4m3_scaled`] for the contract on `inv_s`).
#[inline(always)]
pub fn qdq_int4_scaled(x: f32, inv_s: f32, s: f32) -> f32 {
    qdq_int4(x * inv_s) * s
}

/// Encode one value to its biased INT4 nibble (`q + 8 ∈ [1, 15]`).
/// NaN encodes to the zero code (8), matching the FP8 encoders' policy of
/// never letting a degenerate input poison the store.
#[inline(always)]
pub fn encode_int4(x: f32) -> u8 {
    let q = qdq_int4(x);
    if q.is_nan() {
        return 8;
    }
    (q + 8.0) as u8
}

/// Pack unpacked nibble codes two-per-byte (low nibble first). An odd
/// length zero-pads the final high nibble.
pub fn pack_int4(unpacked: &[u8]) -> Vec<u8> {
    let mut out = vec![0u8; unpacked.len().div_ceil(2)];
    for (i, &c) in unpacked.iter().enumerate() {
        if i % 2 == 0 {
            out[i / 2] |= c & 0x0F;
        } else {
            out[i / 2] |= (c & 0x0F) << 4;
        }
    }
    out
}

/// Unpack `n` nibble codes from their packed form (inverse of
/// [`pack_int4`]; the pad nibble of an odd-length buffer is not returned).
pub fn unpack_int4(packed: &[u8], n: usize) -> Vec<u8> {
    assert_eq!(packed.len(), n.div_ceil(2), "packed len vs n={n}");
    let mut out = vec![0u8; n];
    for (i, o) in out.iter_mut().enumerate() {
        let b = packed[i / 2];
        *o = if i % 2 == 0 { b & 0x0F } else { b >> 4 };
    }
    out
}

/// Bulk-decode a packed INT4 row into f32 values. Dispatches to the
/// SIMD kernel layer ([`super::kernels`]); every mode is bitwise-equal
/// to [`decode_int4_slice_into_scalar`].
#[inline]
pub fn decode_int4_slice_into(packed: &[u8], out: &mut [f32]) {
    super::kernels::decode_int4_into(packed, out);
}

/// The scalar [`INT4_DECODE`] walk behind [`decode_int4_slice_into`] —
/// the always-compiled bitwise reference the SIMD nibble-unpack kernels
/// are verified against, and the `DAQ_SIMD=off` fallback.
pub fn decode_int4_slice_into_scalar(packed: &[u8], out: &mut [f32]) {
    assert_eq!(packed.len(), out.len().div_ceil(2), "packed row len");
    for (i, o) in out.iter_mut().enumerate() {
        let b = packed[i / 2];
        let code = if i % 2 == 0 { b & 0x0F } else { b >> 4 };
        *o = INT4_DECODE[code as usize];
    }
}

/// The per-tensor store descriptor behind the `fmt.<name>` metadata key:
/// everything a loader needs to reconstruct a [`super::QuantizedTensor`]
/// from its sidecars without per-format name conventions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Descriptor {
    /// Code family of the `.codes` sidecar.
    pub format: CodeFormat,
    /// Scale granularity of the `.scales` sidecar.
    pub granularity: Granularity,
    /// Rank of the `.res_u`/`.res_v` low-rank residual pair (0 = none).
    pub residual_rank: usize,
    /// Logical column count — present for sub-byte formats, where the
    /// packed codes shape cannot distinguish `2n` columns from `2n−1`.
    pub cols: Option<usize>,
}

impl Descriptor {
    /// Describe an existing quantized tensor (the writers' path: the
    /// tensor itself is the source of truth, not the pipeline config).
    pub fn for_tensor(q: &super::QuantizedTensor) -> Descriptor {
        Descriptor {
            format: q.scales.format,
            granularity: q.scales.granularity,
            residual_rank: q.residual.as_ref().map_or(0, |r| r.k),
            cols: q.scales.format.is_sub_byte().then_some(q.shape.1),
        }
    }

    /// Serialize to the `fmt.<name>` metadata value:
    /// `<format>;<granularity>[;res=<k>][;cols=<n>]`.
    pub fn to_meta(&self) -> String {
        let mut s = format!("{};{}", self.format.label(), self.granularity.label());
        if self.residual_rank > 0 {
            s.push_str(&format!(";res={}", self.residual_rank));
        }
        if let Some(c) = self.cols {
            s.push_str(&format!(";cols={c}"));
        }
        s
    }

    /// Parse a `fmt.<name>` metadata value (inverse of
    /// [`Descriptor::to_meta`]; unknown fields are hard errors).
    pub fn parse(s: &str) -> Result<Descriptor, String> {
        let mut parts = s.split(';');
        let format = CodeFormat::parse(
            parts.next().ok_or_else(|| format!("empty fmt descriptor {s:?}"))?,
        )?;
        let granularity = Granularity::parse(
            parts
                .next()
                .ok_or_else(|| format!("fmt descriptor {s:?} missing granularity"))?,
        )?;
        let mut residual_rank = 0usize;
        let mut cols = None;
        for p in parts {
            if let Some(k) = p.strip_prefix("res=") {
                residual_rank = k
                    .parse()
                    .map_err(|_| format!("bad residual rank in fmt descriptor {s:?}"))?;
            } else if let Some(c) = p.strip_prefix("cols=") {
                cols = Some(c.parse().map_err(|_| {
                    format!("bad cols field in fmt descriptor {s:?}")
                })?);
            } else {
                return Err(format!("unknown field {p:?} in fmt descriptor {s:?}"));
            }
        }
        Ok(Descriptor { format, granularity, residual_rank, cols })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_label_roundtrip() {
        for s in ["fp8-e4m3", "fp8-e5m2", "int4:64", "int4:128", "int4:7"] {
            let f = CodeFormat::parse(s).unwrap();
            assert_eq!(f.label(), s);
            assert_eq!(CodeFormat::parse(&f.label()).unwrap(), f);
        }
        assert_eq!(
            CodeFormat::parse("int4").unwrap(),
            CodeFormat::Int4 { group: 64 }
        );
        for bad in ["fp8", "int4:", "int4:0", "int4:x", "e4m3", "int8", ""] {
            let err = CodeFormat::parse(bad).unwrap_err();
            assert!(err.contains(VALID_FORMATS), "{bad:?}: {err}");
        }
    }

    #[test]
    fn qmax_and_bits() {
        assert_eq!(CodeFormat::Fp8E4m3.qmax(), 448.0);
        assert_eq!(CodeFormat::Fp8E5m2.qmax(), 57344.0);
        assert_eq!(CodeFormat::Int4 { group: 64 }.qmax(), 7.0);
        assert_eq!(CodeFormat::Fp8E4m3.bits_per_element(), 8);
        assert_eq!(CodeFormat::Int4 { group: 64 }.bits_per_element(), 4);
        assert!(CodeFormat::Int4 { group: 64 }.is_sub_byte());
        assert!(!CodeFormat::Fp8E5m2.is_sub_byte());
    }

    #[test]
    fn packed_layout() {
        let i4 = CodeFormat::Int4 { group: 64 };
        assert_eq!(i4.packed_row_bytes(8), 4);
        assert_eq!(i4.packed_row_bytes(7), 4); // odd row pads the hi nibble
        assert_eq!(i4.packed_len(3, 7), 12);
        assert_eq!(CodeFormat::Fp8E4m3.packed_row_bytes(7), 7);
        assert_eq!(
            i4.default_granularity(),
            crate::quant::Granularity::Block(64)
        );
    }

    #[test]
    fn int4_grid_is_symmetric_and_saturating() {
        assert_eq!(qdq_int4(100.0), 7.0);
        assert_eq!(qdq_int4(-100.0), -7.0);
        assert_eq!(qdq_int4(0.49), 0.0);
        assert_eq!(qdq_int4(0.5), 0.0); // tie to even
        assert_eq!(qdq_int4(1.5), 2.0);
        assert_eq!(qdq_int4(-2.5), -2.0);
        for q in -7..=7 {
            let v = q as f32;
            assert_eq!(qdq_int4(v), v); // grid values are fixed points
            let code = encode_int4(v);
            assert!((1..=15).contains(&code), "code {code}");
            assert_eq!(INT4_DECODE[code as usize], v);
        }
        assert_eq!(encode_int4(f32::NAN), 8);
        assert_eq!(INT4_DECODE[8], 0.0);
    }

    #[test]
    fn qdq_scaled_dispatch_matches_direct() {
        let (s, inv) = (0.037f32, 1.0 / 0.037f32);
        for x in [-3.2f32, -0.01, 0.0, 0.4, 2.9, 17.0] {
            assert_eq!(
                CodeFormat::Int4 { group: 8 }.qdq_scaled(x, inv, s).to_bits(),
                qdq_int4_scaled(x, inv, s).to_bits()
            );
            assert_eq!(
                CodeFormat::Fp8E4m3.qdq_scaled(x, inv, s).to_bits(),
                crate::fp8::qdq_e4m3_scaled(x, inv, s).to_bits()
            );
            assert_eq!(
                CodeFormat::Fp8E5m2.qdq_scaled(x, inv, s).to_bits(),
                crate::fp8::qdq_e5m2_scaled(x, inv, s).to_bits()
            );
        }
    }

    #[test]
    fn pack_unpack_hand_cases() {
        // even length: [1, 15] -> 0xF1 (lo nibble first)
        assert_eq!(pack_int4(&[1, 15]), vec![0xF1]);
        // odd length: pad nibble is zero
        assert_eq!(pack_int4(&[9, 2, 7]), vec![0x29, 0x07]);
        assert_eq!(unpack_int4(&[0x29, 0x07], 3), vec![9, 2, 7]);
        let mut out = vec![0.0f32; 3];
        decode_int4_slice_into(&[0x29, 0x07], &mut out);
        assert_eq!(out, vec![1.0, -6.0, -1.0]);
    }

    #[test]
    fn proptest_pack_roundtrip_odd_lengths_and_group_boundaries() {
        use crate::util::proptest::{run, Config};
        run("int4 pack/unpack roundtrip", Config::default(), |g| {
            // bias lengths toward group boundaries (±1 around multiples
            // of the scale-group width) and odd counts
            let group = *g.pick(&[2usize, 3, 64, 128]);
            let n = match g.usize_range(0, 2) {
                0 => g.usize_range(1, 257),
                1 => group * g.usize_range(1, 4),
                _ => (group * g.usize_range(1, 4)).saturating_sub(1).max(1),
            };
            let codes: Vec<u8> =
                (0..n).map(|_| g.usize_range(1, 15) as u8).collect();
            let packed = pack_int4(&codes);
            assert_eq!(packed.len(), n.div_ceil(2));
            assert_eq!(unpack_int4(&packed, n), codes);
            // the decode path agrees with unpack + LUT
            let mut dec = vec![0.0f32; n];
            decode_int4_slice_into(&packed, &mut dec);
            for (c, d) in codes.iter().zip(&dec) {
                assert_eq!(INT4_DECODE[*c as usize].to_bits(), d.to_bits());
            }
            // odd lengths leave the pad nibble zero
            if n % 2 == 1 {
                assert_eq!(packed[n / 2] >> 4, 0);
            }
        });
    }

    #[test]
    fn descriptor_meta_roundtrip() {
        let cases = [
            Descriptor {
                format: CodeFormat::Fp8E4m3,
                granularity: Granularity::Block(128),
                residual_rank: 0,
                cols: None,
            },
            Descriptor {
                format: CodeFormat::Fp8E5m2,
                granularity: Granularity::PerChannel,
                residual_rank: 2,
                cols: None,
            },
            Descriptor {
                format: CodeFormat::Int4 { group: 64 },
                granularity: Granularity::Block(64),
                residual_rank: 4,
                cols: Some(129),
            },
        ];
        for d in cases {
            let s = d.to_meta();
            assert_eq!(Descriptor::parse(&s).unwrap(), d, "{s}");
        }
        assert_eq!(
            cases[2].to_meta(),
            "int4:64;block64;res=4;cols=129"
        );
        assert_eq!(cases[0].to_meta(), "fp8-e4m3;block128");
        for bad in [
            "",
            "fp8-e4m3",
            "int4:64;bogus",
            "fp8-e4m3;block128;res=x",
            "fp8-e4m3;block128;huh=1",
        ] {
            assert!(Descriptor::parse(bad).is_err(), "{bad:?}");
        }
    }
}
