//! Batched serving loop over the quantized model — proves the full
//! three-layer composition end-to-end: Rust request loop → AOT HLO
//! forward → PJRT, with FP8-quantized (dequantized-at-load) weights and
//! Python nowhere in sight.
//!
//! Workload: styled-completion requests mirroring the corpus — a pattern
//! prompt plus SEP; the server greedily decodes the style signature and
//! continuation. Reports per-request latency percentiles and token
//! throughput.

use anyhow::Result;

use crate::eval::{ForwardFn, Params};
use crate::util::rng::XorShift;
use crate::util::timer::LatencyStats;

/// Token constants mirroring `python/compile/corpus.py`.
pub mod tokens {
    pub const PAD: i32 = 0;
    pub const BOS: i32 = 1;
    pub const EOS: i32 = 2;
    pub const SEP: i32 = 3;
    pub const CONTENT_BASE: i32 = 4;
    pub const CONTENT_N: i32 = 44;
    pub const STYLE_BASE: i32 = 48;
    pub const STYLE_N: i32 = 16;
    pub const PROMPT_LEN: usize = 12;
}

/// One generation request: a prompt prefix (BOS + body + SEP).
#[derive(Clone, Debug)]
pub struct Request {
    pub prompt: Vec<i32>,
}

/// Deterministic request generator (stride patterns, like the corpus).
pub fn gen_requests(n: usize, seed: u64) -> Vec<Request> {
    let mut rng = XorShift::new(seed);
    (0..n)
        .map(|_| {
            let s = rng.below(tokens::CONTENT_N as usize) as i32;
            let d = 1 + rng.below(7) as i32;
            let mut prompt = vec![tokens::BOS];
            for i in 0..tokens::PROMPT_LEN as i32 {
                prompt.push(tokens::CONTENT_BASE + (s + i * d) % tokens::CONTENT_N);
            }
            prompt.push(tokens::SEP);
            Request { prompt }
        })
        .collect()
}

/// Expected variant-1 style signature for a stride prompt (used by the
/// serving example to report style adherence of generated tokens).
pub fn expected_signature(prompt: &[i32]) -> [i32; 3] {
    let b0 = prompt[1] - tokens::CONTENT_BASE;
    let b1 = prompt[2] - tokens::CONTENT_BASE;
    let h = (b0 * 3 + b1 * 7).rem_euclid(tokens::STYLE_N);
    [
        tokens::STYLE_BASE + h,
        tokens::STYLE_BASE + (h * 7 + 2).rem_euclid(tokens::STYLE_N),
        tokens::STYLE_BASE + (h * 9 + 4).rem_euclid(tokens::STYLE_N),
    ]
}

/// Serving report.
pub struct ServeReport {
    pub requests: usize,
    pub batches: usize,
    pub new_tokens_per_request: usize,
    pub batch_latency: LatencyStats,
    pub request_latency: LatencyStats,
    pub tokens_per_sec: f64,
    /// Fraction of generated signature tokens matching the SFT style.
    pub style_adherence: f64,
    pub completions: Vec<Vec<i32>>,
}

/// Run the serving workload: batches of `fwd.batch()` requests, greedy
/// decoding `new_tokens` tokens each.
pub fn serve(
    fwd: &dyn ForwardFn,
    requests: &[Request],
    new_tokens: usize,
) -> Result<ServeReport> {
    let b = fwd.batch();
    let seq = fwd.seq_len();
    let vocab = fwd.vocab();
    let mut batch_latency = LatencyStats::default();
    let mut request_latency = LatencyStats::default();
    let mut completions = Vec::with_capacity(requests.len());
    let mut sig_match = 0usize;
    let mut sig_total = 0usize;
    let t_all = std::time::Instant::now();
    let dummy = Params::new();

    for chunk in requests.chunks(b) {
        let t_batch = std::time::Instant::now();
        // tokens buffer [b, seq]; pad short batches by repeating slot 0
        let mut buf = vec![tokens::PAD; b * seq];
        let mut cursors = vec![0usize; b];
        for (j, req) in chunk.iter().enumerate() {
            buf[j * seq..j * seq + req.prompt.len()].copy_from_slice(&req.prompt);
            cursors[j] = req.prompt.len();
        }
        for j in chunk.len()..b {
            let len = chunk[0].prompt.len();
            buf.copy_within(0..len, j * seq);
            cursors[j] = len;
        }

        for _ in 0..new_tokens {
            let logits = fwd.forward(b, &buf, &dummy)?;
            for j in 0..b {
                let cur = cursors[j];
                if cur >= seq {
                    continue;
                }
                // prediction made at position cur-1 selects token at cur
                let row = &logits[(j * seq + cur - 1) * vocab..(j * seq + cur) * vocab];
                let mut best = 0usize;
                for v in 1..vocab {
                    if row[v] > row[best] {
                        best = v;
                    }
                }
                buf[j * seq + cur] = best as i32;
                cursors[j] = cur + 1;
            }
        }

        let batch_ms = t_batch.elapsed().as_secs_f64() * 1e3;
        batch_latency.record(batch_ms);
        for (j, req) in chunk.iter().enumerate() {
            request_latency.record(batch_ms); // synchronous batch: shared latency
            let gen: Vec<i32> = buf
                [j * seq + req.prompt.len()..(j * seq + req.prompt.len() + new_tokens).min((j + 1) * seq)]
                .to_vec();
            let want = expected_signature(&req.prompt);
            for (g, w) in gen.iter().take(3).zip(want.iter()) {
                sig_total += 1;
                if g == w {
                    sig_match += 1;
                }
            }
            completions.push(gen);
        }
    }

    let total_s = t_all.elapsed().as_secs_f64();
    let total_new = requests.len() * new_tokens;
    Ok(ServeReport {
        requests: requests.len(),
        batches: requests.len().div_ceil(b),
        new_tokens_per_request: new_tokens,
        batch_latency,
        request_latency,
        tokens_per_sec: total_new as f64 / total_s,
        style_adherence: if sig_total == 0 {
            0.0
        } else {
            sig_match as f64 / sig_total as f64
        },
        completions,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_generation_shape() {
        let reqs = gen_requests(10, 7);
        assert_eq!(reqs.len(), 10);
        for r in &reqs {
            assert_eq!(r.prompt.len(), 2 + tokens::PROMPT_LEN);
            assert_eq!(r.prompt[0], tokens::BOS);
            assert_eq!(*r.prompt.last().unwrap(), tokens::SEP);
            for &t in &r.prompt[1..=tokens::PROMPT_LEN] {
                assert!((tokens::CONTENT_BASE
                    ..tokens::CONTENT_BASE + tokens::CONTENT_N)
                    .contains(&t));
            }
        }
    }

    #[test]
    fn deterministic_requests() {
        let a = gen_requests(5, 1);
        let b = gen_requests(5, 1);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
        }
    }

    #[test]
    fn expected_signature_in_style_alphabet() {
        for r in gen_requests(20, 3) {
            for t in expected_signature(&r.prompt) {
                assert!((tokens::STYLE_BASE
                    ..tokens::STYLE_BASE + tokens::STYLE_N)
                    .contains(&t));
            }
        }
    }

    /// A mock forward that always predicts the expected signature chain,
    /// exercising the decode loop without PJRT.
    struct MockForward {
        batch: usize,
        seq: usize,
        vocab: usize,
    }

    impl ForwardFn for MockForward {
        fn forward(&self, batch: usize, toks: &[i32], _p: &Params) -> Result<Vec<f32>> {
            let mut logits = vec![0.0f32; batch * self.seq * self.vocab];
            for j in 0..batch {
                for t in 0..self.seq {
                    // find current end: predict SEP-following signature
                    let prompt = &toks[j * self.seq..j * self.seq + 14];
                    let want = expected_signature(prompt);
                    // position 13 = SEP: predict want[0]; 14 -> want[1]; 15 -> want[2]
                    let target = match t {
                        13 => want[0],
                        14 => want[1],
                        15 => want[2],
                        _ => tokens::EOS,
                    };
                    logits[(j * self.seq + t) * self.vocab + target as usize] = 1.0;
                }
            }
            Ok(logits)
        }

        fn vocab(&self) -> usize {
            self.vocab
        }

        fn seq_len(&self) -> usize {
            self.seq
        }

        fn batch(&self) -> usize {
            self.batch
        }
    }

    #[test]
    fn serve_loop_decodes_and_scores_style() {
        let fwd = MockForward { batch: 4, seq: 32, vocab: 64 };
        let reqs = gen_requests(6, 9);
        let rep = serve(&fwd, &reqs, 3).unwrap();
        assert_eq!(rep.requests, 6);
        assert_eq!(rep.batches, 2);
        assert_eq!(rep.completions.len(), 6);
        // the mock always emits the right signature
        assert!((rep.style_adherence - 1.0).abs() < 1e-12);
        assert!(rep.tokens_per_sec > 0.0);
    }
}
