//! Continuous-batching serving over the (optionally quantized-resident)
//! model.
//!
//! The scheduler owns a fixed set of decode **slots**. Requests queue for
//! admission, join the active batch the moment a slot frees up, and leave
//! the batch the moment they finish — a long request never holds short
//! ones hostage, and latency percentiles are **per request** (admission →
//! completion), not shared across a lock-stepped batch.
//!
//! Each scheduler tick fans the active slots out across
//! [`ServeConfig::workers`] threads ([`par_map_mut`]): a slot in its
//! prefill phase consumes the next [`ServeConfig::prefill_chunk`] prompt
//! tokens in one batched forward ([`TokenDecoder::prefill`] — bulk KV
//! writes, no logits), and a slot in its decode phase consumes one token
//! through its own incremental session (per-layer KV cache, O(t) per
//! token). Prefill is interleaved with running decodes tick-by-tick, so a
//! long prompt cannot head-of-line-block the batch. Workers only touch
//! their own slots' sessions, and the coordinator merges results in fixed
//! slot order — completions, latency stats, and telemetry count-metrics
//! are **bitwise-identical for any worker count** (the same contract the
//! tiled sweep honors).
//!
//! The pre-refactor full-reforward loop survives as
//! [`serve_reforward`]: it re-runs the whole-sequence forward for every
//! generated token (O(seq²) per token) and is kept as the PJRT path and
//! the bench baseline the incremental scheduler is measured against.
//!
//! Workload: styled-completion requests mirroring the corpus — a pattern
//! prompt plus SEP; the server greedily decodes the style signature and
//! continuation.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::eval::decode::TokenDecoder;
use crate::eval::ForwardFn;
use crate::util::rng::XorShift;
use crate::util::telemetry::{self, Snapshot};
use crate::util::threadpool::par_map_mut;
use crate::util::timer::LatencyStats;

/// Token constants mirroring `python/compile/corpus.py`.
pub mod tokens {
    pub const PAD: i32 = 0;
    pub const BOS: i32 = 1;
    pub const EOS: i32 = 2;
    pub const SEP: i32 = 3;
    pub const CONTENT_BASE: i32 = 4;
    pub const CONTENT_N: i32 = 44;
    pub const STYLE_BASE: i32 = 48;
    pub const STYLE_N: i32 = 16;
    pub const PROMPT_LEN: usize = 12;
}

/// One generation request: a prompt prefix (BOS + body + SEP).
#[derive(Clone, Debug)]
pub struct Request {
    pub prompt: Vec<i32>,
}

/// Deterministic request generator (stride patterns, like the corpus).
pub fn gen_requests(n: usize, seed: u64) -> Vec<Request> {
    let mut rng = XorShift::new(seed);
    (0..n)
        .map(|_| {
            let s = rng.below(tokens::CONTENT_N as usize) as i32;
            let d = 1 + rng.below(7) as i32;
            let mut prompt = vec![tokens::BOS];
            for i in 0..tokens::PROMPT_LEN as i32 {
                prompt.push(tokens::CONTENT_BASE + (s + i * d) % tokens::CONTENT_N);
            }
            prompt.push(tokens::SEP);
            Request { prompt }
        })
        .collect()
}

/// Expected variant-1 style signature for a stride prompt (used by the
/// serving example to report style adherence of generated tokens).
pub fn expected_signature(prompt: &[i32]) -> [i32; 3] {
    let b0 = prompt[1] - tokens::CONTENT_BASE;
    let b1 = prompt[2] - tokens::CONTENT_BASE;
    let h = (b0 * 3 + b1 * 7).rem_euclid(tokens::STYLE_N);
    [
        tokens::STYLE_BASE + h,
        tokens::STYLE_BASE + (h * 7 + 2).rem_euclid(tokens::STYLE_N),
        tokens::STYLE_BASE + (h * 9 + 4).rem_euclid(tokens::STYLE_N),
    ]
}

/// Scheduler configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Concurrent decode slots (the continuous batch width).
    pub slots: usize,
    /// Greedy tokens to decode per request (capped by the position table).
    pub new_tokens: usize,
    /// Per-request deadline in milliseconds, measured admission ->
    /// completion. A request past its deadline is evicted at the next
    /// tick boundary with whatever it generated so far (`None` = no
    /// deadline).
    pub deadline_ms: Option<f64>,
    /// Admission-control budget: the wait queue holds at most this many
    /// requests beyond the `slots` in flight; arrivals past that are
    /// shed up front instead of queueing unboundedly (`None` = admit
    /// everything).
    pub queue_budget: Option<usize>,
    /// Worker threads the tick fans active slots out over. `0` and `1`
    /// both mean serial (no threads spawned). Completions and telemetry
    /// count-metrics are bitwise-identical for any value.
    pub workers: usize,
    /// Max prompt tokens one prefill tick consumes per slot; `0` means
    /// the whole remaining prompt in one chunk. Smaller chunks trade
    /// prefill throughput for decode latency of the already-running
    /// slots (head-of-line fairness).
    pub prefill_chunk: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            slots: 4,
            new_tokens: 16,
            deadline_ms: None,
            queue_budget: None,
            workers: 1,
            prefill_chunk: 0,
        }
    }
}

/// Serving report.
pub struct ServeReport {
    pub requests: usize,
    pub slots: usize,
    /// Effective tick worker threads (1 for the serial/reforward paths).
    pub workers: usize,
    pub new_tokens_per_request: usize,
    /// Scheduler ticks (continuous path) or forward batches (reforward).
    pub steps: usize,
    /// Wall time of one scheduler tick / one reforward batch.
    pub step_latency: LatencyStats,
    /// Per-request latency, admission → completion.
    pub request_latency: LatencyStats,
    pub tokens_per_sec: f64,
    /// Fraction of generated signature tokens matching the SFT style.
    pub style_adherence: f64,
    pub completions: Vec<Vec<i32>>,
    /// Bytes the model parameters occupy resident while serving.
    pub resident_param_bytes: usize,
    /// High-water mark of simultaneously active slots.
    pub peak_active_slots: usize,
    /// Requests rejected at admission (queue over budget). Their
    /// completions stay empty.
    pub shed: usize,
    /// Requests evicted past their deadline (partial completions kept).
    pub timed_out: usize,
    /// Requests dropped because their decode step returned an error or
    /// panicked; the failure is contained to the slot.
    pub errored: usize,
    /// End-of-run view of the run's telemetry registry (prefill/decode/
    /// queue-wait histograms, shed/evict counters, occupancy gauges).
    /// Empty when no telemetry context was installed.
    pub telemetry: Snapshot,
}

fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    for v in 1..row.len() {
        if row[v] > row[best] {
            best = v;
        }
    }
    best
}

/// Where an active slot is in its lifecycle: still consuming prompt
/// tokens (chunk by chunk), or generating.
#[derive(Clone, Copy)]
enum Phase {
    Prefill { consumed: usize },
    Decode,
}

/// What one slot's tick produced, returned from the worker to the
/// coordinator, which applies all bookkeeping in fixed slot order.
enum TickOutcome {
    Prefilled,
    Decoded(Vec<f32>),
    Failed,
}

struct Active<S> {
    idx: usize,
    session: S,
    phase: Phase,
    next_input: i32,
    generated: Vec<i32>,
    budget: usize,
    admitted: Instant,
}

/// Run the continuous-batching scheduler: up to `cfg.slots` requests
/// prefill/decode concurrently across `cfg.workers` threads, each through
/// its own incremental session; a finishing request frees its slot for
/// the next queued one immediately.
///
/// Determinism contract: workers only mutate their own slot's session,
/// and every cross-slot effect (argmax, completion bookkeeping, latency
/// stats) is applied by the coordinator in fixed slot order — the report's
/// completions and telemetry count-metrics are bitwise-identical for any
/// `cfg.workers` value.
pub fn serve<D>(dec: &D, requests: &[Request], cfg: &ServeConfig) -> Result<ServeReport>
where
    D: TokenDecoder + Sync,
    D::Session: Send,
{
    assert!(cfg.slots > 0, "need at least one decode slot");
    let max_pos = dec.max_positions();
    // validate the whole workload up front: a malformed request must
    // fail fast, not abort the run after other requests already finished
    for (idx, r) in requests.iter().enumerate() {
        if r.prompt.is_empty() {
            bail!("request {idx}: empty prompt");
        }
        if r.prompt.len() > max_pos {
            bail!(
                "request {idx}: prompt len {} exceeds the model's \
                 position table ({max_pos})",
                r.prompt.len()
            );
        }
    }
    // admission control: the wait queue caps at slots + queue_budget;
    // everything past that is shed immediately rather than queued into
    // an unbounded backlog (overload degrades by refusing work, not by
    // blowing every deadline at once)
    let cap = cfg.queue_budget.map(|b| cfg.slots.saturating_add(b));
    let mut shed = 0usize;
    let mut queue: VecDeque<usize> = VecDeque::new();
    for idx in 0..requests.len() {
        match cap {
            Some(c) if queue.len() >= c => shed += 1,
            _ => queue.push_back(idx),
        }
    }

    // telemetry handles hoisted out of the scheduler loop: every update
    // below is one relaxed atomic op (or a no-op without a context)
    let tel = telemetry::current();
    let prefill_hist = tel.histogram("serve.prefill.seconds");
    let decode_hist = tel.histogram("serve.decode.seconds");
    let queue_hist = tel.histogram("serve.queue_wait.seconds");
    let shed_counter = tel.counter("serve.shed");
    let timed_out_counter = tel.counter("serve.timed_out");
    let errored_counter = tel.counter("serve.errored");
    let completed_counter = tel.counter("serve.completed");
    let prefill_chunks_counter = tel.counter("serve.prefill.chunks");
    let occupancy_gauge = tel.gauge("serve.slot_occupancy");
    tel.gauge("serve.resident_param_bytes")
        .set(dec.resident_param_bytes() as f64);
    // gauge, not a label on the count metrics: a per-worker label would
    // break the counter-map determinism contract across worker counts
    let workers = cfg.workers.max(1);
    tel.gauge("serve.workers").set(workers as f64);
    shed_counter.add(shed as u64);
    let mut slots: Vec<Option<Active<D::Session>>> = Vec::new();
    slots.resize_with(cfg.slots, || None);
    let mut completions: Vec<Vec<i32>> = vec![Vec::new(); requests.len()];
    let mut step_latency = LatencyStats::default();
    let mut request_latency = LatencyStats::default();
    let mut sig_match = 0usize;
    let mut sig_total = 0usize;
    let mut total_generated = 0usize;
    let mut steps = 0usize;
    let mut peak_active = 0usize;
    let mut timed_out = 0usize;
    let mut errored = 0usize;
    let t_all = Instant::now();

    // per-slot fault isolation: a decoder step/prefill that errors or
    // panics takes down its own request, never the batch
    let step_isolated = |session: &mut D::Session, token: i32| -> Result<Vec<f32>> {
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            dec.step(session, token)
        }))
        .unwrap_or_else(|_| Err(anyhow::anyhow!("decoder panicked during step")))
    };
    let prefill_isolated = |session: &mut D::Session, toks: &[i32]| -> Result<()> {
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            dec.prefill(session, toks)
        }))
        .unwrap_or_else(|_| Err(anyhow::anyhow!("decoder panicked during prefill")))
    };

    // one slot's unit of work for one tick, run on whichever worker
    // claims the slot: a prefill chunk or a decode step. Only this slot's
    // own state is touched; cross-slot bookkeeping stays on the
    // coordinator. Telemetry handles are lock-free and their count
    // updates commute, so recording from workers preserves determinism.
    let tick_slot = |a: &mut Active<D::Session>| -> TickOutcome {
        match a.phase {
            Phase::Prefill { consumed } => {
                let prompt = &requests[a.idx].prompt;
                // the final prompt token is not prefilled: it becomes the
                // first decode input (its logits are the first prediction)
                let end = prompt.len() - 1;
                let len = match cfg.prefill_chunk {
                    0 => end - consumed,
                    c => c.min(end - consumed),
                };
                let ok = {
                    let _t = prefill_hist.start_timer();
                    prefill_isolated(&mut a.session, &prompt[consumed..consumed + len])
                        .is_ok()
                };
                if !ok {
                    return TickOutcome::Failed;
                }
                prefill_chunks_counter.incr();
                let consumed = consumed + len;
                a.phase = if consumed >= end {
                    Phase::Decode
                } else {
                    Phase::Prefill { consumed }
                };
                TickOutcome::Prefilled
            }
            Phase::Decode => {
                let stepped = {
                    let _t = decode_hist.start_timer();
                    step_isolated(&mut a.session, a.next_input)
                };
                match stepped {
                    Ok(logits) => TickOutcome::Decoded(logits),
                    Err(_) => TickOutcome::Failed,
                }
            }
        }
    };

    let mut complete = |a: Active<D::Session>,
                        completions: &mut Vec<Vec<i32>>,
                        request_latency: &mut LatencyStats,
                        sig_match: &mut usize,
                        sig_total: &mut usize| {
        request_latency.record(a.admitted.elapsed().as_secs_f64() * 1e3);
        let want = expected_signature(&requests[a.idx].prompt);
        for (g, w) in a.generated.iter().take(3).zip(want.iter()) {
            *sig_total += 1;
            if g == w {
                *sig_match += 1;
            }
        }
        completions[a.idx] = a.generated;
    };

    loop {
        // admission: fill every free slot from the queue. Admission only
        // allocates the session and occupies the slot — the prompt is
        // consumed chunk-by-chunk inside ticks (interleaved with running
        // decodes), so a long prompt cannot head-of-line-block the batch.
        for slot in slots.iter_mut() {
            if slot.is_some() {
                continue;
            }
            while let Some(idx) = queue.pop_front() {
                let prompt = &requests[idx].prompt;
                // the admission timestamp precedes the prefill phase so
                // the per-request latency really is admission ->
                // completion (prompt consumption included)
                let admitted = Instant::now();
                queue_hist.observe(admitted.duration_since(t_all).as_secs_f64());
                // room left in the position table caps the generation
                // budget (feeding the token at position p needs p < max_pos)
                let budget = cfg.new_tokens.min(max_pos - prompt.len() + 1);
                let a = Active {
                    idx,
                    session: dec.start(),
                    // a 1-token prompt has nothing to prefill: the lone
                    // token is already the first decode input
                    phase: if prompt.len() > 1 {
                        Phase::Prefill { consumed: 0 }
                    } else {
                        Phase::Decode
                    },
                    next_input: *prompt.last().expect("validated non-empty"),
                    generated: Vec::with_capacity(budget),
                    budget,
                    admitted,
                };
                if budget == 0 {
                    completed_counter.incr();
                    complete(
                        a,
                        &mut completions,
                        &mut request_latency,
                        &mut sig_match,
                        &mut sig_total,
                    );
                } else {
                    *slot = Some(a);
                    break;
                }
            }
        }

        let active = slots.iter().filter(|s| s.is_some()).count();
        occupancy_gauge.set(active as f64);
        peak_active = peak_active.max(active);
        if active == 0 {
            if queue.is_empty() {
                break;
            }
            continue; // zero-budget admissions drained the slots; refill
        }

        // one tick: every active slot does one unit of work (a prefill
        // chunk or a decode step). Deadline eviction happens first, at
        // the tick boundary (the request keeps what it generated so
        // far); then the surviving slots fan out across the workers and
        // the coordinator merges outcomes in fixed slot order.
        let t_tick = Instant::now();
        for slot in slots.iter_mut() {
            let Some(a) = slot.as_mut() else { continue };
            let expired = cfg
                .deadline_ms
                .is_some_and(|d| a.admitted.elapsed().as_secs_f64() * 1e3 > d);
            if expired {
                let late = slot.take().expect("checked");
                timed_out += 1;
                timed_out_counter.incr();
                complete(
                    late,
                    &mut completions,
                    &mut request_latency,
                    &mut sig_match,
                    &mut sig_total,
                );
            }
        }

        let mut work: Vec<&mut Active<D::Session>> = Vec::with_capacity(cfg.slots);
        let mut work_slots: Vec<usize> = Vec::with_capacity(cfg.slots);
        for (i, slot) in slots.iter_mut().enumerate() {
            if let Some(a) = slot.as_mut() {
                work_slots.push(i);
                work.push(a);
            }
        }
        if work.is_empty() {
            // every active slot was deadline-evicted this tick
            step_latency.record(t_tick.elapsed().as_secs_f64() * 1e3);
            steps += 1;
            continue;
        }
        let outcomes = par_map_mut(workers, &mut work, |a| tick_slot(a));
        drop(work);

        // merge in fixed slot order: everything below is coordinator-side
        // and independent of which worker ran which slot
        for (&slot_i, outcome) in work_slots.iter().zip(outcomes) {
            match outcome {
                TickOutcome::Prefilled => {}
                TickOutcome::Failed => {
                    slots[slot_i] = None;
                    errored += 1;
                    errored_counter.incr();
                }
                TickOutcome::Decoded(logits) => {
                    let a = slots[slot_i].as_mut().expect("worked slot is active");
                    let best = argmax(&logits) as i32;
                    a.generated.push(best);
                    a.next_input = best;
                    total_generated += 1;
                    if a.generated.len() >= a.budget {
                        let done = slots[slot_i].take().expect("checked");
                        completed_counter.incr();
                        complete(
                            done,
                            &mut completions,
                            &mut request_latency,
                            &mut sig_match,
                            &mut sig_total,
                        );
                    }
                }
            }
        }
        step_latency.record(t_tick.elapsed().as_secs_f64() * 1e3);
        steps += 1;
    }

    let total_s = t_all.elapsed().as_secs_f64();
    Ok(ServeReport {
        requests: requests.len(),
        slots: cfg.slots,
        workers,
        new_tokens_per_request: cfg.new_tokens,
        steps,
        step_latency,
        request_latency,
        tokens_per_sec: total_generated as f64 / total_s,
        style_adherence: if sig_total == 0 {
            0.0
        } else {
            sig_match as f64 / sig_total as f64
        },
        completions,
        resident_param_bytes: dec.resident_param_bytes(),
        peak_active_slots: peak_active,
        shed,
        timed_out,
        errored,
        telemetry: tel.snapshot(),
    })
}

/// The pre-refactor serving loop: fixed batches of `fwd.batch()` requests,
/// each generated token re-running the **whole-sequence** forward. Kept as
/// the PJRT serving path (the AOT graph is full-sequence) and as the
/// baseline the incremental scheduler is benchmarked against.
/// `resident_param_bytes` is reported as given (the ForwardFn trait does
/// not expose its parameter storage).
pub fn serve_reforward(
    fwd: &dyn ForwardFn,
    requests: &[Request],
    new_tokens: usize,
    resident_param_bytes: usize,
) -> Result<ServeReport> {
    let b = fwd.batch();
    let seq = fwd.seq_len();
    let vocab = fwd.vocab();
    let mut step_latency = LatencyStats::default();
    let mut request_latency = LatencyStats::default();
    let mut completions = Vec::with_capacity(requests.len());
    let mut sig_match = 0usize;
    let mut sig_total = 0usize;
    let mut steps = 0usize;
    let t_all = Instant::now();

    for chunk in requests.chunks(b) {
        let t_batch = Instant::now();
        // tokens buffer [b, seq]; pad short batches by repeating slot 0
        let mut buf = vec![tokens::PAD; b * seq];
        let mut cursors = vec![0usize; b];
        for (j, req) in chunk.iter().enumerate() {
            buf[j * seq..j * seq + req.prompt.len()].copy_from_slice(&req.prompt);
            cursors[j] = req.prompt.len();
        }
        for j in chunk.len()..b {
            let len = chunk[0].prompt.len();
            buf.copy_within(0..len, j * seq);
            cursors[j] = len;
        }

        for _ in 0..new_tokens {
            let logits = fwd.forward(b, &buf)?;
            for j in 0..b {
                let cur = cursors[j];
                if cur >= seq {
                    continue;
                }
                // prediction made at position cur-1 selects token at cur
                let row = &logits[(j * seq + cur - 1) * vocab..(j * seq + cur) * vocab];
                buf[j * seq + cur] = argmax(row) as i32;
                cursors[j] = cur + 1;
            }
        }
        steps += 1;

        let batch_ms = t_batch.elapsed().as_secs_f64() * 1e3;
        step_latency.record(batch_ms);
        for (j, req) in chunk.iter().enumerate() {
            request_latency.record(batch_ms); // synchronous batch: shared latency
            let gen: Vec<i32> = buf[j * seq + req.prompt.len()
                ..(j * seq + req.prompt.len() + new_tokens).min((j + 1) * seq)]
                .to_vec();
            let want = expected_signature(&req.prompt);
            for (g, w) in gen.iter().take(3).zip(want.iter()) {
                sig_total += 1;
                if g == w {
                    sig_match += 1;
                }
            }
            completions.push(gen);
        }
    }

    let total_s = t_all.elapsed().as_secs_f64();
    let total_new = requests.len() * new_tokens;
    Ok(ServeReport {
        requests: requests.len(),
        slots: b,
        workers: 1,
        new_tokens_per_request: new_tokens,
        steps,
        step_latency,
        request_latency,
        tokens_per_sec: total_new as f64 / total_s,
        style_adherence: if sig_total == 0 {
            0.0
        } else {
            sig_match as f64 / sig_total as f64
        },
        completions,
        resident_param_bytes,
        peak_active_slots: b,
        shed: 0,
        timed_out: 0,
        errored: 0,
        telemetry: telemetry::current().snapshot(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_generation_shape() {
        let reqs = gen_requests(10, 7);
        assert_eq!(reqs.len(), 10);
        for r in &reqs {
            assert_eq!(r.prompt.len(), 2 + tokens::PROMPT_LEN);
            assert_eq!(r.prompt[0], tokens::BOS);
            assert_eq!(*r.prompt.last().unwrap(), tokens::SEP);
            for &t in &r.prompt[1..=tokens::PROMPT_LEN] {
                assert!((tokens::CONTENT_BASE
                    ..tokens::CONTENT_BASE + tokens::CONTENT_N)
                    .contains(&t));
            }
        }
    }

    #[test]
    fn deterministic_requests() {
        let a = gen_requests(5, 1);
        let b = gen_requests(5, 1);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
        }
    }

    #[test]
    fn expected_signature_in_style_alphabet() {
        for r in gen_requests(20, 3) {
            for t in expected_signature(&r.prompt) {
                assert!((tokens::STYLE_BASE
                    ..tokens::STYLE_BASE + tokens::STYLE_N)
                    .contains(&t));
            }
        }
    }

    /// A mock incremental decoder that always predicts the expected
    /// signature chain, exercising the scheduler without a model: the
    /// session accumulates consumed tokens, and once the prompt (14
    /// tokens) is in, predictions follow the signature.
    struct MockDecoder {
        vocab: usize,
        max_pos: usize,
    }

    impl TokenDecoder for MockDecoder {
        type Session = Vec<i32>;

        fn start(&self) -> Vec<i32> {
            Vec::new()
        }

        fn step(&self, s: &mut Vec<i32>, token: i32) -> Result<Vec<f32>> {
            assert!(s.len() < self.max_pos, "scheduler overran the cursor");
            s.push(token);
            let t = s.len() - 1; // position just consumed
            let mut logits = vec![0.0f32; self.vocab];
            let target = if s.len() >= 14 {
                let want = expected_signature(&s[..14]);
                match t {
                    13 => want[0],
                    14 => want[1],
                    15 => want[2],
                    _ => tokens::EOS,
                }
            } else {
                tokens::EOS
            };
            logits[target as usize] = 1.0;
            Ok(logits)
        }

        fn max_positions(&self) -> usize {
            self.max_pos
        }

        fn resident_param_bytes(&self) -> usize {
            1234
        }
    }

    #[test]
    fn scheduler_decodes_and_scores_style() {
        let dec = MockDecoder { vocab: 64, max_pos: 32 };
        let reqs = gen_requests(6, 9);
        let cfg = ServeConfig { slots: 4, new_tokens: 3, ..Default::default() };
        let rep = serve(&dec, &reqs, &cfg).unwrap();
        assert_eq!(rep.requests, 6);
        assert_eq!((rep.shed, rep.timed_out, rep.errored), (0, 0, 0));
        assert_eq!(rep.completions.len(), 6);
        for (req, gen) in reqs.iter().zip(&rep.completions) {
            assert_eq!(gen.as_slice(), &expected_signature(&req.prompt));
        }
        assert!((rep.style_adherence - 1.0).abs() < 1e-12);
        assert!(rep.tokens_per_sec > 0.0);
        // latency is per-request, not per-batch
        assert_eq!(rep.request_latency.count(), 6);
        assert!(rep.peak_active_slots <= 4);
        assert_eq!(rep.resident_param_bytes, 1234);
    }

    #[test]
    fn slots_refill_as_requests_finish() {
        // 7 requests through 2 slots: everything completes, and the
        // scheduler never has more than 2 active
        let dec = MockDecoder { vocab: 64, max_pos: 32 };
        let reqs = gen_requests(7, 11);
        let cfg = ServeConfig { slots: 2, new_tokens: 4, ..Default::default() };
        let rep = serve(&dec, &reqs, &cfg).unwrap();
        assert_eq!(rep.request_latency.count(), 7);
        assert!(rep.peak_active_slots <= 2);
        for gen in &rep.completions {
            assert_eq!(gen.len(), 4);
        }
        // 7 requests x 4 tokens through 2 slots needs >= 14 ticks
        assert!(rep.steps >= 14, "steps = {}", rep.steps);
    }

    #[test]
    fn oversized_prompt_is_an_error_not_a_panic() {
        // a model whose position table cannot even hold the prompt must
        // surface a clean error through the Result API
        let dec = MockDecoder { vocab: 64, max_pos: 10 };
        let reqs = gen_requests(2, 5); // 14-token prompts
        let err = serve(
            &dec,
            &reqs,
            &ServeConfig { slots: 2, new_tokens: 2, ..Default::default() },
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("position table"), "{err:#}");

        let empty = vec![Request { prompt: Vec::new() }];
        let err = serve(
            &dec,
            &empty,
            &ServeConfig { slots: 1, new_tokens: 1, ..Default::default() },
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("empty prompt"), "{err:#}");
    }

    #[test]
    fn generation_budget_respects_position_table() {
        // prompt is 14 tokens; a 15-position table leaves room to feed
        // exactly positions 13 and 14 -> 2 generated tokens
        let dec = MockDecoder { vocab: 64, max_pos: 15 };
        let reqs = gen_requests(3, 13);
        let cfg = ServeConfig { slots: 2, new_tokens: 8, ..Default::default() };
        let rep = serve(&dec, &reqs, &cfg).unwrap();
        for gen in &rep.completions {
            assert_eq!(gen.len(), 2);
        }
        assert_eq!(rep.request_latency.count(), 3);
    }

    /// Full-reforward mock (old-style ForwardFn) for the baseline loop.
    struct MockForward {
        batch: usize,
        seq: usize,
        vocab: usize,
    }

    impl ForwardFn for MockForward {
        fn forward(&self, batch: usize, toks: &[i32]) -> Result<Vec<f32>> {
            let mut logits = vec![0.0f32; batch * self.seq * self.vocab];
            for j in 0..batch {
                for t in 0..self.seq {
                    let prompt = &toks[j * self.seq..j * self.seq + 14];
                    let want = expected_signature(prompt);
                    let target = match t {
                        13 => want[0],
                        14 => want[1],
                        15 => want[2],
                        _ => tokens::EOS,
                    };
                    logits[(j * self.seq + t) * self.vocab + target as usize] = 1.0;
                }
            }
            Ok(logits)
        }

        fn vocab(&self) -> usize {
            self.vocab
        }

        fn seq_len(&self) -> usize {
            self.seq
        }

        fn batch(&self) -> usize {
            self.batch
        }
    }

    #[test]
    fn reforward_baseline_still_decodes() {
        let fwd = MockForward { batch: 4, seq: 32, vocab: 64 };
        let reqs = gen_requests(6, 9);
        let rep = serve_reforward(&fwd, &reqs, 3, 4096).unwrap();
        assert_eq!(rep.requests, 6);
        assert_eq!(rep.steps, 2); // two fixed batches of 4
        assert_eq!(rep.completions.len(), 6);
        assert!((rep.style_adherence - 1.0).abs() < 1e-12);
        assert_eq!(rep.resident_param_bytes, 4096);
    }

    #[test]
    fn scheduler_and_reforward_agree_on_completions() {
        // same mock policy on both paths -> identical greedy completions
        let dec = MockDecoder { vocab: 64, max_pos: 32 };
        let fwd = MockForward { batch: 4, seq: 32, vocab: 64 };
        let reqs = gen_requests(9, 17);
        let a = serve(
            &dec,
            &reqs,
            &ServeConfig { slots: 3, new_tokens: 3, ..Default::default() },
        )
        .unwrap();
        let b = serve_reforward(&fwd, &reqs, 3, 0).unwrap();
        assert_eq!(a.completions, b.completions);
    }

    #[test]
    fn overload_sheds_requests_past_the_queue_budget() {
        // 10 requests, 2 slots, wait queue of 3: the first 5 serve
        // normally and bitwise-correctly, the back 5 are refused up front
        let dec = MockDecoder { vocab: 64, max_pos: 32 };
        let reqs = gen_requests(10, 21);
        let cfg = ServeConfig {
            slots: 2,
            new_tokens: 3,
            queue_budget: Some(3),
            ..Default::default()
        };
        let rep = serve(&dec, &reqs, &cfg).unwrap();
        assert_eq!(rep.shed, 5);
        assert_eq!(rep.timed_out, 0);
        assert_eq!(rep.errored, 0);
        assert_eq!(rep.request_latency.count(), 5);
        for (req, gen) in reqs.iter().take(5).zip(rep.completions.iter().take(5)) {
            assert_eq!(gen.as_slice(), &expected_signature(&req.prompt));
        }
        for gen in rep.completions.iter().skip(5) {
            assert!(gen.is_empty(), "shed requests must not decode");
        }
    }

    #[test]
    fn expired_deadline_evicts_at_the_tick_boundary() {
        // an already-expired deadline evicts every request on its first
        // tick, before it generates a token; the run still terminates
        // and every eviction is counted + latency-recorded
        let dec = MockDecoder { vocab: 64, max_pos: 32 };
        let reqs = gen_requests(4, 31);
        let cfg = ServeConfig {
            slots: 2,
            new_tokens: 4,
            deadline_ms: Some(0.0),
            ..Default::default()
        };
        let rep = serve(&dec, &reqs, &cfg).unwrap();
        assert_eq!(rep.timed_out, 4);
        assert_eq!(rep.shed, 0);
        assert_eq!(rep.request_latency.count(), 4);
        for gen in &rep.completions {
            assert!(gen.is_empty(), "expired requests must keep only partial output");
        }
    }

    /// Decoder that panics the moment it is fed a poison token; wraps the
    /// well-behaved mock for everything else.
    struct PanickyDecoder {
        inner: MockDecoder,
        poison: i32,
    }

    impl TokenDecoder for PanickyDecoder {
        type Session = Vec<i32>;

        fn start(&self) -> Vec<i32> {
            self.inner.start()
        }

        fn step(&self, s: &mut Vec<i32>, token: i32) -> Result<Vec<f32>> {
            if token == self.poison {
                panic!("poison token fed to decoder");
            }
            self.inner.step(s, token)
        }

        fn max_positions(&self) -> usize {
            self.inner.max_positions()
        }

        fn resident_param_bytes(&self) -> usize {
            self.inner.resident_param_bytes()
        }
    }

    #[test]
    fn completions_identical_for_any_worker_count_and_chunk() {
        // the core determinism contract: slot-order merge makes the
        // report independent of both the worker count and how the prompt
        // is chunked into prefill ticks
        let reqs = gen_requests(9, 33);
        let mut reference: Option<Vec<Vec<i32>>> = None;
        for workers in [1, 2, 4, 8] {
            for chunk in [0, 1, 5, 16] {
                let dec = MockDecoder { vocab: 64, max_pos: 32 };
                let cfg = ServeConfig {
                    slots: 3,
                    new_tokens: 3,
                    workers,
                    prefill_chunk: chunk,
                    ..Default::default()
                };
                let rep = serve(&dec, &reqs, &cfg).unwrap();
                assert_eq!(rep.workers, workers.max(1));
                assert_eq!((rep.shed, rep.timed_out, rep.errored), (0, 0, 0));
                match &reference {
                    None => reference = Some(rep.completions),
                    Some(want) => assert_eq!(
                        &rep.completions, want,
                        "workers={workers} chunk={chunk}"
                    ),
                }
            }
        }
    }

    #[test]
    fn chunked_prefill_spreads_the_prompt_over_ticks() {
        // 13 prefill tokens: chunk=0 consumes them in 1 tick, chunk=3
        // needs ceil(13/3)=5 ticks — same completions, more ticks
        let reqs = gen_requests(2, 39);
        let run = |chunk: usize| {
            let dec = MockDecoder { vocab: 64, max_pos: 32 };
            serve(
                &dec,
                &reqs,
                &ServeConfig {
                    slots: 2,
                    new_tokens: 3,
                    prefill_chunk: chunk,
                    ..Default::default()
                },
            )
            .unwrap()
        };
        let whole = run(0);
        let chunked = run(3);
        assert_eq!(whole.completions, chunked.completions);
        // both slots admit on tick 1: whole = 1 prefill + 3 decode ticks,
        // chunked = 5 prefill + 3 decode ticks
        assert_eq!(whole.steps, 4, "steps = {}", whole.steps);
        assert_eq!(chunked.steps, 8, "steps = {}", chunked.steps);
    }

    #[test]
    fn poisoned_request_is_contained_to_its_slot() {
        // one request carries a token that makes the decoder panic; the
        // panic is confined to that slot and every other request decodes
        // to exactly what it would have without the poison
        let dec = PanickyDecoder {
            inner: MockDecoder { vocab: 64, max_pos: 32 },
            poison: tokens::PAD,
        };
        let mut reqs = gen_requests(5, 9);
        reqs[2].prompt[1] = tokens::PAD;
        let cfg = ServeConfig { slots: 2, new_tokens: 3, ..Default::default() };
        let rep = serve(&dec, &reqs, &cfg).unwrap();
        assert_eq!(rep.errored, 1);
        assert!(rep.completions[2].is_empty());
        for (i, (req, gen)) in reqs.iter().zip(&rep.completions).enumerate() {
            if i == 2 {
                continue;
            }
            assert_eq!(gen.as_slice(), &expected_signature(&req.prompt));
        }
    }
}
