//! DTS — Delta Tensor Store reader/writer (Rust side).
//!
//! Binary-compatible with `python/compile/dts.py`; see that file for the
//! on-disk layout. The reader parses the index first and then reads tensor
//! payloads sequentially, so checkpoints stream without being resident
//! twice; the writer is the mirror image, used to persist quantized
//! checkpoints and sidecar scale tensors.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;

const MAGIC: &[u8; 4] = b"DTS1";
const VERSION: u32 = 1;

/// A tensor as stored in a DTS container.
#[derive(Clone, Debug, PartialEq)]
pub enum DtsTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    U8 { shape: Vec<usize>, data: Vec<u8> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl DtsTensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            DtsTensor::F32 { shape, .. }
            | DtsTensor::U8 { shape, .. }
            | DtsTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn dtype_code(&self) -> u8 {
        match self {
            DtsTensor::F32 { .. } => 0,
            DtsTensor::U8 { .. } => 1,
            DtsTensor::I32 { .. } => 2,
        }
    }

    fn nbytes(&self) -> usize {
        match self {
            DtsTensor::F32 { data, .. } => data.len() * 4,
            DtsTensor::U8 { data, .. } => data.len(),
            DtsTensor::I32 { data, .. } => data.len() * 4,
        }
    }
}

/// An in-memory DTS container: ordered tensors + string metadata.
#[derive(Default, Debug)]
pub struct Dts {
    pub meta: BTreeMap<String, String>,
    names: Vec<String>,
    tensors: BTreeMap<String, DtsTensor>,
}

impl Dts {
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert (or replace) a tensor, preserving first-insertion order.
    pub fn insert(&mut self, name: &str, t: DtsTensor) {
        if !self.tensors.contains_key(name) {
            self.names.push(name.to_string());
        }
        self.tensors.insert(name.to_string(), t);
    }

    pub fn insert_f32(&mut self, name: &str, t: &Tensor) {
        self.insert(name, DtsTensor::F32 {
            shape: t.shape().to_vec(),
            data: t.data().to_vec(),
        });
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn get(&self, name: &str) -> Option<&DtsTensor> {
        self.tensors.get(name)
    }

    pub fn contains(&self, name: &str) -> bool {
        self.tensors.contains_key(name)
    }

    /// Fetch an f32 tensor as a `Tensor` (errors on missing or wrong dtype).
    pub fn tensor_f32(&self, name: &str) -> Result<Tensor> {
        match self.get(name) {
            Some(DtsTensor::F32 { shape, data }) => {
                Ok(Tensor::new(shape.clone(), data.clone()))
            }
            Some(other) => bail!("tensor {name:?} has dtype {:?}, wanted f32",
                                 other.dtype_code()),
            None => bail!("tensor {name:?} not found"),
        }
    }

    pub fn tensor_i32(&self, name: &str) -> Result<(Vec<usize>, Vec<i32>)> {
        match self.get(name) {
            Some(DtsTensor::I32 { shape, data }) => Ok((shape.clone(), data.clone())),
            Some(_) => bail!("tensor {name:?} is not i32"),
            None => bail!("tensor {name:?} not found"),
        }
    }

    pub fn tensor_u8(&self, name: &str) -> Result<(Vec<usize>, Vec<u8>)> {
        match self.get(name) {
            Some(DtsTensor::U8 { shape, data }) => Ok((shape.clone(), data.clone())),
            Some(_) => bail!("tensor {name:?} is not u8"),
            None => bail!("tensor {name:?} not found"),
        }
    }

    // -- serialization ----------------------------------------------------

    pub fn read(path: impl AsRef<Path>) -> Result<Dts> {
        let path = path.as_ref();
        let f = File::open(path).with_context(|| format!("open {path:?}"))?;
        let mut r = BufReader::new(f);

        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{path:?}: bad magic {magic:?}");
        }
        let version = read_u32(&mut r)?;
        if version != VERSION {
            bail!("{path:?}: unsupported version {version}");
        }
        let n_meta = read_u32(&mut r)? as usize;
        let n_tensor = read_u32(&mut r)? as usize;

        let mut dts = Dts::new();
        for _ in 0..n_meta {
            let klen = read_u16(&mut r)? as usize;
            let key = read_string(&mut r, klen)?;
            let vlen = read_u32(&mut r)? as usize;
            let val = read_string(&mut r, vlen)?;
            dts.meta.insert(key, val);
        }

        struct Entry {
            name: String,
            dtype: u8,
            shape: Vec<usize>,
            offset: u64,
            nbytes: u64,
        }
        let mut entries = Vec::with_capacity(n_tensor);
        for _ in 0..n_tensor {
            let nlen = read_u16(&mut r)? as usize;
            let name = read_string(&mut r, nlen)?;
            let mut db = [0u8; 2];
            r.read_exact(&mut db)?;
            let (dtype, ndim) = (db[0], db[1] as usize);
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u64(&mut r)? as usize);
            }
            let offset = read_u64(&mut r)?;
            let nbytes = read_u64(&mut r)?;
            entries.push(Entry { name, dtype, shape, offset, nbytes });
        }

        // payload: entries were written sequentially; verify and stream
        let mut cursor = 0u64;
        for e in &entries {
            if e.offset != cursor {
                bail!("{path:?}: non-sequential payload at {:?} \
                       (offset {} expected {cursor})", e.name, e.offset);
            }
            let mut raw = vec![0u8; e.nbytes as usize];
            r.read_exact(&mut raw)
                .with_context(|| format!("payload of {:?}", e.name))?;
            let n: usize = e.shape.iter().product();
            let t = match e.dtype {
                0 => {
                    if raw.len() != n * 4 {
                        bail!("{:?}: f32 payload size mismatch", e.name);
                    }
                    let data = raw
                        .chunks_exact(4)
                        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                        .collect();
                    DtsTensor::F32 { shape: e.shape.clone(), data }
                }
                1 => DtsTensor::U8 { shape: e.shape.clone(), data: raw },
                2 => {
                    let data = raw
                        .chunks_exact(4)
                        .map(|b| i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                        .collect();
                    DtsTensor::I32 { shape: e.shape.clone(), data }
                }
                d => bail!("{:?}: unsupported dtype code {d}", e.name),
            };
            dts.insert(&e.name, t);
            cursor += e.nbytes;
        }
        Ok(dts)
    }

    pub fn write(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let f = File::create(path).with_context(|| format!("create {path:?}"))?;
        let mut w = BufWriter::new(f);

        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&(self.meta.len() as u32).to_le_bytes())?;
        w.write_all(&(self.names.len() as u32).to_le_bytes())?;

        for (k, v) in &self.meta {
            w.write_all(&(k.len() as u16).to_le_bytes())?;
            w.write_all(k.as_bytes())?;
            w.write_all(&(v.len() as u32).to_le_bytes())?;
            w.write_all(v.as_bytes())?;
        }

        let mut offset = 0u64;
        for name in &self.names {
            let t = &self.tensors[name];
            w.write_all(&(name.len() as u16).to_le_bytes())?;
            w.write_all(name.as_bytes())?;
            w.write_all(&[t.dtype_code(), t.shape().len() as u8])?;
            for &d in t.shape() {
                w.write_all(&(d as u64).to_le_bytes())?;
            }
            w.write_all(&offset.to_le_bytes())?;
            w.write_all(&(t.nbytes() as u64).to_le_bytes())?;
            offset += t.nbytes() as u64;
        }

        for name in &self.names {
            match &self.tensors[name] {
                DtsTensor::F32 { data, .. } => {
                    for v in data {
                        w.write_all(&v.to_le_bytes())?;
                    }
                }
                DtsTensor::U8 { data, .. } => w.write_all(data)?,
                DtsTensor::I32 { data, .. } => {
                    for v in data {
                        w.write_all(&v.to_le_bytes())?;
                    }
                }
            }
        }
        w.flush()?;
        Ok(())
    }
}

fn read_u16(r: &mut impl Read) -> Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_string(r: &mut impl Read, len: usize) -> Result<String> {
    let mut b = vec![0u8; len];
    r.read_exact(&mut b)?;
    Ok(String::from_utf8(b)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("daq_test_{name}_{}", std::process::id()));
        p
    }

    #[test]
    fn roundtrip_mixed() {
        let mut d = Dts::new();
        d.meta.insert("kind".into(), "test".into());
        d.insert("w", DtsTensor::F32 {
            shape: vec![2, 3],
            data: vec![1.0, -2.5, 3.25, 0.0, 5.0, -6.125],
        });
        d.insert("codes", DtsTensor::U8 { shape: vec![4], data: vec![0, 127, 128, 255] });
        d.insert("tok", DtsTensor::I32 { shape: vec![2, 2], data: vec![-1, 0, 7, 42] });

        let p = tmpfile("roundtrip");
        d.write(&p).unwrap();
        let d2 = Dts::read(&p).unwrap();
        std::fs::remove_file(&p).unwrap();

        assert_eq!(d2.meta.get("kind").map(|s| s.as_str()), Some("test"));
        assert_eq!(d2.names(), d.names());
        assert_eq!(d2.get("w"), d.get("w"));
        assert_eq!(d2.get("codes"), d.get("codes"));
        assert_eq!(d2.get("tok"), d.get("tok"));
    }

    #[test]
    fn bad_magic_rejected() {
        let p = tmpfile("badmagic");
        std::fs::write(&p, b"NOPE\x01\x00\x00\x00\x00\x00\x00\x00\x00\x00\x00\x00").unwrap();
        let err = Dts::read(&p).unwrap_err().to_string();
        std::fs::remove_file(&p).unwrap();
        assert!(err.contains("bad magic"), "{err}");
    }

    #[test]
    fn missing_tensor_errors() {
        let d = Dts::new();
        assert!(d.tensor_f32("nope").is_err());
    }

    #[test]
    fn dtype_mismatch_errors() {
        let mut d = Dts::new();
        d.insert("codes", DtsTensor::U8 { shape: vec![1], data: vec![1] });
        assert!(d.tensor_f32("codes").is_err());
        assert!(d.tensor_u8("codes").is_ok());
    }

    #[test]
    fn insertion_order_preserved() {
        let mut d = Dts::new();
        for name in ["z", "a", "m"] {
            d.insert(name, DtsTensor::U8 { shape: vec![1], data: vec![0] });
        }
        assert_eq!(d.names(), &["z", "a", "m"]);
        let p = tmpfile("order");
        d.write(&p).unwrap();
        let d2 = Dts::read(&p).unwrap();
        std::fs::remove_file(&p).unwrap();
        assert_eq!(d2.names(), &["z", "a", "m"]);
    }

    #[test]
    fn proptest_roundtrip_f32() {
        use crate::util::proptest::{run, Config};
        run("dts f32 roundtrip", Config { cases: 16, ..Config::default() }, |g| {
            let r = g.usize_range(1, 16);
            let c = g.usize_range(1, 16);
            let data = g.normal_vec(r * c, 2.0);
            let mut d = Dts::new();
            d.insert("t", DtsTensor::F32 { shape: vec![r, c], data: data.clone() });
            let p = std::env::temp_dir().join(format!(
                "daq_prop_{}_{}", std::process::id(), g.u64()));
            d.write(&p).unwrap();
            let d2 = Dts::read(&p).unwrap();
            std::fs::remove_file(&p).unwrap();
            match d2.get("t").unwrap() {
                DtsTensor::F32 { shape, data: data2 } => {
                    assert_eq!(shape, &vec![r, c]);
                    assert_eq!(&data, data2);
                }
                _ => panic!("wrong dtype"),
            }
        });
    }
}
