//! DTS — Delta Tensor Store reader/writer (Rust side).
//!
//! Binary-compatible with `python/compile/dts.py`; see that file for the
//! on-disk layout. Three access paths share one index parser:
//!
//! - [`Dts::read`] — eager: parse the index, then stream every payload
//!   into memory (the original whole-model reader).
//! - [`DtsIndex`] / [`DtsReader`] — lazy: parse *only* the index at open
//!   and serve individual tensors by seeking, so a multi-GB checkpoint is
//!   never resident. This is the seek layer under the streaming pipeline
//!   and the sharded store ([`crate::io::shard`]).
//! - [`Dts::write`] / [`write_index`] — the mirror image, used to persist
//!   quantized checkpoints, sidecar scale tensors, and shard files.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;

const MAGIC: &[u8; 4] = b"DTS1";
/// Current container version: v2 appends a per-tensor CRC-32 section
/// (one little-endian u32 per index entry, in index order) right after
/// the index entries. v1 stores — no checksum section — still read
/// cleanly; their entries simply carry no CRC and skip verification.
const VERSION: u32 = 2;
const VERSION_NO_CHECKSUM: u32 = 1;

/// A tensor as stored in a DTS container.
#[derive(Clone, Debug, PartialEq)]
pub enum DtsTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    U8 { shape: Vec<usize>, data: Vec<u8> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl DtsTensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            DtsTensor::F32 { shape, .. }
            | DtsTensor::U8 { shape, .. }
            | DtsTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub(crate) fn dtype_code(&self) -> u8 {
        match self {
            DtsTensor::F32 { .. } => 0,
            DtsTensor::U8 { .. } => 1,
            DtsTensor::I32 { .. } => 2,
        }
    }

    pub(crate) fn nbytes(&self) -> usize {
        match self {
            DtsTensor::F32 { data, .. } => data.len() * 4,
            DtsTensor::U8 { data, .. } => data.len(),
            DtsTensor::I32 { data, .. } => data.len() * 4,
        }
    }
}

/// One index entry of a DTS container: everything known about a tensor
/// without touching its payload.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorEntry {
    pub name: String,
    pub dtype: u8,
    pub shape: Vec<usize>,
    /// Byte offset from the start of the payload section.
    pub offset: u64,
    pub nbytes: u64,
    /// CRC-32 (zlib) of the payload bytes; `None` for v1 containers,
    /// which predate the checksum section.
    pub crc32: Option<u32>,
}

impl TensorEntry {
    pub fn dtype_label(&self) -> &'static str {
        match self.dtype {
            0 => "f32",
            1 => "u8",
            2 => "i32",
            _ => "?",
        }
    }
}

/// Parsed header + index of a DTS file — the payload is *not* loaded.
/// This is the seek layer under [`DtsReader`] and the sharded store:
/// `open` reads only the index; [`DtsIndex::read_entry`] seeks into an
/// open file and decodes a single tensor.
#[derive(Debug)]
pub struct DtsIndex {
    pub meta: BTreeMap<String, String>,
    pub entries: Vec<TensorEntry>,
    /// Absolute file offset where the payload section starts.
    pub payload_start: u64,
    /// name -> position in `entries` (first occurrence wins), so per-name
    /// access over a large checkpoint is O(log N), not a linear scan.
    lookup: BTreeMap<String, usize>,
}

impl DtsIndex {
    pub fn open(path: impl AsRef<Path>) -> Result<DtsIndex> {
        let path = path.as_ref();
        let f = File::open(path).with_context(|| format!("open {path:?}"))?;
        let mut r = BufReader::new(f);
        match DtsIndex::parse(&mut r) {
            Ok(i) => Ok(i),
            Err(e) => bail!("{path:?}: {e:#}"),
        }
    }

    /// Parse the header + index from the current position of `r`,
    /// leaving `r` positioned at the start of the payload.
    fn parse(r: &mut impl Read) -> Result<DtsIndex> {
        let mut consumed = 0u64;
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        consumed += 4;
        if &magic != MAGIC {
            bail!("bad magic {magic:?}");
        }
        let version = read_u32(r)?;
        if version != VERSION && version != VERSION_NO_CHECKSUM {
            bail!("unsupported version {version}");
        }
        let n_meta = read_u32(r)? as usize;
        let n_tensor = read_u32(r)? as usize;
        consumed += 12;

        let mut meta = BTreeMap::new();
        for _ in 0..n_meta {
            let klen = read_u16(r)? as usize;
            let key = read_string(r, klen)?;
            let vlen = read_u32(r)? as usize;
            let val = read_string(r, vlen)?;
            consumed += 2 + klen as u64 + 4 + vlen as u64;
            meta.insert(key, val);
        }

        let mut entries = Vec::with_capacity(n_tensor);
        for _ in 0..n_tensor {
            let nlen = read_u16(r)? as usize;
            let name = read_string(r, nlen)?;
            let mut db = [0u8; 2];
            r.read_exact(&mut db)?;
            let (dtype, ndim) = (db[0], db[1] as usize);
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u64(r)? as usize);
            }
            let offset = read_u64(r)?;
            let nbytes = read_u64(r)?;
            consumed += 2 + nlen as u64 + 2 + 8 * ndim as u64 + 16;
            entries.push(TensorEntry { name, dtype, shape, offset, nbytes, crc32: None });
        }
        if version >= VERSION {
            // v2 checksum section: one u32 per tensor, in index order
            for e in entries.iter_mut() {
                e.crc32 = Some(read_u32(r)?);
            }
            consumed += 4 * n_tensor as u64;
        }
        let mut lookup = BTreeMap::new();
        for (i, e) in entries.iter().enumerate() {
            lookup.entry(e.name.clone()).or_insert(i);
        }
        Ok(DtsIndex { meta, entries, payload_start: consumed, lookup })
    }

    pub fn entry(&self, name: &str) -> Option<&TensorEntry> {
        self.lookup.get(name).map(|&i| &self.entries[i])
    }

    /// Total payload bytes across all tensors.
    pub fn payload_bytes(&self) -> u64 {
        self.entries.iter().map(|e| e.nbytes).sum()
    }

    /// Seek into `f` and decode the payload of one entry.
    pub fn read_entry(
        &self,
        f: &mut (impl Read + Seek),
        entry: &TensorEntry,
    ) -> Result<DtsTensor> {
        f.seek(SeekFrom::Start(self.payload_start + entry.offset))?;
        let mut raw = vec![0u8; entry.nbytes as usize];
        f.read_exact(&mut raw)
            .with_context(|| format!("payload of {:?}", entry.name))?;
        decode_payload(entry, raw)
    }
}

/// Decode one tensor payload according to its index entry, verifying the
/// v2 checksum first (v1 entries carry none and are decoded as-is).
pub(crate) fn decode_payload(e: &TensorEntry, raw: Vec<u8>) -> Result<DtsTensor> {
    if let Some(want) = e.crc32 {
        let got = crate::util::crc32::crc32(&raw);
        if got != want {
            bail!(
                "tensor {:?}: checksum mismatch at payload offset {} \
                 ({} bytes): stored {want:#010x}, computed {got:#010x}",
                e.name,
                e.offset,
                e.nbytes
            );
        }
    }
    let n: usize = e.shape.iter().product();
    Ok(match e.dtype {
        0 => {
            if raw.len() != n * 4 {
                bail!("{:?}: f32 payload size mismatch", e.name);
            }
            let data = raw
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            DtsTensor::F32 { shape: e.shape.clone(), data }
        }
        1 => {
            if raw.len() != n {
                bail!("{:?}: u8 payload size mismatch", e.name);
            }
            DtsTensor::U8 { shape: e.shape.clone(), data: raw }
        }
        2 => {
            if raw.len() != n * 4 {
                bail!("{:?}: i32 payload size mismatch", e.name);
            }
            let data = raw
                .chunks_exact(4)
                .map(|b| i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            DtsTensor::I32 { shape: e.shape.clone(), data }
        }
        d => bail!("{:?}: unsupported dtype code {d}", e.name),
    })
}

/// CRC-32 of a tensor's payload, byte-for-byte as [`write_payload`]
/// emits it (little-endian elements for f32/i32, raw bytes for u8).
pub(crate) fn payload_crc32(t: &DtsTensor) -> u32 {
    let mut c = crate::util::crc32::Crc32::new();
    match t {
        DtsTensor::F32 { data, .. } => {
            for v in data {
                c.update(&v.to_le_bytes());
            }
        }
        DtsTensor::U8 { data, .. } => c.update(data),
        DtsTensor::I32 { data, .. } => {
            for v in data {
                c.update(&v.to_le_bytes());
            }
        }
    }
    c.finalize()
}

/// Write one tensor's payload bytes.
pub(crate) fn write_payload(w: &mut impl Write, t: &DtsTensor) -> Result<()> {
    match t {
        DtsTensor::F32 { data, .. } => {
            for v in data {
                w.write_all(&v.to_le_bytes())?;
            }
        }
        DtsTensor::U8 { data, .. } => w.write_all(data)?,
        DtsTensor::I32 { data, .. } => {
            for v in data {
                w.write_all(&v.to_le_bytes())?;
            }
        }
    }
    Ok(())
}

/// Write the DTS header, metadata block, and tensor index. Entries carry
/// their final payload offsets. Length prefixes are guarded: a tensor or
/// meta name longer than `u16::MAX` bytes or a meta value longer than
/// `u32::MAX` bytes is an error instead of a silently truncated prefix.
///
/// The version is derived from the entries: all-checksummed writes a v2
/// container with the CRC section, all-unchecksummed writes v1 (the
/// bench uses this to isolate checksum overhead); a mix is a bug.
pub(crate) fn write_index(
    w: &mut impl Write,
    meta: &BTreeMap<String, String>,
    entries: &[TensorEntry],
) -> Result<()> {
    let version = if entries.iter().all(|e| e.crc32.is_some()) {
        VERSION
    } else if entries.iter().all(|e| e.crc32.is_none()) {
        VERSION_NO_CHECKSUM
    } else {
        bail!("index mixes checksummed and checksum-free entries");
    };
    w.write_all(MAGIC)?;
    w.write_all(&version.to_le_bytes())?;
    w.write_all(&(meta.len() as u32).to_le_bytes())?;
    w.write_all(&(entries.len() as u32).to_le_bytes())?;

    for (k, v) in meta {
        if k.len() > u16::MAX as usize {
            bail!("meta key of {} bytes exceeds the u16 length prefix", k.len());
        }
        if v.len() > u32::MAX as usize {
            bail!(
                "meta value for {k:?} ({} bytes) exceeds the u32 length prefix",
                v.len()
            );
        }
        w.write_all(&(k.len() as u16).to_le_bytes())?;
        w.write_all(k.as_bytes())?;
        w.write_all(&(v.len() as u32).to_le_bytes())?;
        w.write_all(v.as_bytes())?;
    }

    for e in entries {
        if e.name.len() > u16::MAX as usize {
            bail!(
                "tensor name of {} bytes exceeds the u16 length prefix",
                e.name.len()
            );
        }
        w.write_all(&(e.name.len() as u16).to_le_bytes())?;
        w.write_all(e.name.as_bytes())?;
        w.write_all(&[e.dtype, e.shape.len() as u8])?;
        for &d in &e.shape {
            w.write_all(&(d as u64).to_le_bytes())?;
        }
        w.write_all(&e.offset.to_le_bytes())?;
        w.write_all(&e.nbytes.to_le_bytes())?;
    }
    if version == VERSION {
        for e in entries {
            w.write_all(&e.crc32.unwrap_or(0).to_le_bytes())?;
        }
    }
    Ok(())
}

/// A random-access DTS file reader: parses only the index at `open`, then
/// serves individual tensors by seeking — a multi-GB checkpoint is never
/// resident. The streaming pipeline's source for monolithic checkpoints
/// (sharded stores use [`crate::io::shard::ShardedDts`]).
#[derive(Debug)]
pub struct DtsReader {
    path: PathBuf,
    pub index: DtsIndex,
}

impl DtsReader {
    pub fn open(path: impl AsRef<Path>) -> Result<DtsReader> {
        let path = path.as_ref().to_path_buf();
        let index = DtsIndex::open(&path)?;
        Ok(DtsReader { path, index })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn names(&self) -> Vec<String> {
        self.index.entries.iter().map(|e| e.name.clone()).collect()
    }

    pub fn read_tensor(&self, name: &str) -> Result<DtsTensor> {
        let entry = self
            .index
            .entry(name)
            .ok_or_else(|| anyhow::anyhow!("tensor {name:?} not found in {:?}", self.path))?;
        let mut f = File::open(&self.path)
            .with_context(|| format!("open {:?}", self.path))?;
        self.index.read_entry(&mut f, entry)
    }
}

/// An in-memory DTS container: ordered tensors + string metadata.
#[derive(Default, Debug)]
pub struct Dts {
    pub meta: BTreeMap<String, String>,
    names: Vec<String>,
    tensors: BTreeMap<String, DtsTensor>,
}

impl Dts {
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert (or replace) a tensor, preserving first-insertion order.
    pub fn insert(&mut self, name: &str, t: DtsTensor) {
        if !self.tensors.contains_key(name) {
            self.names.push(name.to_string());
        }
        self.tensors.insert(name.to_string(), t);
    }

    pub fn insert_f32(&mut self, name: &str, t: &Tensor) {
        self.insert(name, DtsTensor::F32 {
            shape: t.shape().to_vec(),
            data: t.data().to_vec(),
        });
    }

    pub fn insert_i32(&mut self, name: &str, shape: Vec<usize>, data: Vec<i32>) {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        self.insert(name, DtsTensor::I32 { shape, data });
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn get(&self, name: &str) -> Option<&DtsTensor> {
        self.tensors.get(name)
    }

    pub fn contains(&self, name: &str) -> bool {
        self.tensors.contains_key(name)
    }

    /// Fetch an f32 tensor as a `Tensor` (errors on missing or wrong dtype).
    pub fn tensor_f32(&self, name: &str) -> Result<Tensor> {
        match self.get(name) {
            Some(DtsTensor::F32 { shape, data }) => {
                Ok(Tensor::new(shape.clone(), data.clone()))
            }
            Some(other) => bail!("tensor {name:?} has dtype {:?}, wanted f32",
                                 other.dtype_code()),
            None => bail!("tensor {name:?} not found"),
        }
    }

    pub fn tensor_i32(&self, name: &str) -> Result<(Vec<usize>, Vec<i32>)> {
        match self.get(name) {
            Some(DtsTensor::I32 { shape, data }) => Ok((shape.clone(), data.clone())),
            Some(_) => bail!("tensor {name:?} is not i32"),
            None => bail!("tensor {name:?} not found"),
        }
    }

    pub fn tensor_u8(&self, name: &str) -> Result<(Vec<usize>, Vec<u8>)> {
        match self.get(name) {
            Some(DtsTensor::U8 { shape, data }) => Ok((shape.clone(), data.clone())),
            Some(_) => bail!("tensor {name:?} is not u8"),
            None => bail!("tensor {name:?} not found"),
        }
    }

    // -- serialization ----------------------------------------------------

    pub fn read(path: impl AsRef<Path>) -> Result<Dts> {
        let path = path.as_ref();
        let f = File::open(path).with_context(|| format!("open {path:?}"))?;
        let mut r = BufReader::new(f);
        let index = match DtsIndex::parse(&mut r) {
            Ok(i) => i,
            Err(e) => bail!("{path:?}: {e:#}"),
        };

        let mut dts = Dts::new();
        dts.meta = index.meta.clone();

        // payload: entries were written sequentially; verify and stream
        let mut cursor = 0u64;
        for e in &index.entries {
            if e.offset != cursor {
                bail!("{path:?}: non-sequential payload at {:?} \
                       (offset {} expected {cursor})", e.name, e.offset);
            }
            let mut raw = vec![0u8; e.nbytes as usize];
            r.read_exact(&mut raw)
                .with_context(|| format!("payload of {:?}", e.name))?;
            dts.insert(&e.name, decode_payload(e, raw)?);
            cursor += e.nbytes;
        }
        Ok(dts)
    }

    pub fn write(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let f = File::create(path).with_context(|| format!("create {path:?}"))?;
        let mut w = BufWriter::new(f);

        let mut entries = Vec::with_capacity(self.names.len());
        let mut offset = 0u64;
        for name in &self.names {
            let t = &self.tensors[name];
            entries.push(TensorEntry {
                name: name.clone(),
                dtype: t.dtype_code(),
                shape: t.shape().to_vec(),
                offset,
                nbytes: t.nbytes() as u64,
                crc32: Some(payload_crc32(t)),
            });
            offset += t.nbytes() as u64;
        }
        write_index(&mut w, &self.meta, &entries)
            .with_context(|| format!("write {path:?}"))?;

        for name in &self.names {
            write_payload(&mut w, &self.tensors[name])?;
        }
        w.flush()?;
        Ok(())
    }
}

fn read_u16(r: &mut impl Read) -> Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_string(r: &mut impl Read, len: usize) -> Result<String> {
    let mut b = vec![0u8; len];
    r.read_exact(&mut b)?;
    Ok(String::from_utf8(b)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("daq_test_{name}_{}", std::process::id()));
        p
    }

    #[test]
    fn roundtrip_mixed() {
        let mut d = Dts::new();
        d.meta.insert("kind".into(), "test".into());
        d.insert("w", DtsTensor::F32 {
            shape: vec![2, 3],
            data: vec![1.0, -2.5, 3.25, 0.0, 5.0, -6.125],
        });
        d.insert("codes", DtsTensor::U8 { shape: vec![4], data: vec![0, 127, 128, 255] });
        d.insert("tok", DtsTensor::I32 { shape: vec![2, 2], data: vec![-1, 0, 7, 42] });

        let p = tmpfile("roundtrip");
        d.write(&p).unwrap();
        let d2 = Dts::read(&p).unwrap();
        std::fs::remove_file(&p).unwrap();

        assert_eq!(d2.meta.get("kind").map(|s| s.as_str()), Some("test"));
        assert_eq!(d2.names(), d.names());
        assert_eq!(d2.get("w"), d.get("w"));
        assert_eq!(d2.get("codes"), d.get("codes"));
        assert_eq!(d2.get("tok"), d.get("tok"));
    }

    #[test]
    fn bad_magic_rejected() {
        let p = tmpfile("badmagic");
        std::fs::write(&p, b"NOPE\x01\x00\x00\x00\x00\x00\x00\x00\x00\x00\x00\x00").unwrap();
        let err = Dts::read(&p).unwrap_err().to_string();
        std::fs::remove_file(&p).unwrap();
        assert!(err.contains("bad magic"), "{err}");
    }

    #[test]
    fn missing_tensor_errors() {
        let d = Dts::new();
        assert!(d.tensor_f32("nope").is_err());
    }

    #[test]
    fn dtype_mismatch_errors() {
        let mut d = Dts::new();
        d.insert("codes", DtsTensor::U8 { shape: vec![1], data: vec![1] });
        assert!(d.tensor_f32("codes").is_err());
        assert!(d.tensor_u8("codes").is_ok());
    }

    #[test]
    fn insertion_order_preserved() {
        let mut d = Dts::new();
        for name in ["z", "a", "m"] {
            d.insert(name, DtsTensor::U8 { shape: vec![1], data: vec![0] });
        }
        assert_eq!(d.names(), &["z", "a", "m"]);
        let p = tmpfile("order");
        d.write(&p).unwrap();
        let d2 = Dts::read(&p).unwrap();
        std::fs::remove_file(&p).unwrap();
        assert_eq!(d2.names(), &["z", "a", "m"]);
    }

    #[test]
    fn index_open_and_seek_read_match_full_read() {
        let mut d = Dts::new();
        d.meta.insert("k".into(), "v".into());
        d.insert("a", DtsTensor::F32 { shape: vec![3], data: vec![1.0, 2.0, 3.0] });
        d.insert("b", DtsTensor::U8 { shape: vec![2, 2], data: vec![9, 8, 7, 6] });
        d.insert("c", DtsTensor::I32 { shape: vec![2], data: vec![-5, 5] });
        let p = tmpfile("seekread");
        d.write(&p).unwrap();

        let idx = DtsIndex::open(&p).unwrap();
        assert_eq!(idx.meta.get("k").map(|s| s.as_str()), Some("v"));
        assert_eq!(idx.entries.len(), 3);
        assert_eq!(idx.payload_bytes(), 12 + 4 + 8);
        let ea = idx.entry("a").unwrap();
        assert_eq!(ea.dtype_label(), "f32");
        assert_eq!(ea.shape, vec![3]);

        // seek reads (in arbitrary order) equal the eager reader's tensors
        let r = DtsReader::open(&p).unwrap();
        assert_eq!(r.names(), vec!["a".to_string(), "b".into(), "c".into()]);
        for name in ["c", "a", "b"] {
            assert_eq!(&r.read_tensor(name).unwrap(), d.get(name).unwrap());
        }
        assert!(r.read_tensor("missing").is_err());
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn writer_rejects_oversized_length_prefixes() {
        // a tensor name longer than u16::MAX must error, not truncate
        let long = "x".repeat(u16::MAX as usize + 1);
        let mut d = Dts::new();
        d.insert(&long, DtsTensor::U8 { shape: vec![1], data: vec![0] });
        let p = tmpfile("longname");
        let err = d.write(&p).unwrap_err();
        assert!(
            format!("{err:#}").contains("u16 length prefix"),
            "{err:#}"
        );

        // same for meta keys
        let mut d = Dts::new();
        d.meta.insert(long.clone(), "v".into());
        let err = d.write(&p).unwrap_err();
        assert!(
            format!("{err:#}").contains("u16 length prefix"),
            "{err:#}"
        );
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn checksums_written_and_verified() {
        let mut d = Dts::new();
        d.insert("w", DtsTensor::F32 { shape: vec![4], data: vec![1.0, 2.0, 3.0, 4.0] });
        d.insert("codes", DtsTensor::U8 { shape: vec![3], data: vec![5, 6, 7] });
        let p = tmpfile("crc");
        d.write(&p).unwrap();

        // the index carries a CRC per entry and a clean read verifies it
        let idx = DtsIndex::open(&p).unwrap();
        assert!(idx.entries.iter().all(|e| e.crc32.is_some()));
        assert_eq!(
            idx.entry("w").unwrap().crc32,
            Some(payload_crc32(d.get("w").unwrap()))
        );
        Dts::read(&p).unwrap();

        // flip one payload byte -> both readers reject, naming the tensor
        let mut bytes = std::fs::read(&p).unwrap();
        let off = bytes.len() - 1; // last payload byte = "codes"
        bytes[off] ^= 0x40;
        std::fs::write(&p, &bytes).unwrap();
        let err = format!("{:#}", Dts::read(&p).unwrap_err());
        assert!(err.contains("checksum mismatch"), "{err}");
        assert!(err.contains("codes"), "{err}");
        let r = DtsReader::open(&p).unwrap();
        assert!(r.read_tensor("w").is_ok());
        let err = format!("{:#}", r.read_tensor("codes").unwrap_err());
        assert!(err.contains("checksum mismatch"), "{err}");
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn v1_container_without_checksums_reads_cleanly() {
        // hand-write a v1 container through write_index (crc32: None)
        let t = DtsTensor::F32 { shape: vec![2], data: vec![1.5, -2.5] };
        let entries = vec![TensorEntry {
            name: "w".into(),
            dtype: t.dtype_code(),
            shape: t.shape().to_vec(),
            offset: 0,
            nbytes: t.nbytes() as u64,
            crc32: None,
        }];
        let p = tmpfile("v1read");
        let mut w = BufWriter::new(File::create(&p).unwrap());
        write_index(&mut w, &BTreeMap::new(), &entries).unwrap();
        write_payload(&mut w, &t).unwrap();
        w.flush().unwrap();

        let bytes = std::fs::read(&p).unwrap();
        assert_eq!(u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]), 1);
        let d = Dts::read(&p).unwrap();
        assert_eq!(d.get("w"), Some(&t));
        let idx = DtsIndex::open(&p).unwrap();
        assert_eq!(idx.entry("w").unwrap().crc32, None);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn mixed_checksum_entries_rejected() {
        let t = DtsTensor::U8 { shape: vec![1], data: vec![0] };
        let mk = |name: &str, crc| TensorEntry {
            name: name.into(),
            dtype: t.dtype_code(),
            shape: t.shape().to_vec(),
            offset: 0,
            nbytes: t.nbytes() as u64,
            crc32: crc,
        };
        let entries = vec![mk("a", Some(7)), mk("b", None)];
        let mut buf = Vec::new();
        let err = write_index(&mut buf, &BTreeMap::new(), &entries).unwrap_err();
        assert!(format!("{err:#}").contains("mixes"), "{err:#}");
    }

    #[test]
    fn unknown_version_rejected() {
        let p = tmpfile("badver");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&99u32.to_le_bytes());
        bytes.extend_from_slice(&0u32.to_le_bytes());
        bytes.extend_from_slice(&0u32.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        let err = format!("{:#}", Dts::read(&p).unwrap_err());
        std::fs::remove_file(&p).unwrap();
        assert!(err.contains("unsupported version 99"), "{err}");
    }

    #[test]
    fn proptest_roundtrip_f32() {
        use crate::util::proptest::{run, Config};
        run("dts f32 roundtrip", Config { cases: 16, ..Config::default() }, |g| {
            let r = g.usize_range(1, 16);
            let c = g.usize_range(1, 16);
            let data = g.normal_vec(r * c, 2.0);
            let mut d = Dts::new();
            d.insert("t", DtsTensor::F32 { shape: vec![r, c], data: data.clone() });
            let p = std::env::temp_dir().join(format!(
                "daq_prop_{}_{}", std::process::id(), g.u64()));
            d.write(&p).unwrap();
            let d2 = Dts::read(&p).unwrap();
            std::fs::remove_file(&p).unwrap();
            match d2.get("t").unwrap() {
                DtsTensor::F32 { shape, data: data2 } => {
                    assert_eq!(shape, &vec![r, c]);
                    assert_eq!(&data, data2);
                }
                _ => panic!("wrong dtype"),
            }
        });
    }
}
