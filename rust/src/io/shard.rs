//! Sharded streaming checkpoint store.
//!
//! A sharded store is a directory holding a `manifest.json` plus DTS1
//! shard files (`shard_00000.dts`, `shard_00001.dts`, …) split by a byte
//! budget. Each shard is a complete, standalone DTS container (readable
//! by [`Dts::read`](crate::io::dts::Dts::read) or `daq inspect`); the
//! manifest records the shard list and the store-level metadata.
//!
//! Two halves:
//!
//! - [`ShardedDts`] — the reader. `open` parses the manifest and each
//!   shard's *index only*; `read_tensor(name)` seeks into the owning
//!   shard and decodes one payload. Peak memory is one tensor, never the
//!   model.
//! - [`ShardWriter`] — the append-side. Tensors stream into a `.part`
//!   payload file (only the small index is held in memory); at the byte
//!   budget the caller rolls the shard, which writes the final
//!   header+index+payload file atomically (tmp + rename). An interrupted
//!   run therefore leaves only complete shard files plus at most one
//!   discardable `.part`, which is what makes the streaming pipeline's
//!   resume protocol (`coordinator::stream`) safe.
//!
//! The Python artifact side mirrors this format in
//! `python/compile/dts.py` (`write_sharded_dts` / `read_sharded_dts`).

use std::collections::{BTreeMap, BTreeSet};
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::io::dts::{
    payload_crc32, write_index, write_payload, DtsIndex, DtsTensor, TensorEntry,
};
use crate::util::crc32::Crc32;
use crate::util::json::Json;
use crate::util::telemetry::{self, Counter};

/// Manifest file name inside a sharded-store directory.
pub const MANIFEST_NAME: &str = "manifest.json";
/// Manifest `format` field value.
pub const MANIFEST_FORMAT: &str = "daq-sharded-dts";
const MANIFEST_VERSION: f64 = 1.0;
/// Default shard byte budget (MiB) for the CLI.
pub const DEFAULT_SHARD_MB: u64 = 256;

/// File name of shard `i`.
pub fn shard_file_name(i: usize) -> String {
    format!("shard_{i:05}.dts")
}

struct Shard {
    file: String,
    index: DtsIndex,
}

/// Reader over a sharded store: manifest + per-shard indexes only; tensor
/// payloads are fetched on demand by seeking into the owning shard.
pub struct ShardedDts {
    dir: PathBuf,
    pub meta: BTreeMap<String, String>,
    pub shard_budget_bytes: u64,
    names: Vec<String>,
    /// name -> (shard idx, entry idx within that shard's index)
    lookup: BTreeMap<String, (usize, usize)>,
    shards: Vec<Shard>,
}

impl ShardedDts {
    /// Open a store from its manifest path or its directory.
    pub fn open(path: impl AsRef<Path>) -> Result<ShardedDts> {
        let path = path.as_ref();
        let manifest_path = if path.is_dir() {
            path.join(MANIFEST_NAME)
        } else {
            path.to_path_buf()
        };
        let dir = manifest_path
            .parent()
            .ok_or_else(|| anyhow!("{manifest_path:?} has no parent directory"))?
            .to_path_buf();
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("read {manifest_path:?}"))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow!("{manifest_path:?}: {e}"))?;
        match j.get("format").and_then(|f| f.as_str()) {
            Some(MANIFEST_FORMAT) => {}
            other => bail!("{manifest_path:?}: not a sharded-store manifest ({other:?})"),
        }
        let mut meta = BTreeMap::new();
        if let Some(Json::Obj(m)) = j.get("meta") {
            for (k, v) in m {
                match v {
                    Json::Str(s) => meta.insert(k.clone(), s.clone()),
                    other => meta.insert(k.clone(), other.to_string()),
                };
            }
        }
        let shard_budget_bytes = j
            .get("shard_budget_bytes")
            .and_then(|b| b.as_f64())
            .unwrap_or(0.0) as u64;

        let mut shards = Vec::new();
        let mut names = Vec::new();
        let mut lookup = BTreeMap::new();
        for s in j.get("shards").and_then(|s| s.as_arr()).unwrap_or(&[]) {
            let file = s
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| anyhow!("{manifest_path:?}: shard entry without file"))?
                .to_string();
            let index = DtsIndex::open(dir.join(&file))?;
            let si = shards.len();
            for (ei, e) in index.entries.iter().enumerate() {
                if lookup.insert(e.name.clone(), (si, ei)).is_some() {
                    bail!(
                        "{manifest_path:?}: tensor {:?} appears in more than one shard",
                        e.name
                    );
                }
                names.push(e.name.clone());
            }
            shards.push(Shard { file, index });
        }
        Ok(ShardedDts { dir, meta, shard_budget_bytes, names, lookup, shards })
    }

    /// Tensor names in store order (shard order, then in-shard order).
    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn contains(&self, name: &str) -> bool {
        self.lookup.contains_key(name)
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Index entry (dtype/shape/bytes) plus owning shard file, payload
    /// untouched.
    pub fn entry(&self, name: &str) -> Option<(&str, &TensorEntry)> {
        let &(si, ei) = self.lookup.get(name)?;
        Some((self.shards[si].file.as_str(), &self.shards[si].index.entries[ei]))
    }

    /// Total payload bytes across all shards.
    pub fn payload_bytes(&self) -> u64 {
        self.shards.iter().map(|s| s.index.payload_bytes()).sum()
    }

    /// Read one tensor by seeking into its owning shard.
    pub fn read_tensor(&self, name: &str) -> Result<DtsTensor> {
        let &(si, ei) = self
            .lookup
            .get(name)
            .ok_or_else(|| anyhow!("tensor {name:?} not found in {:?}", self.dir))?;
        let shard = &self.shards[si];
        let path = self.dir.join(&shard.file);
        let mut f = File::open(&path).with_context(|| format!("open {path:?}"))?;
        shard
            .index
            .read_entry(&mut f, &shard.index.entries[ei])
            .with_context(|| format!("shard {:?}", shard.file))
    }
}

/// One finalized shard's record for the manifest.
struct ShardRecord {
    file: String,
    tensors: usize,
    bytes: u64,
}

/// Append-side of a sharded store.
///
/// `append` streams the tensor's payload straight to the current shard's
/// `.part` file and keeps only the index entry in memory, so writer
/// residency is O(index), not O(shard). `append` never splits a decision
/// point: the *caller* chooses the roll boundaries by calling
/// [`ShardWriter::maybe_roll`] between logical units (the streaming
/// pipeline rolls between scheduling units — a single layer for delta
/// methods, a whole layernorm-coupled transform group for
/// SmoothQuant/AWQ — so a unit never spans shards and lands finalized
/// all-or-nothing, the invariant its resume protocol checks; the
/// `daq shard` converter rolls between tensors). A shard may therefore
/// overshoot the budget by up to one unit.
pub struct ShardWriter {
    dir: PathBuf,
    budget: u64,
    checksums: bool,
    shards: Vec<ShardRecord>,
    names_seen: BTreeSet<String>,
    // current (unfinalized) shard
    cur_entries: Vec<TensorEntry>,
    cur_bytes: u64,
    part: Option<BufWriter<File>>,
    tel: WriterTelemetry,
}

/// Counter handles captured from the constructing thread's telemetry
/// context — the writer itself may later run on a different thread (the
/// streaming pipeline hands it to the writer stage), so the handles are
/// bound once at `create`/`resume` time.
struct WriterTelemetry {
    rolls: Counter,
    crc_verified: Counter,
    bytes_written: Counter,
}

impl WriterTelemetry {
    fn capture() -> WriterTelemetry {
        let tel = telemetry::current();
        WriterTelemetry {
            rolls: tel.counter("shard.rolls"),
            crc_verified: tel.counter("shard.checksum_verified"),
            bytes_written: tel.counter("shard.bytes_written"),
        }
    }
}

impl ShardWriter {
    /// Start a fresh store in `dir` (created if missing). Fails if the
    /// directory already holds shard files — use [`ShardWriter::resume`]
    /// or remove them first.
    pub fn create(dir: impl AsRef<Path>, budget_bytes: u64) -> Result<ShardWriter> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir).with_context(|| format!("create {dir:?}"))?;
        if !existing_shard_files(&dir)?.is_empty() {
            bail!(
                "{dir:?} already contains shard files; resume or remove them first"
            );
        }
        Ok(ShardWriter {
            dir,
            budget: budget_bytes.max(1),
            checksums: true,
            shards: Vec::new(),
            names_seen: BTreeSet::new(),
            cur_entries: Vec::new(),
            cur_bytes: 0,
            part: None,
            tel: WriterTelemetry::capture(),
        })
    }

    /// Reopen a store directory after an interruption: finalized shards
    /// are kept (their indexes are re-read to rebuild the records), a
    /// leftover `.part` payload is discarded, and writing continues into
    /// new shard files.
    pub fn resume(dir: impl AsRef<Path>, budget_bytes: u64) -> Result<ShardWriter> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir).with_context(|| format!("create {dir:?}"))?;
        let mut shards = Vec::new();
        let mut names_seen = BTreeSet::new();
        for file in existing_shard_files(&dir)? {
            let index = DtsIndex::open(dir.join(&file))?;
            for e in &index.entries {
                if !names_seen.insert(e.name.clone()) {
                    bail!(
                        "{dir:?}: tensor {:?} appears in more than one shard; \
                         remove the directory and restart",
                        e.name
                    );
                }
            }
            shards.push(ShardRecord {
                file,
                tensors: index.entries.len(),
                bytes: index.payload_bytes(),
            });
        }
        // stale partial payloads / tmp finals from the interrupted run:
        // sweep ANY *.part / *.tmp in the store directory (older writers
        // and crashed converters leave differently named orphans), never
        // trip over them
        for entry in std::fs::read_dir(&dir).with_context(|| format!("read {dir:?}"))? {
            let entry = entry?;
            let name = entry.file_name().to_string_lossy().into_owned();
            if name.ends_with(".part") || name.ends_with(".tmp") {
                let _ = std::fs::remove_file(entry.path());
            }
        }
        Ok(ShardWriter {
            dir,
            budget: budget_bytes.max(1),
            checksums: true,
            shards,
            names_seen,
            cur_entries: Vec::new(),
            cur_bytes: 0,
            part: None,
            tel: WriterTelemetry::capture(),
        })
    }

    /// Disable per-payload checksums: shards are written as v1 containers
    /// with no CRC section and `roll` skips the finalize-time verify. The
    /// bench uses this to isolate checksum overhead; production paths
    /// leave it on.
    pub fn set_checksums(&mut self, on: bool) {
        self.checksums = on;
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Tensors already persisted in finalized shards (resume) or staged in
    /// the current shard.
    pub fn contains(&self, name: &str) -> bool {
        self.names_seen.contains(name)
    }

    /// Index of the shard currently being written (= the shard the next
    /// appended tensor lands in).
    pub fn current_shard_index(&self) -> usize {
        self.shards.len()
    }

    /// Payload bytes staged in the current shard.
    pub fn current_bytes(&self) -> u64 {
        self.cur_bytes
    }

    /// Tensors staged in the current (unfinalized) shard.
    pub fn staged_tensors(&self) -> usize {
        self.cur_entries.len()
    }

    fn part_path(&self) -> PathBuf {
        self.dir.join("shard.part")
    }

    /// Append one tensor to the current shard. Never rolls; call
    /// [`ShardWriter::maybe_roll`] at unit boundaries.
    pub fn append(&mut self, name: &str, t: &DtsTensor) -> Result<()> {
        if !self.names_seen.insert(name.to_string()) {
            bail!("tensor {name:?} appended twice");
        }
        if self.part.is_none() {
            let p = self.part_path();
            let f = File::create(&p).with_context(|| format!("create {p:?}"))?;
            self.part = Some(BufWriter::new(f));
        }
        let w = self.part.as_mut().expect("part writer just ensured");
        write_payload(w, t)?;
        self.cur_entries.push(TensorEntry {
            name: name.to_string(),
            dtype: t.dtype_code(),
            shape: t.shape().to_vec(),
            offset: self.cur_bytes,
            nbytes: t.nbytes() as u64,
            crc32: self.checksums.then(|| payload_crc32(t)),
        });
        self.cur_bytes += t.nbytes() as u64;
        self.tel.bytes_written.add(t.nbytes() as u64);
        Ok(())
    }

    /// Roll if the current shard has reached the byte budget.
    pub fn maybe_roll(&mut self) -> Result<()> {
        if self.cur_bytes >= self.budget {
            self.roll()?;
        }
        Ok(())
    }

    /// Re-read the synced `.part` payload and check every staged entry's
    /// CRC against what `append` computed, so a corrupted staging file
    /// (torn write, bad disk, injected fault) is caught *before* it is
    /// finalized into a shard. Errors name the tensor, the shard it was
    /// headed for, and the byte offset.
    fn verify_part(&self, shard_file: &str) -> Result<()> {
        let p = self.part_path();
        let f = File::open(&p).with_context(|| format!("open {p:?}"))?;
        let mut r = BufReader::new(f);
        let mut buf = vec![0u8; 64 << 10];
        // entries were appended sequentially, so one forward pass covers
        // them all without seeking
        for e in &self.cur_entries {
            let Some(want) = e.crc32 else { continue };
            let mut crc = Crc32::new();
            let mut left = e.nbytes as usize;
            while left > 0 {
                let n = left.min(buf.len());
                r.read_exact(&mut buf[..n]).with_context(|| {
                    format!("re-read staged payload of {:?}", e.name)
                })?;
                crc.update(&buf[..n]);
                left -= n;
            }
            let got = crc.finalize();
            if got != want {
                bail!(
                    "tensor {:?}: staged payload corrupted before finalize of \
                     shard {shard_file:?} at payload offset {} ({} bytes): \
                     expected {want:#010x}, computed {got:#010x}",
                    e.name,
                    e.offset,
                    e.nbytes
                );
            }
            self.tel.crc_verified.incr();
        }
        Ok(())
    }

    /// Finalize the current shard: flush + fsync the `.part` payload,
    /// verify its checksums, write the final `shard_NNNNN.dts`
    /// (header + index + payload) to a tmp file, fsync, rename it into
    /// place, and fsync the directory so the rename itself is durable —
    /// a finalized shard can never be torn. No-op when nothing is staged.
    pub fn roll(&mut self) -> Result<()> {
        let Some(part) = self.part.take() else {
            return Ok(());
        };
        let f = part
            .into_inner()
            .map_err(|e| anyhow!("flush shard part: {}", e.error()))?;
        f.sync_all()?;
        drop(f);

        let file = shard_file_name(self.shards.len());
        self.verify_part(&file)?;
        let tmp = self.dir.join("shard.tmp");
        {
            let out = File::create(&tmp).with_context(|| format!("create {tmp:?}"))?;
            let mut w = BufWriter::new(out);
            let mut meta = BTreeMap::new();
            meta.insert("shard_index".to_string(), self.shards.len().to_string());
            write_index(&mut w, &meta, &self.cur_entries)?;
            let mut payload = File::open(self.part_path())?;
            std::io::copy(&mut payload, &mut w)?;
            w.flush()?;
            w.into_inner()
                .map_err(|e| anyhow!("flush {tmp:?}: {}", e.error()))?
                .sync_all()?;
        }
        std::fs::rename(&tmp, self.dir.join(&file))
            .with_context(|| format!("rename {tmp:?}"))?;
        // fsync the directory so the rename is durable before the .part
        // is discarded (best-effort: not every platform can open a dir)
        if let Ok(d) = File::open(&self.dir) {
            let _ = d.sync_all();
        }
        std::fs::remove_file(self.part_path())?;

        self.shards.push(ShardRecord {
            file,
            tensors: self.cur_entries.len(),
            bytes: self.cur_bytes,
        });
        self.cur_entries.clear();
        self.cur_bytes = 0;
        self.tel.rolls.incr();
        Ok(())
    }

    /// Roll any staged tensors and write the manifest with the given
    /// store-level metadata. Returns the manifest path.
    pub fn finish(mut self, meta: &BTreeMap<String, String>) -> Result<PathBuf> {
        self.roll()?;
        let mut obj = BTreeMap::new();
        obj.insert("format".to_string(), Json::Str(MANIFEST_FORMAT.into()));
        obj.insert("version".to_string(), Json::Num(MANIFEST_VERSION));
        obj.insert(
            "shard_budget_bytes".to_string(),
            Json::Num(self.budget as f64),
        );
        obj.insert(
            "meta".to_string(),
            Json::Obj(
                meta.iter()
                    .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                    .collect(),
            ),
        );
        obj.insert(
            "shards".to_string(),
            Json::Arr(
                self.shards
                    .iter()
                    .map(|s| {
                        let mut m = BTreeMap::new();
                        m.insert("file".to_string(), Json::Str(s.file.clone()));
                        m.insert("tensors".to_string(), Json::Num(s.tensors as f64));
                        m.insert("bytes".to_string(), Json::Num(s.bytes as f64));
                        Json::Obj(m)
                    })
                    .collect(),
            ),
        );
        let path = self.dir.join(MANIFEST_NAME);
        std::fs::write(&path, format!("{}\n", Json::Obj(obj)))
            .with_context(|| format!("write {path:?}"))?;
        Ok(path)
    }
}

/// Sorted list of finalized shard files in `dir`.
fn existing_shard_files(dir: &Path) -> Result<Vec<String>> {
    let mut out = Vec::new();
    if !dir.exists() {
        return Ok(out);
    }
    for entry in std::fs::read_dir(dir).with_context(|| format!("read {dir:?}"))? {
        let name = entry?.file_name().to_string_lossy().into_owned();
        if name.starts_with("shard_") && name.ends_with(".dts") {
            out.push(name);
        }
    }
    out.sort();
    Ok(out)
}

/// Convert a monolithic DTS checkpoint into a sharded store, streaming
/// one tensor at a time (the `daq shard` converter). Returns
/// (manifest path, shard count).
pub fn shard_dts_file(
    src: impl AsRef<Path>,
    out_dir: impl AsRef<Path>,
    budget_bytes: u64,
) -> Result<(PathBuf, usize)> {
    let reader = crate::io::dts::DtsReader::open(src)?;
    let mut w = ShardWriter::create(out_dir, budget_bytes)?;
    for name in reader.names() {
        let t = reader.read_tensor(&name)?;
        w.append(&name, &t)?;
        drop(t);
        w.maybe_roll()?;
    }
    let n = w.current_shard_index() + usize::from(w.staged_tensors() > 0);
    let manifest = w.finish(&reader.index.meta)?;
    Ok((manifest, n))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::dts::Dts;
    use crate::util::rng::XorShift;

    fn tmpdir(tag: &str) -> PathBuf {
        let p = std::env::temp_dir()
            .join(format!("daq_shard_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&p);
        p
    }

    fn f32t(n: usize, seed: u64) -> DtsTensor {
        let mut rng = XorShift::new(seed);
        DtsTensor::F32 { shape: vec![n], data: rng.normal_vec(n, 1.0) }
    }

    #[test]
    fn writer_rolls_at_budget_and_reader_round_trips() {
        let dir = tmpdir("roundtrip");
        // budget of 100 bytes; each tensor is 64 bytes -> one per shard
        let mut w = ShardWriter::create(&dir, 100).unwrap();
        let tensors: Vec<(String, DtsTensor)> = (0..5)
            .map(|i| (format!("t{i}"), f32t(16, i as u64 + 1)))
            .collect();
        for (name, t) in &tensors {
            w.append(name, t).unwrap();
            w.maybe_roll().unwrap();
        }
        let mut meta = BTreeMap::new();
        meta.insert("kind".to_string(), "test".to_string());
        let manifest = w.finish(&meta).unwrap();

        let s = ShardedDts::open(&manifest).unwrap();
        // rolls once the payload REACHES the budget: [t0,t1] [t2,t3] [t4]
        assert_eq!(s.n_shards(), 3, "64B tensors under a 100B budget");
        assert_eq!(s.meta.get("kind").map(|s| s.as_str()), Some("test"));
        assert_eq!(
            s.names(),
            tensors.iter().map(|(n, _)| n.clone()).collect::<Vec<_>>()
        );
        for (name, t) in &tensors {
            assert_eq!(&s.read_tensor(name).unwrap(), t, "{name}");
            let (_, e) = s.entry(name).unwrap();
            assert_eq!(e.nbytes, 64);
            assert_eq!(e.dtype_label(), "f32");
        }
        assert_eq!(s.payload_bytes(), 5 * 64);
        // opening by directory works too
        assert!(ShardedDts::open(&dir).is_ok());
        // each shard is a standalone DTS1 container
        let d0 = Dts::read(dir.join(shard_file_name(0))).unwrap();
        assert_eq!(d0.names().len(), 2);
        assert_eq!(
            d0.meta.get("shard_index").map(|s| s.as_str()),
            Some("0")
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn large_budget_packs_one_shard() {
        let dir = tmpdir("pack");
        let mut w = ShardWriter::create(&dir, 1 << 20).unwrap();
        for i in 0..4 {
            w.append(&format!("t{i}"), &f32t(8, i as u64)).unwrap();
            w.maybe_roll().unwrap();
        }
        let manifest = w.finish(&BTreeMap::new()).unwrap();
        let s = ShardedDts::open(&manifest).unwrap();
        assert_eq!(s.n_shards(), 1);
        assert_eq!(s.names().len(), 4);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn duplicate_append_rejected() {
        let dir = tmpdir("dup");
        let mut w = ShardWriter::create(&dir, 1 << 20).unwrap();
        w.append("a", &f32t(4, 1)).unwrap();
        assert!(w.append("a", &f32t(4, 2)).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn resume_keeps_finalized_shards_and_discards_part() {
        let dir = tmpdir("resume");
        let mut w = ShardWriter::create(&dir, 1).unwrap(); // roll every tensor
        w.append("a", &f32t(8, 1)).unwrap();
        w.maybe_roll().unwrap();
        // simulate interruption mid-shard: staged tensor never finalized
        w.append("b", &f32t(8, 2)).unwrap();
        drop(w);
        assert!(dir.join("shard.part").exists());

        let mut w = ShardWriter::resume(&dir, 1).unwrap();
        assert!(w.contains("a"));
        assert!(!w.contains("b"), "unfinalized tensor must not survive");
        assert!(!dir.join("shard.part").exists());
        assert_eq!(w.current_shard_index(), 1);
        w.append("b", &f32t(8, 3)).unwrap();
        w.maybe_roll().unwrap();
        let manifest = w.finish(&BTreeMap::new()).unwrap();
        let s = ShardedDts::open(&manifest).unwrap();
        assert_eq!(s.names().to_vec(), vec!["a".to_string(), "b".into()]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn create_refuses_dir_with_shards() {
        let dir = tmpdir("refuse");
        let mut w = ShardWriter::create(&dir, 1).unwrap();
        w.append("a", &f32t(4, 1)).unwrap();
        w.roll().unwrap();
        drop(w);
        assert!(ShardWriter::create(&dir, 1).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn flipped_byte_in_shard_names_tensor_and_shard() {
        let dir = tmpdir("corrupt");
        let mut w = ShardWriter::create(&dir, 1 << 20).unwrap();
        w.append("ok", &f32t(8, 1)).unwrap();
        w.append("bad", &f32t(8, 2)).unwrap();
        let manifest = w.finish(&BTreeMap::new()).unwrap();

        // flip the last payload byte (belongs to "bad") in place
        let shard = dir.join(shard_file_name(0));
        let mut bytes = std::fs::read(&shard).unwrap();
        let off = bytes.len() - 1;
        bytes[off] ^= 0x01;
        std::fs::write(&shard, &bytes).unwrap();

        let s = ShardedDts::open(&manifest).unwrap();
        assert!(s.read_tensor("ok").is_ok(), "untouched tensor still reads");
        let err = format!("{:#}", s.read_tensor("bad").unwrap_err());
        assert!(err.contains("checksum mismatch"), "{err}");
        assert!(err.contains("bad"), "{err}");
        assert!(err.contains("shard_00000.dts"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn roll_catches_part_corrupted_on_disk() {
        use std::io::{Seek, SeekFrom, Write as _};
        let dir = tmpdir("tornpart");
        let mut w = ShardWriter::create(&dir, 1 << 20).unwrap();
        // 16 KiB tensor: BufWriter (8 KiB) has flushed the head to disk
        w.append("big", &f32t(4096, 7)).unwrap();

        // corrupt an already-flushed byte of the staging file in place
        let part = dir.join("shard.part");
        let mut f = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .open(&part)
            .unwrap();
        let mut b = [0u8; 1];
        std::io::Read::read_exact(&mut f, &mut b).unwrap();
        f.seek(SeekFrom::Start(0)).unwrap();
        f.write_all(&[b[0] ^ 0x10]).unwrap();
        drop(f);

        let err = format!("{:#}", w.roll().unwrap_err());
        assert!(err.contains("staged payload corrupted"), "{err}");
        assert!(err.contains("big"), "{err}");
        assert!(err.contains("shard_00000.dts"), "{err}");
        // nothing was finalized
        assert!(!dir.join(shard_file_name(0)).exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn resume_sweeps_any_orphaned_part_and_tmp_files() {
        let dir = tmpdir("orphans");
        let mut w = ShardWriter::create(&dir, 1).unwrap();
        w.append("a", &f32t(8, 1)).unwrap();
        w.roll().unwrap();
        drop(w);
        for orphan in ["shard.part", "shard.tmp", "old_convert.part", "stale.tmp"] {
            std::fs::write(dir.join(orphan), b"garbage").unwrap();
        }

        let w = ShardWriter::resume(&dir, 1).unwrap();
        assert!(w.contains("a"));
        for orphan in ["shard.part", "shard.tmp", "old_convert.part", "stale.tmp"] {
            assert!(!dir.join(orphan).exists(), "{orphan} must be swept");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn checksums_off_writes_v1_shards() {
        let dir = tmpdir("nocrc");
        let mut w = ShardWriter::create(&dir, 1 << 20).unwrap();
        w.set_checksums(false);
        let t = f32t(8, 3);
        w.append("a", &t).unwrap();
        let manifest = w.finish(&BTreeMap::new()).unwrap();

        let shard = dir.join(shard_file_name(0));
        let bytes = std::fs::read(&shard).unwrap();
        assert_eq!(u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]), 1);
        let s = ShardedDts::open(&manifest).unwrap();
        let (_, e) = s.entry("a").unwrap();
        assert_eq!(e.crc32, None);
        assert_eq!(s.read_tensor("a").unwrap(), t);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn shard_converter_matches_source() {
        let dir = tmpdir("convert");
        let mut d = Dts::new();
        d.meta.insert("vocab".into(), "64".into());
        for i in 0..3 {
            d.insert(&format!("w{i}"), f32t(32, 10 + i as u64));
        }
        let src = std::env::temp_dir()
            .join(format!("daq_shard_src_{}.dts", std::process::id()));
        d.write(&src).unwrap();

        let (manifest, n) = shard_dts_file(&src, &dir, 200).unwrap();
        assert!(n >= 2, "128B tensors under a 200B budget must split");
        let s = ShardedDts::open(&manifest).unwrap();
        assert_eq!(s.meta.get("vocab").map(|s| s.as_str()), Some("64"));
        for i in 0..3 {
            let name = format!("w{i}");
            assert_eq!(&s.read_tensor(&name).unwrap(), d.get(&name).unwrap());
        }
        std::fs::remove_file(&src).unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

