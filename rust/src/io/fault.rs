//! Deterministic fault injection over [`TensorSource`] — the substrate
//! for the chaos suite (`tests/fault.rs`) and for reproducing CI chaos
//! failures locally.
//!
//! [`FaultSource`] wraps any source and injects *seeded* faults on
//! `read_tensor`, so a failing run replays exactly from its seed:
//!
//! - **transient read errors** drawn from an advancing PRNG — the same
//!   read retried later can succeed, which is what exercises the
//!   prefetcher's backoff path;
//! - **persistent corruption** decided per tensor *name* (seed ⊕ name
//!   hash) — every read of an afflicted tensor fails until the store is
//!   repaired, which is what exercises the quarantine path. Bit flips
//!   and truncations are injected as the *detected* error (exactly what
//!   the CRC/length verification in `io::dts` turns them into), so the
//!   pipeline never consumes silently corrupted data — mirroring the
//!   integrity guarantee the checksums provide on real disks;
//! - **latency**, a fixed per-read sleep.
//!
//! For corruption that really lands on disk (and must be caught by the
//! checksum layer itself), use [`flip_byte`] / [`truncate_file`].
//!
//! Classification is string-based because the vendored `anyhow` carries
//! no typed chain: transient errors embed [`TRANSIENT_MARKER`] and are
//! recognized by [`is_transient`].

use std::collections::BTreeMap;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::Path;
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::io::dts::DtsTensor;
use crate::io::TensorSource;
use crate::util::rng::XorShift;

/// Substring identifying an injected *transient* fault (retry may
/// succeed). Kept stable: the prefetcher's retry classification and the
/// chaos suite both match on it.
pub const TRANSIENT_MARKER: &str = "injected transient fault";
/// Substring identifying injected *persistent* corruption (retry is
/// pointless; the unit must be quarantined).
pub const PERSISTENT_MARKER: &str = "injected persistent corruption";

/// Injection rates and seed. All rates default to 0 (no faults).
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultConfig {
    pub seed: u64,
    /// Probability that any given read fails transiently.
    pub read_error_rate: f64,
    /// Probability that a tensor (by name) is persistently bit-flipped.
    pub flip_rate: f64,
    /// Probability that a tensor (by name) is persistently truncated.
    pub truncate_rate: f64,
    /// Fixed sleep per read, in milliseconds.
    pub latency_ms: u64,
}

impl FaultConfig {
    /// Read the config from `DAQ_FAULT_*` environment variables
    /// (`DAQ_FAULT_SEED`, `DAQ_FAULT_READ_ERR`, `DAQ_FAULT_FLIP`,
    /// `DAQ_FAULT_TRUNC`, `DAQ_FAULT_LATENCY_MS`); anything unset or
    /// unparsable keeps its default.
    pub fn from_env() -> FaultConfig {
        fn num<T: std::str::FromStr>(key: &str, default: T) -> T {
            std::env::var(key)
                .ok()
                .and_then(|s| s.parse().ok())
                .unwrap_or(default)
        }
        FaultConfig {
            seed: num("DAQ_FAULT_SEED", 0),
            read_error_rate: num("DAQ_FAULT_READ_ERR", 0.0),
            flip_rate: num("DAQ_FAULT_FLIP", 0.0),
            truncate_rate: num("DAQ_FAULT_TRUNC", 0.0),
            latency_ms: num("DAQ_FAULT_LATENCY_MS", 0),
        }
    }
}

/// Counts of faults injected so far, for test assertions.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultCounters {
    pub transient: usize,
    pub persistent: usize,
    pub reads: usize,
}

/// Is this error an injected transient fault (worth retrying)?
pub fn is_transient(e: &anyhow::Error) -> bool {
    format!("{e:#}").contains(TRANSIENT_MARKER)
}

/// FNV-1a, so per-name persistent faults are stable across runs and
/// independent of read order.
fn name_hash(name: &str) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for &b in name.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// A [`TensorSource`] wrapper injecting seeded faults on payload reads.
/// Index-only operations (names, shapes, metadata) pass through
/// untouched — faults model payload I/O, not catalog access.
pub struct FaultSource<'a> {
    inner: &'a dyn TensorSource,
    cfg: FaultConfig,
    state: Mutex<(XorShift, FaultCounters)>,
}

impl<'a> FaultSource<'a> {
    pub fn new(inner: &'a dyn TensorSource, cfg: FaultConfig) -> FaultSource<'a> {
        FaultSource {
            inner,
            cfg,
            state: Mutex::new((XorShift::new(cfg.seed), FaultCounters::default())),
        }
    }

    /// Snapshot of the injection counters.
    pub fn counters(&self) -> FaultCounters {
        self.state.lock().expect("fault state poisoned").1
    }

    /// The persistent fault (if any) afflicting `name`, decided from the
    /// seed and the name alone.
    fn persistent_fault(&self, name: &str) -> Option<&'static str> {
        let mut rng = XorShift::new(self.cfg.seed ^ name_hash(name));
        if rng.f64() < self.cfg.flip_rate {
            return Some("bit flip (checksum mismatch)");
        }
        if rng.f64() < self.cfg.truncate_rate {
            return Some("truncated payload");
        }
        None
    }
}

impl TensorSource for FaultSource<'_> {
    fn names(&self) -> Vec<String> {
        self.inner.names()
    }

    fn meta(&self) -> &BTreeMap<String, String> {
        self.inner.meta()
    }

    fn contains(&self, name: &str) -> bool {
        self.inner.contains(name)
    }

    fn shape_of(&self, name: &str) -> Option<Vec<usize>> {
        self.inner.shape_of(name)
    }

    fn nbytes_of(&self, name: &str) -> Option<u64> {
        self.inner.nbytes_of(name)
    }

    fn crc32_of(&self, name: &str) -> Option<u32> {
        self.inner.crc32_of(name)
    }

    fn names_with_prefix(&self, prefix: &str) -> Vec<String> {
        self.inner.names_with_prefix(prefix)
    }

    fn read_tensor(&self, name: &str) -> Result<DtsTensor> {
        if self.cfg.latency_ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(self.cfg.latency_ms));
        }
        {
            let mut s = self.state.lock().expect("fault state poisoned");
            s.1.reads += 1;
            if let Some(kind) = self.persistent_fault(name) {
                s.1.persistent += 1;
                bail!("{PERSISTENT_MARKER}: {kind} in tensor {name:?}");
            }
            if s.0.f64() < self.cfg.read_error_rate {
                s.1.transient += 1;
                let n = s.1.transient;
                bail!("{TRANSIENT_MARKER} #{n}: read of tensor {name:?}");
            }
        }
        self.inner.read_tensor(name)
    }
}

/// XOR one byte of a file in place (disk-level corruption for tests —
/// goes through the real checksum verification, unlike the modeled
/// faults above).
pub fn flip_byte(path: impl AsRef<Path>, offset: u64, mask: u8) -> Result<()> {
    let path = path.as_ref();
    if mask == 0 {
        bail!("flip mask 0 would leave {path:?} unchanged");
    }
    let mut f = std::fs::OpenOptions::new()
        .read(true)
        .write(true)
        .open(path)
        .with_context(|| format!("open {path:?}"))?;
    f.seek(SeekFrom::Start(offset))?;
    let mut b = [0u8; 1];
    f.read_exact(&mut b)
        .with_context(|| format!("read byte {offset} of {path:?}"))?;
    f.seek(SeekFrom::Start(offset))?;
    f.write_all(&[b[0] ^ mask])?;
    f.sync_all()?;
    Ok(())
}

/// Truncate a file to `len` bytes (torn-write simulation for tests).
pub fn truncate_file(path: impl AsRef<Path>, len: u64) -> Result<()> {
    let path = path.as_ref();
    let f = std::fs::OpenOptions::new()
        .write(true)
        .open(path)
        .with_context(|| format!("open {path:?}"))?;
    f.set_len(len)
        .with_context(|| format!("truncate {path:?} to {len}"))?;
    f.sync_all()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::dts::Dts;
    use crate::tensor::Tensor;

    fn small_dts() -> Dts {
        let mut d = Dts::new();
        for (i, name) in ["a", "b", "c", "d"].iter().enumerate() {
            let data: Vec<f32> = (0..8).map(|j| (i * 8 + j) as f32).collect();
            d.insert_f32(name, &Tensor::new(vec![2, 4], data));
        }
        d
    }

    #[test]
    fn no_faults_is_a_transparent_wrapper() {
        let d = small_dts();
        let fs = FaultSource::new(&d, FaultConfig::default());
        assert_eq!(TensorSource::names(&fs), TensorSource::names(&d));
        for name in ["a", "b", "c", "d"] {
            assert_eq!(
                fs.read_tensor(name).unwrap(),
                TensorSource::read_tensor(&d, name).unwrap()
            );
        }
        let c = fs.counters();
        assert_eq!((c.transient, c.persistent, c.reads), (0, 0, 4));
    }

    #[test]
    fn transient_faults_are_seeded_and_eventually_clear() {
        let d = small_dts();
        let cfg = FaultConfig { seed: 11, read_error_rate: 0.5, ..Default::default() };
        // same seed -> identical fault sequence across instances
        let outcomes = |src: &FaultSource| -> Vec<bool> {
            (0..32).map(|_| src.read_tensor("a").is_ok()).collect()
        };
        let s1 = FaultSource::new(&d, cfg);
        let s2 = FaultSource::new(&d, cfg);
        let o1 = outcomes(&s1);
        assert_eq!(o1, outcomes(&s2));
        assert!(o1.iter().any(|ok| *ok), "some reads must succeed");
        assert!(o1.iter().any(|ok| !*ok), "some reads must fail at rate 0.5");
        // failures are transient-classified, and a bounded retry loop
        // always gets through at rate 0.5
        let s3 = FaultSource::new(&d, cfg);
        for _ in 0..8 {
            let mut ok = false;
            for _ in 0..64 {
                match s3.read_tensor("b") {
                    Ok(_) => {
                        ok = true;
                        break;
                    }
                    Err(e) => assert!(is_transient(&e), "{e:#}"),
                }
            }
            assert!(ok, "retry never cleared a 0.5-rate transient fault");
        }
    }

    #[test]
    fn persistent_faults_stick_to_names() {
        let d = small_dts();
        let cfg = FaultConfig { seed: 5, flip_rate: 0.5, ..Default::default() };
        let fs = FaultSource::new(&d, cfg);
        let afflicted: Vec<&str> = ["a", "b", "c", "d"]
            .into_iter()
            .filter(|n| fs.read_tensor(n).is_err())
            .collect();
        assert!(!afflicted.is_empty(), "rate 0.5 over 4 names hit none");
        assert!(afflicted.len() < 4, "rate 0.5 over 4 names hit all");
        for name in &afflicted {
            // every retry fails identically, and never as transient
            for _ in 0..4 {
                let e = fs.read_tensor(name).unwrap_err();
                assert!(!is_transient(&e), "{e:#}");
                assert!(format!("{e:#}").contains(PERSISTENT_MARKER), "{e:#}");
                assert!(format!("{e:#}").contains(name), "{e:#}");
            }
        }
    }

    #[test]
    fn env_config_parses() {
        std::env::set_var("DAQ_FAULT_SEED", "42");
        std::env::set_var("DAQ_FAULT_READ_ERR", "0.25");
        std::env::set_var("DAQ_FAULT_LATENCY_MS", "3");
        let cfg = FaultConfig::from_env();
        std::env::remove_var("DAQ_FAULT_SEED");
        std::env::remove_var("DAQ_FAULT_READ_ERR");
        std::env::remove_var("DAQ_FAULT_LATENCY_MS");
        assert_eq!(cfg.seed, 42);
        assert_eq!(cfg.read_error_rate, 0.25);
        assert_eq!(cfg.flip_rate, 0.0);
        assert_eq!(cfg.latency_ms, 3);
    }

    #[test]
    fn disk_helpers_corrupt_in_place() {
        let p = std::env::temp_dir()
            .join(format!("daq_fault_disk_{}", std::process::id()));
        std::fs::write(&p, [1u8, 2, 3, 4]).unwrap();
        flip_byte(&p, 2, 0xFF).unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), vec![1, 2, 3 ^ 0xFF, 4]);
        assert!(flip_byte(&p, 0, 0).is_err(), "no-op mask rejected");
        truncate_file(&p, 2).unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), vec![1, 2]);
        std::fs::remove_file(&p).unwrap();
    }
}
