//! Checkpoint and artifact I/O.

pub mod dts;
