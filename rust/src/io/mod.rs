//! Checkpoint and artifact I/O.
//!
//! Three interchangeable checkpoint backends implement [`TensorSource`]:
//! the in-memory [`Dts`](dts::Dts) container, the seek-based
//! [`DtsReader`](dts::DtsReader) over a monolithic file, and the sharded
//! [`ShardedDts`](shard::ShardedDts) store. The streaming coordinator and
//! the sidecar dequant loader are written against the trait, so they run
//! over any of them.

pub mod dts;
pub mod fault;
pub mod shard;

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Result};

use crate::tensor::Tensor;
use dts::{Dts, DtsReader, DtsTensor};
use shard::ShardedDts;

/// Read access to a named-tensor container. `Sync` so a prefetch thread
/// can pull tensors while other threads hold the same source.
pub trait TensorSource: Sync {
    /// Tensor names in the container's canonical order.
    fn names(&self) -> Vec<String>;

    /// Container-level string metadata.
    fn meta(&self) -> &BTreeMap<String, String>;

    fn contains(&self, name: &str) -> bool;

    /// Dims of a stored tensor without reading its payload.
    fn shape_of(&self, name: &str) -> Option<Vec<usize>>;

    /// At-rest payload bytes of a stored tensor, from the index alone —
    /// the serving path reports store-size vs resident-size from this
    /// without pulling a single payload.
    fn nbytes_of(&self, name: &str) -> Option<u64>;

    /// Stored CRC-32 of a tensor's payload, when its container recorded
    /// one (DTS v2+). `None` for v1 containers and purely in-memory
    /// sources — `daq verify-store` uses this to tell "verified ok"
    /// apart from "read back but unverifiable".
    fn crc32_of(&self, _name: &str) -> Option<u32> {
        None
    }

    /// Peek-by-prefix: names starting with `prefix`, in container order,
    /// from the index alone (no payloads). The group planner uses this to
    /// locate a layernorm's affine parameters next to its GEMMs.
    fn names_with_prefix(&self, prefix: &str) -> Vec<String> {
        self.names()
            .into_iter()
            .filter(|n| n.starts_with(prefix))
            .collect()
    }

    /// Read one tensor (seek-based backends load only this payload).
    fn read_tensor(&self, name: &str) -> Result<DtsTensor>;

    fn tensor_f32(&self, name: &str) -> Result<Tensor> {
        match self.read_tensor(name)? {
            DtsTensor::F32 { shape, data } => Ok(Tensor::new(shape, data)),
            other => bail!(
                "tensor {name:?} has dtype {:?}, wanted f32",
                other.dtype_code()
            ),
        }
    }

    fn tensor_u8(&self, name: &str) -> Result<(Vec<usize>, Vec<u8>)> {
        match self.read_tensor(name)? {
            DtsTensor::U8 { shape, data } => Ok((shape, data)),
            _ => bail!("tensor {name:?} is not u8"),
        }
    }
}

impl TensorSource for Dts {
    fn names(&self) -> Vec<String> {
        Dts::names(self).to_vec()
    }

    fn meta(&self) -> &BTreeMap<String, String> {
        &self.meta
    }

    fn contains(&self, name: &str) -> bool {
        Dts::contains(self, name)
    }

    fn shape_of(&self, name: &str) -> Option<Vec<usize>> {
        self.get(name).map(|t| t.shape().to_vec())
    }

    fn nbytes_of(&self, name: &str) -> Option<u64> {
        self.get(name).map(|t| t.nbytes() as u64)
    }

    fn read_tensor(&self, name: &str) -> Result<DtsTensor> {
        self.get(name)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("tensor {name:?} not found"))
    }
}

impl TensorSource for DtsReader {
    fn names(&self) -> Vec<String> {
        DtsReader::names(self)
    }

    fn meta(&self) -> &BTreeMap<String, String> {
        &self.index.meta
    }

    fn contains(&self, name: &str) -> bool {
        self.index.entry(name).is_some()
    }

    fn shape_of(&self, name: &str) -> Option<Vec<usize>> {
        self.index.entry(name).map(|e| e.shape.clone())
    }

    fn nbytes_of(&self, name: &str) -> Option<u64> {
        self.index.entry(name).map(|e| e.nbytes)
    }

    fn crc32_of(&self, name: &str) -> Option<u32> {
        self.index.entry(name).and_then(|e| e.crc32)
    }

    fn read_tensor(&self, name: &str) -> Result<DtsTensor> {
        DtsReader::read_tensor(self, name)
    }
}

impl TensorSource for ShardedDts {
    fn names(&self) -> Vec<String> {
        ShardedDts::names(self).to_vec()
    }

    fn meta(&self) -> &BTreeMap<String, String> {
        &self.meta
    }

    fn contains(&self, name: &str) -> bool {
        ShardedDts::contains(self, name)
    }

    fn shape_of(&self, name: &str) -> Option<Vec<usize>> {
        self.entry(name).map(|(_, e)| e.shape.clone())
    }

    fn nbytes_of(&self, name: &str) -> Option<u64> {
        self.entry(name).map(|(_, e)| e.nbytes)
    }

    fn crc32_of(&self, name: &str) -> Option<u32> {
        self.entry(name).and_then(|(_, e)| e.crc32)
    }

    fn read_tensor(&self, name: &str) -> Result<DtsTensor> {
        ShardedDts::read_tensor(self, name)
    }
}

/// Open a checkpoint for streaming reads, auto-detecting the backend:
/// a directory or a `*.json` path opens as a sharded store; anything else
/// as a seek-based monolithic DTS file. Either way only indexes are
/// parsed — payloads load on demand.
pub fn open_source(path: &str) -> Result<Box<dyn TensorSource>> {
    let p = Path::new(path);
    if p.is_dir() || path.ends_with(".json") {
        Ok(Box::new(ShardedDts::open(p)?))
    } else {
        Ok(Box::new(DtsReader::open(p)?))
    }
}
