//! Unified telemetry: typed metrics, lightweight spans, a structured
//! JSONL trace, and a Prometheus-style exposition endpoint — all on std
//! alone.
//!
//! Design:
//!
//! - A [`Telemetry`] instance owns a registry of named counters, gauges,
//!   and fixed-bucket histograms. Registration (name → cell) takes a
//!   mutex; the returned handles ([`Counter`], [`Gauge`], [`Histogram`])
//!   are plain `Arc`ed atomics, so the hot path is lock-free — hoist the
//!   handle outside a loop and every update is one relaxed atomic op.
//! - The *disabled* instance ([`Telemetry::disabled`]) hands out empty
//!   handles: no allocation, no atomics, no clock reads. Uninstrumented
//!   callers pay nothing — the bench prices the difference at ≤3%.
//! - Instrumented code finds its registry through a thread-scoped
//!   current-telemetry context ([`set_current`] / [`current`]), the same
//!   way the pipeline threads its config: the CLI installs one enabled
//!   instance per run, `run_stream` re-installs it on every thread it
//!   spawns, and library code deep in the sweep just asks for
//!   `current()` — tests that run concurrently in one process never see
//!   each other's registries.
//! - Histograms share one fixed log-spaced bound set
//!   ([`BUCKET_BOUNDS`]), so bucket counts are pure event counts:
//!   per-bucket increments commute, which makes snapshots of count-type
//!   metrics **bitwise-deterministic for any worker count** — the same
//!   contract the tiled sweep's fixed-order merge honors. Snapshots
//!   iterate the registry in sorted name order.
//! - Spans ([`Telemetry::span`], or the [`span!`](crate::span) macro
//!   with fields) record wall time into a `<name>.seconds` histogram on
//!   drop and, when a trace sink is attached
//!   ([`Telemetry::set_trace_out`]), append one JSON object per
//!   span/event. The `ts_us` timestamp is assigned *under the sink
//!   lock*, so timestamps are monotonically non-decreasing in file
//!   order even with concurrent writers (validated by
//!   `python/compile/check_telemetry_schema.py`).
//! - [`MetricsServer`] serves `GET /metrics` (Prometheus text format)
//!   from a `std::net::TcpListener` thread; `daq serve --metrics-addr`
//!   wires it up.
//! - [`log`] / [`warn`] / [`info`] / [`debug`] are the one leveled way
//!   the binary talks about what it's doing, gated by
//!   `DAQ_LOG=warn|info|debug` (default `info`).

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::report::Table;
use crate::util::json::Json;

/// Shared histogram bucket upper bounds: powers of 4 from 1 µs, spanning
/// both durations in seconds (1 µs … ~18 min) and small count-valued
/// observations (candidates per tile, tokens per request). One fixed set
/// keeps every snapshot's bucket layout identical, which is what makes
/// cross-worker snapshot comparison meaningful.
pub const BUCKET_BOUNDS: [f64; 16] = [
    1e-6, 4e-6, 1.6e-5, 6.4e-5, 2.56e-4, 1.024e-3, 4.096e-3, 1.6384e-2,
    6.5536e-2, 0.262144, 1.048576, 4.194304, 16.777216, 67.108864,
    268.435456, 1073.741824,
];

// ---------------------------------------------------------------------
// metric cells

/// f64 accumulator over an `AtomicU64` bit pattern (CAS-add). Integer
/// observations below 2^53 accumulate exactly, so order does not matter
/// for count-type sums.
fn add_f64(cell: &AtomicU64, v: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + v).to_bits();
        match cell.compare_exchange_weak(
            cur,
            next,
            Ordering::Relaxed,
            Ordering::Relaxed,
        ) {
            Ok(_) => return,
            Err(now) => cur = now,
        }
    }
}

struct HistCell {
    /// Per-bucket (non-cumulative) counts; last bucket is +Inf overflow.
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    /// Σ observed values as f64 bits.
    sum: AtomicU64,
}

impl HistCell {
    fn new() -> HistCell {
        HistCell {
            buckets: (0..=BUCKET_BOUNDS.len()).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    fn observe(&self, v: f64) {
        let idx = BUCKET_BOUNDS
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(BUCKET_BOUNDS.len());
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        add_f64(&self.sum, v);
    }
}

enum Metric {
    Counter(Arc<AtomicU64>),
    Gauge(Arc<AtomicU64>),
    Hist(Arc<HistCell>),
}

/// Monotonic counter handle. Disabled-registry handles are inert.
#[derive(Clone, Default)]
pub struct Counter(Option<Arc<AtomicU64>>);

impl Counter {
    /// Add `n` to the counter (relaxed; commutes across threads).
    pub fn add(&self, n: u64) {
        if let Some(c) = &self.0 {
            c.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Add 1 to the counter.
    pub fn incr(&self) {
        self.add(1);
    }

    /// Current counter value (0 for a disabled handle).
    pub fn value(&self) -> u64 {
        self.0.as_ref().map_or(0, |c| c.load(Ordering::Relaxed))
    }
}

/// Last-write-wins gauge handle (f64 stored as bits).
#[derive(Clone, Default)]
pub struct Gauge(Option<Arc<AtomicU64>>);

impl Gauge {
    /// Overwrite the gauge with `v` (last write wins).
    pub fn set(&self, v: f64) {
        if let Some(c) = &self.0 {
            c.store(v.to_bits(), Ordering::Relaxed);
        }
    }
}

/// Fixed-bucket histogram handle.
#[derive(Clone, Default)]
pub struct Histogram(Option<Arc<HistCell>>);

impl Histogram {
    /// Record one observation into the fixed bucket layout.
    pub fn observe(&self, v: f64) {
        if let Some(h) = &self.0 {
            h.observe(v);
        }
    }

    /// Whether this handle is backed by a live registry.
    pub fn is_enabled(&self) -> bool {
        self.0.is_some()
    }

    /// Start a timer that records elapsed seconds on drop. Disabled
    /// handles skip the clock read entirely.
    pub fn start_timer(&self) -> HistTimer<'_> {
        HistTimer(self.0.as_deref().map(|h| (h, Instant::now())))
    }
}

/// Drop guard from [`Histogram::start_timer`].
pub struct HistTimer<'a>(Option<(&'a HistCell, Instant)>);

impl Drop for HistTimer<'_> {
    fn drop(&mut self) {
        if let Some((h, start)) = self.0.take() {
            h.observe(start.elapsed().as_secs_f64());
        }
    }
}

// ---------------------------------------------------------------------
// the registry

struct Inner {
    run_id: String,
    start: Instant,
    metrics: Mutex<BTreeMap<String, Metric>>,
    trace: Mutex<Option<std::io::BufWriter<std::fs::File>>>,
}

/// A telemetry registry. `Telemetry::new` builds an enabled instance;
/// `Telemetry::disabled` is the shared passive default whose handles are
/// all no-ops.
pub struct Telemetry {
    inner: Option<Inner>,
}

impl std::fmt::Debug for Telemetry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.inner {
            Some(i) => write!(f, "Telemetry({:?})", i.run_id),
            None => write!(f, "Telemetry(disabled)"),
        }
    }
}

impl Telemetry {
    /// Build an enabled registry tagged with `run_id`.
    pub fn new(run_id: &str) -> Arc<Telemetry> {
        Arc::new(Telemetry {
            inner: Some(Inner {
                run_id: run_id.to_string(),
                start: Instant::now(),
                metrics: Mutex::new(BTreeMap::new()),
                trace: Mutex::new(None),
            }),
        })
    }

    /// The shared passive instance: handles are inert, spans skip the
    /// clock, snapshots are empty.
    pub fn disabled() -> Arc<Telemetry> {
        static DISABLED: OnceLock<Arc<Telemetry>> = OnceLock::new();
        DISABLED.get_or_init(|| Arc::new(Telemetry { inner: None })).clone()
    }

    /// Whether this registry records anything at all.
    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// The run identifier (empty for the disabled instance).
    pub fn run_id(&self) -> &str {
        self.inner.as_ref().map_or("", |i| i.run_id.as_str())
    }

    /// Register (or look up) a metric. Cold path: takes the registry
    /// mutex — hoist the returned handle out of hot loops.
    fn metric(
        &self,
        name: &str,
        make: impl FnOnce() -> Metric,
    ) -> Option<&Inner> {
        let inner = self.inner.as_ref()?;
        let mut m = inner.metrics.lock().unwrap();
        if !m.contains_key(name) {
            m.insert(name.to_string(), make());
        }
        Some(inner)
    }

    /// Handle to the monotonic counter `name`, registering it on
    /// first use. Cold path (registry mutex) — hoist out of hot loops.
    pub fn counter(&self, name: &str) -> Counter {
        let Some(inner) = self.metric(name, || Metric::Counter(Arc::new(AtomicU64::new(0))))
        else {
            return Counter(None);
        };
        match inner.metrics.lock().unwrap().get(name) {
            Some(Metric::Counter(c)) => Counter(Some(c.clone())),
            _ => Counter(None), // name registered under a different type
        }
    }

    /// Handle to the gauge `name`, registering it on first use.
    pub fn gauge(&self, name: &str) -> Gauge {
        let Some(inner) = self.metric(name, || Metric::Gauge(Arc::new(AtomicU64::new(0))))
        else {
            return Gauge(None);
        };
        match inner.metrics.lock().unwrap().get(name) {
            Some(Metric::Gauge(g)) => Gauge(Some(g.clone())),
            _ => Gauge(None),
        }
    }

    /// Handle to the histogram `name`, registering it on first use.
    pub fn histogram(&self, name: &str) -> Histogram {
        let Some(inner) = self.metric(name, || Metric::Hist(Arc::new(HistCell::new())))
        else {
            return Histogram(None);
        };
        match inner.metrics.lock().unwrap().get(name) {
            Some(Metric::Hist(h)) => Histogram(Some(h.clone())),
            _ => Histogram(None),
        }
    }

    /// Open a span: wall time records into `<name>.seconds` on drop and,
    /// with a trace sink attached, one JSONL object is appended.
    pub fn span(&self, name: &'static str) -> Span<'_> {
        self.span_with(name, Vec::new())
    }

    /// [`Telemetry::span`] with extra key=value trace fields (see the
    /// [`span!`](crate::span) macro for the ergonomic form).
    pub fn span_with(
        &self,
        name: &'static str,
        fields: Vec<(&'static str, Json)>,
    ) -> Span<'_> {
        if !self.enabled() {
            return Span(None);
        }
        let hist = self.histogram(&format!("{name}.seconds"));
        Span(Some(SpanState { tel: self, name, hist, fields, start: Instant::now() }))
    }

    /// Append a point event to the trace (no histogram, no duration).
    /// Inert without a trace sink.
    pub fn event(&self, name: &str, fields: &[(&'static str, Json)]) {
        self.write_trace("event", name, None, fields);
    }

    /// Attach a JSONL trace sink. One object per span/event; `ts_us`
    /// assigned at write time under the sink lock, so timestamps are
    /// monotone in file order. No-op on the disabled instance.
    pub fn set_trace_out(&self, path: &Path) -> Result<()> {
        let Some(inner) = self.inner.as_ref() else { return Ok(()) };
        let f = std::fs::File::create(path)
            .with_context(|| format!("create trace file {path:?}"))?;
        *inner.trace.lock().unwrap() = Some(std::io::BufWriter::new(f));
        Ok(())
    }

    fn write_trace(
        &self,
        kind: &str,
        name: &str,
        dur_us: Option<u64>,
        fields: &[(&'static str, Json)],
    ) {
        let Some(inner) = self.inner.as_ref() else { return };
        let mut sink = inner.trace.lock().unwrap();
        let Some(w) = sink.as_mut() else { return };
        let mut o = BTreeMap::new();
        // timestamp taken under the lock: file order == time order
        o.insert(
            "ts_us".to_string(),
            Json::Num(inner.start.elapsed().as_micros() as f64),
        );
        o.insert("run".to_string(), Json::Str(inner.run_id.clone()));
        o.insert("kind".to_string(), Json::Str(kind.to_string()));
        o.insert("name".to_string(), Json::Str(name.to_string()));
        if let Some(d) = dur_us {
            o.insert("dur_us".to_string(), Json::Num(d as f64));
        }
        for (k, v) in fields {
            o.insert((*k).to_string(), v.clone());
        }
        // a full disk mustn't take the pipeline down with it; flush per
        // line so an interrupted run leaves a readable trace
        let _ = writeln!(w, "{}", Json::Obj(o));
        let _ = w.flush();
    }

    /// Consistent point-in-time view of every metric, in sorted name
    /// order. Counter values and count-type histogram buckets are
    /// bitwise-deterministic across worker counts.
    pub fn snapshot(&self) -> Snapshot {
        let mut snap = Snapshot::default();
        let Some(inner) = self.inner.as_ref() else { return snap };
        snap.run_id = inner.run_id.clone();
        for (name, m) in inner.metrics.lock().unwrap().iter() {
            match m {
                Metric::Counter(c) => {
                    snap.counters.insert(name.clone(), c.load(Ordering::Relaxed));
                }
                Metric::Gauge(g) => {
                    snap.gauges
                        .insert(name.clone(), f64::from_bits(g.load(Ordering::Relaxed)));
                }
                Metric::Hist(h) => {
                    snap.histograms.insert(
                        name.clone(),
                        HistSnapshot {
                            count: h.count.load(Ordering::Relaxed),
                            sum: f64::from_bits(h.sum.load(Ordering::Relaxed)),
                            buckets: h
                                .buckets
                                .iter()
                                .map(|b| b.load(Ordering::Relaxed))
                                .collect(),
                        },
                    );
                }
            }
        }
        snap
    }

    /// Write `snapshot().to_json()` to `path` (atomic enough for a
    /// metrics file: whole-file rewrite per call).
    pub fn write_metrics_file(&self, path: &Path) -> Result<()> {
        let text = format!("{}\n", self.snapshot().to_json());
        std::fs::write(path, text)
            .with_context(|| format!("write metrics file {path:?}"))
    }
}

/// Span guard returned by [`Telemetry::span`]; records on drop.
pub struct Span<'a>(Option<SpanState<'a>>);

struct SpanState<'a> {
    tel: &'a Telemetry,
    name: &'static str,
    hist: Histogram,
    fields: Vec<(&'static str, Json)>,
    start: Instant,
}

impl Drop for Span<'_> {
    fn drop(&mut self) {
        if let Some(s) = self.0.take() {
            let el = s.start.elapsed();
            s.hist.observe(el.as_secs_f64());
            s.tel.write_trace(
                "span",
                s.name,
                Some(el.as_micros() as u64),
                &s.fields,
            );
        }
    }
}

/// Open a span on a telemetry handle with optional key=value trace
/// fields: `span!(tel, "stream.compute", "unit" = label)`.
#[macro_export]
macro_rules! span {
    ($tel:expr, $name:expr $(, $k:literal = $v:expr)* $(,)?) => {
        $tel.span_with(
            $name,
            vec![$(($k, $crate::util::telemetry::field($v))),*],
        )
    };
}

/// Convert common value types into trace-field [`Json`].
pub trait ToField {
    fn to_field(self) -> Json;
}

impl ToField for f64 {
    fn to_field(self) -> Json {
        Json::Num(self)
    }
}
impl ToField for usize {
    fn to_field(self) -> Json {
        Json::Num(self as f64)
    }
}
impl ToField for u64 {
    fn to_field(self) -> Json {
        Json::Num(self as f64)
    }
}
impl ToField for bool {
    fn to_field(self) -> Json {
        Json::Bool(self)
    }
}
impl ToField for &str {
    fn to_field(self) -> Json {
        Json::Str(self.to_string())
    }
}
impl ToField for String {
    fn to_field(self) -> Json {
        Json::Str(self)
    }
}

/// Coerce a value into a [`Json`] trace field (used by [`span!`](crate::span)).
pub fn field(v: impl ToField) -> Json {
    v.to_field()
}

// ---------------------------------------------------------------------
// current-telemetry context

thread_local! {
    static CURRENT: RefCell<Option<Arc<Telemetry>>> = const { RefCell::new(None) };
}

/// The calling thread's telemetry, or the disabled instance when none
/// was installed.
pub fn current() -> Arc<Telemetry> {
    CURRENT
        .with(|c| c.borrow().clone())
        .unwrap_or_else(Telemetry::disabled)
}

/// Install `tel` as the calling thread's telemetry until the returned
/// guard drops (the previous value is restored). Pipeline drivers
/// re-install on every thread they spawn.
pub fn set_current(tel: Arc<Telemetry>) -> CurrentGuard {
    let prev = CURRENT.with(|c| c.replace(Some(tel)));
    CurrentGuard { prev }
}

/// Restores the previous thread-local telemetry on drop.
pub struct CurrentGuard {
    prev: Option<Arc<Telemetry>>,
}

impl Drop for CurrentGuard {
    fn drop(&mut self) {
        let prev = self.prev.take();
        CURRENT.with(|c| *c.borrow_mut() = prev);
    }
}

// ---------------------------------------------------------------------
// snapshots

/// Point-in-time view of one histogram.
#[derive(Clone, Debug, PartialEq)]
pub struct HistSnapshot {
    pub count: u64,
    pub sum: f64,
    /// Per-bucket counts, `BUCKET_BOUNDS.len() + 1` long (+Inf last).
    pub buckets: Vec<u64>,
}

/// Point-in-time view of a registry; `Default` is the empty snapshot a
/// disabled run reports.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Snapshot {
    pub run_id: String,
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, f64>,
    pub histograms: BTreeMap<String, HistSnapshot>,
}

impl Snapshot {
    /// True when nothing was recorded (the disabled-registry snapshot).
    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.histograms.is_empty()
    }

    /// The `metrics.json` document (schema:
    /// `python/compile/telemetry_schema.json`).
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("run_id".to_string(), Json::Str(self.run_id.clone()));
        o.insert(
            "bucket_bounds".to_string(),
            Json::Arr(BUCKET_BOUNDS.iter().map(|&b| Json::Num(b)).collect()),
        );
        o.insert(
            "counters".to_string(),
            Json::Obj(
                self.counters
                    .iter()
                    .map(|(k, &v)| (k.clone(), Json::Num(v as f64)))
                    .collect(),
            ),
        );
        o.insert(
            "gauges".to_string(),
            Json::Obj(
                self.gauges
                    .iter()
                    .map(|(k, &v)| (k.clone(), Json::Num(v)))
                    .collect(),
            ),
        );
        o.insert(
            "histograms".to_string(),
            Json::Obj(
                self.histograms
                    .iter()
                    .map(|(k, h)| {
                        let mut ho = BTreeMap::new();
                        ho.insert("count".to_string(), Json::Num(h.count as f64));
                        ho.insert("sum".to_string(), Json::Num(h.sum));
                        ho.insert(
                            "buckets".to_string(),
                            Json::Arr(
                                h.buckets
                                    .iter()
                                    .map(|&b| Json::Num(b as f64))
                                    .collect(),
                            ),
                        );
                        (k.clone(), Json::Obj(ho))
                    })
                    .collect(),
            ),
        );
        Json::Obj(o)
    }

    /// Prometheus text exposition (format 0.0.4): counters as `_total`,
    /// histograms as cumulative `_bucket{le=...}` + `_sum` + `_count`.
    pub fn prometheus_text(&self) -> String {
        fn sanitize(name: &str) -> String {
            let mut s = String::with_capacity(name.len() + 4);
            s.push_str("daq_");
            for ch in name.chars() {
                s.push(if ch.is_ascii_alphanumeric() { ch } else { '_' });
            }
            s
        }
        let mut out = String::new();
        for (name, &v) in &self.counters {
            let n = sanitize(name);
            out.push_str(&format!("# TYPE {n}_total counter\n{n}_total {v}\n"));
        }
        for (name, &v) in &self.gauges {
            let n = sanitize(name);
            out.push_str(&format!("# TYPE {n} gauge\n{n} {}\n", Json::Num(v)));
        }
        for (name, h) in &self.histograms {
            let n = sanitize(name);
            out.push_str(&format!("# TYPE {n} histogram\n"));
            let mut cum = 0u64;
            for (i, &b) in h.buckets.iter().enumerate() {
                cum += b;
                let le = match BUCKET_BOUNDS.get(i) {
                    Some(&bound) => format!("{}", Json::Num(bound)),
                    None => "+Inf".to_string(),
                };
                out.push_str(&format!("{n}_bucket{{le=\"{le}\"}} {cum}\n"));
            }
            out.push_str(&format!("{n}_sum {}\n", Json::Num(h.sum)));
            out.push_str(&format!("{n}_count {}\n", h.count));
        }
        out
    }

    /// Phase-attribution table over the `.seconds` span histograms
    /// (share = fraction of the summed span time). None when no spans
    /// recorded.
    pub fn phase_table(&self) -> Option<Table> {
        let phases: Vec<(&str, &HistSnapshot)> = self
            .histograms
            .iter()
            .filter_map(|(name, h)| {
                name.strip_suffix(".seconds").map(|p| (p, h))
            })
            .filter(|(_, h)| h.count > 0)
            .collect();
        if phases.is_empty() {
            return None;
        }
        let total: f64 = phases.iter().map(|(_, h)| h.sum).sum();
        let mut t = Table::new(
            "phase attribution",
            &["phase", "count", "total s", "mean ms", "share"],
        );
        for (name, h) in phases {
            t.row(vec![
                name.to_string(),
                h.count.to_string(),
                format!("{:.3}", h.sum),
                format!("{:.3}", 1e3 * h.sum / h.count as f64),
                format!("{:.1}%", 100.0 * h.sum / total.max(1e-12)),
            ]);
        }
        Some(t)
    }

    /// Counters + gauges table. None when both are empty.
    pub fn counter_table(&self) -> Option<Table> {
        if self.counters.is_empty() && self.gauges.is_empty() {
            return None;
        }
        let mut t = Table::new("telemetry counters", &["metric", "value"]);
        for (name, &v) in &self.counters {
            t.row(vec![name.clone(), v.to_string()]);
        }
        for (name, &v) in &self.gauges {
            let shown = if v.fract() == 0.0 && v.abs() < 1e15 {
                format!("{}", v as i64)
            } else {
                format!("{v:.3}")
            };
            t.row(vec![name.clone(), shown]);
        }
        Some(t)
    }

    /// End-of-run rendering: phase attribution + counters, or empty when
    /// nothing was recorded.
    pub fn render(&self) -> String {
        let mut out = String::new();
        if let Some(t) = self.phase_table() {
            out.push_str(&t.render());
        }
        if let Some(t) = self.counter_table() {
            out.push_str(&t.render());
        }
        out
    }
}

// ---------------------------------------------------------------------
// metrics endpoint

/// Background `GET /metrics` server over `std::net::TcpListener`;
/// shuts its thread down on drop.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9184`, port 0 for ephemeral) and
    /// serve `tel`'s live snapshot as Prometheus text.
    pub fn bind(addr: &str, tel: Arc<Telemetry>) -> Result<MetricsServer> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("bind metrics endpoint {addr:?}"))?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                if let Ok(mut stream) = conn {
                    let _ = handle_conn(&mut stream, &tel);
                }
            }
        });
        Ok(MetricsServer { addr: local, stop, handle: Some(handle) })
    }

    /// The bound listen address (useful with a `:0` ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // unblock the accept loop with a throwaway connection
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_millis(200));
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(stream: &mut TcpStream, tel: &Telemetry) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(500)))?;
    let mut request = String::new();
    BufReader::new(&mut *stream).read_line(&mut request)?;
    let mut parts = request.split_whitespace();
    let (method, path) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    let (status, body) = if method == "GET" && (path == "/metrics" || path == "/") {
        ("200 OK", tel.snapshot().prometheus_text())
    } else {
        ("404 Not Found", "not found\n".to_string())
    };
    let head = format!(
        "HTTP/1.1 {status}\r\nContent-Type: text/plain; version=0.0.4\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

// ---------------------------------------------------------------------
// leveled logging

/// `DAQ_LOG` levels, most to least severe.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum LogLevel {
    Warn,
    Info,
    Debug,
}

impl LogLevel {
    fn label(self) -> &'static str {
        match self {
            LogLevel::Warn => "warn",
            LogLevel::Info => "info",
            LogLevel::Debug => "debug",
        }
    }
}

/// Parse a `DAQ_LOG` value; anything unrecognized falls back to `info`.
pub fn parse_log_level(s: &str) -> LogLevel {
    match s.trim().to_ascii_lowercase().as_str() {
        "warn" | "warning" | "error" => LogLevel::Warn,
        "debug" | "trace" => LogLevel::Debug,
        _ => LogLevel::Info,
    }
}

fn log_threshold() -> LogLevel {
    static THRESHOLD: OnceLock<LogLevel> = OnceLock::new();
    *THRESHOLD.get_or_init(|| {
        std::env::var("DAQ_LOG")
            .map(|v| parse_log_level(&v))
            .unwrap_or(LogLevel::Info)
    })
}

/// The one leveled way the binary talks: stderr, gated by `DAQ_LOG`.
pub fn log(level: LogLevel, msg: &str) {
    if level <= log_threshold() {
        eprintln!("[daq {}] {msg}", level.label());
    }
}

/// [`log`] at warn level.
pub fn warn(msg: &str) {
    log(LogLevel::Warn, msg);
}

/// [`log`] at info level.
pub fn info(msg: &str) {
    log(LogLevel::Info, msg);
}

/// [`log`] at debug level.
pub fn debug(msg: &str) {
    log(LogLevel::Debug, msg);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_default_is_truly_passive() {
        let tel = Telemetry::disabled();
        assert!(!tel.enabled());
        let c = tel.counter("x");
        c.add(5);
        assert_eq!(c.value(), 0, "disabled counter must stay inert");
        tel.gauge("g").set(1.0);
        let h = tel.histogram("h");
        h.observe(1.0);
        assert!(!h.is_enabled());
        drop(h.start_timer());
        drop(tel.span("s"));
        tel.event("e", &[]);
        let snap = tel.snapshot();
        assert!(snap.is_empty());
        assert!(snap.render().is_empty());
        assert!(snap.prometheus_text().is_empty());
        // without an installed context, current() IS the disabled instance
        let cur = current();
        assert!(!cur.enabled());
    }

    #[test]
    fn counters_gauges_histograms_round_trip_through_snapshot() {
        let tel = Telemetry::new("t1");
        let c = tel.counter("stream.retries");
        c.add(2);
        c.incr();
        tel.gauge("serve.slots").set(4.0);
        let h = tel.histogram("stream.compute.seconds");
        h.observe(3e-6);
        h.observe(3e-6);
        h.observe(0.5);
        let snap = tel.snapshot();
        assert_eq!(snap.run_id, "t1");
        assert_eq!(snap.counters["stream.retries"], 3);
        assert_eq!(snap.gauges["serve.slots"], 4.0);
        let hs = &snap.histograms["stream.compute.seconds"];
        assert_eq!(hs.count, 3);
        assert!((hs.sum - 0.500006).abs() < 1e-12);
        assert_eq!(hs.buckets.len(), BUCKET_BOUNDS.len() + 1);
        assert_eq!(hs.buckets.iter().sum::<u64>(), 3);
        assert_eq!(hs.buckets[1], 2, "3e-6 falls in the (1e-6, 4e-6] bucket");
    }

    #[test]
    fn concurrent_counting_is_deterministic_for_any_thread_count() {
        // the commuting-updates contract behind the worker-determinism
        // acceptance test: N increments land identically however they
        // are sharded across threads
        let observe = |threads: usize| -> Snapshot {
            let tel = Telemetry::new("det");
            let c = tel.counter("events");
            let h = tel.histogram("sizes");
            std::thread::scope(|s| {
                for t in 0..threads {
                    let (c, h) = (c.clone(), h.clone());
                    s.spawn(move || {
                        for i in 0..240 / threads {
                            c.incr();
                            h.observe(((t + i) % 7 + 1) as f64);
                        }
                    });
                }
            });
            tel.snapshot()
        };
        let one = observe(1);
        let four = observe(4);
        assert_eq!(one.counters, four.counters);
        // same multiset of integer observations → identical buckets+sum
        let (a, b) = (&one.histograms["sizes"], &four.histograms["sizes"]);
        assert_eq!(a.count, b.count);
        assert_eq!(a.buckets, b.buckets);
        assert_eq!(a.sum.to_bits(), b.sum.to_bits());
    }

    #[test]
    fn spans_record_into_seconds_histograms() {
        let tel = Telemetry::new("spans");
        {
            let _s = tel.span("work");
        }
        {
            let _s = crate::span!(&*tel, "work", "unit" = "l0.wq", "idx" = 3usize);
        }
        let snap = tel.snapshot();
        assert_eq!(snap.histograms["work.seconds"].count, 2);
        let table = snap.phase_table().expect("spans recorded");
        assert!(table.n_rows() >= 1);
    }

    #[test]
    fn current_context_scopes_and_restores() {
        let tel = Telemetry::new("ctx");
        {
            let _g = set_current(tel.clone());
            assert!(current().enabled());
            assert_eq!(current().run_id(), "ctx");
            // nested scope restores the outer instance
            {
                let inner = Telemetry::new("inner");
                let _g2 = set_current(inner);
                assert_eq!(current().run_id(), "inner");
            }
            assert_eq!(current().run_id(), "ctx");
            // other threads are unaffected
            std::thread::scope(|s| {
                s.spawn(|| assert!(!current().enabled()));
            });
        }
        assert!(!current().enabled());
    }

    #[test]
    fn trace_sink_writes_monotonic_jsonl() {
        let path = std::env::temp_dir()
            .join(format!("daq_tel_trace_{}.jsonl", std::process::id()));
        let tel = Telemetry::new("trace");
        tel.set_trace_out(&path).unwrap();
        drop(tel.span("a"));
        tel.event("retry", &[("attempt", field(1usize))]);
        drop(tel.span_with("b", vec![("unit", field("l0.wq"))]));
        let text = std::fs::read_to_string(&path).unwrap();
        let mut last = -1.0f64;
        let mut names = Vec::new();
        for line in text.lines() {
            let j = Json::parse(line).unwrap();
            for key in ["ts_us", "run", "kind", "name"] {
                assert!(j.get(key).is_some(), "{line} missing {key}");
            }
            let ts = j.get("ts_us").unwrap().as_f64().unwrap();
            assert!(ts >= last, "timestamps must be monotone in file order");
            last = ts;
            names.push(j.get("name").unwrap().as_str().unwrap().to_string());
            if j.get("kind").unwrap().as_str() == Some("span") {
                assert!(j.get("dur_us").is_some(), "{line}");
            }
        }
        assert_eq!(names, ["a", "retry", "b"]);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn prometheus_text_is_well_formed() {
        let tel = Telemetry::new("prom");
        tel.counter("serve.shed").add(2);
        tel.gauge("serve.resident_bytes").set(1024.0);
        tel.histogram("serve.decode.seconds").observe(0.002);
        let text = tel.snapshot().prometheus_text();
        assert!(text.contains("# TYPE daq_serve_shed_total counter"));
        assert!(text.contains("daq_serve_shed_total 2"));
        assert!(text.contains("# TYPE daq_serve_resident_bytes gauge"));
        assert!(text.contains("# TYPE daq_serve_decode_seconds histogram"));
        assert!(text.contains("daq_serve_decode_seconds_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("daq_serve_decode_seconds_count 1"));
        // every non-comment line is "name{labels} value"
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let (name, value) = line.rsplit_once(' ').unwrap();
            assert!(name.starts_with("daq_"), "{line}");
            assert!(value.parse::<f64>().is_ok(), "{line}");
        }
    }

    #[test]
    fn metrics_endpoint_serves_exposition_text() {
        use std::io::Read;
        let tel = Telemetry::new("http");
        tel.counter("hits").add(7);
        let srv = MetricsServer::bind("127.0.0.1:0", tel.clone()).unwrap();
        let mut conn = TcpStream::connect(srv.addr()).unwrap();
        write!(conn, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut resp = String::new();
        conn.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 200 OK\r\n"), "{resp}");
        assert!(resp.contains("daq_hits_total 7"), "{resp}");
        // unknown paths 404 without killing the server
        let mut conn = TcpStream::connect(srv.addr()).unwrap();
        write!(conn, "GET /nope HTTP/1.1\r\n\r\n").unwrap();
        let mut resp = String::new();
        conn.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 404"), "{resp}");
        drop(srv); // Drop joins the listener thread
    }

    #[test]
    fn metrics_json_matches_committed_schema_shape() {
        let tel = Telemetry::new("schema");
        tel.counter("c").add(1);
        tel.histogram("h.seconds").observe(0.01);
        let j = tel.snapshot().to_json();
        for key in ["run_id", "bucket_bounds", "counters", "gauges", "histograms"] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
        let h = j.get("histograms").unwrap().get("h.seconds").unwrap();
        assert_eq!(
            h.get("buckets").unwrap().as_arr().unwrap().len(),
            BUCKET_BOUNDS.len() + 1
        );
        // round-trips through the parser (what the python checker reads)
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.get("counters").unwrap().get("c").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn log_level_parsing() {
        assert_eq!(parse_log_level("warn"), LogLevel::Warn);
        assert_eq!(parse_log_level("WARNING"), LogLevel::Warn);
        assert_eq!(parse_log_level("debug"), LogLevel::Debug);
        assert_eq!(parse_log_level("info"), LogLevel::Info);
        assert_eq!(parse_log_level("bogus"), LogLevel::Info);
        assert!(LogLevel::Warn < LogLevel::Info);
        assert!(LogLevel::Info < LogLevel::Debug);
    }
}
