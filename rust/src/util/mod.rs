//! In-repo substrates for everything the offline crate registry lacks:
//! PRNG, property testing, CLI parsing, JSON, timing, telemetry, and a
//! thread pool.
//!
//! The offline registry only carries the `xla` crate closure, so the usual
//! suspects (rand, proptest, clap, serde_json, criterion, rayon/tokio,
//! prometheus/tracing) are reimplemented here at the scale this project
//! needs.

pub mod bench;
pub mod cliargs;
pub mod crc32;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod telemetry;
pub mod threadpool;
pub mod timer;
