//! Lightweight wall-clock timing helpers used across benches and the
//! coordinator's progress reporting.
//!
//! Phase breakdowns live in `util::telemetry` (spans recording into
//! `<name>.seconds` histograms); this module keeps only the primitives
//! that don't need a registry.

use std::time::Instant;

/// Measure one closure; returns (result, seconds).
pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Latency statistics accumulator (used by the serving loop).
#[derive(Default, Clone)]
pub struct LatencyStats {
    samples_ms: Vec<f64>,
}

impl LatencyStats {
    pub fn record(&mut self, ms: f64) {
        self.samples_ms.push(ms);
    }

    pub fn count(&self) -> usize {
        self.samples_ms.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples_ms.is_empty() {
            return 0.0;
        }
        self.samples_ms.iter().sum::<f64>() / self.samples_ms.len() as f64
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples_ms.is_empty() {
            return 0.0;
        }
        let mut v = self.samples_ms.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx.min(v.len() - 1)]
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.2}ms p50={:.2}ms p95={:.2}ms p99={:.2}ms max={:.2}ms",
            self.count(),
            self.mean(),
            self.percentile(50.0),
            self.percentile(95.0),
            self.percentile(99.0),
            self.percentile(100.0),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_measures() {
        let (v, secs) = time(|| {
            std::thread::sleep(std::time::Duration::from_millis(10));
            42
        });
        assert_eq!(v, 42);
        assert!(secs >= 0.009, "{secs}");
    }

    #[test]
    fn latency_percentiles() {
        let mut s = LatencyStats::default();
        for i in 1..=100 {
            s.record(i as f64);
        }
        assert_eq!(s.count(), 100);
        assert!((s.mean() - 50.5).abs() < 1e-9);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert!((s.percentile(50.0) - 50.0).abs() <= 1.0);
    }

    #[test]
    fn empty_latency_stats() {
        let s = LatencyStats::default();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(99.0), 0.0);
    }
}
