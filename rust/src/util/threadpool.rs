//! Work-queue thread pool for the coordinator (rayon/tokio are not in the
//! offline registry; the coordinator's needs — a bounded pool draining a
//! job queue with results collected in completion order — fit in ~100
//! lines of std).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

/// Run `jobs` over `workers` threads; returns results in *input order*.
///
/// Jobs are pulled from a shared queue (work stealing degenerates to a
/// single shared deque at this scale). Panics in jobs propagate.
pub fn run_jobs<T, F>(workers: usize, jobs: Vec<F>) -> Vec<T>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    let queue: Arc<Mutex<Vec<(usize, F)>>> =
        Arc::new(Mutex::new(jobs.into_iter().enumerate().rev().collect()));
    let (tx, rx) = mpsc::channel::<(usize, thread::Result<T>)>();

    let mut handles = Vec::with_capacity(workers);
    for _ in 0..workers {
        let queue = Arc::clone(&queue);
        let tx = tx.clone();
        handles.push(thread::spawn(move || loop {
            let job = queue.lock().unwrap().pop();
            match job {
                Some((idx, f)) => {
                    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
                    if tx.send((idx, res)).is_err() {
                        return;
                    }
                }
                None => return,
            }
        }));
    }
    drop(tx);

    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for (idx, res) in rx {
        match res {
            Ok(v) => slots[idx] = Some(v),
            Err(e) => {
                // drain workers before propagating
                for h in handles.drain(..) {
                    let _ = h.join();
                }
                std::panic::resume_unwind(e);
            }
        }
    }
    for h in handles {
        h.join().expect("worker thread panicked after completion");
    }
    slots.into_iter().map(|s| s.expect("missing job result")).collect()
}

/// Parallel map preserving order.
pub fn par_map<I, T, F>(workers: usize, items: Vec<I>, f: F) -> Vec<T>
where
    I: Send + 'static,
    T: Send + 'static,
    F: Fn(I) -> T + Send + Sync + 'static,
{
    let f = Arc::new(f);
    let jobs: Vec<_> = items
        .into_iter()
        .map(|item| {
            let f = Arc::clone(&f);
            move || f(item)
        })
        .collect();
    run_jobs(workers, jobs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = par_map(4, (0..100).collect::<Vec<_>>(), |i| i * 2);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker() {
        let out = par_map(1, vec![3, 1, 2], |i| i + 1);
        assert_eq!(out, vec![4, 2, 3]);
    }

    #[test]
    fn more_workers_than_jobs() {
        let out = par_map(16, vec![1], |i| i);
        assert_eq!(out, vec![1]);
    }

    #[test]
    fn empty_jobs() {
        let out: Vec<i32> = par_map(4, Vec::<i32>::new(), |i| i);
        assert!(out.is_empty());
    }

    #[test]
    #[should_panic]
    fn propagates_panics() {
        par_map(2, vec![1, 2, 3], |i| {
            if i == 2 {
                panic!("boom");
            }
            i
        });
    }

    #[test]
    fn actually_parallel_when_multicore() {
        // jobs record their thread ids; on a 1-core box this may be 1
        let ids = par_map(4, (0..32).collect::<Vec<_>>(), |_| {
            std::thread::sleep(std::time::Duration::from_millis(1));
            format!("{:?}", std::thread::current().id())
        });
        assert_eq!(ids.len(), 32);
    }
}
