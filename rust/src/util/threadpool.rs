//! Work-queue thread pool for the coordinator and the tiled sweep
//! (rayon/tokio are not in the offline registry; the needs here — a
//! bounded pool draining a job queue with results in input order, plus a
//! scoped borrow-friendly parallel map — fit in a couple hundred lines of
//! std).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

/// Run `jobs` over `workers` threads; returns results in *input order*.
///
/// Jobs are pulled from a shared queue (work stealing degenerates to a
/// single shared deque at this scale). Panics in jobs propagate.
pub fn run_jobs<T, F>(workers: usize, jobs: Vec<F>) -> Vec<T>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    let queue: Arc<Mutex<Vec<(usize, F)>>> =
        Arc::new(Mutex::new(jobs.into_iter().enumerate().rev().collect()));
    let (tx, rx) = mpsc::channel::<(usize, thread::Result<T>)>();

    let mut handles = Vec::with_capacity(workers);
    for _ in 0..workers {
        let queue = Arc::clone(&queue);
        let tx = tx.clone();
        handles.push(thread::spawn(move || loop {
            let job = queue.lock().unwrap().pop();
            match job {
                Some((idx, f)) => {
                    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
                    if tx.send((idx, res)).is_err() {
                        return;
                    }
                }
                None => return,
            }
        }));
    }
    drop(tx);

    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for (idx, res) in rx {
        match res {
            Ok(v) => slots[idx] = Some(v),
            Err(e) => {
                // clear the pending queue first so panic propagation only
                // waits for the jobs already in flight, not for every
                // remaining queued job to run to completion
                queue.lock().unwrap().clear();
                for h in handles.drain(..) {
                    let _ = h.join();
                }
                std::panic::resume_unwind(e);
            }
        }
    }
    for h in handles {
        h.join().expect("worker thread panicked after completion");
    }
    slots.into_iter().map(|s| s.expect("missing job result")).collect()
}

/// Parallel map preserving order.
pub fn par_map<I, T, F>(workers: usize, items: Vec<I>, f: F) -> Vec<T>
where
    I: Send + 'static,
    T: Send + 'static,
    F: Fn(I) -> T + Send + Sync + 'static,
{
    let f = Arc::new(f);
    let jobs: Vec<_> = items
        .into_iter()
        .map(|item| {
            let f = Arc::clone(&f);
            move || f(item)
        })
        .collect();
    run_jobs(workers, jobs)
}

/// Scoped parallel map over a slice: unlike [`par_map`], the items and
/// the closure may *borrow* (no `'static` bound) — the workers run inside
/// `std::thread::scope`. Results come back in input order, and because
/// each result is computed independently and placed by index, the output
/// is bitwise-deterministic for any `workers` value.
///
/// This is the engine under `metrics::SweepPlan`'s tile evaluation: tiles
/// are cheap range descriptors borrowing the plan's arrays.
pub fn par_map_slice<I, T, F>(workers: usize, items: &[I], f: F) -> Vec<T>
where
    I: Sync,
    T: Send,
    F: Fn(&I) -> T + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        return items.iter().map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let chunks: Vec<Vec<(usize, T)>> = thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                s.spawn(|| {
                    let mut out = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        out.push((i, f(&items[i])));
                    }
                    out
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| match h.join() {
                Ok(v) => v,
                Err(e) => std::panic::resume_unwind(e),
            })
            .collect()
    });
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for (i, v) in chunks.into_iter().flatten() {
        slots[i] = Some(v);
    }
    slots
        .into_iter()
        .map(|s| s.expect("missing job result"))
        .collect()
}

/// Scoped parallel map over a *mutable* slice: like [`par_map_slice`] but
/// each item is handed to the closure as `&mut I`, so a worker may mutate
/// its item in place (a decode slot advancing its own KV cache) while the
/// closure's return value carries whatever the coordinator needs back.
///
/// The slice is split into `workers` contiguous chunks, one scoped thread
/// per chunk, each walking its chunk in order. Results are flattened back
/// in chunk order, so the output is index-aligned with `items` and —
/// because no item is touched by more than one thread and each result is
/// computed independently — bitwise-deterministic for any `workers` value.
/// `workers <= 1` (or a single item) degenerates to a serial loop with no
/// threads spawned.
///
/// This is the engine under the serve scheduler's decode tick: each active
/// slot steps (or prefills) independently, and the coordinator merges the
/// returned logits in fixed slot order.
pub fn par_map_mut<I, T, F>(workers: usize, items: &mut [I], f: F) -> Vec<T>
where
    I: Send,
    T: Send,
    F: Fn(&mut I) -> T + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        return items.iter_mut().map(f).collect();
    }
    let chunk = n.div_ceil(workers);
    let fr = &f;
    let per_chunk: Vec<Vec<T>> = thread::scope(|s| {
        let handles: Vec<_> = items
            .chunks_mut(chunk)
            .map(|ch| s.spawn(move || ch.iter_mut().map(fr).collect::<Vec<T>>()))
            .collect();
        handles
            .into_iter()
            .map(|h| match h.join() {
                Ok(v) => v,
                Err(e) => std::panic::resume_unwind(e),
            })
            .collect()
    });
    per_chunk.into_iter().flatten().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = par_map(4, (0..100).collect::<Vec<_>>(), |i| i * 2);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker() {
        let out = par_map(1, vec![3, 1, 2], |i| i + 1);
        assert_eq!(out, vec![4, 2, 3]);
    }

    #[test]
    fn more_workers_than_jobs() {
        let out = par_map(16, vec![1], |i| i);
        assert_eq!(out, vec![1]);
    }

    #[test]
    fn empty_jobs() {
        let out: Vec<i32> = par_map(4, Vec::<i32>::new(), |i| i);
        assert!(out.is_empty());
    }

    #[test]
    #[should_panic]
    fn propagates_panics() {
        par_map(2, vec![1, 2, 3], |i| {
            if i == 2 {
                panic!("boom");
            }
            i
        });
    }

    #[test]
    fn panic_clears_pending_queue() {
        use std::time::Duration;
        let ran = Arc::new(AtomicUsize::new(0));
        let jobs: Vec<_> = (0..200usize)
            .map(|i| {
                let ran = Arc::clone(&ran);
                move || {
                    if i == 0 {
                        panic!("boom");
                    }
                    thread::sleep(Duration::from_millis(5));
                    ran.fetch_add(1, Ordering::SeqCst);
                }
            })
            .collect();
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_jobs(2, jobs)
        }));
        assert!(res.is_err());
        // without queue clearing the drain runs ALL 199 remaining sleep
        // jobs before propagating; with it only the jobs popped before
        // the collector clears the queue run. Counter-based (not
        // wall-clock) so a loaded CI box can't flake the assertion.
        let ran = ran.load(Ordering::SeqCst);
        assert!(ran < 150, "queue was not cleared on panic: {ran} jobs ran");
    }

    #[test]
    fn slice_map_matches_serial_and_preserves_order() {
        let items: Vec<u64> = (0..257).collect();
        let serial: Vec<u64> = items.iter().map(|&i| i * i + 1).collect();
        for workers in [1, 2, 5, 16] {
            let out = par_map_slice(workers, &items, |&i| i * i + 1);
            assert_eq!(out, serial, "workers={workers}");
        }
    }

    #[test]
    fn slice_map_borrows_environment() {
        // the whole point vs par_map: no 'static — borrow a local buffer
        let data: Vec<f64> = (0..1000).map(|i| i as f64 * 0.25).collect();
        let tiles: Vec<(usize, usize)> = vec![(0, 400), (400, 900), (900, 1000)];
        let sums = par_map_slice(4, &tiles, |&(lo, hi)| {
            data[lo..hi].iter().sum::<f64>()
        });
        let total: f64 = sums.iter().sum();
        assert_eq!(total, data.iter().sum::<f64>());
    }

    #[test]
    fn slice_map_empty() {
        let out: Vec<u8> = par_map_slice(4, &[] as &[u8], |&b| b);
        assert!(out.is_empty());
    }

    #[test]
    #[should_panic]
    fn slice_map_propagates_panics() {
        par_map_slice(3, &[1, 2, 3, 4], |&i| {
            if i == 3 {
                panic!("tile boom");
            }
            i
        });
    }

    #[test]
    fn mut_map_mutates_in_place_and_preserves_order() {
        let serial: Vec<u64> = (0..97u64).map(|i| i * 3).collect();
        for workers in [1, 2, 4, 16] {
            let mut items: Vec<u64> = (0..97).collect();
            let out = par_map_mut(workers, &mut items, |i| {
                *i *= 3;
                *i
            });
            assert_eq!(out, serial, "workers={workers}");
            assert_eq!(items, serial, "workers={workers}");
        }
    }

    #[test]
    fn mut_map_empty() {
        let mut items: Vec<u8> = Vec::new();
        let out: Vec<u8> = par_map_mut(4, &mut items, |&mut b| b);
        assert!(out.is_empty());
    }

    #[test]
    #[should_panic]
    fn mut_map_propagates_panics() {
        let mut items = vec![1, 2, 3, 4];
        par_map_mut(2, &mut items, |i| {
            if *i == 3 {
                panic!("slot boom");
            }
            *i
        });
    }

    #[test]
    fn actually_parallel_when_multicore() {
        // jobs record their thread ids; on a 1-core box this may be 1
        let ids = par_map(4, (0..32).collect::<Vec<_>>(), |_| {
            std::thread::sleep(std::time::Duration::from_millis(1));
            format!("{:?}", std::thread::current().id())
        });
        assert_eq!(ids.len(), 32);
    }
}
