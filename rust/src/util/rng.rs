//! xorshift64* PRNG — deterministic, seedable, dependency-free.
//!
//! Used by tests, property testing, the benchmark workload generators and
//! the serving-load generator. NOT cryptographic.

/// xorshift64* generator (Vigna 2016). Never yields state 0.
#[derive(Clone, Debug)]
pub struct XorShift {
    state: u64,
}

impl XorShift {
    pub fn new(seed: u64) -> Self {
        // splitmix64 the seed so small seeds diverge immediately
        let mut z = seed.wrapping_add(0x9E3779B97F4A7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        let state = (z ^ (z >> 31)).max(1);
        Self { state }
    }

    #[inline]
    pub fn u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [0, 1) with f64 resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Vector of normals with the given std.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = XorShift::new(42);
        let mut b = XorShift::new(42);
        for _ in 0..100 {
            assert_eq!(a.u64(), b.u64());
        }
    }

    #[test]
    fn seeds_diverge() {
        let mut a = XorShift::new(1);
        let mut b = XorShift::new(2);
        assert_ne!(a.u64(), b.u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = XorShift::new(3);
        for _ in 0..10_000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = XorShift::new(4);
        let xs = r.normal_vec(200_000, 1.0);
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
            / xs.len() as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = XorShift::new(5);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }
}
