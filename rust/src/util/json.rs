//! Minimal JSON parser and serializer — enough to read
//! `artifacts/manifest.json` / `train_summary.json` and to write the
//! sharded-store manifest and the streaming pipeline's resume journal
//! (serde is not in the offline registry).
//!
//! Supports the full JSON grammar except `\u` escapes beyond the BMP.
//! The serializer ([`Json`]'s `Display`) emits compact one-line JSON;
//! finite `f64` values round-trip exactly through parse (Rust's shortest
//! `Display` repr), which the resume journal relies on for bit-exact
//! restart statistics.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Convenience: `obj.path(&["a", "b"])` == `obj["a"]["b"]`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }
}

impl fmt::Display for Json {
    /// Compact serialization. Non-finite numbers (which JSON cannot
    /// represent) serialize as `null`; integral values within the exact
    /// i64/f64 range print without a fractional part.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                // -0.0 must not take the integer path: "0" parses back as
                // +0.0, breaking the exact-bits round-trip ("-0" is valid
                // JSON and Rust's f64 Display emits it)
                if !n.is_finite() {
                    f.write_str("null")
                } else if n.fract() == 0.0
                    && !n.is_sign_negative()
                    && *n < 9.007_199_254_740_992e15
                {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_json_string(f, s),
            Json::Arr(v) => {
                f.write_str("[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{x}")?;
                }
                f.write_str("]")
            }
            Json::Obj(m) => {
                f.write_str("{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_json_string(f, k)?;
                    f.write_str(":")?;
                    write!(f, "{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

fn write_json_string(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    f.write_str("\"")?;
    for ch in s.chars() {
        match ch {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\t' => f.write_str("\\t")?,
            '\r' => f.write_str("\\r")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek().ok_or_else(|| self.err("unexpected eof"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("eof in string"))? {
                b'"' => {
                    self.pos += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("eof in escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| self.err("short \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| self.err("surrogate \\u escape"))?,
                            );
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let s = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        ) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let j = Json::parse(
            r#"{"n_candidates": 16, "sweeps": [{"shape": [128, 512],
                "file": "daq_sweep_128x512.hlo.txt"}], "ok": true}"#,
        )
        .unwrap();
        assert_eq!(j.get("n_candidates").unwrap().as_usize(), Some(16));
        let sweep = &j.get("sweeps").unwrap().as_arr().unwrap()[0];
        assert_eq!(sweep.get("file").unwrap().as_str(),
                   Some("daq_sweep_128x512.hlo.txt"));
        let shape = sweep.get("shape").unwrap().as_arr().unwrap();
        assert_eq!(shape[1].as_usize(), Some(512));
    }

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn serializer_roundtrips() {
        let src = r#"{"a": [1, 2.5, true, null], "s": "line\n\"q\"", "n": -3}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
        // compact: no spaces outside strings
        assert!(out.contains("\"a\":[1,2.5,true,null]"), "{out}");
    }

    #[test]
    fn serializer_f64_exact_roundtrip() {
        // shortest-repr Display must parse back to the identical bits —
        // the resume journal depends on this for restart-exact stats
        for v in [0.1f64, 1.0 / 3.0, 1.05f32 as f64, 2.5e-300, 123456789.25, -0.0, -3.0]
        {
            let s = Json::Num(v).to_string();
            let back = Json::parse(&s).unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), v.to_bits(), "{v} via {s}");
        }
        // the signed-zero case specifically must not flatten to "0"
        assert_eq!(Json::Num(-0.0).to_string(), "-0");
        // integral values print without a fractional part
        assert_eq!(Json::Num(16.0).to_string(), "16");
        // non-finite degrades to null (JSON has no representation)
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn serializer_escapes_strings() {
        let j = Json::Str("a\"b\\c\nd\u{1}".into());
        let s = j.to_string();
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\\u0001\"");
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn nested_path() {
        let j = Json::parse(r#"{"a": {"b": {"c": 3}}}"#).unwrap();
        assert_eq!(j.path(&["a", "b", "c"]).unwrap().as_f64(), Some(3.0));
        assert!(j.path(&["a", "x"]).is_none());
    }
}
