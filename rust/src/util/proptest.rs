//! Mini property-testing framework (proptest is not in the offline
//! registry). Runs a property over many random cases; on failure, performs
//! a bounded shrink by retrying the failing case's seed neighbourhood with
//! smaller size hints, and reports the minimal seed found.
//!
//! ```no_run
//! use daq::util::proptest::{Config, run};
//! run("abs is non-negative", Config::default(), |g| {
//!     let x = g.f32_range(-100.0, 100.0);
//!     assert!(x.abs() >= 0.0);
//! });
//! ```
//!
//! (no_run: doctest binaries bypass the crate rpath and cannot locate
//! libxla_extension's libstdc++; the same code runs as a unit test below.)

use super::rng::XorShift;

/// Per-case value generator handed to properties.
pub struct Gen {
    rng: XorShift,
    /// Size hint in [0.0, 1.0]; shrinking lowers it so ranges tighten.
    pub size: f64,
}

impl Gen {
    fn new(seed: u64, size: f64) -> Self {
        Self { rng: XorShift::new(seed), size }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.u64()
    }

    pub fn usize_range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        let span = ((hi - lo) as f64 * self.size).ceil() as usize;
        lo + self.rng.below(span.max(1))
    }

    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        let span = (hi - lo) * self.size as f32;
        lo + self.rng.f32() * span
    }

    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        self.rng.normal_vec(n, std)
    }

    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.rng.below(items.len())]
    }

    pub fn bool(&mut self) -> bool {
        self.rng.u64() & 1 == 1
    }
}

#[derive(Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub shrink_rounds: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 64, seed: 0xDA0_5EED, shrink_rounds: 16 }
    }
}

/// Run `prop` over `cfg.cases` random cases. Panics (with the failing seed
/// and the smallest reproducing size) if any case fails.
pub fn run<F>(name: &str, cfg: Config, prop: F)
where
    F: Fn(&mut Gen) + std::panic::RefUnwindSafe,
{
    for case in 0..cfg.cases {
        let seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let failed = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed, 1.0);
            prop(&mut g);
        })
        .is_err();
        if failed {
            // shrink: retry with progressively smaller size hints
            let mut min_size = 1.0f64;
            for round in 0..cfg.shrink_rounds {
                let size = 1.0 / (2.0f64).powi(round as i32 + 1);
                let still_fails = std::panic::catch_unwind(|| {
                    let mut g = Gen::new(seed, size);
                    prop(&mut g);
                })
                .is_err();
                if still_fails {
                    min_size = size;
                } else {
                    break;
                }
            }
            panic!(
                "property '{name}' failed: case {case}, seed {seed:#x}, \
                 minimal size {min_size}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        run("sum of squares non-negative", Config::default(), |g| {
            let v = g.normal_vec(32, 1.0);
            assert!(v.iter().map(|x| x * x).sum::<f32>() >= 0.0);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails' failed")]
    fn failing_property_reports() {
        run(
            "always fails",
            Config { cases: 3, ..Config::default() },
            |g| {
                let x = g.f32_range(0.0, 1.0);
                assert!(x < 0.0, "x = {x}");
            },
        );
    }

    #[test]
    fn generator_ranges() {
        run("usize_range respects bounds", Config::default(), |g| {
            let v = g.usize_range(3, 10);
            assert!((3..=10).contains(&v));
        });
    }
}
