//! CRC-32 (ISO-HDLC / zlib) — the checksum behind the DTS v2 integrity
//! section, dependency-free and bit-identical to Python's `zlib.crc32`.
//!
//! Reflected polynomial 0xEDB88320, init and xorout 0xFFFFFFFF. The
//! lookup table is built in a `const` so the hot path is one table load
//! and one shift per byte.

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ 0xEDB8_8320 } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

const TABLE: [u32; 256] = build_table();

/// Incremental CRC-32 state, for checksumming data that is produced in
/// pieces (the shard writer streams payloads section by section).
#[derive(Clone, Debug)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    pub fn new() -> Self {
        Self { state: 0xFFFF_FFFF }
    }

    #[inline]
    pub fn update(&mut self, bytes: &[u8]) {
        let mut crc = self.state;
        for &b in bytes {
            crc = (crc >> 8) ^ TABLE[((crc ^ b as u32) & 0xFF) as usize];
        }
        self.state = crc;
    }

    /// Final digest; the state itself is untouched, so interleaved
    /// peeking (verify-as-you-stream) stays valid.
    #[inline]
    pub fn finalize(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_vector() {
        // the canonical CRC-32/ISO-HDLC check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }

    #[test]
    fn zlib_parity_vectors() {
        // pinned against CPython: zlib.crc32(b"") etc.
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
        assert_eq!(crc32(b"hello world"), 0x0D4A_1185);
        assert_eq!(crc32(&[0u8; 32]), 0x190A_55AD);
    }

    #[test]
    fn streaming_matches_one_shot() {
        let data: Vec<u8> = (0..1024u32).map(|i| (i * 31 % 251) as u8).collect();
        let mut c = Crc32::new();
        for chunk in data.chunks(17) {
            c.update(chunk);
        }
        assert_eq!(c.finalize(), crc32(&data));
    }

    #[test]
    fn finalize_does_not_consume() {
        let mut c = Crc32::new();
        c.update(b"123");
        let _ = c.finalize();
        c.update(b"456789");
        assert_eq!(c.finalize(), crc32(b"123456789"));
    }
}
