//! Tiny CLI argument parser (clap is not in the offline registry).
//!
//! Grammar: `daq <subcommand> [--flag] [--key value] ...`.
//! Collects flags/options into maps; typed accessors with defaults.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    return Err("empty option name '--'".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.options.insert(name.to_string(), v);
                } else {
                    args.flags.push(name.to_string());
                }
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(a);
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name}: expected integer, got {v:?}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name}: expected number, got {v:?}")),
        }
    }

    /// Parse "--range lo,hi".
    pub fn range_or(&self, name: &str, default: (f32, f32)) -> Result<(f32, f32), String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => {
                let (lo, hi) = v
                    .split_once(',')
                    .ok_or_else(|| format!("--{name}: expected 'lo,hi', got {v:?}"))?;
                let lo: f32 = lo.trim().parse().map_err(|_| format!("--{name}: bad lo"))?;
                let hi: f32 = hi.trim().parse().map_err(|_| format!("--{name}: bad hi"))?;
                if lo >= hi || lo <= 0.0 {
                    return Err(format!("--{name}: need 0 < lo < hi, got {lo},{hi}"));
                }
                Ok((lo, hi))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("quantize --metric sign --range 0.8,1.25 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("quantize"));
        assert_eq!(a.get("metric"), Some("sign"));
        assert_eq!(a.range_or("range", (0.5, 2.0)).unwrap(), (0.8, 1.25));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn equals_form() {
        let a = parse("eval --ckpt=foo.dts");
        assert_eq!(a.get("ckpt"), Some("foo.dts"));
    }

    #[test]
    fn typed_defaults() {
        let a = parse("bench");
        assert_eq!(a.usize_or("iters", 10).unwrap(), 10);
        assert_eq!(a.f64_or("alpha", 1.5).unwrap(), 1.5);
        assert_eq!(a.str_or("out", "x"), "x");
    }

    #[test]
    fn bad_values_error() {
        let a = parse("bench --iters ten");
        assert!(a.usize_or("iters", 10).is_err());
        let b = parse("q --range 2,1");
        assert!(b.range_or("range", (0.5, 2.0)).is_err());
    }

    #[test]
    fn positional_args() {
        let a = parse("inspect file1.dts file2.dts");
        assert_eq!(a.subcommand.as_deref(), Some("inspect"));
        assert_eq!(a.positional, vec!["file1.dts", "file2.dts"]);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("serve --pjrt");
        assert!(a.flag("pjrt"));
    }
}
