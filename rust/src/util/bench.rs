//! Micro-benchmark harness (criterion is not in the offline registry).
//!
//! Each `cargo bench` target is a `harness = false` binary that uses
//! [`bench`] for warmed-up, repeated measurements with simple statistics,
//! and the `report` module for the paper-shaped tables.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub stddev_s: f64,
}

impl BenchResult {
    pub fn throughput(&self, items: f64) -> f64 {
        items / self.mean_s
    }

    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>10.3}ms  min {:>9.3}ms  max {:>9.3}ms  sd {:>8.3}ms  ({} iters)",
            self.name,
            self.mean_s * 1e3,
            self.min_s * 1e3,
            self.max_s * 1e3,
            self.stddev_s * 1e3,
            self.iters
        )
    }
}

/// Run `f` repeatedly: `warmup` unmeasured iterations, then `iters`
/// measured ones.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T)
    -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>()
        / samples.len() as f64;
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: mean,
        min_s: samples.iter().cloned().fold(f64::INFINITY, f64::min),
        max_s: samples.iter().cloned().fold(0.0, f64::max),
        stddev_s: var.sqrt(),
    }
}

/// Auto-calibrating variant: picks an iteration count so the measured
/// phase lasts roughly `target_s` seconds.
pub fn bench_auto<T>(name: &str, target_s: f64, mut f: impl FnMut() -> T)
    -> BenchResult {
    let t0 = Instant::now();
    std::hint::black_box(f());
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target_s / once).ceil() as usize).clamp(3, 10_000);
    bench(name, 1, iters, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = bench("noop-ish", 2, 5, || {
            (0..1000).map(|i: u64| i.wrapping_mul(7)).sum::<u64>()
        });
        assert_eq!(r.iters, 5);
        assert!(r.mean_s >= 0.0);
        assert!(r.min_s <= r.mean_s && r.mean_s <= r.max_s);
    }

    #[test]
    fn auto_calibrates() {
        let r = bench_auto("sleepy", 0.02, || {
            std::thread::sleep(std::time::Duration::from_millis(2))
        });
        assert!(r.iters >= 3);
    }

    #[test]
    fn line_formats() {
        let r = bench("fmt", 0, 3, || 1 + 1);
        assert!(r.line().contains("fmt"));
    }
}
