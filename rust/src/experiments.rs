//! Experiment drivers regenerating the paper's tables (DESIGN.md §4).
//!
//! Each driver runs the full pipeline (quantize → evaluate) for the rows
//! of one paper table and returns a `report::Table` whose columns mirror
//! the paper's. Shared by the CLI (`daq tables`), the benches
//! (`cargo bench`), and the examples.

use anyhow::{Context, Result};

use crate::coordinator::{run_pipeline, Engine, Method, PipelineConfig, PipelineOutcome};
use crate::eval::{eval_rubric, load_params, EvalSet, NativeForward, Params, PjrtForward};
use crate::eval::model_native::ModelCfg;
use crate::io::dts::Dts;
use crate::quant::{CodeFormat, Granularity};
use crate::report::{fmt3, fmt_l2, fmt_pct, na, Table};
use crate::runtime::Runtime;
use crate::search::Objective;

/// Everything the experiment drivers need, loaded once.
pub struct Lab {
    pub base: Dts,
    pub post: Dts,
    pub calib: Dts,
    pub style: EvalSet,
    pub general: EvalSet,
    pub cfg: ModelCfg,
    pub quantizable: Vec<String>,
    pub rt: Option<Runtime>,
    pub workers: usize,
}

impl Lab {
    /// Load from an artifacts directory (`make artifacts` output).
    pub fn open(dir: &str, use_pjrt: bool) -> Result<Lab> {
        let base = Dts::read(format!("{dir}/ckpt_base.dts"))
            .context("load base checkpoint (run `make artifacts`)")?;
        let post = Dts::read(format!("{dir}/ckpt_post.dts"))?;
        let calib = Dts::read(format!("{dir}/calib.dts"))?;
        let style = EvalSet::load(&format!("{dir}/eval_style.dts"))?;
        let general = EvalSet::load(&format!("{dir}/eval_general.dts"))?;
        let cfg = ModelCfg::from_meta(&post.meta)?;
        let rt = if use_pjrt { Some(Runtime::open(dir)?) } else { None };
        let quantizable = match &rt {
            Some(rt) => rt.manifest.quantizable.clone(),
            None => quantizable_from_names(&post),
        };
        let workers = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        Ok(Lab { base, post, calib, style, general, cfg, quantizable, rt, workers })
    }

    /// Score a parameter set on (Style, General).
    pub fn rubric(&self, params: &Params) -> Result<(f64, f64)> {
        if let Some(rt) = &self.rt {
            let fwd = PjrtForward { rt, params, batch: rt.manifest.eval_batch };
            Ok((eval_rubric(&fwd, &self.style)?, eval_rubric(&fwd, &self.general)?))
        } else {
            let fwd = NativeForward { params, cfg: self.cfg, batch: 64 };
            Ok((eval_rubric(&fwd, &self.style)?, eval_rubric(&fwd, &self.general)?))
        }
    }

    fn engine(&self) -> Engine {
        match &self.rt {
            Some(_) => Engine::Pjrt,
            None => Engine::Native { workers: self.workers },
        }
    }

    /// Run one pipeline configuration (default fp8-e4m3 code format,
    /// no residual).
    pub fn quantize(&self, granularity: Granularity, method: Method)
        -> Result<PipelineOutcome> {
        self.quantize_fmt(granularity, method, CodeFormat::Fp8E4m3, 0)
    }

    /// Run one pipeline configuration under an explicit code format and
    /// residual rank (the CLI's `--format` / `--residual-rank` path).
    pub fn quantize_fmt(
        &self,
        granularity: Granularity,
        method: Method,
        format: CodeFormat,
        residual_rank: usize,
    ) -> Result<PipelineOutcome> {
        let mut cfg = PipelineConfig::new(granularity, method, self.engine());
        cfg.format = format;
        cfg.residual_rank = residual_rank;
        run_pipeline(&self.post, &self.base, &self.quantizable,
                     Some(&self.calib), &cfg, self.rt.as_ref())
    }

    /// Run with the native engine regardless of PJRT availability (used
    /// by perf comparisons).
    pub fn quantize_native(&self, granularity: Granularity, method: Method)
        -> Result<PipelineOutcome> {
        let cfg = PipelineConfig::new(
            granularity,
            method,
            Engine::Native { workers: self.workers },
        );
        run_pipeline(&self.post, &self.base, &self.quantizable,
                     Some(&self.calib), &cfg, None)
    }
}

/// Infer quantizable names without a manifest: 2-D weights following the
/// model naming convention.
pub fn quantizable_from_names(post: &Dts) -> Vec<String> {
    quantizable_from_source(post)
}

/// [`quantizable_from_names`] over any checkpoint backend (monolithic or
/// sharded) — shapes come from the index, so no payload is read.
pub fn quantizable_from_source(post: &dyn crate::io::TensorSource) -> Vec<String> {
    post.names()
        .into_iter()
        .filter(|n| {
            let is_linear = n.ends_with(".wq") || n.ends_with(".wk")
                || n.ends_with(".wv") || n.ends_with(".wo")
                || n.ends_with(".w1") || n.ends_with(".w2")
                || n.as_str() == "head";
            is_linear && post.shape_of(n).map(|s| s.len() == 2).unwrap_or(false)
        })
        .collect()
}

pub const PAPER_RANGES: [(f32, f32); 3] = [(0.5, 2.0), (0.8, 1.25), (0.9, 1.11)];

fn range_label(r: (f32, f32)) -> String {
    format!("[{}, {}]", r.0, r.1)
}

fn outcome_row(
    label: &str,
    out: &PipelineOutcome,
    scores: (f64, f64),
) -> Vec<String> {
    match &out.agg {
        Some(a) => vec![
            label.to_string(),
            fmt_l2(a.delta_l2()),
            fmt_pct(a.sign_rate()),
            fmt3(a.cos_sim()),
            fmt3(scores.0),
            fmt3(scores.1),
        ],
        None => vec![
            label.to_string(),
            na(),
            na(),
            na(),
            fmt3(scores.0),
            fmt3(scores.1),
        ],
    }
}

/// Table 2 — baseline comparison: Base / Post BF16 references, AbsMax FP8
/// (block & channel), SmoothQuant, AWQ.
pub fn table2(lab: &Lab) -> Result<Table> {
    let mut t = Table::new(
        "Table 2: Baseline comparison",
        &["Model", "dW L2", "SignRate", "CosSim", "Style", "General"],
    );

    let base_params = load_params(&lab.base)?;
    let (s, g) = lab.rubric(&base_params)?;
    t.row(vec!["Base (f32)".into(), "-".into(), "-".into(), "-".into(),
               fmt3(s), fmt3(g)]);

    let post_params = load_params(&lab.post)?;
    let (s, g) = lab.rubric(&post_params)?;
    t.row(vec!["Post-trained (f32)".into(), "0".into(), "100.00%".into(),
               "1.000".into(), fmt3(s), fmt3(g)]);

    for gran in [Granularity::Block(128), Granularity::PerChannel] {
        let out = lab.quantize(gran, Method::AbsMax)?;
        let scores = lab.rubric(&out.params)?;
        t.row(outcome_row(
            &format!("AbsMax (FP8 {})", gran.label()), &out, scores));
    }

    let out = lab.quantize(Granularity::PerChannel,
                           Method::SmoothQuant { alpha: 0.5 })?;
    let scores = lab.rubric(&out.params)?;
    t.row(outcome_row("SmoothQuant (FP8 channel)", &out, scores));

    let out = lab.quantize(Granularity::PerChannel, Method::Awq)?;
    let scores = lab.rubric(&out.params)?;
    t.row(outcome_row("AWQ (FP8 channel)", &out, scores));

    Ok(t)
}

/// Tables 3/4/5 — scale search under one objective over the paper's
/// {block, channel} × three ranges grid.
pub fn table_search(lab: &Lab, objective: Objective) -> Result<Table> {
    let number = match objective {
        Objective::NegMse => 3,
        Objective::SignRate => 4,
        Objective::CosSim => 5,
        Objective::Hybrid => 6, // extension: §3.5(3)'s suggested hybrid
    };
    let mut t = Table::new(
        &format!("Table {number}: scale search with {} metric", objective.label()),
        &["Type", "Range", "dW L2", "SignRate", "CosSim", "Style", "General"],
    );
    for (gran, gname) in [(Granularity::Block(128), "Block"),
                          (Granularity::PerChannel, "Channel")] {
        for range in PAPER_RANGES {
            let out = lab.quantize(gran, Method::Search { objective, range })?;
            let (s, g) = lab.rubric(&out.params)?;
            let a = out.agg.as_ref().unwrap();
            t.row(vec![
                gname.to_string(),
                range_label(range),
                fmt_l2(a.delta_l2()),
                fmt_pct(a.sign_rate()),
                fmt3(a.cos_sim()),
                fmt3(s),
                fmt3(g),
            ]);
        }
    }
    Ok(t)
}

/// Table 1 — metric characterization: range/delta-awareness (definition)
/// plus *measured* per-element evaluation cost on this machine.
pub fn table1(iters_tensor: &crate::tensor::Tensor,
              base_tensor: &crate::tensor::Tensor) -> Result<Table> {
    use crate::metrics::sweep_native;
    use crate::quant::absmax_scales;
    use crate::util::bench::bench;

    let s0 = absmax_scales(iters_tensor, Granularity::Block(128));
    let n = iters_tensor.len() as f64;

    // cost of evaluating each metric = shared sweep + metric closure; we
    // report the end-to-end per-element cost of a 1-candidate sweep and
    // the (negligible) closed-form metric extraction.
    let r = bench("sweep1", 1, 5, || {
        sweep_native(iters_tensor, base_tensor, &s0, &[1.0])
    });
    let per_elem_ns = r.mean_s * 1e9 / n;

    let mut t = Table::new(
        "Table 1: metric comparison",
        &["Metric", "Range", "Delta-Aware", "Complexity", "ns/elem (measured)"],
    );
    t.row(vec!["MSE".into(), "[0, +inf)".into(), "No".into(), "Low".into(),
               format!("{per_elem_ns:.1}")]);
    t.row(vec!["SignRate".into(), "[0, 1]".into(), "Yes".into(), "Low".into(),
               format!("{per_elem_ns:.1}")]);
    t.row(vec!["CosSim".into(), "[-1, 1]".into(), "Yes".into(), "Medium".into(),
               format!("{per_elem_ns:.1}")]);
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::dts::Dts;
    use crate::tensor::Tensor;
    use crate::util::rng::XorShift;

    #[test]
    fn quantizable_inference() {
        let mut d = Dts::new();
        let mut rng = XorShift::new(1);
        for n in ["l0.wq", "l0.ln1.g", "embed", "head", "l0.w2"] {
            d.insert_f32(n, &Tensor::new(vec![4, 4], rng.normal_vec(16, 1.0)));
        }
        let q = quantizable_from_names(&d);
        assert_eq!(q, vec!["l0.wq".to_string(), "head".into(), "l0.w2".into()]
            .into_iter().filter(|n| q.contains(n)).collect::<Vec<_>>());
        assert!(q.contains(&"l0.wq".to_string()));
        assert!(q.contains(&"head".to_string()));
        assert!(!q.contains(&"embed".to_string()));
        assert!(!q.contains(&"l0.ln1.g".to_string()));
    }

    #[test]
    fn table1_renders() {
        let mut rng = XorShift::new(2);
        let w = Tensor::new(vec![64, 64], rng.normal_vec(64 * 64, 0.1));
        let b = Tensor::new(vec![64, 64], rng.normal_vec(64 * 64, 0.1));
        let t = table1(&w, &b).unwrap();
        assert_eq!(t.n_rows(), 3);
        assert!(t.render().contains("SignRate"));
    }
}
