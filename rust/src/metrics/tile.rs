//! Cache-sized tile kernel for the planned sweep ([`super::SweepPlan`]).
//!
//! A tile is a contiguous row-major run of elements small enough that its
//! candidate-invariant plan state stays cache-resident while every
//! candidate streams over it. The inner loop is branch- and division-free:
//!
//! * the scale and its reciprocal come from per-candidate tables computed
//!   once per batch, fed to the caller-supplied reciprocal-multiply qdq
//!   projection (`fp8::qdq_e4m3_scaled` or its per-format twins, see
//!   [`crate::quant::CodeFormat`] — the kernel is generic, monomorphized
//!   per projection);
//! * sign agreement counts through integer compares (`setcc`-style, no
//!   data-dependent branches);
//! * per-candidate sums accumulate in registers and merge at the tile
//!   boundary in a deterministic, fixed order.
//!
//! Accumulators are f64 (not the f32-in-tile variant the design sketch
//! floated): f32 partials lose ~1e-5 relative accuracy per 2k-element
//! tile, which would break the 1e-9 agreement bar against `sweep_native`,
//! and f64 adds cost the same as f32 on scalar CPUs.

/// Elements per tile: ~2k elements × ~17 B of per-element plan state
/// (p, b, Δp, sign, scale index) ≈ 34 KB — sized to sit in L1/L2 while
/// amortizing per-tile loop and merge overhead.
pub const DEFAULT_TILE: usize = 2048;

/// Branchless `sign` in {−1, 0, 1}; NaN → 0 (matches `jnp.sign`).
#[inline(always)]
pub(crate) fn sign_i8(x: f32) -> i8 {
    (x > 0.0) as i8 - (x < 0.0) as i8
}

/// Borrowed per-tile slices of the plan's candidate-invariant state.
pub struct TileView<'a> {
    /// Post-trained weights.
    pub p: &'a [f32],
    /// Base weights.
    pub b: &'a [f32],
    /// Δp = p − b.
    pub dp: &'a [f32],
    /// sign(Δp) in {−1, 0, 1}.
    pub sp: &'a [i8],
    /// Per-element index into the compact scale table.
    pub scale_idx: &'a [u32],
}

/// Per-candidate partial statistics of one tile. The candidate-invariant
/// terms (‖Δp‖², N) are tracked once by the plan, not per tile×candidate.
pub struct TileStats {
    pub agree: Vec<u64>,
    pub dot: Vec<f64>,
    pub nq: Vec<f64>,
    pub sq: Vec<f64>,
}

/// Evaluate every candidate over one tile.
///
/// `s_tab` / `inv_tab` are laid out `[candidate][region]` with
/// `n_regions` columns: `s_tab[k·R + r] = scales[r]·α_k` and
/// `inv_tab[k·R + r] = 1 / s_tab[k·R + r]` — the exact same scalar
/// computation `sweep_native` performs per element, hoisted. `qdq` is the
/// format's scaled projection `(x, s⁻¹, s) → qdq(x·s⁻¹)·s`; passing the
/// same fn item the pointwise reference uses keeps the two engines
/// bit-identical per format.
pub fn eval_tile<F: Fn(f32, f32, f32) -> f32>(
    v: &TileView,
    s_tab: &[f32],
    inv_tab: &[f32],
    n_regions: usize,
    n_candidates: usize,
    qdq: F,
) -> TileStats {
    let len = v.p.len();
    debug_assert_eq!(v.b.len(), len);
    debug_assert_eq!(v.dp.len(), len);
    debug_assert_eq!(v.sp.len(), len);
    debug_assert_eq!(v.scale_idx.len(), len);
    debug_assert_eq!(s_tab.len(), n_regions * n_candidates);
    debug_assert_eq!(inv_tab.len(), n_regions * n_candidates);

    let mut st = TileStats {
        agree: vec![0u64; n_candidates],
        dot: vec![0.0f64; n_candidates],
        nq: vec![0.0f64; n_candidates],
        sq: vec![0.0f64; n_candidates],
    };
    for k in 0..n_candidates {
        let s_row = &s_tab[k * n_regions..(k + 1) * n_regions];
        let inv_row = &inv_tab[k * n_regions..(k + 1) * n_regions];
        let mut agree = 0u64;
        let (mut dot, mut nq, mut sq) = (0.0f64, 0.0f64, 0.0f64);
        for i in 0..len {
            let si = v.scale_idx[i] as usize;
            let q = qdq(v.p[i], inv_row[si], s_row[si]);
            let dq = q - v.b[i];
            let err = q - v.p[i];
            agree += (sign_i8(dq) == v.sp[i]) as u64;
            // term shapes mirror sweep_native exactly (f32 products widened
            // for nq/sq, f64 product for dot) so only the cross-tile merge
            // order can differ from the reference
            dot += dq as f64 * v.dp[i] as f64;
            nq += (dq * dq) as f64;
            sq += (err * err) as f64;
        }
        st.agree[k] = agree;
        st.dot[k] = dot;
        st.nq[k] = nq;
        st.sq[k] = sq;
    }
    st
}

/// Format-dispatching tile evaluation: runs the SIMD tile kernel
/// ([`crate::quant::kernels::eval_tile_simd`]) when the active dispatch
/// mode has one, else [`eval_tile`] with the format's scalar scaled
/// projection. Per element the two produce bitwise-equal projections;
/// only the f64 accumulation order differs (per-ISA fixed lane partials
/// vs element order), which is covered by the sweep's 1e-9 agreement
/// bar and stays invariant across worker counts on a fixed ISA.
pub fn eval_tile_fmt(
    v: &TileView,
    s_tab: &[f32],
    inv_tab: &[f32],
    n_regions: usize,
    n_candidates: usize,
    format: crate::quant::CodeFormat,
) -> TileStats {
    let simd = crate::quant::kernels::eval_tile_simd(
        format,
        v.p,
        v.b,
        v.dp,
        v.sp,
        v.scale_idx,
        s_tab,
        inv_tab,
        n_regions,
        n_candidates,
    );
    if let Some(p) = simd {
        return TileStats { agree: p.agree, dot: p.dot, nq: p.nq, sq: p.sq };
    }
    use crate::quant::CodeFormat;
    match format {
        CodeFormat::Fp8E4m3 => {
            eval_tile(v, s_tab, inv_tab, n_regions, n_candidates, crate::fp8::qdq_e4m3_scaled)
        }
        CodeFormat::Fp8E5m2 => {
            eval_tile(v, s_tab, inv_tab, n_regions, n_candidates, crate::fp8::qdq_e5m2_scaled)
        }
        CodeFormat::Int4 { .. } => {
            let qdq = crate::quant::format::qdq_int4_scaled;
            eval_tile(v, s_tab, inv_tab, n_regions, n_candidates, qdq)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sign_i8_matches_branching_sign() {
        for (x, want) in [
            (1.5f32, 1i8),
            (-0.25, -1),
            (0.0, 0),
            (-0.0, 0),
            (f32::NAN, 0),
            (f32::INFINITY, 1),
            (f32::NEG_INFINITY, -1),
        ] {
            assert_eq!(sign_i8(x), want, "sign({x})");
        }
    }

    #[test]
    fn single_element_tile_against_hand_computation() {
        let (p, b) = (0.5f32, 0.4f32);
        let dp = p - b;
        let s = 0.01f32;
        let inv = 1.0 / s;
        let v = TileView {
            p: &[p],
            b: &[b],
            dp: &[dp],
            sp: &[sign_i8(dp)],
            scale_idx: &[0],
        };
        let st = eval_tile(&v, &[s], &[inv], 1, 1, crate::fp8::qdq_e4m3_scaled);
        let q = crate::fp8::qdq_e4m3_scaled(p, inv, s);
        let dq = q - b;
        let err = q - p;
        assert_eq!(st.agree[0], (sign_i8(dq) == sign_i8(dp)) as u64);
        assert_eq!(st.dot[0], dq as f64 * dp as f64);
        assert_eq!(st.nq[0], (dq * dq) as f64);
        assert_eq!(st.sq[0], (err * err) as f64);
    }

    #[test]
    fn empty_tile_is_all_zero() {
        let v = TileView { p: &[], b: &[], dp: &[], sp: &[], scale_idx: &[] };
        let st =
            eval_tile(&v, &[1.0, 2.0], &[1.0, 0.5], 1, 2, crate::fp8::qdq_e4m3_scaled);
        assert_eq!(st.agree, vec![0, 0]);
        assert_eq!(st.dot, vec![0.0, 0.0]);
    }
}
