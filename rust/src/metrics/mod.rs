//! Delta-aware metrics (paper §2.3): Sign Preservation Rate, Cosine
//! Similarity, plus the conventional MSE and the ΔW L2 norm the paper's
//! tables report.
//!
//! Everything is computed from six *sufficient statistics* accumulated in
//! one pass — the same contract as the fused Pallas sweep kernel
//! (`python/compile/kernels/delta_metrics.py`), so the native engine and
//! the PJRT engine are interchangeable inside the search.

pub mod sweep;
pub mod tile;

pub use sweep::SweepPlan;

use crate::fp8;
use crate::quant::ScaleGrid;
use crate::tensor::Tensor;

/// Sufficient statistics of all delta metrics over one tensor:
/// `[sign_agree, Δq·Δp, ‖Δq‖², ‖Δp‖², ‖Wq−Wp‖², N]`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DeltaStats {
    pub agree: f64,
    pub dot: f64,
    pub nq: f64,
    pub npost: f64,
    pub sq: f64,
    pub n: f64,
}

impl DeltaStats {
    /// Merge statistics from two disjoint element sets (used by the
    /// coordinator to aggregate per-layer stats into model-level rows).
    pub fn merge(&self, other: &DeltaStats) -> DeltaStats {
        DeltaStats {
            agree: self.agree + other.agree,
            dot: self.dot + other.dot,
            nq: self.nq + other.nq,
            npost: self.npost + other.npost,
            sq: self.sq + other.sq,
            n: self.n + other.n,
        }
    }

    /// Sign Preservation Rate (paper Eq. 8) in [0, 1].
    pub fn sign_rate(&self) -> f64 {
        if self.n == 0.0 {
            return 1.0;
        }
        self.agree / self.n
    }

    /// Cosine Similarity (paper Eq. 9) in [-1, 1].
    pub fn cos_sim(&self) -> f64 {
        let denom = (self.nq * self.npost).sqrt();
        if denom <= 0.0 {
            return 0.0;
        }
        self.dot / denom
    }

    /// Mean Squared Error (paper Eq. 6).
    pub fn mse(&self) -> f64 {
        if self.n == 0.0 {
            return 0.0;
        }
        self.sq / self.n
    }

    /// ‖ΔW_quant‖₂ — the "ΔW L2" column of the paper's tables.
    pub fn delta_l2(&self) -> f64 {
        self.nq.sqrt()
    }

    /// Build from a stats row produced by the PJRT sweep artifact.
    pub fn from_row(row: &[f32]) -> DeltaStats {
        DeltaStats {
            agree: row[0] as f64,
            dot: row[1] as f64,
            nq: row[2] as f64,
            npost: row[3] as f64,
            sq: row[4] as f64,
            n: row[5] as f64,
        }
    }
}

/// `jnp.sign`-semantics sign in {−1, 0, 1} (NaN → 0) — delegates to the
/// branchless [`tile::sign_i8`] so the reference sweeps and the tiled
/// engine share one implementation of the contract the cross-engine
/// bit-exactness tests depend on.
#[inline(always)]
fn sign(x: f32) -> i8 {
    tile::sign_i8(x)
}

/// One-pass statistics of a given quantized tensor vs (post, base).
pub fn delta_stats(w_post: &Tensor, w_base: &Tensor, w_quant: &Tensor) -> DeltaStats {
    assert_eq!(w_post.shape(), w_base.shape());
    assert_eq!(w_post.shape(), w_quant.shape());
    let mut s = DeltaStats::default();
    for ((&wp, &wb), &wq) in w_post
        .data()
        .iter()
        .zip(w_base.data())
        .zip(w_quant.data())
    {
        let dp = wp - wb;
        let dq = wq - wb;
        let err = wq - wp;
        if sign(dp) == sign(dq) {
            s.agree += 1.0;
        }
        s.dot += (dq * dp) as f64;
        s.nq += (dq * dq) as f64;
        s.npost += (dp * dp) as f64;
        s.sq += (err * err) as f64;
        s.n += 1.0;
    }
    s
}

/// The fused native sweep — L3's implementation of the L1 Pallas kernel's
/// contract: for each candidate alpha, quantize `w_post` under `s0·alpha`
/// and accumulate all six statistics in a single pass over the tensor.
///
/// Layout: the inner loop runs over candidates for one element so each
/// element (and its scale lookup) is loaded once — the scalar-CPU analogue
/// of the kernel's HBM-tile reuse.
///
/// This is the *reference* sweep: straightforward, recomputing everything
/// per call. The production engine is the planned, tiled
/// [`SweepPlan`](sweep::SweepPlan), which hoists all candidate-invariant
/// state out of the loop and is verified against this function across
/// every granularity. Both use the canonical reciprocal-multiply scaled
/// projection (`qdq(p·s⁻¹)·s`, [`fp8::qdq_e4m3_scaled`] and its
/// per-format twins, dispatched on the grid's `CodeFormat`), so their
/// sign counts match bit-for-bit at every format.
pub fn sweep_native(
    w_post: &Tensor,
    w_base: &Tensor,
    s0: &ScaleGrid,
    alphas: &[f32],
) -> Vec<DeltaStats> {
    assert_eq!(w_post.shape(), w_base.shape());
    let (rows, cols) = (w_post.rows(), w_post.cols());
    let nc = alphas.len();
    let mut stats = vec![DeltaStats::default(); nc];
    let wp = w_post.data();
    let wb = w_base.data();
    let fmt = s0.format;
    for r in 0..rows {
        for c in 0..cols {
            let idx = r * cols + c;
            let p = wp[idx];
            let b = wb[idx];
            let dp = p - b;
            let sp = sign(dp);
            let dp64 = dp as f64;
            let s_base = s0.at(r, c);
            for (k, &alpha) in alphas.iter().enumerate() {
                let s = s_base * alpha;
                let inv_s = fp8::recip_scale(s);
                let q = fmt.qdq_scaled(p, inv_s, s);
                let dq = q - b;
                let err = q - p;
                let st = &mut stats[k];
                if sign(dq) == sp {
                    st.agree += 1.0;
                }
                st.dot += dq as f64 * dp64;
                st.nq += (dq * dq) as f64;
                st.npost += (dp * dp) as f64;
                st.sq += (err * err) as f64;
                st.n += 1.0;
            }
        }
    }
    stats
}

/// Region-hoisted fused sweep (§Perf pass, iteration 1). Identical
/// statistics to [`sweep_native`], restructured as follows:
///
/// * iterates scale *regions* (block / channel / tensor) so the per-
///   element `ScaleGrid::at` lookup and the per-candidate `s0·α` multiply
///   hoist out of the inner loops;
/// * the candidate-invariant terms (‖Δp‖², N) accumulate once per element
///   instead of once per (element × candidate);
/// * sign agreement counts in integer registers (f64 adds removed from
///   the comparison path);
/// * per-region f64 partial sums merge at region end (also improves
///   summation accuracy).
///
/// Measured 0.93-0.95x vs the straightforward loop on the 1-core testbed
/// (the division + f64 accumulation dominate; hoisting the lookup does
/// not pay for the extra indirection) — kept as the documented negative
/// result of the perf pass and exercised by perf_hotpath. Superseded as
/// the fast path by the planned, tiled [`SweepPlan`](sweep::SweepPlan),
/// which additionally removes the per-element division and precomputes
/// Δp/sign(Δp) across candidate batches (see ROADMAP §Perf log).
pub fn sweep_native_regions(
    w_post: &Tensor,
    w_base: &Tensor,
    s0: &ScaleGrid,
    alphas: &[f32],
) -> Vec<DeltaStats> {
    assert_eq!(w_post.shape(), w_base.shape());
    let (rows, cols) = (w_post.rows(), w_post.cols());
    let nc = alphas.len();
    let wp = w_post.data();
    let wb = w_base.data();

    let mut stats = vec![DeltaStats::default(); nc];
    let mut npost_total = 0.0f64;

    // per-candidate region accumulators
    let mut agree = vec![0u64; nc];
    let mut dot = vec![0.0f64; nc];
    let mut nq = vec![0.0f64; nc];
    let mut sq = vec![0.0f64; nc];
    let mut scales = vec![0.0f32; nc];
    let mut inv_scales = vec![0.0f32; nc];
    let fmt = s0.format;

    let mut do_region = |r0: usize, r1: usize, c0: usize, c1: usize, s_base: f32| {
        for (k, &alpha) in alphas.iter().enumerate() {
            scales[k] = s_base * alpha;
            inv_scales[k] = fp8::recip_scale(scales[k]);
        }
        for r in r0..r1 {
            let row_p = &wp[r * cols + c0..r * cols + c1];
            let row_b = &wb[r * cols + c0..r * cols + c1];
            for (&p, &b) in row_p.iter().zip(row_b) {
                let dp = p - b;
                let sp = sign(dp);
                let dp64 = dp as f64;
                npost_total += dp64 * dp64;
                for k in 0..nc {
                    let q = fmt.qdq_scaled(p, inv_scales[k], scales[k]);
                    let dq = q - b;
                    let err = q - p;
                    agree[k] += (sign(dq) == sp) as u64;
                    dot[k] += dq as f64 * dp64;
                    nq[k] += (dq * dq) as f64;
                    sq[k] += (err * err) as f64;
                }
            }
        }
    };

    match s0.granularity {
        crate::quant::Granularity::PerTensor => {
            do_region(0, rows, 0, cols, s0.scales[0]);
        }
        crate::quant::Granularity::PerChannel => {
            // row-major traversal with a precomputed (candidate × column)
            // scale table — column-regions would stride the cache
            let mut col_scales = vec![0.0f32; nc * cols];
            let mut inv_col_scales = vec![0.0f32; nc * cols];
            for (k, &alpha) in alphas.iter().enumerate() {
                for c in 0..cols {
                    let s = s0.scales[c] * alpha;
                    col_scales[k * cols + c] = s;
                    inv_col_scales[k * cols + c] = fp8::recip_scale(s);
                }
            }
            for r in 0..rows {
                let row_p = &wp[r * cols..(r + 1) * cols];
                let row_b = &wb[r * cols..(r + 1) * cols];
                for c in 0..cols {
                    let p = row_p[c];
                    let b = row_b[c];
                    let dp = p - b;
                    let sp = sign(dp);
                    let dp64 = dp as f64;
                    npost_total += dp64 * dp64;
                    for k in 0..nc {
                        let q = fmt.qdq_scaled(
                            p,
                            inv_col_scales[k * cols + c],
                            col_scales[k * cols + c],
                        );
                        let dq = q - b;
                        let err = q - p;
                        agree[k] += (sign(dq) == sp) as u64;
                        dot[k] += dq as f64 * dp64;
                        nq[k] += (dq * dq) as f64;
                        sq[k] += (err * err) as f64;
                    }
                }
            }
        }
        crate::quant::Granularity::Block(b) => {
            for gr in 0..s0.grid_rows {
                for gc in 0..s0.grid_cols {
                    do_region(
                        gr * b,
                        ((gr + 1) * b).min(rows),
                        gc * b,
                        ((gc + 1) * b).min(cols),
                        s0.scales[gr * s0.grid_cols + gc],
                    );
                }
            }
        }
    }

    let n = (rows * cols) as f64;
    for k in 0..nc {
        stats[k] = DeltaStats {
            agree: agree[k] as f64,
            dot: dot[k],
            nq: nq[k],
            npost: npost_total,
            sq: sq[k],
            n,
        };
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{absmax_scales, qdq, Granularity};
    use crate::util::rng::XorShift;

    fn pair(r: usize, c: usize, delta: f32, seed: u64) -> (Tensor, Tensor) {
        let mut rng = XorShift::new(seed);
        let wb = Tensor::new(vec![r, c], rng.normal_vec(r * c, 0.1));
        let wp = Tensor::new(
            vec![r, c],
            wb.data().iter().map(|&b| b + rng.normal() * delta).collect(),
        );
        (wp, wb)
    }

    #[test]
    fn perfect_quantization_stats() {
        let (wp, wb) = pair(16, 16, 0.01, 1);
        let s = delta_stats(&wp, &wb, &wp);
        assert_eq!(s.sign_rate(), 1.0);
        assert!((s.cos_sim() - 1.0).abs() < 1e-9);
        assert_eq!(s.mse(), 0.0);
    }

    #[test]
    fn reverted_to_base_stats() {
        // quantizing all the way back to the base: delta_quant = 0
        let (wp, wb) = pair(16, 16, 0.01, 2);
        let s = delta_stats(&wp, &wb, &wb);
        assert_eq!(s.cos_sim(), 0.0); // ‖Δq‖ = 0 -> defined as 0
        assert_eq!(s.delta_l2(), 0.0);
        // sign(0) never equals sign(dp) unless dp == 0
        assert!(s.sign_rate() < 0.05);
    }

    #[test]
    fn reversed_delta_cos_is_minus_one() {
        let (wp, wb) = pair(16, 16, 0.01, 3);
        let reversed = wb.zip(&wp, |b, p| b - (p - b)); // W_base - ΔW
        let s = delta_stats(&wp, &wb, &reversed);
        assert!((s.cos_sim() + 1.0).abs() < 1e-6);
        assert_eq!(s.sign_rate(), 0.0);
    }

    #[test]
    fn eq7_identity() {
        // ||Δq − Δp||² == ||Wq − Wp||² (paper Eq. 7): nq − 2·dot + npost == sq
        let (wp, wb) = pair(64, 64, 0.005, 4);
        let s0 = absmax_scales(&wp, Granularity::Block(32));
        let wq = qdq(&wp, &s0, 1.0);
        let s = delta_stats(&wp, &wb, &wq);
        let lhs = s.nq - 2.0 * s.dot + s.npost;
        assert!((lhs - s.sq).abs() < 1e-6 * s.sq.max(1e-12), "{lhs} vs {}", s.sq);
    }

    #[test]
    fn sweep_matches_pointwise_stats() {
        let (wp, wb) = pair(64, 96, 0.003, 5);
        let s0 = absmax_scales(&wp, Granularity::PerChannel);
        let alphas = [0.7f32, 1.0, 1.3];
        let sweep = sweep_native(&wp, &wb, &s0, &alphas);
        for (k, &alpha) in alphas.iter().enumerate() {
            let wq = qdq(&wp, &s0, alpha);
            let direct = delta_stats(&wp, &wb, &wq);
            let sw = &sweep[k];
            assert_eq!(sw.agree, direct.agree, "alpha {alpha}");
            assert!((sw.dot - direct.dot).abs() < 1e-9);
            assert!((sw.nq - direct.nq).abs() < 1e-9);
            assert!((sw.sq - direct.sq).abs() < 1e-9);
            assert_eq!(sw.n, direct.n);
        }
    }

    #[test]
    fn sweep_matches_pointwise_stats_every_format() {
        use crate::quant::{absmax_scales_fmt, CodeFormat};
        let (wp, wb) = pair(48, 70, 0.003, 55);
        let alphas = [0.7f32, 1.0, 1.3];
        for fmt in [CodeFormat::Fp8E5m2, CodeFormat::Int4 { group: 32 }] {
            let s0 = absmax_scales_fmt(&wp, Granularity::Block(32), fmt);
            let sweep = sweep_native(&wp, &wb, &s0, &alphas);
            let regions = sweep_native_regions(&wp, &wb, &s0, &alphas);
            for (k, &alpha) in alphas.iter().enumerate() {
                let wq = qdq(&wp, &s0, alpha);
                let direct = delta_stats(&wp, &wb, &wq);
                let sw = &sweep[k];
                assert_eq!(sw.agree, direct.agree, "{fmt:?} alpha {alpha}");
                assert!((sw.dot - direct.dot).abs() < 1e-9, "{fmt:?}");
                assert!((sw.nq - direct.nq).abs() < 1e-9, "{fmt:?}");
                assert!((sw.sq - direct.sq).abs() < 1e-9, "{fmt:?}");
                assert_eq!(sw.n, direct.n);
                let rg = &regions[k];
                assert_eq!(rg.agree, direct.agree, "{fmt:?} regions");
                assert!((rg.sq - direct.sq).abs() < 1e-9 * direct.sq.max(1e-9));
            }
        }
    }

    #[test]
    fn merge_is_concatenation() {
        let (wp, wb) = pair(32, 32, 0.004, 6);
        let s0 = absmax_scales(&wp, Granularity::PerTensor);
        let wq = qdq(&wp, &s0, 1.0);
        let whole = delta_stats(&wp, &wb, &wq);
        // split rows into two halves and merge
        let split = |t: &Tensor, lo: usize, hi: usize| {
            Tensor::new(
                vec![hi - lo, 32],
                t.data()[lo * 32..hi * 32].to_vec(),
            )
        };
        let a = delta_stats(&split(&wp, 0, 16), &split(&wb, 0, 16), &split(&wq, 0, 16));
        let b = delta_stats(&split(&wp, 16, 32), &split(&wb, 16, 32), &split(&wq, 16, 32));
        let merged = a.merge(&b);
        assert_eq!(merged.agree, whole.agree);
        assert!((merged.sq - whole.sq).abs() < 1e-12);
        assert_eq!(merged.n, whole.n);
    }

    #[test]
    fn metric_ranges() {
        use crate::util::proptest::{run, Config};
        run("metric ranges", Config { cases: 24, ..Config::default() }, |g| {
            let r = g.usize_range(2, 32);
            let c = g.usize_range(2, 32);
            let wb = Tensor::new(vec![r, c], g.normal_vec(r * c, 0.2));
            let wp = Tensor::new(
                vec![r, c],
                wb.data().iter().map(|&b| b + 0.01).collect(),
            );
            let s0 = absmax_scales(&wp, Granularity::PerTensor);
            let alpha = g.f32_range(0.5, 2.0);
            let wq = qdq(&wp, &s0, alpha);
            let s = delta_stats(&wp, &wb, &wq);
            assert!((0.0..=1.0).contains(&s.sign_rate()));
            assert!((-1.0 - 1e-9..=1.0 + 1e-9).contains(&s.cos_sim()));
            assert!(s.mse() >= 0.0);
            assert!(s.delta_l2() >= 0.0);
        });
    }

    #[test]
    fn optimized_sweep_equals_naive_all_granularities() {
        let (wp, wb) = pair(96, 160, 0.003, 77);
        let alphas = [0.5f32, 0.8, 1.0, 1.11, 2.0];
        for gran in [
            Granularity::PerTensor,
            Granularity::PerChannel,
            Granularity::Block(32),
            Granularity::Block(128), // ragged: 96x160 -> 1x2 grid
        ] {
            let s0 = absmax_scales(&wp, gran);
            let fast = sweep_native_regions(&wp, &wb, &s0, &alphas);
            let slow = sweep_native(&wp, &wb, &s0, &alphas);
            for (k, (a, b)) in fast.iter().zip(&slow).enumerate() {
                assert_eq!(a.agree, b.agree, "{gran:?} cand {k} agree");
                assert_eq!(a.n, b.n);
                let close = |x: f64, y: f64, name: &str| {
                    assert!(
                        (x - y).abs() <= 1e-9 * x.abs().max(1e-9),
                        "{gran:?} cand {k} {name}: {x} vs {y}"
                    );
                };
                close(a.dot, b.dot, "dot");
                close(a.nq, b.nq, "nq");
                close(a.npost, b.npost, "npost");
                close(a.sq, b.sq, "sq");
            }
        }
    }

    #[test]
    fn from_row_roundtrip() {
        let row = [10.0f32, 0.5, 2.0, 3.0, 0.25, 100.0];
        let s = DeltaStats::from_row(&row);
        assert_eq!(s.agree, 10.0);
        assert_eq!(s.sign_rate(), 0.1);
        assert!((s.cos_sim() - 0.5 / 6.0f64.sqrt()).abs() < 1e-9);
    }

    #[test]
    fn empty_tensor_stats() {
        let e = Tensor::new(vec![0, 4], vec![]);
        let s = delta_stats(&e, &e, &e);
        assert_eq!(s.sign_rate(), 1.0);
        assert_eq!(s.cos_sim(), 0.0);
        assert_eq!(s.mse(), 0.0);
    }
}
