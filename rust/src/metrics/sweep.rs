//! The planned, tiled sweep — the production engine under Algorithm 1's
//! hot loop.
//!
//! `sweep_native` recomputes everything per candidate batch: Δp, sign(Δp),
//! the per-element scale lookup, and a reciprocal per (element ×
//! candidate). Algorithm 1 runs 16 candidate evaluations per layer per
//! objective over the *same* (layer, granularity), so all of that state is
//! candidate-invariant. A [`SweepPlan`] computes it once:
//!
//! * Δp, sign(Δp), and the per-element index into the compact scale table
//!   (the `ScaleGrid` resolved to a flat, granularity-free lookup);
//! * the global ‖Δp‖² and N totals (identical for every candidate);
//! * a tile decomposition of the element stream ([`tile::DEFAULT_TILE`]).
//!
//! Evaluating a candidate batch then reduces to: build the per-candidate
//! `scales·α` / reciprocal tables (one division per candidate × region —
//! thousands, not millions), and stream every tile through the branchless
//! division-free kernel [`tile::eval_tile`]. Tiles are independent, so
//! they fan out over `util::threadpool::par_map_slice`; partials merge in
//! fixed tile order, making the result bitwise-identical for every worker
//! count.

use super::tile::{self, sign_i8, TileView};
use super::DeltaStats;
use crate::quant::{CodeFormat, ScaleGrid};
use crate::tensor::Tensor;
use crate::util::telemetry;
use crate::util::threadpool::par_map_slice;

/// Precomputed candidate-invariant sweep state for one (layer,
/// granularity); build once, evaluate any number of candidate batches.
pub struct SweepPlan {
    rows: usize,
    cols: usize,
    /// Post-trained weights (flat row-major copy).
    p: Vec<f32>,
    /// Base weights.
    b: Vec<f32>,
    /// Δp = p − b.
    dp: Vec<f32>,
    /// sign(Δp) in {−1, 0, 1}.
    sp: Vec<i8>,
    /// Per-element index into `scales`.
    scale_idx: Vec<u32>,
    /// Compact per-region base scales (copied from the `ScaleGrid`).
    scales: Vec<f32>,
    /// Code format captured from the `ScaleGrid`: selects the qdq
    /// projection the tile kernel is monomorphized over.
    format: CodeFormat,
    /// Σ Δp² — candidate-invariant, accumulated in element order (bitwise
    /// identical to `sweep_native`'s per-candidate accumulation).
    npost: f64,
    /// Elements per tile.
    tile: usize,
}

impl SweepPlan {
    /// Build a plan with the default tile size.
    pub fn new(w_post: &Tensor, w_base: &Tensor, s0: &ScaleGrid) -> SweepPlan {
        Self::with_tile(w_post, w_base, s0, tile::DEFAULT_TILE)
    }

    /// Build a plan with an explicit tile size (elements per tile).
    pub fn with_tile(
        w_post: &Tensor,
        w_base: &Tensor,
        s0: &ScaleGrid,
        tile: usize,
    ) -> SweepPlan {
        assert_eq!(w_post.shape(), w_base.shape());
        assert!(tile > 0, "tile size must be positive");
        let (rows, cols) = (w_post.rows(), w_post.cols());
        assert_eq!((s0.rows, s0.cols), (rows, cols), "ScaleGrid shape mismatch");
        let p = w_post.data().to_vec();
        let b = w_base.data().to_vec();
        let n = rows * cols;
        let mut dp = Vec::with_capacity(n);
        let mut sp = Vec::with_capacity(n);
        let mut scale_idx = Vec::with_capacity(n);
        let mut npost = 0.0f64;
        for r in 0..rows {
            for c in 0..cols {
                let d = p[r * cols + c] - b[r * cols + c];
                dp.push(d);
                sp.push(sign_i8(d));
                npost += (d * d) as f64;
                scale_idx.push(s0.region_index(r, c) as u32);
            }
        }
        SweepPlan {
            rows,
            cols,
            p,
            b,
            dp,
            sp,
            scale_idx,
            scales: s0.scales.clone(),
            format: s0.format,
            npost,
            tile,
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Candidate-invariant ‖Δp‖².
    pub fn npost(&self) -> f64 {
        self.npost
    }

    /// Number of tiles the element stream splits into.
    pub fn tile_count(&self) -> usize {
        self.p.len().div_ceil(self.tile)
    }

    /// Evaluate one candidate batch single-threaded.
    pub fn eval(&self, alphas: &[f32]) -> Vec<DeltaStats> {
        self.eval_with_workers(alphas, 1)
    }

    /// Evaluate one candidate batch with tile-level parallelism.
    ///
    /// Bitwise-deterministic across `workers`: tiles are fixed by the
    /// plan, each tile's partial is computed independently, and partials
    /// merge in tile order regardless of which thread ran them. Each tile
    /// evaluates through [`tile::eval_tile_fmt`], which dispatches on the
    /// plan's [`CodeFormat`] and on the active SIMD mode: the scalar path
    /// uses the same qdq fn items the pointwise `sweep_native` reference
    /// uses, and the SIMD tile kernel keeps every per-element projection
    /// bitwise-equal while summing in per-ISA fixed-order lane partials —
    /// within the 1e-9 planned/native agreement bar, and still bitwise
    /// identical across worker counts on a fixed ISA.
    pub fn eval_with_workers(&self, alphas: &[f32], workers: usize) -> Vec<DeltaStats> {
        let nc = alphas.len();
        if nc == 0 {
            return Vec::new();
        }
        // telemetry handles resolve on the calling thread (which owns the
        // installed context); the tile closure captures the plain atomic
        // handles, so pool threads need no context of their own. All
        // observations are count-valued or commuting adds, keeping
        // snapshots bitwise-identical for every worker count.
        let tel = telemetry::current();
        let tile_hist = tel.histogram("sweep.tile.seconds");
        let cand_hist = tel.histogram("sweep.tile.candidates");
        let t0 = tel.enabled().then(std::time::Instant::now);
        let nr = self.scales.len();
        // per-candidate scale and reciprocal tables: the only divisions in
        // the whole evaluation (candidates × regions, not × elements)
        let mut s_tab = vec![0.0f32; nc * nr];
        let mut inv_tab = vec![0.0f32; nc * nr];
        for (k, &alpha) in alphas.iter().enumerate() {
            for (r, &s0) in self.scales.iter().enumerate() {
                let s = s0 * alpha;
                s_tab[k * nr + r] = s;
                inv_tab[k * nr + r] = crate::fp8::recip_scale(s);
            }
        }

        let n_elems = self.p.len();
        let tiles: Vec<(usize, usize)> = (0..n_elems)
            .step_by(self.tile)
            .map(|lo| (lo, (lo + self.tile).min(n_elems)))
            .collect();
        let parts = par_map_slice(workers, &tiles, |&(lo, hi)| {
            let _t = tile_hist.start_timer();
            cand_hist.observe(nc as f64);
            tile::eval_tile_fmt(
                &TileView {
                    p: &self.p[lo..hi],
                    b: &self.b[lo..hi],
                    dp: &self.dp[lo..hi],
                    sp: &self.sp[lo..hi],
                    scale_idx: &self.scale_idx[lo..hi],
                },
                &s_tab,
                &inv_tab,
                nr,
                nc,
                self.format,
            )
        });

        // deterministic fixed-order merge across tiles
        let mut stats = vec![DeltaStats::default(); nc];
        for (part, &(lo, hi)) in parts.iter().zip(&tiles) {
            let tile_n = (hi - lo) as f64;
            for (k, st) in stats.iter_mut().enumerate() {
                *st = st.merge(&DeltaStats {
                    agree: part.agree[k] as f64,
                    dot: part.dot[k],
                    nq: part.nq[k],
                    npost: 0.0,
                    sq: part.sq[k],
                    n: tile_n,
                });
            }
        }
        for st in &mut stats {
            st.npost = self.npost;
        }
        if let Some(t0) = t0 {
            let evaluated = (nc * n_elems) as u64;
            tel.counter("sweep.candidates_evaluated").add(evaluated);
            let secs = t0.elapsed().as_secs_f64();
            if secs > 0.0 {
                tel.gauge("sweep.melem_per_s")
                    .set(evaluated as f64 / secs / 1e6);
            }
        }
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::sweep_native;
    use crate::quant::{absmax_scales, Granularity};
    use crate::util::rng::XorShift;

    fn pair(r: usize, c: usize, delta: f32, seed: u64) -> (Tensor, Tensor) {
        let mut rng = XorShift::new(seed);
        let wb = Tensor::new(vec![r, c], rng.normal_vec(r * c, 0.1));
        let wp = Tensor::new(
            vec![r, c],
            wb.data().iter().map(|&b| b + rng.normal() * delta).collect(),
        );
        (wp, wb)
    }

    fn assert_close(x: f64, y: f64, what: &str) {
        assert!(
            (x - y).abs() <= 1e-9 * x.abs().max(1e-9),
            "{what}: {x} vs {y}"
        );
    }

    #[test]
    fn planned_matches_sweep_native_all_granularities() {
        // 96x160 makes Block(128) ragged (1x2 grid with edge blocks)
        let (wp, wb) = pair(96, 160, 0.003, 21);
        let alphas = [0.5f32, 0.8, 1.0, 1.11, 2.0];
        for gran in [
            Granularity::PerTensor,
            Granularity::PerChannel,
            Granularity::Block(32),
            Granularity::Block(128),
        ] {
            let s0 = absmax_scales(&wp, gran);
            let want = sweep_native(&wp, &wb, &s0, &alphas);
            let plan = SweepPlan::new(&wp, &wb, &s0);
            for workers in [1usize, 4] {
                let got = plan.eval_with_workers(&alphas, workers);
                for (k, (g, w)) in got.iter().zip(&want).enumerate() {
                    let tag = format!("{gran:?} cand {k} workers {workers}");
                    assert_eq!(g.agree, w.agree, "{tag} agree");
                    assert_eq!(g.n, w.n, "{tag} n");
                    // npost is accumulated in the same element order as the
                    // reference: bitwise equal, not merely close
                    assert_eq!(g.npost.to_bits(), w.npost.to_bits(), "{tag} npost");
                    assert_close(g.dot, w.dot, &format!("{tag} dot"));
                    assert_close(g.nq, w.nq, &format!("{tag} nq"));
                    assert_close(g.sq, w.sq, &format!("{tag} sq"));
                }
            }
        }
    }

    #[test]
    fn bitwise_deterministic_across_worker_counts() {
        let (wp, wb) = pair(128, 96, 0.004, 22);
        let alphas: Vec<f32> = (0..16).map(|i| 0.8 + 0.028 * i as f32).collect();
        for gran in [Granularity::PerChannel, Granularity::Block(32)] {
            let s0 = absmax_scales(&wp, gran);
            // small tile so several tiles exist per worker
            let plan = SweepPlan::with_tile(&wp, &wb, &s0, 512);
            let base = plan.eval_with_workers(&alphas, 1);
            for workers in [2usize, 8] {
                let got = plan.eval_with_workers(&alphas, workers);
                // DeltaStats is PartialEq over f64 fields: exact equality
                // IS the bitwise-determinism assertion
                assert_eq!(got, base, "{gran:?} workers {workers}");
            }
        }
    }

    #[test]
    fn tile_size_changes_only_rounding() {
        let (wp, wb) = pair(64, 80, 0.002, 23);
        let s0 = absmax_scales(&wp, Granularity::Block(16));
        let alphas = [0.9f32, 1.0, 1.1];
        let want = SweepPlan::with_tile(&wp, &wb, &s0, tile::DEFAULT_TILE).eval(&alphas);
        for tile in [1usize, 7, 509] {
            let got = SweepPlan::with_tile(&wp, &wb, &s0, tile).eval(&alphas);
            for (k, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_eq!(g.agree, w.agree, "tile {tile} cand {k}");
                assert_eq!(g.n, w.n);
                assert_close(g.dot, w.dot, "dot");
                assert_close(g.nq, w.nq, "nq");
                assert_close(g.sq, w.sq, "sq");
            }
        }
    }

    #[test]
    fn planned_matches_sweep_native_every_format() {
        use crate::quant::{absmax_scales_fmt, CodeFormat};
        let (wp, wb) = pair(96, 130, 0.003, 25); // odd-ish cols, ragged blocks
        let alphas: Vec<f32> = (0..16).map(|i| 0.75 + 0.03 * i as f32).collect();
        for fmt in [
            CodeFormat::Fp8E5m2,
            CodeFormat::Int4 { group: 64 },
            CodeFormat::Int4 { group: 32 },
        ] {
            let gran = fmt.default_granularity();
            let s0 = absmax_scales_fmt(&wp, gran, fmt);
            let want = sweep_native(&wp, &wb, &s0, &alphas);
            let plan = SweepPlan::with_tile(&wp, &wb, &s0, 512);
            let base = plan.eval_with_workers(&alphas, 1);
            for (k, (g, w)) in base.iter().zip(&want).enumerate() {
                let tag = format!("{fmt:?} cand {k}");
                assert_eq!(g.agree, w.agree, "{tag} agree");
                assert_eq!(g.n, w.n, "{tag} n");
                assert_eq!(g.npost.to_bits(), w.npost.to_bits(), "{tag} npost");
                assert_close(g.dot, w.dot, &format!("{tag} dot"));
                assert_close(g.nq, w.nq, &format!("{tag} nq"));
                assert_close(g.sq, w.sq, &format!("{tag} sq"));
            }
            // bitwise determinism for any worker count, per format
            for workers in [2usize, 4, 8] {
                assert_eq!(
                    plan.eval_with_workers(&alphas, workers),
                    base,
                    "{fmt:?} workers {workers}"
                );
            }
        }
    }

    #[test]
    fn plan_reuse_is_stateless() {
        let (wp, wb) = pair(32, 48, 0.005, 24);
        let s0 = absmax_scales(&wp, Granularity::PerChannel);
        let plan = SweepPlan::new(&wp, &wb, &s0);
        let coarse = [0.8f32, 1.0, 1.25];
        let fine = [0.95f32, 1.0, 1.05];
        let a1 = plan.eval(&coarse);
        let b1 = plan.eval(&fine);
        // evaluating again (other batch in between) must reproduce exactly
        assert_eq!(plan.eval(&coarse), a1);
        assert_eq!(plan.eval(&fine), b1);
        // and match a fresh plan
        assert_eq!(SweepPlan::new(&wp, &wb, &s0).eval(&coarse), a1);
    }

    #[test]
    fn empty_and_degenerate_shapes() {
        let e = Tensor::new(vec![0, 4], vec![]);
        let s0 = absmax_scales(&e, Granularity::PerTensor);
        let plan = SweepPlan::new(&e, &e, &s0);
        let st = plan.eval(&[1.0]);
        assert_eq!(st.len(), 1);
        assert_eq!(st[0].n, 0.0);
        assert_eq!(st[0].npost, 0.0);
        assert!(plan.eval(&[]).is_empty());

        let one = Tensor::new(vec![1, 1], vec![0.5]);
        let s1 = absmax_scales(&one, Granularity::Block(128));
        let plan1 = SweepPlan::new(&one, &one, &s1);
        let st1 = plan1.eval_with_workers(&[1.0], 8);
        assert_eq!(st1[0].n, 1.0);
        assert_eq!(st1[0].npost, 0.0); // identical pair: delta is zero
        assert!(st1[0].sq < 1e-12, "near-exact reconstruction: {}", st1[0].sq);
    }
}
